//! Quickstart: build the paper's multigraph topology for the Gaia
//! network, inspect its states, and compare simulated cycle time with
//! the RING baseline.
//!
//! Run: `cargo run --release --example quickstart`
//! (no artifacts needed — this exercises the pure-topology API).

use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::simulate;
use mgfl::topo::{ring::RingTopology, MultigraphTopology, TopologyDesign};

fn main() {
    // 1. Pick a network and a workload profile (paper Table 2).
    let net = zoo::gaia();
    let profile = DatasetProfile::femnist();
    println!("network: {} ({} silos)", net.name, net.n());

    // 2. Algorithm 1 + 2: overlay -> multigraph -> states.
    let mut ours = MultigraphTopology::from_network(&net, &profile, 5);
    let mg = ours.multigraph();
    println!(
        "multigraph: {} pairs, {} total edges ({} weak), d_min {:.2} ms, {} states",
        mg.edges.len(),
        mg.total_edges(),
        mg.weak_edges(),
        mg.d_min_ms,
        ours.s_max()
    );
    for e in &mg.edges {
        println!(
            "  {:<11} – {:<11} delay {:6.2} ms -> n = {}",
            net.silos[e.u].name, net.silos[e.v].name, e.delay_ms, e.n_edges
        );
    }

    // 3. A few states with their isolated nodes.
    println!("\nfirst four states (S = strong edge count):");
    for s in 0..ours.s_max().min(4) {
        let plan = ours.plan_for_state(s);
        let iso: Vec<&str> =
            plan.isolated_nodes().iter().map(|&i| net.silos[i].name.as_str()).collect();
        println!(
            "  state {s}: S={} isolated={:?}",
            plan.strong_edges().count(),
            iso
        );
    }

    // 4. Cycle-time comparison (Eq. 5) over 6400 rounds, as in Table 1.
    let rounds = 6400;
    let mut ring = RingTopology::new(&net, &profile);
    let r = simulate(&mut ring, &net, &profile, rounds);
    let o = simulate(&mut ours, &net, &profile, rounds);
    println!(
        "\ncycle time over {rounds} rounds:\n  RING       {:7.1} ms\n  multigraph {:7.1} ms  ({:.2}x faster, {} rounds had isolated nodes)",
        r.mean_cycle_ms,
        o.mean_cycle_ms,
        r.mean_cycle_ms / o.mean_cycle_ms,
        o.rounds_with_isolated
    );
}
