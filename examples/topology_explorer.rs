//! Topology explorer: sweep every design across every network and
//! profile; print a Table-1-style grid plus per-design overlay
//! diagnostics (degrees, weight, matchings, multigraph states).
//!
//! Run: `cargo run --release --example topology_explorer [-- --rounds 6400]`

use anyhow::Result;
use mgfl::config::{ExperimentConfig, TopologyKind};
use mgfl::metrics::render_table;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::simulate;
use mgfl::topo::MultigraphTopology;
use mgfl::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rounds: usize = args.get("rounds", 6400)?;
    let t: u32 = args.get("t", 5)?;

    for prof in DatasetProfile::all() {
        println!(
            "\n== {} (M = {} Mbit, T_c = {} ms, u = {}; {} rounds) ==",
            prof.name, prof.model_size_mbits, prof.t_c_ms, prof.u, rounds
        );
        let mut rows = Vec::new();
        for net in zoo::all_networks() {
            let mut row = vec![net.name.clone()];
            let mut ring_ms = f64::NAN;
            for kind in TopologyKind::all() {
                let cfg = ExperimentConfig {
                    network: net.name.clone(),
                    topology: kind,
                    t,
                    sim_rounds: rounds,
                    ..Default::default()
                };
                let mut topo = cfg.build_topology();
                let res = simulate(topo.as_mut(), &net, &prof, rounds);
                if kind == TopologyKind::Ring {
                    ring_ms = res.mean_cycle_ms;
                }
                row.push(format!("{:.1}", res.mean_cycle_ms));
            }
            // Speedup column (RING / ours) like the paper's (↓ x) marks.
            let ours: f64 = row.last().unwrap().parse().unwrap();
            row.push(format!("{:.2}x", ring_ms / ours));
            rows.push(row);
        }
        print!(
            "{}",
            render_table(
                &["network", "STAR", "MATCHA", "MATCHA+", "MST", "d-MBST", "RING", "OURS", "vs RING"],
                &rows
            )
        );
    }

    // Per-network multigraph diagnostics.
    println!("\n== multigraph diagnostics (femnist, t = {t}) ==");
    let prof = DatasetProfile::femnist();
    let mut rows = Vec::new();
    for net in zoo::all_networks() {
        let topo = MultigraphTopology::from_network(&net, &prof, t);
        let mg = topo.multigraph();
        let iso = topo.states_with_isolated(10_000).len();
        rows.push(vec![
            net.name.clone(),
            format!("{}", net.n()),
            format!("{}", mg.total_edges()),
            format!("{}", mg.weak_edges()),
            format!("{:.2}", mg.d_min_ms),
            format!("{}", topo.s_max()),
            format!("{}/{}", iso, topo.s_max().min(10_000)),
        ]);
    }
    print!(
        "{}",
        render_table(
            &["network", "silos", "edges", "weak", "d_min ms", "s_max", "iso states"],
            &rows
        )
    );
    Ok(())
}
