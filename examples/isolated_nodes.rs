//! Fig. 4 reproduction: isolated-node illustration on the Gaia network.
//!
//! Paper setup (§5.3): Gaia geometry, FEMNIST CNN (4.62 Mb transmitted),
//! 10 Gbps access links, u = 1 local update, t = 3. The figure shows the
//! initialized state (the overlay, no isolated nodes) followed by states
//! where isolated nodes appear, the shrinking strong-edge set, and the
//! resulting per-state cycle-time reduction.
//!
//! Run: `cargo run --release --example isolated_nodes [-- --t 3]`

use anyhow::Result;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::DelayTracker;
use mgfl::topo::{MultigraphTopology, TopologyDesign};
use mgfl::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let t: u32 = args.get("t", 3)?;
    let net = zoo::gaia();
    let profile = DatasetProfile::femnist();
    let mut topo = MultigraphTopology::from_network(&net, &profile, t);

    println!(
        "== Fig. 4 — isolated nodes on Gaia (t = {t}, {} states) ==\n",
        topo.s_max()
    );

    let mut tracker = DelayTracker::new(&net, &profile);
    let mut state0_cycle = f64::NAN;
    for k in 0..topo.s_max() as usize {
        let plan = topo.plan(k);
        let rt = tracker.step(&plan);
        if k == 0 {
            state0_cycle = rt.cycle_ms;
        }
        let iso = plan.isolated_nodes();
        println!(
            "state {k}: cycle {:>6.1} ms  ({:.1}x vs state 0)",
            rt.cycle_ms,
            state0_cycle / rt.cycle_ms
        );
        // Node roster: blue(*) = isolated, red(.) = normal (paper's colors).
        let roster: Vec<String> = (0..net.n())
            .map(|i| {
                let mark = if iso.contains(&i) { "*" } else { " " };
                format!("{}{}", net.silos[i].name, mark)
            })
            .collect();
        println!("  nodes : {}", roster.join("  "));
        let strong: Vec<String> = plan
            .strong_edges()
            .map(|(u, v)| format!("{}—{}", net.silos[u].name, net.silos[v].name))
            .collect();
        println!("  strong: [{}]", strong.join(", "));
        let weak = plan.edges.len() - strong.len();
        println!("  weak  : {weak} edges (async, nobody waits)\n");
    }

    // The paper's headline for this figure: isolated states cut both the
    // cycle time (~4x) and the active connections (~3.6x, 11 -> 3).
    let overlay_edges = topo.overlay().edges().len();
    let min_strong = (0..topo.s_max())
        .map(|s| topo.plan_for_state(s).strong_edges().count())
        .min()
        .unwrap();
    println!(
        "summary: connections drop from {overlay_edges} (overlay) to {min_strong} (sparsest state), a {:.1}x reduction",
        overlay_edges as f64 / min_strong as f64
    );
    Ok(())
}
