//! End-to-end driver: REAL federated training through all three layers.
//!
//! Trains the paper's FEMNIST CNN (≈1.14M params, compiled from the
//! Pallas/JAX L1+L2 stack into `artifacts/femnist_cnn_*.hlo.txt`) with
//! DPASGD across the 11-silo Gaia network, once under the RING baseline
//! and once under the multigraph topology, logging per-round loss,
//! simulated wall-clock, and isolated-node counts. This proves the full
//! composition: rust coordinator -> PJRT executables -> Pallas kernels.
//!
//! Run (after `make artifacts && cargo build --release`):
//!   cargo run --release --example end_to_end_train             # CNN, 60 rounds
//!   cargo run --release --example end_to_end_train -- --quick  # MLP, 20 rounds
//!   cargo run --release --example end_to_end_train -- --model femnist_cnn --rounds 200
//!
//! Outputs: results/e2e_<topology>.csv + a comparison summary on stdout.

use anyhow::Result;
use mgfl::config::TrainConfig;
use mgfl::coordinator::Trainer;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::runtime::ModelRuntime;
use mgfl::topo::{ring::RingTopology, MultigraphTopology, TopologyDesign};
use mgfl::util::args::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.has("quick");
    let model = args.get_str("model", if quick { "femnist_mlp" } else { "femnist_cnn" });
    let rounds: usize = args.get("rounds", if quick { 20 } else { 60 })?;
    let eval_every: usize = args.get("eval-every", (rounds / 6).max(1))?;
    let t: u32 = args.get("t", 5)?;

    if !mgfl::runtime::artifacts_available() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    std::fs::create_dir_all("results")?;

    let net = zoo::gaia();
    let profile = DatasetProfile::femnist();
    println!(
        "== end-to-end: {} on {} ({} silos, {} rounds, u=1) ==",
        model,
        net.name,
        net.n(),
        rounds
    );

    let mut summaries = Vec::new();
    for topo_name in ["ring", "multigraph"] {
        let runtime = ModelRuntime::load_default(&model)?;
        println!(
            "\n-- {topo_name}: loaded {} (P={}, {:.2} MB) --",
            model,
            runtime.param_count(),
            runtime.entry.model_size_mb
        );
        let topo: Box<dyn TopologyDesign> = match topo_name {
            "ring" => Box::new(RingTopology::new(&net, &profile)),
            _ => Box::new(MultigraphTopology::from_network(&net, &profile, t)),
        };
        let cfg = TrainConfig {
            model: model.clone(),
            rounds,
            lr: 0.06,
            eval_examples: 512,
            ..Default::default()
        };
        let mut trainer = Trainer::new(runtime, topo, net.clone(), profile.clone(), cfg)?;
        let trace = trainer.run(eval_every)?;

        // Loss curve to stdout (sparse) + full CSV.
        for r in trace.records.iter().step_by((rounds / 10).max(1)) {
            println!(
                "  round {:>4}  loss {:.4}  sim {:>9.1} ms  isolated {}",
                r.round, r.train_loss, r.sim_elapsed_ms, r.isolated
            );
        }
        let timings = trainer.runtime.timings.borrow().clone();
        let path = format!("results/e2e_{topo_name}.csv");
        trace.write_csv(&path)?;
        println!(
            "  final: acc {:.2}%  train-loss {:.4}  sim {:.2} s  host {:.1} s  (mean step {:.1} ms, mean agg {:.1} ms) -> {path}",
            trace.final_accuracy().unwrap_or(f64::NAN) * 100.0,
            trace.final_train_loss().unwrap_or(f64::NAN),
            trace.total_sim_ms() / 1e3,
            trace.host_elapsed_ms / 1e3,
            timings.mean_train_ms(),
            timings.mean_agg_ms(),
        );
        summaries.push((topo_name, trace));
    }

    let (_, ring) = &summaries[0];
    let (_, ours) = &summaries[1];
    println!(
        "\n== comparison ({rounds} rounds) ==\n  simulated time : ring {:.2} s vs multigraph {:.2} s  ({:.2}x faster)\n  final accuracy : ring {:.2}% vs multigraph {:.2}%\n  final loss     : ring {:.4} vs multigraph {:.4}",
        ring.total_sim_ms() / 1e3,
        ours.total_sim_ms() / 1e3,
        ring.total_sim_ms() / ours.total_sim_ms(),
        ring.final_accuracy().unwrap_or(f64::NAN) * 100.0,
        ours.final_accuracy().unwrap_or(f64::NAN) * 100.0,
        ring.final_train_loss().unwrap_or(f64::NAN),
        ours.final_train_loss().unwrap_or(f64::NAN),
    );
    Ok(())
}
