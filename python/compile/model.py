"""Layer-2: JAX model definitions + flat-parameter train/eval steps.

Every model is a `ModelDef`: a list of named parameter shapes plus an
`apply(params_dict, x) -> logits` function whose FLOP-carrying ops route
through the Layer-1 Pallas kernels (kernels.matmul / kernels.conv).

The cross-layer contract with the Rust coordinator is a FLAT f32[P]
parameter vector: `train_step` / `eval_step` unflatten internally, so the
Rust side stays model-agnostic (aggregation, staleness buffers, and
transmission accounting all operate on flat vectors).

Models (paper Table 2):
  femnist_cnn    Marfoq-style 2-conv CNN, 28x28x1 -> 62 classes, ~1.1M
                 params (paper: 1.2M).
  sentiment_lstm single-layer LSTM over token ids (paper: Sentiment140
                 LSTM; `paper` preset ~4.8M params, `small` for training
                 on this CPU testbed).
  cifar_resnet   small residual CNN standing in for the iNaturalist
                 ResNet (compile-path exercised; the paper's accuracy
                 experiments are FEMNIST-only).
  femnist_mlp    tiny MLP used by tests and the quickstart example.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import aggregate as agg_k
from .kernels import conv as conv_k
from .kernels import matmul as mm_k

# ---------------------------------------------------------------------------
# Parameter spec / flattening
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    # Fan-in for scaled init; 0 means zeros-init (biases).
    fan_in: int = 0

    @property
    def size(self) -> int:
        return math.prod(self.shape)


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """A model the coordinator can train: specs + pure apply function."""

    name: str
    specs: tuple[ParamSpec, ...]
    apply: Callable  # (params: dict[str, Array], x) -> logits
    input_shape: tuple[int, ...]  # per-example shape (no batch dim)
    input_dtype: str  # "f32" | "i32"
    num_classes: int

    @property
    def param_count(self) -> int:
        return sum(s.size for s in self.specs)

    @property
    def model_size_mbits(self) -> float:
        """Transmission size in Mbit (used by the Eq. 3 delay model)."""
        return self.param_count * 32 / 1e6

    @property
    def model_size_mb(self) -> float:
        """Size in MB -- the unit paper Table 2 actually reports (its
        "4.62 Mb" for the 1.2M-param CNN is params*4B/1e6)."""
        return self.param_count * 4 / 1e6

    def unflatten(self, flat: jax.Array) -> dict[str, jax.Array]:
        out, off = {}, 0
        for s in self.specs:
            out[s.name] = jax.lax.dynamic_slice_in_dim(flat, off, s.size).reshape(s.shape)
            off += s.size
        return out

    def flatten(self, params: dict[str, jax.Array]) -> jax.Array:
        return jnp.concatenate([params[s.name].reshape(-1) for s in self.specs])

    def init(self, seed: jax.Array) -> jax.Array:
        """Flat He-initialized parameter vector from an i32 seed scalar."""
        key = jax.random.PRNGKey(seed)
        chunks = []
        for i, s in enumerate(self.specs):
            if s.fan_in == 0:
                chunks.append(jnp.zeros((s.size,), jnp.float32))
            else:
                sub = jax.random.fold_in(key, i)
                scale = jnp.sqrt(2.0 / s.fan_in)
                chunks.append(
                    jax.random.normal(sub, (s.size,), jnp.float32) * scale
                )
        return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Shared nn pieces (all matmuls route through the Pallas kernel)
# ---------------------------------------------------------------------------


def dense(params: dict, name: str, x: jax.Array) -> jax.Array:
    return mm_k.matmul(x, params[f"{name}.w"]) + params[f"{name}.b"]


def max_pool2(x: jax.Array) -> jax.Array:
    """2x2 max pool, NHWC."""
    n, h, w, c = x.shape
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logz = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logz, labels[:, None], axis=1).mean()


# ---------------------------------------------------------------------------
# FEMNIST CNN (Marfoq et al. backbone; ~1.14M params vs paper's 1.2M)
# ---------------------------------------------------------------------------


def _femnist_cnn_apply(p: dict, x: jax.Array) -> jax.Array:
    x = conv_k.conv2d(x, p["conv1.w"]) + p["conv1.b"]
    x = max_pool2(jax.nn.relu(x))  # 14x14x32
    x = conv_k.conv2d(x, p["conv2.w"]) + p["conv2.b"]
    x = max_pool2(jax.nn.relu(x))  # 7x7x64
    x = x.reshape(x.shape[0], -1)  # 3136
    x = jax.nn.relu(dense(p, "fc1", x))
    return dense(p, "fc2", x)


FEMNIST_CNN = ModelDef(
    name="femnist_cnn",
    specs=(
        ParamSpec("conv1.w", (3, 3, 1, 32), fan_in=9),
        ParamSpec("conv1.b", (32,)),
        ParamSpec("conv2.w", (3, 3, 32, 64), fan_in=288),
        ParamSpec("conv2.b", (64,)),
        ParamSpec("fc1.w", (3136, 350), fan_in=3136),
        ParamSpec("fc1.b", (350,)),
        ParamSpec("fc2.w", (350, 62), fan_in=350),
        ParamSpec("fc2.b", (62,)),
    ),
    apply=_femnist_cnn_apply,
    input_shape=(28, 28, 1),
    input_dtype="f32",
    num_classes=62,
)


# ---------------------------------------------------------------------------
# FEMNIST MLP (tests / quickstart; fast to compile and run)
# ---------------------------------------------------------------------------


def _femnist_mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(dense(p, "fc1", x))
    return dense(p, "fc2", x)


FEMNIST_MLP = ModelDef(
    name="femnist_mlp",
    specs=(
        ParamSpec("fc1.w", (784, 128), fan_in=784),
        ParamSpec("fc1.b", (128,)),
        ParamSpec("fc2.w", (128, 62), fan_in=128),
        ParamSpec("fc2.b", (62,)),
    ),
    apply=_femnist_mlp_apply,
    input_shape=(28, 28, 1),
    input_dtype="f32",
    num_classes=62,
)


# ---------------------------------------------------------------------------
# Sentiment LSTM
# ---------------------------------------------------------------------------


def _make_lstm(name: str, vocab: int, embed: int, hidden: int, seq: int,
               classes: int) -> ModelDef:
    def apply(p: dict, x: jax.Array) -> jax.Array:
        # x: i32[B, T] token ids
        emb = p["embed.w"][x]  # (B, T, E)
        b = emb.shape[0]
        h0 = jnp.zeros((b, hidden), jnp.float32)
        c0 = jnp.zeros((b, hidden), jnp.float32)

        def cell(carry, x_t):
            h, c = carry
            z = mm_k.matmul(jnp.concatenate([x_t, h], axis=1), p["lstm.w"]) + p["lstm.b"]
            i, f, g, o = jnp.split(z, 4, axis=1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), None

        (h, _), _ = jax.lax.scan(cell, (h0, c0), emb.transpose(1, 0, 2))
        return dense(p, "fc", h)

    return ModelDef(
        name=name,
        specs=(
            ParamSpec("embed.w", (vocab, embed), fan_in=embed),
            ParamSpec("lstm.w", (embed + hidden, 4 * hidden), fan_in=embed + hidden),
            ParamSpec("lstm.b", (4 * hidden,)),
            ParamSpec("fc.w", (hidden, classes), fan_in=hidden),
            ParamSpec("fc.b", (classes,)),
        ),
        apply=apply,
        input_shape=(seq,),
        input_dtype="i32",
        num_classes=classes,
    )


SENTIMENT_LSTM = _make_lstm("sentiment_lstm", vocab=2048, embed=64,
                            hidden=96, seq=24, classes=2)
# Paper-scale preset (Table 2: 4.8M params, 18.38 Mbit).  Compile-only;
# exporting it is gated behind `aot.py --full`.
SENTIMENT_LSTM_PAPER = _make_lstm("sentiment_lstm_paper", vocab=16384,
                                  embed=256, hidden=256, seq=24, classes=2)


# ---------------------------------------------------------------------------
# Small residual CNN (iNaturalist stand-in)
# ---------------------------------------------------------------------------


def _make_resnet(name: str, widths: tuple[int, ...], classes: int,
                 hw: int = 32) -> ModelDef:
    specs: list[ParamSpec] = [
        ParamSpec("stem.w", (3, 3, 3, widths[0]), fan_in=27),
        ParamSpec("stem.b", (widths[0],)),
    ]
    for i, w in enumerate(widths):
        cin = widths[i - 1] if i else widths[0]
        specs += [
            ParamSpec(f"b{i}.c1.w", (3, 3, cin, w), fan_in=9 * cin),
            ParamSpec(f"b{i}.c1.b", (w,)),
            ParamSpec(f"b{i}.c2.w", (3, 3, w, w), fan_in=9 * w),
            ParamSpec(f"b{i}.c2.b", (w,)),
        ]
        if cin != w:
            specs.append(ParamSpec(f"b{i}.proj.w", (1, 1, cin, w), fan_in=cin))
    specs += [
        ParamSpec("fc.w", (widths[-1], classes), fan_in=widths[-1]),
        ParamSpec("fc.b", (classes,)),
    ]

    def apply(p: dict, x: jax.Array) -> jax.Array:
        x = jax.nn.relu(conv_k.conv2d(x, p["stem.w"]) + p["stem.b"])
        for i, w in enumerate(widths):
            cin = widths[i - 1] if i else widths[0]
            h = jax.nn.relu(conv_k.conv2d(x, p[f"b{i}.c1.w"]) + p[f"b{i}.c1.b"])
            h = conv_k.conv2d(h, p[f"b{i}.c2.w"]) + p[f"b{i}.c2.b"]
            if cin != w:
                x = conv_k.conv2d(x, p[f"b{i}.proj.w"], padding=0)
            x = jax.nn.relu(x + h)
            if i + 1 < len(widths):
                x = max_pool2(x)
        x = x.mean(axis=(1, 2))
        return dense(p, "fc", x)

    return ModelDef(
        name=name,
        specs=tuple(specs),
        apply=apply,
        input_shape=(hw, hw, 3),
        input_dtype="f32",
        num_classes=classes,
    )


CIFAR_RESNET = _make_resnet("cifar_resnet", widths=(16, 32, 64), classes=64)


MODELS: dict[str, ModelDef] = {
    m.name: m
    for m in (FEMNIST_CNN, FEMNIST_MLP, SENTIMENT_LSTM, SENTIMENT_LSTM_PAPER,
              CIFAR_RESNET)
}


# ---------------------------------------------------------------------------
# Flat-parameter step functions (what aot.py lowers)
# ---------------------------------------------------------------------------


def make_train_step(model: ModelDef):
    """(flat f32[P], x[B,...], y i32[B], lr f32[]) -> (flat', loss)."""

    def loss_fn(flat, x, y):
        logits = model.apply(model.unflatten(flat), x)
        return softmax_xent(logits, y)

    def step(flat, x, y, lr):
        loss, g = jax.value_and_grad(loss_fn)(flat, x, y)
        return flat - lr * g, loss

    return step


def make_eval_step(model: ModelDef):
    """(flat, x, y) -> (loss, correct_count f32[])."""

    def step(flat, x, y):
        logits = model.apply(model.unflatten(flat), x)
        loss = softmax_xent(logits, y)
        correct = (logits.argmax(axis=1) == y).sum().astype(jnp.float32)
        return loss, correct

    return step


def make_aggregate(model: ModelDef, k_max: int = agg_k.K_MAX):
    """(weights f32[K], models f32[K, P]) -> f32[P] via the Pallas kernel."""
    del model, k_max  # shape comes from the lowering args

    def step(weights, models):
        return agg_k.aggregate(weights, models)

    return step


def make_init(model: ModelDef):
    """(seed i32[]) -> flat f32[P]."""

    def step(seed):
        return model.init(seed)

    return step


def example_batch(model: ModelDef, batch: int):
    """ShapeDtypeStructs for lowering."""
    dt = jnp.float32 if model.input_dtype == "f32" else jnp.int32
    x = jax.ShapeDtypeStruct((batch, *model.input_shape), dt)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return x, y
