"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness contracts: pytest asserts allclose between each
Pallas kernel and its oracle across a hypothesis-driven shape/dtype sweep
(python/tests/test_kernel.py).  Keep these trivially-obviously-correct --
no tiling, no padding, just the textbook expression.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """C = X @ Y, f32 accumulation."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def aggregate_ref(weights: jax.Array, models: jax.Array) -> jax.Array:
    """o[p] = sum_k w[k] models[k,p]."""
    return jnp.einsum("k,kp->p", weights, models,
                      preferred_element_type=jnp.float32).astype(models.dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1,
               padding: int = 1) -> jax.Array:
    """NHWC x HWIO conv via lax.conv_general_dilated."""
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
