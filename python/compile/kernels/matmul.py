"""Layer-1 Pallas kernel: tiled matmul targeting the MXU systolic array.

The paper's per-silo compute hot-spot is the CNN/LSTM forward+backward,
which is GEMM-dominated (conv is lowered to im2col + GEMM, see conv.py).
On the paper's P100 testbed this work ran through cuDNN; the TPU-shaped
re-think is a Pallas kernel tiled for VMEM with (bm, bk) x (bk, bn)
blocks feeding the 128x128 MXU, f32 accumulation in a VMEM scratch
accumulator, and a K-innermost grid so each output tile is revisited
contiguously (double-buffer friendly HBM->VMEM schedule via BlockSpec).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact
runs under the rust runtime.  Real-TPU efficiency is *estimated* from
the BlockSpec footprint in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU-shaped default tiles.  8 * (128*128*4B) * 3 buffers ~= 1.5 MiB of
# VMEM at the defaults -- far under the ~16 MiB budget, leaving room for
# double buffering (see DESIGN.md §Perf for the footprint table).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; grid is (M/bm, N/bn, K/bk), K innermost.

    The accumulator lives in VMEM scratch across the K sweep; the output
    ref is written once on the final K step (revisiting o_ref every step
    would round-trip HBM).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # f32 accumulation regardless of input dtype: this is the MXU contract
    # (bf16 multiplicands, f32 accumulate).
    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_dim(d: int, b: int) -> int:
    return (d + b - 1) // b * b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_tiled(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """C = X @ Y via the Pallas tile kernel, any (M, K) x (K, N) f32.

    Ragged shapes are zero-padded up to the tile grid and sliced back;
    zero padding is exact for matmul.
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    bm_ = min(bm, _pad_dim(m, 8))
    bn_ = min(bn, _pad_dim(n, 8))
    bk_ = min(bk, _pad_dim(k, 8))
    mp, kp, np_ = _pad_dim(m, bm_), _pad_dim(k, bk_), _pad_dim(n, bn_)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    n_k = kp // bk_

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm_, np_ // bn_, n_k),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def _pick_tiles(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Shape-adaptive tiles (§Perf iteration 2, EXPERIMENTS.md).

    interpret=True lowers the grid to an XLA while-loop whose
    per-iteration overhead (dynamic-slice / dot / dynamic-update-slice)
    dominates small dots on CPU; larger tiles cut the step count ~5-10x
    on the CNN's GEMMs (conv1 196->49 steps, fc1 75->4). The (512, 512,
    1024) caps keep the worst-case VMEM footprint ~6 MiB -- still valid
    for a real-TPU deployment, where one would drop back to the
    (128, 128, 128) MXU defaults of `matmul_tiled`.
    """
    bm = min(512, _pad_dim(m, 8))
    bn = min(512, _pad_dim(n, 8))
    bk = min(1024, _pad_dim(k, 8))
    return bm, bn, bk


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable C = X @ Y at shape-adaptive tiles.

    pallas_call has no automatic AD rule; the VJP is expressed with the
    same kernel (dX = dC @ Yᵀ, dY = Xᵀ @ dC) so the backward pass also
    runs on the tiled kernel -- the whole train-step HLO stays on the L1
    kernel path.
    """
    bm, bn, bk = _pick_tiles(x.shape[0], x.shape[1], y.shape[1])
    return matmul_tiled(x, y, bm=bm, bn=bn, bk=bk)


def _matmul_fwd(x, y):
    bm, bn, bk = _pick_tiles(x.shape[0], x.shape[1], y.shape[1])
    return matmul_tiled(x, y, bm=bm, bn=bn, bk=bk), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    bm, bn, bk = _pick_tiles(g.shape[0], g.shape[1], y.shape[0])
    dx = matmul_tiled(g, y.T, bm=bm, bn=bn, bk=bk)
    bm, bn, bk = _pick_tiles(x.shape[1], x.shape[0], g.shape[1])
    dy = matmul_tiled(x.T, g, bm=bm, bn=bn, bk=bk)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                         bk: int = DEFAULT_BK, dtype_bytes: int = 4) -> int:
    """Static VMEM estimate for DESIGN.md §Perf: x tile + y tile + out tile
    + f32 accumulator, x2 for double buffering of the input streams."""
    x_t = bm * bk * dtype_bytes
    y_t = bk * bn * dtype_bytes
    o_t = bm * bn * dtype_bytes
    acc = bm * bn * 4
    return 2 * (x_t + y_t) + o_t + acc
