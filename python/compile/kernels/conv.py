"""Layer-1: conv2d lowered to im2col + the Pallas matmul kernel.

The paper's FEMNIST backbone is the Marfoq et al. CNN (two conv layers +
two dense).  On GPU the convs hit cuDNN implicit-GEMM; the TPU-shaped
equivalent is explicit im2col (pure data movement, XLA fuses the gathers)
feeding the MXU-tiled Pallas matmul from matmul.py, so the *entire*
FLOP-carrying path of the model runs through the L1 kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul as mm


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int) -> tuple[jax.Array, int, int]:
    """NHWC -> (N*OH*OW, KH*KW*C) patch matrix."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    # Gather patches with static slices; XLA fuses this into the consumer.
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = xp[:, di:di + stride * oh:stride, dj:dj + stride * ow:stride, :]
            cols.append(sl)
    patches = jnp.concatenate(cols, axis=-1)  # (N, OH, OW, KH*KW*C)
    return patches.reshape(n * oh * ow, kh * kw * c), oh, ow


def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1, padding: int = 1) -> jax.Array:
    """NHWC conv2d with HWIO weights via im2col + Pallas matmul.

    Args:
      x: f32[N, H, W, C_in]
      w: f32[KH, KW, C_in, C_out]
    Returns:
      f32[N, OH, OW, C_out]
    """
    kh, kw, cin, cout = w.shape
    if x.shape[-1] != cin:
        raise ValueError(f"conv2d channel mismatch: {x.shape} vs {w.shape}")
    n = x.shape[0]
    patches, oh, ow = _im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(kh * kw * cin, cout)
    out = mm.matmul(patches, wmat)
    return out.reshape(n, oh, ow, cout)
