"""Layer-1 Pallas kernel: consensus aggregation (the DPASGD mixing step).

Eq. 2 / Eq. 6 of the paper: w_i(k+1) = sum_j A[i,j] * w_j(k-h) over the
strong in-neighbour set.  On the rust side every silo's model is a flat
f32[P] vector; the coordinator stacks the (up to K_MAX) neighbour models
into f32[K, P] plus a weight vector f32[K] (zero-padded -- zero weights
are exact no-ops), and this kernel computes the weighted sum.

This is the per-round hot-spot of the *coordination* layer: for the
paper's iNaturalist model (11.2M params) at 87 silos it is ~1 GB of
streamed reads per round, so it is tiled as a 1-D grid over P with the
K-reduction unrolled inside the block (K <= K_MAX is tiny; P is huge).
The HBM->VMEM schedule streams (K, bp) slabs; weights stay resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block along the parameter axis.  (K_MAX+1) * bp * 4B per slab; at
# K_MAX=16, bp=65536 that is ~4.2 MiB -- within VMEM with double
# buffering.  §Perf iteration 2: raised from 4096 to cut the interpret
# grid from 278 to 18 steps at P=1.14M (per-step loop overhead
# dominates on CPU; on real TPU both sizes stream fine).
DEFAULT_BP = 65536
K_MAX = 16


def _agg_kernel(w_ref, models_ref, o_ref):
    """o[p] = sum_k w[k] * models[k, p] for one parameter block."""
    # (K, bp) slab contracted against (K,) weights on the VPU; no MXU
    # needed -- this is bandwidth-bound, the tiling is for streaming.
    o_ref[...] = jnp.einsum(
        "k,kp->p", w_ref[...], models_ref[...],
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bp",))
def aggregate(weights: jax.Array, models: jax.Array, *, bp: int = DEFAULT_BP) -> jax.Array:
    """Weighted sum over stacked flat models.

    Args:
      weights: f32[K] consensus row (A[i, j] entries; zero = padding).
      models:  f32[K, P] stacked neighbour parameter vectors.
    Returns:
      f32[P] aggregated parameters.
    """
    if models.ndim != 2 or weights.ndim != 1 or weights.shape[0] != models.shape[0]:
        raise ValueError(f"aggregate shape mismatch: {weights.shape} x {models.shape}")
    k, p = models.shape
    bp_ = min(bp, p) if p else 1
    pp = (p + bp_ - 1) // bp_ * bp_
    mp = jnp.pad(models, ((0, 0), (0, pp - p)))

    out = pl.pallas_call(
        _agg_kernel,
        grid=(pp // bp_,),
        in_specs=[
            # Weights are tiny and revisited every block: index map pins
            # them to block 0 so they stay VMEM-resident across the grid.
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, bp_), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bp_,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), models.dtype),
        interpret=True,
    )(weights, mp)
    return out[:p]


def vmem_footprint_bytes(k: int = K_MAX, bp: int = DEFAULT_BP,
                         dtype_bytes: int = 4) -> int:
    """Static VMEM estimate: weight vector + double-buffered model slab +
    output block."""
    return k * dtype_bytes + 2 * k * bp * dtype_bytes + bp * dtype_bytes
