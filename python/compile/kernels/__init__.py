"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

Public surface: matmul.matmul, conv.conv2d, aggregate.aggregate, and the
pure-jnp oracles in ref.  All kernels run interpret=True (CPU-PJRT
compatible); see DESIGN.md §Hardware-Adaptation.
"""
from . import aggregate, conv, matmul, ref  # noqa: F401
