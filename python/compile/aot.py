"""AOT export: lower L2 step functions to HLO *text* + a JSON manifest.

This is the only place Python touches the artifact boundary.  The rust
runtime (rust/src/runtime/) loads `artifacts/<name>.hlo.txt` via
`HloModuleProto::from_text_file`, compiles on the PJRT CPU client, and
executes -- Python never runs on the round path.

Interchange is HLO TEXT, not `.serialize()`: jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts per model (shapes are baked; the manifest records them):
  <model>_train     (params f32[P], x, y i32[B], lr f32[]) -> (params', loss)
  <model>_eval      (params, x, y)                         -> (loss, correct)
  <model>_init      (seed i32[])                           -> params
  <model>_agg       (w f32[K], models f32[K,P])            -> params
Usage: python -m compile.aot --out ../artifacts [--full]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import aggregate as agg_k

# Models exported by default; --full adds the compile-only paper-scale
# presets (slow to lower, never trained on this testbed).
DEFAULT_MODELS = ("femnist_mlp", "femnist_cnn", "sentiment_lstm")
FULL_MODELS = DEFAULT_MODELS + ("cifar_resnet", "sentiment_lstm_paper")

TRAIN_BATCH = 32
EVAL_BATCH = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned on parse)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _lower(fn, *args):
    return to_hlo_text(jax.jit(fn).lower(*args))


def export_model(model: M.ModelDef, out: pathlib.Path,
                 train_batch: int = TRAIN_BATCH,
                 eval_batch: int = EVAL_BATCH,
                 k_max: int = agg_k.K_MAX) -> dict:
    """Write all artifacts for one model; return its manifest entry."""
    p = model.param_count
    flat = jax.ShapeDtypeStruct((p,), jnp.float32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    xt, yt = M.example_batch(model, train_batch)
    xe, ye = M.example_batch(model, eval_batch)
    w = jax.ShapeDtypeStruct((k_max,), jnp.float32)
    stack = jax.ShapeDtypeStruct((k_max, p), jnp.float32)

    files = {}
    for suffix, text in (
        ("train", _lower(M.make_train_step(model), flat, xt, yt, lr)),
        ("eval", _lower(M.make_eval_step(model), flat, xe, ye)),
        ("init", _lower(M.make_init(model), seed)),
        ("agg", _lower(M.make_aggregate(model), w, stack)),
    ):
        name = f"{model.name}_{suffix}.hlo.txt"
        (out / name).write_text(text)
        files[suffix] = name

    return {
        "model": model.name,
        "param_count": p,
        "model_size_mbits": model.model_size_mbits,
        "model_size_mb": model.model_size_mb,
        "num_classes": model.num_classes,
        "input_shape": list(model.input_shape),
        "input_dtype": model.input_dtype,
        "train_batch": train_batch,
        "eval_batch": eval_batch,
        "k_max": k_max,
        "artifacts": files,
        "param_specs": [
            {"name": s.name, "shape": list(s.shape)} for s in model.specs
        ],
    }


def _input_fingerprint() -> str:
    """Hash of the compile-path sources, for `make artifacts` up-to-date
    checks on the rust side (runtime refuses stale manifests loudly)."""
    h = hashlib.sha256()
    root = pathlib.Path(__file__).parent
    for f in sorted(root.rglob("*.py")):
        h.update(f.read_bytes())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also export compile-only paper-scale models")
    ap.add_argument("--models", nargs="*", default=None,
                    help="explicit model subset")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    names = args.models or (FULL_MODELS if args.full else DEFAULT_MODELS)

    entries = []
    for name in names:
        model = M.MODELS[name]
        print(f"[aot] lowering {name} (P={model.param_count:,}) ...", flush=True)
        entries.append(export_model(model, out))

    manifest = {
        "version": 1,
        "fingerprint": _input_fingerprint(),
        "models": {e["model"]: e for e in entries},
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2) + "\n")
    total = sum(len((out / f).read_bytes())
                for e in entries for f in e["artifacts"].values())
    print(f"[aot] wrote {len(entries)} models, {total/1e6:.1f} MB of HLO, "
          f"manifest fingerprint {manifest['fingerprint']}")


if __name__ == "__main__":
    main()
