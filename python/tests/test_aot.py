"""AOT boundary: HLO text export is parseable, runs, and matches the
eager L2 computation -- the exact contract the rust runtime relies on.
"""

import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


class TestExport:
    def test_to_hlo_text_roundtrip_simple(self):
        """Lower a function, confirm the text contains a parseable module
        with the ENTRY signature the rust loader expects."""
        def fn(x, y):
            return (jnp.matmul(x, y) + 2.0,)

        spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
        assert "ENTRY" in text and "f32[2,2]" in text
        # return_tuple=True: the rust side unwraps a 1-tuple
        assert "(f32[2,2]" in text

    def test_manifest_written(self, tmp_path):
        entry = aot.export_model(M.FEMNIST_MLP, tmp_path, train_batch=4,
                                 eval_batch=4)
        for f in entry["artifacts"].values():
            assert (tmp_path / f).exists()
            text = (tmp_path / f).read_text()
            assert text.startswith("HloModule") and "ENTRY" in text
        assert entry["param_count"] == M.FEMNIST_MLP.param_count

    def test_train_artifact_signature(self, tmp_path):
        entry = aot.export_model(M.FEMNIST_MLP, tmp_path, train_batch=4,
                                 eval_batch=4)
        text = (tmp_path / entry["artifacts"]["train"]).read_text()
        p = M.FEMNIST_MLP.param_count
        # params, x, y, lr inputs all appear in the entry computation
        assert f"f32[{p}]" in text
        assert "f32[4,28,28,1]" in text
        assert "s32[4]" in text

    def test_fingerprint_stable(self):
        assert aot._input_fingerprint() == aot._input_fingerprint()


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(),
                    reason="run `make artifacts` first")
class TestBuiltArtifacts:
    """Validate the checked-out artifacts/ dir the rust tests also use."""

    @pytest.fixture(scope="class")
    def manifest(self):
        return json.loads((ARTIFACTS / "manifest.json").read_text())

    def test_all_files_present(self, manifest):
        for entry in manifest["models"].values():
            for f in entry["artifacts"].values():
                assert (ARTIFACTS / f).exists(), f

    def test_fingerprint_current(self, manifest):
        assert manifest["fingerprint"] == aot._input_fingerprint(), (
            "artifacts stale vs python/compile sources -- run `make artifacts`"
        )

    def test_param_counts_match_models(self, manifest):
        for name, entry in manifest["models"].items():
            assert entry["param_count"] == M.MODELS[name].param_count
