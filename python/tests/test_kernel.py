"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts -- the same
kernels lower into the train/eval HLO the rust runtime executes.
Hypothesis sweeps shapes; fixed cases pin the paper-relevant geometries
(the FEMNIST CNN's GEMMs, the aggregation slabs at K_MAX).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate as agg
from compile.kernels import conv as cv
from compile.kernels import matmul as mm
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=160)
small_dims = st.integers(min_value=1, max_value=40)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_random_shapes(self, m, k, n, seed):
        x = rand(seed, (m, k))
        y = rand(seed + 1, (k, n))
        np.testing.assert_allclose(
            mm.matmul(x, y), ref.matmul_ref(x, y), rtol=2e-5, atol=2e-5
        )

    @pytest.mark.parametrize(
        "m,k,n",
        [
            (25088, 16, 32),   # femnist conv1 im2col GEMM (padded K)
            (6272, 288, 64),   # femnist conv2
            (32, 3136, 350),   # femnist fc1, train batch
            (32, 350, 62),     # femnist fc2
            (128, 128, 128),   # exact single tile
            (256, 384, 512),   # exact multi-tile
            (1, 1, 1),         # degenerate
            (129, 129, 129),   # one past the tile boundary
        ],
    )
    def test_paper_geometries(self, m, k, n):
        x = rand(m * 7 + n, (m, k))
        y = rand(k * 3 + 1, (k, n))
        np.testing.assert_allclose(
            mm.matmul(x, y), ref.matmul_ref(x, y), rtol=3e-5, atol=3e-5
        )

    @pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 128, 32), (8, 8, 8)])
    def test_tile_invariance(self, bm, bn, bk):
        """Result must not depend on the tiling (pure perf knob)."""
        x = rand(0, (100, 70))
        y = rand(1, (70, 90))
        np.testing.assert_allclose(
            mm.matmul_tiled(x, y, bm=bm, bn=bn, bk=bk),
            ref.matmul_ref(x, y),
            rtol=2e-5,
            atol=2e-5,
        )

    def test_grad_matches_ref(self):
        """custom_vjp must agree with jnp autodiff."""
        x = rand(2, (17, 23))
        y = rand(3, (23, 9))

        def f_pallas(x, y):
            return (mm.matmul(x, y) ** 2).sum()

        def f_ref(x, y):
            return (ref.matmul_ref(x, y) ** 2).sum()

        gx_p, gy_p = jax.grad(f_pallas, argnums=(0, 1))(x, y)
        gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
        np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gy_p, gy_r, rtol=1e-4, atol=1e-4)

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError, match="matmul shape mismatch"):
            mm.matmul_tiled(jnp.zeros((2, 3)), jnp.zeros((4, 5)))

    def test_vmem_budget(self):
        """Default tiles must fit the 16 MiB VMEM budget with headroom."""
        assert mm.vmem_footprint_bytes() < 8 * 1024 * 1024


# ---------------------------------------------------------------------------
# aggregate (the consensus kernel)
# ---------------------------------------------------------------------------


class TestAggregate:
    @settings(max_examples=25, deadline=None)
    @given(
        k=st.integers(1, agg.K_MAX),
        p=st.integers(1, 20000),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, k, p, seed):
        w = rand(seed, (k,))
        m = rand(seed + 1, (k, p))
        np.testing.assert_allclose(
            agg.aggregate(w, m), ref.aggregate_ref(w, m), rtol=2e-5, atol=2e-5
        )

    def test_zero_weights_are_padding(self):
        """Coordinator zero-pads to K_MAX: padded rows must be exact no-ops
        even when they hold garbage."""
        w = jnp.array([0.5, 0.5, 0.0, 0.0])
        m = jnp.stack(
            [rand(0, (1000,)), rand(1, (1000,)),
             jnp.full((1000,), 1e30), jnp.full((1000,), -1e30)]
        )
        expect = 0.5 * m[0] + 0.5 * m[1]
        np.testing.assert_allclose(agg.aggregate(w, m), expect, rtol=1e-6)

    def test_convex_identity(self):
        """sum(w)=1 over identical models returns the model unchanged."""
        x = rand(5, (4096,))
        w = jnp.array([0.2, 0.3, 0.5])
        m = jnp.stack([x, x, x])
        np.testing.assert_allclose(agg.aggregate(w, m), x, rtol=1e-6, atol=1e-6)

    def test_femnist_scale(self):
        """Paper-scale slab: K_MAX x 1.14M params."""
        p = 1138528
        w = jnp.ones((agg.K_MAX,)) / agg.K_MAX
        m = rand(9, (agg.K_MAX, p))
        np.testing.assert_allclose(
            agg.aggregate(w, m), ref.aggregate_ref(w, m), rtol=2e-5, atol=2e-5
        )

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError, match="aggregate shape mismatch"):
            agg.aggregate(jnp.zeros((3,)), jnp.zeros((4, 10)))

    def test_vmem_budget(self):
        # §Perf iteration 2 raised bp to 65536 (~8.5 MiB with double
        # buffering) — must stay inside the 16 MiB VMEM budget.
        assert agg.vmem_footprint_bytes() < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


class TestConv2d:
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(1, 4),
        hw=st.integers(4, 20),
        cin=st.integers(1, 8),
        cout=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_lax_conv(self, n, hw, cin, cout, seed):
        x = rand(seed, (n, hw, hw, cin))
        w = rand(seed + 1, (3, 3, cin, cout)) * 0.2
        np.testing.assert_allclose(
            cv.conv2d(x, w), ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-4
        )

    @pytest.mark.parametrize("stride,padding", [(1, 0), (2, 1), (1, 1), (2, 0)])
    def test_stride_padding(self, stride, padding):
        x = rand(0, (2, 12, 12, 3))
        w = rand(1, (3, 3, 3, 5)) * 0.2
        np.testing.assert_allclose(
            cv.conv2d(x, w, stride=stride, padding=padding),
            ref.conv2d_ref(x, w, stride=stride, padding=padding),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_1x1_projection(self):
        x = rand(0, (2, 8, 8, 16))
        w = rand(1, (1, 1, 16, 32)) * 0.2
        np.testing.assert_allclose(
            cv.conv2d(x, w, padding=0), ref.conv2d_ref(x, w, padding=0),
            rtol=1e-4, atol=1e-4,
        )

    def test_femnist_layer_shapes(self):
        x = rand(0, (8, 28, 28, 1))
        w = rand(1, (3, 3, 1, 32)) * 0.3
        out = cv.conv2d(x, w)
        assert out.shape == (8, 28, 28, 32)
        np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_grad_flows(self):
        x = rand(2, (2, 8, 8, 3))
        w = rand(3, (3, 3, 3, 4)) * 0.2

        def f(w):
            return (cv.conv2d(x, w) ** 2).mean()

        def f_ref(w):
            return (ref.conv2d_ref(x, w) ** 2).mean()

        np.testing.assert_allclose(
            jax.grad(f)(w), jax.grad(f_ref)(w), rtol=1e-4, atol=1e-4
        )

    def test_rejects_channel_mismatch(self):
        with pytest.raises(ValueError, match="channel mismatch"):
            cv.conv2d(jnp.zeros((1, 8, 8, 3)), jnp.zeros((3, 3, 4, 8)))
