"""L2 correctness: model definitions, flat-param bijection, train/eval
steps, and learning sanity (loss decreases on a learnable synthetic task).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def synthetic_batch(model: M.ModelDef, batch: int, seed: int = 0):
    """Class-conditional synthetic batch (same family the rust data layer
    generates): mean-shifted Gaussians per class, so it is learnable."""
    key = jax.random.PRNGKey(seed)
    ky, kx = jax.random.split(key)
    y = jax.random.randint(ky, (batch,), 0, model.num_classes)
    if model.input_dtype == "f32":
        base = jax.random.normal(kx, (batch, *model.input_shape))
        shift = (y / model.num_classes).reshape(batch, *([1] * len(model.input_shape)))
        x = base * 0.3 + shift
    else:
        x = jax.random.randint(kx, (batch, *model.input_shape), 0, 64)
        # Strongly class-dependent prefix token (same scheme as the rust
        # data generator): the sequence starts with a class-indicator id.
        x = x.at[:, 0].set(64 + y * 16)
        x = x.astype(jnp.int32)
    return x, y


ALL = ["femnist_mlp", "femnist_cnn", "sentiment_lstm", "cifar_resnet"]
FAST = ["femnist_mlp", "sentiment_lstm"]


class TestParamSpecs:
    @pytest.mark.parametrize("name", ALL + ["sentiment_lstm_paper"])
    def test_flatten_unflatten_roundtrip(self, name):
        m = M.MODELS[name]
        flat = m.init(jnp.int32(7))
        assert flat.shape == (m.param_count,)
        rt = m.flatten(m.unflatten(flat))
        np.testing.assert_array_equal(flat, rt)

    def test_femnist_cnn_matches_paper_size(self):
        """Paper Table 2: FEMNIST CNN = 1.2M params, 4.62 'Mb' (MB)."""
        m = M.FEMNIST_CNN
        assert 1.0e6 < m.param_count < 1.3e6
        assert 4.0 < m.model_size_mb < 5.1

    def test_sentiment_paper_preset_size(self):
        """Paper Table 2: 4.8M params."""
        m = M.MODELS["sentiment_lstm_paper"]
        assert 4.3e6 < m.param_count < 5.3e6

    def test_init_deterministic_in_seed(self):
        m = M.FEMNIST_MLP
        a, b = m.init(jnp.int32(3)), m.init(jnp.int32(3))
        c = m.init(jnp.int32(4))
        np.testing.assert_array_equal(a, b)
        assert not np.allclose(a, c)

    def test_biases_zero_init(self):
        m = M.FEMNIST_MLP
        p = m.unflatten(m.init(jnp.int32(0)))
        np.testing.assert_array_equal(p["fc1.b"], 0.0)
        np.testing.assert_array_equal(p["fc2.b"], 0.0)


class TestSteps:
    @pytest.mark.parametrize("name", ALL)
    def test_shapes_and_finite(self, name):
        m = M.MODELS[name]
        flat = m.init(jnp.int32(0))
        x, y = synthetic_batch(m, 8)
        step = jax.jit(M.make_train_step(m))
        flat2, loss = step(flat, x, y, jnp.float32(0.05))
        assert flat2.shape == flat.shape
        assert np.isfinite(float(loss))
        ev = jax.jit(M.make_eval_step(m))
        l2, correct = ev(flat2, x, y)
        assert np.isfinite(float(l2))
        assert 0 <= float(correct) <= 8

    @pytest.mark.parametrize("name", FAST)
    def test_loss_decreases(self, name):
        m = M.MODELS[name]
        flat = m.init(jnp.int32(1))
        step = jax.jit(M.make_train_step(m))
        x, y = synthetic_batch(m, 32, seed=5)
        first = None
        for i in range(30):
            flat, loss = step(flat, x, y, jnp.float32(0.1))
            if first is None:
                first = float(loss)
        assert float(loss) < 0.7 * first, (first, float(loss))

    def test_initial_loss_near_log_c(self):
        """Untrained softmax CE should sit at ~log(num_classes)."""
        m = M.FEMNIST_MLP
        flat = m.init(jnp.int32(0))
        x, y = synthetic_batch(m, 64)
        loss, _ = jax.jit(M.make_eval_step(m))(flat, x, y)
        assert abs(float(loss) - np.log(62)) < 1.0

    def test_aggregate_step_is_convex_combination(self):
        m = M.FEMNIST_MLP
        a, b = m.init(jnp.int32(0)), m.init(jnp.int32(1))
        k = 16
        w = jnp.zeros((k,)).at[0].set(0.25).at[1].set(0.75)
        stack = jnp.zeros((k, m.param_count)).at[0].set(a).at[1].set(b)
        out = jax.jit(M.make_aggregate(m))(w, stack)
        np.testing.assert_allclose(out, 0.25 * a + 0.75 * b, rtol=2e-5, atol=2e-5)

    def test_train_step_gradient_direction(self):
        """One step at tiny lr must reduce loss on the same batch."""
        m = M.FEMNIST_MLP
        flat = m.init(jnp.int32(2))
        x, y = synthetic_batch(m, 16, seed=3)
        step = jax.jit(M.make_train_step(m))
        ev = jax.jit(M.make_eval_step(m))
        l0, _ = ev(flat, x, y)
        flat2, _ = step(flat, x, y, jnp.float32(0.01))
        l1, _ = ev(flat2, x, y)
        assert float(l1) < float(l0)
