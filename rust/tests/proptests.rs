//! Property-based tests over coordinator invariants (routing, batching,
//! state management). The offline build has no proptest crate, so
//! randomized cases are driven by the in-tree xoshiro generator with a
//! fixed seed per test (failures print the case index; reproduce by
//! re-running — generation is fully deterministic).

use mgfl::config::IsolatedPolicy;
use mgfl::delay::{EdgeDelayState, EdgeType};
use mgfl::fl::{round_actions, ConsensusMatrix, SiloAction};
use mgfl::graph::{
    christofides_cycle, degree_bounded_mst, eulerian_circuit, greedy_min_weight_matching,
    matching_decomposition, prim_mst, Graph,
};
use mgfl::net::DatasetProfile;
use mgfl::topo::{
    multigraph::Multigraph, states::parse_states_explicit, MultigraphTopology, RoundPlan,
};
use mgfl::util::{lcm, Rng64};

const CASES: usize = 60;

/// Random connected metric-ish graph: complete with random point weights.
fn random_complete(rng: &mut Rng64, n: usize) -> Graph {
    let pts: Vec<(f64, f64)> =
        (0..n).map(|_| (rng.gen_f64() * 100.0, rng.gen_f64() * 100.0)).collect();
    Graph::complete(n, |u, v| {
        let (x1, y1) = pts[u];
        let (x2, y2) = pts[v];
        ((x1 - x2).powi(2) + (y1 - y2).powi(2)).sqrt().max(0.1)
    })
}

/// Random synthetic network spec over random geo coordinates.
fn random_network(rng: &mut Rng64, n: usize) -> mgfl::net::NetworkSpec {
    mgfl::net::NetworkSpec {
        name: "prop".into(),
        silos: (0..n)
            .map(|i| {
                mgfl::net::Silo::new(
                    &format!("s{i}"),
                    rng.gen_f64() * 120.0 - 60.0,
                    rng.gen_f64() * 360.0 - 180.0,
                )
            })
            .collect(),
    }
}

#[test]
fn prop_mst_has_n_minus_1_edges_and_spans() {
    let mut rng = Rng64::seed_from_u64(101);
    for case in 0..CASES {
        let n = rng.gen_range(2, 40);
        let g = random_complete(&mut rng, n);
        let t = prim_mst(&g);
        assert_eq!(t.edges().len(), n - 1, "case {case}");
        assert!(t.is_connected(), "case {case}");
        // MST weight <= any spanning tree; spot-check vs a star.
        let star: f64 = (1..n).map(|v| g.edge_weight(0, v).unwrap()).sum();
        assert!(t.total_weight() <= star + 1e-9, "case {case}");
    }
}

#[test]
fn prop_degree_bounded_mst_respects_bound() {
    let mut rng = Rng64::seed_from_u64(102);
    for case in 0..CASES {
        let n = rng.gen_range(3, 30);
        let delta = rng.gen_range(2, 6);
        let g = random_complete(&mut rng, n);
        let t = degree_bounded_mst(&g, delta);
        assert!(t.is_connected(), "case {case}");
        // The fallback may relax the bound by 1 on adversarial inputs.
        for u in 0..n {
            assert!(t.degree(u) <= delta + 1, "case {case}: deg {u} = {}", t.degree(u));
        }
    }
}

#[test]
fn prop_christofides_visits_every_node_once() {
    let mut rng = Rng64::seed_from_u64(103);
    for case in 0..CASES {
        let n = rng.gen_range(2, 35);
        let g = random_complete(&mut rng, n);
        let cycle = christofides_cycle(&g);
        assert_eq!(cycle.len(), n, "case {case}");
        let set: std::collections::BTreeSet<_> = cycle.iter().collect();
        assert_eq!(set.len(), n, "case {case}: repeated node");
    }
}

#[test]
fn prop_matching_is_perfect_and_disjoint() {
    let mut rng = Rng64::seed_from_u64(104);
    for case in 0..CASES {
        let n = rng.gen_range(1, 15) * 2;
        let nodes: Vec<usize> = (0..n).collect();
        let pts: Vec<f64> = (0..n).map(|_| rng.gen_f64()).collect();
        let m = greedy_min_weight_matching(&nodes, |u, v| (pts[u] - pts[v]).abs());
        assert_eq!(m.len(), n / 2, "case {case}");
        let mut seen = std::collections::BTreeSet::new();
        for (u, v) in m {
            assert!(seen.insert(u) && seen.insert(v), "case {case}");
        }
    }
}

#[test]
fn prop_matching_decomposition_partitions_edges() {
    let mut rng = Rng64::seed_from_u64(105);
    for case in 0..CASES {
        let n = rng.gen_range(3, 20);
        let g = random_complete(&mut rng, n);
        // Random sparse subset of edges.
        let edges: Vec<(usize, usize, f64)> = g
            .edges()
            .iter()
            .filter(|_| rng.gen_f64() < 0.4)
            .map(|e| (e.u, e.v, e.w))
            .collect();
        let parts = matching_decomposition(&edges);
        let total: usize = parts.iter().map(|m| m.len()).sum();
        assert_eq!(total, edges.len(), "case {case}");
        for m in &parts {
            let mut seen = std::collections::BTreeSet::new();
            for &(u, v, _) in m {
                assert!(seen.insert(u) && seen.insert(v), "case {case}");
            }
        }
        // Vizing-style bound: Δ+1 matchings suffice; greedy may use a
        // bit more but never more than 2Δ (sanity ceiling).
        let max_deg = edges
            .iter()
            .flat_map(|&(u, v, _)| [u, v])
            .fold(std::collections::BTreeMap::<usize, usize>::new(), |mut m, x| {
                *m.entry(x).or_default() += 1;
                m
            })
            .into_values()
            .max()
            .unwrap_or(0);
        assert!(parts.len() <= (2 * max_deg).max(1), "case {case}");
    }
}

#[test]
fn prop_euler_circuit_covers_every_edge_exactly_once() {
    let mut rng = Rng64::seed_from_u64(106);
    for case in 0..CASES {
        // Build an even multigraph: union of 1-3 random cycles over n nodes.
        let n = rng.gen_range(3, 12);
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for _ in 0..rng.gen_range(1, 4) {
            let mut order: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut order);
            for i in 0..n {
                edges.push((order[i], order[(i + 1) % n]));
            }
        }
        let circuit = eulerian_circuit(n, &edges);
        assert_eq!(circuit.len(), edges.len() + 1, "case {case}");
        assert_eq!(circuit.first(), circuit.last(), "case {case}");
    }
}

#[test]
fn prop_multigraph_construction_invariants() {
    let mut rng = Rng64::seed_from_u64(107);
    for case in 0..CASES {
        let n = rng.gen_range(3, 25);
        let t = rng.gen_range(1, 9) as u32;
        let net = random_network(&mut rng, n);
        let prof = DatasetProfile::femnist();
        let conn = net.connectivity_graph(&prof);
        let overlay = mgfl::graph::ring_overlay(&conn);
        let mg = Multigraph::construct(&overlay, &net, &prof, t);

        // Multiplicities in [1, t]; d_min pair at multiplicity 1.
        assert!(mg.edges.iter().all(|e| (1..=t).contains(&e.n_edges)), "case {case}");
        let min_e = mg.edges.iter().min_by(|a, b| a.delay_ms.total_cmp(&b.delay_ms)).unwrap();
        assert_eq!(min_e.n_edges, 1, "case {case}");
        // s_max = LCM of multiplicities.
        let want = mg.edges.iter().map(|e| e.n_edges as u64).fold(1, lcm);
        assert_eq!(mg.s_max(), want, "case {case}");
    }
}

#[test]
fn prop_states_closed_form_equals_algorithm2() {
    let mut rng = Rng64::seed_from_u64(108);
    for case in 0..30 {
        let n = rng.gen_range(3, 15);
        let t = rng.gen_range(1, 6) as u32;
        let net = random_network(&mut rng, n);
        let prof = DatasetProfile::femnist();
        let topo = MultigraphTopology::from_network(&net, &prof, t);
        let explicit = parse_states_explicit(topo.multigraph(), 120);
        for st in &explicit {
            let plan = topo.plan_for_state(st.index);
            assert_eq!(plan.edges, st.edges, "case {case} state {}", st.index);
            assert_eq!(plan.isolated_nodes(), st.isolated, "case {case}");
        }
    }
}

#[test]
fn prop_round_actions_weights_always_sum_to_one() {
    let mut rng = Rng64::seed_from_u64(109);
    for case in 0..CASES {
        let n = rng.gen_range(3, 20);
        let net = random_network(&mut rng, n);
        let prof = DatasetProfile::femnist();
        let t = rng.gen_range(2, 7) as u32;
        let mut topo = MultigraphTopology::from_network(&net, &prof, t);
        let consensus = ConsensusMatrix::metropolis(
            mgfl::topo::TopologyDesign::overlay(&topo),
        );
        for k in 0..topo.s_max().min(20) as usize {
            let plan = mgfl::topo::TopologyDesign::plan(&mut topo, k);
            for policy in [IsolatedPolicy::StaleAggregate, IsolatedPolicy::Skip] {
                let actions = round_actions(&plan, &consensus, policy);
                assert_eq!(actions.len(), n);
                for (i, a) in actions.iter().enumerate() {
                    if let SiloAction::Aggregate { row, .. } = a {
                        let sum: f64 = row.iter().map(|&(_, w)| w).sum();
                        assert!((sum - 1.0).abs() < 1e-9, "case {case} round {k} silo {i}");
                        // Self must participate.
                        assert!(row.iter().any(|&(j, _)| j == i), "case {case}");
                        // All weights non-negative.
                        assert!(row.iter().all(|&(_, w)| w >= -1e-12), "case {case}");
                    }
                }
            }
        }
    }
}

#[test]
fn prop_isolated_nodes_never_wait() {
    let mut rng = Rng64::seed_from_u64(110);
    for case in 0..CASES {
        let n = rng.gen_range(3, 18);
        let net = random_network(&mut rng, n);
        let prof = DatasetProfile::femnist();
        let mut topo = MultigraphTopology::from_network(&net, &prof, 5);
        let consensus =
            ConsensusMatrix::metropolis(mgfl::topo::TopologyDesign::overlay(&topo));
        for k in 0..topo.s_max().min(30) as usize {
            let plan = mgfl::topo::TopologyDesign::plan(&mut topo, k);
            let isolated: std::collections::BTreeSet<_> =
                plan.isolated_nodes().into_iter().collect();
            let actions = round_actions(&plan, &consensus, IsolatedPolicy::StaleAggregate);
            for (i, a) in actions.iter().enumerate() {
                if let SiloAction::Aggregate { wait, .. } = a {
                    if isolated.contains(&i) {
                        assert!(!wait, "case {case}: isolated {i} waits at round {k}");
                    } else {
                        assert!(wait, "case {case}: strong node {i} not waiting");
                    }
                }
            }
        }
    }
}

#[test]
fn prop_delay_state_bounded_by_d0() {
    let mut rng = Rng64::seed_from_u64(111);
    let prof = DatasetProfile::femnist();
    for case in 0..CASES {
        let d0 = 1.0 + rng.gen_f64() * 200.0;
        let mut st = EdgeDelayState::new(d0);
        for step in 0..500 {
            let ty = if rng.gen_f64() < 0.4 { EdgeType::Strong } else { EdgeType::Weak };
            let tau = rng.gen_f64() * 100.0;
            let d = st.strong_delay_ms(&prof);
            assert!(
                d <= d0 + 1e-9 && d >= prof.t_c_ms * prof.u as f64 - 1e-9,
                "case {case} step {step}: {d} not in [T_c, {d0}]"
            );
            st.advance(ty, tau, &prof);
        }
    }
}

#[test]
fn prop_round_plan_isolated_consistency() {
    // isolated_nodes() must be exactly the nodes with edges but no
    // strong edges, for arbitrary random plans.
    let mut rng = Rng64::seed_from_u64(112);
    for case in 0..CASES {
        let n = rng.gen_range(2, 25);
        let mut edges = Vec::new();
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.gen_f64() < 0.3 {
                    let ty = if rng.gen_f64() < 0.5 { EdgeType::Strong } else { EdgeType::Weak };
                    edges.push((u, v, ty));
                }
            }
        }
        let plan = RoundPlan { n, edges: edges.clone() };
        let iso = plan.isolated_nodes();
        for i in 0..n {
            let has_edge = edges.iter().any(|&(u, v, _)| u == i || v == i);
            let has_strong = edges
                .iter()
                .any(|&(u, v, ty)| (u == i || v == i) && ty == EdgeType::Strong);
            assert_eq!(
                iso.contains(&i),
                has_edge && !has_strong,
                "case {case} node {i}"
            );
        }
    }
}
