//! `mgfl optimize` determinism and correctness gates.
//!
//! Pins the search subsystem's contracts: the SearchReport is a pure
//! function of the spec (byte-identical across runs and thread counts),
//! every reported fitness is bitwise reproducible on the naive
//! reference simulator, a small-network search provably finds the
//! enumerated optimum, and the committed `specs/optimize_gaia.toml`
//! beats the paper multigraph from chain 0's bit-exact baseline start.

use mgfl::net::{DatasetProfile, NetworkSpec, Silo};
use mgfl::search::{
    paper_start, random_genome, run, Anneal, Evaluator, Genome, HillClimb, OptimizeSpec,
    SearchStrategy, StrategyKind,
};
use mgfl::simtime::simulate_summary_naive;
use mgfl::sweep::RunOptions;
use mgfl::topo::CandidateTopology;
use mgfl::util::rng::{named_stream, Rng64};

fn small_spec(strategy: StrategyKind) -> OptimizeSpec {
    OptimizeSpec {
        name: "det".into(),
        rounds: 80,
        chains: 3,
        steps: 40,
        restart_after: 12,
        strategy,
        matcha_budgets: vec![0.5],
        ..Default::default()
    }
}

fn opts(threads: usize) -> RunOptions {
    RunOptions { threads, ..Default::default() }
}

#[test]
fn report_is_byte_identical_across_runs_and_thread_counts() {
    for strategy in [StrategyKind::Hill, StrategyKind::Anneal] {
        let spec = small_spec(strategy);
        let a = run(&spec, &opts(1)).unwrap().report;
        let b = run(&spec, &opts(1)).unwrap().report;
        let c = run(&spec, &opts(2)).unwrap().report;
        let name = spec.strategy.as_str();
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "{name}: run-to-run JSON must be byte-identical"
        );
        assert_eq!(
            a.to_json().to_string(),
            c.to_json().to_string(),
            "{name}: 1-thread and 2-thread JSON must be byte-identical"
        );
        assert_eq!(a.to_csv(), c.to_csv(), "{name}: CSV must be thread-invariant");
        // The shared fitness cache only dedups; its accounting is part
        // of the report and must be scheduling-invariant too.
        assert_eq!(a.unique_evals, c.unique_evals, "{name}");
        assert_eq!(a.cache_hits, c.cache_hits, "{name}");
    }
}

#[test]
fn reported_fitness_is_bitwise_reproducible_on_the_naive_oracle() {
    let spec = small_spec(StrategyKind::Hill);
    let report = run(&spec, &opts(2)).unwrap().report;
    let net = mgfl::net::by_name(&report.network).unwrap();
    let profile = DatasetProfile::by_name(&report.profile).unwrap();
    // Rebuild the winner from nothing but its reported genome and
    // re-simulate on the unbatched reference engine.
    let g = Genome {
        order: report.best.order.clone(),
        chords: report.best.chords.clone(),
        t: report.best.t,
    };
    assert_eq!(g.canonical_key(), report.best.key, "report key must match the genome");
    let mut topo = CandidateTopology::new(g.overlay(&net, &profile), &net, &profile, g.t);
    let naive = simulate_summary_naive(&mut topo, &net, &profile, report.rounds);
    assert_eq!(
        naive.mean_cycle_ms.to_bits(),
        report.best.mean_cycle_ms.to_bits(),
        "search fitness must be bit-identical to the naive simulator"
    );
    // Every accepted-trace fitness is a real simulation too — spot-check
    // each chain's start the same way.
    for chain in &report.chains {
        let s = Genome {
            order: chain.start.order.clone(),
            chords: chain.start.chords.clone(),
            t: chain.start.t,
        };
        let mut topo = CandidateTopology::new(s.overlay(&net, &profile), &net, &profile, s.t);
        let naive = simulate_summary_naive(&mut topo, &net, &profile, report.rounds);
        assert_eq!(
            naive.mean_cycle_ms.to_bits(),
            chain.start.mean_cycle_ms.to_bits(),
            "chain {} start fitness must replay bitwise",
            chain.chain
        );
    }
}

/// Six Gaia-coordinate silos: small enough to enumerate every ring
/// (5! = 120 orders, 60 after direction symmetry) with the naive
/// simulator as the oracle.
fn six_silo_net() -> NetworkSpec {
    NetworkSpec {
        name: "six".into(),
        silos: vec![
            Silo::new("virginia", 38.95, -77.45),
            Silo::new("california", 37.35, -121.95),
            Silo::new("ireland", 53.34, -6.26),
            Silo::new("tokyo", 35.68, 139.69),
            Silo::new("singapore", 1.35, 103.82),
            Silo::new("sao_paulo", -23.55, -46.63),
        ],
    }
}

fn permutations(items: &[usize]) -> Vec<Vec<usize>> {
    if items.len() <= 1 {
        return vec![items.to_vec()];
    }
    let mut out = Vec::new();
    for (i, &x) in items.iter().enumerate() {
        let mut rest = items.to_vec();
        rest.remove(i);
        for mut p in permutations(&rest) {
            p.insert(0, x);
            out.push(p);
        }
    }
    out
}

#[test]
fn search_finds_the_enumerated_optimum_on_a_six_silo_network() {
    let net = six_silo_net();
    let profile = DatasetProfile::femnist();
    let rounds = 120;
    let spec = OptimizeSpec {
        name: "six".into(),
        rounds,
        seed: 17,
        chains: 3,
        steps: 120,
        restart_after: 25,
        t_min: 3,
        t_max: 3,
        baseline_t: 3,
        max_degree: 2, // pure ring search: the space is exactly the 120 orders
        ..Default::default()
    };

    // Ground truth by exhaustive enumeration on the naive engine.
    let mut enum_best = f64::INFINITY;
    for perm in permutations(&[1, 2, 3, 4, 5]) {
        let mut order = vec![0];
        order.extend(perm);
        let g = Genome { order, chords: vec![], t: 3 };
        let mut topo = CandidateTopology::new(g.overlay(&net, &profile), &net, &profile, g.t);
        let s = simulate_summary_naive(&mut topo, &net, &profile, rounds);
        if s.mean_cycle_ms < enum_best {
            enum_best = s.mean_cycle_ms;
        }
    }
    assert!(
        (enum_best - 39.37042857536237).abs() < 1e-9,
        "pinned optimum drifted: {enum_best}"
    );

    // Both strategies must land exactly on the optimum (compare fitness
    // bits, not orders — the optimum is fitness-tied between orders).
    for strategy in [&HillClimb as &dyn SearchStrategy, &Anneal] {
        let ev = Evaluator::new(&net, &profile, rounds);
        let mut best = f64::INFINITY;
        for c in 0..spec.chains {
            let start = if c == 0 {
                paper_start(&net, &profile, &spec)
            } else {
                let mut rng =
                    Rng64::seed_from_u64(named_stream(spec.seed, &format!("optimize/init/{c}")));
                random_genome(&mut rng, net.n(), &spec)
            };
            let r = strategy.run_chain(c, start, &ev, &spec, None);
            if r.best_fitness_ms < best {
                best = r.best_fitness_ms;
            }
        }
        assert_eq!(
            best.to_bits(),
            enum_best.to_bits(),
            "{} must find the enumerated optimum (got {best}, want {enum_best})",
            strategy.name()
        );
    }
}

#[test]
fn committed_gaia_spec_beats_the_paper_multigraph() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/specs/optimize_gaia.toml");
    let spec = OptimizeSpec::from_toml_file(path).unwrap();
    assert_eq!(spec.name, "gaia");
    assert_eq!(spec.strategy, StrategyKind::Hill);
    let report = run(&spec, &opts(0)).unwrap().report;

    // Chain 0 starts bit-exactly at the paper design, so "beats the
    // baseline" is an apples-to-apples claim, not a calibration gap.
    assert_eq!(report.baselines[0].topology, "multigraph");
    assert_eq!(
        report.chains[0].start.mean_cycle_ms.to_bits(),
        report.baselines[0].mean_cycle_ms.to_bits(),
        "chain 0 must start exactly at the paper multigraph"
    );
    assert!(
        report.best.mean_cycle_ms < report.baselines[0].mean_cycle_ms,
        "searched best {} must beat the paper multigraph {}",
        report.best.mean_cycle_ms,
        report.baselines[0].mean_cycle_ms
    );
    assert!(
        report.improvement_pct > 25.0,
        "expected a large win on gaia (got {:.2}%, expected ~41%)",
        report.improvement_pct
    );
    // The ring baseline rides along for the paper's Table-1 framing.
    assert_eq!(report.baselines[1].topology, "ring");
    assert!(report.unique_evals > 100, "the search must actually explore");
    assert!(report.cache_hits > 0, "revisited candidates must hit the cache");
}
