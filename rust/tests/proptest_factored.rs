//! Property tests for the period-factorized engine, backed by the real
//! proptest crate (gated behind `--features proptest` like the other
//! proptest suites; the offline build vendors no proptest).
//!
//! Strategy: random multiplicity assignments over synthetic networks —
//! a ring backbone plus random chords over a seeded `synth-geo`
//! network, each pair carrying an arbitrary multiplicity — simulated
//! four ways:
//!
//! * the naive `DelayTracker` reference (the oracle),
//! * the streaming engine (the factorization hidden behind a wrapper),
//! * the factored engine invoked directly,
//! * whatever `simulate_summary` dispatches to (periodic when the LCM
//!   is small enough, factored otherwise — both legs get exercised
//!   across cases).
//!
//! All four `SimSummary`s must be **bitwise** equal, counters included.

#![cfg(feature = "proptest")]

use std::collections::BTreeSet;

use mgfl::graph::Graph;
use mgfl::net::{synth, DatasetProfile};
use mgfl::simtime::{
    simulate_summary, simulate_summary_factored_with_stats, simulate_summary_naive,
    simulate_summary_streaming_with_stats, EngineKind, SimSummary,
};
use mgfl::topo::{RoundPlan, ScheduleFactorization, TopologyDesign};
use mgfl::util::lcm;
use proptest::prelude::*;
use proptest::test_runner::TestCaseError;

/// A synthetic multigraph schedule: an arbitrary edge set with
/// arbitrary multiplicities, planned in full every round with pair
/// (u, v, m) strong iff `k % m == 0` — the factorization closed form
/// as a standalone design.
struct RandomMultigraph {
    overlay: Graph,
    edges: Vec<(usize, usize, u32)>,
}

impl RandomMultigraph {
    fn new(n: usize, edges: Vec<(usize, usize, u32)>) -> Self {
        let overlay = Graph::from_edges(n, edges.iter().map(|&(u, v, _)| (u, v, 1.0)));
        RandomMultigraph { overlay, edges }
    }
}

impl TopologyDesign for RandomMultigraph {
    fn name(&self) -> &str {
        "random-multigraph"
    }

    fn overlay(&self) -> &Graph {
        &self.overlay
    }

    fn plan(&mut self, k: usize) -> RoundPlan {
        let mut out = RoundPlan::empty(self.overlay.n());
        self.plan_into(k, &mut out);
        out
    }

    fn plan_into(&mut self, k: usize, out: &mut RoundPlan) {
        out.reset(self.overlay.n());
        for &(u, v, m) in &self.edges {
            let ty = if k as u64 % m as u64 == 0 {
                mgfl::delay::EdgeType::Strong
            } else {
                mgfl::delay::EdgeType::Weak
            };
            out.push(u, v, ty);
        }
    }

    fn period(&self) -> Option<u64> {
        Some(self.edges.iter().map(|&(_, _, m)| m as u64).fold(1, lcm))
    }

    fn factorization(&self) -> Option<ScheduleFactorization> {
        Some(ScheduleFactorization {
            n: self.overlay.n(),
            edges: self.edges.clone(),
        })
    }

    fn seed_sensitive(&self) -> bool {
        false
    }
}

/// The same schedule with its structure hidden (no period, no
/// factorization): the dispatcher has no choice but to stream.
struct Hidden(RandomMultigraph);

impl TopologyDesign for Hidden {
    fn name(&self) -> &str {
        self.0.name()
    }

    fn overlay(&self) -> &Graph {
        self.0.overlay()
    }

    fn plan(&mut self, k: usize) -> RoundPlan {
        self.0.plan(k)
    }

    fn plan_into(&mut self, k: usize, out: &mut RoundPlan) {
        self.0.plan_into(k, out);
    }

    fn period(&self) -> Option<u64> {
        None
    }
}

fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) -> Result<(), TestCaseError> {
    prop_assert_eq!(&a.topology, &b.topology, "{}", ctx);
    prop_assert_eq!(a.rounds, b.rounds, "{}", ctx);
    prop_assert_eq!(
        a.total_ms.to_bits(),
        b.total_ms.to_bits(),
        "{}: total_ms {} vs {}",
        ctx,
        a.total_ms,
        b.total_ms
    );
    prop_assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{}", ctx);
    prop_assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{}", ctx);
    prop_assert_eq!(a.max_isolated, b.max_isolated, "{}", ctx);
    Ok(())
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(40))]

    #[test]
    fn factored_streaming_and_naive_agree_bitwise(
        n in 4usize..40,
        net_seed in 1u64..1000,
        chord_seeds in proptest::collection::vec((0usize..1000, 0usize..1000), 0..12),
        mult_seed in 0u64..(1 << 32),
        max_mult in 1u32..=12,
        rounds in 1usize..160,
    ) {
        let net = synth::by_name(&format!("synth-geo-n{n}-s{net_seed}"))
            .expect("synth size in range");
        let prof = DatasetProfile::femnist();

        // Ring backbone (connected, every node participates) plus
        // random chords, deduplicated; multiplicities derived from a
        // cheap splitmix over the pair so they are reproducible.
        let mut pairs: BTreeSet<(usize, usize)> = BTreeSet::new();
        for i in 0..n - 1 {
            pairs.insert((i, i + 1));
        }
        pairs.insert((0, n - 1));
        for &(a, b) in &chord_seeds {
            let (u, v) = (a % n, b % n);
            if u < v {
                pairs.insert((u, v));
            }
        }
        let edges: Vec<(usize, usize, u32)> = pairs
            .into_iter()
            .map(|(u, v)| {
                let h = mult_seed
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(((u as u64) << 32) | v as u64)
                    .wrapping_mul(0xBF58476D1CE4E5B9);
                (u, v, 1 + (h >> 33) as u32 % max_mult)
            })
            .collect();

        let mut naive_topo = RandomMultigraph::new(n, edges.clone());
        let naive = simulate_summary_naive(&mut naive_topo, &net, &prof, rounds);

        let mut hidden = Hidden(RandomMultigraph::new(n, edges.clone()));
        let (streamed, s_stats) =
            simulate_summary_streaming_with_stats(&mut hidden, &net, &prof, rounds);
        prop_assert_eq!(s_stats.kind, EngineKind::Streaming);
        assert_bitwise(&naive, &streamed, "streaming vs naive")?;

        let factored_topo = RandomMultigraph::new(n, edges.clone());
        let (factored, f_stats) =
            simulate_summary_factored_with_stats(&factored_topo, &net, &prof, rounds)
                .expect("random multigraph factorizes");
        prop_assert_eq!(f_stats.kind, EngineKind::Factored);
        assert_bitwise(&naive, &factored, "factored vs naive")?;

        // Full dispatch: periodic when the LCM fits the budget,
        // factored otherwise — either way, same bits.
        let mut dispatch_topo = RandomMultigraph::new(n, edges);
        let dispatched = simulate_summary(&mut dispatch_topo, &net, &prof, rounds);
        assert_bitwise(&naive, &dispatched, "dispatch vs naive")?;
    }
}
