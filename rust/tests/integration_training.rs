//! Integration: the full L3 coordinator — real DPASGD rounds over the
//! PJRT runtime, multigraph vs baselines, isolated-node policies, and
//! metric traces. This is the system-level correctness signal: all three
//! layers composing on a real (small) federated workload.

use mgfl::config::{ExperimentConfig, IsolatedPolicy, TopologyKind, TrainConfig};
use mgfl::coordinator::Trainer;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::runtime::{artifacts_available, ModelRuntime};
use mgfl::topo::{ring::RingTopology, MultigraphTopology};

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn small_cfg(rounds: usize) -> TrainConfig {
    TrainConfig {
        model: "femnist_mlp".into(),
        rounds,
        lr: 0.08,
        eval_examples: 256,
        ..Default::default()
    }
}

#[test]
fn multigraph_training_loss_decreases_on_gaia() {
    require_artifacts!();
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();
    let rt = ModelRuntime::load_default("femnist_mlp").unwrap();
    let topo = Box::new(MultigraphTopology::from_network(&net, &prof, 5));
    let mut trainer = Trainer::new(rt, topo, net, prof, small_cfg(12)).unwrap();
    let trace = trainer.run(6).unwrap();

    assert_eq!(trace.records.len(), 12);
    let first = trace.records[0].train_loss;
    let last = trace.records[11].train_loss;
    assert!(last < 0.8 * first, "loss {first} -> {last}");
    // Eval happened and produced sane numbers.
    let acc = trace.final_accuracy().expect("eval ran");
    assert!((0.0..=1.0).contains(&acc));
    // Isolated nodes appeared (multigraph on gaia has isolating states).
    assert!(trace.records.iter().any(|r| r.isolated > 0));
    // Simulated clock is monotone.
    assert!(trace
        .records
        .windows(2)
        .all(|w| w[1].sim_elapsed_ms > w[0].sim_elapsed_ms));
}

#[test]
fn multigraph_faster_than_ring_same_rounds() {
    require_artifacts!();
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();

    let rt1 = ModelRuntime::load_default("femnist_mlp").unwrap();
    let ring = Box::new(RingTopology::new(&net, &prof));
    let mut t_ring = Trainer::new(rt1, ring, net.clone(), prof.clone(), small_cfg(8)).unwrap();
    let ring_trace = t_ring.run(0).unwrap();

    let rt2 = ModelRuntime::load_default("femnist_mlp").unwrap();
    let ours = Box::new(MultigraphTopology::from_network(&net, &prof, 5));
    let mut t_ours = Trainer::new(rt2, ours, net, prof, small_cfg(8)).unwrap();
    let ours_trace = t_ours.run(0).unwrap();

    // The headline claim at system level: same #rounds, less simulated
    // wall-clock, comparable training loss.
    assert!(
        ours_trace.total_sim_ms() < ring_trace.total_sim_ms(),
        "ours {} ms vs ring {} ms",
        ours_trace.total_sim_ms(),
        ring_trace.total_sim_ms()
    );
    let lr = ring_trace.final_train_loss().unwrap();
    let lo = ours_trace.final_train_loss().unwrap();
    assert!(lo < 1.4 * lr + 0.5, "ours loss {lo} vs ring {lr}");
}

#[test]
fn isolated_policies_both_train() {
    require_artifacts!();
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();
    for policy in [IsolatedPolicy::StaleAggregate, IsolatedPolicy::Skip] {
        let rt = ModelRuntime::load_default("femnist_mlp").unwrap();
        let topo = Box::new(MultigraphTopology::from_network(&net, &prof, 5));
        let cfg = TrainConfig { isolated_policy: policy, ..small_cfg(6) };
        let mut trainer = Trainer::new(rt, topo, net.clone(), prof.clone(), cfg).unwrap();
        let trace = trainer.run(0).unwrap();
        let first = trace.records[0].train_loss;
        let last = trace.final_train_loss().unwrap();
        assert!(last < first, "{policy:?}: {first} -> {last}");
    }
}

#[test]
fn trainer_from_config_star_topology() {
    require_artifacts!();
    let cfg = ExperimentConfig {
        network: "gaia".into(),
        topology: TopologyKind::Star,
        sim_rounds: 4,
        train: Some(small_cfg(4)),
        ..Default::default()
    };
    let mut trainer = Trainer::from_config(&cfg).unwrap();
    assert_eq!(trainer.topology_name(), "star");
    assert_eq!(trainer.num_silos(), 11);
    let trace = trainer.run(0).unwrap();
    assert_eq!(trace.records.len(), 4);
    // Star never isolates anyone.
    assert!(trace.records.iter().all(|r| r.isolated == 0));
}

#[test]
fn deterministic_given_seed() {
    require_artifacts!();
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();
    let run = || {
        let rt = ModelRuntime::load_default("femnist_mlp").unwrap();
        let topo = Box::new(MultigraphTopology::from_network(&net, &prof, 5));
        let mut trainer =
            Trainer::new(rt, topo, net.clone(), prof.clone(), small_cfg(5)).unwrap();
        trainer.run(0).unwrap()
    };
    let a = run();
    let b = run();
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.train_loss, rb.train_loss, "round {}", ra.round);
        assert_eq!(ra.cycle_ms, rb.cycle_ms);
    }
}

#[test]
fn trace_csv_has_eval_columns() {
    require_artifacts!();
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();
    let rt = ModelRuntime::load_default("femnist_mlp").unwrap();
    let topo = Box::new(RingTopology::new(&net, &prof));
    let mut trainer = Trainer::new(rt, topo, net, prof, small_cfg(4)).unwrap();
    let trace = trainer.run(2).unwrap();
    let dir = std::env::temp_dir().join(format!("mgfl_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    trace.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 5);
    // eval at rounds 2 and 4 -> at least two rows with eval_acc set
    let with_eval = text.lines().skip(1).filter(|l| !l.ends_with(",,")).count();
    assert!(with_eval >= 2, "{text}");
}
