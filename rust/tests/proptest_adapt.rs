//! Property tests for the adaptation layer (`search::adapt`), backed by
//! the real proptest crate (gated behind `--features proptest` like
//! `tests/proptest_scenarios.rs`; the offline build vendors no
//! proptest).
//!
//! Four pins over random churn timelines:
//!
//! * **Policy `none` is PR 9**: an inactive [`AdaptSpec`] routed through
//!   the adaptive entry point must reproduce the static masked scenario
//!   tracker bitwise — totals, isolation counters, degraded-mode
//!   metrics — or fail with the same structured error.
//! * **Ladder**: a warm search with zero budget must degrade to the
//!   rebuild policy bitwise, recording its fallbacks.
//! * **Oracle**: the spliced production engine must match the naive
//!   single-segment oracle bitwise for every policy.
//! * **Scheduling invariance**: adaptive sweep artifacts (JSON + CSV)
//!   are byte-identical across thread counts and with dedup on or off.
#![cfg(feature = "proptest")]

use std::sync::Arc;

use mgfl::config::TopologyKind;
use mgfl::net::synth::geo_clustered;
use mgfl::net::{zoo, DatasetProfile, NetworkSpec};
use mgfl::search::{
    simulate_summary_adaptive, simulate_summary_adaptive_oracle, AdaptPolicy, AdaptSpec,
};
use mgfl::simtime::{simulate_summary_scenario_naive, ScenarioSpec, SimSummary};
use mgfl::sweep::{run, RunOptions, SweepSpec};
use mgfl::topo::{MultigraphTopology, TopologyDesign};
use proptest::prelude::*;

/// One randomly-drawn event, still abstract (silo indices are resolved
/// against the concrete network's size at render time).
#[derive(Debug, Clone)]
enum RawEvent {
    Leave { round: usize, silo: usize },
    Rejoin { round: usize, silo: usize },
    Scale { round: usize, factor: f64 },
    Jitter { round: usize, amp: f64 },
    Outage { round: usize, frac: f64, dur: usize, epicenter: Option<usize> },
}

impl RawEvent {
    /// Render as the sweep-spec DSL string, clamping silo references
    /// into `0..n` so every draw is valid on the chosen network.
    fn to_dsl(&self, n: usize) -> String {
        match self {
            RawEvent::Leave { round, silo } => format!("leave@{round}:silo={}", silo % n),
            RawEvent::Rejoin { round, silo } => format!("rejoin@{round}:silo={}", silo % n),
            RawEvent::Scale { round, factor } => format!("scale@{round}:factor={factor}"),
            RawEvent::Jitter { round, amp } => format!("jitter@{round}:amp={amp}"),
            RawEvent::Outage { round, frac, dur, epicenter } => {
                let epi = epicenter.map(|e| format!(":epicenter={}", e % n)).unwrap_or_default();
                format!("outage@{round}:frac={frac}:dur={dur}{epi}")
            }
        }
    }
}

/// Event strategy: rounds drawn from a small range on purpose, so
/// same-round stacking, short segments, and freeze windows overlapping
/// the next boundary all come up. Mask-changing events dominate the
/// weights — those are the ones that trigger re-planning.
fn raw_event(rounds: usize) -> impl Strategy<Value = RawEvent> {
    let r = 0..rounds;
    let leave = (r.clone(), 0usize..32).prop_map(|(round, silo)| RawEvent::Leave { round, silo });
    let rejoin =
        (r.clone(), 0usize..32).prop_map(|(round, silo)| RawEvent::Rejoin { round, silo });
    let scale = (r.clone(), 1u32..40)
        .prop_map(|(round, f)| RawEvent::Scale { round, factor: f as f64 / 10.0 });
    let jitter = (r.clone(), 0u32..80)
        .prop_map(|(round, a)| RawEvent::Jitter { round, amp: a as f64 / 10.0 });
    let outage = (r, 1u32..7, 1usize..25, prop::option::of(0usize..32)).prop_map(
        |(round, decifrac, dur, epicenter)| RawEvent::Outage {
            round,
            frac: decifrac as f64 / 10.0,
            dur,
            epicenter,
        },
    );
    prop_oneof![4 => leave, 3 => rejoin, 2 => scale, 1 => jitter, 2 => outage]
}

/// The network pool: both zoo networks plus seeded synthetic
/// geo-clusters of different sizes.
fn network(choice: usize) -> NetworkSpec {
    match choice % 4 {
        0 => zoo::gaia(),
        1 => zoo::amazon(),
        2 => geo_clustered(9, 41),
        _ => geo_clustered(14, 42),
    }
}

fn spec_on(net: &NetworkSpec, seed: u64, raw: &[RawEvent]) -> ScenarioSpec {
    let strs: Vec<String> = raw.iter().map(|e| e.to_dsl(net.n())).collect();
    ScenarioSpec::from_event_strs(seed, &strs).expect("clamped draws always parse")
}

fn base(net: &NetworkSpec, prof: &DatasetProfile, t: u32) -> Box<dyn TopologyDesign> {
    Box::new(MultigraphTopology::from_network(net, prof, t))
}

fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) {
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.total_ms.to_bits(), b.total_ms.to_bits(), "{ctx}: total_ms");
    assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}: mean_cycle_ms");
    assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}: isolation rounds");
    assert_eq!(a.max_isolated, b.max_isolated, "{ctx}: max isolated");
    assert_eq!(a.scenario, b.scenario, "{ctx}: degraded-mode metrics");
}

/// Drop the adapt accounting block so two summaries produced under
/// different (but behaviorally identical) policies compare equal.
fn strip_adapt(mut s: SimSummary) -> SimSummary {
    if let Some(m) = s.scenario.as_mut() {
        m.adapt = None;
    }
    s
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(24))]

    /// An inactive adapt spec must be invisible: the adaptive entry
    /// point under policy `none` reproduces the PR 9 masked scenario
    /// tracker bitwise — including the absence of an adapt metrics
    /// block — or errors identically on non-viable timelines.
    #[test]
    fn policy_none_matches_the_pr9_scenario_path_bitwise(
        raw in prop::collection::vec(raw_event(48), 1..8),
        net_choice in 0usize..4,
        t in prop::sample::select(vec![3u32, 5]),
        seed in 0u64..1000,
    ) {
        let rounds = 48usize;
        let net = network(net_choice);
        let prof = DatasetProfile::femnist();
        let sc = spec_on(&net, seed, &raw);
        let spec = AdaptSpec::default();
        prop_assert!(!spec.is_active());
        let got = simulate_summary_adaptive(base(&net, &prof, t), &net, &prof, rounds, &sc, &spec, t);
        let mut b = MultigraphTopology::from_network(&net, &prof, t);
        let want = simulate_summary_scenario_naive(&mut b, &net, &prof, rounds, &sc);
        match (want, got) {
            (Err(we), Err(ge)) => prop_assert_eq!(we, ge, "errors must match"),
            (Ok(want), Ok((got, _))) => {
                prop_assert!(
                    got.scenario.as_ref().is_some_and(|m| m.adapt.is_none()),
                    "policy none must not grow an adapt block"
                );
                assert_bitwise(&want, &got, "policy none vs PR 9 tracker");
            }
            _ => prop_assert!(false, "adaptive and static paths disagree about viability"),
        }
    }

    /// The graceful-degradation ladder: a warm search with no eval
    /// budget can never plan, so every re-planned segment falls back to
    /// the rebuild genome and the run equals policy `rebuild` bitwise.
    #[test]
    fn zero_budget_warm_equals_rebuild_everywhere(
        raw in prop::collection::vec(raw_event(48), 1..8),
        net_choice in 0usize..4,
        seed in 0u64..1000,
    ) {
        let rounds = 48usize;
        let net = network(net_choice);
        let prof = DatasetProfile::femnist();
        let sc = spec_on(&net, seed, &raw);
        let warm0 = AdaptSpec { policy: AdaptPolicy::Warm, budget: 0, ..Default::default() };
        let rebuild = AdaptSpec { policy: AdaptPolicy::Rebuild, ..Default::default() };
        let w = simulate_summary_adaptive(base(&net, &prof, 5), &net, &prof, rounds, &sc, &warm0, 5);
        let r =
            simulate_summary_adaptive(base(&net, &prof, 5), &net, &prof, rounds, &sc, &rebuild, 5);
        match (w, r) {
            (Err(we), Err(re)) => prop_assert_eq!(we, re, "errors must match"),
            (Ok((w, _)), Ok((r, _))) => {
                let wm = w.scenario.as_ref().unwrap().adapt.clone().unwrap();
                let rm = r.scenario.as_ref().unwrap().adapt.clone().unwrap();
                prop_assert_eq!(wm.replans, rm.replans, "same boundaries, same replans");
                prop_assert_eq!(wm.evals_spent, 0, "no budget, no evals");
                prop_assert!(
                    wm.fallbacks >= rm.fallbacks.max(wm.replans),
                    "every zero-budget replan must fall down the ladder ({wm:?} vs {rm:?})"
                );
                assert_bitwise(&strip_adapt(w), &strip_adapt(r), "zero-budget warm vs rebuild");
            }
            _ => prop_assert!(false, "warm and rebuild disagree about viability"),
        }
    }

    /// The tentpole invariant: for every policy, the spliced production
    /// engine matches the naive single-segment oracle bitwise — cycle
    /// totals, isolation counters, degraded-mode metrics, and the adapt
    /// accounting block itself.
    #[test]
    fn adaptive_engine_matches_the_single_segment_oracle_bitwise(
        raw in prop::collection::vec(raw_event(48), 1..8),
        net_choice in 0usize..4,
        policy in prop::sample::select(vec![AdaptPolicy::None, AdaptPolicy::Rebuild, AdaptPolicy::Warm]),
        seed in 0u64..1000,
    ) {
        let rounds = 48usize;
        let net = network(net_choice);
        let prof = DatasetProfile::femnist();
        let sc = spec_on(&net, seed, &raw);
        let spec = AdaptSpec { policy, budget: 6, eval_rounds: 20, ..Default::default() };
        let a = simulate_summary_adaptive(base(&net, &prof, 5), &net, &prof, rounds, &sc, &spec, 5);
        let b = simulate_summary_adaptive_oracle(
            base(&net, &prof, 5),
            &net,
            &prof,
            rounds,
            &sc,
            &spec,
            5,
        );
        match (a, b) {
            (Err(ae), Err(be)) => prop_assert_eq!(ae, be, "errors must match"),
            (Ok((a, sa)), Ok((b, sb))) => {
                prop_assert_eq!(sa.kind, sb.kind);
                assert_bitwise(&a, &b, "engine vs oracle");
            }
            _ => prop_assert!(false, "engine and oracle disagree about viability"),
        }
    }
}

proptest! {
    // Whole-sweep cases simulate one grid per policy twice per knob
    // setting; trim the case count accordingly.
    #![proptest_config(ProptestConfig::with_cases(8))]

    /// Adaptive sweep artifacts are a pure function of the spec: JSON
    /// and CSV must be byte-identical across thread counts and with the
    /// dedup layer on or off (adaptive cells always run solo, so dedup
    /// must be a pure pass-through for them).
    #[test]
    fn adaptive_sweep_artifacts_are_thread_and_dedup_invariant(
        raw in prop::collection::vec(raw_event(40), 1..6),
        seed in 0u64..1000,
    ) {
        let net = zoo::gaia();
        let sc = spec_on(&net, seed, &raw);
        let spec = SweepSpec {
            name: "prop_adapt".into(),
            topologies: vec![TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![17],
            rounds: 40,
            scenario: Some(Arc::new(sc)),
            adapt: vec![
                Arc::new(AdaptSpec::default()),
                Arc::new(AdaptSpec {
                    policy: AdaptPolicy::Warm,
                    budget: 6,
                    eval_rounds: 20,
                    ..Default::default()
                }),
            ],
        };
        spec.validate().unwrap();
        let baseline = run(&spec, &RunOptions { threads: 1, progress: false, dedup: false })
            .unwrap()
            .report;
        prop_assert_eq!(baseline.cells.len(), 2, "one row per policy");
        prop_assert!(baseline.adaptive, "the report must flag its adapt columns");
        for (threads, dedup) in [(1, true), (4, false), (4, true)] {
            let got = run(&spec, &RunOptions { threads, progress: false, dedup })
                .unwrap()
                .report;
            prop_assert_eq!(
                baseline.to_json().to_string(),
                got.to_json().to_string(),
                "JSON must be byte-identical at threads={} dedup={}",
                threads,
                dedup
            );
            prop_assert_eq!(
                baseline.to_csv(),
                got.to_csv(),
                "CSV must be byte-identical at threads={} dedup={}",
                threads,
                dedup
            );
        }
    }
}
