//! Model-based property tests for the fault-injection scenario layer,
//! backed by the real proptest crate (gated behind `--features proptest`
//! like `tests/proptest_sweep.rs`; the offline build vendors no
//! proptest).
//!
//! Two families:
//!
//! * **Stateful model**: random event sequences are folded through an
//!   independent, naive per-round event model; [`build_timeline`]'s
//!   piecewise-static segments must agree with the model at every
//!   round (mask, up-count, capacity scale, partition of `0..rounds`).
//! * **Engine equivalence**: over zoo and synthetic geo networks, the
//!   naive masked tracker (the oracle) must match the piecewise
//!   compiled, factored, and batched engines *bitwise* — totals, mean
//!   cycle, isolation counters, and the degraded-mode metrics — under
//!   random churn, including event rounds packed tightly together so
//!   segment boundaries land mid-period and exercise the Eq. 4 backlog
//!   carry across segments.
#![cfg(feature = "proptest")]

use mgfl::net::synth::geo_clustered;
use mgfl::net::{zoo, DatasetProfile, NetworkSpec};
use mgfl::simtime::{
    build_timeline, run_scenario_batched, run_scenario_compiled, run_scenario_factored,
    simulate_summary_scenario, simulate_summary_scenario_naive, BatchLane, CompiledTopology,
    EngineKind, ScenarioSpec, SimSummary,
};
use mgfl::topo::MultigraphTopology;
use proptest::prelude::*;

/// One randomly-drawn event, still abstract (silo indices are resolved
/// against the concrete network's size at build time).
#[derive(Debug, Clone)]
enum RawEvent {
    Leave { round: usize, silo: usize },
    Rejoin { round: usize, silo: usize },
    Scale { round: usize, factor: f64 },
    Jitter { round: usize, amp: f64 },
    Outage { round: usize, frac: f64, dur: usize, epicenter: Option<usize> },
}

impl RawEvent {
    /// Render as the sweep-spec DSL string, clamping silo references
    /// into `0..n` so every draw is valid on the chosen network.
    fn to_dsl(&self, n: usize) -> String {
        match self {
            RawEvent::Leave { round, silo } => format!("leave@{round}:silo={}", silo % n),
            RawEvent::Rejoin { round, silo } => format!("rejoin@{round}:silo={}", silo % n),
            RawEvent::Scale { round, factor } => format!("scale@{round}:factor={factor}"),
            RawEvent::Jitter { round, amp } => format!("jitter@{round}:amp={amp}"),
            RawEvent::Outage { round, frac, dur, epicenter } => {
                let epi = epicenter.map(|e| format!(":epicenter={}", e % n)).unwrap_or_default();
                format!("outage@{round}:frac={frac}:dur={dur}{epi}")
            }
        }
    }
}

/// Event strategy. Rounds are drawn from a small range on purpose:
/// collisions and near-collisions are the interesting cases (same-round
/// stacking, zero-length segments, boundaries adjacent to the period).
fn raw_event(rounds: usize, with_outage: bool) -> impl Strategy<Value = RawEvent> {
    let r = 0..rounds;
    let leave = (r.clone(), 0usize..32).prop_map(|(round, silo)| RawEvent::Leave { round, silo });
    let rejoin =
        (r.clone(), 0usize..32).prop_map(|(round, silo)| RawEvent::Rejoin { round, silo });
    let scale =
        (r.clone(), 1u32..40).prop_map(|(round, f)| RawEvent::Scale { round, factor: f as f64 / 10.0 });
    let jitter =
        (r.clone(), 0u32..80).prop_map(|(round, a)| RawEvent::Jitter { round, amp: a as f64 / 10.0 });
    let outage = (r, 1u32..7, 1usize..25, prop::option::of(0usize..32)).prop_map(
        |(round, decifrac, dur, epicenter)| RawEvent::Outage {
            round,
            frac: decifrac as f64 / 10.0,
            dur,
            epicenter,
        },
    );
    if with_outage {
        prop_oneof![4 => leave, 3 => rejoin, 2 => scale, 2 => jitter, 2 => outage].boxed()
    } else {
        prop_oneof![4 => leave, 3 => rejoin, 2 => scale, 2 => jitter].boxed()
    }
}

/// The network pool the engine-equivalence tests draw from: both zoo
/// networks plus seeded synthetic geo-clusters of different sizes.
fn network(choice: usize) -> NetworkSpec {
    match choice % 4 {
        0 => zoo::gaia(),
        1 => zoo::amazon(),
        2 => geo_clustered(9, 41),
        _ => geo_clustered(14, 42),
    }
}

fn spec_on(net: &NetworkSpec, seed: u64, raw: &[RawEvent]) -> ScenarioSpec {
    let strs: Vec<String> = raw.iter().map(|e| e.to_dsl(net.n())).collect();
    ScenarioSpec::from_event_strs(seed, &strs).expect("clamped draws always parse")
}

fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) {
    assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
    assert_eq!(a.total_ms.to_bits(), b.total_ms.to_bits(), "{ctx}: total_ms");
    assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}: mean_cycle_ms");
    assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}: isolation rounds");
    assert_eq!(a.max_isolated, b.max_isolated, "{ctx}: max isolated");
    assert_eq!(a.scenario, b.scenario, "{ctx}: degraded-mode metrics");
}

proptest! {
    // The model test is pure bookkeeping (no simulation), so it can
    // afford the default case count; the engine tests below simulate
    // real cells and trim theirs.

    /// Fold the events through a naive one-round-at-a-time model and
    /// check `build_timeline` agrees everywhere. Outages are excluded
    /// here (their blast region is geometry- and seed-dependent — the
    /// engine tests cover them); everything else is modeled exactly.
    #[test]
    fn timeline_segments_agree_with_a_naive_event_model(
        raw in prop::collection::vec(raw_event(60, false), 0..10),
        rounds in 1usize..60,
        seed in 0u64..1000,
    ) {
        let net = zoo::gaia();
        let n = net.n();
        let sc = spec_on(&net, seed, &raw);
        // The model: replay events round by round.
        let mut up = vec![true; n];
        let mut scale = 1.0f64;
        let mut model: Vec<(Vec<bool>, f64)> = Vec::with_capacity(rounds);
        let mut dies_at: Option<usize> = None;
        'rounds: for k in 0..rounds {
            for e in &sc.events {
                if e.round != k {
                    continue;
                }
                match e.kind {
                    mgfl::simtime::EventKind::Leave { silo } => up[silo] = false,
                    mgfl::simtime::EventKind::Rejoin { silo } => up[silo] = true,
                    mgfl::simtime::EventKind::Scale { factor } => scale = factor,
                    _ => {}
                }
            }
            if up.iter().filter(|&&u| u).count() < 2 {
                dies_at = Some(k);
                break 'rounds;
            }
            model.push((up.clone(), scale));
        }
        let timeline = build_timeline(&sc, &net, rounds);
        if let Some(k) = dies_at {
            let err = timeline.expect_err("model says the network empties");
            prop_assert!(
                err.contains(&format!("at round {k}")) && err.contains("need at least 2"),
                "unexpected error: {err}"
            );
            return Ok(());
        }
        let timeline = timeline.unwrap();
        // Segments partition 0..rounds in order, none empty.
        let mut next = 0usize;
        for seg in &timeline.segments {
            prop_assert_eq!(seg.start, next, "segments must tile the run");
            prop_assert!(seg.len > 0, "zero-length segments must be dropped");
            next = seg.start + seg.len;
            // Constant state inside the segment, equal to the model.
            for k in seg.start..next {
                let (ref want_up, want_scale) = model[k];
                prop_assert_eq!(&seg.up, want_up, "round {} mask", k);
                prop_assert_eq!(seg.scale.to_bits(), want_scale.to_bits(), "round {} scale", k);
            }
            prop_assert_eq!(seg.up_count, seg.up.iter().filter(|&&u| u).count());
        }
        prop_assert_eq!(next, rounds, "segments must cover every round");
        // Jitter series: per-round, finite, empty iff never enabled
        // inside the horizon (events at `round >= rounds` never fire).
        if sc
            .events
            .iter()
            .any(|e| e.round < rounds && matches!(e.kind, mgfl::simtime::EventKind::Jitter { .. }))
        {
            prop_assert_eq!(timeline.jitter.len(), rounds);
            prop_assert!(timeline.jitter.iter().all(|j| j.is_finite() && *j >= 0.0));
        } else {
            prop_assert!(timeline.jitter.is_empty());
        }
    }
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(24))]

    /// The tentpole invariant: naive == compiled == factored == batched,
    /// bitwise, for arbitrary churn over zoo + synthetic networks —
    /// or the same structured error from every path.
    #[test]
    fn every_engine_agrees_with_the_naive_oracle_bitwise(
        raw in prop::collection::vec(raw_event(48, true), 1..8),
        net_choice in 0usize..4,
        t in prop::sample::select(vec![3u32, 5]),
        seed in 0u64..1000,
    ) {
        let rounds = 48usize;
        let net = network(net_choice);
        let prof = DatasetProfile::femnist();
        let sc = spec_on(&net, seed, &raw);
        let mut naive_topo = MultigraphTopology::from_network(&net, &prof, t);
        let want = simulate_summary_scenario_naive(&mut naive_topo, &net, &prof, rounds, &sc);

        // Dispatcher (periodic multigraph → compiled piecewise path).
        let mut topo = MultigraphTopology::from_network(&net, &prof, t);
        let got = simulate_summary_scenario(&mut topo, &net, &prof, rounds, &sc);
        match (&want, &got) {
            (Err(we), Err(ge)) => {
                // Structured per-cell error: every path reports the
                // same string, nothing panics.
                prop_assert_eq!(we, ge);
                let f = MultigraphTopology::from_network(&net, &prof, t);
                if let Some(fact) = run_scenario_factored(&f, &net, &prof, rounds, &sc) {
                    prop_assert_eq!(&fact.expect_err("factored must error too"), we);
                }
                return Ok(());
            }
            (Err(_), Ok(_)) | (Ok(_), Err(_)) => {
                prop_assert!(false, "oracle and dispatcher disagree about viability");
            }
            (Ok(want), Ok((got, _stats))) => {
                assert_bitwise(want, got, "dispatcher vs oracle");
            }
        }
        let want = want.unwrap();

        // Factored grouped path (admission is network-dependent).
        let f = MultigraphTopology::from_network(&net, &prof, t);
        if let Some(fact) = run_scenario_factored(&f, &net, &prof, rounds, &sc) {
            let (fact, stats) = fact.unwrap();
            prop_assert_eq!(stats.kind, EngineKind::Factored);
            assert_bitwise(&want, &fact, "factored vs oracle");
        }

        // Compiled + single-lane batched paths.
        let mut c = MultigraphTopology::from_network(&net, &prof, t);
        if let Some(ct) = CompiledTopology::compile(&mut c, rounds) {
            let (solo, _) = run_scenario_compiled(&ct, &net, &prof, rounds, &sc).unwrap();
            assert_bitwise(&want, &solo, "compiled vs oracle");
            let lanes = [BatchLane { ct: &ct, net: &net, profile: &prof }];
            let mut lanes_out = run_scenario_batched(&ct, &lanes, rounds, &sc).unwrap();
            let (batched, stats) = lanes_out.pop().unwrap();
            prop_assert_eq!(stats.kind, EngineKind::Batched);
            assert_bitwise(&want, &batched, "batched vs oracle");
        }
    }

    /// Batched lanes must be width-independent under churn: each lane of
    /// a 3-profile batch equals its own solo compiled run bitwise, with
    /// the backlog carried across every segment boundary identically.
    #[test]
    fn batched_lanes_are_width_independent_under_random_churn(
        raw in prop::collection::vec(raw_event(40, true), 1..6),
        seed in 0u64..1000,
    ) {
        let rounds = 40usize;
        let net = zoo::gaia();
        let sc = spec_on(&net, seed, &raw);
        let profiles = DatasetProfile::all();
        let mut compiles = Vec::new();
        for prof in &profiles {
            let mut topo = MultigraphTopology::from_network(&net, prof, 5);
            compiles.push(CompiledTopology::compile(&mut topo, rounds).expect("gaia t=5 compiles"));
        }
        let lanes: Vec<BatchLane> = profiles
            .iter()
            .zip(&compiles)
            .map(|(prof, ct)| BatchLane { ct, net: &net, profile: prof })
            .collect();
        let batched = run_scenario_batched(&compiles[0], &lanes, rounds, &sc);
        match batched {
            Err(e) => {
                // Chunk-wide structured error: the solo path must agree.
                let solo = run_scenario_compiled(&compiles[0], &net, &profiles[0], rounds, &sc);
                prop_assert_eq!(&solo.expect_err("solo must error too"), &e);
            }
            Ok(per_lane) => {
                prop_assert_eq!(per_lane.len(), profiles.len());
                for ((prof, ct), (summary, stats)) in
                    profiles.iter().zip(&compiles).zip(&per_lane)
                {
                    prop_assert_eq!(stats.kind, EngineKind::Batched);
                    let (solo, _) =
                        run_scenario_compiled(ct, &net, prof, rounds, &sc).unwrap();
                    assert_bitwise(summary, &solo, &format!("lane {} vs solo", prof.name));
                }
            }
        }
    }
}
