//! Integration: the persistent cell store's crash-safety contract.
//!
//! The store's append-only logs must degrade *monotonically*: chopping
//! a shard file at any byte (a crash mid-append) loses at most the torn
//! record, a flipped byte poisons only the records at and after it, a
//! bumped engine epoch hides every stale-generation record, and two
//! processes appending concurrently never corrupt each other. Each
//! property is exercised here against real files, byte by byte.

use std::path::{Path, PathBuf};
use std::process::Command;

use mgfl::store::{gc, gc_with_epoch, verify, CellStore};

/// Shard-file header length (magic + version + epoch), mirrored from
/// the store's log format so the tests can parse frames themselves.
const HEADER_LEN: usize = 16;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mgfl_store_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `n` distinct keys that all land in the same shard (so one file holds
/// every record and truncation offsets are easy to reason about).
fn keys_in_one_shard(n: usize) -> Vec<String> {
    let shard_of = |key: &str| mgfl::util::rng::fnv1a(key.as_bytes()) & 0xF;
    let target = shard_of("k0");
    let mut keys = vec!["k0".to_string()];
    let mut i = 1u64;
    while keys.len() < n {
        let key = format!("k{i}");
        if shard_of(&key) == target {
            keys.push(key);
        }
        i += 1;
    }
    keys
}

/// The one shard file in `dir` that holds records (len > header).
fn populated_shard(dir: &Path) -> PathBuf {
    let mut hits: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| std::fs::metadata(p).unwrap().len() > HEADER_LEN as u64)
        .collect();
    assert_eq!(hits.len(), 1, "all test keys must share one shard");
    hits.pop().unwrap()
}

/// Offsets of each record's *end* within a shard file's bytes
/// (frame = u32 payload len | payload | u64 checksum).
fn record_ends(bytes: &[u8]) -> Vec<usize> {
    let mut ends = Vec::new();
    let mut pos = HEADER_LEN;
    while pos + 4 <= bytes.len() {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let end = pos + 4 + len + 8;
        if end > bytes.len() {
            break;
        }
        ends.push(end);
        pos = end;
    }
    assert_eq!(pos, bytes.len(), "fixture file must end on a record boundary");
    ends
}

#[test]
fn truncating_a_shard_at_any_byte_loses_at_most_the_torn_record() {
    let dir = tmp("chop_src");
    let keys = keys_in_one_shard(3);
    {
        let store = CellStore::open(&dir).unwrap();
        for (i, key) in keys.iter().enumerate() {
            store.put(key, format!("value-{i}").as_bytes()).unwrap();
        }
    }
    let shard = populated_shard(&dir);
    let bytes = std::fs::read(&shard).unwrap();
    let file_name = shard.file_name().unwrap().to_owned();
    let ends = record_ends(&bytes);
    assert_eq!(ends.len(), keys.len());

    let work = tmp("chop_work");
    for cut in HEADER_LEN..bytes.len() {
        let _ = std::fs::remove_dir_all(&work);
        std::fs::create_dir_all(&work).unwrap();
        std::fs::write(work.join(&file_name), &bytes[..cut]).unwrap();
        // Records whose frame ends at or before the cut survive; the
        // torn one (and everything the crash never wrote) is dropped.
        let intact = ends.iter().filter(|&&e| e <= cut).count();
        let store = CellStore::open(&work)
            .unwrap_or_else(|e| panic!("open must recover a torn tail (cut={cut}): {e:#}"));
        for (i, key) in keys.iter().enumerate() {
            let got = store.get(key).unwrap();
            if i < intact {
                assert_eq!(got.as_deref(), Some(format!("value-{i}").as_bytes()), "cut={cut}");
            } else {
                assert_eq!(got, None, "cut={cut}: torn record must not resurface");
            }
        }
        // Recovery truncated to the last clean boundary, so appends
        // land on it and survive another reopen.
        store.put("fresh", b"post-recovery").unwrap();
        drop(store);
        let reopened = CellStore::open(&work).unwrap();
        assert_eq!(
            reopened.get("fresh").unwrap().as_deref(),
            Some(b"post-recovery".as_slice()),
            "cut={cut}"
        );
        assert!(verify(&work).unwrap().ok(), "cut={cut}: recovered store must verify clean");
    }
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn a_flipped_byte_is_detected_and_quarantines_only_later_records() {
    let dir = tmp("flip");
    let keys = keys_in_one_shard(3);
    {
        let store = CellStore::open(&dir).unwrap();
        for (i, key) in keys.iter().enumerate() {
            store.put(key, format!("value-{i}").as_bytes()).unwrap();
        }
    }
    let shard = populated_shard(&dir);
    let mut bytes = std::fs::read(&shard).unwrap();
    let ends = record_ends(&bytes);
    // Flip one payload byte inside the *second* record.
    bytes[ends[0] + 6] ^= 0x40;
    std::fs::write(&shard, &bytes).unwrap();

    let audit = verify(&dir).unwrap();
    assert!(!audit.ok(), "a checksum mismatch must fail verification");
    assert_eq!(audit.corrupt.len(), 1);
    assert_eq!(audit.torn_tails, 0);

    // Opening recovers: the record before the corruption survives, the
    // corrupt one and everything after it are dropped, and the file is
    // truncated back to a clean state.
    let store = CellStore::open(&dir).unwrap();
    assert_eq!(store.get(&keys[0]).unwrap().as_deref(), Some(b"value-0".as_slice()));
    assert_eq!(store.get(&keys[1]).unwrap(), None);
    assert_eq!(store.get(&keys[2]).unwrap(), None);
    drop(store);
    assert!(verify(&dir).unwrap().ok(), "recovery must leave a clean store behind");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bumping_the_engine_epoch_invalidates_every_stale_record() {
    let dir = tmp("epoch");
    {
        let old = CellStore::open_with_epoch(&dir, 1).unwrap();
        old.put("shared-key", b"epoch-1").unwrap();
    }
    let new = CellStore::open_with_epoch(&dir, 2).unwrap();
    assert_eq!(new.get("shared-key").unwrap(), None, "stale generations must be invisible");
    new.put("shared-key", b"epoch-2").unwrap();
    assert_eq!(new.get("shared-key").unwrap().as_deref(), Some(b"epoch-2".as_slice()));
    drop(new);

    // gc under the new epoch deletes the stale generation's files.
    let report = gc_with_epoch(&dir, 2).unwrap();
    assert!(report.removed_files > 0, "stale epoch-1 files must be deleted");
    let survivor = CellStore::open_with_epoch(&dir, 2).unwrap();
    assert_eq!(survivor.get("shared-key").unwrap().as_deref(), Some(b"epoch-2".as_slice()));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_compacts_superseded_records_without_losing_the_latest() {
    let dir = tmp("gc");
    {
        let store = CellStore::open(&dir).unwrap();
        for i in 0..50u32 {
            store.put("hot-key", format!("rev-{i}").as_bytes()).unwrap();
        }
        store.put("other", b"kept").unwrap();
        let stats = store.stats().unwrap();
        assert_eq!(stats.entries, 2);
        assert_eq!(stats.records, 51, "superseded records pile up until gc");
    }
    let report = gc(&dir).unwrap();
    assert_eq!(report.records_before, 51);
    assert_eq!(report.records_after, 2, "compaction keeps exactly the live entries");
    assert!(report.bytes_after < report.bytes_before);

    let store = CellStore::open(&dir).unwrap();
    assert_eq!(store.get("hot-key").unwrap().as_deref(), Some(b"rev-49".as_slice()));
    assert_eq!(store.get("other").unwrap().as_deref(), Some(b"kept".as_slice()));
    assert!(verify(&dir).unwrap().ok());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression for the adaptive key-space extension: gc must treat the
/// `/sc` (scenario) and `/ad` (adaptive) suffixed cell keys exactly
/// like classic static keys — compacting superseded revisions, keeping
/// the latest record of *each* namespace, and never collapsing a cell
/// onto its static or policy-none twin.
#[test]
fn gc_compacts_across_the_extended_cell_key_namespaces() {
    use mgfl::config::TopologyKind;
    use mgfl::simtime::{
        AdaptMetrics, EngineKind, EngineStats, ScenarioMetrics, SegmentMetrics, SimSummary,
    };
    use mgfl::sweep::CellFingerprint;

    let dir = tmp("gc_namespaces");
    let fp = |scenario: Option<u64>, adapt: Option<u64>| CellFingerprint {
        topology: TopologyKind::Multigraph,
        network: "gaia".into(),
        profile: "femnist".into(),
        t: 5,
        rounds: 60,
        seed: None,
        scenario,
        adapt,
    };
    let summary = |mean: f64, adapt: Option<AdaptMetrics>, scenario: bool| SimSummary {
        topology: "multigraph".into(),
        network: "gaia".into(),
        profile: "femnist".into(),
        rounds: 60,
        mean_cycle_ms: mean,
        total_ms: mean * 60.0,
        rounds_with_isolated: 1,
        max_isolated: 2,
        scenario: scenario.then(|| ScenarioMetrics {
            segments: vec![SegmentMetrics {
                start: 0,
                len: 60,
                up_silos: 11,
                p50_ms: mean,
                p95_ms: mean * 1.2,
                max_ms: mean * 1.5,
            }],
            p50_ms: mean,
            p95_ms: mean * 1.2,
            max_ms: mean * 1.5,
            isolation_rate: 0.01,
            recovery_rounds: 3,
            adapt,
        }),
    };
    let stats = EngineStats {
        kind: EngineKind::Streaming,
        period: None,
        cycle_detected_at: None,
        cycle_len: None,
        simulated_rounds: 60,
        groups: None,
    };
    let warm = AdaptMetrics {
        policy: "warm".to_string(),
        replans: 3,
        fallbacks: 1,
        evals_spent: 96,
        freeze_rounds: 12,
    };

    let statics = fp(None, None);
    let churned = fp(Some(0x1234), None);
    let adaptive = fp(Some(0x1234), Some(0xfeed));
    {
        let store = CellStore::open(&dir).unwrap();
        // Supersede each namespace several times; only the last
        // revision of each may survive compaction.
        for rev in 0..8 {
            let mean = 10.0 + rev as f64;
            store.put_cell(&statics, &summary(mean, None, false), &stats).unwrap();
            store.put_cell(&churned, &summary(mean, None, true), &stats).unwrap();
            store.put_cell(&adaptive, &summary(mean, Some(warm.clone()), true), &stats).unwrap();
        }
        store.put_fitness("fit/gaia/femnist/r60/x", 1.5).unwrap();
        let s = store.stats().unwrap();
        assert_eq!(s.entries, 4);
        assert_eq!(s.records, 25, "superseded records pile up until gc");
    }

    let report = gc(&dir).unwrap();
    assert_eq!(report.records_before, 25);
    assert_eq!(report.records_after, 4, "compaction keeps one live record per namespace");

    let store = CellStore::open(&dir).unwrap();
    for (fp, adapt, scenario) in
        [(&statics, None, false), (&churned, None, true), (&adaptive, Some(warm), true)]
    {
        let got = store.get_cell(fp).unwrap().expect("latest revision must survive gc");
        assert_eq!(got.mean_cycle_ms.to_bits(), 17.0f64.to_bits());
        assert_eq!(got.scenario, summary(17.0, adapt, scenario).scenario);
    }
    // The namespace breakdown survives compaction: one live cell per
    // key space plus the fitness entry.
    let s = store.stats().unwrap();
    assert_eq!(s.static_cells, 1);
    assert_eq!(s.scenario_cells, 1);
    assert_eq!(s.adaptive_cells, 1);
    assert_eq!(s.other_entries, 1);
    drop(store);
    assert!(verify(&dir).unwrap().ok());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Helper "test" driven by the two-process test below: when
/// `MGFL_STORE_CHILD` points at a store directory, this process is the
/// child appender; in a normal test run the env var is absent and this
/// is a no-op.
#[test]
fn child_appender() {
    let Ok(dir) = std::env::var("MGFL_STORE_CHILD") else {
        return;
    };
    let store = CellStore::open(&dir).unwrap();
    for i in 0..200u32 {
        store.put(&format!("child/{i}"), format!("cv-{i}").as_bytes()).unwrap();
    }
}

#[test]
fn two_processes_append_concurrently_without_corruption() {
    let dir = tmp("twoproc");
    std::fs::create_dir_all(&dir).unwrap();
    let exe = std::env::current_exe().unwrap();
    let mut child = Command::new(exe)
        .args(["child_appender", "--exact", "--nocapture"])
        .env("MGFL_STORE_CHILD", dir.to_str().unwrap())
        .spawn()
        .expect("spawning the child appender");

    let store = CellStore::open(&dir).unwrap();
    for i in 0..200u32 {
        store.put(&format!("parent/{i}"), format!("pv-{i}").as_bytes()).unwrap();
    }
    let status = child.wait().unwrap();
    assert!(status.success(), "child appender must exit cleanly");
    drop(store);

    let reopened = CellStore::open(&dir).unwrap();
    for i in 0..200u32 {
        assert_eq!(
            reopened.get(&format!("parent/{i}")).unwrap().as_deref(),
            Some(format!("pv-{i}").as_bytes()),
            "parent record {i} must survive the concurrent child"
        );
        assert_eq!(
            reopened.get(&format!("child/{i}")).unwrap().as_deref(),
            Some(format!("cv-{i}").as_bytes()),
            "child record {i} must survive the concurrent parent"
        );
    }
    assert!(verify(&dir).unwrap().ok(), "interleaved appends must leave a clean store");
    let _ = std::fs::remove_dir_all(&dir);
}
