//! Property tests for the sweep spec layer, backed by the real proptest
//! crate. Gated behind `--features proptest` so the offline build (which
//! vendors no proptest) still passes `cargo test`; CI runs them with the
//! feature on. The in-tree xoshiro-driven property tests in
//! `tests/proptests.rs` cover the coordinator invariants regardless.
#![cfg(feature = "proptest")]

use mgfl::config::TopologyKind;
use mgfl::sweep::SweepSpec;
use proptest::prelude::*;

fn kind_from(i: usize) -> TopologyKind {
    TopologyKind::all()[i % 7]
}

proptest! {
    #[test]
    fn sweep_spec_toml_roundtrip(
        kinds in prop::collection::vec(0usize..7, 1..5),
        raw_nets in prop::collection::vec("[a-z]{1,8}", 1..4),
        raw_profs in prop::collection::vec("[a-z]{1,8}", 1..3),
        ts in prop::collection::vec(1u32..64, 1..4),
        seeds in prop::collection::vec(0u64..(1 << 53), 1..4),
        rounds in 1usize..100_000,
    ) {
        let topologies: Vec<TopologyKind> = kinds.iter().map(|&i| kind_from(i)).collect();
        // Prefix with 'x' so no axis value collides with the "all" sugar.
        let nets: Vec<String> = raw_nets.iter().map(|s| format!("x{s}")).collect();
        let profs: Vec<String> = raw_profs.iter().map(|s| format!("x{s}")).collect();
        let spec = SweepSpec {
            name: "prop".into(),
            topologies: topologies.clone(),
            networks: nets.clone(),
            profiles: profs.clone(),
            t_values: ts.clone(),
            seeds: seeds.clone(),
            rounds,
            scenario: None,
            adapt: Vec::new(),
        };
        let back = SweepSpec::from_toml_str(&spec.to_toml_string()).unwrap();
        prop_assert_eq!(back.topologies, topologies);
        prop_assert_eq!(back.networks, nets);
        prop_assert_eq!(back.profiles, profs);
        prop_assert_eq!(back.t_values, ts);
        prop_assert_eq!(back.seeds, seeds);
        prop_assert_eq!(back.rounds, rounds);
    }

    #[test]
    fn expansion_is_complete_and_seed_stable(
        ts in prop::collection::vec(1u32..16, 1..3),
        seeds in prop::collection::vec(0u64..(1 << 53), 1..3),
    ) {
        let spec = SweepSpec { t_values: ts, seeds, rounds: 10, ..Default::default() };
        let cells = spec.expand();
        prop_assert_eq!(cells.len(), spec.cell_count());
        let again = spec.expand();
        for (a, b) in cells.iter().zip(&again) {
            prop_assert_eq!(a.cell_seed, b.cell_seed);
            prop_assert_eq!(a.index, b.index);
        }
    }
}
