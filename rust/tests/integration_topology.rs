//! Integration: topology designs x networks x delay simulator — the
//! paper's qualitative claims as executable assertions, across all five
//! evaluation networks and all three dataset profiles.

use mgfl::config::{ExperimentConfig, TopologyKind};
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::simulate;
use mgfl::topo::{ring::RingTopology, MultigraphTopology, TopologyDesign};

const ROUNDS: usize = 640;

/// Table 1's headline: the multigraph beats RING on every network and
/// every dataset profile.
#[test]
fn ours_beats_ring_everywhere() {
    for prof in DatasetProfile::all() {
        for net in zoo::all_networks() {
            let mut ring = RingTopology::new(&net, &prof);
            let mut ours = MultigraphTopology::from_network(&net, &prof, 5);
            let r = simulate(&mut ring, &net, &prof, ROUNDS);
            let o = simulate(&mut ours, &net, &prof, ROUNDS);
            assert!(
                o.mean_cycle_ms <= r.mean_cycle_ms + 1e-9,
                "{}/{}: ours {:.1} vs ring {:.1}",
                net.name,
                prof.name,
                o.mean_cycle_ms,
                r.mean_cycle_ms
            );
        }
    }
}

/// STAR is the slowest design on every network (server congestion).
#[test]
fn star_is_slowest_on_femnist() {
    let prof = DatasetProfile::femnist();
    for net in zoo::all_networks() {
        let cfgs: Vec<(TopologyKind, f64)> = TopologyKind::all()
            .into_iter()
            .map(|kind| {
                let cfg = ExperimentConfig {
                    network: net.name.clone(),
                    topology: kind,
                    sim_rounds: ROUNDS,
                    ..Default::default()
                };
                let mut topo = cfg.build_topology();
                (kind, simulate(topo.as_mut(), &net, &prof, ROUNDS).mean_cycle_ms)
            })
            .collect();
        let star = cfgs.iter().find(|(k, _)| *k == TopologyKind::Star).unwrap().1;
        for (k, v) in &cfgs {
            assert!(star >= *v - 1e-9, "{}: star {star:.1} < {k:?} {v:.1}", net.name);
        }
    }
}

/// MATCHA(+) waits for every matching, so it can never beat MATCHA.
#[test]
fn matcha_plus_not_faster_than_matcha() {
    let prof = DatasetProfile::femnist();
    for net in zoo::all_networks() {
        let mut m = mgfl::topo::matcha::MatchaTopology::new(&net, &prof, 0.5, 17);
        let mut mp = mgfl::topo::matcha::MatchaTopology::plus(&net, &prof, 17);
        let rm = simulate(&mut m, &net, &prof, ROUNDS);
        let rmp = simulate(&mut mp, &net, &prof, ROUNDS);
        assert!(
            rmp.mean_cycle_ms >= rm.mean_cycle_ms - 1e-9,
            "{}: matcha+ {:.1} < matcha {:.1}",
            net.name,
            rmp.mean_cycle_ms,
            rm.mean_cycle_ms
        );
    }
}

/// Table 6's monotonicity: cycle time is non-increasing in t (more weak
/// edges -> more isolation -> shorter rounds), and t=1 equals RING.
#[test]
fn cycle_time_monotone_in_t_and_t1_is_ring() {
    let prof = DatasetProfile::femnist();
    let net = zoo::exodus();
    let mut ring = RingTopology::new(&net, &prof);
    let ring_ms = simulate(&mut ring, &net, &prof, ROUNDS).mean_cycle_ms;

    let mut last = f64::MAX;
    for t in [1u32, 3, 5, 8, 10] {
        let mut ours = MultigraphTopology::from_network(&net, &prof, t);
        let ms = simulate(&mut ours, &net, &prof, ROUNDS).mean_cycle_ms;
        assert!(ms <= last * 1.05, "t={t}: {ms:.1} not <= {last:.1}");
        last = ms;
        if t == 1 {
            assert!((ms - ring_ms).abs() < 1e-6, "t=1 {ms:.3} != ring {ring_ms:.3}");
        }
    }
}

/// Table 3's correlation: networks where more states isolate see larger
/// cycle-time reductions vs RING.
#[test]
fn isolation_rate_correlates_with_speedup() {
    let prof = DatasetProfile::femnist();
    let mut rows = Vec::new();
    for net in zoo::all_networks() {
        let topo = MultigraphTopology::from_network(&net, &prof, 5);
        let iso_frac =
            topo.states_with_isolated(10_000).len() as f64 / topo.s_max().min(10_000) as f64;
        let mut ours = MultigraphTopology::from_network(&net, &prof, 5);
        let mut ring = RingTopology::new(&net, &prof);
        let o = simulate(&mut ours, &net, &prof, ROUNDS).mean_cycle_ms;
        let r = simulate(&mut ring, &net, &prof, ROUNDS).mean_cycle_ms;
        rows.push((net.name.clone(), iso_frac, r / o));
    }
    // Spearman-ish sanity: the max-isolation network must speed up more
    // than the min-isolation network.
    let max_iso = rows.iter().cloned().max_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
    let min_iso = rows.iter().cloned().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
    assert!(
        max_iso.2 >= min_iso.2 * 0.8,
        "isolation/speedup inversion: {rows:?}"
    );
    // And every network must actually have isolating states at t=5.
    for (name, iso, _) in &rows {
        assert!(*iso > 0.0, "{name} produced no isolated states");
    }
}

/// The simulator agrees with the topology's own period: repeating the
/// schedule produces a periodic cycle-time sequence after warmup.
#[test]
fn multigraph_cycle_times_are_periodic_after_warmup() {
    let prof = DatasetProfile::femnist();
    let net = zoo::gaia();
    let mut ours = MultigraphTopology::from_network(&net, &prof, 5);
    let period = ours.s_max() as usize;
    let res = simulate(&mut ours, &net, &prof, period * 4);
    let a = &res.per_round_ms[period * 2..period * 3];
    let b = &res.per_round_ms[period * 3..period * 4];
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < 1e-9, "offset {i}: {x} vs {y}");
    }
}

/// Every design yields a connected overlay spanning all silos (isolated
/// *rounds* are fine; a disconnected *overlay* would break consensus).
#[test]
fn all_overlays_connected_on_all_networks() {
    let prof = DatasetProfile::femnist();
    for net in zoo::all_networks() {
        for kind in TopologyKind::all() {
            let cfg = ExperimentConfig {
                network: net.name.clone(),
                topology: kind,
                ..Default::default()
            };
            let topo = cfg.build_topology();
            assert!(
                topo.overlay().is_connected(),
                "{} overlay disconnected on {}",
                kind.as_str(),
                net.name
            );
            assert_eq!(topo.overlay().n(), net.n());
        }
    }
}

/// Cross-profile consistency: heavier models (iNaturalist) produce
/// longer cycle times than lighter ones (FEMNIST) for every topology.
#[test]
fn heavier_profiles_cost_more() {
    let net = zoo::gaia();
    for kind in TopologyKind::all() {
        let run = |prof: &DatasetProfile| {
            let cfg = ExperimentConfig {
                network: "gaia".into(),
                topology: kind,
                ..Default::default()
            };
            let mut topo = cfg.build_topology();
            simulate(topo.as_mut(), &net, prof, 120).mean_cycle_ms
        };
        let f = run(&DatasetProfile::femnist());
        let i = run(&DatasetProfile::inaturalist());
        assert!(i > f, "{}: inaturalist {i:.1} <= femnist {f:.1}", kind.as_str());
    }
}
