//! Integration: AOT artifacts -> PJRT runtime -> numerics.
//!
//! These tests exercise the exact artifact path the coordinator uses:
//! load HLO text, compile on the CPU PJRT client, execute train / eval /
//! init / aggregate, and check the numbers behave like the L2 model
//! (loss ~ log C at init, decreases under SGD, aggregation is convex).
//!
//! Requires `make artifacts`; tests are skipped (with a loud message)
//! when artifacts are missing so `cargo test` stays runnable pre-build.

use mgfl::data::{Batch, SyntheticTask};
use mgfl::fl::Partition;
use mgfl::runtime::{aggregate_native, artifacts_available, Manifest, ModelRuntime};
use mgfl::util::Rng64;

fn artifacts_dir() -> std::path::PathBuf {
    // cargo test runs from the workspace root.
    mgfl::runtime::default_artifacts_dir()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn mlp() -> ModelRuntime {
    ModelRuntime::load(artifacts_dir(), "femnist_mlp").expect("load femnist_mlp artifacts")
}

fn train_batch(rt: &ModelRuntime, seed: u64) -> Batch {
    let task = SyntheticTask::image(rt.entry.input_len(), rt.entry.num_classes, 7);
    let part = Partition::iid(1, rt.entry.num_classes);
    task.batch(&part, 0, rt.entry.train_batch, &mut Rng64::seed_from_u64(seed))
}

fn eval_batch(rt: &ModelRuntime, seed: u64) -> Batch {
    let task = SyntheticTask::image(rt.entry.input_len(), rt.entry.num_classes, 7);
    task.eval_batch(rt.entry.eval_batch, &mut Rng64::seed_from_u64(seed))
}

#[test]
fn manifest_loads_and_lists_models() {
    require_artifacts!();
    let m = Manifest::load(artifacts_dir()).unwrap();
    assert!(m.models.contains_key("femnist_mlp"), "{:?}", m.models.keys());
    assert!(m.models.contains_key("femnist_cnn"));
    let e = &m.models["femnist_mlp"];
    assert_eq!(e.input_shape, vec![28, 28, 1]);
    assert_eq!(e.num_classes, 62);
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    require_artifacts!();
    let rt = mlp();
    let a = rt.init_params(3).unwrap();
    let b = rt.init_params(3).unwrap();
    let c = rt.init_params(4).unwrap();
    assert_eq!(a.len(), rt.param_count());
    assert_eq!(a, b);
    assert_ne!(a, c);
    // He init: finite, zero-mean-ish, nonzero spread.
    assert!(a.iter().all(|x| x.is_finite()));
    let mean: f32 = a.iter().sum::<f32>() / a.len() as f32;
    assert!(mean.abs() < 0.01, "{mean}");
}

#[test]
fn initial_loss_near_log_c_and_training_reduces_it() {
    require_artifacts!();
    let rt = mlp();
    let mut params = rt.init_params(0).unwrap();
    let batch = train_batch(&rt, 1);
    let (_, loss0) = rt.train_step(&params, &batch, 0.0).unwrap();
    // softmax over 62 classes at init: loss ~ ln(62) = 4.127
    assert!((loss0 - 62f32.ln()).abs() < 1.0, "init loss {loss0}");

    let mut last = loss0;
    for step in 0..20 {
        let (p, l) = rt.train_step(&params, &batch, 0.1).unwrap();
        params = p;
        last = l;
        assert!(l.is_finite(), "step {step} loss {l}");
    }
    assert!(last < 0.6 * loss0, "loss did not decrease: {loss0} -> {last}");
}

#[test]
fn zero_lr_step_is_identity_on_params() {
    require_artifacts!();
    let rt = mlp();
    let params = rt.init_params(5).unwrap();
    let batch = train_batch(&rt, 2);
    let (p2, _) = rt.train_step(&params, &batch, 0.0).unwrap();
    assert_eq!(params, p2, "lr=0 must not move parameters");
}

#[test]
fn eval_counts_are_sane_and_improve() {
    require_artifacts!();
    let rt = mlp();
    let mut params = rt.init_params(1).unwrap();
    let eb = eval_batch(&rt, 3);
    let (loss_init, correct_init) = rt.eval_step(&params, &eb).unwrap();
    assert!(correct_init >= 0.0 && correct_init <= rt.entry.eval_batch as f32);
    assert!(loss_init.is_finite());

    // Train on the same distribution; eval loss must drop.
    let tb = train_batch(&rt, 4);
    for _ in 0..30 {
        params = rt.train_step(&params, &tb, 0.1).unwrap().0;
    }
    let (loss_after, _) = rt.eval_step(&params, &eb).unwrap();
    assert!(loss_after < loss_init, "{loss_init} -> {loss_after}");
}

#[test]
fn aggregate_matches_native_and_handles_padding() {
    require_artifacts!();
    let rt = mlp();
    let a = rt.init_params(10).unwrap();
    let b = rt.init_params(11).unwrap();
    let c = rt.init_params(12).unwrap();
    let weights = [0.5f32, 0.3, 0.2];
    let models = [a.as_slice(), b.as_slice(), c.as_slice()];
    let kernel = rt.aggregate(&weights, &models).unwrap();
    let native = aggregate_native(&weights, &models);
    assert_eq!(kernel.len(), native.len());
    let max_err = kernel
        .iter()
        .zip(&native)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-5, "kernel vs native max err {max_err}");
}

#[test]
fn aggregate_identity_on_single_model() {
    require_artifacts!();
    let rt = mlp();
    let a = rt.init_params(20).unwrap();
    let out = rt.aggregate(&[1.0], &[a.as_slice()]).unwrap();
    let max_err = out.iter().zip(&a).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(max_err < 1e-6, "{max_err}");
}

#[test]
fn aggregate_rejects_overflow_and_mismatch() {
    require_artifacts!();
    let rt = mlp();
    let a = rt.init_params(0).unwrap();
    let too_many: Vec<&[f32]> = (0..rt.entry.k_max + 1).map(|_| a.as_slice()).collect();
    let w = vec![0.1f32; rt.entry.k_max + 1];
    assert!(rt.aggregate(&w, &too_many).is_err());
    assert!(rt.aggregate(&[0.5, 0.5], &[a.as_slice()]).is_err());
    let short = vec![0.0f32; 3];
    assert!(rt.aggregate(&[1.0], &[short.as_slice()]).is_err());
}

#[test]
fn train_step_rejects_wrong_batch_shape() {
    require_artifacts!();
    let rt = mlp();
    let params = rt.init_params(0).unwrap();
    let bad = Batch { x_f32: vec![0.0; 10], x_i32: vec![], y: vec![0; 2] };
    assert!(rt.train_step(&params, &bad, 0.1).is_err());
}

#[test]
fn lstm_token_model_runs() {
    require_artifacts!();
    let rt = ModelRuntime::load(artifacts_dir(), "sentiment_lstm").expect("load lstm");
    let task = SyntheticTask::tokens(rt.entry.input_len(), rt.entry.num_classes, 7);
    let part = Partition::iid(1, rt.entry.num_classes);
    let mut rng = Rng64::seed_from_u64(0);
    let batch = task.batch(&part, 0, rt.entry.train_batch, &mut rng);
    let mut params = rt.init_params(0).unwrap();
    let (_, loss0) = rt.train_step(&params, &batch, 0.0).unwrap();
    assert!((loss0 - 2f32.ln()).abs() < 0.5, "binary init loss {loss0}");
    for _ in 0..15 {
        params = rt.train_step(&params, &batch, 0.2).unwrap().0;
    }
    let (_, loss1) = rt.train_step(&params, &batch, 0.0).unwrap();
    assert!(loss1 < loss0, "{loss0} -> {loss1}");
}

#[test]
fn measured_t_c_is_positive() {
    require_artifacts!();
    let rt = mlp();
    let batch = train_batch(&rt, 9);
    let t_c = rt.measure_t_c_ms(&batch, 3).unwrap();
    assert!(t_c > 0.0 && t_c < 60_000.0, "{t_c}");
}
