//! Integration: sweep determinism. The engine's contract is that a
//! report is a pure function of its spec — the same `SweepSpec` produces
//! byte-identical JSON/CSV artifacts on 1 thread and N threads, across
//! repeated runs, and (because per-cell seeds derive from cell
//! coordinates, not execution order) even for stochastic topologies
//! like MATCHA whose schedules consume randomness. Since PR 3 the same
//! contract covers the memoization layer: deduplicated sweeps (the
//! default) must be byte-identical to the pre-cache engine
//! (`dedup: false`), and stochastic cells with distinct seeds must
//! never be merged.

use mgfl::config::TopologyKind;
use mgfl::simtime::simulate_summary_naive;
use mgfl::sweep::{self, Axis, RunOptions, SweepSpec};

/// A small but adversarial grid: two networks of very different sizes
/// (so cell runtimes differ and threads finish out of order), stochastic
/// MATCHA variants alongside static designs, two t values, two seeds.
fn spec() -> SweepSpec {
    SweepSpec {
        name: "determinism".into(),
        topologies: vec![
            TopologyKind::Star,
            TopologyKind::Matcha,
            TopologyKind::MatchaPlus,
            TopologyKind::Ring,
            TopologyKind::Multigraph,
        ],
        networks: vec!["gaia".into(), "amazon".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![3, 5],
        seeds: vec![11, 23],
        rounds: 80,
        scenario: None,
        adapt: Vec::new(),
    }
}

#[test]
fn one_thread_and_n_threads_produce_identical_artifacts() {
    let spec = spec();
    let serial = sweep::run(&spec, &RunOptions { threads: 1, ..Default::default() }).unwrap();
    let parallel = sweep::run(&spec, &RunOptions { threads: 4, ..Default::default() }).unwrap();
    assert_eq!(serial.threads, 1);
    assert_eq!(parallel.threads, 4);

    let json_a = serial.report.to_json().to_string();
    let json_b = parallel.report.to_json().to_string();
    assert_eq!(json_a, json_b, "JSON artifact must be byte-identical across thread counts");
    assert_eq!(
        serial.report.to_csv(),
        parallel.report.to_csv(),
        "CSV artifact must be byte-identical across thread counts"
    );
    // And across repeated parallel runs (schedule-independence).
    let again = sweep::run(&spec, &RunOptions { threads: 4, ..Default::default() }).unwrap();
    assert_eq!(json_b, again.report.to_json().to_string());
}

#[test]
fn artifacts_written_to_disk_are_identical_too() {
    let spec = spec();
    let dir = std::env::temp_dir().join(format!("mgfl_sweep_det_{}", std::process::id()));
    let a_dir = dir.join("serial");
    let b_dir = dir.join("parallel");
    let a = sweep::run(&spec, &RunOptions { threads: 1, ..Default::default() }).unwrap();
    let b = sweep::run(&spec, &RunOptions { threads: 4, ..Default::default() }).unwrap();
    let (a_json, a_csv) = a.report.write_artifacts(&a_dir).unwrap();
    let (b_json, b_csv) = b.report.write_artifacts(&b_dir).unwrap();
    assert_eq!(
        std::fs::read(&a_json).unwrap(),
        std::fs::read(&b_json).unwrap(),
        "on-disk JSON differs"
    );
    assert_eq!(
        std::fs::read(&a_csv).unwrap(),
        std::fs::read(&b_csv).unwrap(),
        "on-disk CSV differs"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn report_is_grid_ordered_and_complete() {
    let spec = spec();
    let outcome = sweep::run(&spec, &RunOptions { threads: 4, ..Default::default() }).unwrap();
    let report = &outcome.report;
    assert_eq!(report.cells.len(), spec.cell_count());
    // Output order is exactly expansion order, whatever the scheduling.
    for (cell, expect) in report.cells.iter().zip(spec.expand()) {
        assert_eq!(cell.topology, expect.topology.as_str());
        assert_eq!(cell.network, expect.network);
        assert_eq!(cell.profile, expect.profile);
        assert_eq!(cell.t, expect.t);
        assert_eq!(cell.seed, expect.base_seed);
        assert_eq!(cell.cell_seed, expect.cell_seed, "derived stream is exported verbatim");
        assert_eq!(cell.rounds, spec.rounds);
    }
    // Every topology axis value made it into the report.
    assert_eq!(
        report.axis_values(Axis::Topology),
        vec!["star", "matcha", "matcha_plus", "ring", "multigraph"]
    );
}

#[test]
fn compiled_engine_cells_match_the_naive_oracle_bitwise() {
    // Since PR 2 every sweep cell runs on the compiled simulation
    // engine; at 400 rounds the multigraph cells (period = s_max) go
    // through the cycle-detection fast path (state recurrence within
    // two periods, then τ-sequence replay). The sweep artifact must
    // nevertheless be bit-identical to simulating each cell by hand on
    // the naive DelayTracker reference path — the invariant that lets
    // the fast path exist at all.
    let mut spec = spec();
    spec.rounds = 400;
    let outcome = sweep::run(&spec, &RunOptions { threads: 4, ..Default::default() }).unwrap();
    assert_eq!(outcome.report.cells.len(), spec.cell_count());
    for (got, cell) in outcome.report.cells.iter().zip(spec.expand()) {
        let cfg = cell.to_experiment();
        let net = cfg.resolve_network();
        let prof = cfg.resolve_profile().unwrap();
        let mut topo = cfg.build_topology();
        let want = simulate_summary_naive(topo.as_mut(), &net, &prof, cell.rounds);
        let ctx = format!("{}/{}/{} t={}", got.topology, got.network, got.profile, got.t);
        assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits(), "total_ms differs: {ctx}");
        assert_eq!(
            got.mean_cycle_ms.to_bits(),
            want.mean_cycle_ms.to_bits(),
            "mean_cycle_ms differs: {ctx}"
        );
        assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated, "{ctx}");
        assert_eq!(got.max_isolated, want.max_isolated, "{ctx}");
    }
}

#[test]
fn stochastic_cells_vary_with_seed_but_not_with_threads() {
    // MATCHA consumes randomness every round; distinct base seeds must
    // give distinct schedules (else the seed axis is dead weight), while
    // the same seed must be thread-invariant (covered above). Pin the
    // seed-sensitivity half here.
    let mut spec = spec();
    spec.topologies = vec![TopologyKind::Matcha];
    spec.t_values = vec![5];
    spec.networks = vec!["gaia".into()];
    let outcome = sweep::run(&spec, &RunOptions { threads: 2, ..Default::default() }).unwrap();
    let cells = &outcome.report.cells;
    assert_eq!(cells.len(), 2);
    assert_ne!(
        cells[0].mean_cycle_ms.to_bits(),
        cells[1].mean_cycle_ms.to_bits(),
        "different base seeds should produce different MATCHA schedules"
    );
}

/// The acceptance grid: the paper's 7 topologies on Gaia/FEMNIST,
/// replicated across 8 seeds. Only MATCHA (budget < 1) is stochastic,
/// so the dedup layer must simulate 6 + 8 = 14 of the 56 cells.
fn seed_replicated_spec(rounds: usize) -> SweepSpec {
    SweepSpec {
        name: "seedrep".into(),
        topologies: TopologyKind::all().to_vec(),
        networks: vec!["gaia".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![5],
        seeds: (17..25).collect(),
        rounds,
        scenario: None,
        adapt: Vec::new(),
    }
}

#[test]
fn memoized_and_unmemoized_sweeps_are_byte_identical() {
    let spec = seed_replicated_spec(120);
    assert_eq!(spec.cell_count(), 7 * 8);
    let reference =
        sweep::run(&spec, &RunOptions { threads: 1, progress: false, dedup: false }).unwrap();
    assert_eq!(reference.unique_cells, spec.cell_count(), "dedup off simulates every cell");
    let ref_json = reference.report.to_json().to_string();
    let ref_csv = reference.report.to_csv();
    for threads in [1, 4] {
        let memo =
            sweep::run(&spec, &RunOptions { threads, progress: false, dedup: true }).unwrap();
        assert_eq!(memo.unique_cells, 6 + 8, "6 deterministic designs + 8 MATCHA seeds");
        assert_eq!(memo.report.cells.len(), spec.cell_count());
        assert_eq!(
            memo.report.to_json().to_string(),
            ref_json,
            "memoized JSON artifact differs from the pre-cache engine (threads={threads})"
        );
        assert_eq!(
            memo.report.to_csv(),
            ref_csv,
            "memoized CSV artifact differs from the pre-cache engine (threads={threads})"
        );
    }
    // The unmemoized engine is itself thread-invariant (the original
    // determinism contract, re-pinned under the new scheduler).
    let opts4 = RunOptions { threads: 4, progress: false, dedup: false };
    let ref4 = sweep::run(&spec, &opts4).unwrap();
    assert_eq!(ref4.report.to_json().to_string(), ref_json);
}

#[test]
fn stochastic_matcha_cells_with_distinct_seeds_are_never_merged() {
    let mut spec = seed_replicated_spec(60);
    spec.topologies = vec![TopologyKind::Matcha];
    spec.seeds = vec![11, 23, 31];
    let outcome = sweep::run(&spec, &RunOptions { threads: 2, ..Default::default() }).unwrap();
    assert_eq!(outcome.unique_cells, 3, "every stochastic seed is its own work item");
    let bits: Vec<u64> = outcome.report.cells.iter().map(|c| c.mean_cycle_ms.to_bits()).collect();
    assert_eq!(bits.len(), 3);
    assert!(bits[0] != bits[1] && bits[1] != bits[2] && bits[0] != bits[2]);

    // Fingerprint level: MATCHA cells differing only in seed have
    // distinct fingerprints; a deterministic design's collapse.
    let cells = spec.expand();
    assert_ne!(cells[0].fingerprint(), cells[1].fingerprint());
    let mut det = spec.clone();
    det.topologies = vec![TopologyKind::MatchaPlus];
    let det_cells = det.expand();
    assert_eq!(
        det_cells[0].fingerprint(),
        det_cells[1].fingerprint(),
        "MATCHA+ (budget 1.0) consumes no randomness and must merge"
    );
    let plus = sweep::run(&det, &RunOptions { threads: 2, ..Default::default() }).unwrap();
    assert_eq!(plus.unique_cells, 1);
    assert_eq!(plus.report.cells.len(), 3);
}
