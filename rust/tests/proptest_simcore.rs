//! Property tests for the compiled simulation engine, backed by the
//! real proptest crate (gated behind `--features proptest` like
//! `tests/proptest_sweep.rs`; the offline build vendors no proptest).
//!
//! The property is the engine's entire contract: for ANY cell —
//! random network, any of the topology designs (stochastic MATCHA
//! included), t ∈ 1..=10, arbitrary seed and round count — the compiled
//! `simulate_summary` must be **bitwise** equal to the naive
//! `DelayTracker` reference, counters included.

#![cfg(feature = "proptest")]

use mgfl::config::{ExperimentConfig, TopologyKind};
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::{simulate_summary, simulate_summary_naive};
use proptest::prelude::*;

proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    #[test]
    fn compiled_engine_is_bitwise_equal_to_naive(
        net_i in 0usize..64,
        kind_i in 0usize..64,
        prof_i in 0usize..64,
        t in 1u32..=10,
        seed in 0u64..(1 << 48),
        rounds in 1usize..220,
    ) {
        let nets = zoo::all_networks();
        let net_name = nets[net_i % nets.len()].name.clone();
        let profiles = DatasetProfile::all();
        let prof_name = profiles[prof_i % profiles.len()].name.clone();
        let kinds = TopologyKind::all();
        let kind = kinds[kind_i % kinds.len()];

        let cfg = ExperimentConfig {
            network: net_name,
            profile: prof_name,
            topology: kind,
            t,
            sim_rounds: rounds,
            seed,
            train: None,
        };
        cfg.validate().unwrap();
        let net = cfg.resolve_network();
        let prof = cfg.resolve_profile().unwrap();

        // Two independent instances: stochastic designs consume RNG, so
        // each engine needs its own identically-seeded topology.
        let mut naive_topo = cfg.build_topology();
        let mut fast_topo = cfg.build_topology();
        let naive = simulate_summary_naive(naive_topo.as_mut(), &net, &prof, rounds);
        let fast = simulate_summary(fast_topo.as_mut(), &net, &prof, rounds);

        prop_assert_eq!(&naive.topology, &fast.topology);
        prop_assert_eq!(&naive.network, &fast.network);
        prop_assert_eq!(&naive.profile, &fast.profile);
        prop_assert_eq!(naive.rounds, fast.rounds);
        prop_assert_eq!(
            naive.total_ms.to_bits(),
            fast.total_ms.to_bits(),
            "total_ms: naive {} vs compiled {} on {:?}/{}/{} t={} rounds={}",
            naive.total_ms, fast.total_ms, kind, net.name, prof.name, t, rounds
        );
        prop_assert_eq!(naive.mean_cycle_ms.to_bits(), fast.mean_cycle_ms.to_bits());
        prop_assert_eq!(naive.rounds_with_isolated, fast.rounds_with_isolated);
        prop_assert_eq!(naive.max_isolated, fast.max_isolated);
    }
}
