//! Integration gate for the cross-cell SoA batched engine (ISSUE 7
//! acceptance): batch lanes must be **bit-identical** to the naive
//! `DelayTracker` oracle on every zoo network across all dataset
//! profiles, the sweep batch planner's dispatch must be observable per
//! cell in reports, and sweep artifacts must stay byte-identical across
//! thread counts and dedup modes when batching kicks in.

use mgfl::config::TopologyKind;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::{
    run_batched, simulate_summary_naive, BatchLane, BatchSlab, CompiledTopology, EngineKind,
    SimSummary,
};
use mgfl::sweep::{self, RunOptions, SweepSpec};
use mgfl::topo::ring::RingTopology;

fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) {
    assert_eq!(a.rounds, b.rounds, "{ctx}");
    assert_eq!(
        a.total_ms.to_bits(),
        b.total_ms.to_bits(),
        "{ctx}: total_ms {} vs {}",
        a.total_ms,
        b.total_ms
    );
    assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}");
    assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}");
    assert_eq!(a.max_isolated, b.max_isolated, "{ctx}");
}

/// Every zoo network: one ring batch with a lane per dataset profile
/// (the ring schedule is profile-independent, so the lanes genuinely
/// share one schedule at three different delay resolutions), each lane
/// bit-identical to the naive oracle.
#[test]
fn ring_batches_match_naive_on_every_zoo_network() {
    let rounds = 90;
    let profiles = DatasetProfile::all();
    for net in zoo::all_networks() {
        let compiled: Vec<CompiledTopology> = profiles
            .iter()
            .map(|p| {
                let mut topo = RingTopology::new(&net, p);
                CompiledTopology::compile(&mut topo, rounds).expect("ring schedules are periodic")
            })
            .collect();
        let rep = &compiled[0];
        let lanes: Vec<BatchLane<'_>> = compiled
            .iter()
            .zip(&profiles)
            .map(|(ct, p)| {
                assert!(rep.schedule_eq(ct), "ring schedule must be profile-independent");
                BatchLane { ct, net: &net, profile: p }
            })
            .collect();
        let mut slab = BatchSlab::default();
        let res = run_batched(rep, &lanes, rounds, &mut slab);
        for ((got, stats), p) in res.iter().zip(&profiles) {
            let mut naive_topo = RingTopology::new(&net, p);
            let naive = simulate_summary_naive(&mut naive_topo, &net, p, rounds);
            assert_bitwise(got, &naive, &format!("{}/{}", net.name, p.name));
            assert_eq!(stats.kind, EngineKind::Batched);
        }
    }
}

/// A grid mixing batched, solo-periodic, and streaming dispatch: ring
/// t ∈ {3, 5} share one schedule under two distinct cell fingerprints
/// (the only guaranteed-batchable pair), the multigraph's two t values
/// compile to structurally different periodic schedules (solo), and
/// matcha streams. The report's engine column and the sweep summary's
/// `EngineMix` are the observables, and the artifacts must stay
/// byte-identical whatever the thread count or dedup mode — the batch
/// planner labels cells by structure, never by execution strategy.
#[test]
fn sweep_batch_planner_dispatch_is_observable_and_deterministic() {
    let spec = SweepSpec {
        name: "batched".into(),
        topologies: vec![TopologyKind::Ring, TopologyKind::Multigraph, TopologyKind::Matcha],
        networks: vec!["gaia".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![3, 5],
        seeds: vec![17],
        rounds: 60,
        scenario: None,
        adapt: Vec::new(),
    };
    let outcome = sweep::run(&spec, &RunOptions { threads: 2, ..Default::default() }).unwrap();

    let engines_of = |topo: &str| -> Vec<&str> {
        outcome
            .report
            .cells
            .iter()
            .filter(|c| c.topology == topo)
            .map(|c| c.engine)
            .collect()
    };
    assert_eq!(engines_of("ring"), ["batched", "batched"], "ring t=3/t=5 share one schedule");
    assert_eq!(
        engines_of("multigraph"),
        ["periodic", "periodic"],
        "structural singletons stay solo"
    );
    assert_eq!(engines_of("matcha"), ["streaming", "streaming"]);
    assert_eq!(outcome.engines.batched, 2, "{:?}", outcome.engines);
    assert_eq!(outcome.engines.periodic, 2, "{:?}", outcome.engines);
    assert_eq!(outcome.engines.streaming, 2, "{:?}", outcome.engines);
    assert_eq!(outcome.engines.factored, 0, "{:?}", outcome.engines);

    // The engine column survives the JSON artifact, and the artifact is
    // byte-identical across thread counts and dedup modes: the batch
    // planner's labels are a pure function of cell structure.
    let json = outcome.report.to_json().to_string();
    let csv = outcome.report.to_csv();
    assert!(json.contains("\"engine\":\"batched\""), "{json}");
    for (threads, dedup) in [(1, true), (4, true), (1, false), (4, false)] {
        let opts = RunOptions { threads, progress: false, dedup };
        let again = sweep::run(&spec, &opts).unwrap();
        let ctx = format!("threads={threads} dedup={dedup}");
        assert_eq!(again.report.to_json().to_string(), json, "{ctx}");
        assert_eq!(again.report.to_csv(), csv, "{ctx}");
        assert_eq!(again.engines, outcome.engines, "{ctx}");
    }
}

/// A seed-replicated all-ring grid: every cell shares the ring
/// schedule, so batching covers the whole grid in both dedup modes —
/// and the artifacts must not move by a single bit between them.
#[test]
fn seed_replicated_ring_grid_batches_without_perturbing_artifacts() {
    let spec = SweepSpec {
        name: "lanes".into(),
        topologies: vec![TopologyKind::Ring],
        networks: vec!["gaia".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![3, 5],
        seeds: (17..22).collect(),
        rounds: 40,
        scenario: None,
        adapt: Vec::new(),
    };
    let dedup = sweep::run(&spec, &RunOptions { threads: 2, ..Default::default() }).unwrap();
    let no_dedup =
        sweep::run(&spec, &RunOptions { threads: 2, dedup: false, ..Default::default() }).unwrap();
    assert_eq!(
        no_dedup.report.to_json().to_string(),
        dedup.report.to_json().to_string(),
        "dedup fan-out must not change batched artifacts"
    );
    assert_eq!(no_dedup.report.to_csv(), dedup.report.to_csv());
    // EngineMix counts simulated (unique) cells: the seed axis merges
    // under dedup (2 unique ring schedules run as one 2-lane chunk);
    // without dedup all 10 cells execute through the batch dispatch
    // (single-lane runs of the batch-labeled schedule — same bits).
    assert_eq!(dedup.engines.batched, 2, "{:?}", dedup.engines);
    assert_eq!(no_dedup.engines.batched, spec.cell_count(), "{:?}", no_dedup.engines);
    for c in &dedup.report.cells {
        assert_eq!(c.engine, "batched");
    }
}
