//! Integration: store-backed sweeps and searches are invisible in the
//! artifacts.
//!
//! The read-through contract: a sweep (or search) against a store —
//! cold, warm, or partially warm, at any thread count, with dedup on or
//! off — produces artifacts byte-identical to a storeless run of the
//! same spec, while the hit/miss accounting proves what was actually
//! served from disk. This is the same oracle discipline as the dedup
//! layer: the store may only ever change *when* a result was computed,
//! never *what* it is.

use std::path::PathBuf;

use mgfl::config::TopologyKind;
use mgfl::search::{self, OptimizeSpec, StrategyKind};
use mgfl::store::CellStore;
use mgfl::sweep::{self, RunOptions, SweepSpec};

fn tmp(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mgfl_roundtrip_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Stochastic MATCHA next to deterministic designs, two t values, so
/// the store sees seed-sensitive and seed-insensitive keys side by side.
fn grid(seeds: Vec<u64>) -> SweepSpec {
    SweepSpec {
        name: "store_roundtrip".into(),
        topologies: vec![TopologyKind::Matcha, TopologyKind::Ring, TopologyKind::Multigraph],
        networks: vec!["gaia".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![3, 5],
        seeds,
        rounds: 60,
        scenario: None,
        adapt: Vec::new(),
    }
}

fn opts(threads: usize, dedup: bool) -> RunOptions {
    RunOptions { threads, progress: false, dedup }
}

#[test]
fn warm_sweeps_are_byte_identical_at_any_thread_count_and_dedup_mode() {
    let spec = grid(vec![11, 23]);
    let reference = sweep::run(&spec, &opts(1, true)).unwrap();
    let ref_json = reference.report.to_json().to_string();
    let ref_csv = reference.report.to_csv();

    let dir = tmp("warm");
    let store = CellStore::open(&dir).unwrap();
    let cold = sweep::run_with_store(&spec, &opts(1, true), Some(&store)).unwrap();
    assert_eq!(cold.store_hits, 0, "an empty store must hit nothing");
    assert_eq!(cold.store_misses, cold.unique_cells, "cold must simulate every unique cell");
    assert_eq!(cold.report.to_json().to_string(), ref_json, "cold JSON must match storeless");
    assert_eq!(cold.report.to_csv(), ref_csv, "cold CSV must match storeless");

    for threads in [1usize, 4] {
        for dedup in [true, false] {
            let warm = sweep::run_with_store(&spec, &opts(threads, dedup), Some(&store)).unwrap();
            let ctx = format!("threads={threads} dedup={dedup}");
            assert_eq!(warm.store_misses, 0, "{ctx}: a warm store must simulate nothing");
            assert_eq!(
                warm.store_hits, warm.unique_cells,
                "{ctx}: every planned work item must be served from the store"
            );
            assert_eq!(warm.report.to_json().to_string(), ref_json, "{ctx}: JSON must match");
            assert_eq!(warm.report.to_csv(), ref_csv, "{ctx}: CSV must match");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_partially_warm_store_serves_hits_and_simulates_only_the_rest() {
    let dir = tmp("partial");
    let store = CellStore::open(&dir).unwrap();
    // Populate from the single-seed subset...
    sweep::run_with_store(&grid(vec![11]), &opts(2, true), Some(&store)).unwrap();

    // ...then sweep the superset: seed-insensitive cells (ring and the
    // multigraph) hit, the new seed's MATCHA cells must still simulate.
    let spec = grid(vec![11, 23]);
    let reference = sweep::run(&spec, &opts(1, true)).unwrap();
    let warm = sweep::run_with_store(&spec, &opts(2, true), Some(&store)).unwrap();
    assert!(warm.store_hits > 0, "subset results must be reused");
    assert!(warm.store_misses > 0, "the new seed's stochastic cells must simulate");
    assert_eq!(
        warm.store_hits + warm.store_misses,
        warm.unique_cells,
        "accounting must cover exactly the planned work"
    );
    assert_eq!(
        warm.report.to_json().to_string(),
        reference.report.to_json().to_string(),
        "a partially warm sweep must still match the storeless artifacts byte for byte"
    );
    assert_eq!(warm.report.to_csv(), reference.report.to_csv());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scenario_cells_never_cross_hit_their_static_twins_in_a_warm_store() {
    use mgfl::simtime::ScenarioSpec;
    use std::sync::Arc;

    let sc = Arc::new(
        ScenarioSpec::from_event_strs(
            9,
            &["leave@10:silo=2", "scale@20:factor=1.4", "rejoin@35:silo=2"],
        )
        .unwrap(),
    );
    let static_spec = grid(vec![11]);
    let churned_spec = SweepSpec { scenario: Some(Arc::clone(&sc)), ..grid(vec![11]) };
    // Same grid, different identity: every cell fingerprint must split
    // on the scenario hash.
    for (a, b) in static_spec.expand().iter().zip(churned_spec.expand().iter()) {
        assert_ne!(a.fingerprint(), b.fingerprint(), "scenario must join the cell identity");
        assert_eq!(a.fingerprint().scenario, None);
        assert!(b.fingerprint().scenario.is_some());
    }

    let dir = tmp("scenario");
    let store = CellStore::open(&dir).unwrap();
    // Warm the store with the static grid, then sweep the churned twin:
    // nothing may be served across the scenario boundary.
    let static_cold = sweep::run_with_store(&static_spec, &opts(2, true), Some(&store)).unwrap();
    assert_eq!(static_cold.store_hits, 0);
    let churn_cold = sweep::run_with_store(&churned_spec, &opts(2, true), Some(&store)).unwrap();
    assert_eq!(
        churn_cold.store_hits, 0,
        "a static-warm store must never serve a scenario cell"
    );
    assert_eq!(churn_cold.store_misses, churn_cold.unique_cells);

    // And the reverse: the churned results are in the store now, but a
    // static re-sweep hits only its own records...
    let static_warm = sweep::run_with_store(&static_spec, &opts(2, true), Some(&store)).unwrap();
    assert_eq!(static_warm.store_misses, 0, "static cells re-serve from their own records");
    assert_eq!(
        static_warm.report.to_json().to_string(),
        static_cold.report.to_json().to_string(),
        "static artifacts stay byte-identical with scenario records interleaved in the store"
    );
    // ...and a churned re-sweep serves every cell, metrics included,
    // byte-identical to its cold run, across dedup modes.
    for dedup in [true, false] {
        let warm = sweep::run_with_store(&churned_spec, &opts(4, dedup), Some(&store)).unwrap();
        assert_eq!(warm.store_misses, 0, "dedup={dedup}: churned cells re-serve");
        assert_eq!(
            warm.report.to_json().to_string(),
            churn_cold.report.to_json().to_string(),
            "dedup={dedup}: warm scenario artifacts must match cold byte for byte"
        );
        assert_eq!(warm.report.to_csv(), churn_cold.report.to_csv());
    }
    // Degraded-mode metrics actually round-tripped through the log.
    let warm = sweep::run_with_store(&churned_spec, &opts(1, true), Some(&store)).unwrap();
    assert!(warm.report.scenario);
    assert!(
        warm.report.cells.iter().all(|c| c.scenario.is_some() && c.error.is_none()),
        "every served scenario cell must carry its ScenarioMetrics"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn optimize_warm_starts_from_a_persisted_fitness_store() {
    let spec = OptimizeSpec {
        name: "store_warmstart".into(),
        rounds: 80,
        chains: 2,
        steps: 20,
        restart_after: 12,
        strategy: StrategyKind::Hill,
        matcha_budgets: vec![0.5],
        ..Default::default()
    };
    let run_opts = RunOptions { threads: 2, ..Default::default() };
    let reference = search::run(&spec, &run_opts).unwrap();
    let ref_json = reference.report.to_json().to_string();

    let dir = tmp("optimize");
    let store = CellStore::open(&dir).unwrap();
    let cold = search::run_with_store(&spec, &run_opts, Some(&store)).unwrap();
    assert_eq!(cold.store_hits, 0, "an empty store must hit nothing");
    assert!(cold.store_misses > 0, "cold must simulate candidates, baselines, and probes");
    assert_eq!(
        cold.report.to_json().to_string(),
        ref_json,
        "persisting fitness must not change the search"
    );

    // The search is a pure function of the spec, so a second invocation
    // asks for exactly the fitness values the first one persisted.
    let warm = search::run_with_store(&spec, &run_opts, Some(&store)).unwrap();
    assert!(warm.store_hits > 0, "the second invocation must warm-start");
    assert_eq!(warm.store_misses, 0, "every fitness must be served from the store");
    assert_eq!(warm.report.to_json().to_string(), ref_json, "warm JSON must match");
    assert_eq!(warm.report.to_csv(), reference.report.to_csv(), "warm CSV must match");
    let _ = std::fs::remove_dir_all(&dir);
}
