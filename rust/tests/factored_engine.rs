//! Integration gate for the period-factorized engine (ISSUE 5
//! acceptance): the factored `SimSummary` must be **bit-identical** to
//! the naive `DelayTracker` oracle on every zoo network at multigraph
//! t ∈ {10, 20, 30} and on N ∈ {64, 256} synthetic networks, and the
//! engine dispatch must be observable per cell in sweep reports.
//!
//! The paper-scale cell (N = 1024, t = 30, 6400 rounds) runs behind
//! the full-run gate (`cargo test -- --ignored`) — too heavy for the
//! tier-1 debug-build suite, same policy as the bench wall-clock bars.

use mgfl::net::{synth, zoo, DatasetProfile};
use mgfl::simtime::{
    simulate_summary_compiled_with_stats, simulate_summary_factored_with_stats,
    simulate_summary_naive, EngineKind, SimSummary,
};
use mgfl::topo::MultigraphTopology;

fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) {
    assert_eq!(a.topology, b.topology, "{ctx}");
    assert_eq!(a.network, b.network, "{ctx}");
    assert_eq!(a.profile, b.profile, "{ctx}");
    assert_eq!(a.rounds, b.rounds, "{ctx}");
    assert_eq!(
        a.total_ms.to_bits(),
        b.total_ms.to_bits(),
        "{ctx}: total_ms {} vs {}",
        a.total_ms,
        b.total_ms
    );
    assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}");
    assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}");
    assert_eq!(a.max_isolated, b.max_isolated, "{ctx}");
}

/// naive oracle vs (a) the factored engine invoked directly and (b)
/// whatever `simulate_summary` dispatches to — both must match bitwise.
fn check_cell(net: &mgfl::net::NetworkSpec, t: u32, rounds: usize) {
    let prof = DatasetProfile::femnist();
    let mut naive_topo = MultigraphTopology::from_network(net, &prof, t);
    let naive = simulate_summary_naive(&mut naive_topo, net, &prof, rounds);

    let factored_topo = MultigraphTopology::from_network(net, &prof, t);
    let (factored, stats) =
        simulate_summary_factored_with_stats(&factored_topo, net, &prof, rounds)
            .expect("multigraph always factorizes");
    assert_bitwise(&naive, &factored, &format!("factored {} t={t} x{rounds}", net.name));
    assert_eq!(stats.kind, EngineKind::Factored);
    assert!(
        stats.groups.unwrap() <= t as usize,
        "{}: {} groups exceed t={t}",
        net.name,
        stats.groups.unwrap()
    );

    let mut dispatch_topo = MultigraphTopology::from_network(net, &prof, t);
    let (dispatched, _) =
        simulate_summary_compiled_with_stats(&mut dispatch_topo, net, &prof, rounds);
    assert_bitwise(&naive, &dispatched, &format!("dispatch {} t={t} x{rounds}", net.name));
}

#[test]
fn factored_matches_naive_on_every_zoo_network() {
    for net in zoo::all_networks() {
        for t in [10u32, 20, 30] {
            check_cell(&net, t, 1500);
        }
    }
}

#[test]
fn factored_matches_naive_on_synthetic_networks() {
    for (n, rounds) in [(64usize, 1200usize), (256, 800)] {
        for variant in ["geo", "sphere"] {
            let name = format!("synth-{variant}-n{n}-s7");
            let net = synth::by_name(&name).expect("synth size in range");
            check_cell(&net, 30, rounds);
        }
    }
}

#[test]
fn sweep_reports_carry_the_engine_dispatch() {
    use mgfl::config::TopologyKind;
    use mgfl::sweep::{self, RunOptions, SweepSpec};
    // One grid mixing all three engines: multigraph t=30 (factored —
    // the round budget is chosen strictly below its s_max so the
    // periodic compile is provably skipped), ring (periodic), matcha
    // (streaming). The report's engine column is the observable.
    let prof = DatasetProfile::femnist();
    let s_max = MultigraphTopology::from_network(&zoo::gaia(), &prof, 30).s_max();
    assert!(s_max >= 5, "gaia t=30 must have a non-trivial schedule");
    let rounds = (s_max - 1).min(50) as usize;
    let spec = SweepSpec {
        name: "engines".into(),
        topologies: vec![
            TopologyKind::Multigraph,
            TopologyKind::Ring,
            TopologyKind::Matcha,
        ],
        networks: vec!["gaia".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![30],
        seeds: vec![17],
        rounds,
        scenario: None,
        adapt: Vec::new(),
    };
    let outcome = sweep::run(&spec, &RunOptions { threads: 2, ..Default::default() }).unwrap();
    let engine_of = |topo: &str| {
        outcome
            .report
            .cells
            .iter()
            .find(|c| c.topology == topo)
            .map(|c| c.engine)
            .expect("grid cell")
    };
    assert_eq!(engine_of("multigraph"), "factored");
    assert_eq!(engine_of("ring"), "periodic");
    assert_eq!(engine_of("matcha"), "streaming");
    assert_eq!(outcome.engines.periodic, 1, "{:?}", outcome.engines);
    assert_eq!(outcome.engines.factored, 1, "{:?}", outcome.engines);
    assert_eq!(outcome.engines.streaming, 1, "{:?}", outcome.engines);
    // The ring cell replays after round 0; factored/streaming step all.
    assert_eq!(outcome.engines.total_rounds, 3 * rounds as u64);
    assert_eq!(outcome.engines.stepped_rounds, 1 + 2 * rounds as u64);

    // The engine columns survive the JSON artifact (and dedup fan-out
    // keeps them byte-identical — the determinism suite pins the rest).
    let json = outcome.report.to_json().to_string();
    assert!(json.contains("\"engine\":\"factored\""), "{json}");
    let opts = RunOptions { threads: 1, dedup: false, ..Default::default() };
    let no_dedup = sweep::run(&spec, &opts).unwrap();
    assert_eq!(no_dedup.report.to_json().to_string(), json);
    assert_eq!(no_dedup.engines, outcome.engines);
}

/// The paper-scale identity cell the ISSUE names: N = 1024 synthetic,
/// t = 30, 6400 rounds, plus the heaviest zoo network at the same
/// budget. Heavy (a full naive large-N simulation — the exact cost the
/// factored engine removes), so it runs on full runs only:
/// `cargo test --release --test factored_engine -- --ignored`.
#[test]
#[ignore = "full-run gate: naive N=1024 x 6400 rounds is minutes of oracle work"]
fn full_run_paper_scale_identity() {
    let net = synth::by_name("synth-geo-n1024-s7").expect("synth size in range");
    check_cell(&net, 30, 6400);
    check_cell(&zoo::ebone(), 30, 6400);
}
