//! Property tests for the cross-cell SoA batched engine, backed by the
//! real proptest crate (gated behind `--features proptest` like the
//! other proptest suites; the offline build vendors no proptest).
//!
//! Strategy: random multigraph schedules over synthetic networks — a
//! ring backbone plus random chords, each pair carrying a multiplicity
//! drawn from the divisors of 12 so the schedule's LCM always fits the
//! round budget and the periodic compile is guaranteed — batched at
//! every width from 1 to `LANE_WIDTH`, with lanes cycling through all
//! dataset profiles (the schedule is profile-independent, the delays
//! are not). Every lane must be **bitwise** equal to both the per-cell
//! compiled engine and the naive `DelayTracker` oracle, and must replay
//! the compiled engine's cycle detection stat for stat.

#![cfg(feature = "proptest")]

use std::collections::BTreeSet;

use mgfl::graph::Graph;
use mgfl::net::{synth, DatasetProfile};
use mgfl::simtime::{
    run_batched, run_compiled, simulate_summary_naive, BatchLane, BatchSlab, CompiledTopology,
    DelaySlab, EngineKind, SimSummary, LANE_WIDTH,
};
use mgfl::topo::{RoundPlan, ScheduleFactorization, TopologyDesign};
use mgfl::util::lcm;
use proptest::prelude::*;
use proptest::test_runner::TestCaseError;

/// A synthetic multigraph schedule: an arbitrary edge set with
/// arbitrary multiplicities, planned in full every round with pair
/// (u, v, m) strong iff `k % m == 0`.
struct RandomMultigraph {
    overlay: Graph,
    edges: Vec<(usize, usize, u32)>,
}

impl RandomMultigraph {
    fn new(n: usize, edges: Vec<(usize, usize, u32)>) -> Self {
        let overlay = Graph::from_edges(n, edges.iter().map(|&(u, v, _)| (u, v, 1.0)));
        RandomMultigraph { overlay, edges }
    }
}

impl TopologyDesign for RandomMultigraph {
    fn name(&self) -> &str {
        "random-multigraph"
    }

    fn overlay(&self) -> &Graph {
        &self.overlay
    }

    fn plan(&mut self, k: usize) -> RoundPlan {
        let mut out = RoundPlan::empty(self.overlay.n());
        self.plan_into(k, &mut out);
        out
    }

    fn plan_into(&mut self, k: usize, out: &mut RoundPlan) {
        out.reset(self.overlay.n());
        for &(u, v, m) in &self.edges {
            let ty = if k as u64 % m as u64 == 0 {
                mgfl::delay::EdgeType::Strong
            } else {
                mgfl::delay::EdgeType::Weak
            };
            out.push(u, v, ty);
        }
    }

    fn period(&self) -> Option<u64> {
        Some(self.edges.iter().map(|&(_, _, m)| m as u64).fold(1, lcm))
    }

    fn factorization(&self) -> Option<ScheduleFactorization> {
        Some(ScheduleFactorization {
            n: self.overlay.n(),
            edges: self.edges.clone(),
        })
    }

    fn seed_sensitive(&self) -> bool {
        false
    }
}

fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) -> Result<(), TestCaseError> {
    prop_assert_eq!(a.rounds, b.rounds, "{}", ctx);
    prop_assert_eq!(
        a.total_ms.to_bits(),
        b.total_ms.to_bits(),
        "{}: total_ms {} vs {}",
        ctx,
        a.total_ms,
        b.total_ms
    );
    prop_assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{}", ctx);
    prop_assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{}", ctx);
    prop_assert_eq!(a.max_isolated, b.max_isolated, "{}", ctx);
    Ok(())
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(40))]

    #[test]
    fn batched_lanes_match_compiled_and_naive_bitwise(
        n in 4usize..32,
        net_seed in 1u64..1000,
        chord_seeds in proptest::collection::vec((0usize..1000, 0usize..1000), 0..10),
        mult_seed in 0u64..(1 << 32),
        rounds in 13usize..160,
        width in 1usize..=LANE_WIDTH,
    ) {
        let net = synth::by_name(&format!("synth-geo-n{n}-s{net_seed}"))
            .expect("synth size in range");
        let profiles = DatasetProfile::all();

        // Ring backbone (connected, every node participates) plus
        // random chords, deduplicated; multiplicities drawn from the
        // divisors of 12 via a cheap splitmix over the pair, so the
        // schedule period (the LCM) divides 12 and 12 < rounds.
        const DIVISORS: [u32; 6] = [1, 2, 3, 4, 6, 12];
        let mut pairs: BTreeSet<(usize, usize)> = BTreeSet::new();
        for i in 0..n - 1 {
            pairs.insert((i, i + 1));
        }
        pairs.insert((0, n - 1));
        for &(a, b) in &chord_seeds {
            let (u, v) = (a % n, b % n);
            if u < v {
                pairs.insert((u, v));
            }
        }
        let edges: Vec<(usize, usize, u32)> = pairs
            .into_iter()
            .map(|(u, v)| {
                let h = mult_seed
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(((u as u64) << 32) | v as u64)
                    .wrapping_mul(0xBF58476D1CE4E5B9);
                (u, v, DIVISORS[(h >> 33) as usize % DIVISORS.len()])
            })
            .collect();

        // One shared schedule compile (profile-independent); lanes
        // cycle through the profiles, so the batch mixes three delay
        // resolutions over one plan.
        let mut topo = RandomMultigraph::new(n, edges.clone());
        let ct = CompiledTopology::compile(&mut topo, rounds)
            .expect("divisor-of-12 LCM fits any rounds >= 13");
        let lanes: Vec<BatchLane<'_>> = (0..width)
            .map(|j| BatchLane { ct: &ct, net: &net, profile: &profiles[j % profiles.len()] })
            .collect();
        let mut slab = BatchSlab::default();
        let res = run_batched(&ct, &lanes, rounds, &mut slab);
        prop_assert_eq!(res.len(), width);

        for (j, (got, stats)) in res.iter().enumerate() {
            let prof = &profiles[j % profiles.len()];
            let mut naive_topo = RandomMultigraph::new(n, edges.clone());
            let naive = simulate_summary_naive(&mut naive_topo, &net, prof, rounds);
            assert_bitwise(got, &naive, &format!("lane {j} vs naive"))?;

            let mut delay = DelaySlab::new(&ct, &net, prof);
            let (want, want_stats) = run_compiled(&ct, &mut delay, &net, prof, rounds);
            assert_bitwise(got, &want, &format!("lane {j} vs compiled"))?;
            prop_assert_eq!(stats.kind, EngineKind::Batched);
            prop_assert_eq!(stats.period, want_stats.period, "lane {}", j);
            prop_assert_eq!(stats.cycle_detected_at, want_stats.cycle_detected_at, "lane {}", j);
            prop_assert_eq!(stats.cycle_len, want_stats.cycle_len, "lane {}", j);
            prop_assert_eq!(stats.simulated_rounds, want_stats.simulated_rounds, "lane {}", j);
        }
    }
}
