//! Integration: synthetic large-N networks + the dense-graph
//! construction layer.
//!
//! Contracts pinned here (extending the `sweep_determinism.rs`
//! pattern to the synthetic axis):
//!
//! * generation determinism — the same `synth-…` name yields a
//!   byte-identical `NetworkSpec`; different seeds differ;
//! * connectivity weights are symmetric and positive under the
//!   Pareto-spread capacities;
//! * the compiled simulation engine matches the naive `DelayTracker`
//!   oracle bitwise on a synthetic N=256 network (the same oracle
//!   cross-check the paper zoo gets);
//! * dense-built designs equal their pre-overhaul reference builders
//!   on a synthetic network, not just on the zoo;
//! * the sweep engine resolves synthetic names, canonicalizes their
//!   case, and stays thread-count invariant over a synthetic axis.

use mgfl::config::TopologyKind;
use mgfl::net::synth::{self, SynthVariant};
use mgfl::net::DatasetProfile;
use mgfl::simtime::{simulate_summary, simulate_summary_naive};
use mgfl::sweep::{self, RunOptions, SweepSpec};
use mgfl::topo::delta_mbst::{DeltaMbstTopology, DEFAULT_DELTA};
use mgfl::topo::matcha::{MatchaCore, MatchaTopology, DEFAULT_BUDGET};
use mgfl::topo::mst::MstTopology;
use mgfl::topo::ring::RingTopology;
use mgfl::topo::star::StarTopology;
use mgfl::topo::{MultigraphTopology, TopologyDesign};

#[test]
fn same_seed_is_byte_identical_and_seeds_differ() {
    for variant in SynthVariant::all() {
        let name = synth::name_of(variant, 96, 7);
        let a = synth::by_name(&name).unwrap();
        let b = synth::by_name(&name).unwrap();
        assert_eq!(a.name, b.name);
        assert_eq!(a.n(), b.n());
        for (x, y) in a.silos.iter().zip(&b.silos) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.lat.to_bits(), y.lat.to_bits());
            assert_eq!(x.lon.to_bits(), y.lon.to_bits());
            assert_eq!(x.up_gbps.to_bits(), y.up_gbps.to_bits());
            assert_eq!(x.dn_gbps.to_bits(), y.dn_gbps.to_bits());
        }

        let c = synth::by_name(&synth::name_of(variant, 96, 8)).unwrap();
        assert_ne!(c.name, a.name, "seed is part of the canonical name");
        let moved = a
            .silos
            .iter()
            .zip(&c.silos)
            .filter(|(x, y)| x.lat.to_bits() != y.lat.to_bits())
            .count();
        assert!(moved > 90, "{variant:?}: different seeds must relocate silos ({moved}/96)");
    }
}

#[test]
fn connectivity_weights_symmetric_and_positive() {
    let prof = DatasetProfile::femnist();
    for variant in SynthVariant::all() {
        let net = synth::generate(variant, 64, 3);
        let dense = net.connectivity_dense(&prof);
        for u in 0..net.n() {
            for v in 0..net.n() {
                if u == v {
                    continue;
                }
                let w = net.conn_weight(&prof, u, v);
                assert!(w > 0.0 && w.is_finite(), "{variant:?} ({u},{v}): weight {w}");
                assert_eq!(
                    w.to_bits(),
                    net.conn_weight(&prof, v, u).to_bits(),
                    "{variant:?} ({u},{v}): weight must be symmetric"
                );
                assert_eq!(dense.weight(u, v).to_bits(), w.to_bits());
            }
        }
    }
}

/// The compiled-vs-naive oracle cross-check on a synthetic N=256
/// network — the bit-identity contract must hold beyond the paper zoo.
#[test]
fn compiled_engine_matches_naive_oracle_on_synth_n256() {
    let net = synth::by_name("synth-geo-n256-s7").unwrap();
    let prof = DatasetProfile::femnist();
    let rounds = 120;
    let build = |kind: TopologyKind| -> Box<dyn TopologyDesign> {
        match kind {
            TopologyKind::Ring => Box::new(RingTopology::new(&net, &prof)),
            TopologyKind::Matcha => {
                Box::new(MatchaTopology::new(&net, &prof, DEFAULT_BUDGET, 23))
            }
            _ => Box::new(MultigraphTopology::from_network(&net, &prof, 5)),
        }
    };
    for kind in [TopologyKind::Ring, TopologyKind::Matcha, TopologyKind::Multigraph] {
        let mut a = build(kind);
        let mut b = build(kind);
        let fast = simulate_summary(a.as_mut(), &net, &prof, rounds);
        let naive = simulate_summary_naive(b.as_mut(), &net, &prof, rounds);
        let ctx = format!("{}/{}", fast.topology, net.name);
        assert_eq!(fast.total_ms.to_bits(), naive.total_ms.to_bits(), "{ctx}");
        assert_eq!(fast.mean_cycle_ms.to_bits(), naive.mean_cycle_ms.to_bits(), "{ctx}");
        assert_eq!(fast.rounds_with_isolated, naive.rounds_with_isolated, "{ctx}");
        assert_eq!(fast.max_isolated, naive.max_isolated, "{ctx}");
    }
}

/// Dense builders vs pre-overhaul reference on a synthetic network:
/// the byte-identity contract is substrate-wide, not zoo-specific.
#[test]
fn dense_builders_match_reference_on_synth() {
    let net = synth::by_name("synth-sphere-n64-s1").unwrap();
    let prof = DatasetProfile::femnist();
    let pairs: Vec<(Box<dyn TopologyDesign>, Box<dyn TopologyDesign>)> = vec![
        (
            Box::new(StarTopology::new(&net, &prof)),
            Box::new(StarTopology::new_reference(&net, &prof)),
        ),
        (
            Box::new(MatchaTopology::new(&net, &prof, DEFAULT_BUDGET, 17)),
            Box::new(MatchaTopology::from_core(
                std::sync::Arc::new(MatchaCore::build_reference(&net, &prof)),
                DEFAULT_BUDGET,
                17,
            )),
        ),
        (
            Box::new(MstTopology::new(&net, &prof)),
            Box::new(MstTopology::new_reference(&net, &prof)),
        ),
        (
            Box::new(DeltaMbstTopology::new(&net, &prof, DEFAULT_DELTA)),
            Box::new(DeltaMbstTopology::new_reference(&net, &prof, DEFAULT_DELTA)),
        ),
        (
            Box::new(RingTopology::new(&net, &prof)),
            Box::new(RingTopology::new_reference(&net, &prof)),
        ),
        (
            Box::new(MultigraphTopology::from_network(&net, &prof, 5)),
            Box::new(MultigraphTopology::from_network_reference(&net, &prof, 5)),
        ),
    ];
    for (mut dense, mut reference) in pairs {
        let ctx = dense.name().to_string();
        let (a, b) = (dense.overlay().edges(), reference.overlay().edges());
        assert_eq!(a.len(), b.len(), "{ctx}: overlay size");
        for (x, y) in a.iter().zip(b) {
            assert_eq!((x.u, x.v, x.w.to_bits()), (y.u, y.v, y.w.to_bits()), "{ctx}");
        }
        for k in 0..4 {
            assert_eq!(dense.plan(k).edges, reference.plan(k).edges, "{ctx}: round {k}");
        }
    }
}

#[test]
fn sweep_engine_resolves_and_canonicalizes_synthetic_networks() {
    let mut spec = SweepSpec {
        name: "synth_axis".into(),
        topologies: vec![TopologyKind::Ring, TopologyKind::Multigraph],
        networks: vec!["SYNTH-GEO-N64-S3".into(), "gaia".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![5],
        seeds: vec![17],
        rounds: 40,
        scenario: None,
        adapt: Vec::new(),
    };
    spec.canonicalize().unwrap();
    assert_eq!(spec.networks, vec!["synth-geo-n64-s3", "gaia"]);
    spec.validate().unwrap();

    let serial = sweep::run(&spec, &RunOptions { threads: 1, ..Default::default() }).unwrap();
    let parallel = sweep::run(&spec, &RunOptions { threads: 4, ..Default::default() }).unwrap();
    assert_eq!(
        serial.report.to_json().to_string(),
        parallel.report.to_json().to_string(),
        "synthetic-axis sweeps must stay thread-count invariant"
    );
    assert_eq!(serial.report.cells.len(), 4);
    assert!(serial.build_ms >= 0.0 && serial.sim_ms > 0.0, "timing split populated");

    // Synthetic multigraph beats synthetic ring (the paper's headline
    // transfers to the generated networks).
    let ours = serial.report.cell("multigraph", "synth-geo-n64-s3", "femnist").unwrap();
    let ring = serial.report.cell("ring", "synth-geo-n64-s3", "femnist").unwrap();
    assert!(
        ours.mean_cycle_ms < ring.mean_cycle_ms,
        "multigraph {} vs ring {}",
        ours.mean_cycle_ms,
        ring.mean_cycle_ms
    );

    // Unknown synthetic spellings fail validation, not simulation.
    let mut bad = spec.clone();
    bad.networks = vec!["synth-torus-n64-s3".into()];
    assert!(bad.validate().is_err());
}
