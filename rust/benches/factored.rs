//! Bench: the period-factorized engine vs the per-edge streaming
//! engine on the cells the factorization exists for — huge-s_max
//! multigraphs on large synthetic networks.
//!
//! Three jobs in one binary:
//!
//! 1. **Zoo identity gate** — on every paper network at multigraph
//!    t ∈ {10, 20, 30}, the factored `SimSummary` must be bit-identical
//!    to the naive `DelayTracker` oracle. Aborts (failing CI) on any
//!    disagreement.
//! 2. **Synthetic identity gate** — on a synthetic network at the
//!    smallest requested size (t = 30), factored, streaming, and naive
//!    must agree bitwise: the large-N axis gets the same contract.
//! 3. **Per-cell simulation throughput** — for each N in `--n`
//!    (default 64,256,1024): wall-clock of one simulation cell
//!    (topology pre-built; the cell is compile/resolve + round loop) on
//!    the streaming engine vs the factored engine at `--rounds`
//!    (default 6400). The ≥ 10× streaming-cells/sec bar is asserted
//!    when N = 1024 is measured at ≥ 6400 rounds — i.e. on full runs;
//!    the CI smoke (`-- --n 128 --rounds 400`) runs the gates only.
//!
//! Run: `cargo bench --bench factored` (refreshes
//! `BENCH_factored.json`); CI smoke: `-- --n 128 --rounds 400`.

use std::collections::BTreeMap;

use mgfl::net::synth::{self, SynthVariant};
use mgfl::net::{zoo, DatasetProfile, NetworkSpec};
use mgfl::simtime::{
    run_factored, simulate_summary_naive, simulate_summary_streaming_with_stats, FactoredSlab,
    FactoredTopology, SimSummary,
};
use mgfl::topo::MultigraphTopology;
use mgfl::util::args::Args;
use mgfl::util::bench;
use mgfl::util::json::Json;

const BAR_N: usize = 1024;
const BAR: f64 = 10.0;
const BAR_ROUNDS: usize = 6400;
const T_VALUES: [u32; 3] = [10, 20, 30];

fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) {
    assert_eq!(
        a.total_ms.to_bits(),
        b.total_ms.to_bits(),
        "{ctx}: total_ms diverged ({} vs {})",
        a.total_ms,
        b.total_ms
    );
    assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}");
    assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}");
    assert_eq!(a.max_isolated, b.max_isolated, "{ctx}");
}

/// naive oracle vs factored vs forced-streaming on one multigraph cell.
fn gate_cell(net: &NetworkSpec, prof: &DatasetProfile, t: u32, rounds: usize) {
    let ctx = format!("{}/t{t}/x{rounds}", net.name);
    let mut naive_topo = MultigraphTopology::from_network(net, prof, t);
    let naive = simulate_summary_naive(&mut naive_topo, net, prof, rounds);

    let topo = MultigraphTopology::from_network(net, prof, t);
    let ft = FactoredTopology::compile(&topo).expect("multigraph factorizes");
    let mut slab = FactoredSlab::new(&ft, net, prof);
    let (factored, _) = run_factored(&ft, &mut slab, net, prof, rounds);
    assert_bitwise(&naive, &factored, &format!("factored {ctx}"));

    let mut stream_topo = MultigraphTopology::from_network(net, prof, t);
    let (streamed, _) = simulate_summary_streaming_with_stats(&mut stream_topo, net, prof, rounds);
    assert_bitwise(&naive, &streamed, &format!("streaming {ctx}"));
}

fn main() {
    let args = Args::from_env();
    let sizes: Vec<usize> = args
        .get_parsed_list::<usize>("n")
        .expect("--n takes comma-separated silo counts")
        .unwrap_or_else(|| vec![64, 256, 1024]);
    assert!(!sizes.is_empty(), "--n must list at least one size");
    let rounds: usize = args.get("rounds", BAR_ROUNDS).expect("--rounds takes an integer");
    let variant_s = args.get_str("variant", "geo");
    let variant = SynthVariant::parse(&variant_s).expect("--variant geo|sphere");
    let out = args.get_str("out", "BENCH_factored.json");
    let prof = DatasetProfile::femnist();
    let gate_rounds = rounds.min(400);

    // --- 1. zoo identity gate ---------------------------------------
    bench::header(&format!(
        "factored identity gate — factored vs streaming vs naive, paper zoo, {gate_rounds} rounds"
    ));
    let mut zoo_cells = 0usize;
    for net in zoo::all_networks() {
        for t in T_VALUES {
            gate_cell(&net, &prof, t, gate_rounds);
            zoo_cells += 1;
        }
    }
    println!("{zoo_cells} zoo cells bit-identical across all three engines");

    // --- 2. synthetic identity gate ---------------------------------
    let oracle_n = *sizes.iter().min().unwrap();
    let oracle_name = synth::name_of(variant, oracle_n, 7);
    bench::header(&format!("synthetic identity gate — {oracle_name}, t = 30"));
    let oracle_net = synth::by_name(&oracle_name).expect("synthetic size in range");
    gate_cell(&oracle_net, &prof, 30, gate_rounds);
    println!("synthetic cell bit-identical across all three engines ({gate_rounds} rounds)");

    // --- 3. per-cell simulation throughput --------------------------
    // The topology is built once per size (construction is identical
    // either way); a "cell" is everything a sweep worker pays per
    // simulation: schedule compile/resolve plus the round loop.
    // (n, groups, stream_ms, factored_ms)
    let mut per_n: Vec<(usize, usize, f64, f64)> = Vec::new();
    let mut bar_speedup: Option<f64> = None;
    for &n in &sizes {
        bench::header(&format!(
            "per-cell simulation throughput — multigraph t=30, synth-{}-n{n}-s7, {rounds} rounds",
            variant.as_str()
        ));
        let net = synth::by_name(&synth::name_of(variant, n, 7)).expect("size in range");
        let mut topo = MultigraphTopology::from_network(&net, &prof, 30);
        let groups = FactoredTopology::compile(&topo).expect("factorizes").num_groups();
        let (warmup, iters) = if n >= 2048 { (0, 2) } else { (1, 3) };
        let stream_m = bench::bench(&format!("streaming cell  N={n}"), warmup, iters, || {
            let (s, _) = simulate_summary_streaming_with_stats(&mut topo, &net, &prof, rounds);
            std::hint::black_box(s.total_ms);
        });
        let factored_m = bench::bench(&format!("factored cell   N={n}"), warmup, iters, || {
            let ft = FactoredTopology::compile(&topo).expect("factorizes");
            let mut slab = FactoredSlab::new(&ft, &net, &prof);
            let (s, _) = run_factored(&ft, &mut slab, &net, &prof, rounds);
            std::hint::black_box(s.total_ms);
        });
        let speedup = stream_m.mean_ms / factored_m.mean_ms.max(1e-9);
        println!("speedup {speedup:.1}x ({groups} multiplicity groups vs {n} edges per round)");
        if n == BAR_N && rounds >= BAR_ROUNDS {
            bar_speedup = Some(speedup);
        }
        per_n.push((n, groups, stream_m.mean_ms, factored_m.mean_ms));
    }
    if let Some(speedup) = bar_speedup {
        assert!(
            speedup >= BAR,
            "acceptance: factored cells/sec must be >= {BAR}x streaming at N={BAR_N}, t=30, \
             {BAR_ROUNDS} rounds (got {speedup:.2}x)"
        );
        println!("\n>= {BAR}x streaming-cells/sec bar at N={BAR_N}: PASS ({speedup:.2}x)");
    } else {
        println!(
            "\n(>= {BAR}x bar asserts when N={BAR_N} is measured at >= {BAR_ROUNDS} rounds; \
             this run: --n {sizes:?} --rounds {rounds})"
        );
    }

    // --- 4. baseline artifact ---------------------------------------
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("factored".into()));
    obj.insert(
        "provenance".to_string(),
        Json::Str(
            "measured by `cargo bench --bench factored` (zoo + synthetic identity gates and \
             the >= 10x N=1024 streaming-cells/sec bar passed first)"
                .into(),
        ),
    );
    // A full measured run leaves no nulls in this artifact; smoke runs
    // say so explicitly (CI checks the consistency of committed files).
    obj.insert("measured".to_string(), Json::Bool(bar_speedup.is_some()));
    obj.insert("variant".to_string(), Json::Str(variant.as_str().into()));
    obj.insert("rounds".to_string(), Json::Num(rounds as f64));
    obj.insert("zoo_cells_checked".to_string(), Json::Num(zoo_cells as f64));
    obj.insert("identity_gates_passed".to_string(), Json::Bool(true));
    obj.insert(
        "bar_speedup_n1024".to_string(),
        bar_speedup.map_or(Json::Null, Json::Num),
    );
    let cells: Vec<Json> = per_n
        .iter()
        .map(|&(n, groups, stream_ms, factored_ms)| {
            let mut m = BTreeMap::new();
            m.insert("n".to_string(), Json::Num(n as f64));
            m.insert("multiplicity_groups".to_string(), Json::Num(groups as f64));
            m.insert("streaming_ms_per_cell".to_string(), Json::Num(stream_ms));
            m.insert("factored_ms_per_cell".to_string(), Json::Num(factored_ms));
            m.insert(
                "speedup".to_string(),
                Json::Num(stream_ms / factored_ms.max(1e-9)),
            );
            Json::Obj(m)
        })
        .collect();
    obj.insert("sizes".to_string(), Json::Arr(cells));
    let json = Json::Obj(obj).to_string();
    std::fs::write(&out, format!("{json}\n")).expect("writing bench baseline");
    println!("\nbaseline -> {out}");
}
