//! Bench: the cross-cell SoA batched engine vs the per-cell compiled
//! engine — cells sharing one periodic schedule stepped in lockstep
//! lanes through a single pass over the plan per round.
//!
//! Three jobs in one binary:
//!
//! 1. **Zoo identity gate** — on every paper network, a ring batch with
//!    one lane per dataset profile must be bit-identical, lane by lane,
//!    to the naive `DelayTracker` oracle. (The ring schedule is
//!    profile-independent, so the lanes genuinely share one schedule at
//!    three different delay resolutions.)
//! 2. **Multigraph identity gate** — gaia multigraph t = 5: a
//!    single-lane batch must match both the naive oracle and the
//!    per-cell compiled engine bitwise, and an 8-identical-lane batch
//!    must replay the compiled engine's cycle detection lane by lane.
//!    A synthetic network repeats the 8-lane check at N = 64.
//! 3. **Lockstep throughput** — pick the gaia multigraph t whose
//!    materialized period keeps the round loop stepping (no replay
//!    shortcut dominating) at `--rounds`, then time one
//!    `LANE_WIDTH`-lane batch of that cell against the same number of
//!    sequential per-cell compiled runs. The ≥ 3x cells/sec bar is
//!    asserted on full runs (`--rounds` ≥ 6400) when such a t exists;
//!    the CI smoke (`-- --rounds 400`) runs the gates only.
//!
//! Run: `cargo bench --bench batched` (refreshes `BENCH_batched.json`);
//! CI smoke: `-- --rounds 400 --out /tmp/BENCH_batched.json`.

use std::collections::BTreeMap;

use mgfl::net::synth::{self, SynthVariant};
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::{
    run_batched, run_compiled, simulate_summary_naive, BatchLane, BatchSlab, CompiledTopology,
    DelaySlab, SimSummary, LANE_WIDTH,
};
use mgfl::topo::ring::RingTopology;
use mgfl::topo::MultigraphTopology;
use mgfl::util::args::Args;
use mgfl::util::bench;
use mgfl::util::json::Json;

const BAR: f64 = 3.0;
const BAR_ROUNDS: usize = 6400;

fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) {
    assert_eq!(
        a.total_ms.to_bits(),
        b.total_ms.to_bits(),
        "{ctx}: total_ms diverged ({} vs {})",
        a.total_ms,
        b.total_ms
    );
    assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}");
    assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}");
    assert_eq!(a.max_isolated, b.max_isolated, "{ctx}");
}

fn main() {
    let args = Args::from_env();
    let rounds: usize = args.get("rounds", BAR_ROUNDS).expect("--rounds takes an integer");
    assert!(rounds > 0, "--rounds must be positive");
    let out = args.get_str("out", "BENCH_batched.json");
    let gate_rounds = rounds.min(400);
    let profiles = DatasetProfile::all();

    // --- 1. zoo ring identity gate ----------------------------------
    bench::header(&format!(
        "batched identity gate — ring lanes across profiles vs naive, paper zoo, {gate_rounds} rounds"
    ));
    let mut zoo_lanes = 0usize;
    for net in zoo::all_networks() {
        let compiled: Vec<CompiledTopology> = profiles
            .iter()
            .map(|p| {
                let mut topo = RingTopology::new(&net, p);
                CompiledTopology::compile(&mut topo, gate_rounds)
                    .expect("ring schedules are periodic")
            })
            .collect();
        let rep = &compiled[0];
        let lanes: Vec<BatchLane<'_>> = compiled
            .iter()
            .zip(&profiles)
            .map(|(ct, p)| {
                assert!(rep.schedule_eq(ct), "ring schedule must be profile-independent");
                BatchLane { ct, net: &net, profile: p }
            })
            .collect();
        let mut slab = BatchSlab::default();
        for ((s, _), p) in run_batched(rep, &lanes, gate_rounds, &mut slab).iter().zip(&profiles) {
            let mut naive_topo = RingTopology::new(&net, p);
            let naive = simulate_summary_naive(&mut naive_topo, &net, p, gate_rounds);
            assert_bitwise(s, &naive, &format!("{}/{}", net.name, p.name));
            zoo_lanes += 1;
        }
    }
    println!("{zoo_lanes} ring lanes bit-identical to the naive oracle");

    // --- 2. multigraph identity gate --------------------------------
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();
    bench::header(&format!(
        "batched identity gate — gaia multigraph t=5, single lane + {LANE_WIDTH} lanes, {gate_rounds} rounds"
    ));
    let mut naive_topo = MultigraphTopology::from_network(&net, &prof, 5);
    let naive = simulate_summary_naive(&mut naive_topo, &net, &prof, gate_rounds);
    let mut topo = MultigraphTopology::from_network(&net, &prof, 5);
    let ct = CompiledTopology::compile(&mut topo, gate_rounds).expect("gaia t=5 is materializable");
    let mut delay = DelaySlab::new(&ct, &net, &prof);
    let (solo, solo_stats) = run_compiled(&ct, &mut delay, &net, &prof, gate_rounds);
    assert_bitwise(&solo, &naive, "gaia/t5 per-cell compiled");
    let mut slab = BatchSlab::default();
    let single = run_batched(
        &ct,
        &[BatchLane { ct: &ct, net: &net, profile: &prof }],
        gate_rounds,
        &mut slab,
    );
    assert_bitwise(&single[0].0, &naive, "gaia/t5 single-lane batch");
    let lanes: Vec<BatchLane<'_>> =
        (0..LANE_WIDTH).map(|_| BatchLane { ct: &ct, net: &net, profile: &prof }).collect();
    for (j, (s, stats)) in run_batched(&ct, &lanes, gate_rounds, &mut slab).iter().enumerate() {
        assert_bitwise(s, &solo, &format!("gaia/t5 lane {j}"));
        assert_eq!(stats.cycle_detected_at, solo_stats.cycle_detected_at, "lane {j}");
        assert_eq!(stats.simulated_rounds, solo_stats.simulated_rounds, "lane {j}");
    }
    println!(
        "single-lane and {LANE_WIDTH}-lane batches bit-identical to per-cell compiled + naive"
    );

    // A synthetic network repeats the full-width check: batching must
    // not depend on zoo-sized edge tables.
    let synth_net =
        synth::by_name(&synth::name_of(SynthVariant::Geo, 64, 7)).expect("synthetic size in range");
    let mut synth_checked = false;
    for t in [2u32, 3, 4, 5] {
        let mut topo = MultigraphTopology::from_network(&synth_net, &prof, t);
        let Some(ct) = CompiledTopology::compile(&mut topo, gate_rounds) else { continue };
        let mut delay = DelaySlab::new(&ct, &synth_net, &prof);
        let (want, _) = run_compiled(&ct, &mut delay, &synth_net, &prof, gate_rounds);
        let lanes: Vec<BatchLane<'_>> = (0..LANE_WIDTH)
            .map(|_| BatchLane { ct: &ct, net: &synth_net, profile: &prof })
            .collect();
        let mut slab = BatchSlab::default();
        for (j, (s, _)) in run_batched(&ct, &lanes, gate_rounds, &mut slab).iter().enumerate() {
            assert_bitwise(s, &want, &format!("{}/t{t} lane {j}", synth_net.name));
        }
        println!(
            "{}/t{t}: {LANE_WIDTH} lanes bit-identical to the per-cell compiled engine",
            synth_net.name
        );
        synth_checked = true;
        break;
    }
    if !synth_checked {
        println!(
            "(no synthetic t in 2..=5 compiles periodically at {gate_rounds} rounds — \
             gate covered by the zoo)"
        );
    }

    // --- 3. lockstep throughput -------------------------------------
    // Pick the t whose period p keeps the engines stepping for most of
    // `rounds` (p in [rounds/4, rounds]): a tiny period would let cycle
    // replay shortcut both engines and time bookkeeping, not lanes.
    let mut pick: Option<(u32, u64)> = None;
    for t in 2..=40u32 {
        let s = MultigraphTopology::from_network(&net, &prof, t).s_max();
        let p = s as usize;
        if p * 4 >= rounds && p <= rounds && pick.map_or(true, |(_, best)| s > best) {
            pick = Some((t, s));
        }
    }
    let mut bar_speedup: Option<f64> = None;
    // (t, period, solo_ms, batched_ms) for one LANE_WIDTH-cell batch.
    let mut timing: Option<(u32, u64, f64, f64)> = None;
    if let Some((t, s_max)) = pick {
        let mut topo = MultigraphTopology::from_network(&net, &prof, t);
        if let Some(ct) = CompiledTopology::compile(&mut topo, rounds) {
            bench::header(&format!(
                "lockstep throughput — gaia multigraph t={t} (period {s_max}), {LANE_WIDTH} lanes, {rounds} rounds"
            ));
            let mut delay = DelaySlab::new(&ct, &net, &prof);
            let solo_m = bench::bench(&format!("per-cell compiled x{LANE_WIDTH}"), 1, 3, || {
                for _ in 0..LANE_WIDTH {
                    let (s, _) = run_compiled(&ct, &mut delay, &net, &prof, rounds);
                    std::hint::black_box(s.total_ms);
                }
            });
            let lanes: Vec<BatchLane<'_>> =
                (0..LANE_WIDTH).map(|_| BatchLane { ct: &ct, net: &net, profile: &prof }).collect();
            let mut slab = BatchSlab::default();
            let batch_m = bench::bench(&format!("batched {LANE_WIDTH}-lane"), 1, 3, || {
                let res = run_batched(&ct, &lanes, rounds, &mut slab);
                std::hint::black_box(res[0].0.total_ms);
            });
            let speedup = solo_m.mean_ms / batch_m.mean_ms.max(1e-9);
            println!(
                "speedup {speedup:.1}x cells/sec ({LANE_WIDTH} lockstep lanes vs {LANE_WIDTH} sequential runs)"
            );
            if rounds >= BAR_ROUNDS {
                bar_speedup = Some(speedup);
            }
            timing = Some((t, s_max, solo_m.mean_ms, batch_m.mean_ms));
        } else {
            println!(
                "(gaia t={t} did not compile periodically at {rounds} rounds — timing skipped)"
            );
        }
    } else {
        println!(
            "\n(no gaia t in 2..=40 has a stepping-dominated period at --rounds {rounds}; \
             timing skipped)"
        );
    }
    if let Some(speedup) = bar_speedup {
        assert!(
            speedup >= BAR,
            "acceptance: batched cells/sec must be >= {BAR}x the per-cell compiled path \
             ({LANE_WIDTH} lanes, {rounds} rounds; got {speedup:.2}x)"
        );
        println!("\n>= {BAR}x cells/sec bar: PASS ({speedup:.2}x)");
    } else {
        println!(
            "\n(>= {BAR}x bar asserts when the timing workload runs at >= {BAR_ROUNDS} rounds; \
             this run: --rounds {rounds})"
        );
    }

    // --- 4. baseline artifact ---------------------------------------
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("batched".into()));
    obj.insert(
        "provenance".to_string(),
        Json::Str(
            "measured by `cargo bench --bench batched` (zoo + multigraph + synthetic identity \
             gates passed first; the >= 3x cells/sec bar asserts on full runs)"
                .into(),
        ),
    );
    obj.insert("measured".to_string(), Json::Bool(bar_speedup.is_some()));
    obj.insert("rounds".to_string(), Json::Num(rounds as f64));
    obj.insert("lane_width".to_string(), Json::Num(LANE_WIDTH as f64));
    obj.insert("zoo_lanes_checked".to_string(), Json::Num(zoo_lanes as f64));
    obj.insert("identity_gates_passed".to_string(), Json::Bool(true));
    obj.insert("bar_speedup".to_string(), bar_speedup.map_or(Json::Null, Json::Num));
    match &timing {
        Some(&(t, period, solo_ms, batched_ms)) => {
            obj.insert("timing_t".to_string(), Json::Num(t as f64));
            obj.insert("timing_period".to_string(), Json::Num(period as f64));
            obj.insert("solo_ms_per_batch".to_string(), Json::Num(solo_ms));
            obj.insert("batched_ms_per_batch".to_string(), Json::Num(batched_ms));
        }
        None => {
            obj.insert("timing_t".to_string(), Json::Null);
            obj.insert("timing_period".to_string(), Json::Null);
            obj.insert("solo_ms_per_batch".to_string(), Json::Null);
            obj.insert("batched_ms_per_batch".to_string(), Json::Null);
        }
    }
    let json = Json::Obj(obj).to_string();
    std::fs::write(&out, format!("{json}\n")).expect("writing bench baseline");
    println!("\nbaseline -> {out}");
}
