//! Bench: regenerate paper Fig. 5 — training loss vs communication
//! rounds (top row) and vs simulated wall-clock (bottom row) for every
//! topology, plus Fig. 1's accuracy-vs-overhead scatter.
//!
//! Real training through the PJRT runtime when artifacts are present
//! (default 24 rounds on Gaia to keep bench time sane — the full-scale
//! curves come from `mgfl fig5`); falls back to simulation-only series
//! when artifacts are missing.

use mgfl::config::{ExperimentConfig, TopologyKind, TrainConfig};
use mgfl::coordinator::Trainer;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::simulate;
use mgfl::util::bench;

fn main() {
    let rounds: usize = std::env::var("MGFL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    bench::header(&format!("Fig. 5 — convergence curves ({rounds} real training rounds, Gaia)"));

    if !mgfl::runtime::artifacts_available() {
        println!("artifacts/ missing — emitting simulated time axes only (run `make artifacts`)");
        let net = zoo::exodus();
        let prof = DatasetProfile::femnist();
        for kind in TopologyKind::all() {
            let cfg = ExperimentConfig {
                network: "exodus".into(),
                topology: kind,
                sim_rounds: 6400,
                ..Default::default()
            };
            let mut topo = cfg.build_topology();
            let res = simulate(topo.as_mut(), &net, &prof, 6400);
            println!("{:<12} total {:.1} s", kind.as_str(), res.total_ms / 1e3);
        }
        return;
    }

    std::fs::create_dir_all("results").ok();
    let mut scatter = Vec::new();
    for kind in TopologyKind::all() {
        let cfg = ExperimentConfig {
            network: "gaia".into(),
            topology: kind,
            sim_rounds: rounds,
            train: Some(TrainConfig {
                rounds,
                model: "femnist_mlp".into(),
                eval_examples: 256,
                ..Default::default()
            }),
            ..Default::default()
        };
        let mut trainer = Trainer::from_config(&cfg).expect("trainer");
        let trace = trainer.run(rounds).expect("train");
        // Loss-vs-round and loss-vs-time series (the two Fig. 5 rows).
        let series: Vec<String> = trace
            .records
            .iter()
            .step_by((rounds / 8).max(1))
            .map(|r| format!("({}, {:.0}ms, {:.3})", r.round, r.sim_elapsed_ms, r.train_loss))
            .collect();
        println!("{:<12} {}", kind.as_str(), series.join(" "));
        let path = format!("results/fig5_bench_{}.csv", kind.as_str());
        trace.write_csv(&path).ok();
        scatter.push((
            kind.as_str(),
            trace.total_sim_ms(),
            trace.final_accuracy().unwrap_or(f64::NAN),
        ));
    }

    bench::header("Fig. 1 — accuracy vs overhead time (same runs)");
    for (name, ms, acc) in &scatter {
        println!("{:<12} time {:>9.1} ms   acc {:.2}%", name, ms, acc * 100.0);
    }
    // The paper's claim: ours sits at the lowest time with accuracy
    // within the pack.
    let ours = scatter.iter().find(|(n, _, _)| *n == "multigraph").unwrap();
    let ring = scatter.iter().find(|(n, _, _)| *n == "ring").unwrap();
    assert!(ours.1 < ring.1, "multigraph must finish faster than ring");
    println!(
        "\nmultigraph vs ring: {:.2}x faster at {:+.2} accuracy points",
        ring.1 / ours.1,
        (ours.2 - ring.2) * 100.0
    );
}
