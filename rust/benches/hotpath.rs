//! Bench: the L3 hot paths — aggregation backends (compiled Pallas
//! kernel vs native SIMD-ish loop), PJRT step latencies, topology plan
//! generation, and the Eq. 4 delay tracker. This is the §Perf
//! before/after instrument (EXPERIMENTS.md).

use mgfl::data::SyntheticTask;
use mgfl::fl::Partition;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::runtime::{aggregate_native, ModelRuntime};
use mgfl::simtime::DelayTracker;
use mgfl::topo::{MultigraphTopology, TopologyDesign};
use mgfl::util::{bench, Rng64};

fn main() {
    // --- pure-rust paths (always available) ---
    bench::header("topology + delay hot loop (no PJRT)");
    let prof = DatasetProfile::femnist();
    let net = zoo::ebone(); // largest network, 87 silos

    bench::bench("christofides ring, ebone (87 nodes)", 2, 20, || {
        let conn = net.connectivity_graph(&prof);
        std::hint::black_box(mgfl::graph::ring_overlay(&conn).edges().len());
    });

    let mut topo = MultigraphTopology::from_network(&net, &prof, 5);
    bench::bench("plan() x1000 rounds, ebone", 2, 20, || {
        let mut acc = 0usize;
        for k in 0..1000 {
            acc += topo.plan(k).edges.len();
        }
        std::hint::black_box(acc);
    });

    bench::bench("DelayTracker.step x1000 rounds, ebone", 2, 20, || {
        let mut tracker = DelayTracker::new(&net, &prof);
        let mut acc = 0.0;
        for k in 0..1000 {
            acc += tracker.step(&topo.plan(k)).cycle_ms;
        }
        std::hint::black_box(acc);
    });

    // --- aggregation backends ---
    bench::header("aggregation backends (K=8 neighbours)");
    let p_count = 1_138_528; // femnist_cnn size
    let models_owned: Vec<Vec<f32>> = (0..8)
        .map(|i| {
            let mut rng = Rng64::seed_from_u64(i);
            (0..p_count).map(|_| rng.gen_f32()).collect()
        })
        .collect();
    let models: Vec<&[f32]> = models_owned.iter().map(|m| m.as_slice()).collect();
    let weights = vec![0.125f32; 8];

    bench::bench("native rust loop, P=1.14M K=8", 2, 20, || {
        std::hint::black_box(aggregate_native(&weights, &models).len());
    });

    if !mgfl::runtime::artifacts_available() {
        println!("artifacts/ missing — skipping PJRT benches (run `make artifacts`)");
        return;
    }

    let rt = ModelRuntime::load_default("femnist_cnn").expect("load cnn");
    bench::bench("PJRT pallas agg kernel, P=1.14M K=8 (incl. marshal)", 1, 10, || {
        std::hint::black_box(rt.aggregate(&weights, &models).unwrap().len());
    });

    // --- PJRT step latencies (the real per-round cost) ---
    bench::header("PJRT step latencies");
    let task = SyntheticTask::image(rt.entry.input_len(), rt.entry.num_classes, 7);
    let part = Partition::iid(1, rt.entry.num_classes);
    let mut rng = Rng64::seed_from_u64(0);
    let batch = task.batch(&part, 0, rt.entry.train_batch, &mut rng);
    let params = rt.init_params(0).unwrap();

    bench::bench("femnist_cnn train_step (B=32)", 1, 8, || {
        std::hint::black_box(rt.train_step(&params, &batch, 0.05).unwrap().1);
    });
    let ebatch = task.eval_batch(rt.entry.eval_batch, &mut rng);
    bench::bench("femnist_cnn eval_step (B=64)", 1, 8, || {
        std::hint::black_box(rt.eval_step(&params, &ebatch).unwrap().0);
    });

    let mlp = ModelRuntime::load_default("femnist_mlp").expect("load mlp");
    let mtask = SyntheticTask::image(mlp.entry.input_len(), mlp.entry.num_classes, 7);
    let mbatch = mtask.batch(&part, 0, mlp.entry.train_batch, &mut rng);
    let mparams = mlp.init_params(0).unwrap();
    bench::bench("femnist_mlp train_step (B=32)", 1, 20, || {
        std::hint::black_box(mlp.train_step(&mparams, &mbatch, 0.05).unwrap().1);
    });
}
