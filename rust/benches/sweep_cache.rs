//! Bench: the sweep engine's cross-cell memoization layer vs the
//! pre-cache engine, on a seed-replicated paper grid.
//!
//! Three jobs in one binary:
//!
//! 1. **Identity gate** — the memoized scheduler (fingerprint dedup +
//!    shared-construction cache) must produce JSON/CSV artifacts
//!    byte-identical to the pre-cache engine (`dedup: false`), on 1 and
//!    4 threads, while simulating only the unique cells (6 deterministic
//!    designs + one cell per stochastic MATCHA seed). Aborts (failing
//!    CI) on any disagreement.
//! 2. **Dedup bar** — cells/sec with memoization on vs off, measured
//!    single-threaded at a construction-bound round count
//!    (`min(rounds, 100)`). This is the regime the cache layer targets:
//!    per-cell cost dominated by topology construction + compilation,
//!    which dedup collapses across the seed axis. The ≥ 3× acceptance
//!    bar is asserted on full runs (`--rounds` ≥ 6400, like simcore's
//!    5× gate); smoke runs print the measured ratio without a timing
//!    assert a loaded CI runner could flake.
//! 3. **Full-depth measurement** — the same grid at `--rounds` (default
//!    6400, the paper's setting). Recorded, not asserted: at full depth
//!    the 8 stochastic MATCHA cells must still stream all their rounds
//!    (they are irreducible by design — distinct seeds are never
//!    merged), while the 48 deterministic cells are already nearly free
//!    after PR 2's cycle replay, so the end-to-end ratio converges
//!    toward the stochastic floor. The JSON records both numbers.
//!
//! Run: `cargo bench --bench sweep_cache` (refreshes
//! `BENCH_sweep_cache.json`); CI smoke: `-- --rounds 120`.

use std::collections::BTreeMap;

use mgfl::config::TopologyKind;
use mgfl::sweep::{self, RunOptions, SweepSpec};
use mgfl::util::args::Args;
use mgfl::util::bench;
use mgfl::util::json::Json;

/// The acceptance grid: 7 topologies × gaia × femnist × 1 t × 8 seeds.
fn grid(rounds: usize) -> SweepSpec {
    SweepSpec {
        name: "sweep_cache".into(),
        topologies: TopologyKind::all().to_vec(),
        networks: vec!["gaia".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![5],
        seeds: (17..25).collect(),
        rounds,
        scenario: None,
        adapt: Vec::new(),
    }
}

fn opts(threads: usize, dedup: bool) -> RunOptions {
    RunOptions { threads, progress: false, dedup }
}

/// Measure grid cells/sec for one engine configuration.
fn throughput(label: &str, spec: &SweepSpec, dedup: bool) -> (f64, f64) {
    let cells = spec.cell_count() as f64;
    let m = bench::bench(label, 1, 5, || {
        let outcome = sweep::run(spec, &opts(1, dedup)).expect("sweep run");
        std::hint::black_box(outcome.report.cells.len());
    });
    (m.mean_ms, cells / (m.mean_ms / 1e3))
}

fn main() {
    let args = Args::from_env();
    let rounds: usize = args.get("rounds", 6400).expect("--rounds takes an integer");
    let out = args.get_str("out", "BENCH_sweep_cache.json");

    // --- 1. identity gate -------------------------------------------
    let gate_rounds = rounds.min(200);
    bench::header(&format!(
        "sweep_cache identity gate — memoized vs pre-cache engine, {gate_rounds} rounds"
    ));
    let gate = grid(gate_rounds);
    let reference = sweep::run(&gate, &opts(1, false)).expect("reference sweep");
    assert_eq!(reference.unique_cells, gate.cell_count());
    let ref_json = reference.report.to_json().to_string();
    let ref_csv = reference.report.to_csv();
    let mut unique_cells = 0usize;
    for threads in [1usize, 4] {
        let memo = sweep::run(&gate, &opts(threads, true)).expect("memoized sweep");
        assert_eq!(
            memo.report.to_json().to_string(),
            ref_json,
            "memoized JSON must be byte-identical to the pre-cache engine (threads={threads})"
        );
        assert_eq!(
            memo.report.to_csv(),
            ref_csv,
            "memoized CSV must be byte-identical to the pre-cache engine (threads={threads})"
        );
        unique_cells = memo.unique_cells;
    }
    let total_cells = gate.cell_count();
    assert_eq!(unique_cells, 6 + 8, "expected 6 deterministic designs + 8 MATCHA seeds");
    let dedup_ratio = total_cells as f64 / unique_cells as f64;
    println!(
        "{total_cells} cells -> {unique_cells} unique ({dedup_ratio:.2}x dedup), \
         artifacts byte-identical across engines and thread counts"
    );

    // --- 2. dedup bar (construction-bound regime) -------------------
    let bar_rounds = rounds.min(100);
    bench::header(&format!(
        "dedup throughput bar — {total_cells}-cell grid, {bar_rounds} rounds, 1 thread"
    ));
    let bar = grid(bar_rounds);
    let (base_ms, base_cps) = throughput("pre-cache engine  (dedup off)", &bar, false);
    let (memo_ms, memo_cps) = throughput("memoized scheduler (dedup on)", &bar, true);
    let bar_speedup = base_ms / memo_ms.max(1e-9);
    println!(
        "cells/sec: {base_cps:.0} -> {memo_cps:.0} | speedup {bar_speedup:.2}x \
         (bar: >= 3x on the seed-replicated grid)"
    );
    // Like simcore's 5x gate, the wall-clock bar is asserted on full
    // runs only — CI smoke invocations (small --rounds) check the
    // byte-identity and unique-cell invariants above without a timing
    // assert that a loaded shared runner could flake.
    if rounds >= 6400 {
        assert!(
            bar_speedup >= 3.0,
            "acceptance: memoized sweep must be >= 3x cells/sec on the seed-replicated \
             Gaia grid (got {bar_speedup:.2}x)"
        );
    } else {
        println!("(>= 3x bar asserted on full runs; this is a smoke run at {rounds} rounds)");
    }

    // --- 3. full-depth measurement ----------------------------------
    let (full, full_speedup) = if rounds > bar_rounds {
        bench::header(&format!(
            "full-depth measurement — {total_cells}-cell grid, {rounds} rounds, 1 thread"
        ));
        let deep = grid(rounds);
        let (b_ms, b_cps) = throughput("pre-cache engine  (dedup off)", &deep, false);
        let (m_ms, m_cps) = throughput("memoized scheduler (dedup on)", &deep, true);
        let speedup = b_ms / m_ms.max(1e-9);
        println!(
            "cells/sec: {b_cps:.0} -> {m_cps:.0} | speedup {speedup:.2}x \
             (stochastic MATCHA cells are irreducible at depth; recorded, not asserted)"
        );
        (Some((b_ms, m_ms, b_cps, m_cps)), speedup)
    } else {
        (None, bar_speedup)
    };

    // --- 4. baseline artifact ---------------------------------------
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("sweep_cache".into()));
    obj.insert(
        "provenance".to_string(),
        Json::Str(
            "measured by `cargo bench --bench sweep_cache` (identity gate and >= 3x \
             dedup bar passed first)"
                .into(),
        ),
    );
    // A full measured run (>= 3x bar asserted, full-depth pass taken)
    // leaves no nulls in this artifact; anything else says so.
    obj.insert("measured".to_string(), Json::Bool(rounds >= 6400 && full.is_some()));
    obj.insert("rounds".to_string(), Json::Num(rounds as f64));
    obj.insert("total_cells".to_string(), Json::Num(total_cells as f64));
    obj.insert("unique_cells".to_string(), Json::Num(unique_cells as f64));
    obj.insert("dedup_ratio".to_string(), Json::Num(dedup_ratio));
    obj.insert("artifacts_byte_identical".to_string(), Json::Bool(true));
    obj.insert(
        "construction_bound".to_string(),
        Json::Obj(BTreeMap::from([
            ("rounds".to_string(), Json::Num(bar_rounds as f64)),
            ("precache_ms_per_sweep".to_string(), Json::Num(base_ms)),
            ("memoized_ms_per_sweep".to_string(), Json::Num(memo_ms)),
            ("precache_cells_per_sec".to_string(), Json::Num(base_cps)),
            ("memoized_cells_per_sec".to_string(), Json::Num(memo_cps)),
            ("speedup".to_string(), Json::Num(bar_speedup)),
        ])),
    );
    obj.insert(
        "full_depth".to_string(),
        match full {
            Some((b_ms, m_ms, b_cps, m_cps)) => Json::Obj(BTreeMap::from([
                ("rounds".to_string(), Json::Num(rounds as f64)),
                ("precache_ms_per_sweep".to_string(), Json::Num(b_ms)),
                ("memoized_ms_per_sweep".to_string(), Json::Num(m_ms)),
                ("precache_cells_per_sec".to_string(), Json::Num(b_cps)),
                ("memoized_cells_per_sec".to_string(), Json::Num(m_cps)),
                ("speedup".to_string(), Json::Num(full_speedup)),
            ])),
            None => Json::Null,
        },
    );
    let json = Json::Obj(obj).to_string();
    std::fs::write(&out, format!("{json}\n")).expect("writing bench baseline");
    println!("\nbaseline -> {out}");
}
