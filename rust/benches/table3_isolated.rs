//! Bench: regenerate paper Table 3 — isolated-node effectiveness per
//! network (FEMNIST, t = 5): silo count, rounds/states with isolated
//! nodes, and multigraph vs RING cycle time.

use mgfl::metrics::render_table;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::simulate;
use mgfl::topo::{ring::RingTopology, MultigraphTopology};
use mgfl::util::bench;

fn main() {
    let rounds: usize = std::env::var("MGFL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6400);
    bench::header(&format!("Table 3 — isolated nodes (FEMNIST, {rounds} rounds, t=5)"));

    let prof = DatasetProfile::femnist();
    let mut rows = Vec::new();
    for net in zoo::all_networks() {
        let topo = MultigraphTopology::from_network(&net, &prof, 5);
        let s_max = topo.s_max();
        let iso_states = topo.states_with_isolated(10_000).len();
        let mut ours = MultigraphTopology::from_network(&net, &prof, 5);
        let res = simulate(&mut ours, &net, &prof, rounds);
        let mut ring = RingTopology::new(&net, &prof);
        let ring_res = simulate(&mut ring, &net, &prof, rounds);
        rows.push(vec![
            net.name.clone(),
            format!("{}", net.n()),
            format!("{}/{}", res.rounds_with_isolated, rounds),
            format!(
                "{}/{} ({:.1}%)",
                iso_states,
                s_max,
                100.0 * iso_states as f64 / s_max as f64
            ),
            format!(
                "{:.1} (v{:.1})",
                res.mean_cycle_ms,
                ring_res.mean_cycle_ms / res.mean_cycle_ms
            ),
        ]);
    }
    print!(
        "{}",
        render_table(&["network", "silos", "#rounds", "#states", "cycle ms (vs ring)"], &rows)
    );
    println!(
        "\npaper reference: gaia 4693/6400, 44/60 (73.3%) | amazon 2133/6400, 2/6 (33.3%) |\n\
         geant 4266/6400, 8/12 (66.7%) | exodus 3306/6400, 31/60 (51.7%) | ebone 2346/6400, 11/30 (36.7%)"
    );

    // State-analysis throughput.
    bench::header("state parsing throughput");
    let net = zoo::ebone();
    bench::bench("states_with_isolated ebone (full period)", 2, 10, || {
        let topo = MultigraphTopology::from_network(&net, &prof, 5);
        std::hint::black_box(topo.states_with_isolated(10_000).len());
    });
}
