//! Bench: the compiled zero-allocation simulation engine vs the naive
//! `DelayTracker` reference path.
//!
//! Two jobs in one binary:
//!
//! 1. **Oracle gate** — for every (topology × network) cell of the smoke
//!    grid (FEMNIST profile), assert the compiled `simulate_summary` is
//!    bit-identical to `simulate_summary_naive`. CI runs this at small
//!    rounds and fails the build on any disagreement.
//! 2. **Headline measurement** — time both engines on the paper's
//!    6400-round Gaia/FEMNIST multigraph (t = 5) cell plus a large-
//!    network streaming cell, and write the numbers to
//!    `BENCH_simcore.json` (the committed baseline).
//!
//! Run: `cargo bench --bench simcore -- --rounds 6400 --out BENCH_simcore.json`
//! (CI smoke: `cargo bench --bench simcore -- --rounds 200`.)

use std::collections::BTreeMap;

use mgfl::config::{ExperimentConfig, TopologyKind};
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::{
    simulate_summary, simulate_summary_compiled_with_stats, simulate_summary_naive,
};
use mgfl::topo::MultigraphTopology;
use mgfl::util::args::Args;
use mgfl::util::bench;
use mgfl::util::json::Json;

fn cell_config(kind: TopologyKind, network: &str, t: u32, rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        network: network.into(),
        profile: "femnist".into(),
        topology: kind,
        t,
        sim_rounds: rounds,
        ..Default::default()
    }
}

/// Assert compiled == naive bitwise on one cell.
fn check_cell(kind: TopologyKind, network: &str, t: u32, rounds: usize) {
    let cfg = cell_config(kind, network, t, rounds);
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("profile");
    let mut a = cfg.build_topology();
    let mut b = cfg.build_topology();
    let naive = simulate_summary_naive(a.as_mut(), &net, &prof, rounds);
    let fast = simulate_summary(b.as_mut(), &net, &prof, rounds);
    assert_eq!(
        naive.total_ms.to_bits(),
        fast.total_ms.to_bits(),
        "compiled/naive total_ms disagree on {}/{network} (naive {} vs compiled {})",
        kind.as_str(),
        naive.total_ms,
        fast.total_ms,
    );
    assert_eq!(naive.mean_cycle_ms.to_bits(), fast.mean_cycle_ms.to_bits());
    assert_eq!(naive.rounds_with_isolated, fast.rounds_with_isolated);
    assert_eq!(naive.max_isolated, fast.max_isolated);
}

fn main() {
    let args = Args::from_env();
    let rounds: usize = args.get("rounds", 6400).expect("--rounds takes an integer");
    let out = args.get_str("out", "BENCH_simcore.json");
    let smoke_rounds = rounds.min(200);

    // --- 1. oracle gate ---------------------------------------------
    bench::header(&format!(
        "simcore oracle gate — compiled vs naive, {smoke_rounds} rounds, all designs x networks"
    ));
    let mut checked = 0usize;
    for net in zoo::all_networks() {
        for kind in TopologyKind::all() {
            check_cell(kind, &net.name, 5, smoke_rounds);
            checked += 1;
        }
    }
    println!("{checked} cells bit-identical across engines");

    // --- 2. headline: the paper's Gaia/FEMNIST multigraph cell ------
    bench::header(&format!("compiled engine throughput — {rounds} rounds (paper: 6400)"));
    let gaia = zoo::gaia();
    let prof = DatasetProfile::femnist();

    let naive_m = bench::bench(&format!("naive   multigraph gaia x{rounds}"), 2, 10, || {
        let mut topo = MultigraphTopology::from_network(&gaia, &prof, 5);
        let s = simulate_summary_naive(&mut topo, &gaia, &prof, rounds);
        std::hint::black_box(s.total_ms);
    });
    let compiled_m = bench::bench(&format!("compiled multigraph gaia x{rounds}"), 2, 10, || {
        let mut topo = MultigraphTopology::from_network(&gaia, &prof, 5);
        let s = simulate_summary(&mut topo, &gaia, &prof, rounds);
        std::hint::black_box(s.total_ms);
    });
    let speedup = naive_m.mean_ms / compiled_m.mean_ms.max(1e-9);

    let mut topo = MultigraphTopology::from_network(&gaia, &prof, 5);
    let (_, stats) = simulate_summary_compiled_with_stats(&mut topo, &gaia, &prof, rounds);
    println!(
        "cycle fast path: simulated {} of {rounds} rounds (period {:?}, cycle len {:?}) \
         | speedup {speedup:.1}x",
        stats.simulated_rounds, stats.period, stats.cycle_len,
    );
    // Note: both timed closures rebuild the topology (Alg. 1 + 2), so
    // the measured speedup understates the pure per-round win.
    if rounds >= 6400 {
        assert!(
            speedup >= 5.0,
            "acceptance: compiled path must be >= 5x on the 6400-round \
             Gaia/FEMNIST cell (got {speedup:.2}x)"
        );
    }

    // --- 3. streaming engine on the largest network ------------------
    bench::header("streaming engine (stochastic / unmaterializable periods), ebone");
    let ebone = zoo::ebone();
    let stream_rounds = rounds.min(1000);
    let naive_s = bench::bench(&format!("naive   matcha ebone x{stream_rounds}"), 2, 10, || {
        let cfg = cell_config(TopologyKind::Matcha, "ebone", 5, stream_rounds);
        let mut topo = cfg.build_topology();
        let s = simulate_summary_naive(topo.as_mut(), &ebone, &prof, stream_rounds);
        std::hint::black_box(s.total_ms);
    });
    let compiled_s = bench::bench(&format!("compiled matcha ebone x{stream_rounds}"), 2, 10, || {
        let cfg = cell_config(TopologyKind::Matcha, "ebone", 5, stream_rounds);
        let mut topo = cfg.build_topology();
        let s = simulate_summary(topo.as_mut(), &ebone, &prof, stream_rounds);
        std::hint::black_box(s.total_ms);
    });
    let stream_speedup = naive_s.mean_ms / compiled_s.mean_ms.max(1e-9);

    // --- 4. baseline artifact ----------------------------------------
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("simcore".into()));
    let provenance = "measured by `cargo bench --bench simcore` (oracle gate passed first)";
    obj.insert("provenance".to_string(), Json::Str(provenance.into()));
    // A full measured run (>= 5x bar asserted, cycle fast path hit)
    // leaves no nulls in this artifact; anything else says so.
    obj.insert(
        "measured".to_string(),
        Json::Bool(rounds >= 6400 && stats.cycle_len.is_some()),
    );
    obj.insert("rounds".to_string(), Json::Num(rounds as f64));
    obj.insert("oracle_cells_checked".to_string(), Json::Num(checked as f64));
    obj.insert("oracle_bit_identical".to_string(), Json::Bool(true));
    obj.insert(
        "gaia_multigraph".to_string(),
        Json::Obj(BTreeMap::from([
            ("naive_ms_per_cell".to_string(), Json::Num(naive_m.mean_ms)),
            ("compiled_ms_per_cell".to_string(), Json::Num(compiled_m.mean_ms)),
            ("speedup".to_string(), Json::Num(speedup)),
            ("simulated_rounds".to_string(), Json::Num(stats.simulated_rounds as f64)),
            ("cycle_len".to_string(), stats.cycle_len.map_or(Json::Null, |l| Json::Num(l as f64))),
        ])),
    );
    obj.insert(
        "ebone_matcha_streaming".to_string(),
        Json::Obj(BTreeMap::from([
            ("rounds".to_string(), Json::Num(stream_rounds as f64)),
            ("naive_ms_per_cell".to_string(), Json::Num(naive_s.mean_ms)),
            ("compiled_ms_per_cell".to_string(), Json::Num(compiled_s.mean_ms)),
            ("speedup".to_string(), Json::Num(stream_speedup)),
        ])),
    );
    let json = Json::Obj(obj).to_string();
    std::fs::write(&out, format!("{json}\n")).expect("writing bench baseline");
    println!("\nbaseline -> {out}");
}
