//! Bench: large-N topology construction — the dense-graph builders vs
//! the pre-overhaul sparse reference, on synthetic silo networks.
//!
//! Three jobs in one binary:
//!
//! 1. **Zoo identity gate** — on every paper network
//!    (Gaia/Amazon/Géant/Exodus/Ebone), each of the six constructions
//!    (STAR, MATCHA core, MST, δ-MBST, RING, multigraph) built on the
//!    dense path must produce an overlay byte-identical to the
//!    pre-overhaul sparse builder, and emit identical round plans
//!    (same-seed MATCHA included). Aborts (failing CI) on any
//!    disagreement.
//! 2. **Synthetic oracle gate** — on a synthetic network at the
//!    smallest requested size, compiled-engine simulations of
//!    dense-built designs must match the naive `DelayTracker` oracle
//!    bitwise: the large-N axis gets the same bit-identity contract the
//!    paper zoo has.
//! 3. **Construction throughput** — for each N in `--n` (default
//!    64,256,1024,4096): wall-clock to build all six designs on the
//!    dense path; the sparse reference is measured up to N = 1024 (its
//!    O(N³) matching makes 4096 pointless) and the ≥ 5× bar is
//!    asserted whenever N = 1024 is measured — i.e. on full runs; the
//!    CI smoke (`-- --n 128`) runs the gates only.
//!
//! Run: `cargo bench --bench scaling` (refreshes `BENCH_scaling.json`);
//! CI smoke: `-- --n 128`.

use std::collections::BTreeMap;

use mgfl::config::TopologyKind;
use mgfl::graph::Graph;
use mgfl::net::synth::{self, SynthVariant};
use mgfl::net::{zoo, DatasetProfile, NetworkSpec};
use mgfl::simtime::{simulate_summary, simulate_summary_naive};
use mgfl::topo::delta_mbst::{DeltaMbstTopology, DEFAULT_DELTA};
use mgfl::topo::matcha::{MatchaCore, MatchaTopology, DEFAULT_BUDGET};
use mgfl::topo::mst::MstTopology;
use mgfl::topo::ring::RingTopology;
use mgfl::topo::star::StarTopology;
use mgfl::topo::{MultigraphTopology, TopologyDesign};
use mgfl::util::args::Args;
use mgfl::util::bench;
use mgfl::util::json::Json;

const T: u32 = 5;
const SEED: u64 = 17;

/// The six distinct constructions (MATCHA+ shares MATCHA's), production
/// (dense) path — built through the same [`mgfl::config::build_design`]
/// dispatch sweeps use, so the bench cannot time a different
/// construction than production runs.
const SIX_KINDS: [TopologyKind; 6] = [
    TopologyKind::Star,
    TopologyKind::Matcha,
    TopologyKind::Mst,
    TopologyKind::DeltaMbst,
    TopologyKind::Ring,
    TopologyKind::Multigraph,
];

fn build_dense(net: &NetworkSpec, prof: &DatasetProfile) -> Vec<Box<dyn TopologyDesign>> {
    SIX_KINDS
        .iter()
        .map(|&kind| mgfl::config::build_design(kind, net, prof, T, SEED))
        .collect()
}

/// The same six constructions on the pre-overhaul sparse path.
fn build_reference(net: &NetworkSpec, prof: &DatasetProfile) -> Vec<Box<dyn TopologyDesign>> {
    vec![
        Box::new(StarTopology::new_reference(net, prof)),
        Box::new(MatchaTopology::from_core(
            std::sync::Arc::new(MatchaCore::build_reference(net, prof)),
            DEFAULT_BUDGET,
            SEED,
        )),
        Box::new(MstTopology::new_reference(net, prof)),
        Box::new(DeltaMbstTopology::new_reference(net, prof, DEFAULT_DELTA)),
        Box::new(RingTopology::new_reference(net, prof)),
        Box::new(MultigraphTopology::from_network_reference(net, prof, T)),
    ]
}

fn assert_overlays_identical(a: &Graph, b: &Graph, ctx: &str) {
    assert_eq!(a.edges().len(), b.edges().len(), "{ctx}: overlay edge count differs");
    for (x, y) in a.edges().iter().zip(b.edges()) {
        assert_eq!(
            (x.u, x.v, x.w.to_bits()),
            (y.u, y.v, y.w.to_bits()),
            "{ctx}: overlay edge differs"
        );
    }
}

fn main() {
    let args = Args::from_env();
    let sizes: Vec<usize> = args
        .get_parsed_list::<usize>("n")
        .expect("--n takes comma-separated silo counts")
        .unwrap_or_else(|| vec![64, 256, 1024, 4096]);
    assert!(!sizes.is_empty(), "--n must list at least one size");
    let variant_s = args.get_str("variant", "geo");
    let variant = SynthVariant::parse(&variant_s).expect("--variant geo|sphere");
    let out = args.get_str("out", "BENCH_scaling.json");
    let prof = DatasetProfile::femnist();

    // --- 1. zoo identity gate ---------------------------------------
    bench::header("scaling identity gate — dense builders vs sparse reference, paper zoo");
    let mut overlays_checked = 0usize;
    for net in zoo::all_networks() {
        let mut dense = build_dense(&net, &prof);
        let mut reference = build_reference(&net, &prof);
        assert_eq!(dense.len(), reference.len());
        for (d, r) in dense.iter_mut().zip(reference.iter_mut()) {
            let ctx = format!("{}/{}", net.name, d.name());
            assert_eq!(d.name(), r.name(), "{ctx}: design name");
            assert_overlays_identical(d.overlay(), r.overlay(), &ctx);
            for k in 0..6 {
                assert_eq!(d.plan(k).edges, r.plan(k).edges, "{ctx}: round {k} plan differs");
            }
            overlays_checked += 1;
        }
    }
    println!(
        "{overlays_checked} overlays byte-identical (6 designs x 5 networks), \
         round plans identical through round 5"
    );

    // --- 2. synthetic oracle gate -----------------------------------
    let oracle_n = *sizes.iter().min().unwrap();
    let oracle_name = synth::name_of(variant, oracle_n, 7);
    bench::header(&format!(
        "synthetic oracle gate — compiled vs naive simulation on {oracle_name}"
    ));
    let synth_net = synth::by_name(&oracle_name).expect("synthetic size in range");
    let oracle_rounds = 120;
    let mut oracle_cells = 0usize;
    for kind in [
        TopologyKind::Star,
        TopologyKind::Matcha,
        TopologyKind::Ring,
        TopologyKind::Multigraph,
    ] {
        let mut a = mgfl::config::build_design(kind, &synth_net, &prof, T, SEED);
        let mut b = mgfl::config::build_design(kind, &synth_net, &prof, T, SEED);
        let fast = simulate_summary(a.as_mut(), &synth_net, &prof, oracle_rounds);
        let naive = simulate_summary_naive(b.as_mut(), &synth_net, &prof, oracle_rounds);
        assert_eq!(
            fast.total_ms.to_bits(),
            naive.total_ms.to_bits(),
            "{}: compiled engine diverged from the naive oracle on {oracle_name}",
            fast.topology
        );
        assert_eq!(fast.mean_cycle_ms.to_bits(), naive.mean_cycle_ms.to_bits());
        assert_eq!(fast.rounds_with_isolated, naive.rounds_with_isolated);
        assert_eq!(fast.max_isolated, naive.max_isolated);
        oracle_cells += 1;
    }
    println!("{oracle_cells} synthetic cells bit-identical to the oracle ({oracle_rounds} rounds)");

    // --- 3. construction throughput ---------------------------------
    // The sparse reference is only measured where it is tractable; the
    // acceptance bar lives at N = 1024.
    const REFERENCE_CAP: usize = 1024;
    const BAR_N: usize = 1024;
    const BAR: f64 = 5.0;
    let mut per_n: Vec<(usize, f64, Option<f64>)> = Vec::new(); // (n, dense_ms, ref_ms)
    let mut bar_speedup: Option<f64> = None;
    for &n in &sizes {
        bench::header(&format!(
            "construction throughput — all six designs, synth-{}-n{n}-s7",
            variant.as_str()
        ));
        let net = synth::by_name(&synth::name_of(variant, n, 7)).expect("size in range");
        let (warmup, iters) = if n >= 2048 {
            (0, 1)
        } else if n >= 512 {
            (0, 2)
        } else {
            (1, 3)
        };
        let dense_m = bench::bench(&format!("dense builders     N={n}"), warmup, iters, || {
            std::hint::black_box(build_dense(&net, &prof).len());
        });
        let ref_ms = if n <= REFERENCE_CAP {
            let ref_m =
                bench::bench(&format!("sparse reference   N={n}"), warmup, iters, || {
                    std::hint::black_box(build_reference(&net, &prof).len());
                });
            let speedup = ref_m.mean_ms / dense_m.mean_ms.max(1e-9);
            println!("speedup {speedup:.2}x (reference / dense, six-design build)");
            if n == BAR_N {
                bar_speedup = Some(speedup);
            }
            Some(ref_m.mean_ms)
        } else {
            println!("(sparse reference skipped above N={REFERENCE_CAP}: O(N^3) matching)");
            None
        };
        per_n.push((n, dense_m.mean_ms, ref_ms));
    }
    if let Some(speedup) = bar_speedup {
        assert!(
            speedup >= BAR,
            "acceptance: dense construction must be >= {BAR}x the pre-overhaul baseline at \
             N={BAR_N} (got {speedup:.2}x)"
        );
        println!("\n>= {BAR}x construction bar at N={BAR_N}: PASS ({speedup:.2}x)");
    } else {
        println!("\n(>= {BAR}x bar asserts when N={BAR_N} is measured; this run swept {sizes:?})");
    }

    // --- 4. baseline artifact ---------------------------------------
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("scaling".into()));
    obj.insert(
        "provenance".to_string(),
        Json::Str(
            "measured by `cargo bench --bench scaling` (zoo identity gate, synthetic \
             oracle gate, and the >= 5x N=1024 construction bar passed first)"
                .into(),
        ),
    );
    // A full measured run (bar asserted, reference measured at every
    // size) leaves no nulls in this artifact; anything else says so.
    let measured = bar_speedup.is_some() && per_n.iter().all(|&(_, _, r)| r.is_some());
    obj.insert("measured".to_string(), Json::Bool(measured));
    obj.insert("variant".to_string(), Json::Str(variant.as_str().into()));
    obj.insert("overlays_checked".to_string(), Json::Num(overlays_checked as f64));
    obj.insert("oracle_cells_checked".to_string(), Json::Num(oracle_cells as f64));
    obj.insert("identity_gates_passed".to_string(), Json::Bool(true));
    obj.insert(
        "bar_speedup_n1024".to_string(),
        bar_speedup.map_or(Json::Null, Json::Num),
    );
    let cells: Vec<Json> = per_n
        .iter()
        .map(|&(n, dense_ms, ref_ms)| {
            let mut m = BTreeMap::new();
            m.insert("n".to_string(), Json::Num(n as f64));
            m.insert("dense_ms_six_designs".to_string(), Json::Num(dense_ms));
            m.insert(
                "reference_ms_six_designs".to_string(),
                ref_ms.map_or(Json::Null, Json::Num),
            );
            m.insert(
                "speedup".to_string(),
                ref_ms.map_or(Json::Null, |r| Json::Num(r / dense_ms.max(1e-9))),
            );
            Json::Obj(m)
        })
        .collect();
    obj.insert("sizes".to_string(), Json::Arr(cells));
    let json = Json::Obj(obj).to_string();
    std::fs::write(&out, format!("{json}\n")).expect("writing bench baseline");
    println!("\nbaseline -> {out}");
}
