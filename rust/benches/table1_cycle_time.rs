//! Bench: regenerate paper Table 1 — cycle time (ms) for every topology
//! x network x dataset at 6400 rounds — and time the simulator itself.
//!
//! Run: `cargo bench --bench table1_cycle_time`
//! Override rounds: `MGFL_BENCH_ROUNDS=640 cargo bench ...`

use mgfl::metrics::render_table;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::simulate;
use mgfl::util::bench;

fn rounds() -> usize {
    std::env::var("MGFL_BENCH_ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(6400)
}

fn main() {
    let rounds = rounds();
    bench::header(&format!("Table 1 — cycle time, {rounds} rounds (paper: 6400)"));

    for prof in DatasetProfile::all() {
        let mut rows = Vec::new();
        for net in zoo::all_networks() {
            let mut row = vec![net.name.clone()];
            let mut ring = f64::NAN;
            for mut topo in mgfl::all_topologies(&net, &prof, 5, 17) {
                let res = simulate(topo.as_mut(), &net, &prof, rounds);
                if topo.name() == "ring" {
                    ring = res.mean_cycle_ms;
                }
                row.push(format!("{:.1}", res.mean_cycle_ms));
            }
            let ours: f64 = row.last().unwrap().parse().unwrap();
            row.push(format!("(v{:.1})", ring / ours));
            rows.push(row);
        }
        println!("\n--- {} ---", prof.name);
        print!(
            "{}",
            render_table(
                &["network", "STAR", "MATCHA", "MATCHA+", "MST", "d-MBST", "RING", "OURS", "vsRING"],
                &rows
            )
        );
    }

    // Simulator throughput (the L3 hot loop without PJRT).
    bench::header("simulator throughput");
    let prof = DatasetProfile::femnist();
    for net in [zoo::gaia(), zoo::ebone()] {
        bench::bench(
            &format!("simulate multigraph {} x1000 rounds", net.name),
            2,
            10,
            || {
                let mut topo = mgfl::topo::MultigraphTopology::from_network(&net, &prof, 5);
                let res = simulate(&mut topo, &net, &prof, 1000);
                std::hint::black_box(res.mean_cycle_ms);
            },
        );
    }
}
