//! Bench: regenerate paper Table 1 — cycle time (ms) for every topology
//! x network x dataset — through the parallel sweep engine, report the
//! serial-vs-parallel wall-clock speedup, and time the simulator hot
//! loop.
//!
//! Run: `cargo bench --bench table1_cycle_time -- --rounds 50 --threads 0`
//! (`MGFL_BENCH_ROUNDS` is honored when no `--rounds` flag is given;
//! default 6400, the paper's setting.)

use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::simulate;
use mgfl::sweep::{self, Axis, RunOptions, SweepSpec};
use mgfl::util::args::Args;
use mgfl::util::bench;

fn env_rounds() -> usize {
    std::env::var("MGFL_BENCH_ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(6400)
}

fn main() {
    // `cargo bench` may forward a bare `--bench` flag; Args treats it as
    // an ignored boolean.
    let args = Args::from_env();
    let rounds: usize = args.get("rounds", env_rounds()).expect("--rounds takes an integer");
    let threads: usize = args.get("threads", 0).expect("--threads takes an integer");
    bench::header(&format!("Table 1 — cycle time, {rounds} rounds (paper: 6400)"));

    let profiles: Vec<String> = DatasetProfile::all().iter().map(|p| p.name.clone()).collect();
    let spec = SweepSpec::table1(profiles, 5, rounds);

    // Parallel sweep: the path `mgfl table1` takes.
    let par = sweep::run(&spec, &RunOptions { threads, ..Default::default() }).expect("sweep run");
    for prof in &spec.profiles {
        println!("\n--- {prof} ---");
        print!(
            "{}",
            par.report.render_slice(Axis::Network, Axis::Topology, |c| &c.profile == prof)
        );
    }

    // Serial reference over the identical grid: the engine's wall-clock
    // speedup is this bench's headline number, and byte-identical
    // artifacts across thread counts are re-checked for free.
    let ser = sweep::run(&spec, &RunOptions { threads: 1, ..Default::default() }).expect("sweep run");
    let identical = ser.report.to_json().to_string() == par.report.to_json().to_string();
    println!(
        "\nsweep engine: {} cells | serial {:.2} s | parallel {:.2} s on {} threads \
         | speedup {:.2}x | artifacts identical: {identical}",
        par.report.cells.len(),
        ser.host_elapsed_ms / 1e3,
        par.host_elapsed_ms / 1e3,
        par.threads,
        ser.host_elapsed_ms / par.host_elapsed_ms.max(1e-9),
    );
    assert!(identical, "sweep artifacts must not depend on thread count");

    // Simulator throughput (the L3 hot loop without PJRT).
    bench::header("simulator throughput");
    let prof = DatasetProfile::femnist();
    for net in [zoo::gaia(), zoo::ebone()] {
        bench::bench(
            &format!("simulate multigraph {} x1000 rounds", net.name),
            2,
            10,
            || {
                let mut topo = mgfl::topo::MultigraphTopology::from_network(&net, &prof, 5);
                let res = simulate(&mut topo, &net, &prof, 1000);
                std::hint::black_box(res.mean_cycle_ms);
            },
        );
    }
}
