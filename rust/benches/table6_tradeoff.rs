//! Bench: regenerate paper Table 6 — the cycle-time / accuracy trade-off
//! in t (max edges between two nodes) on Exodus + FEMNIST. Cycle time
//! must be non-increasing in t and saturate (paper: identical values for
//! t >= 8); t = 1 must equal RING exactly.

use mgfl::metrics::render_table;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::simulate;
use mgfl::topo::{ring::RingTopology, MultigraphTopology};
use mgfl::util::bench;

fn main() {
    let rounds: usize = std::env::var("MGFL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6400);
    bench::header(&format!("Table 6 — t sweep (Exodus, FEMNIST, {rounds} rounds)"));

    let net = zoo::exodus();
    let prof = DatasetProfile::femnist();

    let mut ring = RingTopology::new(&net, &prof);
    let ring_ms = simulate(&mut ring, &net, &prof, rounds).mean_cycle_ms;
    let mut rows = vec![vec!["RING".into(), "-".into(), format!("{ring_ms:.1}"), "-".into()]];

    let mut prev = f64::MAX;
    for t in [1u32, 3, 5, 8, 10, 20, 30] {
        let mut topo = MultigraphTopology::from_network(&net, &prof, t);
        let s_max = topo.s_max();
        let ms = simulate(&mut topo, &net, &prof, rounds).mean_cycle_ms;
        assert!(
            ms <= prev * 1.05,
            "cycle time must be ~non-increasing in t: t={t} gives {ms:.1} after {prev:.1}"
        );
        if t == 1 {
            assert!((ms - ring_ms).abs() < 1e-6, "t=1 must equal RING");
        }
        prev = ms;
        rows.push(vec![
            "Multigraph".into(),
            format!("{t}"),
            format!("{ms:.1}"),
            format!("{s_max}"),
        ]);
    }
    print!("{}", render_table(&["topology", "t", "cycle ms", "s_max"], &rows));
    println!(
        "\npaper reference: RING 24.7 | t=1 24.7 | t=3 13.5 | t=5 12.1 | t>=8 11.9 (saturation);\n\
         accuracy column via `mgfl table6 --train-rounds 30` (drops past t~5-8)."
    );

    bench::header("construction cost vs t");
    for t in [5u32, 30] {
        bench::bench(&format!("construct+parse exodus t={t}"), 2, 20, || {
            let topo = MultigraphTopology::from_network(&net, &prof, t);
            std::hint::black_box(topo.states_with_isolated(100).len());
        });
    }
}
