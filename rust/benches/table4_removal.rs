//! Bench: regenerate paper Table 4 — cycle time when silos are removed
//! from the RING overlay (randomly / most-inefficient) vs the
//! multigraph, on Exodus + FEMNIST. The paper's point: removal buys
//! cycle time but costs accuracy (Table 4's acc column comes from the
//! `mgfl table4 --train-rounds N` CLI, which runs real training);
//! the multigraph gets the cycle-time win without removing anyone.

use mgfl::graph::{christofides_cycle, Graph};
use mgfl::metrics::render_table;
use mgfl::net::{zoo, DatasetProfile, NetworkSpec};
use mgfl::simtime::simulate;
use mgfl::topo::{ring::RingTopology, MultigraphTopology, TopologyDesign};
use mgfl::util::{bench, Rng64};

/// Re-ring the retained silos (removed ones become degree-0 spectators).
fn remove_silos(
    net: &NetworkSpec,
    prof: &DatasetProfile,
    criterion: &str,
    count: usize,
) -> Graph {
    let base = RingTopology::new(net, prof);
    let overlay = base.overlay();
    let n = overlay.n();
    let victims: Vec<usize> = match criterion {
        "random" => {
            let mut rng = Rng64::seed_from_u64(99);
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            idx.into_iter().take(count).collect()
        }
        _ => {
            let mut scored: Vec<(f64, usize)> = (0..n)
                .map(|i| {
                    let worst = overlay
                        .neighbors(i)
                        .map(|(j, _)| mgfl::delay::eq3_delay_ms(net, prof, i, j, 2, 2))
                        .fold(0.0, f64::max);
                    (worst, i)
                })
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0));
            scored.into_iter().take(count).map(|(_, i)| i).collect()
        }
    };
    let keep: Vec<usize> = (0..n).filter(|i| !victims.contains(i)).collect();
    let conn = net.connectivity_graph(prof);
    let sub =
        Graph::complete(keep.len(), |a, b| conn.edge_weight(keep[a], keep[b]).unwrap());
    let cycle = christofides_cycle(&sub);
    let mut g = Graph::new(n);
    for w in 0..cycle.len() {
        let a = keep[cycle[w]];
        let b = keep[cycle[(w + 1) % cycle.len()]];
        g.add_edge(a, b, conn.edge_weight(a, b).unwrap());
    }
    g
}

fn main() {
    let rounds: usize = std::env::var("MGFL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6400);
    bench::header(&format!(
        "Table 4 — silo removal vs multigraph (Exodus, FEMNIST, {rounds} rounds)"
    ));

    let net = zoo::exodus();
    let prof = DatasetProfile::femnist();
    let mut rows = Vec::new();

    let mut base = RingTopology::new(&net, &prof);
    let base_ms = simulate(&mut base, &net, &prof, rounds).mean_cycle_ms;
    rows.push(vec!["RING baseline".into(), "-".into(), format!("{base_ms:.1}")]);

    for criterion in ["random", "inefficient"] {
        for removed in [1usize, 5, 10, 20] {
            let reduced = remove_silos(&net, &prof, criterion, removed);
            let mut topo = RingTopology::from_overlay(reduced);
            let ms = simulate(&mut topo, &net, &prof, rounds).mean_cycle_ms;
            rows.push(vec![
                format!("RING remove {criterion}"),
                format!("{removed}"),
                format!("{ms:.1}"),
            ]);
        }
    }

    let mut ours = MultigraphTopology::from_network(&net, &prof, 5);
    let ours_ms = simulate(&mut ours, &net, &prof, rounds).mean_cycle_ms;
    rows.push(vec!["Multigraph (ours)".into(), "-".into(), format!("{ours_ms:.1}")]);

    print!("{}", render_table(&["method", "#removed", "cycle ms"], &rows));
    println!(
        "\npaper reference (cycle/acc): baseline 24.7/71.05 | random-20 13.0/61.2 |\n\
         inefficient-20 11.2/61.48 | ours 12.1/71.13 — removal matches our cycle time\n\
         only at a ~10-point accuracy cost (run `mgfl table4 --train-rounds 30` for acc)."
    );

    bench::header("removal machinery");
    bench::bench("re-ring exodus minus 20 silos", 1, 10, || {
        std::hint::black_box(remove_silos(&net, &prof, "inefficient", 20).edges().len());
    });
}
