//! Bench: the persistent cell store's read-through sweep path — warm
//! runs must be byte-identical to cold runs and much faster.
//!
//! Three jobs in one binary:
//!
//! 1. **Identity gate** — a store-backed sweep (cold, then warm, on 1
//!    and 4 threads) must produce JSON/CSV artifacts byte-identical to
//!    a storeless run of the same grid, the cold pass must miss on
//!    every unique cell, and the warm passes must hit on every unique
//!    cell and simulate none. Aborts (failing CI) on any disagreement.
//! 2. **Warm-start bar** — wall-clock of the grid cold (empty store:
//!    simulate everything, write everything back) vs warm (every cell
//!    served from the log). The ≥ 5× acceptance bar is asserted on
//!    full runs (`--rounds` ≥ 6400); smoke runs print the measured
//!    ratio without a timing assert a loaded CI runner could flake.
//! 3. **Baseline artifact** — `BENCH_store.json`, with `measured`
//!    honest about whether this was a full run.
//!
//! Run: `cargo bench --bench store` (refreshes `BENCH_store.json`);
//! CI smoke: `-- --rounds 120 --out /tmp/BENCH_store.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use mgfl::config::TopologyKind;
use mgfl::store::CellStore;
use mgfl::sweep::{self, RunOptions, SweepSpec};
use mgfl::util::args::Args;
use mgfl::util::bench;
use mgfl::util::json::Json;

/// The committed Gaia grid: 7 topologies × gaia × femnist × 1 t × 8
/// seeds — the same grid the sweep_cache bench pins, so the two
/// baselines measure the same work through different caches.
fn grid(rounds: usize) -> SweepSpec {
    SweepSpec {
        name: "store".into(),
        topologies: TopologyKind::all().to_vec(),
        networks: vec!["gaia".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![5],
        seeds: (17..25).collect(),
        rounds,
        scenario: None,
        adapt: Vec::new(),
    }
}

fn opts(threads: usize) -> RunOptions {
    RunOptions { threads, progress: false, dedup: true }
}

/// A process-unique scratch directory under the system temp dir;
/// `tag` separates the gate store from the timing store.
fn scratch_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mgfl_bench_store_{}_{tag}", std::process::id()))
}

fn fresh_store(dir: &Path) -> CellStore {
    let _ = std::fs::remove_dir_all(dir);
    CellStore::open(dir).expect("opening bench store")
}

fn main() {
    let args = Args::from_env();
    let rounds: usize = args.get("rounds", 6400).expect("--rounds takes an integer");
    let out = args.get_str("out", "BENCH_store.json");

    // --- 1. identity gate -------------------------------------------
    let gate_rounds = rounds.min(200);
    bench::header(&format!(
        "store identity gate — warm sweeps vs storeless runs, {gate_rounds} rounds"
    ));
    let gate = grid(gate_rounds);
    let reference = sweep::run(&gate, &opts(1)).expect("storeless sweep");
    let ref_json = reference.report.to_json().to_string();
    let ref_csv = reference.report.to_csv();
    let unique_cells = reference.unique_cells;

    let gate_dir = scratch_dir("gate");
    let store = fresh_store(&gate_dir);
    let cold = sweep::run_with_store(&gate, &opts(1), Some(&store)).expect("cold sweep");
    assert_eq!(cold.store_hits, 0, "an empty store must hit nothing");
    assert_eq!(cold.store_misses, unique_cells, "cold must simulate every unique cell");
    assert_eq!(cold.report.to_json().to_string(), ref_json, "cold JSON must match storeless");
    assert_eq!(cold.report.to_csv(), ref_csv, "cold CSV must match storeless");
    for threads in [1usize, 4] {
        let warm = sweep::run_with_store(&gate, &opts(threads), Some(&store)).expect("warm sweep");
        assert_eq!(
            warm.store_hits, unique_cells,
            "warm must serve every unique cell from the store (threads={threads})"
        );
        assert_eq!(warm.store_misses, 0, "warm must simulate nothing (threads={threads})");
        assert_eq!(
            warm.report.to_json().to_string(),
            ref_json,
            "warm JSON must be byte-identical to the storeless run (threads={threads})"
        );
        assert_eq!(
            warm.report.to_csv(),
            ref_csv,
            "warm CSV must be byte-identical to the storeless run (threads={threads})"
        );
    }
    let _ = std::fs::remove_dir_all(&gate_dir);
    let total_cells = gate.cell_count();
    println!(
        "{total_cells} cells -> {unique_cells} unique; cold missed all, warm hit all, \
         artifacts byte-identical across store states and thread counts"
    );

    // --- 2. warm-start bar ------------------------------------------
    bench::header(&format!(
        "warm-start bar — {total_cells}-cell grid, {rounds} rounds, 1 thread"
    ));
    let deep = grid(rounds);
    let timing_dir = scratch_dir("timing");
    // Cold populates the store, so it is timed as a single pass against
    // a fresh directory (a second "cold" iteration would be warm).
    let store = fresh_store(&timing_dir);
    let m_cold = bench::bench("cold (empty store, write-back)", 0, 1, || {
        let outcome = sweep::run_with_store(&deep, &opts(1), Some(&store)).expect("cold sweep");
        std::hint::black_box(outcome.report.cells.len());
    });
    let m_warm = bench::bench("warm (every cell from the log)", 1, 5, || {
        let outcome = sweep::run_with_store(&deep, &opts(1), Some(&store)).expect("warm sweep");
        assert_eq!(outcome.store_misses, 0, "timing store must stay fully warm");
        std::hint::black_box(outcome.report.cells.len());
    });
    let _ = std::fs::remove_dir_all(&timing_dir);
    let cold_cps = total_cells as f64 / (m_cold.mean_ms / 1e3);
    let warm_cps = total_cells as f64 / (m_warm.mean_ms / 1e3);
    let speedup = m_cold.mean_ms / m_warm.mean_ms.max(1e-9);
    println!(
        "cells/sec: {cold_cps:.0} -> {warm_cps:.0} | speedup {speedup:.2}x \
         (bar: >= 5x cells/sec on the second run of the committed grid)"
    );
    if rounds >= 6400 {
        assert!(
            speedup >= 5.0,
            "acceptance: a warm store must serve the committed Gaia grid >= 5x faster \
             than the cold run that filled it (got {speedup:.2}x)"
        );
    } else {
        println!("(>= 5x bar asserted on full runs; this is a smoke run at {rounds} rounds)");
    }

    // --- 3. baseline artifact ---------------------------------------
    let measured = rounds >= 6400;
    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("store".into()));
    obj.insert(
        "provenance".to_string(),
        Json::Str(
            "measured by `cargo bench --bench store` (identity gate and >= 5x \
             warm-start bar passed first)"
                .into(),
        ),
    );
    obj.insert("measured".to_string(), Json::Bool(measured));
    obj.insert("rounds".to_string(), Json::Num(rounds as f64));
    obj.insert("total_cells".to_string(), Json::Num(total_cells as f64));
    obj.insert("unique_cells".to_string(), Json::Num(unique_cells as f64));
    obj.insert("artifacts_byte_identical".to_string(), Json::Bool(true));
    obj.insert(
        "warm_start".to_string(),
        if measured {
            Json::Obj(BTreeMap::from([
                ("cold_ms_per_sweep".to_string(), Json::Num(m_cold.mean_ms)),
                ("warm_ms_per_sweep".to_string(), Json::Num(m_warm.mean_ms)),
                ("cold_cells_per_sec".to_string(), Json::Num(cold_cps)),
                ("warm_cells_per_sec".to_string(), Json::Num(warm_cps)),
                ("speedup".to_string(), Json::Num(speedup)),
            ]))
        } else {
            Json::Null
        },
    );
    let json = Json::Obj(obj).to_string();
    std::fs::write(&out, format!("{json}\n")).expect("writing bench baseline");
    println!("\nbaseline -> {out}");
}
