//! Weighted undirected graph — the substrate every topology builder works on.
//!
//! Cross-silo connectivity graphs are small (tens of nodes, paper max 87),
//! so the representation favours clarity and cheap cloning: a dense edge
//! list plus adjacency index. Directed semantics (per-direction delays)
//! live in [`crate::delay`]; topology *construction* is undirected, as in
//! the paper (an overlay edge implies communication both ways).

use std::collections::BTreeSet;

/// Node index. Silos are 0..n.
pub type NodeId = usize;

/// An undirected weighted edge `(u, v, w)` with `u < v` canonically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub u: NodeId,
    pub v: NodeId,
    pub w: f64,
}

impl Edge {
    pub fn new(u: NodeId, v: NodeId, w: f64) -> Self {
        let (u, v) = if u <= v { (u, v) } else { (v, u) };
        Edge { u, v, w }
    }

    /// The endpoint that is not `x`. Panics if `x` is not an endpoint.
    pub fn other(&self, x: NodeId) -> NodeId {
        if x == self.u {
            self.v
        } else {
            assert_eq!(x, self.v, "node {x} not on edge ({}, {})", self.u, self.v);
            self.u
        }
    }

    /// Canonical unordered pair key.
    pub fn pair(&self) -> (NodeId, NodeId) {
        (self.u, self.v)
    }
}

/// Undirected weighted graph over `n` nodes.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    n: usize,
    edges: Vec<Edge>,
    adj: Vec<Vec<usize>>, // node -> indices into `edges`
}

impl Graph {
    pub fn new(n: usize) -> Self {
        Graph { n, edges: Vec::new(), adj: vec![Vec::new(); n] }
    }

    /// Fully-connected graph with weights from `w(u, v)` — the paper's
    /// *connectivity* graph \(\mathcal{G}_c\).
    pub fn complete(n: usize, mut w: impl FnMut(NodeId, NodeId) -> f64) -> Self {
        let mut g = Graph::new(n);
        for u in 0..n {
            for v in (u + 1)..n {
                g.add_edge(u, v, w(u, v));
            }
        }
        g
    }

    pub fn from_edges(n: usize, edges: impl IntoIterator<Item = (NodeId, NodeId, f64)>) -> Self {
        let mut g = Graph::new(n);
        for (u, v, w) in edges {
            g.add_edge(u, v, w);
        }
        g
    }

    pub fn add_edge(&mut self, u: NodeId, v: NodeId, w: f64) {
        assert!(u < self.n && v < self.n, "edge ({u},{v}) out of range n={}", self.n);
        assert_ne!(u, v, "self-loops not allowed in topology graphs");
        let idx = self.edges.len();
        self.edges.push(Edge::new(u, v, w));
        self.adj[u].push(idx);
        self.adj[v].push(idx);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    pub fn degree(&self, u: NodeId) -> usize {
        self.adj[u].len()
    }

    /// Iterate `(neighbor, weight)` of `u`.
    pub fn neighbors(&self, u: NodeId) -> impl Iterator<Item = (NodeId, f64)> + '_ {
        self.adj[u].iter().map(move |&i| {
            let e = self.edges[i];
            (e.other(u), e.w)
        })
    }

    pub fn neighbor_set(&self, u: NodeId) -> BTreeSet<NodeId> {
        self.neighbors(u).map(|(v, _)| v).collect()
    }

    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.neighbors(u).any(|(x, _)| x == v)
    }

    pub fn edge_weight(&self, u: NodeId, v: NodeId) -> Option<f64> {
        self.neighbors(u).find(|&(x, _)| x == v).map(|(_, w)| w)
    }

    /// Connectivity check (ignores weights). Empty graphs are connected.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for (v, _) in self.neighbors(u) {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }

    /// Nodes with odd degree (input to Christofides' matching step).
    pub fn odd_degree_nodes(&self) -> Vec<NodeId> {
        (0..self.n).filter(|&u| self.degree(u) % 2 == 1).collect()
    }

    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|e| e.w).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        Graph::from_edges(3, [(0, 1, 1.0), (1, 2, 2.0)])
    }

    #[test]
    fn edge_canonicalizes_endpoints() {
        let e = Edge::new(5, 2, 1.0);
        assert_eq!((e.u, e.v), (2, 5));
        assert_eq!(e.other(2), 5);
        assert_eq!(e.other(5), 2);
    }

    #[test]
    #[should_panic]
    fn edge_other_panics_off_edge() {
        Edge::new(0, 1, 1.0).other(2);
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = path3();
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.neighbor_set(1), BTreeSet::from([0, 2]));
        assert_eq!(g.edge_weight(1, 2), Some(2.0));
        assert_eq!(g.edge_weight(0, 2), None);
    }

    #[test]
    fn connectivity() {
        assert!(path3().is_connected());
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 1.0);
        assert!(!g.is_connected());
        assert!(Graph::new(0).is_connected());
        assert!(!Graph::new(2).is_connected());
    }

    #[test]
    fn complete_graph_edge_count() {
        let g = Graph::complete(5, |u, v| (u + v) as f64);
        assert_eq!(g.edges().len(), 10);
        assert!(g.is_connected());
        assert_eq!(g.edge_weight(2, 3), Some(5.0));
    }

    #[test]
    fn odd_degree_nodes_of_path() {
        assert_eq!(path3().odd_degree_nodes(), vec![0, 2]);
        // Handshake lemma: odd-degree count is always even.
        let g = Graph::complete(6, |_, _| 1.0);
        assert_eq!(g.odd_degree_nodes().len() % 2, 0);
    }

    #[test]
    #[should_panic]
    fn rejects_self_loop() {
        let mut g = Graph::new(2);
        g.add_edge(1, 1, 1.0);
    }
}
