//! Graph substrate: the algorithms every topology design is built from.
//!
//! The paper's overlay construction (Christofides on the delay-weighted
//! connectivity graph), the MST / δ-MBST baselines (Prim variants), and
//! MATCHA's matching decomposition all live here, independent of any
//! federated-learning semantics.

pub mod christofides;
pub mod dense;
pub mod digraph;
pub mod euler;
pub mod matching;
pub mod mst;

pub use christofides::{
    christofides_cycle, christofides_cycle_dense, cycle_weight, ring_overlay, ring_overlay_dense,
};
pub use dense::DenseGraph;
pub use digraph::{Edge, Graph, NodeId};
pub use euler::{eulerian_circuit, shortcut_to_hamiltonian};
pub use matching::{greedy_min_weight_matching, matching_decomposition, maximal_matching};
pub use mst::{degree_bounded_mst, degree_bounded_mst_dense, prim_mst, prim_mst_dense};
