//! Prim's minimum spanning tree — the paper's MST baseline (Table 1,
//! "MST [72]" = Prim 1957) and the first step of Christofides.
//!
//! Each builder exists twice: over the sparse [`Graph`] (the
//! pre-overhaul reference, kept verbatim) and over the flat
//! [`DenseGraph`] slab (the production path for complete connectivity
//! graphs). The dense twins replicate the reference's iteration order
//! and tie-breaking exactly, so they are byte-identical — pinned by the
//! unit tests here and by `benches/scaling.rs` across the paper zoo.

use super::dense::DenseGraph;
use super::digraph::{Graph, NodeId};

/// Compute an MST of a connected graph with Prim's algorithm.
///
/// Returns the tree as a new [`Graph`] over the same node set.
/// Panics if the input is empty or disconnected (topology builders must
/// feed a connected connectivity graph; this is a programming error).
pub fn prim_mst(g: &Graph) -> Graph {
    assert!(g.n() > 0, "MST of empty graph");
    let n = g.n();
    let mut in_tree = vec![false; n];
    // best[v] = (weight, parent) of the cheapest edge connecting v to the tree
    let mut best: Vec<Option<(f64, NodeId)>> = vec![None; n];
    let mut tree = Graph::new(n);
    in_tree[0] = true;
    for (v, w) in g.neighbors(0) {
        best[v] = merge(best[v], (w, 0));
    }
    for _ in 1..n {
        let u = (0..n)
            .filter(|&v| !in_tree[v] && best[v].is_some())
            .min_by(|&a, &b| best[a].unwrap().0.total_cmp(&best[b].unwrap().0))
            .expect("graph is disconnected: Prim frontier is empty");
        let (w, parent) = best[u].unwrap();
        tree.add_edge(parent, u, w);
        in_tree[u] = true;
        for (v, w) in g.neighbors(u) {
            if !in_tree[v] {
                best[v] = merge(best[v], (w, u));
            }
        }
    }
    tree
}

fn merge(cur: Option<(f64, NodeId)>, cand: (f64, NodeId)) -> Option<(f64, NodeId)> {
    match cur {
        Some((w, _)) if w <= cand.0 => cur,
        _ => Some(cand),
    }
}

/// [`prim_mst`] over the dense slab: same O(N²) algorithm, same
/// ascending-neighbor iteration order and `merge` tie-breaking (so the
/// tree is bit-identical to the sparse reference on the equivalent
/// complete graph), but each weight probe is one slab load instead of
/// an adjacency-index chase, and no complete `Graph` is ever built.
pub fn prim_mst_dense(g: &DenseGraph) -> Graph {
    assert!(g.n() > 0, "MST of empty graph");
    let n = g.n();
    let mut in_tree = vec![false; n];
    let mut best: Vec<Option<(f64, NodeId)>> = vec![None; n];
    let mut tree = Graph::new(n);
    in_tree[0] = true;
    for v in 1..n {
        best[v] = merge(best[v], (g.weight(0, v), 0));
    }
    for _ in 1..n {
        let u = (0..n)
            .filter(|&v| !in_tree[v] && best[v].is_some())
            .min_by(|&a, &b| best[a].unwrap().0.total_cmp(&best[b].unwrap().0))
            .expect("complete graph frontier cannot be empty");
        let (w, parent) = best[u].unwrap();
        tree.add_edge(parent, u, w);
        in_tree[u] = true;
        for v in 0..n {
            if !in_tree[v] {
                best[v] = merge(best[v], (g.weight(u, v), u));
            }
        }
    }
    tree
}

/// Degree-bounded MST approximation for the δ-MBST baseline (Marfoq et
/// al.): Prim, but a node with `delta` tree-neighbors already is frozen —
/// its remaining frontier edges are discarded. NP-hard exactly; this is
/// the greedy the RING paper's implementation uses for its baseline.
///
/// Falls back to relaxing the bound by 1 (retry) if the constrained run
/// cannot span the graph (can happen on sparse graphs with tiny delta).
pub fn degree_bounded_mst(g: &Graph, delta: usize) -> Graph {
    assert!(delta >= 1, "delta must be >= 1");
    let n = g.n();
    if n == 0 {
        return Graph::new(0);
    }
    let mut in_tree = vec![false; n];
    let mut deg = vec![0usize; n];
    let mut tree = Graph::new(n);
    in_tree[0] = true;
    let mut count = 1;
    while count < n {
        // Cheapest edge (u in tree with spare degree) -> (v outside).
        let mut cand: Option<(f64, NodeId, NodeId)> = None;
        for u in 0..n {
            if !in_tree[u] || deg[u] >= delta {
                continue;
            }
            for (v, w) in g.neighbors(u) {
                if !in_tree[v] && deg[v] < delta {
                    let c = (w, u, v);
                    cand = match cand {
                        Some(best) if best.0 <= w => Some(best),
                        _ => Some(c),
                    };
                }
            }
        }
        match cand {
            Some((w, u, v)) => {
                tree.add_edge(u, v, w);
                deg[u] += 1;
                deg[v] += 1;
                in_tree[v] = true;
                count += 1;
            }
            // Bound too tight to span: relax (documented fallback).
            None => return degree_bounded_mst(g, delta + 1),
        }
    }
    tree
}

/// [`degree_bounded_mst`] over the dense slab, with cached row minima.
///
/// The reference rescans every (eligible tree node, outside node) pair
/// per step — O(N³) on complete graphs, each probe an adjacency walk.
/// Here each tree node `u` caches its cheapest outside endpoint
/// `(w, v)`; the cache goes stale only when that `v` joins the tree
/// (the outside set only shrinks and weights are static), which is
/// exactly when the row is rescanned. Selection semantics are the
/// reference's bit for bit: row minima keep the smallest `v` on ties
/// (ascending scan, replace only on strictly smaller), the global scan
/// keeps the earliest eligible `u` on ties — together the same
/// (u, v)-lexicographic tie-break as the reference's nested scan, so
/// the tree is byte-identical.
///
/// Note the reference's `deg[v] < delta` guard on the outside endpoint
/// is vacuous on complete graphs (outside nodes always have degree 0),
/// so the dense twin drops it.
pub fn degree_bounded_mst_dense(g: &DenseGraph, delta: usize) -> Graph {
    assert!(delta >= 1, "delta must be >= 1");
    let n = g.n();
    if n == 0 {
        return Graph::new(0);
    }
    let mut in_tree = vec![false; n];
    let mut deg = vec![0usize; n];
    // Cheapest outside endpoint per tree node (unused once saturated).
    let mut best_v: Vec<Option<(f64, NodeId)>> = vec![None; n];
    let mut tree = Graph::new(n);
    in_tree[0] = true;
    best_v[0] = dense_row_min(g, 0, &in_tree);
    let mut count = 1;
    while count < n {
        let mut cand: Option<(f64, NodeId, NodeId)> = None;
        for u in 0..n {
            if !in_tree[u] || deg[u] >= delta {
                continue;
            }
            if let Some((w, v)) = best_v[u] {
                cand = match cand {
                    Some(best) if best.0 <= w => Some(best),
                    _ => Some((w, u, v)),
                };
            }
        }
        match cand {
            Some((w, u, v)) => {
                tree.add_edge(u, v, w);
                deg[u] += 1;
                deg[v] += 1;
                in_tree[v] = true;
                count += 1;
                best_v[v] = dense_row_min(g, v, &in_tree);
                // Rescan only rows whose cached endpoint just left the
                // outside set (including u's own row).
                for x in 0..n {
                    if x != v && in_tree[x] && deg[x] < delta {
                        if let Some((_, bv)) = best_v[x] {
                            if bv == v {
                                best_v[x] = dense_row_min(g, x, &in_tree);
                            }
                        }
                    }
                }
            }
            // Bound too tight to span: relax (same fallback as the
            // reference).
            None => return degree_bounded_mst_dense(g, delta + 1),
        }
    }
    tree
}

/// Cheapest outside endpoint of `u`'s slab row, smallest index on ties
/// (ascending scan, replace on strictly smaller — mirroring the
/// reference's inner loop).
fn dense_row_min(g: &DenseGraph, u: NodeId, in_tree: &[bool]) -> Option<(f64, NodeId)> {
    let mut best: Option<(f64, NodeId)> = None;
    for v in 0..g.n() {
        if v == u || in_tree[v] {
            continue;
        }
        let w = g.weight(u, v);
        best = match best {
            Some(b) if b.0 <= w => best,
            _ => Some((w, v)),
        };
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mst_of_square_with_diagonal() {
        // 0-1 (1), 1-2 (1), 2-3 (1), 3-0 (10), 0-2 (5)
        let g = Graph::from_edges(
            4,
            [(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 0, 10.0), (0, 2, 5.0)],
        );
        let t = prim_mst(&g);
        assert_eq!(t.edges().len(), 3);
        assert_eq!(t.total_weight(), 3.0);
        assert!(t.is_connected());
    }

    #[test]
    fn mst_is_spanning_and_minimal_on_complete_graph() {
        let g = Graph::complete(8, |u, v| ((u * 7 + v * 13) % 17) as f64 + 1.0);
        let t = prim_mst(&g);
        assert_eq!(t.edges().len(), 7);
        assert!(t.is_connected());
        // Cut property spot-check: every non-tree edge is >= the max tree
        // edge on some path; cheap sanity — total weight below any star.
        for center in 0..8 {
            let star: f64 = (0..8)
                .filter(|&v| v != center)
                .map(|v| g.edge_weight(center, v).unwrap())
                .sum();
            assert!(t.total_weight() <= star + 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "disconnected")]
    fn mst_panics_on_disconnected() {
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(2, 3, 1.0);
        prim_mst(&g);
    }

    #[test]
    fn degree_bounded_respects_delta() {
        let g = Graph::complete(9, |u, v| (u as f64 - v as f64).abs());
        for delta in 2..5 {
            let t = degree_bounded_mst(&g, delta);
            assert!(t.is_connected());
            assert_eq!(t.edges().len(), 8);
            for u in 0..9 {
                assert!(t.degree(u) <= delta, "deg({u}) > {delta}");
            }
        }
    }

    #[test]
    fn degree_bounded_matches_mst_when_loose() {
        let g = Graph::complete(6, |u, v| ((u + 1) * (v + 1)) as f64);
        let t1 = prim_mst(&g);
        let t2 = degree_bounded_mst(&g, 5);
        assert_eq!(t1.total_weight(), t2.total_weight());
    }

    #[test]
    fn delta_one_relaxes_instead_of_looping() {
        // delta=1 cannot span n>2; must fall back to delta=2 (a path).
        let g = Graph::complete(4, |_, _| 1.0);
        let t = degree_bounded_mst(&g, 1);
        assert!(t.is_connected());
    }

    /// Edge-list equality down to the bits, including insertion order —
    /// the dense twins must be indistinguishable from the reference.
    fn assert_trees_identical(a: &Graph, b: &Graph, ctx: &str) {
        assert_eq!(a.edges().len(), b.edges().len(), "{ctx}: edge count");
        for (x, y) in a.edges().iter().zip(b.edges()) {
            assert_eq!(
                (x.u, x.v, x.w.to_bits()),
                (y.u, y.v, y.w.to_bits()),
                "{ctx}: edge mismatch"
            );
        }
    }

    #[test]
    fn dense_prim_is_byte_identical_to_sparse() {
        for n in [2usize, 3, 8, 17] {
            // Adversarial weights with plenty of exact ties.
            let w = |u: usize, v: usize| ((u * 7 + v * 13) % 5) as f64 + 1.0;
            let sparse = prim_mst(&Graph::complete(n, w));
            let dense = prim_mst_dense(&DenseGraph::from_fn(n, w));
            assert_trees_identical(&dense, &sparse, &format!("prim n={n}"));
        }
    }

    #[test]
    fn dense_degree_bounded_is_byte_identical_to_sparse() {
        for n in [2usize, 5, 9, 16] {
            let w = |u: usize, v: usize| ((u * 11 + v * 3) % 7) as f64 + 0.5;
            for delta in 1..5 {
                let sparse = degree_bounded_mst(&Graph::complete(n, w), delta);
                let dense = degree_bounded_mst_dense(&DenseGraph::from_fn(n, w), delta);
                assert_trees_identical(&dense, &sparse, &format!("dmbst n={n} delta={delta}"));
            }
        }
    }

    #[test]
    fn dense_degree_bounded_respects_delta_at_scale() {
        let g = DenseGraph::from_fn(64, |u, v| ((u * 31 + v * 17) % 23) as f64 + 1.0);
        let t = degree_bounded_mst_dense(&g, 3);
        assert!(t.is_connected());
        assert_eq!(t.edges().len(), 63);
        for u in 0..64 {
            assert!(t.degree(u) <= 3);
        }
    }
}
