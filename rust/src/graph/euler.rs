//! Eulerian circuit (Hierholzer) + TSP shortcutting — Christofides step 3.

use super::digraph::NodeId;

/// Multigraph edge list (parallel edges allowed) -> Eulerian circuit as a
/// node sequence starting and ending at the same node.
///
/// Requires: every node that appears has even degree and the edge-induced
/// graph is connected (Christofides guarantees both: MST + perfect
/// matching on odd-degree vertices).
pub fn eulerian_circuit(n: usize, edges: &[(NodeId, NodeId)]) -> Vec<NodeId> {
    if edges.is_empty() {
        return Vec::new();
    }
    // CSR adjacency (three flat slabs instead of n per-node vecs):
    // count degrees, prefix-sum offsets, fill in edge order — which
    // preserves the per-node edge order the old Vec<Vec> construction
    // produced, so the traversal (and circuit) is identical.
    let mut deg = vec![0usize; n];
    for &(u, v) in edges {
        deg[u] += 1;
        deg[v] += 1;
    }
    for (u, &d) in deg.iter().enumerate() {
        assert!(d % 2 == 0, "node {u} has odd degree {d}");
    }
    let mut offset = vec![0usize; n + 1];
    for u in 0..n {
        offset[u + 1] = offset[u] + deg[u];
    }
    let mut adj = vec![0usize; 2 * edges.len()];
    let mut cursor = offset.clone();
    for (i, &(u, v)) in edges.iter().enumerate() {
        adj[cursor[u]] = i;
        cursor[u] += 1;
        adj[cursor[v]] = i;
        cursor[v] += 1;
    }
    let mut used = vec![false; edges.len()];
    let mut ptr = offset.clone(); // per-node cursor into adj
    let start = edges[0].0;
    let mut stack = vec![start];
    let mut circuit = Vec::with_capacity(edges.len() + 1);
    while let Some(&u) = stack.last() {
        // advance cursor past consumed edges
        while ptr[u] < offset[u + 1] && used[adj[ptr[u]]] {
            ptr[u] += 1;
        }
        if ptr[u] == offset[u + 1] {
            circuit.push(u);
            stack.pop();
        } else {
            let ei = adj[ptr[u]];
            used[ei] = true;
            let (a, b) = edges[ei];
            stack.push(if a == u { b } else { a });
        }
    }
    assert!(
        used.iter().all(|&b| b),
        "edge set not connected: Eulerian circuit missed edges"
    );
    circuit
}

/// Shortcut an Eulerian circuit into a Hamiltonian cycle (skip repeats).
/// Returns the node order of the cycle (first node NOT repeated at end).
pub fn shortcut_to_hamiltonian(circuit: &[NodeId]) -> Vec<NodeId> {
    // Flat seen-marker instead of a BTreeSet: node ids are dense graph
    // indices, and a circuit visits every edge, so the marker is small
    // relative to the input.
    let cap = circuit.iter().copied().max().map_or(0, |m| m + 1);
    let mut seen = vec![false; cap];
    let mut cycle = Vec::new();
    for &u in circuit {
        if !seen[u] {
            seen[u] = true;
            cycle.push(u);
        }
    }
    cycle
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_valid_circuit(n: usize, edges: &[(NodeId, NodeId)], circuit: &[NodeId]) -> bool {
        let _ = n;
        if circuit.len() != edges.len() + 1 || circuit.first() != circuit.last() {
            return false;
        }
        // Multiset of traversed edges equals the input multiset.
        let canon = |u: NodeId, v: NodeId| (u.min(v), u.max(v));
        let mut want: Vec<_> = edges.iter().map(|&(u, v)| canon(u, v)).collect();
        let mut got: Vec<_> = circuit.windows(2).map(|w| canon(w[0], w[1])).collect();
        want.sort();
        got.sort();
        want == got
    }

    #[test]
    fn circuit_on_triangle() {
        let edges = vec![(0, 1), (1, 2), (2, 0)];
        let c = eulerian_circuit(3, &edges);
        assert!(is_valid_circuit(3, &edges, &c), "{c:?}");
    }

    #[test]
    fn circuit_on_multigraph_with_parallel_edges() {
        // Two parallel 0-1 edges: circuit 0-1-0.
        let edges = vec![(0, 1), (0, 1)];
        let c = eulerian_circuit(2, &edges);
        assert!(is_valid_circuit(2, &edges, &c), "{c:?}");
    }

    #[test]
    fn circuit_on_bowtie() {
        // Two triangles sharing node 2 — classic Hierholzer case.
        let edges = vec![(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)];
        let c = eulerian_circuit(5, &edges);
        assert!(is_valid_circuit(5, &edges, &c), "{c:?}");
    }

    #[test]
    #[should_panic(expected = "odd degree")]
    fn rejects_odd_degree() {
        eulerian_circuit(3, &[(0, 1), (1, 2)]);
    }

    #[test]
    fn shortcut_visits_each_once() {
        let circuit = vec![0, 1, 2, 0, 3, 4, 2, 0]; // bowtie-ish walk
        let ham = shortcut_to_hamiltonian(&circuit);
        assert_eq!(ham.len(), 5);
        let set: std::collections::BTreeSet<_> = ham.iter().collect();
        assert_eq!(set.len(), 5);
        assert_eq!(ham[0], 0);
    }

    #[test]
    fn empty_edge_set() {
        assert!(eulerian_circuit(4, &[]).is_empty());
    }
}
