//! Eulerian circuit (Hierholzer) + TSP shortcutting — Christofides step 3.

use super::digraph::NodeId;

/// Multigraph edge list (parallel edges allowed) -> Eulerian circuit as a
/// node sequence starting and ending at the same node.
///
/// Requires: every node that appears has even degree and the edge-induced
/// graph is connected (Christofides guarantees both: MST + perfect
/// matching on odd-degree vertices).
pub fn eulerian_circuit(n: usize, edges: &[(NodeId, NodeId)]) -> Vec<NodeId> {
    if edges.is_empty() {
        return Vec::new();
    }
    // adjacency as (edge index) lists; `used` marks consumed edges.
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, &(u, v)) in edges.iter().enumerate() {
        adj[u].push(i);
        adj[v].push(i);
    }
    for (u, a) in adj.iter().enumerate() {
        assert!(a.len() % 2 == 0, "node {u} has odd degree {}", a.len());
    }
    let mut used = vec![false; edges.len()];
    let mut ptr = vec![0usize; n]; // per-node cursor into adj
    let start = edges[0].0;
    let mut stack = vec![start];
    let mut circuit = Vec::with_capacity(edges.len() + 1);
    while let Some(&u) = stack.last() {
        // advance cursor past consumed edges
        while ptr[u] < adj[u].len() && used[adj[u][ptr[u]]] {
            ptr[u] += 1;
        }
        if ptr[u] == adj[u].len() {
            circuit.push(u);
            stack.pop();
        } else {
            let ei = adj[u][ptr[u]];
            used[ei] = true;
            let (a, b) = edges[ei];
            stack.push(if a == u { b } else { a });
        }
    }
    assert!(
        used.iter().all(|&b| b),
        "edge set not connected: Eulerian circuit missed edges"
    );
    circuit
}

/// Shortcut an Eulerian circuit into a Hamiltonian cycle (skip repeats).
/// Returns the node order of the cycle (first node NOT repeated at end).
pub fn shortcut_to_hamiltonian(circuit: &[NodeId]) -> Vec<NodeId> {
    let mut seen = std::collections::BTreeSet::new();
    let mut cycle = Vec::new();
    for &u in circuit {
        if seen.insert(u) {
            cycle.push(u);
        }
    }
    cycle
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_valid_circuit(n: usize, edges: &[(NodeId, NodeId)], circuit: &[NodeId]) -> bool {
        let _ = n;
        if circuit.len() != edges.len() + 1 || circuit.first() != circuit.last() {
            return false;
        }
        // Multiset of traversed edges equals the input multiset.
        let canon = |u: NodeId, v: NodeId| (u.min(v), u.max(v));
        let mut want: Vec<_> = edges.iter().map(|&(u, v)| canon(u, v)).collect();
        let mut got: Vec<_> = circuit.windows(2).map(|w| canon(w[0], w[1])).collect();
        want.sort();
        got.sort();
        want == got
    }

    #[test]
    fn circuit_on_triangle() {
        let edges = vec![(0, 1), (1, 2), (2, 0)];
        let c = eulerian_circuit(3, &edges);
        assert!(is_valid_circuit(3, &edges, &c), "{c:?}");
    }

    #[test]
    fn circuit_on_multigraph_with_parallel_edges() {
        // Two parallel 0-1 edges: circuit 0-1-0.
        let edges = vec![(0, 1), (0, 1)];
        let c = eulerian_circuit(2, &edges);
        assert!(is_valid_circuit(2, &edges, &c), "{c:?}");
    }

    #[test]
    fn circuit_on_bowtie() {
        // Two triangles sharing node 2 — classic Hierholzer case.
        let edges = vec![(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)];
        let c = eulerian_circuit(5, &edges);
        assert!(is_valid_circuit(5, &edges, &c), "{c:?}");
    }

    #[test]
    #[should_panic(expected = "odd degree")]
    fn rejects_odd_degree() {
        eulerian_circuit(3, &[(0, 1), (1, 2)]);
    }

    #[test]
    fn shortcut_visits_each_once() {
        let circuit = vec![0, 1, 2, 0, 3, 4, 2, 0]; // bowtie-ish walk
        let ham = shortcut_to_hamiltonian(&circuit);
        assert_eq!(ham.len(), 5);
        let set: std::collections::BTreeSet<_> = ham.iter().collect();
        assert_eq!(set.len(), 5);
        assert_eq!(ham[0], 0);
    }

    #[test]
    fn empty_edge_set() {
        assert!(eulerian_circuit(4, &[]).is_empty());
    }
}
