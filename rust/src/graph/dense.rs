//! Dense complete-graph substrate for large-N topology construction.
//!
//! Every overlay builder starts from the paper's *connectivity* graph
//! \(\mathcal{G}_c\) — complete by definition. The sparse [`Graph`]
//! represents it as ~N²/2 `Edge` structs plus a `Vec<Vec<usize>>`
//! adjacency index: at N = 4096 that is ~8.4M 24-byte edges, ~17M
//! adjacency slots, and N+1 stray vecs, and every `edge_weight(u, v)`
//! probe walks an O(N) adjacency list (which turns Christofides'
//! matching step into an O(N³) scan). A [`DenseGraph`] stores the same
//! information as **one** flat upper-triangular `f64` slab with O(1)
//! `(u, v)` access; the construction hot paths (Prim, δ-MBST,
//! Christofides) have dense twins in their own modules that are pinned
//! byte-identical to the sparse reference builders.
//!
//! Sparse [`Graph`] stays the representation for *overlays* (rings,
//! trees, stars — O(N) edges) and remains the pre-overhaul reference
//! the dense builders are verified against (`benches/scaling.rs`).

use super::digraph::{Graph, NodeId};

/// Complete weighted graph over `n` nodes: one upper-triangular,
/// row-major weight slab. Pair `(u, v)` with `u < v` lives at
/// `u*(2n-u-1)/2 + (v-u-1)`.
#[derive(Debug, Clone)]
pub struct DenseGraph {
    n: usize,
    w: Vec<f64>,
}

impl DenseGraph {
    /// Build from a weight function, visiting pairs in the same
    /// `(u, v)` row-major order as [`Graph::complete`] — one allocation
    /// total, against ~N²/2 edge pushes plus 2·N²/2 adjacency pushes
    /// for the sparse equivalent.
    pub fn from_fn(n: usize, mut w: impl FnMut(NodeId, NodeId) -> f64) -> Self {
        let mut slab = Vec::with_capacity(n * n.saturating_sub(1) / 2);
        for u in 0..n {
            for v in (u + 1)..n {
                slab.push(w(u, v));
            }
        }
        DenseGraph { n, w: slab }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored pairs: n·(n-1)/2.
    pub fn num_pairs(&self) -> usize {
        self.w.len()
    }

    #[inline]
    fn idx(&self, u: NodeId, v: NodeId) -> usize {
        debug_assert!(u < v && v < self.n, "dense pair ({u},{v}) out of range n={}", self.n);
        // u and (2n-u-1) have opposite parity, so the division is exact.
        u * (2 * self.n - u - 1) / 2 + (v - u - 1)
    }

    /// O(1) symmetric weight lookup. Panics on `u == v` (complete
    /// graphs here carry no self-loops, same as [`Graph::add_edge`]).
    #[inline]
    pub fn weight(&self, u: NodeId, v: NodeId) -> f64 {
        assert_ne!(u, v, "no self-weight in a complete graph");
        let (a, b) = if u < v { (u, v) } else { (v, u) };
        self.w[self.idx(a, b)]
    }

    /// Materialize as a sparse [`Graph`] (tests and small-n tooling;
    /// defeats the point at scale).
    pub fn to_graph(&self) -> Graph {
        Graph::complete(self.n, |u, v| self.weight(u, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn weights(u: NodeId, v: NodeId) -> f64 {
        ((u * 31 + v * 17) % 23) as f64 + 1.0
    }

    #[test]
    fn slab_indexing_covers_every_pair_once() {
        let n = 7;
        let g = DenseGraph::from_fn(n, weights);
        assert_eq!(g.num_pairs(), n * (n - 1) / 2);
        // Every pair readable, symmetric, and equal to the generator.
        for u in 0..n {
            for v in 0..n {
                if u == v {
                    continue;
                }
                let (a, b) = (u.min(v), u.max(v));
                assert_eq!(g.weight(u, v).to_bits(), weights(a, b).to_bits(), "({u},{v})");
                assert_eq!(g.weight(u, v).to_bits(), g.weight(v, u).to_bits());
            }
        }
    }

    #[test]
    fn matches_sparse_complete_graph() {
        let n = 9;
        let dense = DenseGraph::from_fn(n, weights);
        let sparse = Graph::complete(n, weights);
        for e in sparse.edges() {
            assert_eq!(dense.weight(e.u, e.v).to_bits(), e.w.to_bits());
        }
        let back = dense.to_graph();
        assert_eq!(back.edges().len(), sparse.edges().len());
        for (a, b) in back.edges().iter().zip(sparse.edges()) {
            assert_eq!((a.u, a.v, a.w.to_bits()), (b.u, b.v, b.w.to_bits()));
        }
    }

    #[test]
    fn tiny_sizes() {
        let g0 = DenseGraph::from_fn(0, |_, _| unreachable!());
        assert_eq!(g0.num_pairs(), 0);
        let g1 = DenseGraph::from_fn(1, |_, _| unreachable!());
        assert_eq!(g1.num_pairs(), 0);
        let g2 = DenseGraph::from_fn(2, |_, _| 3.5);
        assert_eq!(g2.weight(1, 0), 3.5);
    }

    #[test]
    #[should_panic(expected = "self-weight")]
    fn rejects_self_pair() {
        DenseGraph::from_fn(3, |_, _| 1.0).weight(1, 1);
    }
}
