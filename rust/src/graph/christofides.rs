//! Christofides' TSP approximation — how the paper (following Marfoq et
//! al.'s RING) obtains the *overlay* ring from the delay-weighted
//! connectivity graph: MST → min-weight matching on odd-degree nodes →
//! Eulerian circuit → shortcut to a Hamiltonian cycle.

use super::digraph::{Graph, NodeId};
use super::euler::{eulerian_circuit, shortcut_to_hamiltonian};
use super::matching::greedy_min_weight_matching;
use super::mst::prim_mst;

/// Build a Hamiltonian cycle over the nodes of `g` (must be complete or
/// at least metric-complete on weights; the connectivity graph is).
/// Returns the visiting order; the ring edges are consecutive pairs plus
/// the closing edge.
pub fn christofides_cycle(g: &Graph) -> Vec<NodeId> {
    let n = g.n();
    assert!(n >= 2, "ring needs >= 2 nodes");
    if n == 2 {
        return vec![0, 1];
    }
    let mst = prim_mst(g);
    let odd = mst.odd_degree_nodes();
    let matching = greedy_min_weight_matching(&odd, |u, v| {
        g.edge_weight(u, v)
            .unwrap_or_else(|| panic!("connectivity graph missing edge ({u},{v})"))
    });
    // MST + matching = multigraph with all-even degrees.
    let mut edges: Vec<(NodeId, NodeId)> =
        mst.edges().iter().map(|e| (e.u, e.v)).collect();
    edges.extend(matching);
    let circuit = eulerian_circuit(n, &edges);
    let cycle = shortcut_to_hamiltonian(&circuit);
    assert_eq!(cycle.len(), n, "shortcut did not visit every node");
    cycle
}

/// The overlay graph: ring edges from the Christofides cycle, weighted by
/// the connectivity weights.
pub fn ring_overlay(g: &Graph) -> Graph {
    let cycle = christofides_cycle(g);
    let n = g.n();
    let mut overlay = Graph::new(n);
    for i in 0..cycle.len() {
        let u = cycle[i];
        let v = cycle[(i + 1) % cycle.len()];
        if n == 2 && i == 1 {
            break; // 2-node ring is a single edge, not a double edge
        }
        let w = g.edge_weight(u, v).expect("cycle edge missing from connectivity");
        overlay.add_edge(u, v, w);
    }
    overlay
}

/// Tour length under graph weights (for tests / diagnostics).
pub fn cycle_weight(g: &Graph, cycle: &[NodeId]) -> f64 {
    (0..cycle.len())
        .map(|i| {
            g.edge_weight(cycle[i], cycle[(i + 1) % cycle.len()])
                .expect("cycle uses a non-edge")
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric_complete(points: &[(f64, f64)]) -> Graph {
        Graph::complete(points.len(), |u, v| {
            let (x1, y1) = points[u];
            let (x2, y2) = points[v];
            ((x1 - x2).powi(2) + (y1 - y2).powi(2)).sqrt()
        })
    }

    #[test]
    fn cycle_is_hamiltonian() {
        let pts: Vec<(f64, f64)> =
            (0..9).map(|i| ((i % 3) as f64, (i / 3) as f64)).collect();
        let g = metric_complete(&pts);
        let cycle = christofides_cycle(&g);
        assert_eq!(cycle.len(), 9);
        let set: std::collections::BTreeSet<_> = cycle.iter().collect();
        assert_eq!(set.len(), 9);
    }

    #[test]
    fn overlay_is_a_ring() {
        let pts: Vec<(f64, f64)> = (0..7)
            .map(|i| {
                let a = i as f64 / 7.0 * std::f64::consts::TAU;
                (a.cos(), a.sin())
            })
            .collect();
        let g = metric_complete(&pts);
        let overlay = ring_overlay(&g);
        assert_eq!(overlay.edges().len(), 7);
        assert!(overlay.is_connected());
        for u in 0..7 {
            assert_eq!(overlay.degree(u), 2, "ring degree must be 2");
        }
    }

    #[test]
    fn near_optimal_on_circle_points() {
        // Points on a circle: optimal tour is the circle order. The
        // Christofides ratio bound is 1.5; greedy matching keeps us close
        // in practice — assert within 1.6x of optimal here.
        let n = 12;
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|i| {
                let a = i as f64 / n as f64 * std::f64::consts::TAU;
                (a.cos(), a.sin())
            })
            .collect();
        let g = metric_complete(&pts);
        let cycle = christofides_cycle(&g);
        let opt: f64 = (0..n)
            .map(|i| {
                let (x1, y1) = pts[i];
                let (x2, y2) = pts[(i + 1) % n];
                ((x1 - x2).powi(2) + (y1 - y2).powi(2)).sqrt()
            })
            .sum();
        let got = cycle_weight(&g, &cycle);
        assert!(got <= 1.6 * opt + 1e-9, "tour {got} vs optimal {opt}");
    }

    #[test]
    fn two_node_ring_is_single_edge() {
        let g = Graph::complete(2, |_, _| 3.0);
        let overlay = ring_overlay(&g);
        assert_eq!(overlay.edges().len(), 1);
    }

    #[test]
    fn deterministic() {
        let g = Graph::complete(10, |u, v| ((u * 31 + v * 17) % 23) as f64 + 1.0);
        assert_eq!(christofides_cycle(&g), christofides_cycle(&g));
    }
}
