//! Christofides' TSP approximation — how the paper (following Marfoq et
//! al.'s RING) obtains the *overlay* ring from the delay-weighted
//! connectivity graph: MST → min-weight matching on odd-degree nodes →
//! Eulerian circuit → shortcut to a Hamiltonian cycle.

use super::dense::DenseGraph;
use super::digraph::{Graph, NodeId};
use super::euler::{eulerian_circuit, shortcut_to_hamiltonian};
use super::matching::greedy_min_weight_matching;
use super::mst::{prim_mst, prim_mst_dense};

/// Build a Hamiltonian cycle over the nodes of `g` (must be complete or
/// at least metric-complete on weights; the connectivity graph is).
/// Returns the visiting order; the ring edges are consecutive pairs plus
/// the closing edge.
pub fn christofides_cycle(g: &Graph) -> Vec<NodeId> {
    let n = g.n();
    assert!(n >= 2, "ring needs >= 2 nodes");
    if n == 2 {
        return vec![0, 1];
    }
    let mst = prim_mst(g);
    let odd = mst.odd_degree_nodes();
    let matching = greedy_min_weight_matching(&odd, |u, v| {
        g.edge_weight(u, v)
            .unwrap_or_else(|| panic!("connectivity graph missing edge ({u},{v})"))
    });
    // MST + matching = multigraph with all-even degrees.
    let mut edges: Vec<(NodeId, NodeId)> =
        mst.edges().iter().map(|e| (e.u, e.v)).collect();
    edges.extend(matching);
    let circuit = eulerian_circuit(n, &edges);
    let cycle = shortcut_to_hamiltonian(&circuit);
    assert_eq!(cycle.len(), n, "shortcut did not visit every node");
    cycle
}

/// The overlay graph: ring edges from the Christofides cycle, weighted by
/// the connectivity weights.
pub fn ring_overlay(g: &Graph) -> Graph {
    let cycle = christofides_cycle(g);
    let n = g.n();
    let mut overlay = Graph::new(n);
    for i in 0..cycle.len() {
        let u = cycle[i];
        let v = cycle[(i + 1) % cycle.len()];
        if n == 2 && i == 1 {
            break; // 2-node ring is a single edge, not a double edge
        }
        let w = g.edge_weight(u, v).expect("cycle edge missing from connectivity");
        overlay.add_edge(u, v, w);
    }
    overlay
}

/// [`christofides_cycle`] over the dense slab. The MST is bit-identical
/// ([`prim_mst_dense`]), the matching oracle reads the same weights in
/// O(1) instead of an O(N) adjacency walk per probe (the step that made
/// the sparse path O(N³) at large N), and Euler/shortcut are shared —
/// so the cycle is byte-identical to the sparse reference.
pub fn christofides_cycle_dense(g: &DenseGraph) -> Vec<NodeId> {
    let n = g.n();
    assert!(n >= 2, "ring needs >= 2 nodes");
    if n == 2 {
        return vec![0, 1];
    }
    let mst = prim_mst_dense(g);
    let odd = mst.odd_degree_nodes();
    let matching = greedy_min_weight_matching(&odd, |u, v| g.weight(u, v));
    let mut edges: Vec<(NodeId, NodeId)> =
        mst.edges().iter().map(|e| (e.u, e.v)).collect();
    edges.extend(matching);
    let circuit = eulerian_circuit(n, &edges);
    let cycle = shortcut_to_hamiltonian(&circuit);
    assert_eq!(cycle.len(), n, "shortcut did not visit every node");
    cycle
}

/// [`ring_overlay`] over the dense slab: the overlay itself stays a
/// sparse [`Graph`] (it has N edges), only the complete substrate is
/// dense.
pub fn ring_overlay_dense(g: &DenseGraph) -> Graph {
    let cycle = christofides_cycle_dense(g);
    let n = g.n();
    let mut overlay = Graph::new(n);
    for i in 0..cycle.len() {
        let u = cycle[i];
        let v = cycle[(i + 1) % cycle.len()];
        if n == 2 && i == 1 {
            break; // 2-node ring is a single edge, not a double edge
        }
        overlay.add_edge(u, v, g.weight(u, v));
    }
    overlay
}

/// Tour length under graph weights (for tests / diagnostics).
pub fn cycle_weight(g: &Graph, cycle: &[NodeId]) -> f64 {
    (0..cycle.len())
        .map(|i| {
            g.edge_weight(cycle[i], cycle[(i + 1) % cycle.len()])
                .expect("cycle uses a non-edge")
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric_complete(points: &[(f64, f64)]) -> Graph {
        Graph::complete(points.len(), |u, v| {
            let (x1, y1) = points[u];
            let (x2, y2) = points[v];
            ((x1 - x2).powi(2) + (y1 - y2).powi(2)).sqrt()
        })
    }

    #[test]
    fn cycle_is_hamiltonian() {
        let pts: Vec<(f64, f64)> =
            (0..9).map(|i| ((i % 3) as f64, (i / 3) as f64)).collect();
        let g = metric_complete(&pts);
        let cycle = christofides_cycle(&g);
        assert_eq!(cycle.len(), 9);
        let set: std::collections::BTreeSet<_> = cycle.iter().collect();
        assert_eq!(set.len(), 9);
    }

    #[test]
    fn overlay_is_a_ring() {
        let pts: Vec<(f64, f64)> = (0..7)
            .map(|i| {
                let a = i as f64 / 7.0 * std::f64::consts::TAU;
                (a.cos(), a.sin())
            })
            .collect();
        let g = metric_complete(&pts);
        let overlay = ring_overlay(&g);
        assert_eq!(overlay.edges().len(), 7);
        assert!(overlay.is_connected());
        for u in 0..7 {
            assert_eq!(overlay.degree(u), 2, "ring degree must be 2");
        }
    }

    #[test]
    fn near_optimal_on_circle_points() {
        // Points on a circle: optimal tour is the circle order. The
        // Christofides ratio bound is 1.5; greedy matching keeps us close
        // in practice — assert within 1.6x of optimal here.
        let n = 12;
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|i| {
                let a = i as f64 / n as f64 * std::f64::consts::TAU;
                (a.cos(), a.sin())
            })
            .collect();
        let g = metric_complete(&pts);
        let cycle = christofides_cycle(&g);
        let opt: f64 = (0..n)
            .map(|i| {
                let (x1, y1) = pts[i];
                let (x2, y2) = pts[(i + 1) % n];
                ((x1 - x2).powi(2) + (y1 - y2).powi(2)).sqrt()
            })
            .sum();
        let got = cycle_weight(&g, &cycle);
        assert!(got <= 1.6 * opt + 1e-9, "tour {got} vs optimal {opt}");
    }

    #[test]
    fn two_node_ring_is_single_edge() {
        let g = Graph::complete(2, |_, _| 3.0);
        let overlay = ring_overlay(&g);
        assert_eq!(overlay.edges().len(), 1);
    }

    #[test]
    fn deterministic() {
        let g = Graph::complete(10, |u, v| ((u * 31 + v * 17) % 23) as f64 + 1.0);
        assert_eq!(christofides_cycle(&g), christofides_cycle(&g));
    }

    #[test]
    fn dense_cycle_is_byte_identical_to_sparse() {
        for n in [2usize, 3, 7, 12, 25] {
            let w = |u: usize, v: usize| ((u * 31 + v * 17) % 23) as f64 + 1.0;
            let sparse = christofides_cycle(&Graph::complete(n, w));
            let dense = christofides_cycle_dense(&DenseGraph::from_fn(n, w));
            assert_eq!(dense, sparse, "n={n}");
        }
    }

    #[test]
    fn dense_overlay_is_byte_identical_to_sparse() {
        for n in [2usize, 9, 14] {
            let w = |u: usize, v: usize| ((u * 5 + v * 19) % 13) as f64 + 0.25;
            let a = ring_overlay(&Graph::complete(n, w));
            let b = ring_overlay_dense(&DenseGraph::from_fn(n, w));
            assert_eq!(a.edges().len(), b.edges().len(), "n={n}");
            for (x, y) in a.edges().iter().zip(b.edges()) {
                assert_eq!((x.u, x.v, x.w.to_bits()), (y.u, y.v, y.w.to_bits()), "n={n}");
            }
        }
    }
}
