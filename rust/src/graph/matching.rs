//! Min-weight perfect matching (Christofides step 2) and maximal-matching
//! extraction (MATCHA's decomposition).
//!
//! Exact min-weight perfect matching is Blossom-V territory; for
//! Christofides a greedy matching suffices to keep the 3/2-ish quality on
//! metric weights, and is what practical RING implementations ship. We
//! additionally run a single improvement pass (2-opt swap over matched
//! pairs) which closes most of the greedy gap on geo-metric inputs.

use super::digraph::NodeId;

/// Greedy min-weight perfect matching over `nodes`, using `w(u, v)` as the
/// (symmetric) weight oracle. `nodes.len()` must be even (guaranteed by
/// the handshake lemma when called on odd-degree vertices).
///
/// Returns matched pairs `(u, v)`.
pub fn greedy_min_weight_matching(
    nodes: &[NodeId],
    mut w: impl FnMut(NodeId, NodeId) -> f64,
) -> Vec<(NodeId, NodeId)> {
    assert!(nodes.len() % 2 == 0, "perfect matching needs an even node set");
    let npairs = nodes.len() * nodes.len().saturating_sub(1) / 2;
    // Parallel weight/endpoint slabs plus a sorted index slab: the sort
    // moves 4-byte indices instead of the old 24-byte (f64, u, v)
    // triples (at large N the odd set — and so this quadratic pair set —
    // dominates Christofides construction). The stable sort preserves
    // generation order on ties, exactly like the old triple sort.
    let mut weights: Vec<f64> = Vec::with_capacity(npairs);
    let mut ends: Vec<(u32, u32)> = Vec::with_capacity(npairs);
    for (i, &u) in nodes.iter().enumerate() {
        for &v in &nodes[i + 1..] {
            weights.push(w(u, v));
            ends.push((u as u32, v as u32));
        }
    }
    let mut order: Vec<u32> = (0..npairs as u32).collect();
    order.sort_by(|&a, &b| weights[a as usize].total_cmp(&weights[b as usize]));
    // Flat marker pass over the sorted pairs: node ids are dense graph
    // indices, so they index `used` directly — O(1) per probe with one
    // allocation total, where the old BTreeSet paid O(log k) plus a
    // node allocation per insert on a path that construction caching
    // has made hot.
    let mut used = vec![false; nodes.iter().map(|&u| u + 1).max().unwrap_or(0)];
    let mut matching = Vec::with_capacity(nodes.len() / 2);
    for &p in &order {
        let (u, v) = ends[p as usize];
        let (u, v) = (u as usize, v as usize);
        if !used[u] && !used[v] {
            used[u] = true;
            used[v] = true;
            matching.push((u, v));
        }
    }
    debug_assert_eq!(matching.len() * 2, nodes.len());
    improve_matching(&mut matching, &mut w);
    matching
}

/// One 2-opt pass: for every pair of matched edges (a,b),(c,d) try the
/// re-pairings (a,c),(b,d) and (a,d),(b,c); keep the cheapest.
fn improve_matching(m: &mut [(NodeId, NodeId)], w: &mut impl FnMut(NodeId, NodeId) -> f64) {
    let len = m.len();
    for i in 0..len {
        for j in (i + 1)..len {
            let (a, b) = m[i];
            let (c, d) = m[j];
            let cur = w(a, b) + w(c, d);
            let s1 = w(a, c) + w(b, d);
            let s2 = w(a, d) + w(b, c);
            if s1 < cur && s1 <= s2 {
                m[i] = (a, c);
                m[j] = (b, d);
            } else if s2 < cur {
                m[i] = (a, d);
                m[j] = (b, c);
            }
        }
    }
}

/// Extract a maximal matching from an edge list, preferring low weights.
/// Used by the MATCHA decomposition: repeatedly peel maximal matchings
/// until no edges remain.
pub fn maximal_matching(edges: &[(NodeId, NodeId, f64)]) -> Vec<(NodeId, NodeId, f64)> {
    let mut sorted: Vec<_> = edges.to_vec();
    sorted.sort_by(|a, b| a.2.total_cmp(&b.2));
    // Same flat marker pass as `greedy_min_weight_matching`.
    let mut used = vec![false; edges.iter().map(|&(u, v, _)| u.max(v) + 1).max().unwrap_or(0)];
    let mut out = Vec::new();
    for (u, v, w) in sorted {
        if !used[u] && !used[v] {
            used[u] = true;
            used[v] = true;
            out.push((u, v, w));
        }
    }
    out
}

/// Decompose an edge set into disjoint matchings (greedy peeling).
/// Vizing's theorem bounds the count by Δ+1; greedy typically lands there.
pub fn matching_decomposition(
    edges: &[(NodeId, NodeId, f64)],
) -> Vec<Vec<(NodeId, NodeId, f64)>> {
    let mut remaining: Vec<_> = edges.to_vec();
    let mut out = Vec::new();
    while !remaining.is_empty() {
        let m = maximal_matching(&remaining);
        assert!(!m.is_empty(), "maximal matching of non-empty edge set is empty");
        let taken: std::collections::BTreeSet<(NodeId, NodeId)> =
            m.iter().map(|&(u, v, _)| (u, v)).collect();
        remaining.retain(|&(u, v, _)| !taken.contains(&(u, v)));
        out.push(m);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matching_is_perfect_and_disjoint() {
        let nodes = vec![0, 1, 2, 3, 4, 5];
        let m = greedy_min_weight_matching(&nodes, |u, v| ((u * 3 + v * 5) % 7) as f64);
        assert_eq!(m.len(), 3);
        let mut seen = std::collections::BTreeSet::new();
        for (u, v) in m {
            assert!(seen.insert(u));
            assert!(seen.insert(v));
        }
    }

    #[test]
    fn matching_picks_cheap_pairs_on_line() {
        // Points on a line: 0,1, 10,11 -> optimal matching pairs neighbors.
        let pos: [f64; 4] = [0.0, 1.0, 10.0, 11.0];
        let nodes = vec![0, 1, 2, 3];
        let m = greedy_min_weight_matching(&nodes, |u, v| (pos[u] - pos[v]).abs());
        let cost: f64 = m.iter().map(|&(u, v)| (pos[u] - pos[v]).abs()).sum();
        assert_eq!(cost, 2.0);
    }

    #[test]
    fn two_opt_improves_adversarial_greedy() {
        // Greedy takes (1,2) cost 1 first, forcing (0,3) cost 100.
        // Optimal is (0,1)+(2,3) = 2+2. 2-opt must find it.
        let w = |u: NodeId, v: NodeId| -> f64 {
            match (u.min(v), u.max(v)) {
                (1, 2) => 1.0,
                (0, 1) | (2, 3) => 2.0,
                (0, 3) => 100.0,
                _ => 50.0,
            }
        };
        let m = greedy_min_weight_matching(&[0, 1, 2, 3], w);
        let cost: f64 = m.iter().map(|&(u, v)| w(u, v)).sum();
        assert_eq!(cost, 4.0);
    }

    #[test]
    #[should_panic(expected = "even")]
    fn rejects_odd_node_set() {
        greedy_min_weight_matching(&[0, 1, 2], |_, _| 1.0);
    }

    #[test]
    fn marker_pass_handles_sparse_node_ids() {
        // Odd-degree vertex sets are arbitrary subsets of 0..n, so the
        // flat `used` vec must be sized by the max id, not the count.
        let m = greedy_min_weight_matching(&[3, 10, 21, 4], |u, v| (u as f64 - v as f64).abs());
        let mut pairs: Vec<(NodeId, NodeId)> =
            m.iter().map(|&(u, v)| (u.min(v), u.max(v))).collect();
        pairs.sort();
        assert_eq!(pairs, vec![(3, 4), (10, 21)]);
        let mm = maximal_matching(&[(9, 2, 1.0), (2, 5, 0.5), (9, 30, 2.0)]);
        assert_eq!(mm, vec![(2, 5, 0.5), (9, 30, 2.0)]);
        assert!(maximal_matching(&[]).is_empty());
        assert!(greedy_min_weight_matching(&[], |_, _| 0.0).is_empty());
    }

    #[test]
    fn decomposition_covers_all_edges_disjointly() {
        // K4 edge set: Δ=3, expect ~3 matchings.
        let edges: Vec<(NodeId, NodeId, f64)> = vec![
            (0, 1, 1.0),
            (0, 2, 2.0),
            (0, 3, 3.0),
            (1, 2, 4.0),
            (1, 3, 5.0),
            (2, 3, 6.0),
        ];
        let parts = matching_decomposition(&edges);
        let total: usize = parts.iter().map(|m| m.len()).sum();
        assert_eq!(total, edges.len());
        for m in &parts {
            let mut seen = std::collections::BTreeSet::new();
            for &(u, v, _) in m {
                assert!(seen.insert(u) && seen.insert(v), "matching not disjoint");
            }
        }
        assert!(parts.len() <= 4, "K4 should decompose into <= Δ+1 matchings");
    }

    #[test]
    fn decomposition_of_ring() {
        // Even cycle: exactly 2 matchings suffice; greedy must not exceed 3.
        let edges: Vec<(NodeId, NodeId, f64)> =
            (0..6).map(|i| (i, (i + 1) % 6, 1.0)).collect();
        let parts = matching_decomposition(&edges);
        assert!(parts.len() <= 3);
        assert_eq!(parts.iter().map(|m| m.len()).sum::<usize>(), 6);
    }
}
