//! `mgfl` — CLI for the multigraph cross-silo FL framework.
//!
//! Subcommands regenerate each paper table/figure (see DESIGN.md §6) or
//! run ad-hoc simulations and real training. Every simulation-grid
//! subcommand (`table1/3/4/6`, `sweep`) is a thin adapter over the
//! parallel sweep engine ([`mgfl::sweep`]): it expands a grid, runs the
//! cells across threads, and renders slices of the report.

use anyhow::Result;

use mgfl::config::{ExperimentConfig, TopologyKind, TrainConfig};
use mgfl::metrics::render_table;
use mgfl::net::{zoo, DatasetProfile};
use mgfl::simtime::{simulate, simulate_summary, simulate_summary_compiled_with_stats};
use mgfl::store::CellStore;
use mgfl::sweep::{self, Axis, RunOptions, SweepFile, SweepSpec};
use mgfl::topo::{MultigraphTopology, TopologyDesign};
use mgfl::util::args::Args;

const USAGE: &str = "\
mgfl — multigraph topology for cross-silo federated learning

USAGE: mgfl <subcommand> [--flag value ...]

SUBCOMMANDS
  simulate  --network gaia --profile femnist --topology multigraph --t 5 --rounds 6400 --seed 17
  sweep     [spec.toml] [--threads 0] [--out results] [--name sweep] [--rounds 6400]
            [--topologies all|a,b] [--networks all|a,b] [--profiles all|a,b]
            [--t 1,3,5] [--seeds 17,18] [--no-dedup] [--store PATH] [--no-store]
            [--allow-errors]
  optimize  [spec.toml] [--name optimize] [--network gaia] [--profile femnist]
            [--strategy hill|anneal] [--chains 4] [--steps 400] [--rounds 600]
            [--seed 17] [--deadline-ms 0] [--threads 0] [--out results]
            [--store PATH]
  serve     --store PATH [--addr 127.0.0.1:7700] [--threads 0]
  cache     <stats|verify|gc> --store PATH
  scale     [--sizes 64,256,1024] [--variant geo|sphere] [--seed 7]
            [--profile femnist] [--t 5] [--rounds 0]
  train     <config.toml> [--eval-every 10] [--csv out.csv]
  table1    [--rounds 6400] [--t 5] [--profile femnist] [--threads 0]
  table2
  table3    [--rounds 6400] [--t 5] [--threads 0]
  table4    [--rounds 6400] [--train-rounds 0] [--threads 0]
  table5    [--rounds 40] [--model femnist_mlp] [--network gaia]
  table6    [--rounds 6400] [--train-rounds 0] [--threads 0]
  fig1      [--rounds 6400] [--train-rounds 30] [--model femnist_mlp]
  fig4      [--t 3]
  fig5      [--rounds 40] [--model femnist_mlp] [--network exodus] [--out results]

`--threads 0` means one worker per core; sweep artifacts are
byte-identical for any thread count. Sweeps deduplicate cells that are
provably identical (deterministic topologies replicated across seeds)
and fan the results out; `--no-dedup` forces every cell to simulate —
the artifacts are byte-identical either way.

Network axes accept the five zoo names and synthetic large-N networks
by name: synth-geo-n1024-s7 / synth-sphere-n256-s17 (variant, silo
count, generator seed). `scale` times topology construction per design
across synthetic sizes (add --rounds to also simulate each cell).

`--store PATH` points sweeps and searches at a persistent on-disk cell
store: previously simulated cells are served from disk and new results
are written back, so re-running a spec simulates only what changed —
with byte-identical artifacts either way. Spec files may carry a
`[store]` section; `--store` overrides it and `--no-store` disables it.
`serve` keeps one store open behind a local HTTP/JSON endpoint, and
`cache` inspects (stats), audits (verify), or compacts (gc) a store.

Sweep spec files may also carry `[events]` (deterministic fault
injection) and `[adapt]` (online re-planning at segment boundaries;
policies none|rebuild|warm) sections — see docs/SPECS.md. A sweep with
failed cells (engine=\"error\" rows in the artifacts) exits nonzero
unless `--allow-errors` is passed. `optimize --deadline-ms N` stops
chains gracefully at a wall-clock budget; truncated searches set
`budget_exhausted` in the report.
";

fn resolve_profile(name: &str) -> Result<DatasetProfile> {
    DatasetProfile::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown profile {name}"))
}

fn main() -> Result<()> {
    // Die quietly when stdout is a closed pipe (`mgfl table1 | head`),
    // like every other unix CLI.
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let args = Args::from_env();
    if args.has("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    run(args)
}

fn run(args: Args) -> Result<()> {
    match args.require_sub(USAGE)? {
        "simulate" => {
            let network = args.get_str("network", "gaia");
            let profile = args.get_str("profile", "femnist");
            let topology: TopologyKind = args.get_str("topology", "multigraph").parse()?;
            let t: u32 = args.get("t", 5)?;
            let rounds: usize = args.get("rounds", 6400)?;
            let seed: u64 = args.get("seed", 17)?;
            let cfg = ExperimentConfig {
                network,
                profile,
                topology,
                t,
                sim_rounds: rounds,
                seed,
                train: None,
            };
            cfg.validate()?;
            let net = cfg.resolve_network();
            let prof = cfg.resolve_profile()?;
            let mut topo = cfg.build_topology();
            let res = simulate(topo.as_mut(), &net, &prof, rounds);
            println!(
                "{} / {} / {}: mean cycle {:.1} ms over {} rounds ({} rounds with isolated nodes, total {:.1} s)",
                res.topology,
                res.network,
                res.profile,
                res.mean_cycle_ms,
                res.rounds,
                res.rounds_with_isolated,
                res.total_ms / 1e3,
            );
        }
        "sweep" => sweep_cmd(&args)?,
        "optimize" => optimize_cmd(&args)?,
        "serve" => serve_cmd(&args)?,
        "cache" => cache_cmd(&args)?,
        "scale" => scale_cmd(&args)?,
        "train" => {
            let config = args
                .positional
                .first()
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("train requires a config path\n{USAGE}"))?;
            let eval_every: usize = args.get("eval-every", 10)?;
            let csv = args.flag("csv").map(String::from);
            let cfg = ExperimentConfig::from_toml_file(&config)?;
            let mut trainer = mgfl::coordinator::Trainer::from_config(&cfg)?;
            eprintln!(
                "training {} on {} ({} silos, topology {})",
                cfg.train.as_ref().unwrap().model,
                cfg.network,
                trainer.num_silos(),
                trainer.topology_name()
            );
            let trace = trainer.run(eval_every)?;
            println!(
                "final: acc {:.2}% | train loss {:.4} | sim time {:.1} s | host {:.1} s",
                trace.final_accuracy().unwrap_or(f64::NAN) * 100.0,
                trace.final_train_loss().unwrap_or(f64::NAN),
                trace.total_sim_ms() / 1e3,
                trace.host_elapsed_ms / 1e3,
            );
            if let Some(path) = csv {
                trace.write_csv(&path)?;
                eprintln!("trace -> {path}");
            }
        }
        "table1" => table1(
            args.get("rounds", 6400)?,
            args.get("t", 5)?,
            args.flag("profile").map(String::from),
            args.get("threads", 0)?,
        )?,
        "table2" => {
            let manifest = mgfl::runtime::Manifest::load(mgfl::runtime::default_artifacts_dir())?;
            let mut rows = Vec::new();
            for (name, e) in &manifest.models {
                rows.push(vec![
                    name.clone(),
                    format!("{}", e.param_count),
                    format!("{:.2}", e.model_size_mb),
                    format!("{}", e.train_batch),
                    format!("{}", e.num_classes),
                ]);
            }
            println!("== Table 2 — model statistics (from artifacts/manifest.json) ==");
            print!(
                "{}",
                render_table(&["model", "#params", "size MB", "batch", "classes"], &rows)
            );
        }
        "table3" => table3(args.get("rounds", 6400)?, args.get("t", 5)?, args.get("threads", 0)?)?,
        "table4" => table4(
            args.get("rounds", 6400)?,
            args.get("train-rounds", 0)?,
            args.get("threads", 0)?,
        )?,
        "table5" => table5(
            args.get("rounds", 40)?,
            &args.get_str("model", "femnist_mlp"),
            &args.get_str("network", "gaia"),
        )?,
        "table6" => table6(
            args.get("rounds", 6400)?,
            args.get("train-rounds", 0)?,
            args.get("threads", 0)?,
        )?,
        "fig1" => fig1(
            args.get("rounds", 6400)?,
            args.get("train-rounds", 30)?,
            &args.get_str("model", "femnist_mlp"),
        )?,
        "fig4" => fig4(args.get("t", 3)?),
        "fig5" => fig5(
            args.get("rounds", 40)?,
            &args.get_str("model", "femnist_mlp"),
            &args.get_str("network", "exodus"),
            &args.get_str("out", "results"),
        )?,
        other => anyhow::bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
    Ok(())
}

/// `mgfl sweep`: run an arbitrary grid — from a TOML spec file, from
/// axis flags, or both (flags override the file) — and write JSON/CSV
/// artifacts.
fn sweep_cmd(args: &Args) -> Result<()> {
    let defaults = SweepSpec::default();
    let (mut spec, file_store) = match args.positional.first() {
        Some(path) => {
            let file = SweepFile::from_toml_file(path)?;
            (file.spec, file.store)
        }
        None => (defaults.clone(), None),
    };
    if let Some(name) = args.flag("name") {
        spec.name = name.to_string();
    }
    spec.rounds = args.get("rounds", spec.rounds)?;
    if let Some(items) = args.get_list("topologies") {
        spec.topologies = SweepSpec::parse_topologies(&items)?;
    }
    if let Some(items) = args.get_list("networks") {
        spec.networks = SweepSpec::axis_or_all(items, &defaults.networks);
    }
    if let Some(items) = args.get_list("profiles") {
        spec.profiles = SweepSpec::axis_or_all(items, &defaults.profiles);
    }
    if let Some(ts) = args.get_parsed_list::<u32>("t")? {
        spec.t_values = ts;
    }
    if let Some(seeds) = args.get_parsed_list::<u64>("seeds")? {
        spec.seeds = seeds;
    }
    // Canonicalize here too (not just inside sweep::run) so the slice
    // filters below compare against the same names the report carries.
    spec.canonicalize()?;
    spec.validate()?;

    let threads: usize = args.get("threads", 0)?;
    let dedup = !args.has("no-dedup");
    // Store resolution: `--no-store` beats `--store PATH` beats the
    // spec file's `[store]` section (if enabled).
    let store_path = if args.has("no-store") {
        None
    } else if let Some(path) = args.flag("store") {
        Some(path.to_string())
    } else {
        file_store.filter(|s| s.enabled).map(|s| s.path)
    };
    let store = store_path.map(CellStore::open).transpose()?;
    eprintln!(
        "sweep '{}': {} cells ({} topologies x {} networks x {} profiles x {} t x {} seeds{}, {} rounds)",
        spec.name,
        spec.cell_count(),
        spec.topologies.len(),
        spec.networks.len(),
        spec.profiles.len(),
        spec.t_values.len(),
        spec.seeds.len(),
        if spec.adapt.is_empty() {
            String::new()
        } else {
            format!(" x {} adapt policies", spec.adapt.len())
        },
        spec.rounds,
    );
    if let Some(sc) = &spec.scenario {
        eprintln!(
            "  scenario: seed {} with {} event(s) — fault injection via piecewise-static dispatch",
            sc.seed,
            sc.events.len()
        );
    }
    if spec.is_adaptive() {
        let policies: Vec<&str> = spec.adapt.iter().map(|a| a.policy.as_str()).collect();
        eprintln!(
            "  adapt: policies [{}] — online re-planning at scenario segment boundaries",
            policies.join(", ")
        );
    }
    let outcome = sweep::run_with_store(
        &spec,
        &RunOptions { threads, progress: true, dedup },
        store.as_ref(),
    )?;
    let (json_path, csv_path) = outcome.report.write_artifacts(args.get_str("out", "results"))?;

    // One table per (profile, t) pair: a slice must only ever average
    // over the seed axis, so multi-t specs get one grid per t instead of
    // a silently t-averaged table.
    for prof in &spec.profiles {
        for &t in &spec.t_values {
            let t_label =
                if spec.t_values.len() > 1 { format!(", t={t}") } else { String::new() };
            println!(
                "\n== sweep '{}' — {}{} (mean cycle ms over seeds; {} rounds) ==",
                spec.name, prof, t_label, spec.rounds
            );
            print!(
                "{}",
                outcome.report.render_slice(Axis::Network, Axis::Topology, |c| {
                    &c.profile == prof && c.t == t
                })
            );
        }
    }
    let store_note = match &store {
        Some(st) => format!(
            "; store: {} hits + {} misses @ {}",
            outcome.store_hits,
            outcome.store_misses,
            st.dir().display()
        ),
        None => String::new(),
    };
    let errors = outcome.report.cells.iter().filter(|c| c.error.is_some()).count();
    let scenario_note = if outcome.report.scenario {
        format!("; scenario mode: {errors} engine=\"error\" cell(s)")
    } else if errors > 0 {
        format!("; {errors} engine=\"error\" cell(s)")
    } else {
        String::new()
    };
    println!(
        "\n{} cells ({} unique simulated, {:.1}x dedup) in {:.2} s on {} threads ({:.1} cells/s; worker time: build {:.2} s + sim {:.2} s; engines: {}{}{})",
        outcome.report.cells.len(),
        outcome.unique_cells,
        outcome.dedup_ratio(),
        outcome.host_elapsed_ms / 1e3,
        outcome.threads,
        outcome.cells_per_sec(),
        outcome.build_ms / 1e3,
        outcome.sim_ms / 1e3,
        outcome.engines.describe(),
        store_note,
        scenario_note,
    );
    println!("artifacts: {} | {}", json_path.display(), csv_path.display());
    // A failed cell is a failed sweep: the artifacts record the error
    // rows either way, but the exit status should not look green unless
    // the caller explicitly opted into partial results.
    if errors > 0 && !args.has("allow-errors") {
        anyhow::bail!(
            "{errors} cell(s) failed (engine=\"error\" rows in the artifacts); \
             pass --allow-errors to accept a partial sweep"
        );
    }
    Ok(())
}

/// `mgfl optimize`: search the topology design space (ring order,
/// chords, t) for minimum simulated cycle time — from a TOML spec,
/// from flags, or both (flags override the file) — and write the
/// deterministic SearchReport JSON/CSV artifacts.
fn optimize_cmd(args: &Args) -> Result<()> {
    use mgfl::search::OptimizeSpec;
    let mut spec = match args.positional.first() {
        Some(path) => OptimizeSpec::from_toml_file(path)?,
        None => OptimizeSpec::default(),
    };
    if let Some(name) = args.flag("name") {
        spec.name = name.to_string();
    }
    if let Some(network) = args.flag("network") {
        spec.network = network.to_string();
    }
    if let Some(profile) = args.flag("profile") {
        spec.profile = profile.to_string();
    }
    if let Some(strategy) = args.flag("strategy") {
        spec.strategy = strategy.parse()?;
    }
    spec.rounds = args.get("rounds", spec.rounds)?;
    spec.seed = args.get("seed", spec.seed)?;
    spec.chains = args.get("chains", spec.chains)?;
    spec.steps = args.get("steps", spec.steps)?;
    spec.deadline_ms = args.get("deadline-ms", spec.deadline_ms)?;
    spec.canonicalize()?;
    spec.validate()?;

    let threads: usize = args.get("threads", 0)?;
    eprintln!(
        "optimize '{}': {} on {}/{} — {} {} chains x {} steps, {} rounds/eval, seed {}",
        spec.name,
        spec.strategy.as_str(),
        spec.network,
        spec.profile,
        spec.chains,
        if spec.chains == 1 { "chain" } else { "chains" },
        spec.steps,
        spec.rounds,
        spec.seed,
    );
    let store = args.flag("store").map(CellStore::open).transpose()?;
    let outcome = mgfl::search::run_with_store(
        &spec,
        &RunOptions { threads, ..Default::default() },
        store.as_ref(),
    )?;
    let report = &outcome.report;
    let (json_path, csv_path) = report.write_artifacts(args.get_str("out", "results"))?;

    let mut rows = Vec::new();
    for b in &report.baselines {
        rows.push(vec![b.topology.clone(), format!("t={}", b.t), format!("{:.3}", b.mean_cycle_ms)]);
    }
    for p in &report.budget_probes {
        rows.push(vec!["matcha".into(), format!("Cb={}", p.budget), format!("{:.3}", p.mean_cycle_ms)]);
    }
    rows.push(vec![
        "searched (best)".into(),
        format!("t={}", report.best.t),
        format!("{:.3}", report.best.mean_cycle_ms),
    ]);
    println!(
        "\n== optimize '{}' — {}/{} (mean cycle ms over {} rounds) ==",
        report.name, report.network, report.profile, report.rounds
    );
    print!("{}", render_table(&["design", "param", "cycle ms"], &rows));
    let chords = if report.best.chords.is_empty() {
        "none".to_string()
    } else {
        report
            .best
            .chords
            .iter()
            .map(|(u, v)| format!("{u}-{v}"))
            .collect::<Vec<_>>()
            .join(", ")
    };
    println!(
        "\nbest (chain {}): {:.3} ms — {:.2}% better than the paper multigraph",
        report.best_chain, report.best.mean_cycle_ms, report.improvement_pct
    );
    println!(
        "  order: {:?}\n  chords: {chords}\n  t: {}",
        report.best.order, report.best.t
    );
    let accepted: usize = report.chains.iter().map(|c| c.accepted).sum();
    let store_note = match &store {
        Some(st) => format!(
            "; store: {} hits + {} misses @ {}",
            outcome.store_hits,
            outcome.store_misses,
            st.dir().display()
        ),
        None => String::new(),
    };
    let deadline_note = if report.budget_exhausted {
        format!("; wall-clock budget exhausted ({} ms deadline)", spec.deadline_ms)
    } else {
        String::new()
    };
    println!(
        "{} unique candidates simulated ({} cache hits, {} accepted moves) in {:.2} s on {} threads{}{}",
        report.unique_evals,
        report.cache_hits,
        accepted,
        outcome.host_elapsed_ms / 1e3,
        outcome.threads,
        store_note,
        deadline_note,
    );
    println!("artifacts: {} | {}", json_path.display(), csv_path.display());
    Ok(())
}

/// `mgfl serve`: keep one store open behind a local HTTP/JSON endpoint
/// so the warm cache amortizes across processes (routes: GET /health,
/// GET /stats, POST /sweep — see [`mgfl::store::serve`]).
fn serve_cmd(args: &Args) -> Result<()> {
    let path = args
        .flag("store")
        .ok_or_else(|| anyhow::anyhow!("serve requires --store PATH\n{USAGE}"))?;
    let addr = args.get_str("addr", "127.0.0.1:7700");
    let threads: usize = args.get("threads", 0)?;
    let store = std::sync::Arc::new(CellStore::open(path)?);
    let server = mgfl::store::serve::Server::bind(&addr, store, threads)?;
    mgfl::store::serve::install_signal_handlers();
    eprintln!(
        "mgfl serve: store {path} (epoch {}) at http://{} — GET /health, GET /stats, POST /sweep (Ctrl-C drains and exits)",
        mgfl::store::ENGINE_EPOCH,
        server.local_addr()?,
    );
    server.run()?;
    eprintln!("mgfl serve: shutdown complete (in-flight connections drained)");
    Ok(())
}

/// `mgfl cache`: inspect (stats), audit (verify), or compact (gc) a
/// persistent cell store without running anything.
fn cache_cmd(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("cache requires an action: stats|verify|gc\n{USAGE}"))?;
    let path = args
        .flag("store")
        .ok_or_else(|| anyhow::anyhow!("cache requires --store PATH\n{USAGE}"))?;
    match action.as_str() {
        "stats" => {
            let store = CellStore::open(path)?;
            let s = store.stats()?;
            println!(
                "store {path} (epoch {}): {} entries in {} records across {} shard files, {} bytes",
                store.epoch(),
                s.entries,
                s.records,
                s.shard_files,
                s.bytes,
            );
            println!(
                "  cells: {} static + {} scenario + {} adaptive; {} other entr{} (fitness/probe)",
                s.static_cells,
                s.scenario_cells,
                s.adaptive_cells,
                s.other_entries,
                if s.other_entries == 1 { "y" } else { "ies" },
            );
        }
        "verify" => {
            let report = mgfl::store::verify(path)?;
            println!(
                "store {path}: {} files, {} records, {} torn tails, {} corrupt",
                report.files,
                report.records,
                report.torn_tails,
                report.corrupt.len(),
            );
            for detail in &report.corrupt {
                eprintln!("  corrupt: {detail}");
            }
            anyhow::ensure!(report.ok(), "store {path} failed verification");
        }
        "gc" => {
            let r = mgfl::store::gc(path)?;
            println!(
                "store {path}: removed {} stale files, compacted {} ({} -> {} records, {} -> {} bytes)",
                r.removed_files,
                r.compacted_files,
                r.records_before,
                r.records_after,
                r.bytes_before,
                r.bytes_after,
            );
        }
        other => anyhow::bail!("unknown cache action '{other}' (stats|verify|gc)\n{USAGE}"),
    }
    Ok(())
}

/// `mgfl scale`: construction wall-clock per design across synthetic
/// network sizes — the CLI view of the large-N axis the scaling bench
/// gates. `--rounds N` additionally simulates each cell and reports the
/// mean cycle time next to the build time.
fn scale_cmd(args: &Args) -> Result<()> {
    let sizes: Vec<usize> =
        args.get_parsed_list::<usize>("sizes")?.unwrap_or_else(|| vec![64, 256, 1024]);
    let variant_s = args.get_str("variant", "geo");
    let seed: u64 = args.get("seed", 7)?;
    let profile = args.get_str("profile", "femnist");
    let t: u32 = args.get("t", 5)?;
    let rounds: usize = args.get("rounds", 0)?;
    let prof = resolve_profile(&profile)?;
    let variant = mgfl::net::synth::SynthVariant::parse(&variant_s)
        .ok_or_else(|| anyhow::anyhow!("unknown synth variant '{variant_s}' (geo|sphere)"))?;
    anyhow::ensure!(!sizes.is_empty(), "--sizes must list at least one silo count");

    println!(
        "== scale — construction ms per design (synth-{} networks, {}, t={t}, seed {seed}) ==",
        variant.as_str(),
        prof.name
    );
    let kinds = TopologyKind::all();
    let mut rows = Vec::new();
    for &n in &sizes {
        let name = mgfl::net::synth::name_of(variant, n, seed);
        let net = mgfl::net::by_name(&name)
            .ok_or_else(|| anyhow::anyhow!("'{name}' out of synthesizable range"))?;
        let mut row = vec![format!("{n}")];
        for kind in kinds {
            let t0 = std::time::Instant::now();
            let mut topo = mgfl::config::build_design(kind, &net, &prof, t, seed);
            let build_ms = t0.elapsed().as_secs_f64() * 1e3;
            std::hint::black_box(topo.overlay().edges().len());
            row.push(if rounds > 0 {
                // Build and simulate wall-clocks reported separately
                // (the sweep path's CellTiming split), tagged with the
                // engine the dispatcher picked — large-N multigraph
                // cells should show `f` (factored), and a regression
                // to `s` (streaming) is visible right in the table.
                let t1 = std::time::Instant::now();
                let (s, stats) =
                    simulate_summary_compiled_with_stats(topo.as_mut(), &net, &prof, rounds);
                let sim_ms = t1.elapsed().as_secs_f64() * 1e3;
                let engine = &stats.kind.as_str()[..1];
                format!("{build_ms:.1}+{sim_ms:.1}{engine} ({:.1})", s.mean_cycle_ms)
            } else {
                format!("{build_ms:.1}")
            });
        }
        rows.push(row);
        eprintln!("  n={n} done");
    }
    let mut headers: Vec<&str> = vec!["N"];
    headers.extend(kinds.iter().map(|k| k.as_str()));
    print!("{}", render_table(&headers, &rows));
    if rounds > 0 {
        println!(
            "(cell format: build ms+sim ms over {rounds} rounds, engine \
             p=periodic/b=batched/f=factored/s=streaming (mean cycle ms))"
        );
    }
    Ok(())
}

/// Table 1: the full (topology × network) cycle-time grid per profile,
/// now one parallel sweep instead of a serial double loop.
fn table1(rounds: usize, t: u32, profile: Option<String>, threads: usize) -> Result<()> {
    let profiles = match profile {
        Some(p) => vec![resolve_profile(&p)?.name],
        None => DatasetProfile::all().iter().map(|p| p.name.clone()).collect(),
    };
    let spec = SweepSpec::table1(profiles, t, rounds);
    let outcome = sweep::run(&spec, &RunOptions { threads, progress: true, dedup: true })?;
    for prof in &spec.profiles {
        println!("\n== Table 1 — {prof} (cycle time, ms; {rounds} rounds) ==");
        print!(
            "{}",
            outcome.report.render_slice(Axis::Network, Axis::Topology, |c| &c.profile == prof)
        );
    }
    eprintln!(
        "({} cells in {:.2} s on {} threads; worker time: build {:.2} s + sim {:.2} s)",
        outcome.report.cells.len(),
        outcome.host_elapsed_ms / 1e3,
        outcome.threads,
        outcome.build_ms / 1e3,
        outcome.sim_ms / 1e3,
    );
    Ok(())
}

/// Table 3: isolated-node statistics per network. The multigraph/ring
/// simulations run as a parallel sweep; the per-network state analysis
/// (s_max, states with isolated nodes) is cheap and stays serial.
fn table3(rounds: usize, t: u32, threads: usize) -> Result<()> {
    let spec = SweepSpec {
        name: "table3".into(),
        topologies: vec![TopologyKind::Multigraph, TopologyKind::Ring],
        profiles: vec!["femnist".into()],
        t_values: vec![t],
        rounds,
        ..Default::default()
    };
    let outcome = sweep::run(&spec, &RunOptions { threads, progress: true, dedup: true })?;
    let prof = DatasetProfile::femnist();
    println!("== Table 3 — isolated nodes (FEMNIST, {rounds} rounds, t={t}) ==");
    let mut rows = Vec::new();
    for net in zoo::all_networks() {
        let res = outcome.report.cell("multigraph", &net.name, "femnist").expect("grid cell");
        let ring = outcome.report.cell("ring", &net.name, "femnist").expect("grid cell");
        let topo = MultigraphTopology::from_network(&net, &prof, t);
        let s_max = topo.s_max();
        let iso_states = topo.states_with_isolated(10_000).len();
        rows.push(vec![
            net.name.clone(),
            format!("{}", net.n()),
            format!("{}/{}", res.rounds_with_isolated, rounds),
            format!(
                "{}/{} ({:.1}%)",
                iso_states,
                s_max,
                100.0 * iso_states as f64 / s_max as f64
            ),
            format!("{:.1} (ring {:.1})", res.mean_cycle_ms, ring.mean_cycle_ms),
        ]);
    }
    print!(
        "{}",
        render_table(
            &["network", "silos", "#rounds iso", "#states iso", "cycle ms"],
            &rows
        )
    );
    Ok(())
}

/// One Table 4 row to simulate: which overlay variant, built in-worker.
struct RemovalCell {
    method: String,
    removed: String,
    /// "ring" | "multigraph" | a removal criterion.
    kind: String,
    count: usize,
}

/// Table 4: remove silos from the RING overlay (randomly / most
/// inefficient) vs the multigraph. All ten overlay variants simulate
/// concurrently via the sweep engine's generic cell API; the optional
/// accuracy column (real training) stays serial.
fn table4(rounds: usize, train_rounds: usize, threads: usize) -> Result<()> {
    use mgfl::topo::ring::RingTopology;
    let net = zoo::exodus();
    let prof = DatasetProfile::femnist();
    println!("== Table 4 — silo removal vs multigraph (Exodus, FEMNIST) ==");

    let mut cells = vec![RemovalCell {
        method: "RING baseline".into(),
        removed: "-".into(),
        kind: "ring".into(),
        count: 0,
    }];
    for criterion in ["random", "inefficient"] {
        for removed in [1usize, 5, 10, 20] {
            cells.push(RemovalCell {
                method: format!("RING {criterion} remove"),
                removed: removed.to_string(),
                kind: criterion.into(),
                count: removed,
            });
        }
    }
    cells.push(RemovalCell {
        method: "Multigraph (ours)".into(),
        removed: "-".into(),
        kind: "multigraph".into(),
        count: 0,
    });

    let opts = RunOptions { threads, progress: true, dedup: true };
    let summaries = sweep::run_cells(&cells, &opts, |_, cell| {
        let mut topo: Box<dyn TopologyDesign> = match cell.kind.as_str() {
            "ring" => Box::new(RingTopology::new(&net, &prof)),
            "multigraph" => Box::new(MultigraphTopology::from_network(&net, &prof, 5)),
            criterion => {
                let overlay = RingTopology::new(&net, &prof);
                let reduced = remove_silos(overlay.overlay(), &net, &prof, criterion, cell.count);
                Box::new(RingTopology::from_overlay(reduced))
            }
        };
        simulate_summary(topo.as_mut(), &net, &prof, rounds)
    });

    let acc = |kind: &str, removed: usize| -> String {
        if train_rounds == 0 {
            return String::new();
        }
        train_removed_acc(kind, removed, train_rounds)
            .map_or(String::new(), |a| format!("{:.2}", a * 100.0))
    };
    let rows: Vec<Vec<String>> = cells
        .iter()
        .zip(&summaries)
        .map(|(cell, s)| {
            vec![
                cell.method.clone(),
                cell.removed.clone(),
                format!("{:.1}", s.mean_cycle_ms),
                acc(&cell.kind, cell.count),
            ]
        })
        .collect();
    print!(
        "{}",
        render_table(&["method", "#removed", "cycle ms", "acc %"], &rows)
    );
    Ok(())
}

/// Rebuild a ring overlay over the retained silos (removed silos keep
/// training locally but are cut from the ring).
fn remove_silos(
    overlay: &mgfl::graph::Graph,
    net: &mgfl::net::NetworkSpec,
    prof: &DatasetProfile,
    criterion: &str,
    count: usize,
) -> mgfl::graph::Graph {
    let n = overlay.n();
    let victims: Vec<usize> = match criterion {
        "random" => {
            let mut rng = mgfl::util::Rng64::seed_from_u64(99);
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            idx.into_iter().take(count).collect()
        }
        _ => {
            // most inefficient = largest incident Eq. 3 overlay delay
            let mut scored: Vec<(f64, usize)> = (0..n)
                .map(|i| {
                    let worst = overlay
                        .neighbors(i)
                        .map(|(j, _)| mgfl::delay::eq3_delay_ms(net, prof, i, j, 2, 2))
                        .fold(0.0, f64::max);
                    (worst, i)
                })
                .collect();
            scored.sort_by(|a, b| b.0.total_cmp(&a.0));
            scored.into_iter().take(count).map(|(_, i)| i).collect()
        }
    };
    let keep: Vec<usize> = (0..n).filter(|i| !victims.contains(i)).collect();
    // Dense slab instead of the sparse complete graph: same weights
    // (`conn_weight` is shared), O(1) lookups instead of O(N) adjacency
    // walks per probe.
    let conn = net.connectivity_dense(prof);
    let sub = mgfl::graph::DenseGraph::from_fn(keep.len(), |a, b| conn.weight(keep[a], keep[b]));
    let cycle = mgfl::graph::christofides_cycle_dense(&sub);
    let mut g = mgfl::graph::Graph::new(n);
    for w in 0..cycle.len() {
        let a = keep[cycle[w]];
        let b = keep[cycle[(w + 1) % cycle.len()]];
        g.add_edge(a, b, conn.weight(a, b));
    }
    g
}

/// Short real-training accuracy for Table 4's accuracy column (run on
/// Gaia so the real-compute cost stays tractable; the paper's point —
/// removal hurts accuracy, multigraph does not — is scale-free).
fn train_removed_acc(kind: &str, removed: usize, rounds: usize) -> Result<f64> {
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();
    let cfg = TrainConfig { rounds, model: "femnist_mlp".into(), ..Default::default() };
    let runtime = mgfl::runtime::ModelRuntime::load_default(&cfg.model)?;
    let topo: Box<dyn TopologyDesign> = match kind {
        "multigraph" => Box::new(MultigraphTopology::from_network(&net, &prof, 5)),
        "ring" => Box::new(mgfl::topo::ring::RingTopology::new(&net, &prof)),
        criterion => {
            let overlay = mgfl::topo::ring::RingTopology::new(&net, &prof);
            let reduced = remove_silos(
                overlay.overlay(),
                &net,
                &prof,
                criterion,
                removed.min(net.n() - 3),
            );
            Box::new(mgfl::topo::ring::RingTopology::from_overlay(reduced))
        }
    };
    let mut trainer = mgfl::coordinator::Trainer::new(runtime, topo, net, prof, cfg)?;
    let trace = trainer.run(0)?;
    Ok(trace.final_accuracy().unwrap_or(0.0))
}

/// Table 5: accuracy per topology via real training.
fn table5(rounds: usize, model: &str, network: &str) -> Result<()> {
    let net = zoo::by_name(network).ok_or_else(|| anyhow::anyhow!("unknown network"))?;
    println!(
        "== Table 5 — accuracy after {rounds} rounds ({} silos, model {model}) ==",
        net.n()
    );
    let mut rows = Vec::new();
    for kind in TopologyKind::all() {
        let cfg = ExperimentConfig {
            network: network.into(),
            profile: "femnist".into(),
            topology: kind,
            t: 5,
            sim_rounds: rounds,
            seed: 17,
            train: Some(TrainConfig { rounds, model: model.into(), ..Default::default() }),
        };
        let mut trainer = mgfl::coordinator::Trainer::from_config(&cfg)?;
        let trace = trainer.run(0)?;
        rows.push(vec![
            kind.as_str().into(),
            format!("{:.2}", trace.final_accuracy().unwrap_or(f64::NAN) * 100.0),
            format!("{:.4}", trace.final_train_loss().unwrap_or(f64::NAN)),
            format!("{:.1}", trace.total_sim_ms() / 1e3),
        ]);
        eprintln!("  {} done", kind.as_str());
    }
    print!("{}", render_table(&["topology", "acc %", "train loss", "sim time s"], &rows));
    Ok(())
}

/// Table 6: t sweep on Exodus/FEMNIST — the multigraph grid runs as a
/// parallel sweep over the t axis.
fn table6(rounds: usize, train_rounds: usize, threads: usize) -> Result<()> {
    let net = zoo::exodus();
    let prof = DatasetProfile::femnist();
    println!("== Table 6 — cycle time vs t (Exodus, FEMNIST) ==");
    let mut ring = mgfl::topo::ring::RingTopology::new(&net, &prof);
    let ring_res = simulate_summary(&mut ring, &net, &prof, rounds);
    let mut rows = vec![vec![
        "RING".into(),
        "-".into(),
        format!("{:.1}", ring_res.mean_cycle_ms),
        String::new(),
    ]];
    let spec = SweepSpec {
        name: "table6".into(),
        topologies: vec![TopologyKind::Multigraph],
        networks: vec!["exodus".into()],
        profiles: vec!["femnist".into()],
        t_values: vec![1, 3, 5, 8, 10, 20, 30],
        seeds: vec![17],
        rounds,
        scenario: None,
        adapt: Vec::new(),
    };
    let outcome = sweep::run(&spec, &RunOptions { threads, progress: true, dedup: true })?;
    for &t in &spec.t_values {
        let res = outcome
            .report
            .cells
            .iter()
            .find(|c| c.t == t)
            .expect("grid cell");
        let acc = if train_rounds > 0 {
            format!("{:.2}", train_t_acc(t, train_rounds)? * 100.0)
        } else {
            String::new()
        };
        rows.push(vec![
            "Multigraph".into(),
            format!("{t}"),
            format!("{:.1}", res.mean_cycle_ms),
            acc,
        ]);
    }
    print!("{}", render_table(&["topology", "t", "cycle ms", "acc %"], &rows));
    Ok(())
}

fn train_t_acc(t: u32, rounds: usize) -> Result<f64> {
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();
    let cfg = TrainConfig { rounds, model: "femnist_mlp".into(), ..Default::default() };
    let runtime = mgfl::runtime::ModelRuntime::load_default(&cfg.model)?;
    let topo = Box::new(MultigraphTopology::from_network(&net, &prof, t));
    let mut trainer = mgfl::coordinator::Trainer::new(runtime, topo, net, prof, cfg)?;
    Ok(trainer.run(0)?.final_accuracy().unwrap_or(0.0))
}

/// Fig. 1: accuracy vs total training time per topology.
fn fig1(rounds: usize, train_rounds: usize, model: &str) -> Result<()> {
    let net = zoo::exodus();
    let prof = DatasetProfile::femnist();
    println!(
        "== Fig. 1 — accuracy vs overhead time (Exodus cycle time x Gaia-trained accuracy) =="
    );
    let mut rows = Vec::new();
    for kind in TopologyKind::all() {
        let cfg = ExperimentConfig {
            network: "exodus".into(),
            profile: "femnist".into(),
            topology: kind,
            t: 5,
            sim_rounds: rounds,
            seed: 17,
            train: None,
        };
        let mut topo = cfg.build_topology();
        let sim = simulate(topo.as_mut(), &net, &prof, rounds);
        let tcfg = ExperimentConfig {
            network: "gaia".into(),
            profile: "femnist".into(),
            topology: kind,
            t: 5,
            sim_rounds: train_rounds,
            seed: 17,
            train: Some(TrainConfig {
                rounds: train_rounds,
                model: model.into(),
                ..Default::default()
            }),
        };
        let mut trainer = mgfl::coordinator::Trainer::from_config(&tcfg)?;
        let trace = trainer.run(0)?;
        rows.push(vec![
            kind.as_str().into(),
            format!("{:.1}", sim.total_ms / 1e3),
            format!("{:.2}", trace.final_accuracy().unwrap_or(f64::NAN) * 100.0),
        ]);
        eprintln!("  {} done", kind.as_str());
    }
    print!("{}", render_table(&["topology", "total time s", "acc %"], &rows));
    Ok(())
}

/// Fig. 4: dump per-state topology with isolated nodes (Gaia, t=3).
fn fig4(t: u32) {
    let net = zoo::gaia();
    let prof = DatasetProfile::femnist();
    let topo = MultigraphTopology::from_network(&net, &prof, t);
    println!("== Fig. 4 — multigraph states on Gaia (t={t}, s_max={}) ==", topo.s_max());
    for s in 0..topo.s_max().min(8) {
        let plan = topo.plan_for_state(s);
        let iso = plan.isolated_nodes();
        let strong: Vec<String> = plan
            .strong_edges()
            .map(|(u, v)| format!("{}–{}", net.silos[u].name, net.silos[v].name))
            .collect();
        println!(
            "state {s}: {} strong edges [{}], isolated: [{}]",
            strong.len(),
            strong.join(", "),
            iso.iter().map(|&i| net.silos[i].name.clone()).collect::<Vec<_>>().join(", ")
        );
    }
}

/// Fig. 5: per-round loss curves (vs rounds and vs simulated time).
fn fig5(rounds: usize, model: &str, network: &str, out: &str) -> Result<()> {
    std::fs::create_dir_all(out)?;
    println!("== Fig. 5 — convergence curves ({network}, model {model}) ==");
    for kind in TopologyKind::all() {
        let cfg = ExperimentConfig {
            network: network.into(),
            profile: "femnist".into(),
            topology: kind,
            t: 5,
            sim_rounds: rounds,
            seed: 17,
            train: Some(TrainConfig { rounds, model: model.into(), ..Default::default() }),
        };
        let mut trainer = mgfl::coordinator::Trainer::from_config(&cfg)?;
        let trace = trainer.run((rounds / 10).max(1))?;
        let path = format!("{out}/fig5_{}_{}.csv", network, kind.as_str());
        trace.write_csv(&path)?;
        println!(
            "{:<12} final loss {:.4} acc {:.2}% sim {:.1}s -> {path}",
            kind.as_str(),
            trace.final_train_loss().unwrap_or(f64::NAN),
            trace.final_accuracy().unwrap_or(f64::NAN) * 100.0,
            trace.total_sim_ms() / 1e3
        );
    }
    Ok(())
}
