//! The L3 round engine: real DPASGD training (Eq. 2 / Eq. 6) over any
//! topology design, executing local SGD steps and consensus aggregation
//! through the PJRT runtime, while the Eq. 4 [`DelayTracker`] keeps the
//! simulated wall clock.
//!
//! ## Concurrency model
//!
//! The `xla` crate's PJRT client is `Rc`-based (not `Send`), so silo
//! *compute* is serialized through the runtime on one thread; this does
//! not distort results because training time is **simulated** from the
//! delay model (exactly as the paper's own PyTorch/MPI time simulator
//! does, §5.1) — host wall-clock is tracked separately for §Perf. The
//! round loop is deterministic given the experiment seed.
//!
//! ## Semantics of a round (k)
//!
//! 1. every silo takes `u` local SGD steps on its non-IID shard;
//! 2. silos publish their post-step models along the round's edges:
//!    strong edges deliver synchronously (the cycle time waits for
//!    them), weak edges land in the receiver's [`NeighborCache`] and
//!    become visible from round k+1 — that cache is Eq. 6's w_j(k−h);
//! 3. non-isolated silos aggregate over fresh strong-neighbour models;
//!    isolated silos follow [`IsolatedPolicy`]: aggregate from the stale
//!    cache without waiting (default) or skip (ablation).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{ExperimentConfig, TrainConfig};
use crate::data::{InputKind, SyntheticTask};
use crate::fl::{round_actions, ConsensusMatrix, NeighborCache, Partition, SiloAction};
use crate::metrics::{RoundRecord, TrainTrace};
use crate::net::{DatasetProfile, NetworkSpec};
use crate::runtime::ModelRuntime;
use crate::simtime::DelayTracker;
use crate::util::Rng64;
use crate::topo::TopologyDesign;

/// One silo's training state.
struct SiloState {
    params: Vec<f32>,
    cache: NeighborCache,
    rng: Rng64,
    last_loss: f32,
}

/// The training coordinator.
pub struct Trainer {
    pub runtime: ModelRuntime,
    topo: Box<dyn TopologyDesign>,
    net: NetworkSpec,
    profile: DatasetProfile,
    consensus: ConsensusMatrix,
    task: SyntheticTask,
    partition: Partition,
    cfg: TrainConfig,
    silos: Vec<SiloState>,
    round: usize,
}

impl Trainer {
    /// Build a trainer from an experiment config (must carry `train`).
    pub fn from_config(exp: &ExperimentConfig) -> Result<Self> {
        let cfg =
            exp.train.clone().ok_or_else(|| anyhow!("config has no [train] section"))?;
        let net = exp.resolve_network();
        let profile = exp.resolve_profile()?;
        let topo = exp.build_topology();
        let runtime = ModelRuntime::load_default(&cfg.model)?;
        Self::new(runtime, topo, net, profile, cfg)
    }

    pub fn new(
        runtime: ModelRuntime,
        topo: Box<dyn TopologyDesign>,
        net: NetworkSpec,
        profile: DatasetProfile,
        cfg: TrainConfig,
    ) -> Result<Self> {
        let n = net.n();
        let entry = &runtime.entry;
        let kind = match entry.input_dtype.as_str() {
            "f32" => InputKind::F32,
            "i32" => InputKind::I32,
            other => return Err(anyhow!("unsupported input dtype {other}")),
        };
        let task = match kind {
            InputKind::F32 => SyntheticTask::image(entry.input_len(), entry.num_classes, cfg.seed),
            InputKind::I32 => SyntheticTask::tokens(entry.input_len(), entry.num_classes, cfg.seed),
        };
        let partition =
            Partition::dirichlet(n, entry.num_classes, cfg.dirichlet_alpha, cfg.seed);
        let consensus = ConsensusMatrix::metropolis(topo.overlay());

        // All silos start from the same init (standard decentralized FL).
        let params0 = runtime.init_params(cfg.seed as i32)?;
        let silos = (0..n)
            .map(|i| SiloState {
                params: params0.clone(),
                cache: NeighborCache::new(),
                rng: Rng64::seed_from_u64(cfg.seed ^ ((0x51 + i as u64) << 8)),
                last_loss: f32::NAN,
            })
            .collect();

        Ok(Trainer {
            runtime,
            topo,
            net,
            profile,
            consensus,
            task,
            partition,
            cfg,
            silos,
            round: 0,
        })
    }

    pub fn num_silos(&self) -> usize {
        self.silos.len()
    }

    pub fn topology_name(&self) -> &str {
        self.topo.name()
    }

    /// Run the configured number of rounds; eval every `eval_every`
    /// rounds (and at the end). Returns the full trace.
    pub fn run(&mut self, eval_every: usize) -> Result<TrainTrace> {
        let host_t0 = Instant::now();
        let mut trace = TrainTrace::new(self.topo.name(), &self.net.name, &self.cfg.model);
        let mut tracker = DelayTracker::new(&self.net, &self.profile);
        let mut sim_elapsed = 0.0;

        for k in 0..self.cfg.rounds {
            let rec = self.run_round(k, &mut tracker, &mut sim_elapsed)?;
            let mut rec = rec;
            if eval_every > 0 && (k + 1) % eval_every == 0 || k + 1 == self.cfg.rounds {
                let (loss, acc) = self.evaluate()?;
                rec.eval_loss = Some(loss);
                rec.eval_acc = Some(acc);
            }
            trace.push(rec);
        }
        trace.host_elapsed_ms = host_t0.elapsed().as_secs_f64() * 1e3;
        Ok(trace)
    }

    /// Execute one communication round; returns its metrics record.
    fn run_round(
        &mut self,
        k: usize,
        tracker: &mut DelayTracker,
        sim_elapsed: &mut f64,
    ) -> Result<RoundRecord> {
        let plan = self.topo.plan(k);
        let time = tracker.step(&plan);
        *sim_elapsed += time.cycle_ms;

        // 1. Local updates (Eq. 2 bottom branch), u steps per silo.
        let mut loss_sum = 0.0f64;
        for i in 0..self.silos.len() {
            let mut loss = 0.0f32;
            for _ in 0..self.cfg.local_updates {
                let batch = self.task.batch(
                    &self.partition,
                    i,
                    self.runtime.entry.train_batch,
                    &mut self.silos[i].rng,
                );
                let (new_params, l) =
                    self.runtime.train_step(&self.silos[i].params, &batch, self.cfg.lr)?;
                self.silos[i].params = new_params;
                loss = l;
            }
            self.silos[i].last_loss = loss;
            loss_sum += loss as f64;
        }

        // 2. Aggregation (Eq. 6). Strong neighbours are read fresh
        //    (post-local-update, this round); weak/cached neighbours come
        //    from the (k-h) cache. Aggregations all read pre-aggregation
        //    models, so order across silos does not matter.
        let actions = round_actions(&plan, &self.consensus, self.cfg.isolated_policy);
        let pre_agg: Vec<Vec<f32>> = self.silos.iter().map(|s| s.params.clone()).collect();
        for (i, action) in actions.iter().enumerate() {
            if let SiloAction::Aggregate { row, wait } = action {
                let mut weights = Vec::with_capacity(row.len());
                let mut models: Vec<&[f32]> = Vec::with_capacity(row.len());
                let mut missing = 0.0f32;
                for &(j, w) in row {
                    if j == i {
                        weights.push(w as f32);
                        models.push(&pre_agg[i]);
                    } else if *wait {
                        // strong neighbour: fresh model, synchronous.
                        weights.push(w as f32);
                        models.push(&pre_agg[j]);
                    } else if let Some(c) = self.silos[i].cache.get(j) {
                        // isolated: stale cached model, no waiting.
                        weights.push(w as f32);
                        models.push(&c.params);
                    } else {
                        // neighbour never heard from: fold weight to self.
                        missing += w as f32;
                    }
                }
                if missing > 0.0 {
                    // self entry is last in `row` by construction
                    if let Some(wl) = weights.last_mut() {
                        *wl += missing;
                    }
                }
                if models.len() > 1 {
                    self.silos[i].params =
                        self.runtime.aggregate_with(self.cfg.agg_backend, &weights, &models)?;
                }
            }
        }

        // 3. Publish along every round edge (strong and weak): receivers
        //    cache the sender's post-local-update model of round k, which
        //    is what a later isolated round reads as w_j(k-h).
        for &(u, v, _ty) in &plan.edges {
            let mu = pre_agg[u].clone();
            let mv = pre_agg[v].clone();
            self.silos[v].cache.publish(u, mu, k);
            self.silos[u].cache.publish(v, mv, k);
        }

        self.round = k + 1;
        Ok(RoundRecord {
            round: k,
            cycle_ms: time.cycle_ms,
            sim_elapsed_ms: *sim_elapsed,
            train_loss: loss_sum / self.silos.len() as f64,
            isolated: time.isolated,
            eval_loss: None,
            eval_acc: None,
        })
    }

    /// Evaluate the network-average model on IID eval batches.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let n = self.silos.len();
        let w = vec![1.0f32 / n as f32; n.min(self.runtime.entry.k_max)];
        // Average in chunks of k_max through the aggregation kernel.
        let mut avg: Vec<f32> = vec![0.0; self.runtime.param_count()];
        let mut done = 0usize;
        while done < n {
            let chunk = (n - done).min(self.runtime.entry.k_max);
            let models: Vec<&[f32]> =
                (done..done + chunk).map(|i| self.silos[i].params.as_slice()).collect();
            let weights: Vec<f32> = w.iter().take(chunk).map(|_| 1.0 / n as f32).collect();
            let partial = self.runtime.aggregate_with(self.cfg.agg_backend, &weights, &models)?;
            for (a, p) in avg.iter_mut().zip(&partial) {
                *a += p;
            }
            done += chunk;
        }

        let mut rng = Rng64::seed_from_u64(self.cfg.seed ^ EVAL_SEED_MIX);
        let batches = (self.cfg.eval_examples / self.runtime.entry.eval_batch).max(1);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for _ in 0..batches {
            let b = self.task.eval_batch(self.runtime.entry.eval_batch, &mut rng);
            let (l, c) = self.runtime.eval_step(&avg, &b)?;
            loss_sum += l as f64;
            correct += c as f64;
            total += self.runtime.entry.eval_batch;
        }
        Ok((loss_sum / batches as f64, correct / total as f64))
    }
}

/// Seed domain separator for eval batches (keeps eval data disjoint from
/// training draws under the same experiment seed).
const EVAL_SEED_MIX: u64 = 0xE7A1;
