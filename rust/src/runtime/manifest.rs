//! Artifact manifest: the JSON contract written by `python -m compile.aot`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use crate::util::Json;

#[derive(Debug, Clone)]
pub struct ParamSpecEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One model's artifact set (see aot.py::export_model).
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub model: String,
    pub param_count: usize,
    pub model_size_mbits: f64,
    pub model_size_mb: f64,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    /// "f32" | "i32"
    pub input_dtype: String,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub k_max: usize,
    /// suffix -> filename, suffixes: train/eval/init/agg.
    pub artifacts: BTreeMap<String, String>,
    pub param_specs: Vec<ParamSpecEntry>,
}

impl ModelEntry {
    fn from_json(j: &Json) -> Result<ModelEntry> {
        let mut artifacts = BTreeMap::new();
        for (k, v) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(k.clone(), v.as_str()?.to_string());
        }
        let mut param_specs = Vec::new();
        for s in j.get("param_specs")?.as_arr()? {
            let shape = s
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            param_specs.push(ParamSpecEntry { name: s.get("name")?.as_str()?.to_string(), shape });
        }
        Ok(ModelEntry {
            model: j.get("model")?.as_str()?.to_string(),
            param_count: j.get("param_count")?.as_usize()?,
            model_size_mbits: j.get("model_size_mbits")?.as_f64()?,
            model_size_mb: j.get("model_size_mb")?.as_f64()?,
            num_classes: j.get("num_classes")?.as_usize()?,
            input_shape: j
                .get("input_shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
            input_dtype: j.get("input_dtype")?.as_str()?.to_string(),
            train_batch: j.get("train_batch")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            k_max: j.get("k_max")?.as_usize()?,
            artifacts,
            param_specs,
        })
    }

    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn artifact_path(&self, dir: &Path, suffix: &str) -> Result<PathBuf> {
        let name = self
            .artifacts
            .get(suffix)
            .ok_or_else(|| anyhow!("model {} has no '{suffix}' artifact", self.model))?;
        Ok(dir.join(name))
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub fingerprint: String,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` to build the AOT artifacts first",
                path.display()
            )
        })?;
        let m = Self::from_json(&text).context("parsing manifest.json")?;
        ensure!(m.version == 1, "unsupported manifest version {}", m.version);
        Ok(m)
    }

    /// Parse the manifest JSON (in-tree parser; no serde offline).
    pub fn from_json(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let mut models = BTreeMap::new();
        for (name, entry) in j.get("models")?.as_obj()? {
            models.insert(name.clone(), ModelEntry::from_json(entry)?);
        }
        Ok(Manifest {
            version: j.get("version")?.as_usize()? as u32,
            fingerprint: j.get("fingerprint")?.as_str()?.to_string(),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model '{name}' not in manifest (have: {:?}); re-run `make artifacts`",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

/// Default artifacts dir: $MGFL_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("MGFL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_entry() -> ModelEntry {
        let j = Json::parse(
            r#"{
            "model": "m",
            "param_count": 10,
            "model_size_mbits": 0.32,
            "model_size_mb": 0.04,
            "num_classes": 2,
            "input_shape": [4, 4, 1],
            "input_dtype": "f32",
            "train_batch": 8,
            "eval_batch": 8,
            "k_max": 16,
            "artifacts": {"train": "m_train.hlo.txt"},
            "param_specs": [{"name": "w", "shape": [10]}]
        }"#,
        )
        .unwrap();
        ModelEntry::from_json(&j).unwrap()
    }

    #[test]
    fn manifest_from_json_full() {
        let text = r#"{"version": 1, "fingerprint": "ff", "models": {}}"#;
        let m = Manifest::from_json(text).unwrap();
        assert_eq!(m.version, 1);
        assert!(m.models.is_empty());
        assert!(Manifest::from_json("{}").is_err());
    }

    #[test]
    fn input_len_is_product() {
        assert_eq!(sample_entry().input_len(), 16);
    }

    #[test]
    fn artifact_path_lookup() {
        let e = sample_entry();
        let p = e.artifact_path(Path::new("/a"), "train").unwrap();
        assert_eq!(p, PathBuf::from("/a/m_train.hlo.txt"));
        assert!(e.artifact_path(Path::new("/a"), "missing").is_err());
    }

    #[test]
    fn manifest_load_missing_dir_is_helpful() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
