//! Offline stand-in for the vendored `xla` crate (PJRT bindings).
//!
//! The real runtime links LaurentMazare-style `xla` bindings backed by
//! the `xla_extension` C++ library — neither is fetchable from
//! crates.io, so the default build compiles this API-compatible stub
//! instead: every entry point type-checks exactly like the real crate
//! and fails at *load* time (`PjRtClient::cpu()`) with a clear message.
//! Real-training paths (`mgfl train`, `table5`, …) therefore error
//! gracefully, the simulation/sweep subsystem is unaffected, and the
//! artifact-gated integration tests skip just as they do when
//! `artifacts/` is absent. Deployments with the vendored toolchain add
//! the real crate to `Cargo.toml` and build with `--features pjrt`,
//! which compiles this module out.

use std::fmt;
use std::path::Path;

/// Error carried by every stub entry point.
#[derive(Debug)]
pub struct XlaError(String);

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT runtime unavailable (built with the in-tree xla stub; \
         add the vendored `xla` crate and build with --features pjrt)"
    ))
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// Stand-in for a host literal (tensor) value.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn get_first_element<T>(&self) -> Result<T, XlaError> {
        Err(unavailable("Literal::get_first_element"))
    }
}

/// Stand-in for a device buffer returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stand-in for a parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, XlaError> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stand-in for an XLA computation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for the PJRT client. `cpu()` is the first call on every
/// runtime path, so the stub fails fast and nothing downstream runs.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stand-in for a compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}
