//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! rust round path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Interchange is HLO *text* because
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1's
//! proto path rejects; the text parser reassigns ids.
//!
//! The `xla` crate's client is `Rc`-based (not `Send`), so a process
//! hosts the runtime on one thread; the coordinator serializes silo
//! compute through it (simulated time is independent of host wall-time).

pub mod manifest;

// The `xla` PJRT bindings and their xla_extension C++ closure are
// vendored in accelerator deployments, not fetchable from crates.io.
// Default builds compile the API-compatible in-tree stub (fails at
// client creation with a clear message; simulation/sweep paths never
// touch it). `--features pjrt` compiles the stub out — add the vendored
// `xla` crate to Cargo.toml alongside it.
#[cfg(not(feature = "pjrt"))]
mod xla_stub;
#[cfg(not(feature = "pjrt"))]
use xla_stub as xla;

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

pub use manifest::{default_artifacts_dir, Manifest, ModelEntry};

use crate::data::Batch;

/// A loaded model: the four compiled executables + manifest metadata.
pub struct ModelRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    init: xla::PjRtLoadedExecutable,
    agg: xla::PjRtLoadedExecutable,
    pub entry: ModelEntry,
    /// Cumulative host-time spent in each executable (perf accounting).
    pub timings: std::cell::RefCell<RuntimeTimings>,
}

#[derive(Debug, Default, Clone)]
pub struct RuntimeTimings {
    pub train_ms: f64,
    pub train_calls: u64,
    pub eval_ms: f64,
    pub eval_calls: u64,
    pub agg_ms: f64,
    pub agg_calls: u64,
}

impl RuntimeTimings {
    pub fn mean_train_ms(&self) -> f64 {
        if self.train_calls == 0 {
            0.0
        } else {
            self.train_ms / self.train_calls as f64
        }
    }

    pub fn mean_agg_ms(&self) -> f64 {
        if self.agg_calls == 0 {
            0.0
        } else {
            self.agg_ms / self.agg_calls as f64
        }
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
}

impl ModelRuntime {
    /// Load and compile all artifacts of `model` from `dir`.
    pub fn load(dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let entry = manifest.model(model)?.clone();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let load = |suffix: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = entry.artifact_path(dir, suffix)?;
            compile(&client, &path).with_context(|| format!("artifact '{suffix}'"))
        };
        Ok(ModelRuntime {
            train: load("train")?,
            eval: load("eval")?,
            init: load("init")?,
            agg: load("agg")?,
            client,
            entry,
            timings: Default::default(),
        })
    }

    /// Load from the default artifacts dir ($MGFL_ARTIFACTS or ./artifacts).
    pub fn load_default(model: &str) -> Result<Self> {
        Self::load(default_artifacts_dir(), model)
    }

    pub fn param_count(&self) -> usize {
        self.entry.param_count
    }

    fn params_literal(&self, params: &[f32]) -> Result<xla::Literal> {
        ensure!(
            params.len() == self.entry.param_count,
            "params length {} != P {}",
            params.len(),
            self.entry.param_count
        );
        Ok(xla::Literal::vec1(params))
    }

    fn batch_literal(
        &self,
        batch: &Batch,
        expect_b: usize,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let mut dims: Vec<i64> = vec![expect_b as i64];
        dims.extend(self.entry.input_shape.iter().map(|&d| d as i64));
        let x = match self.entry.input_dtype.as_str() {
            "f32" => {
                ensure!(
                    batch.x_f32.len() == expect_b * self.entry.input_len(),
                    "f32 batch len {} != {}x{}",
                    batch.x_f32.len(),
                    expect_b,
                    self.entry.input_len()
                );
                xla::Literal::vec1(batch.x_f32.as_slice()).reshape(&dims)?
            }
            "i32" => {
                ensure!(
                    batch.x_i32.len() == expect_b * self.entry.input_len(),
                    "i32 batch len {} != {}x{}",
                    batch.x_i32.len(),
                    expect_b,
                    self.entry.input_len()
                );
                xla::Literal::vec1(batch.x_i32.as_slice()).reshape(&dims)?
            }
            other => return Err(anyhow!("unknown input dtype {other}")),
        };
        ensure!(batch.y.len() == expect_b, "label batch {} != {expect_b}", batch.y.len());
        let y = xla::Literal::vec1(batch.y.as_slice());
        Ok((x, y))
    }

    /// (seed) -> flat params.
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = self.init.execute::<xla::Literal>(&[xla::Literal::scalar(seed)])?[0][0]
            .to_literal_sync()?;
        let params = out.to_tuple1()?.to_vec::<f32>()?;
        ensure!(params.len() == self.entry.param_count, "init returned wrong P");
        Ok(params)
    }

    /// One local SGD step: (params, batch, lr) -> (params', loss).
    /// `batch` must match the manifest's train_batch.
    pub fn train_step(&self, params: &[f32], batch: &Batch, lr: f32) -> Result<(Vec<f32>, f32)> {
        let t0 = Instant::now();
        let p = self.params_literal(params)?;
        let (x, y) = self.batch_literal(batch, self.entry.train_batch)?;
        let out = self
            .train
            .execute::<xla::Literal>(&[p, x, y, xla::Literal::scalar(lr)])?[0][0]
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        ensure!(parts.len() == 2, "train artifact must return (params, loss)");
        let new_params = parts[0].to_vec::<f32>()?;
        let loss = parts[1].get_first_element::<f32>()?;
        let mut t = self.timings.borrow_mut();
        t.train_ms += t0.elapsed().as_secs_f64() * 1e3;
        t.train_calls += 1;
        Ok((new_params, loss))
    }

    /// (params, batch) -> (loss, correct_count). Batch = eval_batch.
    pub fn eval_step(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        let t0 = Instant::now();
        let p = self.params_literal(params)?;
        let (x, y) = self.batch_literal(batch, self.entry.eval_batch)?;
        let out = self.eval.execute::<xla::Literal>(&[p, x, y])?[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        ensure!(parts.len() == 2, "eval artifact must return (loss, correct)");
        let loss = parts[0].get_first_element::<f32>()?;
        let correct = parts[1].get_first_element::<f32>()?;
        let mut t = self.timings.borrow_mut();
        t.eval_ms += t0.elapsed().as_secs_f64() * 1e3;
        t.eval_calls += 1;
        Ok((loss, correct))
    }

    /// Consensus aggregation via the compiled Pallas kernel:
    /// out = Σ_k w_k · models_k. Up to k_max models; shorter lists are
    /// zero-padded (zero weights are exact no-ops, tested at L1).
    pub fn aggregate(&self, weights: &[f32], models: &[&[f32]]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let k_max = self.entry.k_max;
        let p_count = self.entry.param_count;
        ensure!(weights.len() == models.len(), "weights/models length mismatch");
        ensure!(models.len() <= k_max, "{} models > k_max {k_max}", models.len());
        for m in models {
            ensure!(m.len() == p_count, "model length {} != P {p_count}", m.len());
        }
        let mut w = vec![0.0f32; k_max];
        w[..weights.len()].copy_from_slice(weights);
        let mut stack = vec![0.0f32; k_max * p_count];
        for (k, m) in models.iter().enumerate() {
            stack[k * p_count..(k + 1) * p_count].copy_from_slice(m);
        }
        let wl = xla::Literal::vec1(&w);
        let sl = xla::Literal::vec1(&stack).reshape(&[k_max as i64, p_count as i64])?;
        let out = self.agg.execute::<xla::Literal>(&[wl, sl])?[0][0].to_literal_sync()?;
        let result = out.to_tuple1()?.to_vec::<f32>()?;
        let mut t = self.timings.borrow_mut();
        t.agg_ms += t0.elapsed().as_secs_f64() * 1e3;
        t.agg_calls += 1;
        Ok(result)
    }

    /// Aggregate via the configured backend (§Perf: native by default
    /// on CPU; the compiled kernel path for accelerator deployments).
    pub fn aggregate_with(
        &self,
        backend: crate::config::AggBackend,
        weights: &[f32],
        models: &[&[f32]],
    ) -> Result<Vec<f32>> {
        match backend {
            crate::config::AggBackend::Kernel => self.aggregate(weights, models),
            crate::config::AggBackend::Native => {
                ensure!(weights.len() == models.len(), "weights/models length mismatch");
                ensure!(!models.is_empty(), "empty aggregation");
                let t0 = Instant::now();
                let out = aggregate_native(weights, models);
                let mut t = self.timings.borrow_mut();
                t.agg_ms += t0.elapsed().as_secs_f64() * 1e3;
                t.agg_calls += 1;
                Ok(out)
            }
        }
    }

    /// Measure the real host train-step time (ms) — feeds T_c into
    /// profiles derived from artifacts instead of the paper's P100 value.
    pub fn measure_t_c_ms(&self, batch: &Batch, reps: usize) -> Result<f64> {
        let params = self.init_params(0)?;
        // warmup (first call pays any lazy initialization)
        let _ = self.train_step(&params, batch, 0.01)?;
        let t0 = Instant::now();
        let mut p = params;
        for _ in 0..reps.max(1) {
            p = self.train_step(&p, batch, 0.01)?.0;
        }
        Ok(t0.elapsed().as_secs_f64() * 1e3 / reps.max(1) as f64)
    }
}

/// Native-rust weighted aggregation — the fallback/ablation backend the
/// `hotpath` bench compares against the compiled kernel.
pub fn aggregate_native(weights: &[f32], models: &[&[f32]]) -> Vec<f32> {
    assert_eq!(weights.len(), models.len());
    assert!(!models.is_empty());
    let p = models[0].len();
    let mut out = vec![0.0f32; p];
    for (&w, m) in weights.iter().zip(models) {
        assert_eq!(m.len(), p);
        if w == 0.0 {
            continue;
        }
        for (o, &x) in out.iter_mut().zip(m.iter()) {
            *o += w * x;
        }
    }
    out
}

/// Are artifacts built? Tests/examples use this to skip gracefully with
/// an actionable message instead of failing obscurely.
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_native_weighted_sum() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let out = aggregate_native(&[0.25, 0.75], &[&a, &b]);
        assert_eq!(out, vec![0.25 + 2.25, 0.5 + 3.0]);
    }

    #[test]
    fn aggregate_native_skips_zero_weight_rows() {
        let a = vec![1.0f32; 4];
        let garbage = vec![f32::NAN; 4];
        let out = aggregate_native(&[1.0, 0.0], &[&a, &garbage]);
        assert_eq!(out, a);
    }

    #[test]
    #[should_panic]
    fn aggregate_native_length_mismatch() {
        aggregate_native(&[1.0], &[&[1.0][..], &[2.0][..]]);
    }
}
