//! Synthetic datasets for the real training runs.
//!
//! Substitution (DESIGN.md §Substitutions): FEMNIST / Sentiment140 are
//! replaced by learnable class-conditional synthetic tasks with the same
//! tensor shapes as the compiled artifacts expect. The image family is
//! mean-shifted Gaussian patches per class (each class has a fixed
//! random prototype); the token family starts every sequence with a
//! class-indicator token. Both match the generators used by the python
//! model tests, so L2 and L3 exercise the same distribution.

use crate::fl::Partition;
use crate::util::Rng64;

/// A batch ready for the runtime: flattened row-major tensors.
#[derive(Debug, Clone)]
pub struct Batch {
    /// f32 inputs, len = batch * input_len (images) — empty for i32 input.
    pub x_f32: Vec<f32>,
    /// i32 inputs, len = batch * input_len (token ids) — empty for f32.
    pub x_i32: Vec<i32>,
    /// Labels, len = batch.
    pub y: Vec<i32>,
}

/// Input element type of a model (mirrors the artifact manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    F32,
    I32,
}

/// Class-conditional synthetic task.
#[derive(Debug, Clone)]
pub struct SyntheticTask {
    pub input_len: usize,
    pub num_classes: usize,
    pub kind: InputKind,
    /// Per-class prototype (images) — num_classes x input_len.
    prototypes: Vec<Vec<f32>>,
    noise: f32,
}

impl SyntheticTask {
    /// Image-family task (FEMNIST-shaped when input_len = 28*28).
    pub fn image(input_len: usize, num_classes: usize, seed: u64) -> Self {
        let mut rng = Rng64::seed_from_u64(seed ^ IMAGE_SEED_MIX);
        let prototypes = (0..num_classes)
            .map(|_| (0..input_len).map(|_| rng.gen_f32() * 2.0 - 1.0).collect())
            .collect();
        SyntheticTask { input_len, num_classes, kind: InputKind::F32, prototypes, noise: 0.6 }
    }

    /// Token-family task (LSTM models): class-indicator first token.
    pub fn tokens(input_len: usize, num_classes: usize, seed: u64) -> Self {
        let _ = seed;
        SyntheticTask {
            input_len,
            num_classes,
            kind: InputKind::I32,
            prototypes: Vec::new(),
            noise: 0.0,
        }
    }

    /// Generate a batch for silo `s` under `partition`.
    pub fn batch(
        &self,
        partition: &Partition,
        silo: usize,
        batch: usize,
        rng: &mut Rng64,
    ) -> Batch {
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            y.push(partition.sample_class(silo, rng) as i32);
        }
        match self.kind {
            InputKind::F32 => {
                let mut x = Vec::with_capacity(batch * self.input_len);
                for &label in &y {
                    let proto = &self.prototypes[label as usize];
                    for &p in proto {
                        x.push(p + self.noise * rng.gen_normal_f32());
                    }
                }
                Batch { x_f32: x, x_i32: Vec::new(), y }
            }
            InputKind::I32 => {
                let mut x = Vec::with_capacity(batch * self.input_len);
                for &label in &y {
                    // Mirrors python/tests/test_model.py: token 0 is the
                    // class indicator 64 + y*16, rest uniform noise ids.
                    x.push(64 + label * 16);
                    for _ in 1..self.input_len {
                        x.push(rng.gen_range_i32(0, 64));
                    }
                }
                Batch { x_f32: Vec::new(), x_i32: x, y }
            }
        }
    }

    /// An IID eval batch (uniform over classes).
    pub fn eval_batch(&self, batch: usize, rng: &mut Rng64) -> Batch {
        let iid = Partition::iid(1, self.num_classes);
        self.batch(&iid, 0, batch, rng)
    }
}

/// Seed domain separator so image prototypes differ from other streams
/// derived from the same experiment seed.
const IMAGE_SEED_MIX: u64 = 0x5EED_1A6E;

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng64 {
        Rng64::seed_from_u64(0)
    }

    #[test]
    fn image_batch_shapes() {
        let task = SyntheticTask::image(784, 62, 1);
        let part = Partition::iid(2, 62);
        let b = task.batch(&part, 0, 16, &mut rng());
        assert_eq!(b.x_f32.len(), 16 * 784);
        assert!(b.x_i32.is_empty());
        assert_eq!(b.y.len(), 16);
        assert!(b.y.iter().all(|&c| (0..62).contains(&c)));
    }

    #[test]
    fn token_batch_has_class_indicator() {
        let task = SyntheticTask::tokens(24, 2, 1);
        let part = Partition::iid(1, 2);
        let b = task.batch(&part, 0, 8, &mut rng());
        assert_eq!(b.x_i32.len(), 8 * 24);
        for (i, &label) in b.y.iter().enumerate() {
            assert_eq!(b.x_i32[i * 24], 64 + label * 16);
        }
    }

    #[test]
    fn classes_are_separable() {
        // Distinct class prototypes: mean distance between class means
        // must dominate within-class noise.
        let task = SyntheticTask::image(64, 4, 2);
        let part = Partition::iid(1, 4);
        let mut sums = vec![vec![0.0f64; 64]; 4];
        let mut counts = vec![0usize; 4];
        let mut r = rng();
        for _ in 0..50 {
            let b = task.batch(&part, 0, 32, &mut r);
            for (i, &label) in b.y.iter().enumerate() {
                counts[label as usize] += 1;
                for d in 0..64 {
                    sums[label as usize][d] += b.x_f32[i * 64 + d] as f64;
                }
            }
        }
        let means: Vec<Vec<f64>> = sums
            .iter()
            .zip(&counts)
            .map(|(s, &c)| s.iter().map(|v| v / c.max(1) as f64).collect())
            .collect();
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt()
        };
        assert!(dist(&means[0], &means[1]) > 2.0, "{}", dist(&means[0], &means[1]));
    }

    #[test]
    fn skewed_partition_biases_labels() {
        let task = SyntheticTask::image(16, 10, 3);
        let part = Partition::dirichlet(4, 10, 0.05, 9);
        let mut r = rng();
        let b = task.batch(&part, 0, 200, &mut r);
        // With alpha=0.05 one class should dominate the silo's batch.
        let mut counts = [0usize; 10];
        for &y in &b.y {
            counts[y as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max > 100, "expected dominant class, counts {counts:?}");
    }

    #[test]
    fn deterministic_prototypes() {
        let a = SyntheticTask::image(32, 3, 7);
        let b = SyntheticTask::image(32, 3, 7);
        assert_eq!(a.prototypes, b.prototypes);
    }
}
