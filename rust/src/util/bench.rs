//! Micro-bench harness for `cargo bench` targets (`harness = false`):
//! warmup + timed iterations with mean/σ/min, plus simple table output.
//! Criterion is unavailable offline; this keeps the same discipline
//! (warmup, multiple samples, report spread) at a fraction of the size.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms/iter  (±{:.3}, min {:.3}, n={})",
            self.name, self.mean_ms, self.std_ms, self.min_ms, self.iters
        )
    }
}

/// Time `f` with `warmup` unmeasured runs + `iters` measured runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Measurement {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / iters as f64;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        std_ms: var.sqrt(),
        min_ms: min,
    };
    println!("{}", m.report());
    m
}

/// Standard bench header so all bench binaries look alike.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let m = bench("noop-ish", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.mean_ms >= 0.0);
        assert!(m.min_ms <= m.mean_ms + 1e-9);
        assert_eq!(m.iters, 5);
    }
}
