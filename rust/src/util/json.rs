//! Minimal JSON substrate: parser + writer for the artifact manifest and
//! metric traces (no serde in the offline build).
//!
//! Supports the full JSON grammar except exotic number forms beyond f64.
//! Parsing is recursive-descent over bytes; good enough for manifests
//! (KBs) and traces (MBs), not a general-purpose speed demon.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors (ergonomic failure messages) --

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("expected object while reading '{key}'"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 * 4096.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    fn write<W: std::fmt::Write>(&self, out: &mut W) -> std::fmt::Result {
        match self {
            Json::Null => out.write_str("null"),
            Json::Bool(b) => out.write_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(out, "{}", *x as i64)
                } else {
                    write!(out, "{x}")
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.write_char('[')?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.write_char(',')?;
                    }
                    x.write(out)?;
                }
                out.write_char(']')
            }
            Json::Obj(m) => {
                out.write_char('{')?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.write_char(',')?;
                    }
                    write_escaped(out, k)?;
                    out.write_char(':')?;
                    v.write(out)?;
                }
                out.write_char('}')
            }
        }
    }
}

/// Compact serialization, streamed straight into the formatter
/// (`Json::to_string()` comes from this impl).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.write(f)
    }
}

fn write_escaped<W: std::fmt::Write>(out: &mut W, s: &str) -> std::fmt::Result {
    out.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => out.write_str("\\\"")?,
            '\\' => out.write_str("\\\\")?,
            '\n' => out.write_str("\\n")?,
            '\r' => out.write_str("\\r")?,
            '\t' => out.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
            c => out.write_char(c)?,
        }
    }
    out.write_char('"')
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected '{}' at byte {}", c as char, self.i),
            None => bail!("unexpected end of input"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| anyhow!("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs unsupported (not produced by
                            // our writers); map to replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        c => bail!("unknown escape '\\{}'", c as char),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_json() {
        let text = r#"{
            "version": 1,
            "fingerprint": "abc",
            "models": {
                "mlp": {"param_count": 108478, "input_shape": [28, 28, 1],
                        "artifacts": {"train": "a.hlo.txt"}, "ok": true,
                        "size": 3.47, "nothing": null}
            }
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 1);
        let mlp = j.get("models").unwrap().get("mlp").unwrap();
        assert_eq!(mlp.get("param_count").unwrap().as_usize().unwrap(), 108478);
        assert_eq!(mlp.get("input_shape").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(mlp.get("ok").unwrap(), &Json::Bool(true));
        assert!((mlp.get("size").unwrap().as_f64().unwrap() - 3.47).abs() < 1e-12);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":{"d":null,"e":false}}"#;
        let j = Json::parse(text).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.5e2").unwrap().as_f64().unwrap(), -50.0);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse(r#""héllo A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo A");
    }

    #[test]
    fn typed_accessor_errors_name_key() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        let err = j.get("missing").unwrap_err().to_string();
        assert!(err.contains("missing"));
    }
}
