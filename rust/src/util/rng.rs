//! Deterministic PRNG substrate: xoshiro256++ with SplitMix64 seeding.
//!
//! The offline build environment has no `rand` crate, so the framework
//! carries its own generator. xoshiro256++ is the reference generator of
//! Blackman & Vigna; SplitMix64 expands the u64 seed into the 256-bit
//! state (the canonical seeding procedure, avoids all-zero states).

/// xoshiro256++ generator. Deterministic in its seed; not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive an independent seed for a named sub-stream of `base`.
///
/// Used by the sweep engine to give every grid cell its own RNG stream
/// that depends only on (base seed, cell identity) — never on execution
/// order — so a sweep is bit-identical on 1 thread and N threads. Two
/// SplitMix64 steps over the mixed inputs decorrelate the streams.
pub fn derive_stream(base: u64, stream: u64) -> u64 {
    let mut sm = base ^ stream.rotate_left(31).wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut sm);
    splitmix64(&mut sm)
}

/// FNV-1a hash of a byte string: stable across runs/platforms, used to
/// name sweep sub-streams after cell coordinates.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// [`derive_stream`] keyed by a human-readable label: the label is
/// FNV-1a hashed into the stream id. `mgfl optimize` names its streams
/// this way (`"optimize/chain/0"`, `"optimize/init/1"`, …) so search
/// chains are independent of each other and of every sweep cell stream.
pub fn named_stream(base: u64, label: &str) -> u64 {
    derive_stream(base, fnv1a(label.as_bytes()))
}

impl Rng64 {
    /// Expand a u64 seed into the 256-bit xoshiro state via four
    /// SplitMix64 draws (the canonical seeding procedure).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng64 {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform usize in [lo, hi) — panics if lo >= hi.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        // Lemire-style rejection-free is overkill for simulation use;
        // modulo bias is < 2^-40 for our ranges.
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform i32 in [lo, hi).
    #[inline]
    pub fn gen_range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo < hi);
        lo + (self.next_u64() % (hi - lo) as u64) as i32
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn gen_normal_f32(&mut self) -> f32 {
        self.gen_normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(0, i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_stream_is_stable_and_spread() {
        // Stable in its inputs…
        assert_eq!(derive_stream(17, 3), derive_stream(17, 3));
        // …and distinct across streams and bases.
        let mut seen = std::collections::BTreeSet::new();
        for base in 0..8u64 {
            for stream in 0..64u64 {
                seen.insert(derive_stream(base, stream));
            }
        }
        assert_eq!(seen.len(), 8 * 64, "derived seeds must not collide");
    }

    #[test]
    fn fnv1a_distinguishes_coordinates() {
        assert_ne!(fnv1a(b"ring/gaia"), fnv1a(b"ring/amazon"));
        assert_eq!(fnv1a(b""), 0xCBF29CE484222325);
    }

    #[test]
    fn deterministic_in_seed() {
        let mut a = Rng64::seed_from_u64(7);
        let mut b = Rng64::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::seed_from_u64(8);
        assert_ne!(Rng64::seed_from_u64(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng64::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn gen_range_covers_and_stays_in_bounds() {
        let mut r = Rng64::seed_from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_range(0, 10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    #[should_panic(expected = "empty range")]
    fn gen_range_rejects_empty() {
        Rng64::seed_from_u64(0).gen_range(5, 5);
    }

    #[test]
    fn normal_has_right_moments() {
        let mut r = Rng64::seed_from_u64(3);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gen_normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::seed_from_u64(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle left input ordered");
    }
}
