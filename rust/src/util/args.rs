//! Tiny CLI argument parser: subcommand + `--flag value` pairs + `--help`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: a subcommand, positional args, and flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(items: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.bools.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.flags.get(name) {
            Some(v) => v.parse::<T>().with_context(|| format!("--{name} {v}")),
            None => Ok(default),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Comma-separated list flag (`--networks gaia,amazon`); `None` when
    /// absent, empty entries dropped.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.flags.get(name).map(|v| {
            v.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect()
        })
    }

    /// Comma-separated list flag parsed into `T` (`--t 1,3,5`).
    pub fn get_parsed_list<T: std::str::FromStr>(&self, name: &str) -> Result<Option<Vec<T>>>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get_list(name) {
            None => Ok(None),
            Some(items) => items
                .iter()
                .map(|s| s.parse::<T>().with_context(|| format!("--{name} {s}")))
                .collect::<Result<Vec<T>>>()
                .map(Some),
        }
    }

    pub fn require_sub(&self, usage: &str) -> Result<&str> {
        match &self.subcommand {
            Some(s) => Ok(s),
            None => bail!("missing subcommand\n{usage}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table1 --rounds 640 --t=5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.get::<usize>("rounds", 0).unwrap(), 640);
        assert_eq!(a.get::<u32>("t", 0).unwrap(), 5);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_and_positional() {
        let a = parse("train cfg.toml --csv out.csv");
        assert_eq!(a.positional, vec!["cfg.toml"]);
        assert_eq!(a.get_str("csv", ""), "out.csv");
        assert_eq!(a.get::<usize>("rounds", 99).unwrap(), 99);
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("x --n abc");
        assert!(a.get::<usize>("n", 0).is_err());
    }

    #[test]
    fn list_flags() {
        let a = parse("sweep --networks gaia,amazon --t 1,3,5");
        assert_eq!(a.get_list("networks").unwrap(), vec!["gaia", "amazon"]);
        assert_eq!(a.get_parsed_list::<u32>("t").unwrap().unwrap(), vec![1, 3, 5]);
        assert!(a.get_list("profiles").is_none());
        assert!(parse("x --t 1,zap").get_parsed_list::<u32>("t").is_err());
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("x --flag");
        assert!(a.has("flag"));
        assert_eq!(a.flag("flag"), None);
    }
}
