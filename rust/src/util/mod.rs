//! In-tree substrates for the offline build: PRNG, JSON, number theory,
//! CLI argument parsing, and the micro-bench harness used by
//! `rust/benches/` (the environment vendors only the `xla` closure; see
//! DESIGN.md §Substitutions).

pub mod args;
pub mod bench;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng64;

/// Greatest common divisor (Euclid).
pub fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Least common multiple; saturates instead of overflowing (schedules
/// with astronomically long periods are handled lazily anyway).
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    (a / gcd(a, b)).saturating_mul(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_lcm_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(1, 9), 9);
        assert_eq!(lcm(0, 9), 0);
    }

    #[test]
    fn lcm_of_1_to_5_is_60() {
        let l = (1..=5u64).fold(1, lcm);
        assert_eq!(l, 60);
    }

    #[test]
    fn lcm_saturates() {
        assert_eq!(lcm(u64::MAX, u64::MAX - 1), u64::MAX);
    }
}
