//! Cross-cell memoization for the sweep engine: semantic cell
//! fingerprints, the grid→unique-work partition, and a concurrent
//! build-once cache for shared construction/compilation artifacts.
//!
//! The paper's grids are heavily redundant: deterministic designs
//! (STAR, MATCHA+, MST, δ-MBST, RING, multigraph) are pure functions of
//! (network, profile, t), so a seed axis of N values replicates the
//! exact same simulation N times, bit for bit. This module makes that
//! redundancy explicit:
//!
//! * [`CellFingerprint`] — the semantic identity of a cell's result:
//!   (topology, network, profile, t, rounds), plus the derived cell
//!   seed **only** when the design is stochastic
//!   ([`TopologyKind::seed_sensitive`]). Equal fingerprints ⇒
//!   bit-identical `SimSummary`s, because every input of
//!   [`CellSpec::to_experiment`] → `simulate_summary` is either in the
//!   fingerprint or provably unused.
//! * [`DedupPlan`] — partitions an expanded grid into unique work items
//!   (first appearance wins) plus a fan-out assignment, so the
//!   scheduler simulates O(unique) cells and copies summaries to every
//!   duplicate coordinate. Reports stay grid-ordered and byte-identical
//!   to the undeduplicated engine: the per-cell `seed`/`cell_seed`
//!   report fields come from each cell's own spec, never from the
//!   representative.
//! * [`SweepCache`] — a [`BuildOnce`] map per artifact kind: shared
//!   compiled schedules ([`CompiledTopology`] / [`FactoredTopology`],
//!   `Arc`ed across the seed axis — and across `t`, for designs that
//!   ignore it; the key keeps `rounds` because it gates the periodic
//!   compile) and shared [`MatchaCore`]s (a stochastic seed axis pays
//!   for one Christofides/MST/decomposition build, not N). Workers
//!   that race on a key block on one `OnceLock`, so a construction
//!   never runs twice.
//! * a **thread-local scratch pool** — every worker thread owns one
//!   [`SimScratch`] (delay slabs, factored-group slab, streaming edge
//!   arena + per-round buffers) that [`run_cell_cached`] reuses across
//!   every cell the thread simulates, whatever engine the cell takes.
//!   Large-N cells stop reallocating O(N²) pair tables and O(E) slabs
//!   per cell; reuse never changes results because each engine fully
//!   re-resolves its layer per cell (pinned by the slab-reuse tests in
//!   `simtime`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex, OnceLock};

use crate::config::TopologyKind;
use crate::net::{DatasetProfile, NetworkSpec};
use crate::simtime::{
    run_compiled, run_factored, simulate_summary_scratch, simulate_summary_streaming_scratch,
    CompiledTopology, EngineStats, FactoredTopology, SimScratch, SimSummary,
};
use crate::topo::matcha::{MatchaCore, MatchaTopology, DEFAULT_BUDGET};
use crate::topo::TopologyDesign;

use super::spec::CellSpec;
use super::CellTiming;

thread_local! {
    /// The per-thread scratch pool: reused across every cell one
    /// worker thread simulates within a sweep. Both pool
    /// implementations spawn fresh workers per `sweep::run`, so
    /// parallel-run scratch is dropped when the sweep ends; only a
    /// caller-thread (threads <= 1) run retains its scratch across
    /// invocations.
    static SCRATCH: RefCell<SimScratch> = RefCell::new(SimScratch::default());
}

/// Run `f` with this thread's pooled [`SimScratch`].
fn with_scratch<R>(f: impl FnOnce(&mut SimScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Simulate an ad-hoc design through this thread's pooled
/// [`SimScratch`] — the entry point `mgfl optimize` uses to evaluate
/// search candidates, so every fitness call reuses the same slabs the
/// sweep workers do (same dispatch, same bits as
/// [`crate::simtime::simulate_summary`]; only allocation is factored).
pub fn simulate_design_pooled(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> (SimSummary, EngineStats) {
    with_scratch(|scratch| simulate_summary_scratch(topo, net, profile, rounds, scratch))
}

/// Semantic identity of one grid cell's simulation result. Two cells
/// with equal fingerprints produce bit-identical [`SimSummary`]s, so
/// the scheduler simulates one and fans the summary out to both.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CellFingerprint {
    /// Topology design kind.
    pub topology: TopologyKind,
    /// Canonical network name.
    pub network: String,
    /// Canonical dataset-profile name.
    pub profile: String,
    /// Algorithm-1 multiplicity cap, verbatim from the cell. Designs
    /// that never consume t still share their *compiled topology* via
    /// the compile-cache key (which zeroes t for them), but their
    /// fingerprints keep t as written.
    pub t: u32,
    /// Simulated rounds.
    pub rounds: usize,
    /// The derived per-cell stream — present **only** when the design
    /// consumes randomness, so stochastic cells with distinct seeds are
    /// never merged while deterministic cells collapse across the whole
    /// seed axis.
    pub seed: Option<u64>,
}

impl CellSpec {
    /// This cell's [`CellFingerprint`] (see the module docs for the
    /// dedup contract it encodes).
    pub fn fingerprint(&self) -> CellFingerprint {
        CellFingerprint {
            topology: self.topology,
            network: self.network.clone(),
            profile: self.profile.clone(),
            t: self.t,
            rounds: self.rounds,
            seed: if self.topology.seed_sensitive() { Some(self.cell_seed) } else { None },
        }
    }
}

/// The grid→unique-work partition: which cells to actually simulate and
/// where every grid coordinate's result comes from.
#[derive(Debug, Clone)]
pub struct DedupPlan {
    /// Indices into the expanded grid of the representative cells, in
    /// grid (first-appearance) order.
    pub unique: Vec<usize>,
    /// For every grid cell, the position in `unique` of its
    /// representative (`assignment[i] == j` ⇒ cell `i`'s summary is
    /// `unique[j]`'s).
    pub assignment: Vec<usize>,
}

impl DedupPlan {
    /// Group `cells` by fingerprint, first appearance representative.
    pub fn partition(cells: &[CellSpec]) -> Self {
        let mut by_fp: HashMap<CellFingerprint, usize> = HashMap::with_capacity(cells.len());
        let mut unique = Vec::new();
        let mut assignment = Vec::with_capacity(cells.len());
        for (i, cell) in cells.iter().enumerate() {
            let slot = *by_fp.entry(cell.fingerprint()).or_insert_with(|| {
                unique.push(i);
                unique.len() - 1
            });
            assignment.push(slot);
        }
        DedupPlan { unique, assignment }
    }

    /// No dedup: every cell is its own work item (the pre-cache
    /// engine's schedule).
    pub fn identity(n: usize) -> Self {
        DedupPlan { unique: (0..n).collect(), assignment: (0..n).collect() }
    }
}

/// A concurrent build-once map: the first caller of a key runs the
/// build closure, concurrent callers of the same key block on its
/// `OnceLock` and then share the (cheaply cloned, e.g. `Arc`ed) value.
/// Distinct keys never contend beyond the brief map-entry lock.
pub struct BuildOnce<K, V> {
    map: Mutex<HashMap<K, Arc<OnceLock<V>>>>,
}

impl<K, V> Default for BuildOnce<K, V> {
    fn default() -> Self {
        BuildOnce { map: Mutex::new(HashMap::new()) }
    }
}

impl<K: Eq + Hash + Clone, V: Clone> BuildOnce<K, V> {
    /// Return `key`'s value, running `build` exactly once per key
    /// (concurrent callers block on the first builder, then clone).
    pub fn get_or_build(&self, key: &K, build: impl FnOnce() -> V) -> V {
        let slot = {
            let mut map = self.map.lock().expect("build-once map lock");
            map.entry(key.clone()).or_default().clone()
        };
        // Outside the map lock: building one key never blocks others.
        slot.get_or_init(build).clone()
    }

    /// Number of distinct keys ever requested (diagnostics/tests).
    pub fn entries(&self) -> usize {
        self.map.lock().expect("build-once map lock").len()
    }
}

/// Key of a shared [`CompiledTopology`]: the construction inputs plus
/// the round budget the compile was gated on. `t` is collapsed to 0 for
/// designs that never consume it ([`TopologyKind::t_sensitive`]), so a
/// multi-`t` sweep compiles e.g. RING once, not once per `t`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CompiledKey {
    topology: TopologyKind,
    network: String,
    profile: String,
    t: u32,
    rounds: usize,
}

impl CompiledKey {
    fn for_cell(cell: &CellSpec) -> Self {
        CompiledKey {
            topology: cell.topology,
            network: cell.network.clone(),
            profile: cell.profile.clone(),
            t: if cell.topology.t_sensitive() { cell.t } else { 0 },
            rounds: cell.rounds,
        }
    }
}

/// The sharable compilation product of one deterministic cell shape —
/// which engine its simulations run on, with the engine's immutable
/// `Arc`-shared half where one exists. Mirrors the dispatch order of
/// [`crate::simtime::simulate_summary_scratch`] exactly, so cached and
/// uncached cells always take the same engine (reports carry the engine
/// kind, which must not depend on the execution strategy).
#[derive(Clone)]
enum SharedSchedule {
    /// Materializable period: per-state tables + cycle replay.
    Periodic(Arc<CompiledTopology>),
    /// Unmaterializable period but multiplicity-factorizable
    /// (huge-s_max multigraphs): the O(groups)-per-round engine.
    Factored(Arc<FactoredTopology>),
    /// No shareable structure: the streaming verdict, cached so doomed
    /// compiles are not re-attempted.
    Stream,
}

/// Shared artifacts for one sweep run. Create one per [`super::run`]
/// invocation (or hold one across invocations to share compiles between
/// sweeps of the same process — everything inside is immutable once
/// built).
#[derive(Default)]
pub struct SweepCache {
    /// (construction inputs, rounds) → compiled schedule (or the
    /// cached streaming verdict).
    compiled: BuildOnce<CompiledKey, SharedSchedule>,
    /// (network, profile) → shared MATCHA construction.
    matcha_cores: BuildOnce<(String, String), Arc<MatchaCore>>,
}

impl SweepCache {
    /// Distinct compiled-topology keys built so far (tests/benches).
    pub fn compiled_entries(&self) -> usize {
        self.compiled.entries()
    }

    /// Distinct MATCHA cores built so far (tests/benches).
    pub fn matcha_entries(&self) -> usize {
        self.matcha_cores.entries()
    }
}

/// Simulate one unique cell through the shared caches. Byte-identical
/// to [`super::run_cell_summary`]: the cached paths factor work, they
/// never change what is computed —
///
/// * deterministic periodic designs run on an `Arc`-shared
///   [`CompiledTopology`] with the thread's pooled
///   [`crate::simtime::DelaySlab`] (same compile the per-cell engine
///   would produce, pinned by `simtime::compiled` tests);
/// * deterministic factorizable designs (huge-s_max multigraphs) run
///   on an `Arc`-shared [`FactoredTopology`] with the pooled
///   [`crate::simtime::FactoredSlab`] (pinned by `simtime::factored`
///   tests);
/// * MATCHA variants instantiate over a shared [`MatchaCore`] with the
///   cell's own RNG stream (pinned by `topo::matcha` tests);
/// * everything else streams through the pooled edge arena.
pub fn run_cell_cached(cell: &CellSpec, cache: &SweepCache) -> SimSummary {
    run_cell_cached_timed(cell, cache).0
}

/// [`run_cell_cached`] with the build/simulate wall-clock split
/// ([`crate::sweep::CellTiming`]) and the engine's [`EngineStats`].
/// Build time is measured *inside* the build-once closures, so it
/// counts only construction work this worker actually performed: a
/// cache hit — and a worker blocked on another thread's in-flight
/// build of the same key — both record ~0 (the wait overlaps other
/// workers' time and is visible only in the sweep's host wall-clock).
/// Simulate time covers the round loop.
pub fn run_cell_cached_timed(
    cell: &CellSpec,
    cache: &SweepCache,
) -> (SimSummary, CellTiming, EngineStats) {
    with_scratch(|scratch| run_cell_cached_scratch(cell, cache, scratch))
}

fn run_cell_cached_scratch(
    cell: &CellSpec,
    cache: &SweepCache,
    scratch: &mut SimScratch,
) -> (SimSummary, CellTiming, EngineStats) {
    use std::time::Instant;
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    match cell.topology {
        TopologyKind::Matcha | TopologyKind::MatchaPlus => {
            let mut build_ms = 0.0;
            let core = cache.matcha_cores.get_or_build(
                &(cell.network.clone(), cell.profile.clone()),
                || {
                    let t0 = Instant::now();
                    let core = Arc::new(MatchaCore::build(&net, &prof));
                    build_ms = t0.elapsed().as_secs_f64() * 1e3;
                    core
                },
            );
            let budget =
                if cell.topology == TopologyKind::MatchaPlus { 1.0 } else { DEFAULT_BUDGET };
            let mut topo = MatchaTopology::from_core(core, budget, cell.cell_seed);
            let t1 = Instant::now();
            let (summary, stats) =
                simulate_summary_scratch(&mut topo, &net, &prof, cell.rounds, scratch);
            let timing = CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 };
            (summary, timing, stats)
        }
        _ => {
            let key = CompiledKey::for_cell(cell);
            // If this worker's compile lands on the streaming verdict,
            // keep its built topology for the fallback below rather
            // than constructing it a second time.
            let mut built: Option<Box<dyn TopologyDesign>> = None;
            let mut build_ms = 0.0;
            let schedule = cache.compiled.get_or_build(&key, || {
                let t0 = Instant::now();
                let mut topo = cfg.build_topology();
                // Same dispatch order as simulate_summary_scratch:
                // periodic → factored → streaming.
                let sched = match CompiledTopology::compile(topo.as_mut(), cell.rounds) {
                    Some(ct) => SharedSchedule::Periodic(Arc::new(ct)),
                    None => match FactoredTopology::compile(topo.as_ref()) {
                        Some(ft) => SharedSchedule::Factored(Arc::new(ft)),
                        None => {
                            built = Some(topo);
                            SharedSchedule::Stream
                        }
                    },
                };
                build_ms = t0.elapsed().as_secs_f64() * 1e3;
                sched
            });
            match schedule {
                SharedSchedule::Periodic(ct) => {
                    let t1 = Instant::now();
                    scratch.slab.resolve(&ct, &net, &prof);
                    let (summary, stats) =
                        run_compiled(&ct, &mut scratch.slab, &net, &prof, cell.rounds);
                    let timing =
                        CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 };
                    (summary, timing, stats)
                }
                SharedSchedule::Factored(ft) => {
                    let t1 = Instant::now();
                    scratch.factored.resolve(&ft, &net, &prof);
                    let (summary, stats) =
                        run_factored(&ft, &mut scratch.factored, &net, &prof, cell.rounds);
                    let timing =
                        CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 };
                    (summary, timing, stats)
                }
                // Streaming cells: the design is consumed mutably per
                // cell, so cache hits still rebuild the topology — but
                // the round loop runs over the pooled arena, and the
                // cached verdict skips straight to the streaming engine
                // (the periodic/factored compiles already failed once
                // for this key; same dispatch outcome, same bits).
                SharedSchedule::Stream => {
                    let tb = Instant::now();
                    let mut topo = built.unwrap_or_else(|| cfg.build_topology());
                    let build_ms = build_ms + tb.elapsed().as_secs_f64() * 1e3;
                    let t1 = Instant::now();
                    let (summary, stats) = simulate_summary_streaming_scratch(
                        topo.as_mut(),
                        &net,
                        &prof,
                        cell.rounds,
                        scratch,
                    );
                    let timing =
                        CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 };
                    (summary, timing, stats)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::run_cell_summary;
    use crate::sweep::spec::SweepSpec;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn spec() -> SweepSpec {
        SweepSpec {
            name: "cache".into(),
            topologies: vec![TopologyKind::Ring, TopologyKind::Matcha, TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![3, 5],
            seeds: vec![11, 23],
            rounds: 60,
        }
    }

    #[test]
    fn fingerprint_includes_seed_only_for_stochastic_kinds() {
        let cells = spec().expand();
        for pair in cells.chunks(2) {
            // Innermost axis is the seed: each chunk is one coordinate
            // under two base seeds.
            let (a, b) = (&pair[0], &pair[1]);
            assert_ne!(a.cell_seed, b.cell_seed);
            if a.topology.seed_sensitive() {
                assert_ne!(a.fingerprint(), b.fingerprint(), "stochastic cells must not merge");
                assert_eq!(a.fingerprint().seed, Some(a.cell_seed));
            } else {
                assert_eq!(a.fingerprint(), b.fingerprint(), "deterministic cells must merge");
                assert_eq!(a.fingerprint().seed, None);
            }
        }
    }

    #[test]
    fn partition_is_order_preserving_and_complete() {
        let cells = spec().expand();
        let plan = DedupPlan::partition(&cells);
        assert_eq!(plan.assignment.len(), cells.len());
        // 3 topologies x 2 t x 2 seeds = 12 cells; matcha keeps all 4
        // (seed-sensitive), ring and multigraph keep one per t.
        assert_eq!(plan.unique.len(), 4 + 2 + 2);
        // Representatives appear in grid order and map to themselves.
        assert!(plan.unique.windows(2).all(|w| w[0] < w[1]));
        for (i, &slot) in plan.assignment.iter().enumerate() {
            let rep = plan.unique[slot];
            assert!(rep <= i);
            assert_eq!(cells[rep].fingerprint(), cells[i].fingerprint());
        }
        let id = DedupPlan::identity(cells.len());
        assert_eq!(id.unique.len(), cells.len());
        assert_eq!(id.assignment, (0..cells.len()).collect::<Vec<_>>());
    }

    #[test]
    fn build_once_builds_each_key_exactly_once_under_contention() {
        let cache: BuildOnce<u32, u64> = BuildOnce::default();
        let builds = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for k in 0..16u32 {
                        let v = cache.get_or_build(&k, || {
                            builds.fetch_add(1, Ordering::Relaxed);
                            k as u64 * 3
                        });
                        assert_eq!(v, k as u64 * 3);
                    }
                });
            }
        });
        assert_eq!(builds.load(Ordering::Relaxed), 16, "each key must build exactly once");
        assert_eq!(cache.entries(), 16);
    }

    #[test]
    fn cached_cells_match_the_uncached_engine_bitwise() {
        let cells = spec().expand();
        let cache = SweepCache::default();
        for cell in &cells {
            let got = run_cell_cached(cell, &cache);
            let want = run_cell_summary(cell);
            let ctx = format!("{}/t{}/seed{}", cell.topology.as_str(), cell.t, cell.base_seed);
            assert_eq!(got.topology, want.topology, "{ctx}");
            assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits(), "{ctx}");
            assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits(), "{ctx}");
            assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated, "{ctx}");
            assert_eq!(got.max_isolated, want.max_isolated, "{ctx}");
        }
        // Shared-artifact accounting: one MATCHA core for the single
        // (network, profile); ring collapses its t axis into one
        // compile, the multigraph keeps one per t.
        assert_eq!(cache.matcha_entries(), 1);
        assert_eq!(cache.compiled_entries(), 1 + 2);
    }

    #[test]
    fn factored_schedules_are_shared_and_exact() {
        // t = 30: s_max is unmaterializable, so the cached path must
        // take the Arc-shared factored schedule — one compile across
        // the seed axis — and stay bit-identical (summary AND engine
        // stats, which ride in reports) to the uncached engine.
        use crate::simtime::EngineKind;
        use crate::topo::MultigraphTopology;
        // Pick a round budget strictly below s_max so the periodic
        // compile is provably skipped whatever gaia's exact t=30 LCM.
        let net = crate::net::zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        let s_max = MultigraphTopology::from_network(&net, &prof, 30).s_max();
        assert!(s_max >= 5, "gaia t=30 must have a non-trivial schedule");
        let rounds = (s_max - 1).min(80) as usize;
        let spec = SweepSpec {
            name: "factored".into(),
            topologies: vec![TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![30],
            seeds: vec![11, 23],
            rounds,
        };
        let cache = SweepCache::default();
        for cell in &spec.expand() {
            let (got, _, got_stats) = run_cell_cached_timed(cell, &cache);
            let (want, _, want_stats) = crate::sweep::run_cell_summary_timed(cell);
            assert_eq!(got_stats.kind, EngineKind::Factored, "t=30 must factor");
            assert_eq!(got_stats, want_stats, "stats must not depend on caching");
            assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
            assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits());
            assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated);
            assert_eq!(got.max_isolated, want.max_isolated);
        }
        assert_eq!(cache.compiled_entries(), 1, "one shared factored compile");
    }
}
