//! Cross-cell memoization for the sweep engine: semantic cell
//! fingerprints, the grid→unique-work partition, and a concurrent
//! build-once cache for shared construction/compilation artifacts.
//!
//! The paper's grids are heavily redundant: deterministic designs
//! (STAR, MATCHA+, MST, δ-MBST, RING, multigraph) are pure functions of
//! (network, profile, t), so a seed axis of N values replicates the
//! exact same simulation N times, bit for bit. This module makes that
//! redundancy explicit:
//!
//! * [`CellFingerprint`] — the semantic identity of a cell's result:
//!   (topology, network, profile, t, rounds), plus the derived cell
//!   seed **only** when the design is stochastic
//!   ([`TopologyKind::seed_sensitive`]). Equal fingerprints ⇒
//!   bit-identical `SimSummary`s, because every input of
//!   [`CellSpec::to_experiment`] → `simulate_summary` is either in the
//!   fingerprint or provably unused.
//! * [`DedupPlan`] — partitions an expanded grid into unique work items
//!   (first appearance wins) plus a fan-out assignment, so the
//!   scheduler simulates O(unique) cells and copies summaries to every
//!   duplicate coordinate. Reports stay grid-ordered and byte-identical
//!   to the undeduplicated engine: the per-cell `seed`/`cell_seed`
//!   report fields come from each cell's own spec, never from the
//!   representative.
//! * [`SweepCache`] — a [`BuildOnce`] map per artifact kind: shared
//!   compiled schedules ([`CompiledTopology`] / [`FactoredTopology`],
//!   `Arc`ed across the seed axis — and across `t`, for designs that
//!   ignore it; the key keeps `rounds` because it gates the periodic
//!   compile) and shared [`MatchaCore`]s (a stochastic seed axis pays
//!   for one Christofides/MST/decomposition build, not N). Workers
//!   that race on a key block on one `OnceLock`, so a construction
//!   never runs twice.
//! * a **thread-local scratch pool** — every worker thread owns one
//!   [`SimScratch`] (delay slabs, factored-group slab, streaming edge
//!   arena + per-round buffers) that [`run_cell_cached`] reuses across
//!   every cell the thread simulates, whatever engine the cell takes.
//!   Large-N cells stop reallocating O(N²) pair tables and O(E) slabs
//!   per cell; reuse never changes results because each engine fully
//!   re-resolves its layer per cell (pinned by the slab-reuse tests in
//!   `simtime`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex, OnceLock};

use std::time::Instant;

use crate::config::TopologyKind;
use crate::net::{DatasetProfile, NetworkSpec};
use crate::simtime::{
    run_batched, run_compiled, run_factored, run_scenario_batched, run_scenario_compiled,
    simulate_summary_scenario, simulate_summary_scratch, simulate_summary_streaming_scratch,
    BatchLane, CompiledTopology, EngineStats, FactoredTopology, ScenarioSpec, SimScratch,
    SimSummary, LANE_WIDTH, MIN_BATCH,
};
use crate::topo::matcha::{MatchaCore, MatchaTopology, DEFAULT_BUDGET};
use crate::topo::TopologyDesign;

use super::spec::CellSpec;
use super::CellTiming;

thread_local! {
    /// The per-thread scratch pool: reused across every cell one
    /// worker thread simulates within a sweep. Both pool
    /// implementations spawn fresh workers per `sweep::run`, so
    /// parallel-run scratch is dropped when the sweep ends; only a
    /// caller-thread (threads <= 1) run retains its scratch across
    /// invocations.
    static SCRATCH: RefCell<SimScratch> = RefCell::new(SimScratch::default());
}

/// Run `f` with this thread's pooled [`SimScratch`].
fn with_scratch<R>(f: impl FnOnce(&mut SimScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Simulate an ad-hoc design through this thread's pooled
/// [`SimScratch`] — the entry point `mgfl optimize` uses to evaluate
/// search candidates, so every fitness call reuses the same slabs the
/// sweep workers do (same dispatch, same bits as
/// [`crate::simtime::simulate_summary`]; only allocation is factored).
pub fn simulate_design_pooled(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> (SimSummary, EngineStats) {
    with_scratch(|scratch| simulate_summary_scratch(topo, net, profile, rounds, scratch))
}

/// Semantic identity of one grid cell's simulation result. Two cells
/// with equal fingerprints produce bit-identical [`SimSummary`]s, so
/// the scheduler simulates one and fans the summary out to both.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CellFingerprint {
    /// Topology design kind.
    pub topology: TopologyKind,
    /// Canonical network name.
    pub network: String,
    /// Canonical dataset-profile name.
    pub profile: String,
    /// Algorithm-1 multiplicity cap, verbatim from the cell. Designs
    /// that never consume t still share their *compiled topology* via
    /// the compile-cache key (which zeroes t for them), but their
    /// fingerprints keep t as written.
    pub t: u32,
    /// Simulated rounds.
    pub rounds: usize,
    /// The derived per-cell stream — present **only** when the design
    /// consumes randomness, so stochastic cells with distinct seeds are
    /// never merged while deterministic cells collapse across the whole
    /// seed axis.
    pub seed: Option<u64>,
    /// [`ScenarioSpec::fingerprint`] of the cell's fault-injection
    /// scenario, when one is attached. Joining the identity here keeps
    /// churned cells from deduping against — and the store from ever
    /// serving — their static twins.
    pub scenario: Option<u64>,
    /// [`crate::search::AdaptSpec::fingerprint`] of the cell's
    /// adaptation config, present **only** for active (non-`none`)
    /// policies. Policy-none cells keep `None` so they dedup against —
    /// and warm-start from — their static scenario twins, exactly as
    /// PR 9 wrote them.
    pub adapt: Option<u64>,
}

impl CellSpec {
    /// This cell's [`CellFingerprint`] (see the module docs for the
    /// dedup contract it encodes).
    pub fn fingerprint(&self) -> CellFingerprint {
        CellFingerprint {
            topology: self.topology,
            network: self.network.clone(),
            profile: self.profile.clone(),
            t: self.t,
            rounds: self.rounds,
            seed: if self.topology.seed_sensitive() { Some(self.cell_seed) } else { None },
            scenario: self.scenario.as_ref().map(|sc| sc.fingerprint()),
            adapt: self.adapt.as_ref().filter(|a| a.is_active()).map(|a| a.fingerprint()),
        }
    }

    /// Whether this cell re-plans at segment boundaries (an attached
    /// adaptation spec with an active, non-`none` policy). Adaptive
    /// cells take the dedicated [`run_cell_adaptive`] executor; policy
    /// `none` cells route through the PR 9 scenario executors
    /// untouched.
    pub fn is_adaptive(&self) -> bool {
        self.adapt.as_ref().is_some_and(|a| a.is_active())
    }
}

/// The grid→unique-work partition: which cells to actually simulate and
/// where every grid coordinate's result comes from.
#[derive(Debug, Clone)]
pub struct DedupPlan {
    /// Indices into the expanded grid of the representative cells, in
    /// grid (first-appearance) order.
    pub unique: Vec<usize>,
    /// For every grid cell, the position in `unique` of its
    /// representative (`assignment[i] == j` ⇒ cell `i`'s summary is
    /// `unique[j]`'s).
    pub assignment: Vec<usize>,
}

impl DedupPlan {
    /// Group `cells` by fingerprint, first appearance representative.
    pub fn partition(cells: &[CellSpec]) -> Self {
        let mut by_fp: HashMap<CellFingerprint, usize> = HashMap::with_capacity(cells.len());
        let mut unique = Vec::new();
        let mut assignment = Vec::with_capacity(cells.len());
        for (i, cell) in cells.iter().enumerate() {
            let slot = *by_fp.entry(cell.fingerprint()).or_insert_with(|| {
                unique.push(i);
                unique.len() - 1
            });
            assignment.push(slot);
        }
        DedupPlan { unique, assignment }
    }

    /// No dedup: every cell is its own work item (the pre-cache
    /// engine's schedule).
    pub fn identity(n: usize) -> Self {
        DedupPlan { unique: (0..n).collect(), assignment: (0..n).collect() }
    }
}

/// A concurrent build-once map: the first caller of a key runs the
/// build closure, concurrent callers of the same key block on its
/// `OnceLock` and then share the (cheaply cloned, e.g. `Arc`ed) value.
/// Distinct keys never contend beyond the brief map-entry lock.
pub struct BuildOnce<K, V> {
    map: Mutex<HashMap<K, Arc<OnceLock<V>>>>,
}

impl<K, V> Default for BuildOnce<K, V> {
    fn default() -> Self {
        BuildOnce { map: Mutex::new(HashMap::new()) }
    }
}

impl<K: Eq + Hash + Clone, V: Clone> BuildOnce<K, V> {
    /// Return `key`'s value, running `build` exactly once per key
    /// (concurrent callers block on the first builder, then clone).
    ///
    /// Panic-safe: the map lock is only ever held around `HashMap` ops
    /// (which don't panic), so a poisoned lock — from a `build` closure
    /// that panicked on some *other* key while a caller held no lock,
    /// or from a panicking cell simulation unwinding through a caller —
    /// carries no torn state and is deliberately entered anyway. A
    /// panicking `build` leaves its `OnceLock` empty (std guarantees
    /// initialization is retried), so the key stays buildable instead
    /// of wedging every later lookup.
    pub fn get_or_build(&self, key: &K, build: impl FnOnce() -> V) -> V {
        let slot = {
            let mut map = self.map.lock().unwrap_or_else(|e| e.into_inner());
            map.entry(key.clone()).or_default().clone()
        };
        // Outside the map lock: building one key never blocks others.
        slot.get_or_init(build).clone()
    }

    /// Probe `key` without building: the value if it has been built,
    /// `None` otherwise (including while another thread's build is
    /// in flight). Never creates a map entry, so [`Self::entries`]
    /// accounting — which tests and the search's `unique_evals` pin —
    /// is unaffected by probes.
    pub fn get(&self, key: &K) -> Option<V> {
        let slot = self.map.lock().unwrap_or_else(|e| e.into_inner()).get(key).cloned()?;
        slot.get().cloned()
    }

    /// Number of distinct keys ever requested (diagnostics/tests).
    pub fn entries(&self) -> usize {
        self.map.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

/// Key of a shared [`CompiledTopology`]: the construction inputs plus
/// the round budget the compile was gated on. `t` is collapsed to 0 for
/// designs that never consume it ([`TopologyKind::t_sensitive`]), so a
/// multi-`t` sweep compiles e.g. RING once, not once per `t`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CompiledKey {
    topology: TopologyKind,
    network: String,
    profile: String,
    t: u32,
    rounds: usize,
}

impl CompiledKey {
    fn for_cell(cell: &CellSpec) -> Self {
        CompiledKey {
            topology: cell.topology,
            network: cell.network.clone(),
            profile: cell.profile.clone(),
            t: if cell.topology.t_sensitive() { cell.t } else { 0 },
            rounds: cell.rounds,
        }
    }
}

/// The sharable compilation product of one deterministic cell shape —
/// which engine its simulations run on, with the engine's immutable
/// `Arc`-shared half where one exists. Mirrors the dispatch order of
/// [`crate::simtime::simulate_summary_scratch`] exactly, so cached and
/// uncached cells always take the same engine (reports carry the engine
/// kind, which must not depend on the execution strategy).
#[derive(Clone)]
pub enum SharedSchedule {
    /// Materializable period: per-state tables + cycle replay. The only
    /// variant the batch planner ([`plan_batches`]) considers — batches
    /// are groups of cells whose `Periodic` compiles are
    /// [`CompiledTopology::schedule_eq`].
    Periodic(Arc<CompiledTopology>),
    /// Unmaterializable period but multiplicity-factorizable
    /// (huge-s_max multigraphs): the O(groups)-per-round engine.
    Factored(Arc<FactoredTopology>),
    /// No shareable structure: the streaming verdict, cached so doomed
    /// compiles are not re-attempted.
    Stream,
}

/// Shared artifacts for one sweep run. Create one per [`super::run`]
/// invocation (or hold one across invocations to share compiles between
/// sweeps of the same process — everything inside is immutable once
/// built).
#[derive(Default)]
pub struct SweepCache {
    /// (construction inputs, rounds) → compiled schedule (or the
    /// cached streaming verdict).
    compiled: BuildOnce<CompiledKey, SharedSchedule>,
    /// (network, profile) → shared MATCHA construction.
    matcha_cores: BuildOnce<(String, String), Arc<MatchaCore>>,
}

impl SweepCache {
    /// Distinct compiled-topology keys built so far (tests/benches).
    pub fn compiled_entries(&self) -> usize {
        self.compiled.entries()
    }

    /// Distinct MATCHA cores built so far (tests/benches).
    pub fn matcha_entries(&self) -> usize {
        self.matcha_cores.entries()
    }

    /// Resolve (building if first) the cell's shared schedule, plus the
    /// construction wall-clock this call actually spent (~0 on a cache
    /// hit). MATCHA variants return `None` — they are stochastic
    /// per-cell instantiations with no shareable schedule, and
    /// [`run_cell_cached`] routes them before the compile cache is
    /// consulted. The batch planner's phase-1 probe: the verdict (and
    /// dispatch) is exactly the one [`run_cell_cached`] would reach for
    /// this cell, so planning never changes which engine a cell takes.
    /// Adaptive cells also return `None` — their spliced schedules are
    /// a function of the run-time re-planning loop, never shareable.
    pub fn schedule_for(&self, cell: &CellSpec) -> (Option<SharedSchedule>, f64) {
        if cell.is_adaptive() {
            return (None, 0.0);
        }
        match cell.topology {
            TopologyKind::Matcha | TopologyKind::MatchaPlus => (None, 0.0),
            _ => {
                let key = CompiledKey::for_cell(cell);
                let mut build_ms = 0.0;
                let schedule = self.compiled.get_or_build(&key, || {
                    let t0 = Instant::now();
                    let mut topo = cell.to_experiment().build_topology();
                    // Same dispatch order as simulate_summary_scratch:
                    // periodic → factored → streaming.
                    let sched = match CompiledTopology::compile(topo.as_mut(), cell.rounds) {
                        Some(ct) => SharedSchedule::Periodic(Arc::new(ct)),
                        None => match FactoredTopology::compile(topo.as_ref()) {
                            Some(ft) => SharedSchedule::Factored(Arc::new(ft)),
                            None => SharedSchedule::Stream,
                        },
                    };
                    build_ms = t0.elapsed().as_secs_f64() * 1e3;
                    sched
                });
                (Some(schedule), build_ms)
            }
        }
    }
}

/// Simulate one unique cell through the shared caches. Byte-identical
/// to [`super::run_cell_summary`]: the cached paths factor work, they
/// never change what is computed —
///
/// * deterministic periodic designs run on an `Arc`-shared
///   [`CompiledTopology`] with the thread's pooled
///   [`crate::simtime::DelaySlab`] (same compile the per-cell engine
///   would produce, pinned by `simtime::compiled` tests);
/// * deterministic factorizable designs (huge-s_max multigraphs) run
///   on an `Arc`-shared [`FactoredTopology`] with the pooled
///   [`crate::simtime::FactoredSlab`] (pinned by `simtime::factored`
///   tests);
/// * MATCHA variants instantiate over a shared [`MatchaCore`] with the
///   cell's own RNG stream (pinned by `topo::matcha` tests);
/// * everything else streams through the pooled edge arena.
pub fn run_cell_cached(cell: &CellSpec, cache: &SweepCache) -> SimSummary {
    run_cell_cached_timed(cell, cache).0
}

/// [`run_cell_cached`] with the build/simulate wall-clock split
/// ([`crate::sweep::CellTiming`]) and the engine's [`EngineStats`].
/// Build time is measured *inside* the build-once closures, so it
/// counts only construction work this worker actually performed: a
/// cache hit — and a worker blocked on another thread's in-flight
/// build of the same key — both record ~0 (the wait overlaps other
/// workers' time and is visible only in the sweep's host wall-clock).
/// Simulate time covers the round loop.
pub fn run_cell_cached_timed(
    cell: &CellSpec,
    cache: &SweepCache,
) -> (SimSummary, CellTiming, EngineStats) {
    with_scratch(|scratch| run_cell_cached_scratch(cell, cache, scratch))
}

fn run_cell_cached_scratch(
    cell: &CellSpec,
    cache: &SweepCache,
    scratch: &mut SimScratch,
) -> (SimSummary, CellTiming, EngineStats) {
    use std::time::Instant;
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    match cell.topology {
        TopologyKind::Matcha | TopologyKind::MatchaPlus => {
            let mut build_ms = 0.0;
            let core = cache.matcha_cores.get_or_build(
                &(cell.network.clone(), cell.profile.clone()),
                || {
                    let t0 = Instant::now();
                    let core = Arc::new(MatchaCore::build(&net, &prof));
                    build_ms = t0.elapsed().as_secs_f64() * 1e3;
                    core
                },
            );
            let budget =
                if cell.topology == TopologyKind::MatchaPlus { 1.0 } else { DEFAULT_BUDGET };
            let mut topo = MatchaTopology::from_core(core, budget, cell.cell_seed);
            let t1 = Instant::now();
            let (summary, stats) =
                simulate_summary_scratch(&mut topo, &net, &prof, cell.rounds, scratch);
            let timing = CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 };
            (summary, timing, stats)
        }
        _ => {
            let key = CompiledKey::for_cell(cell);
            // If this worker's compile lands on the streaming verdict,
            // keep its built topology for the fallback below rather
            // than constructing it a second time.
            let mut built: Option<Box<dyn TopologyDesign>> = None;
            let mut build_ms = 0.0;
            let schedule = cache.compiled.get_or_build(&key, || {
                let t0 = Instant::now();
                let mut topo = cfg.build_topology();
                // Same dispatch order as simulate_summary_scratch:
                // periodic → factored → streaming.
                let sched = match CompiledTopology::compile(topo.as_mut(), cell.rounds) {
                    Some(ct) => SharedSchedule::Periodic(Arc::new(ct)),
                    None => match FactoredTopology::compile(topo.as_ref()) {
                        Some(ft) => SharedSchedule::Factored(Arc::new(ft)),
                        None => {
                            built = Some(topo);
                            SharedSchedule::Stream
                        }
                    },
                };
                build_ms = t0.elapsed().as_secs_f64() * 1e3;
                sched
            });
            match schedule {
                SharedSchedule::Periodic(ct) => {
                    let t1 = Instant::now();
                    scratch.slab.resolve(&ct, &net, &prof);
                    let (summary, stats) =
                        run_compiled(&ct, &mut scratch.slab, &net, &prof, cell.rounds);
                    let timing =
                        CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 };
                    (summary, timing, stats)
                }
                SharedSchedule::Factored(ft) => {
                    let t1 = Instant::now();
                    scratch.factored.resolve(&ft, &net, &prof);
                    let (summary, stats) =
                        run_factored(&ft, &mut scratch.factored, &net, &prof, cell.rounds);
                    let timing =
                        CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 };
                    (summary, timing, stats)
                }
                // Streaming cells: the design is consumed mutably per
                // cell, so cache hits still rebuild the topology — but
                // the round loop runs over the pooled arena, and the
                // cached verdict skips straight to the streaming engine
                // (the periodic/factored compiles already failed once
                // for this key; same dispatch outcome, same bits).
                SharedSchedule::Stream => {
                    let tb = Instant::now();
                    let mut topo = built.unwrap_or_else(|| cfg.build_topology());
                    let build_ms = build_ms + tb.elapsed().as_secs_f64() * 1e3;
                    let t1 = Instant::now();
                    let (summary, stats) = simulate_summary_streaming_scratch(
                        topo.as_mut(),
                        &net,
                        &prof,
                        cell.rounds,
                        scratch,
                    );
                    let timing =
                        CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 };
                    (summary, timing, stats)
                }
            }
        }
    }
}

/// The batch planner's output over one post-dedup unique-cell set:
/// groups of cell indices that share one periodic schedule (each group
/// at most [`LANE_WIDTH`] wide), plus every cell that runs the ordinary
/// per-cell path.
#[derive(Debug, Clone, Default)]
pub struct BatchPlan {
    /// Batches: indices into the planned cell slice, grid order within
    /// each chunk, every chunk's cells mutually `schedule_eq` and over
    /// the same network and round budget.
    pub chunks: Vec<Vec<usize>>,
    /// Cells on the per-cell fallback: factored/streaming verdicts,
    /// MATCHA variants, and periodic cells whose structural group is
    /// smaller than [`MIN_BATCH`].
    pub solos: Vec<usize>,
}

impl BatchPlan {
    /// Cells the plan routes through the batched engine.
    pub fn batched_cells(&self) -> usize {
        self.chunks.iter().map(Vec::len).sum()
    }
}

/// Partition cells by shared periodic schedule. `schedules[i]` is cell
/// `i`'s resolved [`SweepCache::schedule_for`] verdict; only
/// `Periodic` cells are batch-eligible. Grouping is by
/// (network, rounds, [`CompiledTopology::schedule_fingerprint`])
/// *confirmed* by full [`CompiledTopology::schedule_eq`] against the
/// group representative, so a fingerprint collision degrades to extra
/// groups, never to a wrong batch. Structural groups of at least
/// [`MIN_BATCH`] are chunked into runs of at most [`LANE_WIDTH`] cells
/// in grid order (a trailing short chunk stays batched so the label is
/// a pure function of the group, not its chunking).
///
/// The plan is a pure function of `(cells, schedules)` — no pointer
/// identity, no thread scheduling — so dedup and no-dedup sweeps at any
/// thread count label the same cells `batched`, keeping report
/// artifacts byte-identical across execution modes.
pub fn plan_batches(cells: &[&CellSpec], schedules: &[Option<SharedSchedule>]) -> BatchPlan {
    assert_eq!(cells.len(), schedules.len());
    // (network, rounds, fingerprint) → structural subgroups (the inner
    // Vec of Vecs handles fingerprint collisions): first-appearance
    // order throughout.
    let mut order: Vec<Vec<Vec<usize>>> = Vec::new();
    let mut index: HashMap<(&str, usize, u64), usize> = HashMap::new();
    let mut plan = BatchPlan::default();
    for (i, (cell, sched)) in cells.iter().zip(schedules).enumerate() {
        let Some(SharedSchedule::Periodic(ct)) = sched else {
            plan.solos.push(i);
            continue;
        };
        let key = (cell.network.as_str(), cell.rounds, ct.schedule_fingerprint());
        let slot = *index.entry(key).or_insert_with(|| {
            order.push(Vec::new());
            order.len() - 1
        });
        let subgroups = &mut order[slot];
        let rep_of = |sub: &[usize]| match &schedules[sub[0]] {
            Some(SharedSchedule::Periodic(rep)) => Arc::clone(rep),
            _ => unreachable!("subgroups hold periodic cells only"),
        };
        match subgroups.iter_mut().find(|sub| rep_of(sub).schedule_eq(ct)) {
            Some(sub) => sub.push(i),
            None => subgroups.push(vec![i]),
        }
    }
    for sub in order.into_iter().flatten() {
        if sub.len() >= MIN_BATCH {
            for chunk in sub.chunks(LANE_WIDTH) {
                plan.chunks.push(chunk.to_vec());
            }
        } else {
            plan.solos.extend(sub);
        }
    }
    plan
}

/// Execute one planned batch through this thread's pooled scratch:
/// every cell of `chunk` becomes one lane of a single
/// [`run_batched`] call over the first cell's compile as
/// representative. Each lane's summary is bit-identical to the per-cell
/// path; `sim_ms` splits the batch's wall-clock evenly across lanes
/// (the lanes are inseparable inside one lockstep pass), and `build_ms`
/// is 0 — the shared compile was charged when the schedule cache built
/// it.
pub fn run_batch_cached(
    chunk: &[(&CellSpec, Arc<CompiledTopology>)],
    rounds: usize,
) -> Vec<(SimSummary, CellTiming, EngineStats)> {
    // Resolve the (network, profile) pairs first so the lanes can
    // borrow them for the duration of the run.
    let resolved: Vec<(NetworkSpec, DatasetProfile)> = chunk
        .iter()
        .map(|(cell, _)| {
            let cfg = cell.to_experiment();
            let net = cfg.resolve_network();
            let prof = cfg.resolve_profile().expect("validated profile");
            (net, prof)
        })
        .collect();
    let lanes: Vec<BatchLane> = chunk
        .iter()
        .zip(&resolved)
        .map(|((_, ct), (net, prof))| BatchLane { ct, net, profile: prof })
        .collect();
    let rep = &chunk[0].1;
    let t0 = Instant::now();
    let results = with_scratch(|scratch| run_batched(rep, &lanes, rounds, &mut scratch.batched));
    let sim_ms = t0.elapsed().as_secs_f64() * 1e3 / lanes.len() as f64;
    results
        .into_iter()
        .map(|(summary, stats)| (summary, CellTiming { build_ms: 0.0, sim_ms }, stats))
        .collect()
}

/// Run one cell as a single-lane batch, building its compile fresh —
/// the no-dedup engine's executor for cells the planner labels
/// `batched`. A one-lane batch performs exactly the per-lane op
/// sequence of [`run_compiled`], so the summary is bit-identical to
/// every other path; only the reported engine kind says `batched`,
/// which is the point: the report's engine column must not depend on
/// whether dedup ran.
pub fn run_cell_batched_single(cell: &CellSpec) -> (SimSummary, CellTiming, EngineStats) {
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    let t0 = Instant::now();
    let mut topo = cfg.build_topology();
    let ct = CompiledTopology::compile(topo.as_mut(), cell.rounds)
        .expect("batch-labeled cells have a materializable periodic schedule");
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let lane = BatchLane { ct: &ct, net: &net, profile: &prof };
    let mut out = with_scratch(|scratch| {
        run_batched(&ct, std::slice::from_ref(&lane), cell.rounds, &mut scratch.batched)
    });
    let (summary, stats) = out.pop().expect("one lane in, one result out");
    (summary, CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 }, stats)
}

/// Outcome of one scenario cell: the summary/stats pair, or the
/// structured per-cell error (e.g. churn leaving fewer than two silos
/// up on this cell's network) that flows into the report instead of a
/// panic. Timing is always present — it covers the work performed
/// before the error surfaced.
pub type ScenarioOutcome = (Result<(SimSummary, EngineStats), String>, CellTiming);

/// Simulate one scenario cell through the shared caches — the
/// dedup engine's solo executor for cells carrying a
/// [`ScenarioSpec`]. The *base* schedule cache is scenario-free (masks
/// are applied at run time), so scenario cells share compiles with
/// their static twins:
///
/// * a `Periodic` verdict runs the piecewise-static masked engine over
///   the `Arc`-shared base compile ([`run_scenario_compiled`]);
/// * `Factored`/`Stream` verdicts rebuild the design and re-enter the
///   scenario dispatcher ([`simulate_summary_scenario`]), which lands
///   on the scenario-factored or masked-tracker tier — the same tier
///   the uncached engine takes, so engine labels never depend on
///   caching;
/// * MATCHA variants instantiate over the shared [`MatchaCore`] with
///   the cell's own stream, exactly like the static path.
pub fn run_cell_scenario_cached(cell: &CellSpec, cache: &SweepCache) -> ScenarioOutcome {
    let sc = cell.scenario.as_deref().expect("scenario executors require a scenario");
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    match cell.topology {
        TopologyKind::Matcha | TopologyKind::MatchaPlus => {
            let mut build_ms = 0.0;
            let core = cache.matcha_cores.get_or_build(
                &(cell.network.clone(), cell.profile.clone()),
                || {
                    let t0 = Instant::now();
                    let core = Arc::new(MatchaCore::build(&net, &prof));
                    build_ms = t0.elapsed().as_secs_f64() * 1e3;
                    core
                },
            );
            let budget =
                if cell.topology == TopologyKind::MatchaPlus { 1.0 } else { DEFAULT_BUDGET };
            let mut topo = MatchaTopology::from_core(core, budget, cell.cell_seed);
            let t1 = Instant::now();
            let r = simulate_summary_scenario(&mut topo, &net, &prof, cell.rounds, sc);
            (r, CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 })
        }
        _ => {
            let (sched, build_ms) = cache.schedule_for(cell);
            match sched.expect("non-MATCHA cells resolve a schedule") {
                SharedSchedule::Periodic(ct) => {
                    let t1 = Instant::now();
                    let r = run_scenario_compiled(&ct, &net, &prof, cell.rounds, sc);
                    (r, CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 })
                }
                SharedSchedule::Factored(_) | SharedSchedule::Stream => {
                    let tb = Instant::now();
                    let mut topo = cfg.build_topology();
                    let build_ms = build_ms + tb.elapsed().as_secs_f64() * 1e3;
                    let t1 = Instant::now();
                    let r =
                        simulate_summary_scenario(topo.as_mut(), &net, &prof, cell.rounds, sc);
                    (r, CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 })
                }
            }
        }
    }
}

/// Simulate one *adaptive* cell: build the static base design fresh,
/// then hand it to the adaptation loop
/// ([`crate::search::simulate_summary_adaptive`]), which re-plans the
/// overlay at every scenario segment boundary and splices the phases
/// back together. Always solo — spliced schedules are run-time state,
/// so there is nothing to share or batch — and identical under dedup
/// on/off, caching, and any thread count: the adaptation RNG derives
/// from (scenario seed, policy, segment index) only.
pub fn run_cell_adaptive(cell: &CellSpec) -> ScenarioOutcome {
    let sc = cell.scenario.as_deref().expect("adaptive cells carry a scenario");
    let spec = cell.adapt.as_deref().expect("adaptive cells carry an adapt spec");
    debug_assert!(spec.is_active(), "policy-none cells take the scenario executors");
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    let t0 = Instant::now();
    let topo = cfg.build_topology();
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let r = crate::search::simulate_summary_adaptive(
        topo, &net, &prof, cell.rounds, sc, spec, cell.t,
    );
    (r, CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 })
}

/// The uncached scenario executor (dedup off, unlabeled cells): fresh
/// build, full scenario dispatcher. Bit-identical to
/// [`run_cell_scenario_cached`] tier for tier.
pub fn run_cell_scenario_uncached(cell: &CellSpec) -> ScenarioOutcome {
    let sc = cell.scenario.as_deref().expect("scenario executors require a scenario");
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    let t0 = Instant::now();
    let mut topo = cfg.build_topology();
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let r = simulate_summary_scenario(topo.as_mut(), &net, &prof, cell.rounds, sc);
    (r, CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 })
}

/// Run one batch-labeled scenario cell as a single-lane scenario batch
/// (dedup off). A one-lane scenario batch performs exactly the per-lane
/// op sequence of [`run_scenario_compiled`], so only the reported
/// engine kind says `batched` — the report must not depend on whether
/// dedup ran.
pub fn run_cell_scenario_batched_single(cell: &CellSpec) -> ScenarioOutcome {
    let sc = cell.scenario.as_deref().expect("scenario executors require a scenario");
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    let t0 = Instant::now();
    let mut topo = cfg.build_topology();
    let ct = CompiledTopology::compile(topo.as_mut(), cell.rounds)
        .expect("batch-labeled cells have a materializable periodic schedule");
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let lane = BatchLane { ct: &ct, net: &net, profile: &prof };
    let r = run_scenario_batched(&ct, std::slice::from_ref(&lane), cell.rounds, sc)
        .map(|mut v| v.pop().expect("one lane in, one result out"));
    (r, CellTiming { build_ms, sim_ms: t1.elapsed().as_secs_f64() * 1e3 })
}

/// Execute one planned batch of scenario cells: every cell becomes one
/// lane of a single [`run_scenario_batched`] pass over the shared base
/// compile. The batch key holds (network, rounds) constant and the
/// scenario is spec-wide, so a timeline error — a pure function of
/// (scenario, network, rounds) — fails every lane identically; each
/// lane then carries the same structured error its solo run would.
pub fn run_batch_scenario(
    chunk: &[(&CellSpec, Arc<CompiledTopology>)],
    rounds: usize,
    sc: &ScenarioSpec,
) -> Vec<ScenarioOutcome> {
    let resolved: Vec<(NetworkSpec, DatasetProfile)> = chunk
        .iter()
        .map(|(cell, _)| {
            let cfg = cell.to_experiment();
            let net = cfg.resolve_network();
            let prof = cfg.resolve_profile().expect("validated profile");
            (net, prof)
        })
        .collect();
    let lanes: Vec<BatchLane> = chunk
        .iter()
        .zip(&resolved)
        .map(|((_, ct), (net, prof))| BatchLane { ct, net, profile: prof })
        .collect();
    let rep = &chunk[0].1;
    let t0 = Instant::now();
    let results = run_scenario_batched(rep, &lanes, rounds, sc);
    let sim_ms = t0.elapsed().as_secs_f64() * 1e3 / lanes.len() as f64;
    let timing = CellTiming { build_ms: 0.0, sim_ms };
    match results {
        Ok(v) => v.into_iter().map(|r| (Ok(r), timing)).collect(),
        Err(e) => chunk.iter().map(|_| (Err(e.clone()), timing)).collect(),
    }
}

/// Plan and execute a small cell list serially with automatic batching:
/// resolve every cell's shared schedule through `cache`, batch the
/// groups [`plan_batches`] finds, run everything else per-cell. Results
/// come back in input order. This is the search engine's entry for its
/// baseline probes (and any caller too small to justify the sweep
/// engine's parallel phases); schedule-construction cost is folded into
/// each solo/batched cell's timing the same way the sweep engine's
/// phase split does.
pub fn run_cells_auto_batched(
    cells: &[CellSpec],
    cache: &SweepCache,
) -> Vec<(SimSummary, CellTiming, EngineStats)> {
    let refs: Vec<&CellSpec> = cells.iter().collect();
    let scheds: Vec<Option<SharedSchedule>> =
        refs.iter().map(|c| cache.schedule_for(c).0).collect();
    let plan = plan_batches(&refs, &scheds);
    let mut out: Vec<Option<(SimSummary, CellTiming, EngineStats)>> =
        cells.iter().map(|_| None).collect();
    for chunk in &plan.chunks {
        let batch: Vec<(&CellSpec, Arc<CompiledTopology>)> = chunk
            .iter()
            .map(|&i| match &scheds[i] {
                Some(SharedSchedule::Periodic(ct)) => (refs[i], Arc::clone(ct)),
                _ => unreachable!("planner only chunks periodic cells"),
            })
            .collect();
        let rounds = refs[chunk[0]].rounds;
        for (&i, r) in chunk.iter().zip(run_batch_cached(&batch, rounds)) {
            out[i] = Some(r);
        }
    }
    for &i in &plan.solos {
        out[i] = Some(run_cell_cached_timed(refs[i], cache));
    }
    out.into_iter().map(|o| o.expect("every cell executed")).collect()
}

/// Run one caller-assembled batch through this thread's pooled scratch —
/// the search evaluator's entry point, whose lanes are local candidate
/// compiles rather than cache-shared `Arc`s.
pub fn run_batch_pooled(
    rep: &CompiledTopology,
    lanes: &[BatchLane<'_>],
    rounds: usize,
) -> Vec<(SimSummary, EngineStats)> {
    with_scratch(|scratch| run_batched(rep, lanes, rounds, &mut scratch.batched))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::run_cell_summary;
    use crate::sweep::spec::SweepSpec;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn spec() -> SweepSpec {
        SweepSpec {
            name: "cache".into(),
            topologies: vec![TopologyKind::Ring, TopologyKind::Matcha, TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![3, 5],
            seeds: vec![11, 23],
            rounds: 60,
            scenario: None,
            adapt: Vec::new(),
        }
    }

    #[test]
    fn fingerprint_includes_seed_only_for_stochastic_kinds() {
        let cells = spec().expand();
        for pair in cells.chunks(2) {
            // Innermost axis is the seed: each chunk is one coordinate
            // under two base seeds.
            let (a, b) = (&pair[0], &pair[1]);
            assert_ne!(a.cell_seed, b.cell_seed);
            if a.topology.seed_sensitive() {
                assert_ne!(a.fingerprint(), b.fingerprint(), "stochastic cells must not merge");
                assert_eq!(a.fingerprint().seed, Some(a.cell_seed));
            } else {
                assert_eq!(a.fingerprint(), b.fingerprint(), "deterministic cells must merge");
                assert_eq!(a.fingerprint().seed, None);
            }
        }
    }

    #[test]
    fn scenario_joins_the_fingerprint() {
        let cells = spec().expand();
        let a = &cells[0];
        let mut b = a.clone();
        b.scenario =
            Some(Arc::new(ScenarioSpec::from_event_strs(1, &["leave@5:silo=1"]).unwrap()));
        assert_ne!(a.fingerprint(), b.fingerprint(), "a scenario must split the identity");
        assert_eq!(a.fingerprint().scenario, None);
        assert_eq!(
            b.fingerprint().scenario,
            Some(b.scenario.as_ref().unwrap().fingerprint())
        );
        // A different seed over the same events is a different scenario.
        let mut c = b.clone();
        c.scenario =
            Some(Arc::new(ScenarioSpec::from_event_strs(2, &["leave@5:silo=1"]).unwrap()));
        assert_ne!(b.fingerprint(), c.fingerprint());
    }

    #[test]
    fn partition_is_order_preserving_and_complete() {
        let cells = spec().expand();
        let plan = DedupPlan::partition(&cells);
        assert_eq!(plan.assignment.len(), cells.len());
        // 3 topologies x 2 t x 2 seeds = 12 cells; matcha keeps all 4
        // (seed-sensitive), ring and multigraph keep one per t.
        assert_eq!(plan.unique.len(), 4 + 2 + 2);
        // Representatives appear in grid order and map to themselves.
        assert!(plan.unique.windows(2).all(|w| w[0] < w[1]));
        for (i, &slot) in plan.assignment.iter().enumerate() {
            let rep = plan.unique[slot];
            assert!(rep <= i);
            assert_eq!(cells[rep].fingerprint(), cells[i].fingerprint());
        }
        let id = DedupPlan::identity(cells.len());
        assert_eq!(id.unique.len(), cells.len());
        assert_eq!(id.assignment, (0..cells.len()).collect::<Vec<_>>());
    }

    #[test]
    fn build_once_builds_each_key_exactly_once_under_contention() {
        let cache: BuildOnce<u32, u64> = BuildOnce::default();
        let builds = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for k in 0..16u32 {
                        let v = cache.get_or_build(&k, || {
                            builds.fetch_add(1, Ordering::Relaxed);
                            k as u64 * 3
                        });
                        assert_eq!(v, k as u64 * 3);
                    }
                });
            }
        });
        assert_eq!(builds.load(Ordering::Relaxed), 16, "each key must build exactly once");
        assert_eq!(cache.entries(), 16);
    }

    #[test]
    fn build_once_survives_a_panicking_builder() {
        let cache: BuildOnce<u32, u32> = BuildOnce::default();
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.get_or_build(&1, || panic!("boom"))
        }));
        assert!(boom.is_err(), "the panicking build must unwind to the caller");
        // The key must stay buildable (an aborted init leaves the
        // OnceLock empty), and the map must not be wedged for other
        // keys or for the read-side accessors.
        assert_eq!(cache.get(&1), None);
        assert_eq!(cache.get_or_build(&1, || 7), 7);
        assert_eq!(cache.get(&1), Some(7));
        assert_eq!(cache.get_or_build(&2, || 9), 9);
        assert_eq!(cache.entries(), 2);
        // Same contract under contention: one worker's build panics
        // while others build distinct keys; nobody deadlocks and every
        // surviving key resolves.
        let shared: BuildOnce<u32, u32> = BuildOnce::default();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    shared.get_or_build(&0, || panic!("worker boom"))
                }));
                assert!(r.is_err());
            });
            for k in 1..4u32 {
                scope.spawn(move || {
                    assert_eq!(shared.get_or_build(&k, || k * 10), k * 10);
                });
            }
        });
        assert_eq!(shared.get_or_build(&0, || 5), 5, "the panicked key must retry cleanly");
    }

    #[test]
    fn panicking_cell_does_not_wedge_scratch_or_cache() {
        let cells = spec().expand();
        let cache = SweepCache::default();
        let mut bad = cells[0].clone();
        bad.profile = "no-such-profile".into();
        // The panic fires inside the thread-local scratch borrow; the
        // RefCell guard must release on unwind so the same thread's
        // scratch pool stays usable.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_cell_cached_timed(&bad, &cache)
        }));
        assert!(r.is_err(), "an unknown profile must panic, not misreport");
        let good = &cells[0];
        let (got, _, _) = run_cell_cached_timed(good, &cache);
        let want = run_cell_summary(good);
        assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
        assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits());
        assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated);
        assert_eq!(got.max_isolated, want.max_isolated);
    }

    #[test]
    fn scenario_emptying_the_network_errors_instead_of_panicking() {
        // Sits beside the poison-safety test above: a scenario that
        // churns the network below 2 up silos must surface as a
        // structured per-cell error, leaving the caches and the
        // thread's scratch healthy for the next cell.
        let cells = spec().expand();
        let cache = SweepCache::default();
        let n = crate::net::zoo::gaia().n();
        let evs: Vec<String> = (1..n).map(|i| format!("leave@5:silo={i}")).collect();
        let mut bad = cells[0].clone();
        bad.scenario = Some(Arc::new(ScenarioSpec::from_event_strs(1, &evs).unwrap()));
        let (res, _) = run_cell_scenario_cached(&bad, &cache);
        let err = res.expect_err("an emptied network must be a structured error");
        assert!(err.contains("need at least 2"), "unexpected error text: {err}");
        // Same error (same string) from every executor flavor.
        let (res, _) = run_cell_scenario_uncached(&bad);
        assert_eq!(res.expect_err("uncached executor must agree"), err);
        // A survivable scenario on the same cache still simulates, and
        // cached vs uncached stay bitwise identical.
        let mut good = cells[0].clone();
        good.scenario =
            Some(Arc::new(ScenarioSpec::from_event_strs(1, &["leave@5:silo=1"]).unwrap()));
        let (got, _) = run_cell_scenario_cached(&good, &cache);
        let (got, got_stats) = got.expect("mild churn simulates");
        assert!(got.scenario.is_some(), "scenario cells carry degraded-mode metrics");
        let (want, _) = run_cell_scenario_uncached(&good);
        let (want, want_stats) = want.unwrap();
        assert_eq!(got_stats, want_stats);
        assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
        assert_eq!(got.scenario, want.scenario);
    }

    #[test]
    fn scenario_batch_lanes_match_the_solo_executors_bitwise() {
        let cells = spec().expand();
        // ring t=3 and ring t=5 share one periodic schedule — the same
        // chunk shape plan_batches produces.
        let (ring3, ring5) = (&cells[0], &cells[2]);
        let sc = Arc::new(
            ScenarioSpec::from_event_strs(
                7,
                &["leave@10:silo=2", "scale@20:factor=1.25", "rejoin@35:silo=2"],
            )
            .unwrap(),
        );
        let with_sc = |c: &CellSpec| {
            let mut c = c.clone();
            c.scenario = Some(Arc::clone(&sc));
            c
        };
        let (a, b) = (with_sc(ring3), with_sc(ring5));
        let cache = SweepCache::default();
        let arc_of = |c: &CellSpec| match cache.schedule_for(c).0 {
            Some(SharedSchedule::Periodic(ct)) => ct,
            _ => panic!("ring cells compile periodically"),
        };
        let chunk = vec![(&a, arc_of(&a)), (&b, arc_of(&b))];
        let out = run_batch_scenario(&chunk, a.rounds, &sc);
        assert_eq!(out.len(), 2);
        for ((cell, _), (got, _)) in chunk.iter().zip(&out) {
            let (got, got_stats) = got.as_ref().expect("churn batch simulates").clone();
            assert_eq!(got_stats.kind, crate::simtime::EngineKind::Batched);
            let (want, _) = run_cell_scenario_batched_single(cell);
            let (want, want_stats) = want.unwrap();
            assert_eq!(got_stats, want_stats);
            assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
            assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits());
            assert_eq!(got.scenario, want.scenario);
            // and the solo (periodic-labeled) executor agrees on bits.
            let (solo, _) = run_cell_scenario_cached(cell, &cache);
            let (solo, solo_stats) = solo.unwrap();
            assert_eq!(solo_stats.kind, crate::simtime::EngineKind::Periodic);
            assert_eq!(got.total_ms.to_bits(), solo.total_ms.to_bits());
            assert_eq!(got.scenario, solo.scenario);
        }
    }

    #[test]
    fn cached_cells_match_the_uncached_engine_bitwise() {
        let cells = spec().expand();
        let cache = SweepCache::default();
        for cell in &cells {
            let got = run_cell_cached(cell, &cache);
            let want = run_cell_summary(cell);
            let ctx = format!("{}/t{}/seed{}", cell.topology.as_str(), cell.t, cell.base_seed);
            assert_eq!(got.topology, want.topology, "{ctx}");
            assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits(), "{ctx}");
            assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits(), "{ctx}");
            assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated, "{ctx}");
            assert_eq!(got.max_isolated, want.max_isolated, "{ctx}");
        }
        // Shared-artifact accounting: one MATCHA core for the single
        // (network, profile); ring collapses its t axis into one
        // compile, the multigraph keeps one per t.
        assert_eq!(cache.matcha_entries(), 1);
        assert_eq!(cache.compiled_entries(), 1 + 2);
    }

    #[test]
    fn factored_schedules_are_shared_and_exact() {
        // t = 30: s_max is unmaterializable, so the cached path must
        // take the Arc-shared factored schedule — one compile across
        // the seed axis — and stay bit-identical (summary AND engine
        // stats, which ride in reports) to the uncached engine.
        use crate::simtime::EngineKind;
        use crate::topo::MultigraphTopology;
        // Pick a round budget strictly below s_max so the periodic
        // compile is provably skipped whatever gaia's exact t=30 LCM.
        let net = crate::net::zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        let s_max = MultigraphTopology::from_network(&net, &prof, 30).s_max();
        assert!(s_max >= 5, "gaia t=30 must have a non-trivial schedule");
        let rounds = (s_max - 1).min(80) as usize;
        let spec = SweepSpec {
            name: "factored".into(),
            topologies: vec![TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![30],
            seeds: vec![11, 23],
            rounds,
            scenario: None,
            adapt: Vec::new(),
        };
        let cache = SweepCache::default();
        for cell in &spec.expand() {
            let (got, _, got_stats) = run_cell_cached_timed(cell, &cache);
            let (want, _, want_stats) = crate::sweep::run_cell_summary_timed(cell);
            assert_eq!(got_stats.kind, EngineKind::Factored, "t=30 must factor");
            assert_eq!(got_stats, want_stats, "stats must not depend on caching");
            assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
            assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits());
            assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated);
            assert_eq!(got.max_isolated, want.max_isolated);
        }
        assert_eq!(cache.compiled_entries(), 1, "one shared factored compile");
    }

    #[test]
    fn plan_batches_groups_structural_twins_and_isolates_the_rest() {
        let cells = spec().expand();
        let plan = DedupPlan::partition(&cells);
        let cache = SweepCache::default();
        let work: Vec<&CellSpec> = plan.unique.iter().map(|&i| &cells[i]).collect();
        let schedules: Vec<Option<SharedSchedule>> =
            work.iter().map(|c| cache.schedule_for(c).0).collect();
        let bplan = plan_batches(&work, &schedules);
        // Ring t=3 and t=5 share one periodic schedule (ring structure
        // ignores t), so they form the only lockstep chunk; the two
        // multigraph compiles are structurally distinct singletons, and
        // matcha cells never expose a shareable schedule.
        assert_eq!(bplan.chunks.len(), 1, "exactly one batchable group");
        assert_eq!(bplan.chunks[0].len(), 2);
        assert_eq!(bplan.batched_cells(), 2);
        assert_eq!(bplan.solos.len(), work.len() - 2);
        // The plan covers the work list exactly once, in order.
        let mut all: Vec<usize> = bplan
            .chunks
            .iter()
            .flatten()
            .copied()
            .chain(bplan.solos.iter().copied())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..work.len()).collect::<Vec<_>>());
        // Chunk members really share the structure the planner claims.
        for chunk in &bplan.chunks {
            let rep = match &schedules[chunk[0]] {
                Some(SharedSchedule::Periodic(ct)) => Arc::clone(ct),
                _ => panic!("chunks hold periodic schedules"),
            };
            for &i in chunk {
                match &schedules[i] {
                    Some(SharedSchedule::Periodic(ct)) => assert!(rep.schedule_eq(ct)),
                    _ => panic!("chunks hold periodic schedules"),
                }
            }
        }
    }

    #[test]
    fn batched_chunks_match_the_per_cell_engine_bitwise() {
        use crate::simtime::EngineKind;
        let cells = spec().expand();
        // cells[0] is ring t=3, cells[2] ring t=5 (seed is the
        // innermost axis): distinct fingerprints, one shared schedule.
        let (ring3, ring5) = (&cells[0], &cells[2]);
        assert_ne!(ring3.fingerprint(), ring5.fingerprint());
        let cache = SweepCache::default();
        let arc_of = |c: &CellSpec| match cache.schedule_for(c).0 {
            Some(SharedSchedule::Periodic(ct)) => ct,
            _ => panic!("ring cells compile periodically"),
        };
        let chunk = vec![(ring3, arc_of(ring3)), (ring5, arc_of(ring5))];
        let out = run_batch_cached(&chunk, ring3.rounds);
        assert_eq!(out.len(), 2);
        for ((cell, _), (got, _, got_stats)) in chunk.iter().zip(&out) {
            let (want, _, want_stats) = crate::sweep::run_cell_summary_timed(cell);
            let ctx = format!("{}/t{}", cell.topology.as_str(), cell.t);
            assert_eq!(got_stats.kind, EngineKind::Batched, "{ctx}");
            assert_eq!(
                EngineStats { kind: want_stats.kind, ..*got_stats },
                want_stats,
                "{ctx}: stats must agree in everything but the engine tag"
            );
            assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits(), "{ctx}");
            assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits(), "{ctx}");
            assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated, "{ctx}");
            assert_eq!(got.max_isolated, want.max_isolated, "{ctx}");
        }
    }

    #[test]
    fn single_cell_batch_dispatch_matches_the_solo_engine() {
        use crate::simtime::EngineKind;
        let cells = spec().expand();
        let ring3 = &cells[0];
        let (got, timing, got_stats) = run_cell_batched_single(ring3);
        let (want, _, want_stats) = crate::sweep::run_cell_summary_timed(ring3);
        assert_eq!(got_stats.kind, EngineKind::Batched);
        assert_eq!(EngineStats { kind: want_stats.kind, ..got_stats }, want_stats);
        assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
        assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits());
        assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated);
        assert_eq!(got.max_isolated, want.max_isolated);
        assert!(timing.build_ms >= 0.0 && timing.sim_ms >= 0.0);
    }

    #[test]
    fn auto_batched_grid_is_bitwise_identical_and_order_preserving() {
        use crate::simtime::EngineKind;
        let cells = spec().expand();
        let cache = SweepCache::default();
        let out = run_cells_auto_batched(&cells, &cache);
        assert_eq!(out.len(), cells.len());
        let mut batched = 0;
        for (cell, (got, _, stats)) in cells.iter().zip(&out) {
            let want = run_cell_summary(cell);
            let ctx = format!("{}/t{}/seed{}", cell.topology.as_str(), cell.t, cell.base_seed);
            assert_eq!(got.topology, want.topology, "{ctx}");
            assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits(), "{ctx}");
            assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits(), "{ctx}");
            assert_eq!(got.rounds_with_isolated, want.rounds_with_isolated, "{ctx}");
            assert_eq!(got.max_isolated, want.max_isolated, "{ctx}");
            if stats.kind == EngineKind::Batched {
                batched += 1;
            }
        }
        // Without dedup, all four ring cells share one schedule (one
        // 4-lane chunk) and each multigraph t forms a 2-lane chunk
        // across its seed axis; only the four matcha cells run solo.
        assert_eq!(batched, 8, "ring x4 plus multigraph 2x2 must batch");
    }
}
