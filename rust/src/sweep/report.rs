//! Structured sweep output: per-cell results, JSON/CSV artifact writers,
//! and axis slicing into paper-style tables.
//!
//! Artifacts are deliberately free of wall-clock or thread-count fields:
//! a report is a pure function of its [`super::SweepSpec`], so the same
//! spec produces byte-identical artifacts on 1 thread and N threads
//! (pinned by `tests/sweep_determinism.rs`). Host-side timing lives in
//! [`super::SweepOutcome`] instead.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::metrics::render_pivot;
use crate::simtime::{EngineStats, ScenarioMetrics, SimSummary};
use crate::util::Json;

use super::spec::CellSpec;

/// Simulation result of one grid cell, tagged with its coordinates.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Design name as reported by the simulator.
    pub topology: String,
    /// Canonical network name.
    pub network: String,
    /// Canonical dataset-profile name.
    pub profile: String,
    /// Algorithm-1 multiplicity cap of this coordinate.
    pub t: u32,
    /// The spec-level base seed (what the user wrote in the spec;
    /// reports and slices key on it).
    pub seed: u64,
    /// The derived stream the topology actually consumed
    /// ([`super::spec::cell_stream`]); exported so any single cell can
    /// be reproduced with `mgfl simulate --seed <cell_seed>`.
    pub cell_seed: u64,
    /// Simulated communication rounds.
    pub rounds: usize,
    /// Mean Eq. 5 cycle time, ms (the paper's headline metric).
    pub mean_cycle_ms: f64,
    /// Total simulated time over all rounds, ms.
    pub total_ms: f64,
    /// Rounds in which at least one silo was isolated.
    pub rounds_with_isolated: usize,
    /// Largest isolated-silo count seen in any round.
    pub max_isolated: usize,
    /// Which engine simulated the cell ("periodic" | "batched" |
    /// "factored" | "streaming"). Deterministic per cell spec — the
    /// dispatch (including the batch planner's labels) is a pure
    /// function of the design's structure and the round budget —
    /// so it rides in the artifact without breaking determinism, and
    /// an engine regression (a factorizable cell silently falling back
    /// to streaming) diffs in every report.
    pub engine: &'static str,
    /// Rounds that did real per-edge/per-group work (cycle-replayed
    /// rounds excluded). Also deterministic.
    pub simulated_rounds: usize,
    /// Degraded-mode metrics, present iff the cell ran under a
    /// fault-injection scenario ([`crate::simtime::ScenarioSpec`]).
    pub scenario: Option<ScenarioMetrics>,
    /// Structured per-cell failure (e.g. a scenario churning this
    /// cell's network below 2 up silos). Error rows keep their grid
    /// coordinates, zero out the numeric columns, report engine
    /// `error`, and are never written to the store. Deterministic —
    /// the message is a pure function of (scenario, network, rounds).
    pub error: Option<String>,
    /// The cell's adaptation-policy coordinate (`"none"` / `"rebuild"`
    /// / `"warm"`), present iff the sweep carries an `[adapt]` axis
    /// with at least one active policy ([`super::SweepSpec`]
    /// canonicalization drops inert all-`none` axes). It is the column
    /// that distinguishes an adaptive row from its static-degraded twin
    /// at the same grid coordinates.
    pub adapt_policy: Option<String>,
}

impl CellResult {
    /// Tag a simulation summary with `cell`'s grid coordinates. The
    /// summary may come from `cell` itself or from a fingerprint-equal
    /// representative (the dedup fan-out) — the seed columns always
    /// come from `cell`'s own spec, so fanned-out rows stay
    /// coordinate-exact. (`stats` is fingerprint-determined, so fanning
    /// it out is exact too.)
    pub fn from_summary(s: &SimSummary, cell: &CellSpec, stats: &EngineStats) -> Self {
        CellResult {
            topology: s.topology.clone(),
            network: s.network.clone(),
            profile: s.profile.clone(),
            t: cell.t,
            seed: cell.base_seed,
            cell_seed: cell.cell_seed,
            rounds: s.rounds,
            mean_cycle_ms: s.mean_cycle_ms,
            total_ms: s.total_ms,
            rounds_with_isolated: s.rounds_with_isolated,
            max_isolated: s.max_isolated,
            engine: stats.kind.as_str(),
            simulated_rounds: stats.simulated_rounds,
            scenario: s.scenario.clone(),
            error: None,
            adapt_policy: cell.adapt.as_ref().map(|a| a.policy.as_str().to_string()),
        }
    }

    /// An error row: the cell's own coordinates, zeroed numerics, and
    /// the structured failure string.
    pub fn from_error(cell: &CellSpec, error: &str) -> Self {
        CellResult {
            topology: cell.topology.as_str().to_string(),
            network: cell.network.clone(),
            profile: cell.profile.clone(),
            t: cell.t,
            seed: cell.base_seed,
            cell_seed: cell.cell_seed,
            rounds: cell.rounds,
            mean_cycle_ms: 0.0,
            total_ms: 0.0,
            rounds_with_isolated: 0,
            max_isolated: 0,
            engine: "error",
            simulated_rounds: 0,
            scenario: None,
            error: Some(error.to_string()),
            adapt_policy: cell.adapt.as_ref().map(|a| a.policy.as_str().to_string()),
        }
    }

    /// This cell's JSON object, exactly as it appears inside the
    /// [`SweepReport::to_json`] artifact (also streamed per-line by
    /// `mgfl serve`).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("topology".into(), Json::Str(self.topology.clone()));
        m.insert("network".into(), Json::Str(self.network.clone()));
        m.insert("profile".into(), Json::Str(self.profile.clone()));
        m.insert("t".into(), Json::Num(self.t as f64));
        // Base seeds are validated to fit a JSON number exactly
        // (< 2^53); the derived stream is a full 64-bit value,
        // so it travels as a decimal string.
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("cell_seed".into(), Json::Str(self.cell_seed.to_string()));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("mean_cycle_ms".into(), Json::Num(self.mean_cycle_ms));
        m.insert("total_ms".into(), Json::Num(self.total_ms));
        m.insert(
            "rounds_with_isolated".into(),
            Json::Num(self.rounds_with_isolated as f64),
        );
        m.insert("max_isolated".into(), Json::Num(self.max_isolated as f64));
        m.insert("engine".into(), Json::Str(self.engine.to_string()));
        m.insert("simulated_rounds".into(), Json::Num(self.simulated_rounds as f64));
        // Scenario fields appear only on scenario/error cells, so
        // static-sweep artifacts stay byte-identical to the
        // pre-scenario format.
        if let Some(sc) = &self.scenario {
            let segments: Vec<Json> = sc
                .segments
                .iter()
                .map(|s| {
                    let mut seg = BTreeMap::new();
                    seg.insert("start".into(), Json::Num(s.start as f64));
                    seg.insert("len".into(), Json::Num(s.len as f64));
                    seg.insert("up_silos".into(), Json::Num(s.up_silos as f64));
                    seg.insert("p50_ms".into(), Json::Num(s.p50_ms));
                    seg.insert("p95_ms".into(), Json::Num(s.p95_ms));
                    seg.insert("max_ms".into(), Json::Num(s.max_ms));
                    Json::Obj(seg)
                })
                .collect();
            let mut o = BTreeMap::new();
            o.insert("segments".into(), Json::Arr(segments));
            o.insert("p50_ms".into(), Json::Num(sc.p50_ms));
            o.insert("p95_ms".into(), Json::Num(sc.p95_ms));
            o.insert("max_ms".into(), Json::Num(sc.max_ms));
            o.insert("isolation_rate".into(), Json::Num(sc.isolation_rate));
            o.insert("recovery_rounds".into(), Json::Num(sc.recovery_rounds as f64));
            // Adaptation counters ride inside the scenario object iff
            // the cell actually re-planned (active policies only).
            if let Some(a) = &sc.adapt {
                let mut ad = BTreeMap::new();
                ad.insert("policy".into(), Json::Str(a.policy.clone()));
                ad.insert("replans".into(), Json::Num(a.replans as f64));
                ad.insert("fallbacks".into(), Json::Num(a.fallbacks as f64));
                ad.insert("evals_spent".into(), Json::Num(a.evals_spent as f64));
                ad.insert("freeze_rounds".into(), Json::Num(a.freeze_rounds as f64));
                o.insert("adapt".into(), Json::Obj(ad));
            }
            m.insert("scenario".into(), Json::Obj(o));
        }
        if let Some(e) = &self.error {
            m.insert("error".into(), Json::Str(e.clone()));
        }
        // The policy coordinate appears only on cells of adaptive
        // sweeps (spec canonicalization guarantees `Some` implies an
        // active axis), so every pre-adapt artifact stays byte-stable.
        if let Some(p) = &self.adapt_policy {
            m.insert("adapt_policy".into(), Json::Str(p.clone()));
        }
        Json::Obj(m)
    }
}

/// A sweep grid axis, for slicing reports into 2-D tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// The topology-design axis.
    Topology,
    /// The network axis.
    Network,
    /// The dataset-profile axis.
    Profile,
    /// The Algorithm-1 multiplicity-cap axis.
    T,
    /// The base-seed axis.
    Seed,
}

impl Axis {
    /// Lowercase axis name as used in CLI flags and artifact headers.
    pub fn label(&self) -> &'static str {
        match self {
            Axis::Topology => "topology",
            Axis::Network => "network",
            Axis::Profile => "profile",
            Axis::T => "t",
            Axis::Seed => "seed",
        }
    }

    fn key(&self, c: &CellResult) -> String {
        match self {
            Axis::Topology => c.topology.clone(),
            Axis::Network => c.network.clone(),
            Axis::Profile => c.profile.clone(),
            Axis::T => c.t.to_string(),
            Axis::Seed => c.seed.to_string(),
        }
    }
}

/// The full result set of one sweep run, in grid order.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Artifact stem from the spec (`sweep_<name>.json` / `.csv`).
    pub name: String,
    /// Simulated rounds per cell.
    pub rounds: usize,
    /// Whether the sweep ran under an `[events]` fault-injection
    /// scenario. Gates the degraded-mode CSV columns and the top-level
    /// JSON flag, so static-sweep artifacts stay byte-identical to the
    /// pre-scenario format.
    pub scenario: bool,
    /// Whether the sweep carries an active `[adapt]` axis. Gates the
    /// adaptation CSV columns and the top-level JSON flag the same way
    /// `scenario` gates the degraded-mode ones — scenario-only (and
    /// all-`none`) artifacts stay byte-identical to PR 9.
    pub adaptive: bool,
    /// One result per grid coordinate, in grid order.
    pub cells: Vec<CellResult>,
}

/// Distinct `axis` values over `cells`, in first-appearance order — the
/// single source of row/column ordering for full reports and slices.
fn distinct_values<'a>(cells: impl Iterator<Item = &'a CellResult>, axis: Axis) -> Vec<String> {
    let mut out = Vec::new();
    for c in cells {
        let k = axis.key(c);
        if !out.contains(&k) {
            out.push(k);
        }
    }
    out
}

impl SweepReport {
    /// Distinct values of `axis`, in first-appearance (grid) order.
    pub fn axis_values(&self, axis: Axis) -> Vec<String> {
        distinct_values(self.cells.iter(), axis)
    }

    /// Render any slice of the grid as a table: rows × cols over the two
    /// axes, cells showing mean cycle time (ms) averaged over every
    /// matching result (e.g. over seeds), `-` where the slice is empty.
    pub fn render_slice(
        &self,
        rows: Axis,
        cols: Axis,
        filter: impl Fn(&CellResult) -> bool,
    ) -> String {
        let kept: Vec<&CellResult> = self.cells.iter().filter(|c| filter(c)).collect();
        let row_keys = distinct_values(kept.iter().copied(), rows);
        let col_keys = distinct_values(kept.iter().copied(), cols);
        render_pivot(rows.label(), &row_keys, &col_keys, |r, c| {
            let matching: Vec<f64> = kept
                .iter()
                .filter(|cell| rows.key(cell) == r && cols.key(cell) == c)
                .map(|cell| cell.mean_cycle_ms)
                .collect();
            if matching.is_empty() {
                "-".to_string()
            } else {
                format!("{:.1}", matching.iter().sum::<f64>() / matching.len() as f64)
            }
        })
    }

    /// Look up a single cell by coordinates (first match).
    pub fn cell(&self, topology: &str, network: &str, profile: &str) -> Option<&CellResult> {
        self.cells
            .iter()
            .find(|c| c.topology == topology && c.network == network && c.profile == profile)
    }

    /// JSON artifact (deterministic: BTreeMap keys, grid-ordered cells,
    /// no host timing).
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self.cells.iter().map(|c| c.to_json()).collect();
        let mut top = BTreeMap::new();
        top.insert("name".into(), Json::Str(self.name.clone()));
        top.insert("rounds".into(), Json::Num(self.rounds as f64));
        if self.scenario {
            top.insert("scenario".into(), Json::Bool(true));
        }
        if self.adaptive {
            top.insert("adaptive".into(), Json::Bool(true));
        }
        top.insert("cells".into(), Json::Arr(cells));
        Json::Obj(top)
    }

    /// CSV artifact, one row per cell in grid order (deterministic).
    /// Scenario sweeps append the degraded-mode columns; static sweeps
    /// keep the legacy header byte for byte.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "topology,network,profile,t,seed,cell_seed,rounds,mean_cycle_ms,total_ms,rounds_with_isolated,max_isolated,engine,simulated_rounds",
        );
        if self.scenario {
            out.push_str(",error,p50_ms,p95_ms,max_ms,isolation_rate,recovery_rounds,segments");
        }
        if self.adaptive {
            out.push_str(",adapt_policy,replans,fallbacks,evals_spent,freeze_rounds");
        }
        out.push('\n');
        for c in &self.cells {
            let _ = write!(
                out,
                "{},{},{},{},{},{},{},{:.6},{:.6},{},{},{},{}",
                c.topology,
                c.network,
                c.profile,
                c.t,
                c.seed,
                c.cell_seed,
                c.rounds,
                c.mean_cycle_ms,
                c.total_ms,
                c.rounds_with_isolated,
                c.max_isolated,
                c.engine,
                c.simulated_rounds,
            );
            if self.scenario {
                // Error text rides in the CSV cell with commas
                // sanitized (the structured string lives in the JSON
                // artifact); error rows zero the metric columns.
                let err = c.error.as_deref().unwrap_or("").replace(',', ";");
                match &c.scenario {
                    Some(sc) => {
                        let segments = sc
                            .segments
                            .iter()
                            .map(|s| {
                                format!(
                                    "{}:{}:{}:{:.6}:{:.6}:{:.6}",
                                    s.start, s.len, s.up_silos, s.p50_ms, s.p95_ms, s.max_ms
                                )
                            })
                            .collect::<Vec<_>>()
                            .join("|");
                        let _ = write!(
                            out,
                            ",{},{:.6},{:.6},{:.6},{:.6},{},{}",
                            err,
                            sc.p50_ms,
                            sc.p95_ms,
                            sc.max_ms,
                            sc.isolation_rate,
                            sc.recovery_rounds,
                            segments,
                        );
                    }
                    None => {
                        let _ = write!(out, ",{err},0.000000,0.000000,0.000000,0.000000,0,");
                    }
                }
            }
            if self.adaptive {
                // Policy-`none` (and error) rows carry zero counters:
                // they never re-plan, so the columns stay rectangular
                // without inventing data.
                let policy = c.adapt_policy.as_deref().unwrap_or("");
                match c.scenario.as_ref().and_then(|sc| sc.adapt.as_ref()) {
                    Some(a) => {
                        let _ = write!(
                            out,
                            ",{policy},{},{},{},{}",
                            a.replans, a.fallbacks, a.evals_spent, a.freeze_rounds,
                        );
                    }
                    None => {
                        let _ = write!(out, ",{policy},0,0,0,0");
                    }
                }
            }
            out.push('\n');
        }
        out
    }

    /// Write `<dir>/sweep_<name>.json` + `.csv`; returns the two paths.
    pub fn write_artifacts(&self, dir: impl AsRef<Path>) -> Result<(PathBuf, PathBuf)> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        let json_path = dir.join(format!("sweep_{}.json", self.name));
        let csv_path = dir.join(format!("sweep_{}.csv", self.name));
        std::fs::write(&json_path, self.to_json().to_string())
            .with_context(|| format!("writing {}", json_path.display()))?;
        std::fs::write(&csv_path, self.to_csv())
            .with_context(|| format!("writing {}", csv_path.display()))?;
        Ok((json_path, csv_path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(topology: &str, network: &str, profile: &str, mean: f64, seed: u64) -> CellResult {
        CellResult {
            topology: topology.into(),
            network: network.into(),
            profile: profile.into(),
            t: 5,
            seed,
            cell_seed: seed.wrapping_mul(0x9E3779B97F4A7C15),
            rounds: 10,
            mean_cycle_ms: mean,
            total_ms: mean * 10.0,
            rounds_with_isolated: 3,
            max_isolated: 2,
            engine: "periodic",
            simulated_rounds: 10,
            scenario: None,
            error: None,
            adapt_policy: None,
        }
    }

    fn report() -> SweepReport {
        SweepReport {
            name: "test".into(),
            rounds: 10,
            scenario: false,
            adaptive: false,
            cells: vec![
                cell("ring", "gaia", "femnist", 50.0, 1),
                cell("ring", "gaia", "femnist", 70.0, 2),
                cell("multigraph", "gaia", "femnist", 20.0, 1),
                cell("ring", "amazon", "femnist", 80.0, 1),
            ],
        }
    }

    #[test]
    fn slice_averages_over_hidden_axes() {
        let r = report();
        let table = r.render_slice(Axis::Network, Axis::Topology, |_| true);
        // gaia/ring averages the two seeds: (50 + 70) / 2 = 60.0.
        assert!(table.contains("60.0"), "{table}");
        assert!(table.contains("20.0"), "{table}");
        // amazon has no multigraph cell -> "-".
        assert!(table.contains('-'), "{table}");
        assert_eq!(r.axis_values(Axis::Network), vec!["gaia", "amazon"]);
    }

    #[test]
    fn filter_narrows_the_slice() {
        let r = report();
        let table = r.render_slice(Axis::Network, Axis::Topology, |c| c.seed == 1);
        assert!(table.contains("50.0"), "{table}");
        assert!(!table.contains("60.0"), "{table}");
    }

    #[test]
    fn json_and_csv_are_grid_ordered_and_parseable() {
        let r = report();
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "test");
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].get("topology").unwrap().as_str().unwrap(), "ring");
        // The derived stream survives JSON exactly (as a decimal string).
        assert_eq!(
            cells[0].get("cell_seed").unwrap().as_str().unwrap(),
            "11400714819323198485"
        );
        // Engine columns ride in the artifact.
        assert_eq!(cells[0].get("engine").unwrap().as_str().unwrap(), "periodic");
        assert_eq!(cells[0].get("simulated_rounds").unwrap().as_usize().unwrap(), 10);
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 5);
        let row = csv.lines().nth(1).unwrap();
        assert!(
            row.starts_with("ring,gaia,femnist,5,1,11400714819323198485,10,50.000000"),
            "{row}"
        );
    }

    #[test]
    fn artifacts_round_trip_through_disk() {
        let dir = std::env::temp_dir().join(format!("mgfl_sweep_report_{}", std::process::id()));
        let r = report();
        let (json_path, csv_path) = r.write_artifacts(&dir).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
        assert_eq!(parsed.get("cells").unwrap().as_arr().unwrap().len(), 4);
        assert!(std::fs::read_to_string(&csv_path).unwrap().starts_with("topology,"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cell_lookup_finds_first_match() {
        let r = report();
        assert_eq!(r.cell("ring", "gaia", "femnist").unwrap().seed, 1);
        assert!(r.cell("star", "gaia", "femnist").is_none());
    }

    #[test]
    fn scenario_reports_carry_degraded_mode_columns_and_error_rows() {
        use crate::simtime::{ScenarioMetrics, SegmentMetrics};
        let mut ok = cell("ring", "gaia", "femnist", 50.0, 1);
        ok.scenario = Some(ScenarioMetrics {
            segments: vec![SegmentMetrics {
                start: 0,
                len: 10,
                up_silos: 11,
                p50_ms: 48.5,
                p95_ms: 52.0,
                max_ms: 55.25,
            }],
            p50_ms: 48.5,
            p95_ms: 52.0,
            max_ms: 55.25,
            isolation_rate: 0.05,
            recovery_rounds: 3,
            adapt: None,
        });
        let mut err = cell("ring", "tiny", "femnist", 0.0, 1);
        err.mean_cycle_ms = 0.0;
        err.total_ms = 0.0;
        err.rounds_with_isolated = 0;
        err.max_isolated = 0;
        err.engine = "error";
        err.simulated_rounds = 0;
        err.error = Some("scenario leaves 1 silo(s) up at round 5, need at least 2".into());
        let r = SweepReport {
            name: "churn".into(),
            rounds: 10,
            scenario: true,
            adaptive: false,
            cells: vec![ok, err],
        };
        let csv = r.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(
            header.ends_with("error,p50_ms,p95_ms,max_ms,isolation_rate,recovery_rounds,segments"),
            "{header}"
        );
        let ok_row = csv.lines().nth(1).unwrap();
        assert!(ok_row.contains(",48.500000,52.000000,55.250000,0.050000,3,"), "{ok_row}");
        assert!(ok_row.ends_with("0:10:11:48.500000:52.000000:55.250000"), "{ok_row}");
        let err_row = csv.lines().nth(2).unwrap();
        assert!(err_row.contains(",error,0,"), "{err_row}");
        // Commas in the error are sanitized so the row stays rectangular.
        assert!(err_row.contains("at round 5; need at least 2"), "{err_row}");
        assert_eq!(
            err_row.split(',').count(),
            header.split(',').count(),
            "error rows keep the scenario column count"
        );
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("scenario").unwrap(), &Json::Bool(true));
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        let sc = cells[0].get("scenario").unwrap();
        assert_eq!(sc.get("recovery_rounds").unwrap().as_usize().unwrap(), 3);
        assert_eq!(sc.get("segments").unwrap().as_arr().unwrap().len(), 1);
        assert!(cells[0].get("error").is_err());
        assert!(cells[1].get("scenario").is_err());
        assert!(cells[1]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("need at least 2"));
        // Static reports keep the legacy artifact byte for byte: no
        // scenario flag, no extra columns.
        let legacy = report();
        assert!(legacy.to_csv().lines().next().unwrap().ends_with("simulated_rounds"));
        assert!(Json::parse(&legacy.to_json().to_string()).unwrap().get("scenario").is_err());
    }

    #[test]
    fn adaptive_reports_carry_policy_columns_and_counters() {
        use crate::simtime::{AdaptMetrics, ScenarioMetrics, SegmentMetrics};
        let sc_metrics = |adapt| ScenarioMetrics {
            segments: vec![SegmentMetrics {
                start: 0,
                len: 10,
                up_silos: 11,
                p50_ms: 48.5,
                p95_ms: 52.0,
                max_ms: 55.25,
            }],
            p50_ms: 48.5,
            p95_ms: 52.0,
            max_ms: 55.25,
            isolation_rate: 0.05,
            recovery_rounds: 3,
            adapt,
        };
        let mut stat = cell("multigraph", "gaia", "femnist", 50.0, 1);
        stat.scenario = Some(sc_metrics(None));
        stat.adapt_policy = Some("none".into());
        let mut warm = cell("multigraph", "gaia", "femnist", 44.0, 1);
        warm.scenario = Some(sc_metrics(Some(AdaptMetrics {
            policy: "warm".into(),
            replans: 2,
            fallbacks: 1,
            evals_spent: 64,
            freeze_rounds: 8,
        })));
        warm.adapt_policy = Some("warm".into());
        let r = SweepReport {
            name: "heal".into(),
            rounds: 10,
            scenario: true,
            adaptive: true,
            cells: vec![stat, warm],
        };
        let csv = r.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(
            header.ends_with(",adapt_policy,replans,fallbacks,evals_spent,freeze_rounds"),
            "{header}"
        );
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        assert!(rows[0].ends_with(",none,0,0,0,0"), "{}", rows[0]);
        assert!(rows[1].ends_with(",warm,2,1,64,8"), "{}", rows[1]);
        assert_eq!(rows[0].split(',').count(), header.split(',').count());
        assert_eq!(rows[1].split(',').count(), header.split(',').count());
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("adaptive").unwrap(), &Json::Bool(true));
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells[0].get("adapt_policy").unwrap().as_str().unwrap(), "none");
        assert!(cells[0].get("scenario").unwrap().get("adapt").is_err());
        let a = cells[1].get("scenario").unwrap().get("adapt").unwrap();
        assert_eq!(a.get("policy").unwrap().as_str().unwrap(), "warm");
        assert_eq!(a.get("replans").unwrap().as_usize().unwrap(), 2);
        assert_eq!(a.get("fallbacks").unwrap().as_usize().unwrap(), 1);
        // Non-adaptive scenario reports never emit the columns.
        let plain = SweepReport {
            name: "churn".into(),
            rounds: 10,
            scenario: true,
            adaptive: false,
            cells: vec![],
        };
        assert!(plain.to_csv().lines().next().unwrap().ends_with(",segments"));
        assert!(Json::parse(&plain.to_json().to_string()).unwrap().get("adaptive").is_err());
    }
}
