//! Sweep specifications: the (topology × network × profile × t × seed)
//! grid behind every paper table, as a typed value with a TOML-subset
//! loader (same dialect as [`crate::config`], plus `[list]` values).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{ExperimentConfig, TopologyKind};
use crate::net::{zoo, DatasetProfile};
use crate::search::{AdaptPolicy, AdaptSpec};
use crate::simtime::ScenarioSpec;
use crate::util::rng::{derive_stream, fnv1a};

/// A full experiment grid. Expanding it yields one [`CellSpec`] per
/// combination; every cell is independent, which is what makes the
/// sweep embarrassingly parallel.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Artifact stem (`sweep_<name>.json` / `.csv`).
    pub name: String,
    /// Topology-design axis.
    pub topologies: Vec<TopologyKind>,
    /// Network axis: zoo and/or synthetic names.
    pub networks: Vec<String>,
    /// Dataset-profile axis (paper Table 2).
    pub profiles: Vec<String>,
    /// Algorithm 1's t (max edges between two nodes); multigraph only,
    /// other designs carry it through for bookkeeping.
    pub t_values: Vec<u32>,
    /// Base seeds; each cell derives its own stream from (seed, cell id).
    pub seeds: Vec<u64>,
    /// Simulated communication rounds per cell (paper: 6400).
    pub rounds: usize,
    /// Fault-injection scenario applied to *every* cell (the `[events]`
    /// section), or `None` for the classic static sweep. Shared by
    /// `Arc` — the grid can expand to thousands of cells and the
    /// scenario is immutable.
    pub scenario: Option<Arc<ScenarioSpec>>,
    /// Adaptation-policy axis (the `[adapt]` section): one entry per
    /// policy, sharing the section's knobs. Empty for classic sweeps.
    /// Expands as the *outermost* axis so the static grid keeps its
    /// PR 9 presentation order within each policy block.
    pub adapt: Vec<Arc<AdaptSpec>>,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            name: "sweep".into(),
            topologies: TopologyKind::all().to_vec(),
            networks: zoo::all_networks().iter().map(|n| n.name.clone()).collect(),
            profiles: DatasetProfile::all().iter().map(|p| p.name.clone()).collect(),
            t_values: vec![5],
            seeds: vec![17],
            rounds: 6400,
            scenario: None,
            adapt: Vec::new(),
        }
    }
}

/// One fully-resolved grid cell, ready to simulate. Pure data (no trait
/// objects), so it crosses threads freely; the topology is built inside
/// the worker that runs the cell.
#[derive(Debug, Clone)]
pub struct CellSpec {
    /// Position in the expanded grid (artifact ordering).
    pub index: usize,
    /// Topology design of this coordinate.
    pub topology: TopologyKind,
    /// Canonical network name.
    pub network: String,
    /// Canonical dataset-profile name.
    pub profile: String,
    /// Algorithm-1 multiplicity cap of this coordinate.
    pub t: u32,
    /// The spec-level seed this cell descends from (reported).
    pub base_seed: u64,
    /// The derived per-cell stream (what the topology actually consumes):
    /// a function of (base seed, cell coordinates) only — never of
    /// execution order or thread count.
    pub cell_seed: u64,
    /// Simulated communication rounds.
    pub rounds: usize,
    /// Fault-injection scenario the cell runs under, if any (inherited
    /// from the spec; identical for every cell of one sweep).
    pub scenario: Option<Arc<ScenarioSpec>>,
    /// Adaptation spec of this cell's policy coordinate (`None` when
    /// the sweep has no `[adapt]` section). Policy `none` cells carry
    /// `Some` — the report labels them — but fingerprint and execution
    /// treat them exactly like static-scenario cells.
    pub adapt: Option<Arc<AdaptSpec>>,
}

impl CellSpec {
    /// The equivalent single-experiment config (simulation-only).
    pub fn to_experiment(&self) -> ExperimentConfig {
        ExperimentConfig {
            network: self.network.clone(),
            profile: self.profile.clone(),
            topology: self.topology,
            t: self.t,
            sim_rounds: self.rounds,
            seed: self.cell_seed,
            train: None,
        }
    }
}

/// Derive the per-cell RNG stream from the base seed and the cell's
/// grid coordinates (not its index, so adding an axis value does not
/// reseed unrelated cells).
pub fn cell_stream(
    base_seed: u64,
    topology: TopologyKind,
    network: &str,
    profile: &str,
    t: u32,
) -> u64 {
    let coord = format!("{}/{network}/{profile}/t{t}", topology.as_str());
    derive_stream(base_seed, fnv1a(coord.as_bytes()))
}

impl SweepSpec {
    /// The paper's Table 1 grid: all 7 topologies × all 5 networks for
    /// the selected profiles.
    pub fn table1(profiles: Vec<String>, t: u32, rounds: usize) -> Self {
        SweepSpec {
            name: "table1".into(),
            profiles,
            t_values: vec![t],
            rounds,
            ..Default::default()
        }
    }

    /// Rewrite network/profile names to their canonical (lowercase zoo /
    /// synth / Table 2) spelling, then drop duplicate axis values.
    /// `net::by_name` accepts any case, so without the rewrite two
    /// equivalent specs spelled differently would derive different cell
    /// seeds and render empty slices; canonicalizing at every spec
    /// entry point (TOML loader, CLI flags, [`super::run`]) keeps
    /// coordinates case-stable. Duplicate values on any axis (including
    /// "GAIA"/"gaia" pairs that collapse under the rewrite) would
    /// silently inflate the grid with identical cells, so they are
    /// deduplicated here with a warning — [`Self::validate`] rejects
    /// them outright for callers that skip canonicalization. Errors on
    /// unknown names.
    pub fn canonicalize(&mut self) -> Result<()> {
        for n in &mut self.networks {
            *n = crate::net::by_name(n)
                .ok_or_else(|| anyhow::anyhow!("unknown network '{n}'"))?
                .name;
        }
        for p in &mut self.profiles {
            *p = DatasetProfile::by_name(p)
                .ok_or_else(|| anyhow::anyhow!("unknown profile '{p}'"))?
                .name;
        }
        dedup_axis("topologies", &mut self.topologies);
        dedup_axis("networks", &mut self.networks);
        dedup_axis("profiles", &mut self.profiles);
        dedup_axis("t", &mut self.t_values);
        dedup_axis("seeds", &mut self.seeds);
        // An adapt axis with no active policy is the static sweep under
        // another name; dropping it here means every cell that carries
        // an [`AdaptSpec`] belongs to a genuinely adaptive sweep, and
        // all-`none` specs produce artifacts (and store records) byte-
        // identical to their `[adapt]`-free twins.
        if !self.is_adaptive() {
            self.adapt.clear();
        }
        Ok(())
    }

    /// Range-check every knob and reject empty or duplicated axes.
    /// Assumes canonical names ([`Self::canonicalize`] runs first on
    /// every spec entry point).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "sweep name must be non-empty");
        ensure!(self.rounds >= 1, "rounds must be >= 1");
        for (axis, empty) in [
            ("topologies", self.topologies.is_empty()),
            ("networks", self.networks.is_empty()),
            ("profiles", self.profiles.is_empty()),
            ("t", self.t_values.is_empty()),
            ("seeds", self.seeds.is_empty()),
        ] {
            ensure!(!empty, "sweep axis '{axis}' must be non-empty");
        }
        for (axis, dup) in [
            ("topologies", has_duplicates(&self.topologies)),
            ("networks", has_duplicates(&self.networks)),
            ("profiles", has_duplicates(&self.profiles)),
            ("t", has_duplicates(&self.t_values)),
            ("seeds", has_duplicates(&self.seeds)),
        ] {
            ensure!(
                !dup,
                "sweep axis '{axis}' contains duplicate values (they would inflate the grid \
                 with identical cells; canonicalize() drops them with a warning)"
            );
        }
        for net in &self.networks {
            ensure!(
                crate::net::by_name(net).is_some(),
                "unknown network '{net}' (zoo name or synth-<variant>-n<N>-s<seed>)"
            );
        }
        for prof in &self.profiles {
            ensure!(DatasetProfile::by_name(prof).is_some(), "unknown profile '{prof}'");
        }
        for &t in &self.t_values {
            ensure!(t >= 1, "t must be >= 1 (got {t})");
        }
        for &seed in &self.seeds {
            // Keep the base seed exactly representable in the JSON
            // artifact (Json::Num is f64-backed); derived cell streams
            // use the full 64 bits and travel as strings.
            ensure!(
                seed < (1u64 << 53),
                "base seed {seed} exceeds 2^53 and would lose precision in JSON artifacts"
            );
        }
        if let Some(sc) = &self.scenario {
            sc.validate().context("[events] section")?;
        }
        for a in &self.adapt {
            a.validate().context("[adapt] section")?;
        }
        ensure!(
            !has_duplicates(&self.adapt.iter().map(|a| a.policy).collect::<Vec<_>>()),
            "[adapt] policies contains duplicate values"
        );
        if self.is_adaptive() {
            ensure!(
                self.scenario.is_some(),
                "[adapt] with an active policy requires an [events] section (re-planning \
                 happens at scenario segment boundaries)"
            );
        }
        Ok(())
    }

    /// Expand the `"all"` sugar for a string axis: `["all"]` means the
    /// full default axis, anything else passes through. Shared by the
    /// TOML loader and the CLI flag parser so the two dialects cannot
    /// drift.
    pub fn axis_or_all(items: Vec<String>, full: &[String]) -> Vec<String> {
        if items == ["all"] {
            full.to_vec()
        } else {
            items
        }
    }

    /// Parse a topology axis, honoring the `"all"` sugar.
    pub fn parse_topologies(items: &[String]) -> Result<Vec<TopologyKind>> {
        if items == ["all"] {
            Ok(TopologyKind::all().to_vec())
        } else {
            items.iter().map(|s| s.parse()).collect()
        }
    }

    /// Number of cells the grid expands to.
    pub fn cell_count(&self) -> usize {
        self.profiles.len()
            * self.networks.len()
            * self.topologies.len()
            * self.t_values.len()
            * self.seeds.len()
            * self.adapt.len().max(1)
    }

    /// Whether any policy on the adapt axis actually re-plans
    /// (everything-`none` grids stay byte-identical to PR 9 sweeps).
    pub fn is_adaptive(&self) -> bool {
        self.adapt.iter().any(|a| a.is_active())
    }

    /// Expand the grid into independent cells, in presentation order
    /// (policy, profile, network, topology, t, seed) — the artifact
    /// order. The adapt axis is outermost so each policy block repeats
    /// the PR 9 static order; `cell_seed` never depends on the policy
    /// coordinate, which is what keeps policy-`none` cells bitwise
    /// equal to their static-sweep counterparts.
    pub fn expand(&self) -> Vec<CellSpec> {
        let mut cells = Vec::with_capacity(self.cell_count());
        let adapt_axis: Vec<Option<Arc<AdaptSpec>>> = if self.adapt.is_empty() {
            vec![None]
        } else {
            self.adapt.iter().cloned().map(Some).collect()
        };
        for adapt in &adapt_axis {
            for profile in &self.profiles {
                for network in &self.networks {
                    for &topology in &self.topologies {
                        for &t in &self.t_values {
                            for &base_seed in &self.seeds {
                                cells.push(CellSpec {
                                    index: cells.len(),
                                    topology,
                                    network: network.clone(),
                                    profile: profile.clone(),
                                    t,
                                    base_seed,
                                    cell_seed: cell_stream(
                                        base_seed, topology, network, profile, t,
                                    ),
                                    rounds: self.rounds,
                                    scenario: self.scenario.clone(),
                                    adapt: adapt.clone(),
                                });
                            }
                        }
                    }
                }
            }
        }
        cells
    }

    /// Load, canonicalize, and validate a spec from a TOML file.
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading sweep spec {}", path.as_ref().display()))?;
        let mut spec = Self::from_toml_str(&text)?;
        spec.canonicalize()?;
        spec.validate()?;
        Ok(spec)
    }

    /// Parse the TOML subset: comments, flat `key = value`, where value
    /// is a scalar or a `[a, b, c]` list. `"all"` is sugar for the full
    /// axis on `topologies` / `networks` / `profiles`.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let defaults = SweepSpec::default();
        let mut spec = defaults.clone();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                bail!("line {}: sweep specs have no sections (got '{line}')", lineno + 1);
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let items = split_values(value);
            let ctx = |k: &str| format!("line {}: key '{k}'", lineno + 1);
            match key {
                "name" => spec.name = one(&items, key, lineno)?,
                "rounds" => {
                    spec.rounds = one(&items, key, lineno)?.parse().with_context(|| ctx(key))?
                }
                "topologies" => {
                    spec.topologies = Self::parse_topologies(&items).with_context(|| ctx(key))?
                }
                "networks" => spec.networks = Self::axis_or_all(items, &defaults.networks),
                "profiles" => spec.profiles = Self::axis_or_all(items, &defaults.profiles),
                "t" => {
                    spec.t_values = items
                        .iter()
                        .map(|s| s.parse::<u32>())
                        .collect::<Result<_, _>>()
                        .with_context(|| ctx(key))?
                }
                "seeds" => {
                    spec.seeds = items
                        .iter()
                        .map(|s| s.parse::<u64>())
                        .collect::<Result<_, _>>()
                        .with_context(|| ctx(key))?
                }
                other => bail!("line {}: unknown sweep key '{other}'", lineno + 1),
            }
        }
        Ok(spec)
    }

    /// Serialize back to the TOML subset (for shipped example specs).
    ///
    /// A scenario serializes as a trailing `[events]` section, which
    /// only the *file* dialect ([`SweepFile::from_toml_str`]) parses —
    /// the flat [`Self::from_toml_str`] stays section-free, so specs
    /// with a scenario round-trip through `SweepFile`.
    pub fn to_toml_string(&self) -> String {
        let quote_list = |items: &[String]| -> String {
            let quoted: Vec<String> = items.iter().map(|s| format!("\"{s}\"")).collect();
            format!("[{}]", quoted.join(", "))
        };
        let topo_names: Vec<String> =
            self.topologies.iter().map(|k| k.as_str().to_string()).collect();
        let t_list: Vec<String> = self.t_values.iter().map(|t| t.to_string()).collect();
        let seed_list: Vec<String> = self.seeds.iter().map(|s| s.to_string()).collect();
        let mut out = format!(
            "name = \"{}\"\nrounds = {}\ntopologies = {}\nnetworks = {}\nprofiles = {}\nt = [{}]\nseeds = [{}]\n",
            self.name,
            self.rounds,
            quote_list(&topo_names),
            quote_list(&self.networks),
            quote_list(&self.profiles),
            t_list.join(", "),
            seed_list.join(", "),
        );
        if let Some(sc) = &self.scenario {
            out.push_str(&format!("\n[events]\nseed = {}\n", sc.seed));
            out.push_str(&format!("events = {}\n", quote_list(&sc.event_strs())));
        }
        if let Some(first) = self.adapt.first() {
            let policies: Vec<String> =
                self.adapt.iter().map(|a| a.policy.as_str().to_string()).collect();
            out.push_str(&format!(
                "\n[adapt]\npolicies = {}\nbudget = {}\ndeadline_ms = {}\nfreeze_rounds = \
                 {}\neval_rounds = {}\n",
                quote_list(&policies),
                first.budget,
                first.deadline_ms,
                first.freeze_rounds,
                first.eval_rounds,
            ));
        }
        out
    }
}

/// The `[store]` section of a sweep file: where the persistent cell
/// store lives and whether this spec uses it by default. CLI flags
/// (`--store`, `--no-store`) override both fields.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreSpec {
    /// Store directory (created on first use).
    pub path: String,
    /// Whether the sweep consults the store (default `true`; `false`
    /// keeps the path on record while forcing cold runs).
    pub enabled: bool,
}

/// A parsed sweep file: the grid spec plus the optional `[store]`
/// section. [`SweepSpec::from_toml_str`] stays section-free (flat specs
/// embedded in other tools keep erroring on stray sections); this
/// wrapper is the full file dialect the CLI loads.
#[derive(Debug, Clone)]
pub struct SweepFile {
    /// The experiment grid.
    pub spec: SweepSpec,
    /// The `[store]` section, if the file has one.
    pub store: Option<StoreSpec>,
}

impl SweepFile {
    /// Load, canonicalize, and validate a sweep file.
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading sweep spec {}", path.as_ref().display()))?;
        let mut file = Self::from_toml_str(&text)?;
        file.spec.canonicalize()?;
        file.spec.validate()?;
        Ok(file)
    }

    /// Parse the file dialect: the flat sweep keys, optionally followed
    /// by `[store]` (`path`, `enabled`), `[events]` (`seed`, `events`),
    /// and/or `[adapt]` (`policies`, `budget`, `deadline_ms`,
    /// `freeze_rounds`, `eval_rounds`) sections. Any other section is
    /// an error.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        #[derive(PartialEq, Clone, Copy)]
        enum Section {
            Sweep,
            Store,
            Events,
            Adapt,
        }
        let mut sweep_text = String::new();
        let mut store: Option<StoreSpec> = None;
        let mut ev_seed = 0u64;
        let mut ev_strs: Option<Vec<String>> = None;
        let mut seen_events = false;
        let mut ad_policies: Option<Vec<String>> = None;
        let mut ad_knobs = AdaptSpec::default();
        let mut seen_adapt = false;
        let mut section = Section::Sweep;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.starts_with('[') {
                match line {
                    "[store]" => {
                        ensure!(store.is_none(), "line {}: duplicate [store] section", lineno + 1);
                        section = Section::Store;
                        store = Some(StoreSpec { path: String::new(), enabled: true });
                    }
                    "[events]" => {
                        ensure!(!seen_events, "line {}: duplicate [events] section", lineno + 1);
                        section = Section::Events;
                        seen_events = true;
                    }
                    "[adapt]" => {
                        ensure!(!seen_adapt, "line {}: duplicate [adapt] section", lineno + 1);
                        section = Section::Adapt;
                        seen_adapt = true;
                    }
                    other => bail!(
                        "line {}: unknown section '{other}' (sweep files support [store], \
                         [events], and [adapt])",
                        lineno + 1
                    ),
                }
                sweep_text.push('\n');
                continue;
            }
            if section == Section::Sweep {
                // Keep the raw line (and blank lines below for section
                // keys) so SweepSpec::from_toml_str reports the file's
                // real line numbers.
                sweep_text.push_str(raw);
                sweep_text.push('\n');
                continue;
            }
            sweep_text.push('\n');
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let items = split_values(value);
            match section {
                Section::Sweep => unreachable!("handled above"),
                Section::Store => {
                    let section = store.as_mut().expect("inside [store]");
                    match key.trim() {
                        "path" => section.path = one(&items, "path", lineno)?,
                        "enabled" => {
                            section.enabled = match one(&items, "enabled", lineno)?.as_str() {
                                "true" => true,
                                "false" => false,
                                other => bail!(
                                    "line {}: [store] enabled must be true or false (got \
                                     '{other}')",
                                    lineno + 1
                                ),
                            }
                        }
                        other => bail!("line {}: unknown [store] key '{other}'", lineno + 1),
                    }
                }
                Section::Events => match key.trim() {
                    "seed" => {
                        ev_seed = one(&items, "seed", lineno)?
                            .parse()
                            .with_context(|| format!("line {}: [events] seed", lineno + 1))?
                    }
                    "events" => ev_strs = Some(items),
                    other => bail!("line {}: unknown [events] key '{other}'", lineno + 1),
                },
                Section::Adapt => {
                    let ctx = |k: &str| format!("line {}: [adapt] {k}", lineno + 1);
                    match key.trim() {
                        "policies" => ad_policies = Some(items),
                        "budget" => {
                            ad_knobs.budget = one(&items, "budget", lineno)?
                                .parse()
                                .with_context(|| ctx("budget"))?
                        }
                        "deadline_ms" => {
                            ad_knobs.deadline_ms = one(&items, "deadline_ms", lineno)?
                                .parse()
                                .with_context(|| ctx("deadline_ms"))?
                        }
                        "freeze_rounds" => {
                            ad_knobs.freeze_rounds = one(&items, "freeze_rounds", lineno)?
                                .parse()
                                .with_context(|| ctx("freeze_rounds"))?
                        }
                        "eval_rounds" => {
                            ad_knobs.eval_rounds = one(&items, "eval_rounds", lineno)?
                                .parse()
                                .with_context(|| ctx("eval_rounds"))?
                        }
                        other => bail!("line {}: unknown [adapt] key '{other}'", lineno + 1),
                    }
                }
            }
        }
        if let Some(s) = &store {
            ensure!(!s.path.is_empty(), "[store] section requires a path");
        }
        let mut spec = SweepSpec::from_toml_str(&sweep_text)?;
        if seen_events {
            let strs = ev_strs.unwrap_or_default();
            ensure!(!strs.is_empty(), "[events] section requires a non-empty events list");
            let sc = ScenarioSpec::from_event_strs(ev_seed, &strs).context("[events] section")?;
            spec.scenario = Some(Arc::new(sc));
        }
        if seen_adapt {
            let policies = ad_policies.unwrap_or_default();
            ensure!(!policies.is_empty(), "[adapt] section requires a non-empty policies list");
            spec.adapt = policies
                .iter()
                .map(|p| {
                    let policy = AdaptPolicy::parse(p).context("[adapt] policies")?;
                    Ok(Arc::new(AdaptSpec { policy, ..ad_knobs.clone() }))
                })
                .collect::<Result<_>>()?;
        }
        Ok(SweepFile { spec, store })
    }
}

/// Whether `values` lists any value more than once.
fn has_duplicates<T: PartialEq>(values: &[T]) -> bool {
    values.iter().enumerate().any(|(i, v)| values[..i].contains(v))
}

/// Drop repeated axis values, keeping first appearance, with a stderr
/// warning naming the axis (axes are tiny — O(n²) `contains` beats
/// hashing here).
fn dedup_axis<T: PartialEq + Clone>(axis: &str, values: &mut Vec<T>) {
    if !has_duplicates(values) {
        return;
    }
    let mut kept: Vec<T> = Vec::with_capacity(values.len());
    for v in values.iter() {
        if !kept.contains(v) {
            kept.push(v.clone());
        }
    }
    eprintln!(
        "warning: sweep axis '{axis}' lists duplicate values; deduplicating ({} -> {})",
        values.len(),
        kept.len()
    );
    *values = kept;
}

/// Split a TOML-subset value into its items: `[a, "b", c]` lists or a
/// single scalar; quotes stripped, empties dropped. Shared with the
/// optimize-spec loader ([`crate::search::OptimizeSpec`]) so the two
/// dialects cannot drift.
pub(crate) fn split_values(value: &str) -> Vec<String> {
    let v = value.trim();
    let inner = v.strip_prefix('[').and_then(|s| s.strip_suffix(']'));
    let raw: Vec<&str> = match inner {
        Some(list) => list.split(',').collect(),
        None => vec![v],
    };
    raw.iter()
        .map(|s| s.trim().trim_matches('"').to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Expect exactly one item for scalar-valued keys (shared with the
/// optimize-spec loader).
pub(crate) fn one(items: &[String], key: &str, lineno: usize) -> Result<String> {
    match items {
        [single] => Ok(single.clone()),
        _ => bail!("line {}: key '{key}' takes a single value", lineno + 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_the_full_paper_grid() {
        let spec = SweepSpec::default();
        spec.validate().unwrap();
        // 7 topologies x 5 networks x 3 profiles x 1 t x 1 seed.
        assert_eq!(spec.cell_count(), 7 * 5 * 3);
        let cells = spec.expand();
        assert_eq!(cells.len(), spec.cell_count());
        assert!(cells.iter().enumerate().all(|(i, c)| c.index == i));
    }

    #[test]
    fn cell_seeds_depend_on_coordinates_not_order() {
        let spec = SweepSpec::default();
        let cells = spec.expand();
        // Same coordinates => same stream, across any two expansions.
        let again = spec.expand();
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.cell_seed, b.cell_seed);
        }
        // Distinct coordinates => distinct streams (no collisions here).
        let seeds: std::collections::BTreeSet<u64> = cells.iter().map(|c| c.cell_seed).collect();
        assert_eq!(seeds.len(), cells.len());
        // Removing an axis value must not reseed the survivors.
        let mut narrowed = spec.clone();
        narrowed.networks.retain(|n| n != "amazon");
        let kept: Vec<u64> = narrowed.expand().iter().map(|c| c.cell_seed).collect();
        let expect: Vec<u64> = cells
            .iter()
            .filter(|c| c.network != "amazon")
            .map(|c| c.cell_seed)
            .collect();
        assert_eq!(kept, expect);
    }

    #[test]
    fn toml_roundtrip() {
        let spec = SweepSpec {
            name: "custom".into(),
            topologies: vec![TopologyKind::Ring, TopologyKind::Multigraph],
            networks: vec!["gaia".into(), "exodus".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![3, 5],
            seeds: vec![1, 2, 3],
            rounds: 640,
            scenario: None,
            adapt: Vec::new(),
        };
        let text = spec.to_toml_string();
        let back = SweepSpec::from_toml_str(&text).unwrap();
        back.validate().unwrap();
        assert_eq!(back.name, "custom");
        assert_eq!(back.topologies, vec![TopologyKind::Ring, TopologyKind::Multigraph]);
        assert_eq!(back.networks, vec!["gaia", "exodus"]);
        assert_eq!(back.t_values, vec![3, 5]);
        assert_eq!(back.seeds, vec![1, 2, 3]);
        assert_eq!(back.rounds, 640);
        assert_eq!(back.cell_count(), 2 * 2 * 2 * 3);
    }

    #[test]
    fn parses_all_sugar_scalars_and_comments() {
        let text = r#"
# the full grid at smoke rounds
name = "smoke"       # artifact stem
rounds = 50
topologies = "all"
networks = [gaia, amazon]
profiles = "femnist"
t = 5
seeds = [17]
"#;
        let spec = SweepSpec::from_toml_str(text).unwrap();
        spec.validate().unwrap();
        assert_eq!(spec.topologies.len(), 7);
        assert_eq!(spec.networks, vec!["gaia", "amazon"]);
        assert_eq!(spec.profiles, vec!["femnist"]);
        assert_eq!(spec.t_values, vec![5]);
        assert_eq!(spec.rounds, 50);
    }

    #[test]
    fn canonicalize_makes_specs_case_stable() {
        let mut shouty = SweepSpec {
            networks: vec!["GAIA".into()],
            profiles: vec!["FEMNIST".into()],
            ..Default::default()
        };
        shouty.canonicalize().unwrap();
        assert_eq!(shouty.networks, vec!["gaia"]);
        assert_eq!(shouty.profiles, vec!["femnist"]);
        // Equivalent spellings derive identical cell seeds after
        // canonicalization — the sweep determinism contract.
        let lower = SweepSpec {
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            ..Default::default()
        };
        let a: Vec<u64> = shouty.expand().iter().map(|c| c.cell_seed).collect();
        let b: Vec<u64> = lower.expand().iter().map(|c| c.cell_seed).collect();
        assert_eq!(a, b);
        let mut unknown = SweepSpec::default();
        unknown.networks = vec!["nowhere".into()];
        assert!(unknown.canonicalize().is_err());
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(SweepSpec::from_toml_str("bogus = 1").is_err());
        assert!(SweepSpec::from_toml_str("[section]").is_err());
        assert!(SweepSpec::from_toml_str("t = [0").is_err()); // unparsed '[0'
        let mut empty_axis = SweepSpec::default();
        empty_axis.networks.clear();
        assert!(empty_axis.validate().is_err());
        let mut bad_net = SweepSpec::default();
        bad_net.networks = vec!["nowhere".into()];
        assert!(bad_net.validate().is_err());
        let mut bad_t = SweepSpec::default();
        bad_t.t_values = vec![0];
        assert!(bad_t.validate().is_err());
        let mut big_seed = SweepSpec::default();
        big_seed.seeds = vec![1u64 << 53];
        assert!(big_seed.validate().is_err(), "seeds must stay JSON-exact");
        big_seed.seeds = vec![(1u64 << 53) - 1];
        big_seed.validate().unwrap();
        assert!(SweepSpec::from_toml_file("/nonexistent.toml").is_err());
    }

    #[test]
    fn sweep_files_parse_the_store_section() {
        let text = r#"
name = "warm"
rounds = 50
seeds = [17]

[store]
path = "/tmp/mgfl-store"   # created on first use
enabled = true
"#;
        let file = SweepFile::from_toml_str(text).unwrap();
        assert_eq!(file.spec.name, "warm");
        assert_eq!(file.spec.rounds, 50);
        assert_eq!(
            file.store,
            Some(StoreSpec { path: "/tmp/mgfl-store".into(), enabled: true })
        );

        // No section -> no store; flat specs parse identically to
        // SweepSpec::from_toml_str.
        let flat = SweepFile::from_toml_str("name = \"flat\"\n").unwrap();
        assert!(flat.store.is_none());
        assert_eq!(flat.spec.name, "flat");

        let off = SweepFile::from_toml_str("[store]\npath = \"p\"\nenabled = false\n").unwrap();
        assert!(!off.store.unwrap().enabled);
    }

    #[test]
    fn sweep_files_reject_bad_store_sections() {
        // Unknown sections still error (and name the line).
        let err = SweepFile::from_toml_str("name = \"x\"\n[cache]\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        // Missing path, bad keys, bad bools, duplicates.
        assert!(SweepFile::from_toml_str("[store]\nenabled = true\n").is_err());
        assert!(SweepFile::from_toml_str("[store]\npath = \"p\"\nbogus = 1\n").is_err());
        assert!(SweepFile::from_toml_str("[store]\npath = \"p\"\nenabled = maybe\n").is_err());
        assert!(SweepFile::from_toml_str("[store]\npath = \"p\"\n[store]\n").is_err());
        // Sweep-key errors keep their original line numbers even after
        // a store section is stripped.
        let err = SweepFile::from_toml_str("[store]\npath = \"p\"\n\nbogus = 1\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 4"), "{err}");
    }

    #[test]
    fn sweep_files_parse_the_events_section() {
        let text = r#"
name = "churn"
rounds = 200
networks = [gaia]
seeds = [17]

[events]
seed = 9
events = ["leave@13:silo=3", "rejoin@41:silo=3", "outage@70:frac=0.3:dur=18"]
"#;
        let file = SweepFile::from_toml_str(text).unwrap();
        let sc = file.spec.scenario.as_ref().expect("scenario parsed");
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.events.len(), 3);
        assert_eq!(sc.events[2].round, 70);
        file.spec.validate().unwrap();
        // Every expanded cell inherits the same shared scenario.
        let cells = file.spec.expand();
        assert!(cells.iter().all(|c| c.scenario.as_deref() == Some(sc.as_ref())));

        // Round-trip: spec -> TOML ([events] section) -> SweepFile.
        let back = SweepFile::from_toml_str(&file.spec.to_toml_string()).unwrap();
        assert_eq!(back.spec.scenario.as_deref(), Some(sc.as_ref()));

        // [events] and [store] coexist in either order.
        let both = SweepFile::from_toml_str(
            "name = \"b\"\n[events]\nseed = 1\nevents = [\"leave@1:silo=0\"]\n[store]\npath = \"p\"\n",
        )
        .unwrap();
        assert!(both.spec.scenario.is_some());
        assert_eq!(both.store.unwrap().path, "p");
    }

    #[test]
    fn sweep_files_parse_the_adapt_section() {
        let text = r#"
name = "heal"
rounds = 200
networks = [gaia]
topologies = [multigraph]
profiles = [femnist]
seeds = [17]

[events]
seed = 9
events = ["leave@13:silo=3", "rejoin@41:silo=3"]

[adapt]
policies = ["none", "warm"]
budget = 32
freeze_rounds = 2
eval_rounds = 40
"#;
        let file = SweepFile::from_toml_str(text).unwrap();
        file.spec.validate().unwrap();
        assert_eq!(file.spec.adapt.len(), 2);
        assert_eq!(file.spec.adapt[0].policy, AdaptPolicy::None);
        assert_eq!(file.spec.adapt[1].policy, AdaptPolicy::Warm);
        assert!(file.spec.adapt.iter().all(|a| a.budget == 32
            && a.freeze_rounds == 2
            && a.eval_rounds == 40
            && a.deadline_ms == 0));
        assert!(file.spec.is_adaptive());
        // Policy is the outermost axis: the grid doubles and the first
        // half carries policy none, the second half warm.
        assert_eq!(file.spec.cell_count(), 2);
        let cells = file.spec.expand();
        assert_eq!(cells[0].adapt.as_ref().unwrap().policy, AdaptPolicy::None);
        assert_eq!(cells[1].adapt.as_ref().unwrap().policy, AdaptPolicy::Warm);
        // The policy coordinate never perturbs the cell seed.
        assert_eq!(cells[0].cell_seed, cells[1].cell_seed);

        // Round-trip: spec -> TOML ([adapt] section) -> SweepFile.
        let back = SweepFile::from_toml_str(&file.spec.to_toml_string()).unwrap();
        assert_eq!(back.spec.adapt, file.spec.adapt);
    }

    #[test]
    fn bad_adapt_sections_are_rejected() {
        assert!(SweepFile::from_toml_str("[adapt]\n").is_err(), "policies list required");
        let err = SweepFile::from_toml_str("[adapt]\npolicies = [\"meteor\"]\n")
            .unwrap_err()
            .root_cause()
            .to_string();
        assert!(err.contains("unknown adapt policy"), "{err}");
        assert!(SweepFile::from_toml_str("[adapt]\npolicies = [\"warm\"]\nbogus = 1\n").is_err());
        assert!(SweepFile::from_toml_str(
            "[adapt]\npolicies = [\"warm\"]\n[adapt]\npolicies = [\"warm\"]\n"
        )
        .is_err());
        // An active policy without [events] has no boundaries to
        // re-plan at; validate() rejects the combination.
        let active = SweepFile::from_toml_str("[adapt]\npolicies = [\"rebuild\"]\n").unwrap();
        assert!(active.spec.validate().unwrap_err().to_string().contains("[events]"));
        // All-none adapt axes are fine without events (they are just a
        // labeled re-run of the static sweep).
        let inert = SweepFile::from_toml_str("[adapt]\npolicies = [\"none\"]\n").unwrap();
        assert!(!inert.spec.is_adaptive());
        inert.spec.validate().unwrap();
        // Duplicate policies would inflate the grid with identical cells.
        let dup = SweepFile::from_toml_str(
            "[events]\nseed = 1\nevents = [\"leave@1:silo=0\"]\n\
             [adapt]\npolicies = [\"warm\", \"warm\"]\n",
        )
        .unwrap();
        assert!(dup.spec.validate().unwrap_err().to_string().contains("duplicate"));
        // eval_rounds is range-checked through the spec validator.
        let zero = SweepFile::from_toml_str(
            "[events]\nseed = 1\nevents = [\"leave@1:silo=0\"]\n\
             [adapt]\npolicies = [\"warm\"]\neval_rounds = 0\n",
        )
        .unwrap();
        assert!(zero.spec.validate().is_err());
    }

    #[test]
    fn the_committed_churn_spec_loads_and_validates() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/specs/churn_gaia.toml");
        let file = SweepFile::from_toml_file(path).unwrap();
        assert_eq!(file.spec.name, "churn_gaia");
        let sc = file.spec.scenario.as_ref().expect("churn_gaia carries an [events] section");
        assert_eq!(sc.seed, 9);
        assert_eq!(sc.events.len(), 6);
        // The scenario must be viable on its own network/round budget.
        crate::simtime::build_timeline(sc, &crate::net::zoo::gaia(), file.spec.rounds).unwrap();
    }

    #[test]
    fn the_committed_adapt_spec_loads_and_validates() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/specs/adapt_gaia.toml");
        let file = SweepFile::from_toml_file(path).unwrap();
        file.spec.validate().unwrap();
        assert_eq!(file.spec.name, "adapt_gaia");
        assert!(file.spec.is_adaptive());
        // One policy axis covering the whole ladder: the PR 9 static
        // base, the rebuild fallback, and the warm-started search.
        let policies: Vec<AdaptPolicy> = file.spec.adapt.iter().map(|a| a.policy).collect();
        assert_eq!(policies, vec![AdaptPolicy::None, AdaptPolicy::Rebuild, AdaptPolicy::Warm]);
        // Wall-clock deadlines are host-dependent; the committed spec
        // must stay a pure function of its bytes.
        assert!(file.spec.adapt.iter().all(|a| a.deadline_ms == 0));
        let sc = file.spec.scenario.as_ref().expect("adapt_gaia carries an [events] section");
        crate::simtime::build_timeline(sc, &crate::net::zoo::gaia(), file.spec.rounds).unwrap();
        // Three policies x one static cell: the grid triples, sharing
        // one cell seed so rows differ by policy alone.
        assert_eq!(file.spec.cell_count(), 3);
        let cells = file.spec.expand();
        assert!(cells.iter().all(|c| c.cell_seed == cells[0].cell_seed));
    }

    #[test]
    fn bad_events_sections_are_rejected() {
        assert!(SweepFile::from_toml_str("[events]\n").is_err(), "events list required");
        assert!(SweepFile::from_toml_str("[events]\nevents = [\"meteor@1:x=2\"]\n").is_err());
        assert!(SweepFile::from_toml_str("[events]\nseed = -1\nevents = [\"leave@1:silo=0\"]\n")
            .is_err());
        assert!(SweepFile::from_toml_str("[events]\nbogus = 1\n").is_err());
        assert!(SweepFile::from_toml_str(
            "[events]\nevents = [\"leave@1:silo=0\"]\n[events]\n"
        )
        .is_err());
        // Spec-level validation rejects out-of-range parameters on
        // hand-built scenarios too.
        let mut spec = SweepSpec::default();
        spec.scenario = Some(Arc::new(ScenarioSpec {
            seed: 1,
            events: vec![crate::simtime::Event {
                round: 0,
                kind: crate::simtime::EventKind::Scale { factor: f64::NAN },
            }],
        }));
        assert!(spec.validate().is_err());
    }

    #[test]
    fn duplicate_axis_values_are_rejected_then_deduped() {
        let mut dup = SweepSpec {
            t_values: vec![5, 3, 5],
            seeds: vec![17, 17],
            ..Default::default()
        };
        assert!(dup.validate().is_err(), "validate must reject duplicated axes");
        dup.canonicalize().unwrap();
        assert_eq!(dup.t_values, vec![5, 3], "first appearance wins");
        assert_eq!(dup.seeds, vec![17]);
        dup.validate().unwrap();
        assert_eq!(dup.cell_count(), 7 * 5 * 3 * 2);

        // Case-variant spellings collapse to one coordinate, then dedupe.
        let mut shouty = SweepSpec {
            networks: vec!["GAIA".into(), "gaia".into()],
            topologies: vec![TopologyKind::Ring, TopologyKind::Ring],
            ..Default::default()
        };
        assert!(shouty.validate().is_err());
        shouty.canonicalize().unwrap();
        assert_eq!(shouty.networks, vec!["gaia"]);
        assert_eq!(shouty.topologies, vec![TopologyKind::Ring]);
        shouty.validate().unwrap();

        // The TOML loader canonicalizes, so a duplicated spec file
        // loads as the deduped grid rather than erroring.
        let text = "name = \"d\"\nseeds = [1, 1, 2]\n";
        let spec = SweepSpec::from_toml_str(text).unwrap();
        assert_eq!(spec.seeds, vec![1, 1, 2], "raw parse keeps duplicates");
    }
}
