//! Parallel experiment-sweep engine.
//!
//! The paper's results (Tables 1–6, Fig. 5) are grids of
//! (topology × network × profile × seed × t) simulations. Each grid cell
//! owns its topology and [`crate::simtime::DelayTracker`], so cells are
//! embarrassingly parallel: this module expands a [`SweepSpec`] into
//! independent [`CellSpec`]s and maps them across a thread pool,
//! preserving grid order in the output.
//!
//! Two pool implementations sit behind one order-preserving API:
//! an in-tree scoped-thread pool (default — the offline build has no
//! rayon) and rayon's work-stealing pool (`--features rayon`). Results
//! are byte-identical across pools and thread counts because every cell
//! seeds its own RNG stream from (base seed, cell coordinates) via
//! [`crate::util::rng::derive_stream`] — never from execution order.

pub mod report;
pub mod spec;

pub use report::{Axis, CellResult, SweepReport};
pub use spec::{CellSpec, SweepSpec};

use std::io::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::simtime::simulate_summary;

/// How to execute a sweep (host-side knobs; never part of the artifact).
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Print `done/total` progress to stderr while running.
    pub progress: bool,
}

/// Resolve the worker count: `0` means all available cores, and there is
/// never a reason to spawn more workers than cells.
pub fn effective_threads(requested: usize, cells: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 { hw } else { requested };
    t.clamp(1, cells.max(1))
}

/// Throttled progress meter. The old per-cell `stderr` lock + flush
/// measurably serialized short-cell sweeps (thousands of cells finishing
/// in microseconds all contending on one syscall); this prints only when
/// the integer percentage moves or ≥ 100 ms passed since the last line,
/// and always for the final cell.
struct Progress {
    total: usize,
    state: Mutex<(usize, Instant)>, // (last printed done count, last print time)
}

const PROGRESS_INTERVAL: Duration = Duration::from_millis(100);

impl Progress {
    fn new(total: usize) -> Self {
        // Seed last-print in the past so the first tick always prints
        // (checked_sub: an Instant cannot go before the clock's origin).
        let now = Instant::now();
        let seed = now.checked_sub(PROGRESS_INTERVAL).unwrap_or(now);
        Progress { total, state: Mutex::new((0, seed)) }
    }

    fn tick(&self, done: usize) {
        let finishing = done >= self.total;
        // Non-final ticks bail if another worker holds the lock — it is
        // already printing fresher progress than ours.
        let mut state = if finishing {
            self.state.lock().expect("progress lock")
        } else {
            match self.state.try_lock() {
                Ok(guard) => guard,
                Err(_) => return,
            }
        };
        // Monotonic: a straggler that observed an older count must not
        // print a regressing line (or anything after the final line).
        if done <= state.0 {
            return;
        }
        let pct = done * 100 / self.total.max(1);
        let last_pct = state.0 * 100 / self.total.max(1);
        if !finishing && pct == last_pct && state.1.elapsed() < PROGRESS_INTERVAL {
            return;
        }
        *state = (done, Instant::now());
        let mut err = std::io::stderr().lock();
        let _ = write!(err, "\r  sweep: {done}/{} cells", self.total);
        if finishing {
            let _ = writeln!(err);
        }
        let _ = err.flush();
    }
}

/// Order-preserving parallel map: `out[i] == f(i, &cells[i])` for every
/// `i`, regardless of which worker ran which cell. This is the engine's
/// core primitive; [`run`] feeds it grid cells, and adapters with
/// non-grid work (e.g. Table 4's silo-removal variants) feed it their
/// own cell types.
pub fn run_cells<T, R, F>(cells: &[T], opts: &RunOptions, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let total = cells.len();
    let threads = effective_threads(opts.threads, total);
    let progress = if opts.progress && total > 0 {
        Some(Progress::new(total))
    } else {
        None
    };
    if threads <= 1 {
        return cells
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let r = f(i, c);
                if let Some(p) = &progress {
                    p.tick(i + 1);
                }
                r
            })
            .collect();
    }
    run_parallel(cells, threads, progress.as_ref(), f)
}

/// Work-stealing pool (enabled with `--features rayon`).
#[cfg(feature = "rayon")]
fn run_parallel<T, R, F>(cells: &[T], threads: usize, progress: Option<&Progress>, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    use rayon::prelude::*;
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("building rayon pool");
    let done = AtomicUsize::new(0);
    pool.install(|| {
        cells
            .par_iter()
            .enumerate()
            .map(|(i, c)| {
                let r = f(i, c);
                let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                if let Some(p) = progress {
                    p.tick(finished);
                }
                r
            })
            .collect()
    })
}

/// In-tree scoped-thread pool: workers pull the next cell index off a
/// shared atomic counter and write results into per-cell slots, so
/// output order is the input order whatever the scheduling.
#[cfg(not(feature = "rayon"))]
fn run_parallel<T, R, F>(cells: &[T], threads: usize, progress: Option<&Progress>, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let total = cells.len();
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = cells.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let r = f(i, &cells[i]);
                *slots[i].lock().expect("cell slot lock") = Some(r);
                let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                if let Some(p) = progress {
                    p.tick(finished);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().expect("cell slot lock").expect("worker filled every slot"))
        .collect()
}

/// Simulate one grid cell. Pure in the cell spec: builds the topology
/// (seeded from the cell's derived stream) and its own simulation state,
/// so concurrent cells share nothing mutable. Cells run on the compiled
/// zero-allocation engine ([`crate::simtime::compiled`]); periodic cells
/// additionally take its cycle-detection fast path.
pub fn run_cell(cell: &CellSpec) -> CellResult {
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    let mut topo = cfg.build_topology();
    let s = simulate_summary(topo.as_mut(), &net, &prof, cell.rounds);
    CellResult {
        topology: s.topology,
        network: s.network,
        profile: s.profile,
        t: cell.t,
        seed: cell.base_seed,
        cell_seed: cell.cell_seed,
        rounds: s.rounds,
        mean_cycle_ms: s.mean_cycle_ms,
        total_ms: s.total_ms,
        rounds_with_isolated: s.rounds_with_isolated,
        max_isolated: s.max_isolated,
    }
}

/// A finished sweep: the deterministic report plus host-side execution
/// stats (which deliberately stay out of the artifacts).
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub report: SweepReport,
    pub host_elapsed_ms: f64,
    pub threads: usize,
}

impl SweepOutcome {
    /// Cells simulated per host second (throughput summary line).
    pub fn cells_per_sec(&self) -> f64 {
        if self.host_elapsed_ms <= 0.0 {
            return 0.0;
        }
        self.report.cells.len() as f64 / (self.host_elapsed_ms / 1e3)
    }
}

/// Run the full grid of `spec` in parallel and collect the report in
/// grid order.
pub fn run(spec: &SweepSpec, opts: &RunOptions) -> Result<SweepOutcome> {
    // Canonicalize a local copy so coordinates (and the cell seeds
    // derived from them) are case-stable no matter how the caller
    // spelled the axes.
    let spec = {
        let mut s = spec.clone();
        s.canonicalize()?;
        s
    };
    spec.validate()?;
    let cells = spec.expand();
    let threads = effective_threads(opts.threads, cells.len());
    let t0 = Instant::now();
    let results = run_cells(
        &cells,
        &RunOptions { threads, progress: opts.progress },
        |_, c| run_cell(c),
    );
    Ok(SweepOutcome {
        report: SweepReport { name: spec.name.clone(), rounds: spec.rounds, cells: results },
        host_elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
        threads,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(8, 3), 3);
        assert_eq!(effective_threads(3, 0), 1);
        assert!(effective_threads(0, 100) >= 1);
    }

    #[test]
    fn run_cells_preserves_input_order() {
        let cells: Vec<usize> = (0..64).collect();
        let one = RunOptions { threads: 1, progress: false };
        let four = RunOptions { threads: 4, progress: false };
        let serial = run_cells(&cells, &one, |i, &c| (i, c * 3));
        let parallel = run_cells(&cells, &four, |i, &c| (i, c * 3));
        assert_eq!(serial, parallel);
        assert!(serial.iter().enumerate().all(|(i, &(j, v))| i == j && v == i * 3));
    }

    #[test]
    fn run_cells_handles_empty_and_single() {
        let none: Vec<u32> = vec![];
        assert!(run_cells(&none, &RunOptions::default(), |_, &c| c).is_empty());
        let one = vec![7u32];
        assert_eq!(run_cells(&one, &RunOptions::default(), |_, &c| c + 1), vec![8]);
    }

    #[test]
    fn engine_reproduces_the_paper_ordering_on_gaia() {
        let spec = SweepSpec {
            name: "smoke".into(),
            topologies: vec![TopologyKind::Ring, TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![17],
            rounds: 200,
        };
        let outcome = run(&spec, &RunOptions { threads: 2, progress: false }).unwrap();
        assert_eq!(outcome.threads, 2, "explicit thread request is honored");
        let report = &outcome.report;
        assert_eq!(report.cells.len(), 2);
        // Grid order: ring first, multigraph second.
        assert_eq!(report.cells[0].topology, "ring");
        assert_eq!(report.cells[1].topology, "multigraph");
        let ring = report.cell("ring", "gaia", "femnist").unwrap();
        let ours = report.cell("multigraph", "gaia", "femnist").unwrap();
        assert!(
            ours.mean_cycle_ms < ring.mean_cycle_ms,
            "ours {} vs ring {}",
            ours.mean_cycle_ms,
            ring.mean_cycle_ms
        );
        assert!(ours.rounds_with_isolated > 0);
        assert_eq!(ring.rounds_with_isolated, 0);
    }

    #[test]
    fn engine_cell_matches_direct_simulation() {
        // A sweep cell must equal running the same experiment by hand:
        // same derived seed, same simulator, bit-identical numbers.
        let spec = SweepSpec {
            name: "oracle".into(),
            topologies: vec![TopologyKind::Matcha],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![23],
            rounds: 120,
        };
        let outcome = run(&spec, &RunOptions { threads: 2, progress: false }).unwrap();
        let got = &outcome.report.cells[0];

        let cells = spec.expand();
        let cfg = cells[0].to_experiment();
        let net = cfg.resolve_network();
        let prof = cfg.resolve_profile().unwrap();
        let mut topo = cfg.build_topology();
        let want = crate::simtime::simulate(topo.as_mut(), &net, &prof, cells[0].rounds);
        assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits());
        assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
        assert_eq!(got.seed, 23, "reports carry the base seed, not the derived stream");
    }
}
