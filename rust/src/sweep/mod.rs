//! Parallel experiment-sweep engine.
//!
//! The paper's results (Tables 1–6, Fig. 5) are grids of
//! (topology × network × profile × seed × t) simulations. Each grid cell
//! owns its topology and [`crate::simtime::DelayTracker`], so cells are
//! embarrassingly parallel: this module expands a [`SweepSpec`] into
//! independent [`CellSpec`]s and maps them across a thread pool,
//! preserving grid order in the output.
//!
//! Two pool implementations sit behind one order-preserving API:
//! an in-tree scoped-thread pool (default — the offline build has no
//! rayon) and rayon's work-stealing pool (`--features rayon`). Results
//! are byte-identical across pools and thread counts because every cell
//! seeds its own RNG stream from (base seed, cell coordinates) via
//! [`crate::util::rng::derive_stream`] — never from execution order.
//!
//! Since PR 3 the scheduler additionally **deduplicates** the grid
//! before it reaches the pool: deterministic designs are bit-identical
//! across the seed axis, so [`run`] partitions cells by semantic
//! [`CellFingerprint`], simulates only the unique work items (through
//! the shared-construction [`SweepCache`]), and fans each summary out
//! to every duplicate grid coordinate. Artifacts stay grid-ordered and
//! byte-identical to the pre-dedup engine ([`RunOptions::dedup`] =
//! `false`), which `tests/sweep_determinism.rs` and the `sweep_cache`
//! bench pin down.

pub mod cache;
pub mod report;
pub mod spec;

pub use cache::{
    plan_batches, run_batch_cached, run_batch_pooled, run_batch_scenario, run_cell_adaptive,
    run_cell_batched_single, run_cell_cached, run_cell_cached_timed,
    run_cell_scenario_batched_single, run_cell_scenario_cached, run_cell_scenario_uncached,
    run_cells_auto_batched, simulate_design_pooled, BatchPlan, BuildOnce, CellFingerprint,
    DedupPlan, ScenarioOutcome, SharedSchedule, SweepCache,
};
pub use report::{Axis, CellResult, SweepReport};
pub use spec::{CellSpec, StoreSpec, SweepFile, SweepSpec};

use std::io::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::simtime::{
    simulate_summary_compiled_with_stats, CompiledTopology, EngineKind, EngineStats, SimSummary,
};
use crate::store::{CellStore, StoredCell};

/// How to execute a sweep (host-side knobs; never part of the artifact).
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Print `done/total` progress to stderr while running.
    pub progress: bool,
    /// Partition the grid by [`CellFingerprint`] and simulate only the
    /// unique cells (default). `false` runs every cell independently —
    /// the pre-cache engine, kept as the dedup layer's byte-identity
    /// oracle (artifacts are identical either way).
    pub dedup: bool,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { threads: 0, progress: false, dedup: true }
    }
}

/// Resolve the worker count: `0` means all available cores, and there is
/// never a reason to spawn more workers than cells.
pub fn effective_threads(requested: usize, cells: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = if requested == 0 { hw } else { requested };
    t.clamp(1, cells.max(1))
}

/// Throttled progress meter. The old per-cell `stderr` lock + flush
/// measurably serialized short-cell sweeps (thousands of cells finishing
/// in microseconds all contending on one syscall); this prints only when
/// the integer percentage moves or ≥ 100 ms passed since the last line,
/// and always for the final cell.
struct Progress {
    total: usize,
    state: Mutex<(usize, Instant)>, // (last printed done count, last print time)
}

const PROGRESS_INTERVAL: Duration = Duration::from_millis(100);

impl Progress {
    fn new(total: usize) -> Self {
        // Seed last-print in the past so the first tick always prints
        // (checked_sub: an Instant cannot go before the clock's origin).
        let now = Instant::now();
        let seed = now.checked_sub(PROGRESS_INTERVAL).unwrap_or(now);
        Progress { total, state: Mutex::new((0, seed)) }
    }

    fn tick(&self, done: usize) {
        let finishing = done >= self.total;
        // Non-final ticks bail if another worker holds the lock — it is
        // already printing fresher progress than ours.
        let mut state = if finishing {
            self.state.lock().expect("progress lock")
        } else {
            match self.state.try_lock() {
                Ok(guard) => guard,
                Err(_) => return,
            }
        };
        // Monotonic: a straggler that observed an older count must not
        // print a regressing line (or anything after the final line).
        if done <= state.0 {
            return;
        }
        let pct = done * 100 / self.total.max(1);
        let last_pct = state.0 * 100 / self.total.max(1);
        if !finishing && pct == last_pct && state.1.elapsed() < PROGRESS_INTERVAL {
            return;
        }
        *state = (done, Instant::now());
        let mut err = std::io::stderr().lock();
        let _ = write!(err, "\r  sweep: {done}/{} cells", self.total);
        if finishing {
            let _ = writeln!(err);
        }
        let _ = err.flush();
    }
}

/// Order-preserving parallel map: `out[i] == f(i, &cells[i])` for every
/// `i`, regardless of which worker ran which cell. This is the engine's
/// core primitive; [`run`] feeds it grid cells, and adapters with
/// non-grid work (e.g. Table 4's silo-removal variants) feed it their
/// own cell types.
pub fn run_cells<T, R, F>(cells: &[T], opts: &RunOptions, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let total = cells.len();
    let threads = effective_threads(opts.threads, total);
    let progress = if opts.progress && total > 0 {
        Some(Progress::new(total))
    } else {
        None
    };
    if threads <= 1 {
        return cells
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let r = f(i, c);
                if let Some(p) = &progress {
                    p.tick(i + 1);
                }
                r
            })
            .collect();
    }
    run_parallel(cells, threads, progress.as_ref(), f)
}

/// Work-stealing pool (enabled with `--features rayon`).
#[cfg(feature = "rayon")]
fn run_parallel<T, R, F>(cells: &[T], threads: usize, progress: Option<&Progress>, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    use rayon::prelude::*;
    let pool = rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("building rayon pool");
    let done = AtomicUsize::new(0);
    pool.install(|| {
        cells
            .par_iter()
            .enumerate()
            .map(|(i, c)| {
                let r = f(i, c);
                let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                if let Some(p) = progress {
                    p.tick(finished);
                }
                r
            })
            .collect()
    })
}

/// In-tree scoped-thread pool: workers pull the next cell index off a
/// shared atomic counter and write results into per-cell slots, so
/// output order is the input order whatever the scheduling.
#[cfg(not(feature = "rayon"))]
fn run_parallel<T, R, F>(cells: &[T], threads: usize, progress: Option<&Progress>, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let total = cells.len();
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = cells.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let r = f(i, &cells[i]);
                *slots[i].lock().expect("cell slot lock") = Some(r);
                let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                if let Some(p) = progress {
                    p.tick(finished);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().expect("cell slot lock").expect("worker filled every slot"))
        .collect()
}

/// Host-side timing of one simulated cell: wall-clock spent
/// constructing the topology vs stepping rounds. Never part of the
/// artifacts (reports stay a pure function of the spec); aggregated
/// into [`SweepOutcome`] so construction regressions show up in every
/// sweep's summary line, not only in benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct CellTiming {
    /// Topology construction (and, on the cached path, shared
    /// compilation) work this worker actually performed, ms. On the
    /// cached path a cache hit — or blocking on another worker's
    /// in-flight build of the same key — records ~0.
    pub build_ms: f64,
    /// Simulation time, ms. On the uncached path this includes the
    /// per-cell schedule compile the engine performs internally.
    pub sim_ms: f64,
}

/// Simulate one grid cell with nothing shared: builds the topology
/// (seeded from the cell's derived stream) and its own simulation state.
/// Cells run through the engine dispatcher
/// ([`crate::simtime::simulate_summary_scratch`]): periodic compile,
/// then the period-factorized engine, then streaming. This is the
/// pre-cache engine — the byte-identity oracle for [`run_cell_cached`].
pub fn run_cell_summary(cell: &CellSpec) -> SimSummary {
    run_cell_summary_timed(cell).0
}

/// [`run_cell_summary`] with the build/simulate wall-clock split and
/// the engine's [`EngineStats`].
pub fn run_cell_summary_timed(cell: &CellSpec) -> (SimSummary, CellTiming, EngineStats) {
    let cfg = cell.to_experiment();
    let net = cfg.resolve_network();
    let prof = cfg.resolve_profile().expect("validated profile");
    let t0 = Instant::now();
    let mut topo = cfg.build_topology();
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let (summary, stats) =
        simulate_summary_compiled_with_stats(topo.as_mut(), &net, &prof, cell.rounds);
    let sim_ms = t1.elapsed().as_secs_f64() * 1e3;
    (summary, CellTiming { build_ms, sim_ms }, stats)
}

/// [`run_cell_summary`] tagged with the cell's grid coordinates.
pub fn run_cell(cell: &CellSpec) -> CellResult {
    let (summary, _, stats) = run_cell_summary_timed(cell);
    CellResult::from_summary(&summary, cell, &stats)
}

/// Which engines the simulated (unique) cells ran on, aggregated for
/// the sweep summary line — the observable that makes engine-dispatch
/// regressions visible in every sweep, not only in benches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineMix {
    /// Cells on the periodic per-state engine (cycle replay).
    pub periodic: usize,
    /// Cells on the cross-cell SoA batched engine (lanes of a shared
    /// schedule stepped in lockstep).
    pub batched: usize,
    /// Cells on the period-factorized group engine.
    pub factored: usize,
    /// Cells on the per-edge streaming engine.
    pub streaming: usize,
    /// Rounds that did real per-edge/per-group work across simulated
    /// cells (cycle-replayed rounds excluded).
    pub stepped_rounds: u64,
    /// Total rounds across simulated cells.
    pub total_rounds: u64,
}

impl EngineMix {
    fn count(&mut self, stats: &EngineStats, rounds: usize) {
        match stats.kind {
            EngineKind::Periodic => self.periodic += 1,
            EngineKind::Batched => self.batched += 1,
            EngineKind::Factored => self.factored += 1,
            EngineKind::Streaming => self.streaming += 1,
        }
        self.stepped_rounds += stats.simulated_rounds as u64;
        self.total_rounds += rounds as u64;
    }

    /// Human summary, e.g. `3 periodic + 2 batched + 2 factored + 1
    /// streaming, stepped 180/38400 rounds`.
    pub fn describe(&self) -> String {
        format!(
            "{} periodic + {} batched + {} factored + {} streaming, stepped {}/{} rounds",
            self.periodic,
            self.batched,
            self.factored,
            self.streaming,
            self.stepped_rounds,
            self.total_rounds
        )
    }
}

/// A finished sweep: the deterministic report plus host-side execution
/// stats (which deliberately stay out of the artifacts).
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    /// The deterministic artifact: pure function of the spec.
    pub report: SweepReport,
    /// Wall-clock of the run on this host (never in artifacts).
    pub host_elapsed_ms: f64,
    /// Worker threads actually used (never in artifacts).
    pub threads: usize,
    /// Cells actually simulated after fingerprint dedup; the remaining
    /// `report.cells.len() - unique_cells` results were fanned out from
    /// representatives. Equals the grid size with dedup off or when the
    /// grid has no duplicate work.
    pub unique_cells: usize,
    /// Aggregate topology-construction work over the simulated
    /// (unique) cells, ms, summed across workers (each distinct
    /// construction counted once — see [`CellTiming::build_ms`]).
    pub build_ms: f64,
    /// Aggregate simulation wall-clock over the simulated cells, ms
    /// (same summing convention).
    pub sim_ms: f64,
    /// Engine dispatch over the simulated (unique) cells.
    pub engines: EngineMix,
    /// Planned work items answered by the persistent store instead of
    /// being simulated (unique items with dedup on, grid cells with
    /// dedup off). 0 when no store is attached.
    pub store_hits: usize,
    /// Planned work items the store missed (simulated, then written
    /// back). 0 when no store is attached.
    pub store_misses: usize,
}

impl SweepOutcome {
    /// Cells simulated per host second (throughput summary line). Counts
    /// grid cells, not unique cells — fan-out is part of the engine.
    pub fn cells_per_sec(&self) -> f64 {
        if self.host_elapsed_ms <= 0.0 {
            return 0.0;
        }
        self.report.cells.len() as f64 / (self.host_elapsed_ms / 1e3)
    }

    /// Grid cells per simulated cell (1.0 = no duplicate work).
    pub fn dedup_ratio(&self) -> f64 {
        self.report.cells.len() as f64 / self.unique_cells.max(1) as f64
    }
}

/// Run the full grid of `spec` in parallel and collect the report in
/// grid order. With [`RunOptions::dedup`] (the default) the grid is
/// first partitioned into unique work items by [`CellFingerprint`];
/// only those are simulated (through a per-run [`SweepCache`]) and the
/// summaries are fanned out to every duplicate coordinate — the report
/// is byte-identical to the undeduplicated engine either way.
///
/// The deduplicated engine runs in three phases: (1) resolve every
/// unique cell's shared schedule in parallel, (2) serially plan batches
/// of cells that share one periodic schedule ([`plan_batches`]), (3)
/// execute batches and per-cell fallbacks in parallel. With dedup off
/// the same batch *labels* are still computed (from the fingerprint
/// partition), and labeled cells run as single-lane batches — so the
/// report's `engine` column, like every other column, is byte-identical
/// across modes and thread counts.
pub fn run(spec: &SweepSpec, opts: &RunOptions) -> Result<SweepOutcome> {
    run_with_store(spec, opts, None)
}

/// [`run`] with an optional persistent [`CellStore`] attached:
/// read-through (work items whose fingerprint the store already holds
/// are served without simulating) and write-back (fresh results are
/// appended for the next run). Reports stay byte-identical to a cold,
/// store-less run at any thread count: stored results carry normalized
/// engine stats, and this grid's own batch plan re-labels them (see the
/// [`crate::store`] module docs on label purity) — which is why warm
/// runs still compile schedules in phase 1 even when every cell hits.
pub fn run_with_store(
    spec: &SweepSpec,
    opts: &RunOptions,
    store: Option<&CellStore>,
) -> Result<SweepOutcome> {
    // Canonicalize a local copy so coordinates (and the cell seeds
    // derived from them) are case-stable no matter how the caller
    // spelled the axes. This also dedupes duplicate axis values (with a
    // warning), so an axis typo cannot inflate the grid.
    let spec = {
        let mut s = spec.clone();
        s.canonicalize()?;
        s
    };
    spec.validate()?;
    let cells = spec.expand();
    // The fingerprint partition is computed in BOTH modes: with dedup on
    // it is the work plan; with dedup off it still drives batch
    // labeling, which must not depend on the execution mode.
    let fp_plan = DedupPlan::partition(&cells);
    let plan = if opts.dedup { fp_plan.clone() } else { DedupPlan::identity(cells.len()) };
    let work: Vec<&CellSpec> = plan.unique.iter().map(|&i| &cells[i]).collect();
    // Probe the store serially on the caller thread (reads are index
    // lookups; the first probe per shard pays that shard's load).
    let stored: Vec<Option<StoredCell>> = match store {
        Some(st) => {
            let mut v = Vec::with_capacity(work.len());
            for c in &work {
                v.push(st.get_cell(&c.fingerprint())?);
            }
            v
        }
        None => vec![None; work.len()],
    };
    let store_hits = stored.iter().filter(|s| s.is_some()).count();
    let store_misses = if store.is_some() { work.len() - store_hits } else { 0 };
    let threads = effective_threads(opts.threads, work.len());
    let inner = RunOptions { threads, progress: opts.progress, dedup: opts.dedup };
    let sched_opts = RunOptions { threads, progress: false, dedup: opts.dedup };
    let t0 = Instant::now();
    // Every executed work item resolves to a [`ScenarioOutcome`]:
    // static cells always `Ok`, scenario cells may carry a structured
    // per-cell error (which flows into the report, never the store).
    let (summaries, planner_build_ms): (Vec<ScenarioOutcome>, f64) =
        if opts.dedup {
            let shared = SweepCache::default();
            // Phase 1 (parallel): resolve every unique cell's shared
            // schedule — construction the per-cell path would have done
            // lazily, hoisted so the planner can inspect the compiles.
            let resolved: Vec<(Option<SharedSchedule>, f64)> =
                run_cells(&work, &sched_opts, |_, c| shared.schedule_for(c));
            let phase1_build: f64 = resolved.iter().map(|(_, b)| b).sum();
            let scheds: Vec<Option<SharedSchedule>> =
                resolved.into_iter().map(|(s, _)| s).collect();
            // Phase 2 (serial): group cells sharing one periodic
            // schedule into batches.
            let bplan = plan_batches(&work, &scheds);
            // Phase 3 (parallel): execute batches and solos, scattering
            // results back into work order.
            enum Unit {
                Chunk(usize),
                Solo(usize),
            }
            let units: Vec<Unit> = (0..bplan.chunks.len())
                .map(Unit::Chunk)
                .chain(bplan.solos.iter().map(|&i| Unit::Solo(i)))
                .collect();
            let produced: Vec<Vec<(usize, ScenarioOutcome)>> =
                run_cells(&units, &inner, |_, unit| match unit {
                    Unit::Chunk(ci) => {
                        // Store hits drop out of the batch: per-lane
                        // batched results are width-independent (pinned
                        // by the batched-engine proptest), so running
                        // only the missed lanes is byte-exact.
                        let missed: Vec<usize> = bplan.chunks[*ci]
                            .iter()
                            .copied()
                            .filter(|&i| stored[i].is_none())
                            .collect();
                        if missed.is_empty() {
                            return Vec::new();
                        }
                        let batch: Vec<(&CellSpec, Arc<CompiledTopology>)> = missed
                            .iter()
                            .map(|&i| match &scheds[i] {
                                Some(SharedSchedule::Periodic(ct)) => (work[i], Arc::clone(ct)),
                                _ => unreachable!("planner only chunks periodic cells"),
                            })
                            .collect();
                        // The batch key includes `rounds`, so the chunk
                        // is uniform; take the first cell's budget.
                        let rounds = work[missed[0]].rounds;
                        let outs: Vec<ScenarioOutcome> = match &spec.scenario {
                            Some(sc) => run_batch_scenario(&batch, rounds, sc),
                            None => run_batch_cached(&batch, rounds)
                                .into_iter()
                                .map(|(s, t, st)| (Ok((s, st)), t))
                                .collect(),
                        };
                        missed.iter().copied().zip(outs).collect()
                    }
                    Unit::Solo(i) if stored[*i].is_some() => Vec::new(),
                    Unit::Solo(i) => {
                        let out = if work[*i].is_adaptive() {
                            run_cell_adaptive(work[*i])
                        } else if spec.scenario.is_some() {
                            run_cell_scenario_cached(work[*i], &shared)
                        } else {
                            let (s, t, st) = run_cell_cached_timed(work[*i], &shared);
                            (Ok((s, st)), t)
                        };
                        vec![(*i, out)]
                    }
                });
            let mut slots: Vec<Option<ScenarioOutcome>> =
                work.iter().map(|_| None).collect();
            for (i, r) in produced.into_iter().flatten() {
                slots[i] = Some(r);
            }
            // Fill the store-hit slots, applying THIS grid's batch
            // labels: a stored (normalized, never-batched) result that
            // lands in a chunk reports `batched`, exactly as the cold
            // run would have labeled it.
            let mut in_chunk = vec![false; work.len()];
            for chunk in &bplan.chunks {
                for &i in chunk {
                    in_chunk[i] = true;
                }
            }
            for (i, slot) in slots.iter_mut().enumerate() {
                if slot.is_none() {
                    let sc = stored[i].as_ref().expect("empty slots are store hits");
                    let stats = if in_chunk[i] {
                        EngineStats { kind: EngineKind::Batched, ..sc.stats }
                    } else {
                        sc.stats
                    };
                    *slot = Some((
                        Ok((
                            sc.to_summary(&work[i].network, &work[i].profile, work[i].rounds),
                            stats,
                        )),
                        CellTiming::default(),
                    ));
                }
            }
            let summaries = slots
                .into_iter()
                .map(|s| s.expect("every unique cell executed or served from the store"))
                .collect();
            (summaries, phase1_build)
        } else {
            // Dedup off: every grid cell runs independently, but batch
            // labels still come from the fingerprint partition above so
            // the engine column matches the dedup mode byte for byte;
            // labeled cells run as single-lane batches. The labeling
            // pass's construction cost is not added to build_ms here
            // (every cell's own timing already pays its full build) —
            // it is visible only in host_elapsed_ms.
            let labeler = SweepCache::default();
            let fp_work: Vec<&CellSpec> = fp_plan.unique.iter().map(|&i| &cells[i]).collect();
            let scheds: Vec<Option<SharedSchedule>> =
                run_cells(&fp_work, &sched_opts, |_, c| labeler.schedule_for(c).0);
            let bplan = plan_batches(&fp_work, &scheds);
            let mut batched_label = vec![false; fp_work.len()];
            for chunk in &bplan.chunks {
                for &i in chunk {
                    batched_label[i] = true;
                }
            }
            let summaries = run_cells(&work, &inner, |i, c| {
                if let Some(hit) = &stored[i] {
                    let stats = if batched_label[fp_plan.assignment[i]] {
                        EngineStats { kind: EngineKind::Batched, ..hit.stats }
                    } else {
                        hit.stats
                    };
                    (
                        Ok((hit.to_summary(&c.network, &c.profile, c.rounds), stats)),
                        CellTiming::default(),
                    )
                } else if c.is_adaptive() {
                    run_cell_adaptive(c)
                } else if spec.scenario.is_some() {
                    if batched_label[fp_plan.assignment[i]] {
                        run_cell_scenario_batched_single(c)
                    } else {
                        run_cell_scenario_uncached(c)
                    }
                } else if batched_label[fp_plan.assignment[i]] {
                    let (s, t, st) = run_cell_batched_single(c);
                    (Ok((s, st)), t)
                } else {
                    let (s, t, st) = run_cell_summary_timed(c);
                    (Ok((s, st)), t)
                }
            });
            (summaries, 0.0)
        };
    // Write fresh results back (serially; appends are cheap). Only
    // fingerprint representatives are written — duplicates would append
    // identical records. `stored[i].is_none()` marks the work items
    // that actually simulated, in both modes.
    if let Some(st) = store {
        let mut rep = vec![false; cells.len()];
        for &i in &fp_plan.unique {
            rep[i] = true;
        }
        for (wi, (res, _)) in summaries.iter().enumerate() {
            if let Ok((s, stats)) = res {
                if stored[wi].is_none() && rep[plan.unique[wi]] {
                    st.put_cell(&work[wi].fingerprint(), s, stats)?;
                }
            }
        }
    }
    let results: Vec<CellResult> = cells
        .iter()
        .zip(&plan.assignment)
        .map(|(cell, &slot)| match &summaries[slot].0 {
            Ok((s, stats)) => CellResult::from_summary(s, cell, stats),
            Err(e) => CellResult::from_error(cell, e),
        })
        .collect();
    let build_ms: f64 = planner_build_ms + summaries.iter().map(|(_, t)| t.build_ms).sum::<f64>();
    let sim_ms: f64 = summaries.iter().map(|(_, t)| t.sim_ms).sum();
    let mut engines = EngineMix::default();
    for ((res, _), &i) in summaries.iter().zip(&plan.unique) {
        if let Ok((s, stats)) = res {
            debug_assert_eq!(s.rounds, cells[i].rounds);
            engines.count(stats, cells[i].rounds);
        }
    }
    Ok(SweepOutcome {
        report: SweepReport {
            name: spec.name.clone(),
            rounds: spec.rounds,
            scenario: spec.scenario.is_some(),
            adaptive: spec.is_adaptive(),
            cells: results,
        },
        host_elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
        threads,
        unique_cells: work.len(),
        build_ms,
        sim_ms,
        engines,
        store_hits,
        store_misses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(8, 3), 3);
        assert_eq!(effective_threads(3, 0), 1);
        assert!(effective_threads(0, 100) >= 1);
    }

    #[test]
    fn run_cells_preserves_input_order() {
        let cells: Vec<usize> = (0..64).collect();
        let one = RunOptions { threads: 1, ..Default::default() };
        let four = RunOptions { threads: 4, ..Default::default() };
        let serial = run_cells(&cells, &one, |i, &c| (i, c * 3));
        let parallel = run_cells(&cells, &four, |i, &c| (i, c * 3));
        assert_eq!(serial, parallel);
        assert!(serial.iter().enumerate().all(|(i, &(j, v))| i == j && v == i * 3));
    }

    #[test]
    fn run_cells_handles_empty_and_single() {
        let none: Vec<u32> = vec![];
        assert!(run_cells(&none, &RunOptions::default(), |_, &c| c).is_empty());
        let one = vec![7u32];
        assert_eq!(run_cells(&one, &RunOptions::default(), |_, &c| c + 1), vec![8]);
    }

    #[test]
    fn engine_reproduces_the_paper_ordering_on_gaia() {
        let spec = SweepSpec {
            name: "smoke".into(),
            topologies: vec![TopologyKind::Ring, TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![17],
            rounds: 200,
            scenario: None,
            adapt: Vec::new(),
        };
        let outcome = run(&spec, &RunOptions { threads: 2, ..Default::default() }).unwrap();
        assert_eq!(outcome.threads, 2, "explicit thread request is honored");
        assert_eq!(outcome.unique_cells, 2, "no duplicate work in a single-seed grid");
        assert!(
            outcome.build_ms >= 0.0 && outcome.sim_ms > 0.0,
            "build/sim split must be populated: build {} sim {}",
            outcome.build_ms,
            outcome.sim_ms
        );
        // Both unique cells (ring, multigraph) are periodic at 200
        // rounds (s_max = 60 on gaia t=5); the mix must say so, and
        // cycle replay must have cut the stepped-round count.
        assert_eq!(outcome.engines.periodic, 2, "{:?}", outcome.engines);
        assert_eq!(outcome.engines.total_rounds, 400);
        assert!(
            outcome.engines.stepped_rounds < 400,
            "cycle replay should step fewer rounds than simulated: {:?}",
            outcome.engines
        );
        let report = &outcome.report;
        assert_eq!(report.cells.len(), 2);
        // Grid order: ring first, multigraph second.
        assert_eq!(report.cells[0].topology, "ring");
        assert_eq!(report.cells[1].topology, "multigraph");
        let ring = report.cell("ring", "gaia", "femnist").unwrap();
        let ours = report.cell("multigraph", "gaia", "femnist").unwrap();
        assert!(
            ours.mean_cycle_ms < ring.mean_cycle_ms,
            "ours {} vs ring {}",
            ours.mean_cycle_ms,
            ring.mean_cycle_ms
        );
        assert!(ours.rounds_with_isolated > 0);
        assert_eq!(ring.rounds_with_isolated, 0);
    }

    #[test]
    fn engine_cell_matches_direct_simulation() {
        // A sweep cell must equal running the same experiment by hand:
        // same derived seed, same simulator, bit-identical numbers.
        let spec = SweepSpec {
            name: "oracle".into(),
            topologies: vec![TopologyKind::Matcha],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![23],
            rounds: 120,
            scenario: None,
            adapt: Vec::new(),
        };
        let outcome = run(&spec, &RunOptions { threads: 2, ..Default::default() }).unwrap();
        let got = &outcome.report.cells[0];

        let cells = spec.expand();
        let cfg = cells[0].to_experiment();
        let net = cfg.resolve_network();
        let prof = cfg.resolve_profile().unwrap();
        let mut topo = cfg.build_topology();
        let want = crate::simtime::simulate(topo.as_mut(), &net, &prof, cells[0].rounds);
        assert_eq!(got.mean_cycle_ms.to_bits(), want.mean_cycle_ms.to_bits());
        assert_eq!(got.total_ms.to_bits(), want.total_ms.to_bits());
        assert_eq!(got.seed, 23, "reports carry the base seed, not the derived stream");
    }

    #[test]
    fn dedup_fans_results_out_to_every_duplicate_cell() {
        // Deterministic-only grid with 3 seeds: one simulation per
        // topology, three reported cells each — byte-identical to the
        // pre-cache engine that simulates all nine.
        let spec = SweepSpec {
            name: "fanout".into(),
            topologies: vec![TopologyKind::Ring, TopologyKind::Mst, TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![1, 2, 3],
            rounds: 40,
            scenario: None,
            adapt: Vec::new(),
        };
        let memo = run(&spec, &RunOptions { threads: 3, progress: false, dedup: true }).unwrap();
        let full = run(&spec, &RunOptions { threads: 3, progress: false, dedup: false }).unwrap();
        assert_eq!(memo.unique_cells, 3);
        assert_eq!(full.unique_cells, 9);
        assert_eq!(memo.report.cells.len(), spec.cell_count());
        assert!((memo.dedup_ratio() - 3.0).abs() < 1e-12);
        assert_eq!(
            memo.report.to_json().to_string(),
            full.report.to_json().to_string(),
            "fan-out must be byte-identical to the pre-cache engine"
        );
        // Fanned-out duplicates still carry their own seed columns.
        let seeds: Vec<u64> = memo.report.cells.iter().map(|c| c.seed).collect();
        assert_eq!(seeds, vec![1, 2, 3, 1, 2, 3, 1, 2, 3]);
        let streams: std::collections::BTreeSet<u64> =
            memo.report.cells.iter().map(|c| c.cell_seed).collect();
        assert_eq!(streams.len(), 9, "derived streams stay per-cell after fan-out");
    }

    #[test]
    fn timed_cell_matches_untimed_bitwise() {
        let spec = SweepSpec {
            name: "timing".into(),
            topologies: vec![TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![17],
            rounds: 60,
            scenario: None,
            adapt: Vec::new(),
        };
        let cell = &spec.expand()[0];
        let (timed, timing, stats) = run_cell_summary_timed(cell);
        let plain = run_cell_summary(cell);
        assert_eq!(timed.total_ms.to_bits(), plain.total_ms.to_bits());
        assert_eq!(timed.mean_cycle_ms.to_bits(), plain.mean_cycle_ms.to_bits());
        assert!(timing.build_ms >= 0.0 && timing.sim_ms >= 0.0);
        assert!(stats.simulated_rounds >= 1);
    }

    #[test]
    fn scenario_sweeps_stay_byte_identical_across_dedup_modes() {
        // A churn scenario rides the whole grid: every engine tier the
        // planner picks (batched chunks under dedup, solo compiled or
        // tracker cells without) must produce the same artifact bytes.
        let sc = Arc::new(
            crate::simtime::ScenarioSpec::from_event_strs(
                9,
                &["leave@10:silo=2", "scale@20:factor=1.3", "rejoin@35:silo=2", "jitter@0:amp=2.0"],
            )
            .unwrap(),
        );
        let spec = SweepSpec {
            name: "churn".into(),
            topologies: vec![TopologyKind::Ring, TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![1, 2],
            rounds: 60,
            scenario: Some(Arc::clone(&sc)),
            adapt: Vec::new(),
        };
        let memo = run(&spec, &RunOptions { threads: 2, progress: false, dedup: true }).unwrap();
        let full = run(&spec, &RunOptions { threads: 1, progress: false, dedup: false }).unwrap();
        assert!(memo.report.scenario, "the report must flag scenario mode");
        assert_eq!(
            memo.report.to_json().to_string(),
            full.report.to_json().to_string(),
            "scenario sweeps must be byte-identical across dedup modes and thread counts"
        );
        assert_eq!(memo.report.to_csv(), full.report.to_csv());
        // Deterministic topologies still dedupe across the seed axis:
        // the scenario hash joins the fingerprint but is grid-wide.
        assert_eq!(memo.unique_cells, 2);
        for cell in &memo.report.cells {
            assert!(cell.error.is_none(), "mild churn must not error: {:?}", cell.error);
            let m = cell.scenario.as_ref().expect("scenario cells carry degraded metrics");
            assert!(m.segments.len() >= 3, "leave/rejoin/scale split the timeline");
            assert!(m.p95_ms >= m.p50_ms && m.max_ms >= m.p95_ms);
        }
        let csv = memo.report.to_csv();
        assert!(csv.lines().next().unwrap().ends_with(
            ",error,p50_ms,p95_ms,max_ms,isolation_rate,recovery_rounds,segments"
        ));
    }

    #[test]
    fn scenario_emptying_the_network_yields_error_rows_not_a_panic() {
        // Leave every gaia silo but one: each cell becomes a structured
        // error row with its grid coordinates intact, and the sweep
        // itself still succeeds.
        let n = crate::net::zoo::gaia().n();
        let evs: Vec<String> = (1..n).map(|i| format!("leave@5:silo={i}")).collect();
        let sc = Arc::new(crate::simtime::ScenarioSpec::from_event_strs(3, &evs).unwrap());
        let spec = SweepSpec {
            name: "blackout".into(),
            topologies: vec![TopologyKind::Ring, TopologyKind::Multigraph],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5],
            seeds: vec![1],
            rounds: 40,
            scenario: Some(sc),
            adapt: Vec::new(),
        };
        let memo = run(&spec, &RunOptions { threads: 2, progress: false, dedup: true }).unwrap();
        let full = run(&spec, &RunOptions { threads: 1, progress: false, dedup: false }).unwrap();
        assert_eq!(memo.report.to_json().to_string(), full.report.to_json().to_string());
        for cell in &memo.report.cells {
            assert_eq!(cell.engine, "error");
            let err = cell.error.as_ref().expect("blackout cells carry the failure string");
            assert!(err.contains("need at least 2"), "unexpected error text: {err}");
            assert_eq!(cell.total_ms, 0.0);
        }
        assert_eq!(memo.engines.total_rounds, 0, "error cells never reach an engine");
    }

    #[test]
    fn duplicate_axis_values_no_longer_inflate_the_grid() {
        // run() canonicalizes, which now dedupes duplicated axis values
        // (with a warning) before expansion.
        let spec = SweepSpec {
            name: "dup".into(),
            topologies: vec![TopologyKind::Ring],
            networks: vec!["gaia".into()],
            profiles: vec!["femnist".into()],
            t_values: vec![5, 5],
            seeds: vec![7, 7],
            rounds: 10,
            scenario: None,
            adapt: Vec::new(),
        };
        let outcome = run(&spec, &RunOptions { threads: 1, ..Default::default() }).unwrap();
        assert_eq!(outcome.report.cells.len(), 1, "duplicates must not inflate the grid");
        assert_eq!(outcome.unique_cells, 1);
    }
}
