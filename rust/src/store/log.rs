//! On-disk framing of one store shard: a fixed header followed by
//! length-prefixed, checksummed records.
//!
//! Layout of a shard file:
//!
//! ```text
//! [ 8-byte magic "MGFLSTO1" | u32 LE format version | u32 LE epoch ]
//! [ u32 LE payload_len | payload | u64 LE fnv1a(payload) ]*
//! ```
//!
//! where each payload is `u32 LE key_len | key (UTF-8) | value bytes`.
//! Records are appended with one `write_all` on an `O_APPEND` handle,
//! so a record is either fully present or cut off at the end of the
//! file — [`scan_records`] stops at the first short, malformed, or
//! checksum-failed record and reports the byte offset of the last clean
//! record boundary, so a crash-truncated tail is dropped without ever
//! corrupting (or trusting) anything before it.

use crate::util::rng::fnv1a;

/// Shard-file magic: identifies the format (and its major revision).
pub(crate) const MAGIC: &[u8; 8] = b"MGFLSTO1";

/// Total header length in bytes: magic + version + epoch.
pub(crate) const HEADER_LEN: usize = 16;

/// Cap on a single record payload; anything larger is treated as
/// corruption (real payloads are a few hundred bytes).
const MAX_PAYLOAD: usize = 1 << 30;

/// Serialize the 16-byte shard header for `(version, epoch)`.
pub(crate) fn header_bytes(version: u32, epoch: u32) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(MAGIC);
    h[8..12].copy_from_slice(&version.to_le_bytes());
    h[12..16].copy_from_slice(&epoch.to_le_bytes());
    h
}

/// Parse and validate a shard header. Returns `(version, epoch)`.
pub(crate) fn parse_header(bytes: &[u8]) -> Result<(u32, u32), String> {
    if bytes.len() < HEADER_LEN {
        return Err(format!("file shorter than the {HEADER_LEN}-byte header"));
    }
    if &bytes[..8] != MAGIC {
        return Err("bad magic (not a store shard file)".into());
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
    let epoch = u32::from_le_bytes(bytes[12..16].try_into().expect("4 bytes"));
    Ok((version, epoch))
}

/// Serialize one record (frame + payload + checksum) for appending.
pub(crate) fn encode_record(key: &str, value: &[u8]) -> Vec<u8> {
    let payload_len = 4 + key.len() + value.len();
    let mut out = Vec::with_capacity(4 + payload_len + 8);
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.extend_from_slice(&(key.len() as u32).to_le_bytes());
    out.extend_from_slice(key.as_bytes());
    out.extend_from_slice(value);
    let payload = &out[4..];
    let sum = fnv1a(payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Why a scan stopped before the end of the file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum ScanIssue {
    /// The final record is cut off mid-frame — the crash-recovery case.
    /// Everything before `clean_len` is intact; the tail is dropped.
    TornTail,
    /// A record failed its checksum or carried impossible lengths —
    /// in-place corruption. The scan conservatively stops here: nothing
    /// at or after this offset is trusted.
    Corrupt(String),
}

/// Result of scanning a shard file's record region.
#[derive(Debug, Clone)]
pub(crate) struct ScanResult {
    /// Decoded `(key, value)` records in file order (duplicates kept;
    /// the index layer applies last-record-wins).
    pub records: Vec<(String, Vec<u8>)>,
    /// Byte offset (relative to the start of `bytes`) just past the
    /// last fully-valid record — where appends may safely resume after
    /// truncating anything beyond it.
    pub clean_len: usize,
    /// Why the scan stopped early, if it did.
    pub issue: Option<ScanIssue>,
}

/// Scan the record region of a shard file (everything after the
/// header). Stops at the first torn or corrupt record; see
/// [`ScanIssue`] for the recovery contract.
pub(crate) fn scan_records(bytes: &[u8]) -> ScanResult {
    let mut records = Vec::new();
    let mut i = 0usize;
    let mut issue = None;
    while i < bytes.len() {
        if i + 4 > bytes.len() {
            issue = Some(ScanIssue::TornTail);
            break;
        }
        let len = u32::from_le_bytes(bytes[i..i + 4].try_into().expect("4 bytes")) as usize;
        if len < 4 || len > MAX_PAYLOAD {
            issue = Some(ScanIssue::Corrupt(format!(
                "record at offset {i} claims impossible payload length {len}"
            )));
            break;
        }
        let end = i + 4 + len + 8;
        if end > bytes.len() {
            issue = Some(ScanIssue::TornTail);
            break;
        }
        let payload = &bytes[i + 4..i + 4 + len];
        let sum = u64::from_le_bytes(bytes[i + 4 + len..end].try_into().expect("8 bytes"));
        if fnv1a(payload) != sum {
            issue = Some(ScanIssue::Corrupt(format!(
                "checksum mismatch in record at offset {i}"
            )));
            break;
        }
        let key_len = u32::from_le_bytes(payload[..4].try_into().expect("4 bytes")) as usize;
        if 4 + key_len > len {
            issue = Some(ScanIssue::Corrupt(format!(
                "record at offset {i} claims key length {key_len} beyond its payload"
            )));
            break;
        }
        let key = match std::str::from_utf8(&payload[4..4 + key_len]) {
            Ok(k) => k.to_string(),
            Err(_) => {
                issue = Some(ScanIssue::Corrupt(format!(
                    "record at offset {i} has a non-UTF-8 key"
                )));
                break;
            }
        };
        records.push((key, payload[4 + key_len..].to_vec()));
        i = end;
    }
    ScanResult { records, clean_len: if issue.is_some() { i } else { bytes.len() }, issue }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip_and_rejection() {
        let h = header_bytes(1, 7);
        assert_eq!(parse_header(&h).unwrap(), (1, 7));
        assert!(parse_header(&h[..12]).is_err(), "short header");
        let mut bad = h;
        bad[0] ^= 1;
        assert!(parse_header(&bad).is_err(), "bad magic");
    }

    #[test]
    fn records_roundtrip_in_order() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&encode_record("a", b"one"));
        buf.extend_from_slice(&encode_record("bb", b""));
        buf.extend_from_slice(&encode_record("a", b"two"));
        let scan = scan_records(&buf);
        assert!(scan.issue.is_none());
        assert_eq!(scan.clean_len, buf.len());
        assert_eq!(
            scan.records,
            vec![
                ("a".to_string(), b"one".to_vec()),
                ("bb".to_string(), Vec::new()),
                ("a".to_string(), b"two".to_vec()),
            ]
        );
    }

    #[test]
    fn every_truncation_point_drops_only_the_torn_tail() {
        let r1 = encode_record("first", b"payload-1");
        let r2 = encode_record("second", b"payload-22");
        let mut buf = r1.clone();
        buf.extend_from_slice(&r2);
        for cut in 0..buf.len() {
            let scan = scan_records(&buf[..cut]);
            let complete = if cut >= buf.len() {
                2
            } else if cut >= r1.len() {
                1
            } else {
                0
            };
            assert_eq!(scan.records.len(), complete, "cut at {cut}");
            if cut == r1.len() || cut == 0 {
                // Exactly at a boundary: nothing torn.
                assert!(scan.issue.is_none(), "cut at {cut}");
            } else {
                assert_eq!(scan.issue, Some(ScanIssue::TornTail), "cut at {cut}");
            }
            let boundary = if complete >= 1 { r1.len() } else { 0 };
            assert_eq!(scan.clean_len, boundary.min(cut), "cut at {cut}");
        }
    }

    #[test]
    fn flipped_bytes_are_rejected_as_corrupt() {
        let r1 = encode_record("first", b"payload-1");
        let r2 = encode_record("second", b"payload-22");
        let mut buf = r1.clone();
        buf.extend_from_slice(&r2);
        // Flip one payload byte of the second record: record 1 survives,
        // the scan stops at record 2 with a checksum issue.
        let mut flipped = buf.clone();
        flipped[r1.len() + 6] ^= 0xFF;
        let scan = scan_records(&flipped);
        assert_eq!(scan.records.len(), 1);
        assert_eq!(scan.clean_len, r1.len());
        assert!(matches!(scan.issue, Some(ScanIssue::Corrupt(_))), "{:?}", scan.issue);
        // Corruption mid-file hides everything after it, by design.
        let mut early = buf.clone();
        early[6] ^= 0xFF;
        let scan = scan_records(&early);
        assert!(scan.records.is_empty());
        assert_eq!(scan.clean_len, 0);
        assert!(matches!(scan.issue, Some(ScanIssue::Corrupt(_))));
    }
}
