//! Persistent content-addressed cell-result store.
//!
//! The in-process dedup layers ([`crate::sweep::SweepCache`], the search
//! fitness [`crate::sweep::BuildOnce`]) die with the process; this module
//! makes the fingerprint discipline a durable cross-process contract. A
//! [`CellStore`] is a directory of append-only shard logs (see
//! [`mod@self::log`] for the byte framing) plus an in-memory last-record-wins
//! index rebuilt lazily per shard on first touch, so opening a store with
//! millions of records costs nothing until keys in a shard are actually
//! consulted.
//!
//! # Keys
//!
//! Everything is a flat string key:
//!
//! - `cell/{kind}/{network}/{profile}/t{t}/r{rounds}/s{seed}` — one sweep
//!   cell's [`SimSummary`]-equivalent payload, addressed by its
//!   [`CellFingerprint`] (seed is the literal `-` for deterministic kinds,
//!   matching the fingerprint's `None`).
//! - `fit/{network}/{profile}/r{rounds}/{genome}` — a search genome's
//!   fitness (mean cycle ms), keyed by the genome's canonical key.
//! - `probe/{network}/{profile}/r{rounds}/b{budget}/s{seed}` — a MATCHA
//!   budget-probe fitness from `mgfl optimize`.
//!
//! Keys are sharded by `fnv1a(key) & 0xF` into 16 log files.
//!
//! # Invalidation epochs
//!
//! Every shard file header embeds [`FORMAT_VERSION`] (byte layout) and
//! [`ENGINE_EPOCH`] (simulation semantics), and both are also baked into
//! the file *name*, so a store directory can hold generations side by
//! side. A store opened at epoch N never reads epoch M≠N files: bumping
//! [`ENGINE_EPOCH`] when engine semantics change invalidates every stale
//! result wholesale without deleting anything (run `gc` to reclaim).
//!
//! # Engine-label purity
//!
//! A cell's reported `engine` label depends on the *whole grid's* batch
//! plan (only groups of `MIN_BATCH`-plus lanes run batched), not on the
//! cell alone — so a label stored as-executed under one spec could leak a
//! wrong label into another. [`CellStore::put_cell`] therefore normalizes
//! `batched` to `periodic` (the two engines produce bit-identical
//! summaries per lane), and warm sweeps recompute labels from the current
//! grid's own batch plan before fanning stored results out.
//!
//! # Crash safety
//!
//! Appends are a single `write_all` of a checksummed record on an
//! `O_APPEND` handle under the shard mutex. On open, a torn tail (from a
//! crash mid-append) is detected, logged off, and truncated away;
//! records before it are untouched. [`verify`] audits every generation
//! read-only; [`gc`] drops stale generations and compacts live shards.

pub mod serve;

mod log;

use std::collections::HashMap;
use std::fs::{self, File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::simtime::{
    AdaptMetrics, EngineKind, EngineStats, ScenarioMetrics, SegmentMetrics, SimSummary,
};
use crate::sweep::CellFingerprint;
use crate::util::rng::fnv1a;

/// On-disk byte-layout revision. Bump when the record or value encoding
/// changes shape.
pub const FORMAT_VERSION: u32 = 1;

/// Simulation-semantics epoch. Bump whenever engine changes could alter
/// any stored number (delay model, schedule compilation, RNG streams…):
/// every result stored under an older epoch becomes invisible wholesale.
pub const ENGINE_EPOCH: u32 = 1;

/// Number of shard log files per store generation.
const SHARD_COUNT: usize = 16;

/// How long to wait for another process to finish writing a fresh shard
/// header before giving up (see [`CellStore`] creation race handling).
const HEADER_RACE_TRIES: usize = 500;

/// One shard once loaded: its last-record-wins index plus the open
/// `O_APPEND` handle and bookkeeping counters.
struct ShardState {
    index: HashMap<String, Vec<u8>>,
    file: File,
    /// Records seen at load plus records appended since.
    records: usize,
    /// Current file length in bytes (post any recovery truncation).
    bytes: u64,
}

/// A persistent, content-addressed result store rooted at a directory.
///
/// Cheap to open (shards load lazily) and safe to share across threads —
/// all methods take `&self`. Multiple processes may append to the same
/// store concurrently: appends are atomic records, and each process
/// simply won't *see* the others' writes until it reopens.
pub struct CellStore {
    dir: PathBuf,
    epoch: u32,
    shards: Vec<Mutex<Option<ShardState>>>,
}

impl CellStore {
    /// Open (creating if needed) the store at `dir` under the crate's
    /// current [`ENGINE_EPOCH`].
    pub fn open(dir: impl AsRef<Path>) -> Result<CellStore> {
        CellStore::open_with_epoch(dir, ENGINE_EPOCH)
    }

    /// Open the store at `dir` pinned to an explicit epoch. Production
    /// callers want [`CellStore::open`]; this exists so tests (and `gc`)
    /// can address non-current generations.
    pub fn open_with_epoch(dir: impl AsRef<Path>, epoch: u32) -> Result<CellStore> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating store directory {}", dir.display()))?;
        let shards = (0..SHARD_COUNT).map(|_| Mutex::new(None)).collect();
        Ok(CellStore { dir, epoch, shards })
    }

    /// The store's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The epoch this handle reads and writes.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Raw lookup: the latest value recorded for `key`, if any.
    pub fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        let mut guard = self.shard(shard_of(key))?;
        let state = guard.as_mut().expect("shard loaded");
        Ok(state.index.get(key).cloned())
    }

    /// Raw append: durably record `key = value` (last record wins).
    pub fn put(&self, key: &str, value: &[u8]) -> Result<()> {
        let record = log::encode_record(key, value);
        let mut guard = self.shard(shard_of(key))?;
        let state = guard.as_mut().expect("shard loaded");
        state
            .file
            .write_all(&record)
            .with_context(|| format!("appending to store shard for key {key}"))?;
        state.records += 1;
        state.bytes += record.len() as u64;
        state.index.insert(key.to_string(), value.to_vec());
        Ok(())
    }

    /// Typed lookup of one sweep cell by fingerprint.
    pub fn get_cell(&self, fp: &CellFingerprint) -> Result<Option<StoredCell>> {
        match self.get(&cell_key(fp))? {
            Some(bytes) => Ok(Some(
                StoredCell::decode(&bytes)
                    .with_context(|| format!("decoding stored cell {}", cell_key(fp)))?,
            )),
            None => Ok(None),
        }
    }

    /// Typed write-back of one sweep cell's result.
    ///
    /// The engine label is normalized before storage: `batched` becomes
    /// `periodic` (bit-identical summaries; see the module docs on
    /// label purity), so nothing grid-dependent is ever persisted.
    pub fn put_cell(
        &self,
        fp: &CellFingerprint,
        summary: &SimSummary,
        stats: &EngineStats,
    ) -> Result<()> {
        let mut stats = *stats;
        if stats.kind == EngineKind::Batched {
            stats.kind = EngineKind::Periodic;
        }
        let cell = StoredCell {
            topology: summary.topology.clone(),
            mean_cycle_ms: summary.mean_cycle_ms,
            total_ms: summary.total_ms,
            rounds_with_isolated: summary.rounds_with_isolated,
            max_isolated: summary.max_isolated,
            stats,
            scenario: summary.scenario.clone(),
        };
        self.put(&cell_key(fp), &cell.encode())
    }

    /// Typed lookup of a persisted fitness value (search genomes and
    /// MATCHA budget probes).
    pub fn get_fitness(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key)? {
            Some(bytes) => {
                if bytes.len() != 8 {
                    bail!("fitness value for {key} has {} bytes, want 8", bytes.len());
                }
                let bits = u64::from_le_bytes(bytes[..8].try_into().expect("8 bytes"));
                Ok(Some(f64::from_bits(bits)))
            }
            None => Ok(None),
        }
    }

    /// Typed write-back of a fitness value.
    pub fn put_fitness(&self, key: &str, fitness: f64) -> Result<()> {
        self.put(key, &fitness.to_bits().to_le_bytes())
    }

    /// Aggregate statistics over this generation's shards (forces every
    /// shard to load). Live entries are additionally broken out by key
    /// namespace — static vs scenario vs adaptive cells vs everything
    /// else — so `mgfl cache stats` can say what a store actually holds.
    pub fn stats(&self) -> Result<StoreStats> {
        let mut out = StoreStats::default();
        for s in 0..SHARD_COUNT {
            let mut guard = self.shard(s)?;
            let state = guard.as_mut().expect("shard loaded");
            out.shard_files += 1;
            out.entries += state.index.len();
            out.records += state.records;
            out.bytes += state.bytes;
            for key in state.index.keys() {
                if !key.starts_with("cell/") {
                    out.other_entries += 1;
                    continue;
                }
                // cell_key appends `/sc<hash>` then `/ad<hash>`, so the
                // last path segment is authoritative (axis names are
                // never consulted, whatever characters they contain).
                let last = key.rsplit('/').next().unwrap_or("");
                let is_hash_seg = |p: &str| {
                    last.len() == p.len() + 16
                        && last.starts_with(p)
                        && last[p.len()..].bytes().all(|b| b.is_ascii_hexdigit())
                };
                if is_hash_seg("ad") {
                    out.adaptive_cells += 1;
                } else if is_hash_seg("sc") {
                    out.scenario_cells += 1;
                } else {
                    out.static_cells += 1;
                }
            }
        }
        Ok(out)
    }

    /// Lock shard `idx`, loading it from disk first if this is the first
    /// touch. Poisoned locks are entered anyway: a panic in one lookup
    /// must not wedge the store for every later caller.
    fn shard(&self, idx: usize) -> Result<std::sync::MutexGuard<'_, Option<ShardState>>> {
        let mut guard = self.shards[idx].lock().unwrap_or_else(|e| e.into_inner());
        if guard.is_none() {
            *guard = Some(load_shard(&self.dir, idx, self.epoch)?);
        }
        Ok(guard)
    }
}

/// Which shard a key lives in.
fn shard_of(key: &str) -> usize {
    (fnv1a(key.as_bytes()) & (SHARD_COUNT as u64 - 1)) as usize
}

/// Path of shard `idx` for a `(version, epoch)` generation.
fn shard_path(dir: &Path, idx: usize, version: u32, epoch: u32) -> PathBuf {
    dir.join(format!("shard-{idx:02}-v{version}-e{epoch}.log"))
}

/// Create-or-recover one shard file and build its in-memory state.
fn load_shard(dir: &Path, idx: usize, epoch: u32) -> Result<ShardState> {
    let path = shard_path(dir, idx, FORMAT_VERSION, epoch);
    ensure_shard_file(&path, epoch)?;
    let bytes = fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    let (version, file_epoch) = log::parse_header(&bytes)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    if version != FORMAT_VERSION || file_epoch != epoch {
        bail!(
            "{}: header says v{version}/e{file_epoch}, expected v{FORMAT_VERSION}/e{epoch}",
            path.display()
        );
    }
    let scan = log::scan_records(&bytes[log::HEADER_LEN..]);
    let clean = (log::HEADER_LEN + scan.clean_len) as u64;
    if scan.issue.is_some() && clean < bytes.len() as u64 {
        // Destructive-but-safe recovery: drop the torn/corrupt tail so
        // appends resume on a clean record boundary. Everything before
        // the tail checksummed good and is kept.
        let f = OpenOptions::new()
            .write(true)
            .open(&path)
            .with_context(|| format!("reopening {} for recovery", path.display()))?;
        f.set_len(clean)
            .with_context(|| format!("truncating torn tail of {}", path.display()))?;
    }
    let records = scan.records.len();
    let mut index = HashMap::with_capacity(records);
    for (key, value) in scan.records {
        index.insert(key, value);
    }
    let file = OpenOptions::new()
        .append(true)
        .open(&path)
        .with_context(|| format!("opening {} for append", path.display()))?;
    Ok(ShardState { index, file, records, bytes: clean })
}

/// Make sure `path` exists with a complete header, handling the
/// cross-process creation race: exactly one creator wins `create_new`
/// and writes the header; losers poll until the header bytes land.
fn ensure_shard_file(path: &Path, epoch: u32) -> Result<()> {
    match OpenOptions::new().write(true).create_new(true).open(path) {
        Ok(mut f) => {
            f.write_all(&log::header_bytes(FORMAT_VERSION, epoch))
                .with_context(|| format!("writing header of {}", path.display()))?;
            Ok(())
        }
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
            for _ in 0..HEADER_RACE_TRIES {
                let len = fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                if len >= log::HEADER_LEN as u64 {
                    return Ok(());
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            bail!(
                "{}: another process created this shard but never finished its header",
                path.display()
            )
        }
        Err(e) => Err(e).with_context(|| format!("creating {}", path.display())),
    }
}

/// The store key for one sweep cell's fingerprint.
pub fn cell_key(fp: &CellFingerprint) -> String {
    let seed = match fp.seed {
        Some(s) => s.to_string(),
        None => "-".to_string(),
    };
    let mut key = format!(
        "cell/{}/{}/{}/t{}/r{}/s{}",
        fp.topology.as_str(),
        fp.network,
        fp.profile,
        fp.t,
        fp.rounds,
        seed
    );
    // Scenario cells get a distinct key space: the fault timeline
    // changes the result, so a churned cell must never be served its
    // static twin's record (or vice versa). Static cells keep the
    // legacy key byte-for-byte — no epoch bump, warm stores stay warm.
    if let Some(h) = fp.scenario {
        key.push_str(&format!("/sc{h:016x}"));
    }
    // Adaptive cells (active [adapt] policy) extend the key space
    // again: a re-optimized run must never cross-hit its static twin.
    // Policy-none cells carry no adapt hash and legitimately share the
    // static scenario key.
    if let Some(h) = fp.adapt {
        key.push_str(&format!("/ad{h:016x}"));
    }
    key
}

/// The store key for a search genome's fitness under one evaluation
/// context. `genome_key` is [`crate::search::Genome::canonical_key`].
pub fn fitness_key(network: &str, profile: &str, rounds: usize, genome_key: &str) -> String {
    format!("fit/{network}/{profile}/r{rounds}/{genome_key}")
}

/// The store key for a MATCHA budget probe.
pub fn probe_key(network: &str, profile: &str, rounds: usize, budget: f64, seed: u64) -> String {
    format!("probe/{network}/{profile}/r{rounds}/b{budget}/s{seed}")
}

/// One persisted sweep-cell result: everything a warm sweep needs to
/// reconstruct the cell's report row without simulating.
#[derive(Debug, Clone, PartialEq)]
pub struct StoredCell {
    /// Design display name (e.g. `multigraph(t=5)`).
    pub topology: String,
    /// Mean cycle time over rounds, ms.
    pub mean_cycle_ms: f64,
    /// Simulated total wall-clock, ms.
    pub total_ms: f64,
    /// Rounds with at least one isolated node.
    pub rounds_with_isolated: usize,
    /// Max isolated-node count in any round.
    pub max_isolated: usize,
    /// Engine statistics, normalized (never `batched`; see module docs).
    pub stats: EngineStats,
    /// Degraded-mode metrics, present iff the cell ran under a
    /// fault-injection scenario. Encoded as an optional trailing block,
    /// so static-cell records are byte-identical to the pre-scenario
    /// format.
    pub scenario: Option<ScenarioMetrics>,
}

impl StoredCell {
    /// Rebuild the full [`SimSummary`] by re-attaching the context the
    /// key already pins (network, profile, rounds).
    pub fn to_summary(&self, network: &str, profile: &str, rounds: usize) -> SimSummary {
        SimSummary {
            topology: self.topology.clone(),
            network: network.to_string(),
            profile: profile.to_string(),
            rounds,
            mean_cycle_ms: self.mean_cycle_ms,
            total_ms: self.total_ms,
            rounds_with_isolated: self.rounds_with_isolated,
            max_isolated: self.max_isolated,
            scenario: self.scenario.clone(),
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.topology.len());
        out.extend_from_slice(&(self.topology.len() as u32).to_le_bytes());
        out.extend_from_slice(self.topology.as_bytes());
        out.extend_from_slice(&self.mean_cycle_ms.to_bits().to_le_bytes());
        out.extend_from_slice(&self.total_ms.to_bits().to_le_bytes());
        out.extend_from_slice(&(self.rounds_with_isolated as u64).to_le_bytes());
        out.extend_from_slice(&(self.max_isolated as u64).to_le_bytes());
        out.push(match self.stats.kind {
            EngineKind::Periodic => 0,
            // put_cell normalizes; reaching here with Batched is a bug.
            EngineKind::Batched => 1,
            EngineKind::Factored => 2,
            EngineKind::Streaming => 3,
        });
        push_opt_u64(&mut out, self.stats.period.map(|v| v as u64));
        push_opt_u64(&mut out, self.stats.cycle_detected_at.map(|v| v as u64));
        push_opt_u64(&mut out, self.stats.cycle_len.map(|v| v as u64));
        out.extend_from_slice(&(self.stats.simulated_rounds as u64).to_le_bytes());
        push_opt_u64(&mut out, self.stats.groups.map(|v| v as u64));
        if let Some(m) = &self.scenario {
            out.extend_from_slice(&(m.segments.len() as u64).to_le_bytes());
            for s in &m.segments {
                out.extend_from_slice(&(s.start as u64).to_le_bytes());
                out.extend_from_slice(&(s.len as u64).to_le_bytes());
                out.extend_from_slice(&(s.up_silos as u64).to_le_bytes());
                out.extend_from_slice(&s.p50_ms.to_bits().to_le_bytes());
                out.extend_from_slice(&s.p95_ms.to_bits().to_le_bytes());
                out.extend_from_slice(&s.max_ms.to_bits().to_le_bytes());
            }
            out.extend_from_slice(&m.p50_ms.to_bits().to_le_bytes());
            out.extend_from_slice(&m.p95_ms.to_bits().to_le_bytes());
            out.extend_from_slice(&m.max_ms.to_bits().to_le_bytes());
            out.extend_from_slice(&m.isolation_rate.to_bits().to_le_bytes());
            out.extend_from_slice(&(m.recovery_rounds as u64).to_le_bytes());
            // Optional trailing adapt block (same absent-iff-None idiom
            // as the scenario block, so PR 9 records stay byte-stable).
            if let Some(a) = &m.adapt {
                out.extend_from_slice(&(a.policy.len() as u32).to_le_bytes());
                out.extend_from_slice(a.policy.as_bytes());
                out.extend_from_slice(&(a.replans as u64).to_le_bytes());
                out.extend_from_slice(&(a.fallbacks as u64).to_le_bytes());
                out.extend_from_slice(&(a.evals_spent as u64).to_le_bytes());
                out.extend_from_slice(&(a.freeze_rounds as u64).to_le_bytes());
            }
        }
        out
    }

    fn decode(bytes: &[u8]) -> Result<StoredCell> {
        let mut r = Reader { bytes, pos: 0 };
        let topology = r.str_u32_len()?;
        let mean_cycle_ms = f64::from_bits(r.u64()?);
        let total_ms = f64::from_bits(r.u64()?);
        let rounds_with_isolated = r.u64()? as usize;
        let max_isolated = r.u64()? as usize;
        let kind = match r.u8()? {
            0 => EngineKind::Periodic,
            1 => bail!("stored cell carries a grid-dependent 'batched' label"),
            2 => EngineKind::Factored,
            3 => EngineKind::Streaming,
            k => bail!("unknown engine kind code {k}"),
        };
        let period = r.opt_u64()?.map(|v| v as usize);
        let cycle_detected_at = r.opt_u64()?.map(|v| v as usize);
        let cycle_len = r.opt_u64()?.map(|v| v as usize);
        let simulated_rounds = r.u64()? as usize;
        let groups = r.opt_u64()?.map(|v| v as usize);
        // Optional trailing scenario block: absent in records written
        // before the fault-injection layer (and in static cells since).
        let scenario = if r.pos < bytes.len() {
            let nsegs = r.u64()? as usize;
            let mut segments = Vec::with_capacity(nsegs);
            for _ in 0..nsegs {
                segments.push(SegmentMetrics {
                    start: r.u64()? as usize,
                    len: r.u64()? as usize,
                    up_silos: r.u64()? as usize,
                    p50_ms: f64::from_bits(r.u64()?),
                    p95_ms: f64::from_bits(r.u64()?),
                    max_ms: f64::from_bits(r.u64()?),
                });
            }
            let p50_ms = f64::from_bits(r.u64()?);
            let p95_ms = f64::from_bits(r.u64()?);
            let max_ms = f64::from_bits(r.u64()?);
            let isolation_rate = f64::from_bits(r.u64()?);
            let recovery_rounds = r.u64()? as usize;
            // Optional trailing adapt block: absent in every static or
            // policy-none scenario record.
            let adapt = if r.pos < bytes.len() {
                Some(AdaptMetrics {
                    policy: r.str_u32_len()?,
                    replans: r.u64()? as usize,
                    fallbacks: r.u64()? as usize,
                    evals_spent: r.u64()? as usize,
                    freeze_rounds: r.u64()? as usize,
                })
            } else {
                None
            };
            Some(ScenarioMetrics {
                segments,
                p50_ms,
                p95_ms,
                max_ms,
                isolation_rate,
                recovery_rounds,
                adapt,
            })
        } else {
            None
        };
        if r.pos != bytes.len() {
            bail!("{} trailing bytes after stored cell", bytes.len() - r.pos);
        }
        Ok(StoredCell {
            topology,
            mean_cycle_ms,
            total_ms,
            rounds_with_isolated,
            max_isolated,
            stats: EngineStats {
                kind,
                period,
                cycle_detected_at,
                cycle_len,
                simulated_rounds,
                groups,
            },
            scenario,
        })
    }
}

/// `Option<u64>` encoded as a u64 with `u64::MAX` meaning `None` (the
/// counters involved are round/period counts, far below the sentinel).
fn push_opt_u64(out: &mut Vec<u8>, v: Option<u64>) {
    out.extend_from_slice(&v.unwrap_or(u64::MAX).to_le_bytes());
}

/// Bounds-checked little-endian cursor over a value payload.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("stored value truncated: wanted {n} bytes at offset {}", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn opt_u64(&mut self) -> Result<Option<u64>> {
        let v = self.u64()?;
        Ok(if v == u64::MAX { None } else { Some(v) })
    }

    fn str_u32_len(&mut self) -> Result<String> {
        let len = u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")) as usize;
        let s = std::str::from_utf8(self.take(len)?).context("stored string not UTF-8")?;
        Ok(s.to_string())
    }
}

/// Aggregate shard statistics for one store generation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Shard files in this generation (always the full shard count —
    /// missing files are created empty on first touch).
    pub shard_files: usize,
    /// Live index entries (distinct keys, last record wins).
    pub entries: usize,
    /// Total records in the logs, superseded ones included.
    pub records: usize,
    /// Total bytes across shard files.
    pub bytes: u64,
    /// Live `cell/` entries with no scenario or adapt suffix (classic
    /// static sweep results).
    pub static_cells: usize,
    /// Live `cell/` entries keyed under a `/sc` scenario suffix but no
    /// `/ad` adapt suffix (PR 9 fault-injection results — including
    /// policy-`none` rows of adaptive sweeps, which share this space).
    pub scenario_cells: usize,
    /// Live `cell/` entries keyed under an `/ad` adapt suffix (active
    /// re-optimization policies).
    pub adaptive_cells: usize,
    /// Live non-cell entries (`fit/` fitness values, `probe/` MATCHA
    /// budget probes, anything future).
    pub other_entries: usize,
}

/// Result of a read-only [`verify`] audit.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    /// Shard files inspected (every generation, not just the current
    /// epoch).
    pub files: usize,
    /// Valid records found across all files.
    pub records: usize,
    /// Files ending in a torn tail — recoverable; the next writer open
    /// truncates it away.
    pub torn_tails: usize,
    /// Hard corruption findings (checksum or framing failures before
    /// end-of-file), one message per file.
    pub corrupt: Vec<String>,
}

impl VerifyReport {
    /// True when no hard corruption was found (torn tails are fine).
    pub fn ok(&self) -> bool {
        self.corrupt.is_empty()
    }
}

/// Audit every shard file under `dir` (all versions and epochs) without
/// modifying anything: checksum each record, classify torn tails vs.
/// hard corruption.
pub fn verify(dir: impl AsRef<Path>) -> Result<VerifyReport> {
    let mut report = VerifyReport::default();
    for path in shard_files(dir.as_ref())? {
        report.files += 1;
        let bytes = fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        match log::parse_header(&bytes) {
            Ok(_) => {}
            Err(e) => {
                report.corrupt.push(format!("{}: {e}", path.display()));
                continue;
            }
        }
        let scan = log::scan_records(&bytes[log::HEADER_LEN..]);
        report.records += scan.records.len();
        match scan.issue {
            None => {}
            Some(log::ScanIssue::TornTail) => report.torn_tails += 1,
            Some(log::ScanIssue::Corrupt(msg)) => {
                report.corrupt.push(format!("{}: {msg}", path.display()));
            }
        }
    }
    Ok(report)
}

/// Result of a [`gc`] pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct GcReport {
    /// Stale-generation files deleted outright.
    pub removed_files: usize,
    /// Current-generation shard files rewritten.
    pub compacted_files: usize,
    /// Records across current-generation shards before compaction.
    pub records_before: usize,
    /// Records after (== live entries; superseded and torn records are
    /// gone).
    pub records_after: usize,
    /// Bytes across all shard files before the pass.
    pub bytes_before: u64,
    /// Bytes across surviving files after the pass.
    pub bytes_after: u64,
}

/// Garbage-collect the store at `dir` against the crate's current
/// generation: see [`gc_with_epoch`].
pub fn gc(dir: impl AsRef<Path>) -> Result<GcReport> {
    gc_with_epoch(dir, ENGINE_EPOCH)
}

/// Garbage-collect `dir` against an explicit epoch: delete shard files
/// of any other generation (stale [`FORMAT_VERSION`] or epoch), and
/// compact current-generation shards to last-record-wins (rewrite to a
/// temp file, then rename into place).
///
/// This is an offline maintenance operation: run it while no other
/// process is appending to the store.
pub fn gc_with_epoch(dir: impl AsRef<Path>, epoch: u32) -> Result<GcReport> {
    let dir = dir.as_ref();
    let mut report = GcReport::default();
    for path in shard_files(dir)? {
        let bytes = fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        report.bytes_before += bytes.len() as u64;
        let current = matches!(log::parse_header(&bytes), Ok((v, e)) if v == FORMAT_VERSION && e == epoch);
        if !current {
            fs::remove_file(&path).with_context(|| format!("removing {}", path.display()))?;
            report.removed_files += 1;
            continue;
        }
        let scan = log::scan_records(&bytes[log::HEADER_LEN..]);
        report.records_before += scan.records.len();
        let mut live: HashMap<String, Vec<u8>> = HashMap::with_capacity(scan.records.len());
        for (key, value) in scan.records {
            live.insert(key, value);
        }
        // Deterministic output order so identical stores compact to
        // identical bytes.
        let mut keys: Vec<&String> = live.keys().collect();
        keys.sort();
        let mut out = Vec::with_capacity(bytes.len());
        out.extend_from_slice(&log::header_bytes(FORMAT_VERSION, epoch));
        for key in keys {
            out.extend_from_slice(&log::encode_record(key, &live[key]));
        }
        report.records_after += live.len();
        report.bytes_after += out.len() as u64;
        let tmp = path.with_extension("log.tmp");
        fs::write(&tmp, &out).with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        report.compacted_files += 1;
    }
    Ok(report)
}

/// All shard log files directly under `dir`, sorted by name.
fn shard_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => {
            return Err(e).with_context(|| format!("listing store directory {}", dir.display()))
        }
    };
    for entry in entries {
        let entry = entry.with_context(|| format!("listing {}", dir.display()))?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("shard-") && name.ends_with(".log") {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyKind;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "mgfl_store_unit_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn fp(seed: Option<u64>) -> CellFingerprint {
        CellFingerprint {
            topology: if seed.is_some() { TopologyKind::Matcha } else { TopologyKind::Ring },
            network: "gaia".to_string(),
            profile: "femnist".to_string(),
            t: 5,
            rounds: 60,
            seed,
            scenario: None,
            adapt: None,
        }
    }

    fn sample_cell() -> StoredCell {
        StoredCell {
            topology: "ring".to_string(),
            mean_cycle_ms: 123.456,
            total_ms: 7407.36,
            rounds_with_isolated: 3,
            max_isolated: 1,
            stats: EngineStats {
                kind: EngineKind::Periodic,
                period: Some(4),
                cycle_detected_at: Some(8),
                cycle_len: Some(4),
                simulated_rounds: 12,
                groups: None,
            },
            scenario: None,
        }
    }

    #[test]
    fn keys_are_stable_and_seed_aware() {
        assert_eq!(cell_key(&fp(None)), "cell/ring/gaia/femnist/t5/r60/s-");
        assert_eq!(cell_key(&fp(Some(42))), "cell/matcha/gaia/femnist/t5/r60/s42");
        assert_eq!(
            fitness_key("gaia", "femnist", 400, "overlay/o=0,1;c=;t=5"),
            "fit/gaia/femnist/r400/overlay/o=0,1;c=;t=5"
        );
        assert_eq!(
            probe_key("gaia", "femnist", 400, 0.5, 17),
            "probe/gaia/femnist/r400/b0.5/s17"
        );
        // Scenario cells live in a disjoint key space: the static key
        // plus a hash suffix, so a warm store can never cross-serve a
        // churned cell and its static twin.
        let mut churned = fp(None);
        churned.scenario = Some(0xdead_beef_0123_4567);
        assert_eq!(
            cell_key(&churned),
            "cell/ring/gaia/femnist/t5/r60/s-/scdeadbeef01234567"
        );
        assert_ne!(cell_key(&churned), cell_key(&fp(None)));
    }

    #[test]
    fn scenario_records_roundtrip_with_their_metrics() {
        let dir = tmpdir("scenario_block");
        let mut cell = sample_cell();
        cell.stats = EngineStats {
            kind: EngineKind::Periodic,
            period: Some(4),
            cycle_detected_at: None,
            cycle_len: None,
            simulated_rounds: 60,
            groups: None,
        };
        cell.scenario = Some(ScenarioMetrics {
            segments: vec![
                SegmentMetrics {
                    start: 0,
                    len: 40,
                    up_silos: 11,
                    p50_ms: 10.5,
                    p95_ms: 12.25,
                    max_ms: 13.0,
                },
                SegmentMetrics {
                    start: 40,
                    len: 20,
                    up_silos: 9,
                    p50_ms: 11.5,
                    p95_ms: 14.25,
                    max_ms: 15.0,
                },
            ],
            p50_ms: 10.75,
            p95_ms: 14.0,
            max_ms: 15.0,
            isolation_rate: 0.0125,
            recovery_rounds: 7,
            adapt: None,
        });
        let mut churned = fp(None);
        churned.scenario = Some(0x1234);
        let store = CellStore::open(&dir).unwrap();
        store
            .put_cell(&churned, &cell.to_summary("gaia", "femnist", 60), &cell.stats)
            .unwrap();
        // The scenario record round-trips bit-exactly, and the static
        // twin's key still misses.
        assert_eq!(store.get_cell(&churned).unwrap(), Some(cell.clone()));
        assert_eq!(store.get_cell(&fp(None)).unwrap(), None);
        let summary = store.get_cell(&churned).unwrap().unwrap().to_summary("gaia", "femnist", 60);
        assert_eq!(summary.scenario, cell.scenario);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn adaptive_records_roundtrip_and_stats_break_out_key_namespaces() {
        let dir = tmpdir("adapt_block");
        let mut cell = sample_cell();
        cell.scenario = Some(ScenarioMetrics {
            segments: vec![SegmentMetrics {
                start: 0,
                len: 60,
                up_silos: 11,
                p50_ms: 10.5,
                p95_ms: 12.25,
                max_ms: 13.0,
            }],
            p50_ms: 10.5,
            p95_ms: 12.25,
            max_ms: 13.0,
            isolation_rate: 0.0,
            recovery_rounds: 2,
            adapt: Some(crate::simtime::AdaptMetrics {
                policy: "warm".into(),
                replans: 3,
                fallbacks: 1,
                evals_spent: 96,
                freeze_rounds: 12,
            }),
        });
        let mut adaptive = fp(None);
        adaptive.scenario = Some(0x1234);
        adaptive.adapt = Some(0xfeed_f00d_cafe_0042);
        // The adapt hash extends the key after the scenario suffix.
        assert_eq!(
            cell_key(&adaptive),
            "cell/ring/gaia/femnist/t5/r60/s-/sc0000000000001234/adfeedf00dcafe0042"
        );
        let store = CellStore::open(&dir).unwrap();
        store
            .put_cell(&adaptive, &cell.to_summary("gaia", "femnist", 60), &cell.stats)
            .unwrap();
        // Bit-exact roundtrip, adapt counters included; the policy-none
        // (scenario-only) and static twins still miss.
        assert_eq!(store.get_cell(&adaptive).unwrap(), Some(cell.clone()));
        let mut churn_twin = fp(None);
        churn_twin.scenario = Some(0x1234);
        assert_eq!(store.get_cell(&churn_twin).unwrap(), None);
        assert_eq!(store.get_cell(&fp(None)).unwrap(), None);
        // Populate the other namespaces and check the stats breakdown.
        let mut plain = sample_cell();
        plain.scenario = None;
        store.put_cell(&fp(None), &plain.to_summary("gaia", "femnist", 60), &plain.stats).unwrap();
        let mut churned = sample_cell();
        churned.scenario = cell.scenario.clone();
        churned.scenario.as_mut().unwrap().adapt = None;
        store
            .put_cell(&churn_twin, &churned.to_summary("gaia", "femnist", 60), &churned.stats)
            .unwrap();
        store.put_fitness("fit/x", 1.5).unwrap();
        let stats = store.stats().unwrap();
        assert_eq!(stats.entries, 4);
        assert_eq!(stats.adaptive_cells, 1);
        assert_eq!(stats.scenario_cells, 1);
        assert_eq!(stats.static_cells, 1);
        assert_eq!(stats.other_entries, 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn roundtrip_survives_reopen_and_last_record_wins() {
        let dir = tmpdir("roundtrip");
        let cell = sample_cell();
        {
            let store = CellStore::open(&dir).unwrap();
            assert_eq!(store.get_cell(&fp(None)).unwrap(), None);
            store
                .put_cell(&fp(None), &cell.to_summary("gaia", "femnist", 60), &cell.stats)
                .unwrap();
            store.put_fitness("fit/x", 1.5).unwrap();
            store.put_fitness("fit/x", 2.5).unwrap();
        }
        let store = CellStore::open(&dir).unwrap();
        assert_eq!(store.get_cell(&fp(None)).unwrap(), Some(cell));
        assert_eq!(store.get_fitness("fit/x").unwrap(), Some(2.5));
        let stats = store.stats().unwrap();
        assert_eq!(stats.entries, 2);
        assert_eq!(stats.records, 3);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn batched_label_is_normalized_on_put() {
        let dir = tmpdir("normalize");
        let store = CellStore::open(&dir).unwrap();
        let cell = sample_cell();
        let batched = EngineStats { kind: EngineKind::Batched, ..cell.stats };
        store
            .put_cell(&fp(None), &cell.to_summary("gaia", "femnist", 60), &batched)
            .unwrap();
        let got = store.get_cell(&fp(None)).unwrap().unwrap();
        assert_eq!(got.stats.kind, EngineKind::Periodic);
        assert_eq!(got.stats, cell.stats);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn epoch_generations_are_invisible_to_each_other() {
        let dir = tmpdir("epoch");
        {
            let store = CellStore::open_with_epoch(&dir, 1).unwrap();
            store.put_fitness("fit/a", 1.0).unwrap();
        }
        {
            let store = CellStore::open_with_epoch(&dir, 2).unwrap();
            assert_eq!(store.get_fitness("fit/a").unwrap(), None);
            store.put_fitness("fit/a", 9.0).unwrap();
        }
        let store = CellStore::open_with_epoch(&dir, 1).unwrap();
        assert_eq!(store.get_fitness("fit/a").unwrap(), Some(1.0));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_drops_stale_generations_and_compacts_current() {
        let dir = tmpdir("gc");
        {
            let old = CellStore::open_with_epoch(&dir, 1).unwrap();
            old.put_fitness("fit/old", 1.0).unwrap();
            let cur = CellStore::open_with_epoch(&dir, 2).unwrap();
            cur.put_fitness("fit/a", 1.0).unwrap();
            cur.put_fitness("fit/a", 2.0).unwrap();
            cur.put_fitness("fit/b", 3.0).unwrap();
        }
        let report = gc_with_epoch(&dir, 2).unwrap();
        assert_eq!(report.removed_files, 1);
        assert_eq!(report.records_before, 3);
        assert_eq!(report.records_after, 2);
        assert!(report.bytes_after < report.bytes_before);
        let store = CellStore::open_with_epoch(&dir, 2).unwrap();
        assert_eq!(store.get_fitness("fit/a").unwrap(), Some(2.0));
        assert_eq!(store.get_fitness("fit/b").unwrap(), Some(3.0));
        assert_eq!(
            CellStore::open_with_epoch(&dir, 1).unwrap().get_fitness("fit/old").unwrap(),
            None,
            "stale generation deleted"
        );
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn verify_reports_clean_stores_clean() {
        let dir = tmpdir("verify");
        let store = CellStore::open(&dir).unwrap();
        store.put_fitness("fit/a", 1.0).unwrap();
        store.put_fitness("fit/b", 2.0).unwrap();
        let report = verify(&dir).unwrap();
        assert!(report.ok());
        assert_eq!(report.records, 2);
        assert_eq!(report.torn_tails, 0);
        fs::remove_dir_all(&dir).unwrap();
    }
}
