//! `mgfl serve` — a minimal, dependency-free HTTP/JSON front end over a
//! shared [`CellStore`].
//!
//! The server exists so a warm store can amortize across *processes*:
//! a long-lived `mgfl serve` holds one [`CellStore`] open and answers
//! sweep requests over plain HTTP, serving every previously-simulated
//! cell from the log and simulating (then persisting) only the misses.
//! It is deliberately tiny — `std::net::TcpListener`, one thread per
//! connection, `Connection: close` — because it is an operational
//! convenience, not a product server.
//!
//! ## Routes
//!
//! * `GET /health` — liveness: `{"ok":true}`.
//! * `GET /stats` — store shape: entry/record/byte counts plus the
//!   engine epoch, same numbers as `mgfl cache stats`.
//! * `POST /sweep` — body is a JSON object with any subset of
//!   `name`, `rounds`, `topologies`, `networks`, `profiles`, `t`,
//!   `seeds` (absent axes take [`SweepSpec::default`]; the string
//!   `"all"` sugar works as in TOML specs). The response body is
//!   NDJSON: a header line, one line per cell (byte-identical to the
//!   cell objects in `sweep_<name>.json`), and a trailer with the
//!   store hit/miss accounting.
//!
//! Malformed requests get `400` with `{"error": ...}`, unknown routes
//! `404`, and a sweep that fails mid-run `500`. Request bodies are
//! capped at 1 MiB and reads time out, so a stuck client cannot pin a
//! handler thread forever.
//!
//! ## Robustness
//!
//! The accept loop never dies with a connection: a client that
//! disconnects mid-NDJSON (or mid-request) fails only its own handler
//! thread. Shutdown is graceful — [`Server::shutdown_handle`] (or a
//! SIGINT/SIGTERM after [`install_signal_handlers`]) stops accepting,
//! drains every in-flight handler, then returns from [`Server::run`],
//! so the store log is never abandoned mid-append.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::CellStore;
use crate::sweep::{self, RunOptions, SweepSpec};
use crate::util::Json;

/// Largest accepted request body. Sweep specs are a few hundred bytes;
/// anything near this limit is a client bug, not a bigger spec.
const MAX_BODY: usize = 1 << 20;

/// Per-connection socket timeout (read and write).
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// How often the accept loop polls the shutdown flags while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Process-wide signal flag: flipped by the handler that
/// [`install_signal_handlers`] registers, polled by every accept loop
/// alongside its per-server [`Server::shutdown_handle`].
static SIGNALED: AtomicBool = AtomicBool::new(false);

/// Route SIGINT/SIGTERM to a graceful stop: the handler only flips an
/// atomic (async-signal-safe), and every running [`Server`] notices on
/// its next poll, drains in-flight connections, and returns. Opt-in —
/// `mgfl serve` calls this; embedding tests use [`Server::shutdown_handle`]
/// instead so they never mutate process-global signal state.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    {
        extern "C" fn on_signal(_sig: libc::c_int) {
            SIGNALED.store(true, Ordering::SeqCst);
        }
        unsafe {
            libc::signal(libc::SIGINT, on_signal as libc::sighandler_t);
            libc::signal(libc::SIGTERM, on_signal as libc::sighandler_t);
        }
    }
}

/// A bound-but-not-yet-serving store server. [`Server::run`] consumes
/// it and loops until shut down; tests bind to port 0 and read the
/// resolved address with [`Server::local_addr`] before spawning `run`
/// on a thread.
pub struct Server {
    listener: TcpListener,
    store: Arc<CellStore>,
    threads: usize,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:7700`; port 0 picks a free port).
    /// `threads` is forwarded to each sweep's [`RunOptions`].
    pub fn bind(addr: &str, store: Arc<CellStore>, threads: usize) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve address {addr}"))?;
        Ok(Server { listener, store, threads, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    /// The resolved listen address.
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Per-server stop switch: store `true` and [`Server::run`] exits
    /// after draining in-flight connections.
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Accept loop: one handler thread per connection, until the
    /// shutdown handle (or a routed signal) flips. Accept errors
    /// (transient, e.g. fd pressure) are reported and survived; handler
    /// errors — including a client that hangs up mid-NDJSON — are
    /// contained to their connection. On shutdown, every in-flight
    /// handler is joined before returning, so responses and store
    /// appends already underway complete.
    pub fn run(self) -> Result<()> {
        self.listener.set_nonblocking(true).context("making serve listener pollable")?;
        let mut inflight: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.shutdown.load(Ordering::SeqCst) && !SIGNALED.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // The listener is nonblocking for polling only;
                    // handlers want plain blocking reads with timeouts.
                    if let Err(e) = stream.set_nonblocking(false) {
                        eprintln!("warning: serve accept failed: {e}");
                        continue;
                    }
                    let store = Arc::clone(&self.store);
                    let threads = self.threads;
                    inflight.push(std::thread::spawn(move || {
                        if let Err(e) = handle_connection(stream, &store, threads) {
                            eprintln!("warning: serve connection failed: {e:#}");
                        }
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => {
                    eprintln!("warning: serve accept failed: {e}");
                    std::thread::sleep(ACCEPT_POLL);
                }
            }
            inflight.retain(|h| !h.is_finished());
        }
        // Graceful drain: finish what was accepted before stopping.
        for h in inflight {
            let _ = h.join();
        }
        Ok(())
    }
}

/// One parsed HTTP request — exactly the subset the routes consume.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Request {
    method: String,
    path: String,
    body: String,
}

/// One response, ready to serialize. `body` is already encoded; the
/// NDJSON sweep response and the JSON error objects both go through
/// this.
struct Response {
    status: u16,
    content_type: &'static str,
    body: String,
}

impl Response {
    fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body }
    }

    fn error(status: u16, msg: &str) -> Response {
        let mut obj = BTreeMap::new();
        obj.insert("error".to_string(), Json::Str(msg.to_string()));
        Response::json(status, format!("{}\n", Json::Obj(obj)))
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

fn handle_connection(stream: TcpStream, store: &CellStore, threads: usize) -> Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let resp = match parse_request(&mut reader) {
        Ok(req) => respond(store, threads, &req),
        Err(msg) => Response::error(400, &msg),
    };
    write_response(stream, &resp)
}

fn write_response(mut stream: TcpStream, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Parse one HTTP/1.1 request from `reader`: request line, headers
/// (only `Content-Length` is consumed), then exactly the declared body.
/// Errors are client-facing strings (they become the `400` payload).
fn parse_request<R: BufRead>(reader: &mut R) -> std::result::Result<Request, String> {
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| format!("reading request line: {e}"))?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_string();
    let path = parts.next().ok_or("request line missing path")?.to_string();
    let version = parts.next().ok_or("request line missing HTTP version")?;
    if !version.starts_with("HTTP/1.") {
        return Err(format!("unsupported protocol '{version}'"));
    }

    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).map_err(|e| format!("reading header: {e}"))?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        let Some((name, value)) = header.split_once(':') else {
            return Err(format!("malformed header '{header}'"));
        };
        if name.trim().eq_ignore_ascii_case("content-length") {
            content_length = value
                .trim()
                .parse()
                .map_err(|_| format!("bad Content-Length '{}'", value.trim()))?;
            if content_length > MAX_BODY {
                return Err(format!("body of {content_length} bytes exceeds the 1 MiB cap"));
            }
        }
    }

    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| format!("reading {content_length}-byte body: {e}"))?;
    let body = String::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    Ok(Request { method, path, body })
}

/// Route dispatch. Every arm returns a complete [`Response`]; nothing
/// here touches the socket.
fn respond(store: &CellStore, threads: usize, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => Response::json(200, "{\"ok\":true}\n".to_string()),
        ("GET", "/stats") => match stats_json(store) {
            Ok(body) => Response::json(200, body),
            Err(e) => Response::error(500, &format!("{e:#}")),
        },
        ("POST", "/sweep") => match spec_from_json(&req.body) {
            Ok(spec) => match run_sweep(store, threads, &spec) {
                Ok(body) => Response::json(200, body),
                Err(e) => Response::error(500, &format!("{e:#}")),
            },
            Err(msg) => Response::error(400, &msg),
        },
        _ => Response::error(404, &format!("no route for {} {}", req.method, req.path)),
    }
}

fn stats_json(store: &CellStore) -> Result<String> {
    let s = store.stats()?;
    let mut obj = BTreeMap::new();
    obj.insert("epoch".to_string(), Json::Num(store.epoch() as f64));
    obj.insert("shard_files".to_string(), Json::Num(s.shard_files as f64));
    obj.insert("entries".to_string(), Json::Num(s.entries as f64));
    obj.insert("records".to_string(), Json::Num(s.records as f64));
    obj.insert("bytes".to_string(), Json::Num(s.bytes as f64));
    Ok(format!("{}\n", Json::Obj(obj)))
}

/// Build a [`SweepSpec`] from the `POST /sweep` JSON body: defaults
/// from [`SweepSpec::default`], each present key overriding one axis,
/// then the same canonicalize + validate gauntlet the TOML loader runs.
fn spec_from_json(body: &str) -> std::result::Result<SweepSpec, String> {
    let json = Json::parse(body).map_err(|e| format!("body is not valid JSON: {e:#}"))?;
    let obj = json.as_obj().map_err(|_| "body must be a JSON object".to_string())?;
    let mut spec = SweepSpec::default();
    for (key, value) in obj {
        match key.as_str() {
            "name" => {
                spec.name =
                    value.as_str().map_err(|_| "'name' must be a string".to_string())?.to_string();
            }
            "rounds" => {
                spec.rounds = value
                    .as_usize()
                    .map_err(|_| "'rounds' must be a non-negative integer".to_string())?;
            }
            "topologies" => {
                let items = string_axis(value, "topologies")?;
                spec.topologies = SweepSpec::parse_topologies(&items)
                    .map_err(|e| format!("'topologies': {e:#}"))?;
            }
            "networks" => {
                let full = spec.networks.clone();
                spec.networks = SweepSpec::axis_or_all(string_axis(value, "networks")?, &full);
            }
            "profiles" => {
                let full = spec.profiles.clone();
                spec.profiles = SweepSpec::axis_or_all(string_axis(value, "profiles")?, &full);
            }
            "t" => {
                spec.t_values = num_axis(value, "t")?.into_iter().map(|n| n as u32).collect();
            }
            "seeds" => {
                spec.seeds = num_axis(value, "seeds")?;
            }
            other => return Err(format!("unknown sweep key '{other}'")),
        }
    }
    spec.canonicalize().map_err(|e| format!("{e:#}"))?;
    spec.validate().map_err(|e| format!("{e:#}"))?;
    Ok(spec)
}

fn string_axis(value: &Json, key: &str) -> std::result::Result<Vec<String>, String> {
    let arr = value.as_arr().map_err(|_| format!("'{key}' must be an array of strings"))?;
    arr.iter()
        .map(|v| {
            v.as_str()
                .map(str::to_string)
                .map_err(|_| format!("'{key}' must be an array of strings"))
        })
        .collect()
}

fn num_axis(value: &Json, key: &str) -> std::result::Result<Vec<u64>, String> {
    let arr = value.as_arr().map_err(|_| format!("'{key}' must be an array of integers"))?;
    arr.iter()
        .map(|v| {
            let n = v.as_f64().map_err(|_| format!("'{key}' must be an array of integers"))?;
            if n < 0.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
                return Err(format!("'{key}' entries must be non-negative integers"));
            }
            Ok(n as u64)
        })
        .collect()
}

/// Run one sweep read-through against the server's store and render the
/// NDJSON body: header line, one line per cell (byte-identical to the
/// artifact cells), accounting trailer.
fn run_sweep(store: &CellStore, threads: usize, spec: &SweepSpec) -> Result<String> {
    let opts = RunOptions { threads, progress: false, dedup: true };
    let outcome = sweep::run_with_store(spec, &opts, Some(store))?;
    let mut body = String::new();
    let mut header = BTreeMap::new();
    header.insert("name".to_string(), Json::Str(outcome.report.name.clone()));
    header.insert("rounds".to_string(), Json::Num(spec.rounds as f64));
    header.insert("cells".to_string(), Json::Num(outcome.report.cells.len() as f64));
    body.push_str(&format!("{}\n", Json::Obj(header)));
    for cell in &outcome.report.cells {
        body.push_str(&format!("{}\n", cell.to_json()));
    }
    let mut trailer = BTreeMap::new();
    trailer.insert("done".to_string(), Json::Bool(true));
    trailer.insert("store_hits".to_string(), Json::Num(outcome.store_hits as f64));
    trailer.insert("store_misses".to_string(), Json::Num(outcome.store_misses as f64));
    trailer.insert("unique_cells".to_string(), Json::Num(outcome.unique_cells as f64));
    body.push_str(&format!("{}\n", Json::Obj(trailer)));
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(text: &str) -> std::result::Result<Request, String> {
        parse_request(&mut Cursor::new(text.as_bytes().to_vec()))
    }

    #[test]
    fn requests_parse_and_malformed_ones_do_not() {
        let r = req("GET /health HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/health");
        assert_eq!(r.body, "");

        let r = req("POST /sweep HTTP/1.1\r\nContent-Length: 4\r\n\r\n{}\n!").unwrap();
        assert_eq!(r.body, "{}\n!");

        assert!(req("\r\n\r\n").is_err(), "empty request line");
        assert!(req("GET /x\r\n\r\n").is_err(), "missing version");
        assert!(req("GET /x SPDY/9\r\n\r\n").is_err(), "bad protocol");
        assert!(req("GET /x HTTP/1.1\r\nnocolon\r\n\r\n").is_err(), "malformed header");
        assert!(
            req("POST /x HTTP/1.1\r\nContent-Length: nine\r\n\r\n").is_err(),
            "bad content length"
        );
        assert!(
            req("POST /x HTTP/1.1\r\nContent-Length: 2097152\r\n\r\n").is_err(),
            "body over the cap"
        );
        assert!(
            req("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort").is_err(),
            "truncated body"
        );
    }

    #[test]
    fn sweep_specs_build_from_json_with_defaults() {
        let spec = spec_from_json(
            r#"{"name":"mini","rounds":40,"topologies":["ring","ours"],
                "networks":["gaia"],"profiles":["femnist"],"t":[3,5],"seeds":[11]}"#,
        )
        .unwrap();
        assert_eq!(spec.name, "mini");
        assert_eq!(spec.rounds, 40);
        assert_eq!(spec.topologies.len(), 2);
        assert_eq!(spec.networks, ["gaia"]);
        assert_eq!(spec.profiles, ["femnist"]);
        assert_eq!(spec.t_values, [3, 5]);
        assert_eq!(spec.seeds, [11]);

        // Absent keys keep the defaults; "all" sugar expands.
        let dflt = SweepSpec::default();
        let spec = spec_from_json(r#"{"networks":["all"],"rounds":8}"#).unwrap();
        assert_eq!(spec.networks, dflt.networks);
        assert_eq!(spec.topologies, dflt.topologies);
        assert_eq!(spec.rounds, 8);

        assert!(spec_from_json("not json").is_err());
        assert!(spec_from_json("[1,2]").is_err(), "must be an object");
        assert!(spec_from_json(r#"{"bogus":1}"#).is_err(), "unknown key");
        assert!(spec_from_json(r#"{"networks":["atlantis"]}"#).is_err(), "unknown network");
        assert!(spec_from_json(r#"{"seeds":[-1]}"#).is_err(), "negative seed");
        assert!(spec_from_json(r#"{"rounds":0}"#).is_err(), "validate runs");
    }

    #[test]
    fn serve_answers_health_stats_and_warm_sweeps_end_to_end() {
        let dir = std::env::temp_dir().join(format!("mgfl_serve_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(CellStore::open(&dir).unwrap());
        let server = Server::bind("127.0.0.1:0", Arc::clone(&store), 1).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.shutdown_handle();
        let served = std::thread::spawn(move || server.run());

        let get =
            |path: &str| roundtrip(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"));
        let health = get("/health");
        assert!(health.starts_with("HTTP/1.1 200"), "{health}");
        assert!(health.contains("{\"ok\":true}"), "{health}");
        let stats = get("/stats");
        assert!(stats.starts_with("HTTP/1.1 200"), "{stats}");
        assert!(stats.contains("\"entries\":0"), "{stats}");
        assert!(get("/nope").starts_with("HTTP/1.1 404"));

        let body = r#"{"name":"mini","rounds":40,"topologies":["ring","ours"],
                       "networks":["gaia"],"profiles":["femnist"],"t":[3],"seeds":[11]}"#;
        let post = format!(
            "POST /sweep HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let cold = roundtrip(addr, &post);
        assert!(cold.starts_with("HTTP/1.1 200"), "{cold}");
        assert!(cold.contains("\"store_misses\":2"), "{cold}");
        let warm = roundtrip(addr, &post);
        assert!(warm.contains("\"store_hits\":2"), "{warm}");
        assert!(warm.contains("\"store_misses\":0"), "{warm}");
        // The cell lines themselves must be byte-identical warm vs cold.
        assert_eq!(body_of(&cold), body_of(&warm));

        let bad = "POST /sweep HTTP/1.1\r\nHost: t\r\nContent-Length: 7\r\n\r\nnotjson";
        assert!(roundtrip(addr, bad).starts_with("HTTP/1.1 400"));

        stop.store(true, Ordering::SeqCst);
        served.join().unwrap().expect("graceful shutdown returns Ok");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn early_closing_clients_do_not_kill_the_accept_loop() {
        let dir =
            std::env::temp_dir().join(format!("mgfl_serve_disco_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(CellStore::open(&dir).unwrap());
        let server = Server::bind("127.0.0.1:0", Arc::clone(&store), 1).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.shutdown_handle();
        let served = std::thread::spawn(move || server.run());

        let body = r#"{"name":"disco","rounds":20,"topologies":["ring"],
                       "networks":["gaia"],"profiles":["femnist"],"t":[3],"seeds":[1]}"#;
        let post = format!(
            "POST /sweep HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        // Client 1: sends a full sweep request and hangs up without
        // reading a byte of the NDJSON response.
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(post.as_bytes()).unwrap();
        }
        // Client 2: hangs up mid-request (headers only, missing body).
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"POST /sweep HTTP/1.1\r\nContent-Length: 400\r\n\r\n{\"na").unwrap();
        }
        // Client 3: reads part of the response, then disconnects.
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(post.as_bytes()).unwrap();
            let mut first = [0u8; 16];
            let _ = s.read(&mut first);
        }
        // The accept loop must have survived all three: a well-behaved
        // client still gets a complete answer.
        let health = roundtrip(addr, "GET /health HTTP/1.1\r\nHost: t\r\n\r\n");
        assert!(health.starts_with("HTTP/1.1 200"), "{health}");
        let full = roundtrip(addr, &post);
        assert!(full.starts_with("HTTP/1.1 200"), "{full}");
        assert!(full.contains("\"done\":true"), "{full}");

        // Shutdown drains the (possibly still-running) handler threads.
        stop.store(true, Ordering::SeqCst);
        served.join().unwrap().expect("graceful shutdown returns Ok");
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn roundtrip(addr: SocketAddr, request: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    fn body_of(response: &str) -> &str {
        response.split_once("\r\n\r\n").expect("header/body split").1
    }
}
