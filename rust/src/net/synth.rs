//! Synthetic silo networks for large-N scaling studies.
//!
//! The paper zoo tops out at 87 silos (Ebone); the ROADMAP's
//! production-scale north star needs networks orders of magnitude
//! larger. This module generates them deterministically from a
//! `(variant, n, seed)` triple, addressable *by name* everywhere a zoo
//! network is — sweep specs, `ExperimentConfig`, the CLI — via
//! [`crate::net::by_name`]:
//!
//! ```text
//! synth-geo-n1024-s7      geo-clustered, 1024 silos, seed 7
//! synth-sphere-n256-s17   uniform-sphere, 256 silos, seed 17
//! ```
//!
//! Two variants:
//!
//! * **`geo`** — geo-clustered: ~√n metro centers in the populated
//!   latitude band, silos jittered tens of km around them, with a
//!   Pareto-ish symmetric access-capacity spread (10–100 Gbps). This
//!   reproduces the ISP-PoP clustering that drives the paper's
//!   d(i,j)/d_min ratios (and so the multigraph's isolated states) at
//!   any scale, plus the heterogeneous access links real cross-silo
//!   deployments have.
//! * **`sphere`** — uniform on the sphere with the paper's uniform
//!   10 Gbps links: a structure-free control where every delay is pure
//!   geography.
//!
//! Determinism contract: the same name yields a byte-identical
//! [`NetworkSpec`] (names, coordinate bits, capacity bits) in every
//! process — generation draws from a [`Rng64`] stream derived from the
//! seed and the variant tag, never from global state. Pinned by
//! `tests/synth_scale.rs`.

use super::spec::{NetworkSpec, Silo};
use crate::util::rng::{derive_stream, fnv1a};
use crate::util::Rng64;

/// Smallest synthesizable network (overlay builders need 2 nodes).
pub const MIN_SYNTH_N: usize = 2;
/// Largest synthesizable network: 65 536 silos keeps the dense
/// connectivity slab (~17 GB of f64 at the cap) an explicit opt-in
/// rather than a typo.
pub const MAX_SYNTH_N: usize = 1 << 16;

/// Pareto shape for the geo variant's capacity spread (heavier head,
/// occasional fat links — capped at 10x the 10 Gbps floor).
const CAPACITY_ALPHA: f64 = 2.5;
const CAPACITY_FLOOR_GBPS: f64 = 10.0;
const CAPACITY_CAP_GBPS: f64 = 100.0;

/// Which generator a synthetic name selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthVariant {
    Geo,
    Sphere,
}

impl SynthVariant {
    pub fn as_str(&self) -> &'static str {
        match self {
            SynthVariant::Geo => "geo",
            SynthVariant::Sphere => "sphere",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "geo" => Some(SynthVariant::Geo),
            "sphere" => Some(SynthVariant::Sphere),
            _ => None,
        }
    }

    pub fn all() -> [SynthVariant; 2] {
        [SynthVariant::Geo, SynthVariant::Sphere]
    }
}

/// The canonical name of a synthetic network — what [`by_name`] parses
/// and what the generated [`NetworkSpec::name`] carries, so sweep-spec
/// canonicalization is a fixed point.
pub fn name_of(variant: SynthVariant, n: usize, seed: u64) -> String {
    format!("synth-{}-n{n}-s{seed}", variant.as_str())
}

/// Resolve a `synth-<variant>-n<N>-s<seed>` name (case-insensitive).
/// Returns `None` for non-synthetic names, unknown variants, or N
/// outside [[`MIN_SYNTH_N`], [`MAX_SYNTH_N`]] — the caller falls back
/// to its own error path, mirroring [`super::zoo::by_name`].
pub fn by_name(name: &str) -> Option<NetworkSpec> {
    let lower = name.to_ascii_lowercase();
    let rest = lower.strip_prefix("synth-")?;
    let (variant_s, rest) = rest.split_once("-n")?;
    let (n_s, seed_s) = rest.split_once("-s")?;
    let variant = SynthVariant::parse(variant_s)?;
    let n: usize = n_s.parse().ok()?;
    let seed: u64 = seed_s.parse().ok()?;
    if !(MIN_SYNTH_N..=MAX_SYNTH_N).contains(&n) {
        return None;
    }
    Some(generate(variant, n, seed))
}

/// Generate a synthetic network. Deterministic in `(variant, n, seed)`.
pub fn generate(variant: SynthVariant, n: usize, seed: u64) -> NetworkSpec {
    assert!(
        (MIN_SYNTH_N..=MAX_SYNTH_N).contains(&n),
        "synthetic networks support {MIN_SYNTH_N}..={MAX_SYNTH_N} silos (got {n})"
    );
    match variant {
        SynthVariant::Geo => geo_clustered(n, seed),
        SynthVariant::Sphere => uniform_sphere(n, seed),
    }
}

/// Geo-clustered variant: metro centers, clustered PoPs, Pareto-ish
/// capacities. See the module docs.
pub fn geo_clustered(n: usize, seed: u64) -> NetworkSpec {
    let mut rng = Rng64::seed_from_u64(derive_stream(seed, fnv1a(b"synth-geo")));
    // ~√n metros keeps cluster sizes scale-free: intra-metro pairs stay
    // sub-ms while cross-metro pairs span continents, whatever n is.
    let clusters = ((n as f64).sqrt().ceil() as usize).clamp(2, n);
    let centers: Vec<(f64, f64)> = (0..clusters)
        .map(|_| {
            // Populated-latitude band (matches the zoo's coordinate
            // envelope); full longitude range.
            let lat = -55.0 + 120.0 * rng.gen_f64();
            let lon = -180.0 + 360.0 * rng.gen_f64();
            (lat, lon)
        })
        .collect();
    let mut silos = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.gen_range(0, clusters);
        let (clat, clon) = centers[c];
        // ~0.35° jitter ≈ tens of km: intra-metro link latency lands at
        // the sub-ms floor, exactly the property that makes d_min small
        // on Exodus/Ebone (zoo.rs) and generates isolated states.
        let lat = (clat + 0.35 * rng.gen_normal()).clamp(-89.0, 89.0);
        let lon = wrap_lon(clon + 0.35 * rng.gen_normal());
        let cap = pareto_capacity(&mut rng);
        silos.push(Silo::with_capacity(&format!("geo{i}_c{c}"), lat, lon, cap));
    }
    NetworkSpec { name: name_of(SynthVariant::Geo, n, seed), silos }
}

/// Uniform-sphere variant: area-uniform points, uniform 10 Gbps links.
pub fn uniform_sphere(n: usize, seed: u64) -> NetworkSpec {
    let mut rng = Rng64::seed_from_u64(derive_stream(seed, fnv1a(b"synth-sphere")));
    let mut silos = Vec::with_capacity(n);
    for i in 0..n {
        // Uniform on the sphere: z = sin(lat) uniform in [-1, 1).
        let z = 2.0 * rng.gen_f64() - 1.0;
        let lat = z.asin().to_degrees().clamp(-89.0, 89.0);
        let lon = -180.0 + 360.0 * rng.gen_f64();
        silos.push(Silo::new(&format!("sph{i}"), lat, lon));
    }
    NetworkSpec { name: name_of(SynthVariant::Sphere, n, seed), silos }
}

/// Symmetric access capacity with a Pareto(α) tail over the 10 Gbps
/// paper floor, capped at 100 Gbps. Always positive and finite.
fn pareto_capacity(rng: &mut Rng64) -> f64 {
    // 1 - gen_f64() ∈ (0, 1]: u = 1 maps to the floor, u → 0 to the cap.
    let u = 1.0 - rng.gen_f64();
    (CAPACITY_FLOOR_GBPS * u.powf(-1.0 / CAPACITY_ALPHA)).min(CAPACITY_CAP_GBPS)
}

/// Wrap a longitude into [-180, 180).
fn wrap_lon(lon: f64) -> f64 {
    (lon + 180.0).rem_euclid(360.0) - 180.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip_and_resolve() {
        for variant in SynthVariant::all() {
            let name = name_of(variant, 64, 7);
            let net = by_name(&name).expect("canonical name resolves");
            assert_eq!(net.name, name, "generated name is the canonical name");
            assert_eq!(net.n(), 64);
            // Case-insensitive, like zoo::by_name.
            assert_eq!(by_name(&name.to_ascii_uppercase()).unwrap().name, name);
        }
        assert_eq!(SynthVariant::parse("geo"), Some(SynthVariant::Geo));
        assert_eq!(SynthVariant::parse("SPHERE"), Some(SynthVariant::Sphere));
        assert!(SynthVariant::parse("torus").is_none());
    }

    #[test]
    fn rejects_malformed_names_and_bad_sizes() {
        for bad in [
            "gaia",
            "synth",
            "synth-geo",
            "synth-geo-n64",
            "synth-torus-n64-s1",
            "synth-geo-nxx-s1",
            "synth-geo-n64-sxx",
            "synth-geo-n1-s1",   // below MIN_SYNTH_N
            "synth-geo-n0-s1",
            "synth-geo-n99999999-s1", // above MAX_SYNTH_N
        ] {
            assert!(by_name(bad).is_none(), "{bad} must not resolve");
        }
    }

    #[test]
    fn coordinates_and_capacities_are_plausible() {
        for variant in SynthVariant::all() {
            let net = generate(variant, 128, 3);
            assert_eq!(net.n(), 128);
            let names: std::collections::BTreeSet<_> =
                net.silos.iter().map(|s| s.name.as_str()).collect();
            assert_eq!(names.len(), 128, "silo names must be unique");
            for s in &net.silos {
                assert!((-90.0..=90.0).contains(&s.lat), "{}: lat {}", s.name, s.lat);
                assert!((-180.0..180.0 + 1e-9).contains(&s.lon), "{}: lon {}", s.name, s.lon);
                assert_eq!(s.up_gbps.to_bits(), s.dn_gbps.to_bits(), "symmetric capacity");
                assert!(s.up_gbps >= CAPACITY_FLOOR_GBPS - 1e-12);
                assert!(s.up_gbps <= CAPACITY_CAP_GBPS + 1e-12);
            }
        }
    }

    #[test]
    fn geo_variant_has_metro_clustering_and_capacity_spread() {
        let net = geo_clustered(96, 11);
        let mut min = f64::MAX;
        let mut max: f64 = 0.0;
        for i in 0..net.n() {
            for j in (i + 1)..net.n() {
                let l = net.latency_ms(i, j);
                min = min.min(l);
                max = max.max(l);
            }
        }
        assert!(min < 1.0, "expected sub-ms intra-metro latency, got {min}");
        assert!(max / min > 20.0, "expected wide delay spread, got {max}/{min}");
        // Pareto spread: not every capacity equals the floor.
        let caps: std::collections::BTreeSet<u64> =
            net.silos.iter().map(|s| s.up_gbps.to_bits()).collect();
        assert!(caps.len() > 10, "expected a capacity spread, got {} distinct", caps.len());
    }

    #[test]
    fn wrap_lon_stays_in_range() {
        for lon in [-541.0, -180.0, -179.9, 0.0, 179.9, 180.0, 541.0] {
            let w = wrap_lon(lon);
            assert!((-180.0..180.0).contains(&w), "{lon} -> {w}");
        }
        assert_eq!(wrap_lon(0.0), 0.0);
        assert_eq!(wrap_lon(360.0), 0.0);
    }
}
