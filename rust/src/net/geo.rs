//! Geographic latency model: haversine distance → propagation delay.
//!
//! Silo-to-silo link latency is modelled as light-in-fiber propagation
//! over the great-circle distance plus a fixed per-link processing
//! overhead — the standard model for geo-distributed testbeds (Gaia,
//! NSDI'17 uses the same construction for its synthetic networks).

/// Mean Earth radius, km.
pub const EARTH_RADIUS_KM: f64 = 6371.0;
/// Speed of light in fiber, km/s (~2/3 c).
pub const FIBER_KM_PER_S: f64 = 200_000.0;
/// Fixed per-link overhead (routing/serialization), ms.
pub const LINK_OVERHEAD_MS: f64 = 0.3;
/// Fiber paths are not great circles; typical route-stretch factor.
pub const ROUTE_STRETCH: f64 = 1.4;

/// Great-circle distance between two (lat, lon) points in degrees, km.
pub fn haversine_km(lat1: f64, lon1: f64, lat2: f64, lon2: f64) -> f64 {
    let (la1, lo1, la2, lo2) = (
        lat1.to_radians(),
        lon1.to_radians(),
        lat2.to_radians(),
        lon2.to_radians(),
    );
    let dlat = la2 - la1;
    let dlon = lo2 - lo1;
    let a = (dlat / 2.0).sin().powi(2) + la1.cos() * la2.cos() * (dlon / 2.0).sin().powi(2);
    2.0 * EARTH_RADIUS_KM * a.sqrt().atan2((1.0 - a).sqrt())
}

/// One-way link latency in milliseconds between two geo points.
pub fn link_latency_ms(lat1: f64, lon1: f64, lat2: f64, lon2: f64) -> f64 {
    let km = haversine_km(lat1, lon1, lat2, lon2) * ROUTE_STRETCH;
    km / FIBER_KM_PER_S * 1000.0 + LINK_OVERHEAD_MS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_distance_to_self() {
        assert!(haversine_km(48.85, 2.35, 48.85, 2.35) < 1e-9);
    }

    #[test]
    fn paris_to_nyc_about_5800km() {
        let d = haversine_km(48.8566, 2.3522, 40.7128, -74.0060);
        assert!((5500.0..6100.0).contains(&d), "{d}");
    }

    #[test]
    fn sydney_to_london_is_far() {
        let d = haversine_km(-33.87, 151.21, 51.51, -0.13);
        assert!((16500.0..17500.0).contains(&d), "{d}");
    }

    #[test]
    fn symmetric() {
        let a = haversine_km(1.0, 2.0, 50.0, -120.0);
        let b = haversine_km(50.0, -120.0, 1.0, 2.0);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn latency_has_floor_and_scales() {
        let near = link_latency_ms(37.0, -122.0, 37.1, -122.1);
        let far = link_latency_ms(37.0, -122.0, 51.5, -0.1);
        assert!(near >= LINK_OVERHEAD_MS);
        assert!(near < 1.0);
        // SF <-> London ~ 8600 km * 1.4 / 200k km/s ≈ 60 ms one-way.
        assert!((40.0..90.0).contains(&far), "{far}");
        assert!(far > near);
    }

    #[test]
    fn antipodal_bounded_by_half_circumference() {
        let d = haversine_km(0.0, 0.0, 0.0, 180.0);
        assert!((d - std::f64::consts::PI * EARTH_RADIUS_KM).abs() < 1.0);
    }
}
