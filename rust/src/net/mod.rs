//! Network substrate: geographic latency model, silo/network specs, the
//! five embedded evaluation networks (Gaia, Amazon, Géant, Exodus,
//! Ebone), and deterministic synthetic large-N networks.

pub mod geo;
pub mod spec;
pub mod synth;
pub mod zoo;

pub use spec::{DatasetProfile, LatencyMatrix, NetworkSpec, Silo};

/// The single network resolver behind the config layer, the sweep
/// engine, and the CLI: the five paper networks by zoo name
/// ([`zoo::by_name`]), plus parameterized synthetic networks by
/// `synth-<variant>-n<N>-s<seed>` name ([`synth::by_name`]). Both are
/// case-insensitive; the returned spec's `name` is the canonical
/// spelling (what sweep canonicalization rewrites axis values to).
pub fn by_name(name: &str) -> Option<NetworkSpec> {
    zoo::by_name(name).or_else(|| synth::by_name(name))
}

#[cfg(test)]
mod tests {
    #[test]
    fn resolver_covers_zoo_and_synth() {
        assert_eq!(super::by_name("gaia").unwrap().n(), 11);
        assert_eq!(super::by_name("synth-geo-n64-s7").unwrap().n(), 64);
        assert_eq!(super::by_name("SYNTH-SPHERE-N32-S1").unwrap().name, "synth-sphere-n32-s1");
        assert!(super::by_name("nowhere").is_none());
    }
}
