//! Network substrate: geographic latency model, silo/network specs, and
//! the five embedded evaluation networks (Gaia, Amazon, Géant, Exodus,
//! Ebone).

pub mod geo;
pub mod spec;
pub mod zoo;

pub use spec::{DatasetProfile, NetworkSpec, Silo};
