//! The five evaluation networks of the paper (§5.1): Gaia, Amazon,
//! Géant, Exodus, Ebone.
//!
//! Substitution note (DESIGN.md §Substitutions): the paper loads Exodus /
//! Ebone / Géant from the Internet Topology Zoo and builds Gaia / Amazon
//! from AWS data-center locations. The Zoo's GraphML files are not
//! redistributable here, so each network is embedded as its node set with
//! real city coordinates at the paper's silo counts (11 / 22 / 40 / 79 /
//! 87). Cycle-time behaviour depends on the *delay distribution* — geo
//! RTT plus uniform 10 Gbps access links — which real coordinates
//! reproduce. ISP PoP clustering (many PoPs per metro) is modelled by
//! multiple jittered nodes per metro, matching how Rocketfuel-derived
//! topologies concentrate in cities; that clustering is what makes
//! d_min small and drives the paper's isolated-node counts on
//! Exodus/Ebone.

use super::spec::{NetworkSpec, Silo};

fn net(name: &str, cities: &[(&str, f64, f64)]) -> NetworkSpec {
    NetworkSpec {
        name: name.to_string(),
        silos: cities.iter().map(|&(n, la, lo)| Silo::new(n, la, lo)).collect(),
    }
}

/// Gaia (Hsieh et al., NSDI'17): the 11 AWS regions of the Gaia paper.
pub fn gaia() -> NetworkSpec {
    net(
        "gaia",
        &[
            ("virginia", 38.95, -77.45),
            ("california", 37.35, -121.95),
            ("oregon", 45.60, -121.18),
            ("ireland", 53.34, -6.26),
            ("frankfurt", 50.11, 8.68),
            ("tokyo", 35.68, 139.69),
            ("seoul", 37.57, 126.98),
            ("singapore", 1.35, 103.82),
            ("sydney", -33.87, 151.21),
            ("mumbai", 19.08, 72.88),
            ("sao_paulo", -23.55, -46.63),
        ],
    )
}

/// Amazon: 22 AWS regions (paper's synthetic AWS network).
pub fn amazon() -> NetworkSpec {
    net(
        "amazon",
        &[
            ("virginia", 38.95, -77.45),
            ("ohio", 40.00, -83.00),
            ("california", 37.35, -121.95),
            ("oregon", 45.60, -121.18),
            ("canada", 45.50, -73.57),
            ("sao_paulo", -23.55, -46.63),
            ("ireland", 53.34, -6.26),
            ("london", 51.51, -0.13),
            ("paris", 48.86, 2.35),
            ("frankfurt", 50.11, 8.68),
            ("milan", 45.46, 9.19),
            ("stockholm", 59.33, 18.07),
            ("bahrain", 26.23, 50.59),
            ("cape_town", -33.92, 18.42),
            ("mumbai", 19.08, 72.88),
            ("singapore", 1.35, 103.82),
            ("jakarta", -6.21, 106.85),
            ("hong_kong", 22.32, 114.17),
            ("tokyo", 35.68, 139.69),
            ("osaka", 34.69, 135.50),
            ("seoul", 37.57, 126.98),
            ("sydney", -33.87, 151.21),
        ],
    )
}

/// Géant: the pan-European research network, 40 NREN PoP cities.
pub fn geant() -> NetworkSpec {
    net(
        "geant",
        &[
            ("amsterdam", 52.37, 4.90),
            ("athens", 37.98, 23.73),
            ("belgrade", 44.79, 20.45),
            ("bratislava", 48.15, 17.11),
            ("brussels", 50.85, 4.35),
            ("bucharest", 44.43, 26.10),
            ("budapest", 47.50, 19.04),
            ("copenhagen", 55.68, 12.57),
            ("dublin", 53.35, -6.26),
            ("frankfurt", 50.11, 8.68),
            ("geneva", 46.20, 6.14),
            ("hamburg", 53.55, 9.99),
            ("helsinki", 60.17, 24.94),
            ("istanbul", 41.01, 28.98),
            ("kyiv", 50.45, 30.52),
            ("lisbon", 38.72, -9.14),
            ("ljubljana", 46.06, 14.51),
            ("london", 51.51, -0.13),
            ("luxembourg", 49.61, 6.13),
            ("madrid", 40.42, -3.70),
            ("marseille", 43.30, 5.37),
            ("milan", 45.46, 9.19),
            ("nicosia", 35.19, 33.38),
            ("oslo", 59.91, 10.75),
            ("paris", 48.86, 2.35),
            ("porto", 41.15, -8.61),
            ("prague", 50.08, 14.44),
            ("riga", 56.95, 24.11),
            ("rome", 41.90, 12.50),
            ("sofia", 42.70, 23.32),
            ("stockholm", 59.33, 18.07),
            ("tallinn", 59.44, 24.75),
            ("thessaloniki", 40.64, 22.94),
            ("tirana", 41.33, 19.82),
            ("vienna", 48.21, 16.37),
            ("vilnius", 54.69, 25.28),
            ("warsaw", 52.23, 21.01),
            ("zagreb", 45.81, 15.98),
            ("zurich", 47.38, 8.54),
            ("turin", 45.07, 7.69),
        ],
    )
}

/// Metro bases for the Exodus ISP backbone (Rocketfuel AS-3967): a US
/// ISP with clustered PoPs plus a few international sites.
const EXODUS_METROS: &[(&str, f64, f64, usize)] = &[
    ("santa_clara", 37.35, -121.95, 9),
    ("palo_alto", 37.44, -122.14, 6),
    ("san_jose", 37.34, -121.89, 5),
    ("irvine", 33.68, -117.83, 5),
    ("el_segundo", 33.92, -118.40, 4),
    ("seattle", 47.61, -122.33, 5),
    ("chicago", 41.88, -87.63, 6),
    ("oak_brook", 41.84, -87.95, 3),
    ("austin", 30.27, -97.74, 4),
    ("dallas", 32.78, -96.80, 4),
    ("atlanta", 33.75, -84.39, 4),
    ("miami", 25.76, -80.19, 3),
    ("herndon", 38.97, -77.39, 6),
    ("jersey_city", 40.73, -74.08, 5),
    ("waltham", 42.38, -71.24, 4),
    ("toronto", 43.65, -79.38, 2),
    ("london_uk", 51.51, -0.13, 2),
    ("tokyo_jp", 35.68, 139.69, 2),
];

/// Metro bases for the Ebone ISP backbone (AS-1755): pan-European ISP.
const EBONE_METROS: &[(&str, f64, f64, usize)] = &[
    ("london", 51.51, -0.13, 9),
    ("paris", 48.86, 2.35, 9),
    ("amsterdam", 52.37, 4.90, 8),
    ("frankfurt", 50.11, 8.68, 8),
    ("dusseldorf", 51.23, 6.78, 4),
    ("brussels", 50.85, 4.35, 4),
    ("geneva", 46.20, 6.14, 4),
    ("zurich", 47.38, 8.54, 4),
    ("milan", 45.46, 9.19, 4),
    ("vienna", 48.21, 16.37, 3),
    ("stockholm", 59.33, 18.07, 5),
    ("copenhagen", 55.68, 12.57, 4),
    ("oslo", 59.91, 10.75, 3),
    ("madrid", 40.42, -3.70, 3),
    ("barcelona", 41.39, 2.17, 3),
    ("rome", 41.90, 12.50, 3),
    ("prague", 50.08, 14.44, 3),
    ("warsaw", 52.23, 21.01, 2),
    ("dublin", 53.35, -6.26, 2),
    ("new_york", 40.71, -74.01, 2),
];

/// Expand metro bases into regionally-spread PoP nodes (deterministic
/// offsets). Rocketfuel-derived ISP maps aggregate PoPs at *regional*
/// granularity — sites serving a metro are spread over its wider area
/// (tens to ~200 km), which produces the graded delay ratios
/// d(i,j)/d_min ∈ [1, t] that drive the paper's Exodus/Ebone
/// isolated-node rates (Table 3). Offsets are index-deterministic so
/// the networks are reproducible.
fn expand_metros(name: &str, metros: &[(&str, f64, f64, usize)], want: usize) -> NetworkSpec {
    let mut silos = Vec::new();
    for (m, &(city, lat, lon, count)) in metros.iter().enumerate() {
        for k in 0..count {
            // Ring the PoPs around the metro at graded radii (~1.5–8°,
            // i.e. ~150–800 km), angle varying by metro and index.
            let radius = 1.5 + 0.9 * (k as f64);
            let angle = (m * 7 + k * 3) as f64; // radians, effectively pseudo-random
            let dlat = radius * angle.sin();
            let dlon = radius * angle.cos() * 1.3;
            silos.push(Silo::new(&format!("{city}_{k}"), lat + dlat, lon + dlon));
        }
    }
    assert_eq!(silos.len(), want, "{name}: metro counts must sum to {want}");
    NetworkSpec { name: name.to_string(), silos }
}

/// Exodus (Topology Zoo / Rocketfuel AS-3967): 79 silos (paper Table 3).
pub fn exodus() -> NetworkSpec {
    expand_metros("exodus", EXODUS_METROS, 79)
}

/// Ebone (Topology Zoo / Rocketfuel AS-1755): 87 silos (paper Table 3).
pub fn ebone() -> NetworkSpec {
    expand_metros("ebone", EBONE_METROS, 87)
}

/// All five paper networks in Table 1 order.
pub fn all_networks() -> Vec<NetworkSpec> {
    vec![gaia(), amazon(), geant(), exodus(), ebone()]
}

/// Lookup by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<NetworkSpec> {
    match name.to_ascii_lowercase().as_str() {
        "gaia" => Some(gaia()),
        "amazon" => Some(amazon()),
        "geant" | "géant" => Some(geant()),
        "exodus" => Some(exodus()),
        "ebone" => Some(ebone()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table3_silo_counts() {
        assert_eq!(gaia().n(), 11);
        assert_eq!(amazon().n(), 22);
        assert_eq!(geant().n(), 40);
        assert_eq!(exodus().n(), 79);
        assert_eq!(ebone().n(), 87);
    }

    #[test]
    fn names_unique_within_network() {
        for netw in all_networks() {
            let set: std::collections::BTreeSet<_> =
                netw.silos.iter().map(|s| s.name.as_str()).collect();
            assert_eq!(set.len(), netw.n(), "{}: duplicate silo names", netw.name);
        }
    }

    #[test]
    fn coordinates_are_plausible() {
        for netw in all_networks() {
            for s in &netw.silos {
                assert!((-60.0..=70.0).contains(&s.lat), "{}: {}", netw.name, s.name);
                assert!((-180.0..=180.0).contains(&s.lon));
                assert_eq!(s.up_gbps, 10.0);
                assert_eq!(s.dn_gbps, 10.0);
            }
        }
    }

    #[test]
    fn isp_networks_have_metro_clusters() {
        // Clustered PoPs => some very small inter-silo latencies. This is
        // the property that drives d(i,j)/d_min up and generates isolated
        // nodes on Exodus/Ebone (paper Table 3).
        for netw in [exodus(), ebone()] {
            let mut min = f64::MAX;
            let mut max: f64 = 0.0;
            for i in 0..netw.n() {
                for j in (i + 1)..netw.n() {
                    let l = netw.latency_ms(i, j);
                    min = min.min(l);
                    max = max.max(l);
                }
            }
            assert!(min < 1.0, "{}: expected sub-ms intra-metro latency", netw.name);
            assert!(max / min > 20.0, "{}: expected wide delay spread", netw.name);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for netw in all_networks() {
            assert_eq!(by_name(&netw.name).unwrap().n(), netw.n());
        }
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn gaia_has_intercontinental_spread() {
        let g = gaia();
        let m = g.latency_matrix();
        let max = m.values().iter().cloned().fold(0.0, f64::max);
        assert!(max > 60.0, "Gaia must contain >60ms one-way links: {max}");
    }
}
