//! Network + workload specification: the inputs to the Eq. 3 delay model.
//!
//! A [`NetworkSpec`] is a set of silos with geographic coordinates and
//! access-link capacities (paper: all access links 10 Gbps). A
//! [`DatasetProfile`] carries the per-round compute/transmission numbers
//! from paper Table 2 (model size) plus the local-update compute time
//! `T_c` measured on the paper's P100s — calibrated here so the RING
//! baseline lands at the paper's magnitude (see DESIGN.md §Substitutions).

use super::geo;
use crate::graph::{DenseGraph, Graph};

/// One data silo: a geographic site with symmetric access capacity.
#[derive(Debug, Clone)]
pub struct Silo {
    pub name: String,
    pub lat: f64,
    pub lon: f64,
    /// Upload capacity C_UP, Gbit/s.
    pub up_gbps: f64,
    /// Download capacity C_DN, Gbit/s.
    pub dn_gbps: f64,
}

impl Silo {
    pub fn new(name: &str, lat: f64, lon: f64) -> Self {
        // Paper §5.3: "all access links have 10 Gbps traffic capacity".
        Silo { name: name.to_string(), lat, lon, up_gbps: 10.0, dn_gbps: 10.0 }
    }

    /// A silo with symmetric but non-uniform access capacity (synthetic
    /// networks model a Pareto-ish capacity spread; see
    /// [`super::synth`]). Keeping up == dn keeps the connectivity-graph
    /// weights symmetric.
    pub fn with_capacity(name: &str, lat: f64, lon: f64, gbps: f64) -> Self {
        assert!(gbps > 0.0, "capacity must be positive");
        Silo { name: name.to_string(), lat, lon, up_gbps: gbps, dn_gbps: gbps }
    }
}

/// Row-major one-way latency slab: `n * n` entries behind an `(i, j)`
/// accessor. The old `Vec<Vec<f64>>` shape paid n + 1 allocations and a
/// pointer chase per row — noise at the paper's 87 silos, real money
/// when large-N scaling rebuilds the matrix per synthetic cell.
#[derive(Debug, Clone)]
pub struct LatencyMatrix {
    n: usize,
    data: Vec<f64>,
}

impl LatencyMatrix {
    pub fn n(&self) -> usize {
        self.n
    }

    /// One-way latency l(i, j) in ms; the diagonal is 0.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    /// All entries, row-major (diagonal zeros included).
    pub fn values(&self) -> &[f64] {
        &self.data
    }
}

/// A cross-silo network: the node set of the connectivity graph.
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    pub name: String,
    pub silos: Vec<Silo>,
}

impl NetworkSpec {
    pub fn n(&self) -> usize {
        self.silos.len()
    }

    /// One-way link latency l(i, j) in ms (geo model).
    pub fn latency_ms(&self, i: usize, j: usize) -> f64 {
        let a = &self.silos[i];
        let b = &self.silos[j];
        geo::link_latency_ms(a.lat, a.lon, b.lat, b.lon)
    }

    /// Full latency matrix (ms) as one row-major slab; diagonal is 0.
    pub fn latency_matrix(&self) -> LatencyMatrix {
        let n = self.n();
        let mut data = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    data[i * n + j] = self.latency_ms(i, j);
                }
            }
        }
        LatencyMatrix { n, data }
    }

    /// The degree-1 Eq. 3 connectivity weight of pair `(u, v)` — the
    /// single formula behind both [`Self::connectivity_graph`] and
    /// [`Self::connectivity_dense`], so the two representations are
    /// bit-identical by construction. With M in Mbit and C in Gbit/s,
    /// transmission time in ms is exactly M/C.
    #[inline]
    pub fn conn_weight(&self, profile: &DatasetProfile, u: usize, v: usize) -> f64 {
        let cap = self.silos[u].up_gbps.min(self.silos[v].dn_gbps);
        profile.u as f64 * profile.t_c_ms + self.latency_ms(u, v) + profile.model_size_mbits / cap
    }

    /// The *connectivity* graph \(\mathcal{G}_c\): complete, weighted by
    /// the degree-1 Eq. 3 delay under `profile` (the weight the overlay
    /// builders minimize). This sparse form is the pre-overhaul
    /// substrate, kept as the dense path's reference; production
    /// builders use [`Self::connectivity_dense`].
    pub fn connectivity_graph(&self, profile: &DatasetProfile) -> Graph {
        Graph::complete(self.n(), |u, v| self.conn_weight(profile, u, v))
    }

    /// [`Self::connectivity_graph`] as a flat [`DenseGraph`] slab: one
    /// allocation for the full complete graph (the sparse form at
    /// N = 4096 pushes ~8.4M edges plus twice that in adjacency slots).
    pub fn connectivity_dense(&self, profile: &DatasetProfile) -> DenseGraph {
        DenseGraph::from_fn(self.n(), |u, v| self.conn_weight(profile, u, v))
    }
}

/// Paper Table 2 workload profile.
///
/// Calibration (DESIGN.md §Substitutions): Table 2's "model size Mb"
/// column is taken literally as **megabits** — the paper's own RING
/// cycle times are only consistent with sub-ms transmission at 10 Gbps
/// (4.62 Mbit -> 0.46 ms), and `T_c` per dataset is back-solved from
/// the paper's Gaia RING rows (57.2 / 76.8 / 118.1 ms ≈ worst Gaia
/// one-way latency ~53 ms + M/C + T_c).
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: String,
    /// Model transmission size M, Mbit (paper's "Mb" column is MB;
    /// Mbit = MB * 8).
    pub model_size_mbits: f64,
    /// Time to compute one local update on the testbed GPU, ms.
    pub t_c_ms: f64,
    /// Number of local updates u per communication round.
    pub u: u32,
    /// Mini-batch size (bookkeeping only; folded into t_c_ms).
    pub batch: usize,
}

impl DatasetProfile {
    /// FEMNIST + CNN (1.2M params, 4.62 Mbit; T_c ~ 3.4 ms on a P100).
    pub fn femnist() -> Self {
        DatasetProfile {
            name: "femnist".into(),
            model_size_mbits: 4.62,
            t_c_ms: 3.4,
            u: 1,
            batch: 128,
        }
    }

    /// Sentiment140 + LSTM (4.8M params, 18.38 Mbit; T_c ~ 22 ms).
    pub fn sentiment140() -> Self {
        DatasetProfile {
            name: "sentiment140".into(),
            model_size_mbits: 18.38,
            t_c_ms: 22.0,
            u: 1,
            batch: 512,
        }
    }

    /// iNaturalist + ResNet (11.2M params, 42.88 Mbit; T_c ~ 60 ms —
    /// ResNet fwd+bwd at batch 16 dominates the round).
    pub fn inaturalist() -> Self {
        DatasetProfile {
            name: "inaturalist".into(),
            model_size_mbits: 42.88,
            t_c_ms: 60.0,
            u: 1,
            batch: 16,
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::femnist(), Self::sentiment140(), Self::inaturalist()]
    }

    /// Lookup by (case-insensitive) Table 2 name — the single resolver
    /// shared by the config layer, the CLI, and the sweep engine.
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "femnist" => Some(Self::femnist()),
            "sentiment140" => Some(Self::sentiment140()),
            "inaturalist" => Some(Self::inaturalist()),
            _ => None,
        }
    }

    /// Profile from a built artifact manifest entry (real model, measured
    /// or default T_c) — used by the end-to-end training driver.
    pub fn from_artifact(
        name: &str,
        param_count: usize,
        t_c_ms: f64,
        u: u32,
        batch: usize,
    ) -> Self {
        DatasetProfile {
            name: name.into(),
            model_size_mbits: param_count as f64 * 32.0 / 1e6,
            t_c_ms,
            u,
            batch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_node_net() -> NetworkSpec {
        NetworkSpec {
            name: "test2".into(),
            silos: vec![
                Silo::new("paris", 48.8566, 2.3522),
                Silo::new("nyc", 40.7128, -74.0060),
            ],
        }
    }

    #[test]
    fn latency_symmetric_zero_diagonal() {
        let net = two_node_net();
        let m = net.latency_matrix();
        assert_eq!(m.n(), 2);
        assert_eq!(m.at(0, 0), 0.0);
        assert!((m.at(0, 1) - m.at(1, 0)).abs() < 1e-9);
        assert!(m.at(0, 1) > 20.0, "transatlantic must be tens of ms: {}", m.at(0, 1));
        assert_eq!(m.values().len(), 4);
    }

    #[test]
    fn dense_connectivity_matches_sparse_bitwise() {
        let net = NetworkSpec {
            name: "test4".into(),
            silos: vec![
                Silo::new("paris", 48.8566, 2.3522),
                Silo::new("nyc", 40.7128, -74.0060),
                Silo::with_capacity("tokyo", 35.68, 139.69, 25.0),
                Silo::with_capacity("sydney", -33.87, 151.21, 12.5),
            ],
        };
        let p = DatasetProfile::femnist();
        let sparse = net.connectivity_graph(&p);
        let dense = net.connectivity_dense(&p);
        assert_eq!(dense.num_pairs(), sparse.edges().len());
        for e in sparse.edges() {
            assert_eq!(dense.weight(e.u, e.v).to_bits(), e.w.to_bits(), "({}, {})", e.u, e.v);
        }
        // Non-uniform (but per-silo symmetric) capacities keep the
        // weight symmetric: cap(u, v) = min(c_u, c_v) = cap(v, u).
        for u in 0..net.n() {
            for v in (u + 1)..net.n() {
                assert_eq!(
                    net.conn_weight(&p, u, v).to_bits(),
                    net.conn_weight(&p, v, u).to_bits()
                );
                assert!(net.conn_weight(&p, u, v) > 0.0);
            }
        }
    }

    #[test]
    fn profiles_match_paper_table2() {
        let f = DatasetProfile::femnist();
        assert!((f.model_size_mbits - 4.62).abs() < 1e-9);
        assert_eq!(f.batch, 128);
        let s = DatasetProfile::sentiment140();
        assert_eq!(s.batch, 512);
        let i = DatasetProfile::inaturalist();
        assert_eq!(i.batch, 16);
        // Ordering of model sizes: CNN < LSTM < ResNet.
        assert!(f.model_size_mbits < s.model_size_mbits);
        assert!(s.model_size_mbits < i.model_size_mbits);
    }

    #[test]
    fn from_artifact_computes_mbits() {
        let p = DatasetProfile::from_artifact("femnist_cnn", 1_138_528, 2.0, 1, 32);
        assert!((p.model_size_mbits - 1_138_528.0 * 32.0 / 1e6).abs() < 1e-9);
    }
}
