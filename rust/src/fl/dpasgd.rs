//! DPASGD round scheduling (Eq. 2 / Eq. 6): given a round's
//! [`RoundPlan`], decide what every silo does — pure logic, no compute,
//! so the coordinator and the tests share one source of truth.

use crate::config::IsolatedPolicy;
use crate::delay::EdgeType;
use crate::fl::consensus::ConsensusMatrix;
use crate::topo::RoundPlan;

/// What one silo does in one communication round.
#[derive(Debug, Clone, PartialEq)]
pub enum SiloAction {
    /// u local SGD steps only (Eq. 2 bottom branch / isolated-skip).
    LocalOnly,
    /// Aggregate with `(neighbor, weight)` pairs plus `(self, weight)`;
    /// `wait` = true means strong edges force a synchronous barrier,
    /// false means the silo reads stale cached models (isolated node).
    Aggregate { row: Vec<(usize, f64)>, wait: bool },
}

impl SiloAction {
    pub fn is_aggregate(&self) -> bool {
        matches!(self, SiloAction::Aggregate { .. })
    }
}

/// Compute every silo's action for the round described by `plan`.
///
/// * Silos with ≥1 strong edge aggregate synchronously over their strong
///   neighbours (Eq. 6 top branch, N_i^{++}).
/// * Isolated silos (only weak edges) follow `policy`: aggregate from
///   the stale cache over their weak neighbours, or pure local update.
/// * Silos with no edges at all this round (MATCHA non-matched) do a
///   local update.
pub fn round_actions(
    plan: &RoundPlan,
    consensus: &ConsensusMatrix,
    policy: IsolatedPolicy,
) -> Vec<SiloAction> {
    let n = plan.n;
    let mut strong_nbrs: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut weak_nbrs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(u, v, ty) in &plan.edges {
        match ty {
            EdgeType::Strong => {
                strong_nbrs[u].push(v);
                strong_nbrs[v].push(u);
            }
            EdgeType::Weak => {
                weak_nbrs[u].push(v);
                weak_nbrs[v].push(u);
            }
        }
    }
    (0..n)
        .map(|i| {
            if !strong_nbrs[i].is_empty() {
                let mut participants = strong_nbrs[i].clone();
                participants.push(i);
                SiloAction::Aggregate {
                    row: consensus.restricted_row(i, &participants),
                    wait: true,
                }
            } else if !weak_nbrs[i].is_empty() {
                match policy {
                    IsolatedPolicy::StaleAggregate => {
                        let mut participants = weak_nbrs[i].clone();
                        participants.push(i);
                        SiloAction::Aggregate {
                            row: consensus.restricted_row(i, &participants),
                            wait: false,
                        }
                    }
                    IsolatedPolicy::Skip => SiloAction::LocalOnly,
                }
            } else {
                SiloAction::LocalOnly
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn setup() -> (RoundPlan, ConsensusMatrix) {
        // Ring of 4: 0-1 strong, 1-2 weak, 2-3 weak, 3-0 strong.
        let g = Graph::from_edges(4, [(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0), (3, 0, 1.0)]);
        let plan = RoundPlan {
            n: 4,
            edges: vec![
                (0, 1, EdgeType::Strong),
                (1, 2, EdgeType::Weak),
                (2, 3, EdgeType::Weak),
                (0, 3, EdgeType::Strong),
            ],
        };
        (plan, ConsensusMatrix::metropolis(&g))
    }

    #[test]
    fn strong_nodes_wait_isolated_do_not() {
        let (plan, a) = setup();
        let actions = round_actions(&plan, &a, IsolatedPolicy::StaleAggregate);
        // Node 0 has two strong edges; 1 and 3 have one each; 2 only weak.
        match &actions[0] {
            SiloAction::Aggregate { row, wait } => {
                assert!(wait);
                assert_eq!(row.len(), 3); // {1, 3, self}
            }
            _ => panic!("node 0 must aggregate"),
        }
        match &actions[2] {
            SiloAction::Aggregate { row, wait } => {
                assert!(!wait, "isolated node must not wait");
                assert_eq!(row.len(), 3); // {1, 3, self}
            }
            _ => panic!("node 2 must stale-aggregate"),
        }
    }

    #[test]
    fn skip_policy_makes_isolated_local() {
        let (plan, a) = setup();
        let actions = round_actions(&plan, &a, IsolatedPolicy::Skip);
        assert_eq!(actions[2], SiloAction::LocalOnly);
        assert!(actions[0].is_aggregate());
    }

    #[test]
    fn weights_sum_to_one() {
        let (plan, a) = setup();
        for action in round_actions(&plan, &a, IsolatedPolicy::StaleAggregate) {
            if let SiloAction::Aggregate { row, .. } = action {
                let s: f64 = row.iter().map(|&(_, w)| w).sum();
                assert!((s - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn unplanned_nodes_do_local_updates() {
        let g = Graph::from_edges(3, [(0, 1, 1.0)]);
        let plan = RoundPlan { n: 3, edges: vec![(0, 1, EdgeType::Strong)] };
        let a = ConsensusMatrix::metropolis(&g);
        let actions = round_actions(&plan, &a, IsolatedPolicy::StaleAggregate);
        assert_eq!(actions[2], SiloAction::LocalOnly);
    }
}
