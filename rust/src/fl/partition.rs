//! Non-IID data partitioning: Dirichlet label-skew split, the standard
//! cross-silo heterogeneity model (and what makes topology matter for
//! accuracy — isolated silos drift toward their local label mix).

use crate::util::Rng64;

/// Per-silo class mixture: `mix[s][c]` = probability silo `s` draws an
/// example of class `c`.
#[derive(Debug, Clone)]
pub struct Partition {
    pub mix: Vec<Vec<f64>>,
}

/// Sample a Dirichlet(alpha) vector via normalized Gamma draws
/// (Marsaglia–Tsang for shape < 1 handled by the boost trick).
fn dirichlet(rng: &mut Rng64, alpha: f64, k: usize) -> Vec<f64> {
    let mut v: Vec<f64> = (0..k).map(|_| gamma_sample(rng, alpha)).collect();
    let s: f64 = v.iter().sum();
    if s <= 0.0 {
        // Degenerate draw: fall back to one-hot at a random class.
        let hot = rng.gen_range(0, k);
        return (0..k).map(|i| if i == hot { 1.0 } else { 0.0 }).collect();
    }
    v.iter_mut().for_each(|x| *x /= s);
    v
}

fn gamma_sample(rng: &mut Rng64, shape: f64) -> f64 {
    if shape < 1.0 {
        // Boost: Gamma(a) = Gamma(a+1) * U^(1/a).
        let u: f64 = rng.gen_f64().max(1e-300);
        return gamma_sample(rng, shape + 1.0) * u.powf(1.0 / shape);
    }
    // Marsaglia–Tsang.
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x: f64 = rng.gen_normal();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u: f64 = rng.gen_f64();
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

impl Partition {
    /// Dirichlet(alpha) label mixture per silo. Small alpha = heavy skew.
    pub fn dirichlet(num_silos: usize, num_classes: usize, alpha: f64, seed: u64) -> Self {
        assert!(alpha > 0.0 && num_silos > 0 && num_classes > 0);
        let mut rng = Rng64::seed_from_u64(seed);
        let mix = (0..num_silos).map(|_| dirichlet(&mut rng, alpha, num_classes)).collect();
        Partition { mix }
    }

    /// IID partition (uniform mixture) — baseline / tests.
    pub fn iid(num_silos: usize, num_classes: usize) -> Self {
        Partition { mix: vec![vec![1.0 / num_classes as f64; num_classes]; num_silos] }
    }

    pub fn num_silos(&self) -> usize {
        self.mix.len()
    }

    /// Draw a class label for silo `s`.
    pub fn sample_class(&self, s: usize, rng: &mut Rng64) -> usize {
        let row = &self.mix[s];
        let mut r: f64 = rng.gen_f64();
        for (c, &p) in row.iter().enumerate() {
            r -= p;
            if r <= 0.0 {
                return c;
            }
        }
        row.len() - 1
    }

    /// Total-variation distance of silo `s`'s mixture from uniform — a
    /// skew diagnostic (0 = IID).
    pub fn skew(&self, s: usize) -> f64 {
        let k = self.mix[s].len() as f64;
        0.5 * self.mix[s].iter().map(|p| (p - 1.0 / k).abs()).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixtures_are_distributions() {
        let p = Partition::dirichlet(8, 10, 0.5, 3);
        assert_eq!(p.num_silos(), 8);
        for s in 0..8 {
            let sum: f64 = p.mix[s].iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(p.mix[s].iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn small_alpha_is_skewed_large_alpha_flat() {
        let skewed = Partition::dirichlet(20, 10, 0.1, 7);
        let flat = Partition::dirichlet(20, 10, 100.0, 7);
        let mean_skew = |p: &Partition| {
            (0..20).map(|s| p.skew(s)).sum::<f64>() / 20.0
        };
        assert!(mean_skew(&skewed) > 0.4, "{}", mean_skew(&skewed));
        assert!(mean_skew(&flat) < 0.1, "{}", mean_skew(&flat));
    }

    #[test]
    fn iid_has_zero_skew() {
        let p = Partition::iid(4, 62);
        for s in 0..4 {
            assert!(p.skew(s) < 1e-12);
        }
    }

    #[test]
    fn sampling_matches_mixture() {
        let p = Partition::dirichlet(1, 5, 0.5, 11);
        let mut rng = Rng64::seed_from_u64(0);
        let mut counts = [0usize; 5];
        let n = 20000;
        for _ in 0..n {
            counts[p.sample_class(0, &mut rng)] += 1;
        }
        for c in 0..5 {
            let freq = counts[c] as f64 / n as f64;
            assert!((freq - p.mix[0][c]).abs() < 0.02, "class {c}: {freq} vs {}", p.mix[0][c]);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = Partition::dirichlet(3, 4, 0.5, 42);
        let b = Partition::dirichlet(3, 4, 0.5, 42);
        assert_eq!(a.mix, b.mix);
        let c = Partition::dirichlet(3, 4, 0.5, 43);
        assert_ne!(a.mix, c.mix);
    }
}
