//! Stale-model buffers: the (k−h) bookkeeping of Eq. 6.
//!
//! Every silo keeps the most recent model *published* by each of its
//! in-neighbours, tagged with the round it was produced in. Strong-edge
//! rounds refresh the cache synchronously (that is what the cycle time
//! waits for); weak-edge transfers land asynchronously and are visible
//! from the next round on. Isolated nodes aggregate straight from this
//! cache — "model aggregation without waiting for other nodes".

/// A cached neighbour model with its provenance round.
#[derive(Debug, Clone)]
pub struct CachedModel {
    pub params: Vec<f32>,
    /// Round k at which the owner produced these params.
    pub round: usize,
}

/// Per-silo view of its in-neighbours' models.
#[derive(Debug, Default)]
pub struct NeighborCache {
    slots: std::collections::BTreeMap<usize, CachedModel>,
}

impl NeighborCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record neighbour `j`'s params as of `round`. Keeps the newest.
    pub fn publish(&mut self, j: usize, params: Vec<f32>, round: usize) {
        match self.slots.get(&j) {
            Some(c) if c.round >= round => {}
            _ => {
                self.slots.insert(j, CachedModel { params, round });
            }
        }
    }

    pub fn get(&self, j: usize) -> Option<&CachedModel> {
        self.slots.get(&j)
    }

    /// Staleness h = current_round - cached round (None if never seen).
    pub fn staleness(&self, j: usize, current_round: usize) -> Option<usize> {
        self.slots.get(&j).map(|c| current_round.saturating_sub(c.round))
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_keeps_newest() {
        let mut c = NeighborCache::new();
        c.publish(2, vec![1.0], 5);
        c.publish(2, vec![2.0], 3); // older -> ignored
        assert_eq!(c.get(2).unwrap().params, vec![1.0]);
        assert_eq!(c.get(2).unwrap().round, 5);
        c.publish(2, vec![3.0], 8);
        assert_eq!(c.get(2).unwrap().params, vec![3.0]);
    }

    #[test]
    fn staleness_computation() {
        let mut c = NeighborCache::new();
        c.publish(0, vec![0.0], 4);
        assert_eq!(c.staleness(0, 7), Some(3));
        assert_eq!(c.staleness(0, 4), Some(0));
        assert_eq!(c.staleness(1, 7), None);
    }

    #[test]
    fn empty_and_len() {
        let mut c = NeighborCache::new();
        assert!(c.is_empty());
        c.publish(0, vec![], 0);
        c.publish(1, vec![], 0);
        assert_eq!(c.len(), 2);
    }
}
