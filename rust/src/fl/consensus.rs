//! Consensus matrix A (Eq. 2/6): Metropolis–Hastings weights over an
//! overlay graph — the standard doubly-stochastic choice for DPASGD.

use crate::graph::Graph;

/// Row-indexed consensus matrix; `a[i][j]` is A_{i,j}. Rows sum to 1 and
/// the matrix is symmetric (hence doubly stochastic).
#[derive(Debug, Clone)]
pub struct ConsensusMatrix {
    a: Vec<Vec<f64>>,
}

impl ConsensusMatrix {
    /// Metropolis–Hastings: A_{ij} = 1/(1 + max(deg_i, deg_j)) for
    /// overlay neighbours, A_{ii} = 1 - Σ_j A_{ij}.
    pub fn metropolis(g: &Graph) -> Self {
        let n = g.n();
        let mut a = vec![vec![0.0; n]; n];
        for e in g.edges() {
            let w = 1.0 / (1.0 + g.degree(e.u).max(g.degree(e.v)) as f64);
            a[e.u][e.v] = w;
            a[e.v][e.u] = w;
        }
        for (i, row) in a.iter_mut().enumerate() {
            let off: f64 = row.iter().sum();
            row[i] = 1.0 - off;
        }
        ConsensusMatrix { a }
    }

    /// Uniform averaging over an explicit neighbour subset ∪ {i} — the
    /// weight row used when only part of the neighbourhood participates
    /// (Eq. 6's N_i^{++}): w_j = 1/(|S|+1).
    pub fn uniform_row(i: usize, neighbors: &[usize]) -> Vec<(usize, f64)> {
        let k = neighbors.len() + 1;
        let w = 1.0 / k as f64;
        let mut row: Vec<(usize, f64)> = neighbors.iter().map(|&j| (j, w)).collect();
        row.push((i, w));
        row
    }

    pub fn n(&self) -> usize {
        self.a.len()
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.a[i]
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i][j]
    }

    /// Row restricted to a participating subset S ∪ {i}, re-normalized to
    /// sum 1 (mass of absent neighbours folds into self weight, the
    /// standard partial-participation correction).
    pub fn restricted_row(&self, i: usize, participants: &[usize]) -> Vec<(usize, f64)> {
        let mut row: Vec<(usize, f64)> = participants
            .iter()
            .filter(|&&j| j != i && self.a[i][j] > 0.0)
            .map(|&j| (j, self.a[i][j]))
            .collect();
        let off: f64 = row.iter().map(|&(_, w)| w).sum();
        row.push((i, 1.0 - off));
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring5() -> Graph {
        Graph::from_edges(5, (0..5).map(|i| (i, (i + 1) % 5, 1.0)))
    }

    #[test]
    fn metropolis_rows_sum_to_one_and_symmetric() {
        let a = ConsensusMatrix::metropolis(&ring5());
        for i in 0..5 {
            let s: f64 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            for j in 0..5 {
                assert!((a.get(i, j) - a.get(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn metropolis_weights_on_ring() {
        // All degrees 2 -> neighbour weight 1/3, self 1/3.
        let a = ConsensusMatrix::metropolis(&ring5());
        assert!((a.get(0, 1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((a.get(0, 0) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.get(0, 2), 0.0);
    }

    #[test]
    fn metropolis_nonnegative_self_weight_on_star() {
        let mut g = Graph::new(5);
        for i in 1..5 {
            g.add_edge(0, i, 1.0);
        }
        let a = ConsensusMatrix::metropolis(&g);
        assert!(a.get(0, 0) >= 0.0);
        // Hub: 4 neighbours each 1/5 -> self 1/5.
        assert!((a.get(0, 0) - 0.2).abs() < 1e-12);
        // Leaf: one neighbour 1/5 -> self 4/5.
        assert!((a.get(1, 1) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn restricted_row_renormalizes() {
        let a = ConsensusMatrix::metropolis(&ring5());
        // Node 0 with only neighbour 1 participating.
        let row = a.restricted_row(0, &[1]);
        let sum: f64 = row.iter().map(|&(_, w)| w).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        let self_w = row.iter().find(|&&(j, _)| j == 0).unwrap().1;
        assert!((self_w - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn uniform_row_sums_to_one() {
        let row = ConsensusMatrix::uniform_row(3, &[0, 1]);
        let sum: f64 = row.iter().map(|&(_, w)| w).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert_eq!(row.len(), 3);
    }
}
