//! Federated-learning substrate: consensus weights, DPASGD round
//! scheduling, staleness buffers, and non-IID data partitioning.

pub mod consensus;
pub mod dpasgd;
pub mod partition;
pub mod staleness;

pub use consensus::ConsensusMatrix;
pub use dpasgd::{round_actions, SiloAction};
pub use partition::Partition;
pub use staleness::{CachedModel, NeighborCache};
