//! Deterministic fault-injection scenarios: silo churn, link-capacity
//! shifts, compute jitter, and correlated regional outages as a
//! first-class simulation layer.
//!
//! The paper's cycle-time tables assume a static overlay for all 6400
//! rounds; real cross-silo deployments see silos leave and rejoin,
//! links degrade, and whole regions fail together. A [`ScenarioSpec`]
//! is a seed-streamed event timeline over the round axis:
//!
//! * `leave@k:silo=i` / `rejoin@k:silo=i` — silo `i` drops out of (or
//!   returns to) the federation at round `k`. A down silo keeps its
//!   node id but loses every planned edge
//!   ([`crate::topo::MaskedTopology`]), so it counts as *absent* — not
//!   isolated — under the single isolation rule.
//! * `scale@k:factor=f` — link-capacity shift: from round `k` every
//!   fresh transfer costs `f · d_0` (strong resets and new-pair seeds
//!   rescale; in-flight Eq. 4 backlog drains unchanged, the compute
//!   floor `u·T_c` is unaffected). `f = 1` is a bitwise no-op.
//! * `jitter@k:amp=a` — per-round compute jitter on the access links:
//!   a deterministic, seed-streamed uniform draw in `[0, a)` ms is
//!   *added to the reported cycle time* each round. Jitter models
//!   straggling local compute after the round's transfers complete, so
//!   it never feeds back into the Eq. 4 backlog recurrence — a
//!   deliberate modeling choice that keeps every engine's state
//!   machine untouched and the draw identical across engines.
//! * `outage@k:frac=f:dur=d[:epicenter=i]` — correlated regional
//!   outage: the epicenter silo (explicit, or drawn from the scenario
//!   seed) plus its `ceil(f·n) − 1` haversine-nearest neighbours all
//!   leave at round `k` and rejoin at round `k + d`. On geo-clustered
//!   networks (zoo or `synth-geo-*`) this takes out a metro at a time.
//!
//! # Piecewise-static execution
//!
//! The resolved timeline ([`build_timeline`]) splits the run into
//! maximal *segments* of constant (up-mask, capacity scale). Within a
//! segment the schedule is static, so each segment reuses the existing
//! engine machinery — the compiled per-state tables (filtered through
//! the mask, state-indexed by the *global* round), the factored
//! group-max recurrence (with the strong phase offset by the segment
//! start), or the naive tracker — with per-pair Eq. 4 backlog carried
//! across segment boundaries. Pairs entering the schedule mid-run seed
//! their d_0 from the masked plan degrees of the round they first
//! appear in, exactly as the naive tracker would.
//!
//! Cycle detection is deliberately **not** attempted inside segments:
//! segments are short, the carry-in state breaks the all-strong
//! state-0 recurrence guarantee, and correctness is worth more than
//! replay here. Scenario stats therefore always report
//! `simulated_rounds == rounds` and no cycle fields.
//!
//! # Bit-identity contract
//!
//! Every scenario path — the naive tracker oracle
//! ([`simulate_summary_scenario_naive`]), the masked periodic engine,
//! the multi-lane SoA batch, and the offset factored engine — performs
//! the same f64 operations in the same per-round order, so their τ and
//! isolation series agree bitwise; a shared [`finalize`] then adds the
//! jitter series and accumulates totals in round order. Pinned by the
//! tests below and `tests/proptest_scenarios.rs`.

use std::collections::HashMap;

use crate::delay::{pair_d0_ms, EdgeType};
use crate::net::geo::haversine_km;
use crate::net::{DatasetProfile, NetworkSpec};
use crate::topo::{MaskedTopology, TopologyDesign};
use crate::util::rng::{derive_stream, fnv1a};
use crate::util::Rng64;

use super::batched::BatchLane;
use super::compiled::{CompiledTopology, EngineKind, EngineStats};
use super::factored::MAX_FACTOR_GROUPS;
use super::SimSummary;

/// One scheduled fault event.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Round the event fires at (events at rounds ≥ the run length are
    /// inert, so one scenario can serve several round budgets).
    pub round: usize,
    /// What happens.
    pub kind: EventKind,
}

/// The event vocabulary. See the module docs for semantics.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Silo `silo` leaves the federation.
    Leave { silo: usize },
    /// Silo `silo` rejoins.
    Rejoin { silo: usize },
    /// Fresh-transfer delays rescale to `factor · d_0` from here on.
    Scale { factor: f64 },
    /// Per-round additive compute jitter drawn uniformly in `[0, amp)` ms.
    Jitter { amp: f64 },
    /// Correlated regional outage: epicenter + nearest neighbours
    /// covering `frac` of the network leave for `dur` rounds.
    Outage { frac: f64, dur: usize, epicenter: Option<usize> },
}

/// A deterministic, seed-streamed fault-injection scenario: the
/// `[events]` section of a sweep spec. The seed drives every random
/// choice (outage epicenters, jitter draws) through dedicated
/// [`derive_stream`] streams, so a scenario is a pure value — same
/// spec, same network, same timeline, everywhere.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Seed for epicenter draws and the jitter stream.
    pub seed: u64,
    /// Events in declaration order (same-round events apply in order).
    pub events: Vec<Event>,
}

impl ScenarioSpec {
    /// Parse one event string of the sweep-spec DSL, e.g.
    /// `leave@40:silo=3` or `outage@200:frac=0.3:dur=50`. Fields are
    /// colon-separated (never commas — TOML list splitting owns those).
    pub fn parse_event(s: &str) -> anyhow::Result<Event> {
        let (kind_s, rest) = s
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("event '{s}': expected '<kind>@<round>[:k=v...]'"))?;
        let mut parts = rest.split(':');
        let round_s = parts.next().unwrap_or("");
        let round: usize = round_s
            .parse()
            .map_err(|_| anyhow::anyhow!("event '{s}': bad round '{round_s}'"))?;
        let mut params: Vec<(&str, &str)> = Vec::new();
        for p in parts {
            let (k, v) = p
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("event '{s}': expected 'key=value', got '{p}'"))?;
            params.push((k, v));
        }
        let get = |key: &str| -> anyhow::Result<&str> {
            params
                .iter()
                .find(|(k, _)| *k == key)
                .map(|(_, v)| *v)
                .ok_or_else(|| anyhow::anyhow!("event '{s}': missing '{key}='"))
        };
        let parse_f64 = |key: &str| -> anyhow::Result<f64> {
            let v = get(key)?;
            v.parse::<f64>().map_err(|_| anyhow::anyhow!("event '{s}': bad {key} '{v}'"))
        };
        let parse_usize = |key: &str| -> anyhow::Result<usize> {
            let v = get(key)?;
            v.parse::<usize>().map_err(|_| anyhow::anyhow!("event '{s}': bad {key} '{v}'"))
        };
        let known = |allowed: &[&str]| -> anyhow::Result<()> {
            for (k, _) in &params {
                if !allowed.contains(k) {
                    anyhow::bail!("event '{s}': unknown key '{k}'");
                }
            }
            Ok(())
        };
        let kind = match kind_s {
            "leave" => {
                known(&["silo"])?;
                EventKind::Leave { silo: parse_usize("silo")? }
            }
            "rejoin" => {
                known(&["silo"])?;
                EventKind::Rejoin { silo: parse_usize("silo")? }
            }
            "scale" => {
                known(&["factor"])?;
                let factor = parse_f64("factor")?;
                anyhow::ensure!(
                    factor.is_finite() && factor > 0.0,
                    "event '{s}': factor must be positive and finite"
                );
                EventKind::Scale { factor }
            }
            "jitter" => {
                known(&["amp"])?;
                let amp = parse_f64("amp")?;
                anyhow::ensure!(
                    amp.is_finite() && amp >= 0.0,
                    "event '{s}': amp must be non-negative and finite"
                );
                EventKind::Jitter { amp }
            }
            "outage" => {
                known(&["frac", "dur", "epicenter"])?;
                let frac = parse_f64("frac")?;
                anyhow::ensure!(
                    frac.is_finite() && frac > 0.0 && frac <= 1.0,
                    "event '{s}': frac must be in (0, 1]"
                );
                let dur = parse_usize("dur")?;
                anyhow::ensure!(dur >= 1, "event '{s}': dur must be >= 1");
                let epicenter = match get("epicenter") {
                    Ok(v) => Some(
                        v.parse::<usize>()
                            .map_err(|_| anyhow::anyhow!("event '{s}': bad epicenter '{v}'"))?,
                    ),
                    Err(_) => None,
                };
                EventKind::Outage { frac, dur, epicenter }
            }
            other => anyhow::bail!(
                "event '{s}': unknown kind '{other}' (leave|rejoin|scale|jitter|outage)"
            ),
        };
        Ok(Event { round, kind })
    }

    /// Build a scenario from DSL event strings (the `[events]` TOML
    /// section's `events` list).
    pub fn from_event_strs<S: AsRef<str>>(seed: u64, events: &[S]) -> anyhow::Result<Self> {
        let events = events
            .iter()
            .map(|s| Self::parse_event(s.as_ref()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ScenarioSpec { seed, events })
    }

    /// The canonical DSL string of one event — `parse_event` of this is
    /// the identity.
    pub fn event_str(e: &Event) -> String {
        match &e.kind {
            EventKind::Leave { silo } => format!("leave@{}:silo={}", e.round, silo),
            EventKind::Rejoin { silo } => format!("rejoin@{}:silo={}", e.round, silo),
            EventKind::Scale { factor } => format!("scale@{}:factor={}", e.round, factor),
            EventKind::Jitter { amp } => format!("jitter@{}:amp={}", e.round, amp),
            EventKind::Outage { frac, dur, epicenter } => {
                let mut s = format!("outage@{}:frac={}:dur={}", e.round, frac, dur);
                if let Some(epi) = epicenter {
                    s.push_str(&format!(":epicenter={epi}"));
                }
                s
            }
        }
    }

    /// Canonical DSL strings for every event, in order.
    pub fn event_strs(&self) -> Vec<String> {
        self.events.iter().map(Self::event_str).collect()
    }

    /// Canonical serialization of the whole scenario — the fingerprint
    /// preimage, and what the stored-cell key embeds (hashed).
    pub fn canonical_string(&self) -> String {
        let mut s = format!("seed={}", self.seed);
        for e in &self.events {
            s.push(';');
            s.push_str(&Self::event_str(e));
        }
        s
    }

    /// FNV-1a fingerprint of the canonical string. Joins
    /// [`crate::sweep::CellFingerprint`] and the store cell key, so a
    /// churned cell can never collide with its static twin.
    pub fn fingerprint(&self) -> u64 {
        fnv1a(self.canonical_string().as_bytes())
    }

    /// Network-independent parameter validation (ranges, finiteness) —
    /// the sweep-spec `validate` hook. Per-network checks (silo indices
    /// in range, the network never emptying) happen in
    /// [`build_timeline`] and surface as per-cell errors instead.
    pub fn validate(&self) -> anyhow::Result<()> {
        for e in &self.events {
            match &e.kind {
                EventKind::Scale { factor } => anyhow::ensure!(
                    factor.is_finite() && *factor > 0.0,
                    "scale@{}: factor must be positive and finite",
                    e.round
                ),
                EventKind::Jitter { amp } => anyhow::ensure!(
                    amp.is_finite() && *amp >= 0.0,
                    "jitter@{}: amp must be non-negative and finite",
                    e.round
                ),
                EventKind::Outage { frac, dur, .. } => {
                    anyhow::ensure!(
                        frac.is_finite() && *frac > 0.0 && *frac <= 1.0,
                        "outage@{}: frac must be in (0, 1]",
                        e.round
                    );
                    anyhow::ensure!(*dur >= 1, "outage@{}: dur must be >= 1", e.round);
                }
                EventKind::Leave { .. } | EventKind::Rejoin { .. } => {}
            }
        }
        Ok(())
    }
}

/// One maximal run of rounds with a constant (up-mask, scale) state.
#[derive(Debug, Clone)]
pub struct Segment {
    /// First round of the segment (global round index).
    pub start: usize,
    /// Rounds in the segment.
    pub len: usize,
    /// Per-silo availability during the segment.
    pub up: Vec<bool>,
    /// Silos up during the segment.
    pub up_count: usize,
    /// Capacity scale: fresh transfers cost `scale · d_0`.
    pub scale: f64,
}

/// A resolved outage window `[start, end)` (end clamped to the run).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutageWindow {
    /// Round the region went down.
    pub start: usize,
    /// Round the region came back (exclusive; clamped to `rounds`).
    pub end: usize,
}

/// A [`ScenarioSpec`] resolved against one network and round budget:
/// the piecewise-static segments, the per-round jitter series, and the
/// outage windows the recovery metric is computed over.
#[derive(Debug, Clone)]
pub struct Timeline {
    /// Maximal constant-(mask, scale) segments covering `0..rounds`.
    pub segments: Vec<Segment>,
    /// Per-round additive jitter, ms. Empty iff the scenario has no
    /// jitter events — the engines then skip the add entirely, keeping
    /// jitter-free scenarios bit-identical to the unjittered series.
    pub jitter: Vec<f64>,
    /// Outage windows in firing order.
    pub outages: Vec<OutageWindow>,
}

/// Resolve `sc` against a concrete network and round budget.
///
/// Errors (as a plain report-friendly string) when an event references
/// a silo index outside the network or when churn ever leaves fewer
/// than 2 silos up — both are per-cell conditions (networks in one
/// sweep differ in size), surfaced as structured per-cell errors by the
/// sweep engine rather than panics.
pub fn build_timeline(
    sc: &ScenarioSpec,
    net: &NetworkSpec,
    rounds: usize,
) -> Result<Timeline, String> {
    assert!(rounds > 0);
    let n = net.n();
    for e in &sc.events {
        let bad = match e.kind {
            EventKind::Leave { silo } | EventKind::Rejoin { silo } => (silo >= n).then_some(silo),
            EventKind::Outage { epicenter: Some(epi), .. } => (epi >= n).then_some(epi),
            _ => None,
        };
        if let Some(silo) = bad {
            return Err(format!(
                "scenario references silo {silo} but network '{}' has {n} silos",
                net.name
            ));
        }
    }

    // Bucket events by round, preserving declaration order per round.
    let mut by_round: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, e) in sc.events.iter().enumerate() {
        by_round.entry(e.round).or_default().push(i);
    }

    let mut up = vec![true; n];
    let mut scale = 1.0f64;
    let mut amp = 0.0f64;
    let mut any_jitter = false;
    // Outage-scheduled rejoins: (round, silo), applied before that
    // round's events.
    let mut pending: Vec<(usize, usize)> = Vec::new();
    let mut outage_idx = 0u64;
    let mut outages: Vec<OutageWindow> = Vec::new();
    let mut amp_series = Vec::with_capacity(rounds);
    let mut segments: Vec<Segment> = Vec::new();

    for k in 0..rounds {
        let mut changed = k == 0;
        for &(r, silo) in &pending {
            if r == k && !up[silo] {
                up[silo] = true;
                changed = true;
            }
        }
        pending.retain(|&(r, _)| r > k);
        if let Some(idxs) = by_round.get(&k) {
            for &i in idxs {
                match &sc.events[i].kind {
                    EventKind::Leave { silo } => {
                        changed |= up[*silo];
                        up[*silo] = false;
                    }
                    EventKind::Rejoin { silo } => {
                        changed |= !up[*silo];
                        up[*silo] = true;
                    }
                    EventKind::Scale { factor } => {
                        changed |= scale.to_bits() != factor.to_bits();
                        scale = *factor;
                    }
                    EventKind::Jitter { amp: a } => {
                        any_jitter = true;
                        amp = *a;
                    }
                    EventKind::Outage { frac, dur, epicenter } => {
                        let epi = epicenter.unwrap_or_else(|| {
                            let h = fnv1a(format!("outage/{outage_idx}").as_bytes());
                            (derive_stream(sc.seed, h) % n as u64) as usize
                        });
                        outage_idx += 1;
                        let count = ((frac * n as f64).ceil() as usize).clamp(1, n);
                        for &silo in &nearest_silos(net, epi, count) {
                            changed |= up[silo];
                            up[silo] = false;
                            pending.push((k + dur, silo));
                        }
                        outages.push(OutageWindow { start: k, end: (k + dur).min(rounds) });
                    }
                }
            }
        }
        if changed {
            let up_count = up.iter().filter(|&&u| u).count();
            if up_count < 2 {
                return Err(format!(
                    "scenario leaves {up_count} silo(s) up at round {k} on network '{}' \
                     (need at least 2)",
                    net.name
                ));
            }
            if let Some(last) = segments.last_mut() {
                last.len = k - last.start;
            }
            segments.push(Segment { start: k, len: 0, up: up.clone(), up_count, scale });
        }
        amp_series.push(amp);
    }
    if let Some(last) = segments.last_mut() {
        last.len = rounds - last.start;
    }
    // Drop zero-length segments (two state changes in one round collapse).
    segments.retain(|s| s.len > 0);

    let jitter = if any_jitter {
        (0..rounds)
            .map(|k| {
                let a = amp_series[k];
                if a > 0.0 {
                    Rng64::seed_from_u64(derive_stream(sc.seed, k as u64)).gen_f64() * a
                } else {
                    0.0
                }
            })
            .collect()
    } else {
        Vec::new()
    };

    Ok(Timeline { segments, jitter, outages })
}

/// The outage blast region: `epicenter` plus its haversine-nearest
/// neighbours, `count` silos total. Ties break on silo index, so the
/// region is a pure function of the network geometry.
fn nearest_silos(net: &NetworkSpec, epicenter: usize, count: usize) -> Vec<usize> {
    let e = &net.silos[epicenter];
    let mut scored: Vec<(f64, usize)> = (0..net.n())
        .map(|i| {
            let s = &net.silos[i];
            (haversine_km(e.lat, e.lon, s.lat, s.lon), i)
        })
        .collect();
    scored.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    scored.into_iter().take(count).map(|(_, i)| i).collect()
}

/// Per-segment degraded-mode statistics (over jittered cycle times).
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentMetrics {
    /// First round of the segment.
    pub start: usize,
    /// Rounds in the segment.
    pub len: usize,
    /// Silos up during the segment.
    pub up_silos: usize,
    /// Median cycle time, ms.
    pub p50_ms: f64,
    /// 95th-percentile cycle time, ms.
    pub p95_ms: f64,
    /// Worst cycle time, ms.
    pub max_ms: f64,
}

/// Whole-run degraded-mode metrics, attached to [`SimSummary`] for
/// scenario cells and flowing through sweep reports and the store.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioMetrics {
    /// One entry per timeline segment, in round order.
    pub segments: Vec<SegmentMetrics>,
    /// Whole-run median cycle time, ms.
    pub p50_ms: f64,
    /// Whole-run 95th-percentile cycle time, ms.
    pub p95_ms: f64,
    /// Whole-run worst cycle time, ms.
    pub max_ms: f64,
    /// Isolated node-rounds over all node-rounds: Σ isolated_k / (n · rounds).
    pub isolation_rate: f64,
    /// Σ over outages of rounds-to-recover: after each outage window
    /// ends, rounds until the cycle time first drops back to the
    /// pre-outage segment's maximum (the remaining rounds if it never
    /// does; 0 for outages starting at round 0 or ending past the run).
    pub recovery_rounds: usize,
    /// Adaptation-loop accounting, present only on cells run under an
    /// active [`crate::search::adapt`] policy. `None` on every static
    /// scenario path, which keeps PR 9 outputs (equality, store bytes,
    /// report artifacts) untouched.
    pub adapt: Option<AdaptMetrics>,
}

/// What the adaptation loop spent and where it gave up, accumulated
/// over every re-planned segment boundary of one cell.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdaptMetrics {
    /// Policy that produced this run (`"rebuild"` or `"warm"`).
    pub policy: String,
    /// Boundaries where a freshly planned topology was activated.
    pub replans: usize,
    /// Boundaries that fell down the graceful-degradation ladder
    /// (warm search out of budget/deadline → rebuild; rebuild invalid
    /// → masked static base).
    pub fallbacks: usize,
    /// Total fitness evaluations spent across all warm searches.
    pub evals_spent: usize,
    /// Total rounds spent frozen on the outgoing topology while a new
    /// overlay "deploys" (the reconfiguration-cost model).
    pub freeze_rounds: usize,
}

/// Per-pair Eq. 4 state under a scenario: the unscaled base d_0 (so
/// later scale events rescale fresh transfers, not history) plus the
/// running backlog.
struct PairState {
    base_d0: f64,
    backlog: f64,
}

/// Step one piecewise-static *phase* — a topology under a fixed
/// (mask, scale) for `len` rounds, with masked plan index `r` mapping
/// to the inner schedule's round `offset + r` — appending to the shared
/// τ/isolation series and carrying per-pair Eq. 4 state in `state`.
///
/// This is the naive tracker's inner loop, factored out so the PR 9
/// segment walk ([`run_scenario_tracker`]) and the adaptation layer's
/// spliced-phase walk ([`run_spliced`]) perform byte-for-byte the same
/// f64 operations per phase. Pure code motion from the tracker: any
/// change here moves every scenario engine's bits.
fn step_phase(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    offset: usize,
    up: &[bool],
    scale: f64,
    len: usize,
    state: &mut HashMap<(usize, usize), PairState>,
    tau_series: &mut Vec<f64>,
    iso_series: &mut Vec<u32>,
) {
    let floor = profile.u as f64 * profile.t_c_ms;
    let mut masked = MaskedTopology::new(topo, offset, up);
    for r in 0..len {
        let plan = masked.plan(r);
        let degrees = plan.degrees();
        let mut tau = floor;
        for &(u, v, ty) in &plan.edges {
            let key = if u <= v { (u, v) } else { (v, u) };
            let st = state.entry(key).or_insert_with(|| {
                let d0 = pair_d0_ms(net, profile, u, v, degrees[u], degrees[v]);
                PairState { base_d0: d0, backlog: d0 * scale }
            });
            if ty == EdgeType::Strong {
                tau = tau.max(floor.max(st.backlog));
            }
        }
        for &(u, v, ty) in &plan.edges {
            let key = if u <= v { (u, v) } else { (v, u) };
            let st = state.get_mut(&key).unwrap();
            match ty {
                EdgeType::Strong => st.backlog = st.base_d0 * scale,
                EdgeType::Weak => st.backlog = (st.backlog - tau).max(floor),
            }
        }
        tau_series.push(tau);
        iso_series.push(plan.isolated_nodes().len() as u32);
    }
}

/// The scenario oracle: a [`MaskedTopology`]-driven mirror of the
/// static naive tracker ([`super::simulate_summary_naive`]'s
/// `DelayTracker`), stepping every segment round-by-round with hashed
/// pair state. Never optimized — every scenario engine is pinned
/// bitwise against this.
fn run_scenario_tracker(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    tl: &Timeline,
) -> (Vec<f64>, Vec<u32>) {
    let mut state: HashMap<(usize, usize), PairState> = HashMap::new();
    let rounds: usize = tl.segments.iter().map(|s| s.len).sum();
    let mut tau_series = Vec::with_capacity(rounds);
    let mut iso_series = Vec::with_capacity(rounds);
    for seg in &tl.segments {
        step_phase(
            topo,
            net,
            profile,
            seg.start,
            &seg.up,
            seg.scale,
            seg.len,
            &mut state,
            &mut tau_series,
            &mut iso_series,
        );
    }
    (tau_series, iso_series)
}

/// One phase of an adaptive (spliced-schedule) run: which topology to
/// step, at which schedule offset, under which mask/scale, for how
/// long. Produced by the adaptation planner
/// ([`crate::search::adapt`]); consumed by [`run_spliced`].
#[derive(Debug, Clone)]
pub struct SplicedPhase {
    /// Index into the caller's topology table.
    pub topo: usize,
    /// Schedule offset: phase round `r` steps the topology's plan at
    /// `offset + r`. The static base keeps PR 9's global-round offset;
    /// freshly activated topologies restart at 0.
    pub offset: usize,
    /// Per-silo availability during the phase.
    pub up: Vec<bool>,
    /// Capacity scale during the phase.
    pub scale: f64,
    /// Rounds in the phase.
    pub len: usize,
}

/// Step a spliced sequence of phases over a shared topology table,
/// carrying per-pair Eq. 4 backlog across every phase boundary —
/// including topology swaps, where pairs present in both overlays keep
/// their in-flight backlog and new pairs seed from the masked plan
/// degrees of their first round, exactly as the PR 9 tracker seeds
/// pairs entering a masked schedule mid-run.
///
/// With one topology and phases mirroring the timeline's segments
/// (offset = segment start), this *is* [`run_scenario_tracker`] —
/// pinned bitwise by `policy = "none"` tests.
pub fn run_spliced(
    topos: &mut [Box<dyn TopologyDesign>],
    phases: &[SplicedPhase],
    net: &NetworkSpec,
    profile: &DatasetProfile,
) -> (Vec<f64>, Vec<u32>) {
    let mut state: HashMap<(usize, usize), PairState> = HashMap::new();
    let rounds: usize = phases.iter().map(|p| p.len).sum();
    let mut tau_series = Vec::with_capacity(rounds);
    let mut iso_series = Vec::with_capacity(rounds);
    for ph in phases {
        step_phase(
            topos[ph.topo].as_mut(),
            net,
            profile,
            ph.offset,
            &ph.up,
            ph.scale,
            ph.len,
            &mut state,
            &mut tau_series,
            &mut iso_series,
        );
    }
    (tau_series, iso_series)
}

/// Shared metric/summary assembly over an engine's raw (τ, isolation)
/// series: add the jitter series, accumulate the total sequentially in
/// round order, compute per-segment and whole-run degraded-mode
/// metrics. Engines only have to agree on the input series for the
/// outputs to agree bitwise. `pub(crate)` so the adaptation layer
/// ([`crate::search::adapt`]) assembles its summaries through the same
/// code path.
pub(crate) fn finalize(
    topology: String,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    tl: &Timeline,
    tau: Vec<f64>,
    iso: Vec<u32>,
    kind: EngineKind,
    period: Option<usize>,
    groups: Option<usize>,
) -> (SimSummary, EngineStats) {
    debug_assert_eq!(tau.len(), rounds);
    debug_assert_eq!(iso.len(), rounds);
    let cycles: Vec<f64> = if tl.jitter.is_empty() {
        tau
    } else {
        tau.iter().zip(&tl.jitter).map(|(t, j)| t + j).collect()
    };

    let mut total_ms = 0.0;
    let mut rounds_with_isolated = 0usize;
    let mut max_isolated = 0usize;
    for k in 0..rounds {
        total_ms += cycles[k];
        let i = iso[k] as usize;
        if i > 0 {
            rounds_with_isolated += 1;
            max_isolated = max_isolated.max(i);
        }
    }

    let stats_of = |slice: &[f64]| -> (f64, f64, f64) {
        let mut sorted = slice.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        (
            crate::metrics::percentile(&sorted, 0.50),
            crate::metrics::percentile(&sorted, 0.95),
            sorted[sorted.len() - 1],
        )
    };
    let segments: Vec<SegmentMetrics> = tl
        .segments
        .iter()
        .map(|seg| {
            let (p50, p95, max) = stats_of(&cycles[seg.start..seg.start + seg.len]);
            SegmentMetrics {
                start: seg.start,
                len: seg.len,
                up_silos: seg.up_count,
                p50_ms: p50,
                p95_ms: p95,
                max_ms: max,
            }
        })
        .collect();
    let (p50_ms, p95_ms, max_ms) = stats_of(&cycles);
    let iso_total: u64 = iso.iter().map(|&i| i as u64).sum();
    let isolation_rate = iso_total as f64 / (net.n() as f64 * rounds as f64);

    let mut recovery_rounds = 0usize;
    for w in &tl.outages {
        if w.start == 0 || w.end >= rounds {
            continue;
        }
        let Some(prev) = tl.segments.iter().find(|s| s.start <= w.start - 1 && w.start - 1 < s.start + s.len)
        else {
            continue;
        };
        let baseline = cycles[prev.start..w.start].iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let recovered_at = (w.end..rounds).find(|&r| cycles[r] <= baseline);
        recovery_rounds += match recovered_at {
            Some(r) => r - w.end,
            None => rounds - w.end,
        };
    }

    let summary = SimSummary {
        topology,
        network: net.name.clone(),
        profile: profile.name.clone(),
        rounds,
        mean_cycle_ms: total_ms / rounds as f64,
        total_ms,
        rounds_with_isolated,
        max_isolated,
        scenario: Some(ScenarioMetrics {
            segments,
            p50_ms,
            p95_ms,
            max_ms,
            isolation_rate,
            recovery_rounds,
            adapt: None,
        }),
    };
    let stats = EngineStats {
        kind,
        period,
        cycle_detected_at: None,
        cycle_len: None,
        simulated_rounds: rounds,
        groups,
    };
    (summary, stats)
}

/// The scenario oracle, end to end: masked naive tracker + shared
/// finalize. The bitwise reference every scenario engine is tested
/// against, and itself pinned equal to [`super::simulate_summary_naive`]
/// for the empty scenario.
pub fn simulate_summary_scenario_naive(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    sc: &ScenarioSpec,
) -> Result<SimSummary, String> {
    assert!(rounds > 0);
    let tl = build_timeline(sc, net, rounds)?;
    let (tau, iso) = run_scenario_tracker(topo, net, profile, &tl);
    let name = topo.name().to_string();
    let (summary, _) = finalize(
        name,
        net,
        profile,
        rounds,
        &tl,
        tau,
        iso,
        EngineKind::Streaming,
        None,
        None,
    );
    Ok(summary)
}

/// One base-schedule state filtered through a segment's up-mask: the
/// surviving `(edge id, type)` entries in plan order plus the masked
/// isolation count.
struct MaskedState {
    entries: Vec<(u32, EdgeType)>,
    isolated: usize,
}

/// Piecewise-static periodic engine over `lanes.len()` delay lanes
/// sharing one base [`CompiledTopology`] and one scenario. The masked
/// per-state tables are derived directly from the base compile (state
/// index = global round mod period; entries filtered by the segment's
/// mask), built lazily in round order so pairs entering the masked
/// schedule seed their d_0 at exactly the round — and with exactly the
/// filtered plan degrees — the naive tracker would use. Backlog carries
/// across segment boundaries per lane. With one lane this *is* the
/// scenario periodic engine; the sweep's batch chunks run several
/// lanes, each lane's f64 op sequence identical to its solo run.
fn run_scenario_lanes(
    rep: &CompiledTopology,
    lanes: &[BatchLane<'_>],
    rounds: usize,
    sc: &ScenarioSpec,
    kind: EngineKind,
) -> Result<Vec<(SimSummary, EngineStats)>, String> {
    assert!(rounds > 0);
    assert!(!lanes.is_empty(), "scenario batch must hold at least one lane");
    let n = rep.n();
    for lane in lanes {
        assert_eq!(
            lane.net.n(),
            n,
            "lane network '{}' has {} silos but the schedule was compiled over {}",
            lane.net.name,
            lane.net.n(),
            n
        );
        assert_eq!(
            lane.net.name, lanes[0].net.name,
            "scenario lanes must share one network (masks are geometry-derived)"
        );
        debug_assert!(
            lane.ct.schedule_eq(rep),
            "scenario lane '{}' does not share the representative schedule '{}'",
            lane.ct.name(),
            rep.name()
        );
    }
    let tl = build_timeline(sc, lanes[0].net, rounds)?;
    let l = lanes.len();
    let p = rep.period();
    let n_edges = rep.num_edges();
    let edge_table = rep.edge_table();

    let floors: Vec<f64> =
        lanes.iter().map(|lane| lane.profile.u as f64 * lane.profile.t_c_ms).collect();
    // Per-edge, per-lane slabs ([edge][lane]); `seeded` is lane-shared
    // (seeding rounds are structural).
    let mut seeded = vec![false; n_edges];
    let mut base_d0 = vec![0.0f64; n_edges * l];
    let mut backlog = vec![0.0f64; n_edges * l];
    let mut tau_series: Vec<Vec<f64>> = vec![Vec::with_capacity(rounds); l];
    let mut iso_series: Vec<u32> = Vec::with_capacity(rounds);
    let mut tau = vec![0.0f64; l];
    let mut degrees = vec![0u32; n];
    let mut has_edge = vec![false; n];
    let mut has_strong = vec![false; n];

    for seg in &tl.segments {
        // Lazy masked-state cache for this segment's mask. Built in
        // round order so first-appearance seeding matches the tracker.
        let mut masked: Vec<Option<MaskedState>> = (0..p).map(|_| None).collect();
        for r in 0..seg.len {
            let s = (seg.start + r) % p;
            if masked[s].is_none() {
                let (st_entries, _) = rep.state(s);
                let entries: Vec<(u32, EdgeType)> = st_entries
                    .iter()
                    .copied()
                    .filter(|&(id, _)| {
                        let e = &edge_table[id as usize];
                        seg.up[e.u as usize] && seg.up[e.v as usize]
                    })
                    .collect();
                // Seed pairs entering the masked schedule here, with
                // this filtered plan's degrees — mirroring the naive
                // tracker's entry-on-first-appearance.
                if entries.iter().any(|&(id, _)| !seeded[id as usize]) {
                    degrees.iter_mut().for_each(|d| *d = 0);
                    for &(id, _) in &entries {
                        let e = &edge_table[id as usize];
                        degrees[e.u as usize] += 1;
                        degrees[e.v as usize] += 1;
                    }
                    for &(id, _) in &entries {
                        let id = id as usize;
                        if !seeded[id] {
                            seeded[id] = true;
                            let e = &edge_table[id];
                            for (j, lane) in lanes.iter().enumerate() {
                                let d0 = pair_d0_ms(
                                    lane.net,
                                    lane.profile,
                                    e.u as usize,
                                    e.v as usize,
                                    degrees[e.u as usize] as usize,
                                    degrees[e.v as usize] as usize,
                                );
                                base_d0[id * l + j] = d0;
                                backlog[id * l + j] = d0 * seg.scale;
                            }
                        }
                    }
                }
                has_edge.iter_mut().for_each(|b| *b = false);
                has_strong.iter_mut().for_each(|b| *b = false);
                for &(id, ty) in &entries {
                    let e = &edge_table[id as usize];
                    has_edge[e.u as usize] = true;
                    has_edge[e.v as usize] = true;
                    if ty == EdgeType::Strong {
                        has_strong[e.u as usize] = true;
                        has_strong[e.v as usize] = true;
                    }
                }
                let isolated = (0..n).filter(|&i| has_edge[i] && !has_strong[i]).count();
                masked[s] = Some(MaskedState { entries, isolated });
            }
            let st = masked[s].as_ref().unwrap();

            // Eq. 5 τ per lane (serial fold in plan order, from the
            // lane floor — order-exact with the tracker's fold).
            tau.copy_from_slice(&floors);
            for &(id, ty) in &st.entries {
                if ty == EdgeType::Strong {
                    let base = id as usize * l;
                    for j in 0..l {
                        tau[j] = tau[j].max(floors[j].max(backlog[base + j]));
                    }
                }
            }
            // Eq. 4 advance in plan order; strong resets re-derive
            // base·scale exactly as the tracker does.
            for &(id, ty) in &st.entries {
                let base = id as usize * l;
                match ty {
                    EdgeType::Strong => {
                        for j in 0..l {
                            backlog[base + j] = base_d0[base + j] * seg.scale;
                        }
                    }
                    EdgeType::Weak => {
                        for j in 0..l {
                            let b = &mut backlog[base + j];
                            *b = (*b - tau[j]).max(floors[j]);
                        }
                    }
                }
            }
            for j in 0..l {
                tau_series[j].push(tau[j]);
            }
            iso_series.push(st.isolated as u32);
        }
    }

    Ok(lanes
        .iter()
        .zip(tau_series)
        .map(|(lane, tau_j)| {
            finalize(
                lane.ct.name().to_string(),
                lane.net,
                lane.profile,
                rounds,
                &tl,
                tau_j,
                iso_series.clone(),
                kind,
                Some(p),
                None,
            )
        })
        .collect())
}

/// Scenario periodic engine over one cell: piecewise-static masked
/// stepping of `ct`'s per-state tables. Bit-identical to the oracle.
pub fn run_scenario_compiled(
    ct: &CompiledTopology,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    sc: &ScenarioSpec,
) -> Result<(SimSummary, EngineStats), String> {
    let lane = BatchLane { ct, net, profile };
    let mut out =
        run_scenario_lanes(ct, std::slice::from_ref(&lane), rounds, sc, EngineKind::Periodic)?;
    Ok(out.pop().unwrap())
}

/// Scenario batch engine: several delay lanes sharing one schedule,
/// one network, and one scenario, stepped in lockstep. Per lane the
/// f64 op sequence is exactly [`run_scenario_compiled`]'s, so batch
/// composition never changes bits (stats report
/// [`EngineKind::Batched`]).
pub fn run_scenario_batched(
    rep: &CompiledTopology,
    lanes: &[BatchLane<'_>],
    rounds: usize,
    sc: &ScenarioSpec,
) -> Result<Vec<(SimSummary, EngineStats)>, String> {
    run_scenario_lanes(rep, lanes, rounds, sc, EngineKind::Batched)
}

/// Scenario factored engine: O(groups)-per-round group-max stepping
/// with the strong phase keyed to the *global* round, masks re-grouping
/// per segment, and per-edge backlog reconstructed at each segment
/// boundary by replaying the recorded τ suffix since the edge's last
/// strong round (sequentially — the closed-form drain is not bitwise
/// equal to the iterated one).
///
/// Returns `None` when the design exposes no (valid) factorization —
/// the caller falls through to the streaming path, mirroring the
/// static dispatcher.
pub fn run_scenario_factored(
    topo: &dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    sc: &ScenarioSpec,
) -> Option<Result<(SimSummary, EngineStats), String>> {
    assert!(rounds > 0);
    let f = topo.factorization()?;
    if f.n != net.n() {
        return None;
    }
    // Same admission checks as the static factored compile: malformed
    // edge lists fall back rather than corrupt.
    let mut seen = std::collections::HashSet::with_capacity(f.edges.len());
    let mut all_mults: Vec<u32> = Vec::new();
    for &(u, v, m) in &f.edges {
        if m == 0 || u >= v || v >= f.n || !seen.insert((u, v)) {
            return None;
        }
        if !all_mults.contains(&m) {
            all_mults.push(m);
        }
    }
    if all_mults.len() > MAX_FACTOR_GROUPS {
        return None;
    }

    let tl = match build_timeline(sc, net, rounds) {
        Ok(tl) => tl,
        Err(e) => return Some(Err(e)),
    };
    let floor = profile.u as f64 * profile.t_c_ms;
    let n_edges = f.edges.len();
    let mut seeded = vec![false; n_edges];
    let mut base_d0 = vec![0.0f64; n_edges];
    let mut backlog = vec![0.0f64; n_edges];
    let mut tau_series = Vec::with_capacity(rounds);
    let mut iso_series: Vec<u32> = Vec::with_capacity(rounds);

    for seg in &tl.segments {
        // Filtered edge set (plan order preserved) + round-constant
        // masked degrees.
        let filtered: Vec<usize> = (0..n_edges)
            .filter(|&e| {
                let (u, v, _) = f.edges[e];
                seg.up[u] && seg.up[v]
            })
            .collect();
        let mut degrees = vec![0u32; f.n];
        for &e in &filtered {
            let (u, v, _) = f.edges[e];
            degrees[u] += 1;
            degrees[v] += 1;
        }
        // Factorized plans list every (surviving) pair every round, so
        // pairs new to the schedule seed at the segment's first round.
        for &e in &filtered {
            if !seeded[e] {
                seeded[e] = true;
                let (u, v, _) = f.edges[e];
                let d0 =
                    pair_d0_ms(net, profile, u, v, degrees[u] as usize, degrees[v] as usize);
                base_d0[e] = d0;
                backlog[e] = d0 * seg.scale;
            }
        }
        // Group structure over the filtered set.
        let mut groups: Vec<u32> = Vec::new();
        let mut group_of = vec![0u32; filtered.len()];
        let mut node_mask = vec![0u64; f.n];
        for (fi, &e) in filtered.iter().enumerate() {
            let (u, v, m) = f.edges[e];
            let g = match groups.iter().position(|&x| x == m) {
                Some(g) => g,
                None => {
                    groups.push(m);
                    groups.len() - 1
                }
            };
            group_of[fi] = g as u32;
            node_mask[u] |= 1u64 << g;
            node_mask[v] |= 1u64 << g;
        }
        // Group envelopes: the representative backlog is the member
        // max (exact — both Eq. 4 ops are monotone and the reset
        // targets order with the scaled d_0s), carried-in values
        // included.
        let mut g_d0eff = vec![f64::NEG_INFINITY; groups.len()];
        let mut g_backlog = vec![f64::NEG_INFINITY; groups.len()];
        for (fi, &e) in filtered.iter().enumerate() {
            let g = group_of[fi] as usize;
            g_d0eff[g] = g_d0eff[g].max(base_d0[e] * seg.scale);
            g_backlog[g] = g_backlog[g].max(backlog[e]);
        }
        let mut iso_cache: HashMap<u64, usize> = HashMap::new();
        let mut tau_seg = Vec::with_capacity(seg.len);

        for r in 0..seg.len {
            let k = (seg.start + r) as u64;
            let mut active = 0u64;
            let mut tau = floor;
            for (g, &m) in groups.iter().enumerate() {
                if k % m as u64 == 0 {
                    active |= 1u64 << g;
                    tau = tau.max(floor.max(g_backlog[g]));
                }
            }
            for (g, b) in g_backlog.iter_mut().enumerate() {
                if active & (1u64 << g) != 0 {
                    *b = g_d0eff[g];
                } else {
                    *b = (*b - tau).max(floor);
                }
            }
            let iso = *iso_cache.entry(active).or_insert_with(|| {
                node_mask.iter().filter(|&&m| m != 0 && m & active == 0).count()
            });
            tau_seg.push(tau);
            tau_series.push(tau);
            iso_series.push(iso as u32);
        }

        // Carry-out: rebuild each filtered edge's backlog by replaying
        // its post-reset τ suffix sequentially (the op sequence the
        // tracker applied to it).
        let end = seg.start + seg.len;
        for &e in &filtered {
            let (_, _, m) = f.edges[e];
            let m = m as usize;
            let last_strong = ((end - 1) / m) * m;
            let (mut b, from) = if last_strong >= seg.start {
                (base_d0[e] * seg.scale, last_strong - seg.start + 1)
            } else {
                (backlog[e], 0)
            };
            for &t in &tau_seg[from..] {
                b = (b - t).max(floor);
            }
            backlog[e] = b;
        }
    }

    let name = topo.name().to_string();
    Some(Ok(finalize(
        name,
        net,
        profile,
        rounds,
        &tl,
        tau_series,
        iso_series,
        EngineKind::Factored,
        None,
        Some(all_mults.len()),
    )))
}

/// Scenario engine dispatcher, mirroring the static
/// [`super::simulate_summary_scratch`] tiers: periodic (base schedule
/// materializable within the round budget) → factored (base schedule
/// factorizes) → streaming (the masked naive tracker). The dispatch is
/// a pure function of the design's structure and the round budget;
/// every tier is bit-identical to the oracle.
pub fn simulate_summary_scenario(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    sc: &ScenarioSpec,
) -> Result<(SimSummary, EngineStats), String> {
    assert!(rounds > 0);
    if let Some(ct) = CompiledTopology::compile(topo, rounds) {
        return run_scenario_compiled(&ct, net, profile, rounds, sc);
    }
    if let Some(res) = run_scenario_factored(topo, net, profile, rounds, sc) {
        return res;
    }
    let tl = build_timeline(sc, net, rounds)?;
    let (tau, iso) = run_scenario_tracker(topo, net, profile, &tl);
    let name = topo.name().to_string();
    Ok(finalize(
        name,
        net,
        profile,
        rounds,
        &tl,
        tau,
        iso,
        EngineKind::Streaming,
        None,
        None,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, TopologyKind};
    use crate::net::zoo;
    use crate::simtime::simulate_summary_naive;
    use crate::topo::MultigraphTopology;

    fn gaia_multigraph(t: u32) -> (NetworkSpec, DatasetProfile, MultigraphTopology) {
        let net = zoo::gaia();
        let prof = DatasetProfile::femnist();
        let topo = MultigraphTopology::from_network(&net, &prof, t);
        (net, prof, topo)
    }

    fn churn_spec() -> ScenarioSpec {
        ScenarioSpec::from_event_strs(
            9,
            &[
                "leave@13:silo=3",
                "scale@20:factor=1.5",
                "rejoin@41:silo=3",
                "jitter@50:amp=4.0",
                "outage@70:frac=0.3:dur=18",
                "scale@95:factor=1.0",
            ],
        )
        .unwrap()
    }

    fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) {
        assert_eq!(a.topology, b.topology, "{ctx}");
        assert_eq!(a.network, b.network, "{ctx}");
        assert_eq!(a.profile, b.profile, "{ctx}");
        assert_eq!(a.rounds, b.rounds, "{ctx}");
        assert_eq!(
            a.total_ms.to_bits(),
            b.total_ms.to_bits(),
            "{ctx}: total_ms {} vs {}",
            a.total_ms,
            b.total_ms
        );
        assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}");
        assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}");
        assert_eq!(a.max_isolated, b.max_isolated, "{ctx}");
        assert_eq!(a.scenario, b.scenario, "{ctx}: scenario metrics");
    }

    #[test]
    fn event_dsl_round_trips_and_rejects_garbage() {
        let sc = churn_spec();
        let strs = sc.event_strs();
        let back = ScenarioSpec::from_event_strs(9, &strs).unwrap();
        assert_eq!(sc, back);
        assert_eq!(sc.fingerprint(), back.fingerprint());
        let explicit = ScenarioSpec::parse_event("outage@5:frac=0.5:dur=3:epicenter=2").unwrap();
        assert_eq!(
            explicit.kind,
            EventKind::Outage { frac: 0.5, dur: 3, epicenter: Some(2) }
        );
        for bad in [
            "leave",
            "leave@x:silo=1",
            "leave@4",
            "leave@4:frob=1",
            "scale@4:factor=0",
            "scale@4:factor=nope",
            "jitter@4:amp=-1",
            "outage@4:frac=0:dur=5",
            "outage@4:frac=1.5:dur=5",
            "outage@4:frac=0.5:dur=0",
            "meteor@4:size=big",
        ] {
            assert!(ScenarioSpec::parse_event(bad).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn fingerprints_split_on_any_change() {
        let a = churn_spec();
        let mut b = a.clone();
        b.seed = 10;
        let mut c = a.clone();
        c.events.pop();
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn empty_scenario_oracle_matches_static_naive_bitwise() {
        let sc = ScenarioSpec { seed: 1, events: Vec::new() };
        for kind in TopologyKind::all() {
            let cfg = ExperimentConfig {
                network: "gaia".into(),
                topology: kind,
                t: 5,
                sim_rounds: 120,
                ..Default::default()
            };
            let net = cfg.resolve_network();
            let prof = cfg.resolve_profile().unwrap();
            let mut a = cfg.build_topology();
            let mut b = cfg.build_topology();
            let want = simulate_summary_naive(a.as_mut(), &net, &prof, 120);
            let got = simulate_summary_scenario_naive(b.as_mut(), &net, &prof, 120, &sc).unwrap();
            assert_eq!(want.total_ms.to_bits(), got.total_ms.to_bits(), "{kind:?}");
            assert_eq!(want.rounds_with_isolated, got.rounds_with_isolated, "{kind:?}");
            assert_eq!(want.max_isolated, got.max_isolated, "{kind:?}");
        }
    }

    #[test]
    fn empty_scenario_dispatch_matches_static_naive_bitwise() {
        let sc = ScenarioSpec { seed: 1, events: Vec::new() };
        let (net, prof, _) = gaia_multigraph(5);
        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let mut b = MultigraphTopology::from_network(&net, &prof, 5);
        let want = simulate_summary_naive(&mut a, &net, &prof, 150);
        let (got, stats) = simulate_summary_scenario(&mut b, &net, &prof, 150, &sc).unwrap();
        assert_eq!(stats.kind, EngineKind::Periodic);
        assert_eq!(stats.simulated_rounds, 150);
        assert_eq!(want.total_ms.to_bits(), got.total_ms.to_bits());
        assert_eq!(want.rounds_with_isolated, got.rounds_with_isolated);
    }

    #[test]
    fn churn_scenario_periodic_matches_oracle_bitwise() {
        let sc = churn_spec();
        let (net, prof, _) = gaia_multigraph(5);
        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let mut b = MultigraphTopology::from_network(&net, &prof, 5);
        let want = simulate_summary_scenario_naive(&mut a, &net, &prof, 200, &sc).unwrap();
        let (got, stats) = simulate_summary_scenario(&mut b, &net, &prof, 200, &sc).unwrap();
        assert_eq!(stats.kind, EngineKind::Periodic);
        assert!(stats.cycle_detected_at.is_none(), "no cycle replay under scenarios");
        assert_bitwise(&want, &got, "periodic vs oracle");
        let m = got.scenario.as_ref().unwrap();
        assert!(m.segments.len() >= 5, "expected several segments, got {}", m.segments.len());
        assert_eq!(m.segments.iter().map(|s| s.len).sum::<usize>(), 200);
        assert!(m.isolation_rate > 0.0);
        assert!(m.max_ms >= m.p95_ms && m.p95_ms >= m.p50_ms);
    }

    #[test]
    fn churn_scenario_factored_matches_oracle_bitwise() {
        let sc = churn_spec();
        for t in [5u32, 20] {
            let (net, prof, _) = gaia_multigraph(t);
            let mut a = MultigraphTopology::from_network(&net, &prof, t);
            let b = MultigraphTopology::from_network(&net, &prof, t);
            let want = simulate_summary_scenario_naive(&mut a, &net, &prof, 180, &sc).unwrap();
            let (got, stats) = run_scenario_factored(&b, &net, &prof, 180, &sc)
                .expect("multigraph factorizes")
                .unwrap();
            assert_eq!(stats.kind, EngineKind::Factored, "t={t}");
            assert!(stats.groups.unwrap() >= 1);
            assert_bitwise(&want, &got, &format!("factored vs oracle t={t}"));
        }
    }

    #[test]
    fn churn_scenario_batched_lanes_match_solo_bitwise() {
        let sc = churn_spec();
        let (net, _, _) = gaia_multigraph(5);
        let profiles = DatasetProfile::all();
        let compiles: Vec<CompiledTopology> = profiles
            .iter()
            .map(|prof| {
                let mut topo = MultigraphTopology::from_network(&net, prof, 5);
                CompiledTopology::compile(&mut topo, 160).expect("gaia t=5 materializes")
            })
            .collect();
        let lanes: Vec<BatchLane> = profiles
            .iter()
            .zip(&compiles)
            .map(|(prof, ct)| BatchLane { ct, net: &net, profile: prof })
            .collect();
        let got = run_scenario_batched(&compiles[0], &lanes, 160, &sc).unwrap();
        assert_eq!(got.len(), profiles.len());
        for ((prof, ct), (summary, stats)) in profiles.iter().zip(&compiles).zip(&got) {
            assert_eq!(stats.kind, EngineKind::Batched);
            let (solo, _) = run_scenario_compiled(ct, &net, prof, 160, &sc).unwrap();
            assert_bitwise(summary, &solo, &format!("lane {} vs solo", prof.name));
            let mut naive = MultigraphTopology::from_network(&net, prof, 5);
            let want = simulate_summary_scenario_naive(&mut naive, &net, prof, 160, &sc).unwrap();
            assert_bitwise(summary, &want, &format!("lane {} vs oracle", prof.name));
        }
    }

    #[test]
    fn streaming_designs_take_the_tracker_and_scale_is_identity_at_one() {
        // MATCHA has no period and no factorization: the dispatcher
        // must stream. And a scale=1.0 "shift" must be a bitwise no-op.
        let cfg = ExperimentConfig {
            network: "gaia".into(),
            topology: TopologyKind::Matcha,
            sim_rounds: 90,
            ..Default::default()
        };
        let net = cfg.resolve_network();
        let prof = cfg.resolve_profile().unwrap();
        let sc = ScenarioSpec::from_event_strs(3, &["scale@10:factor=1.0"]).unwrap();
        let mut a = cfg.build_topology();
        let mut b = cfg.build_topology();
        let want = simulate_summary_naive(a.as_mut(), &net, &prof, 90);
        let (got, stats) = simulate_summary_scenario(b.as_mut(), &net, &prof, 90, &sc).unwrap();
        assert_eq!(stats.kind, EngineKind::Streaming);
        assert_eq!(want.total_ms.to_bits(), got.total_ms.to_bits());
    }

    #[test]
    fn capacity_scale_shifts_cycle_times() {
        let (net, prof, _) = gaia_multigraph(5);
        let sc = ScenarioSpec::from_event_strs(1, &["scale@0:factor=2.0"]).unwrap();
        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let mut base = MultigraphTopology::from_network(&net, &prof, 5);
        let (scaled, _) = simulate_summary_scenario(&mut a, &net, &prof, 100, &sc).unwrap();
        let plain = simulate_summary_naive(&mut base, &net, &prof, 100);
        assert!(
            scaled.total_ms > plain.total_ms,
            "doubling d0 must slow the run: {} vs {}",
            scaled.total_ms,
            plain.total_ms
        );
    }

    #[test]
    fn jitter_adds_time_without_touching_isolation_or_backlog() {
        let (net, prof, _) = gaia_multigraph(5);
        let sc = ScenarioSpec::from_event_strs(7, &["jitter@0:amp=10.0"]).unwrap();
        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let mut base = MultigraphTopology::from_network(&net, &prof, 5);
        let (jit, _) = simulate_summary_scenario(&mut a, &net, &prof, 100, &sc).unwrap();
        let plain = simulate_summary_naive(&mut base, &net, &prof, 100);
        assert!(jit.total_ms > plain.total_ms);
        assert!(jit.total_ms < plain.total_ms + 10.0 * 100.0);
        assert_eq!(jit.rounds_with_isolated, plain.rounds_with_isolated);
        assert_eq!(jit.max_isolated, plain.max_isolated);
    }

    #[test]
    fn outage_is_deterministic_and_reports_recovery() {
        let (net, prof, _) = gaia_multigraph(5);
        let sc = ScenarioSpec::from_event_strs(11, &["outage@60:frac=0.3:dur=20"]).unwrap();
        let tl = build_timeline(&sc, &net, 200).unwrap();
        assert_eq!(tl.outages, vec![OutageWindow { start: 60, end: 80 }]);
        let down: Vec<usize> = (0..net.n())
            .filter(|&i| !tl.segments.iter().find(|s| s.start == 60).unwrap().up[i])
            .collect();
        assert_eq!(down.len(), (0.3f64 * net.n() as f64).ceil() as usize);
        let tl2 = build_timeline(&sc, &net, 200).unwrap();
        let down2: Vec<usize> = (0..net.n())
            .filter(|&i| !tl2.segments.iter().find(|s| s.start == 60).unwrap().up[i])
            .collect();
        assert_eq!(down, down2, "outage region must be seed-deterministic");

        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let (got, _) = simulate_summary_scenario(&mut a, &net, &prof, 200, &sc).unwrap();
        let m = got.scenario.unwrap();
        assert_eq!(m.segments.len(), 3, "pre / outage / post");
        assert_eq!(m.segments[1].up_silos, net.n() - down.len());
        assert!(m.recovery_rounds <= 120);
    }

    #[test]
    fn bad_silo_and_empty_network_error_structurally() {
        let (net, prof, _) = gaia_multigraph(5);
        let sc = ScenarioSpec::from_event_strs(1, &["leave@0:silo=99"]).unwrap();
        let err = build_timeline(&sc, &net, 50).unwrap_err();
        assert!(err.contains("silo 99"), "{err}");

        let events: Vec<String> =
            (0..net.n()).map(|i| format!("leave@5:silo={i}")).collect();
        let sc = ScenarioSpec::from_event_strs(1, &events).unwrap();
        let err = build_timeline(&sc, &net, 50).unwrap_err();
        assert!(err.contains("at round 5"), "{err}");
        let mut topo = MultigraphTopology::from_network(&net, &prof, 5);
        assert!(simulate_summary_scenario(&mut topo, &net, &prof, 50, &sc).is_err());
    }

    #[test]
    fn backlog_carries_across_segment_boundaries() {
        // A leave/rejoin pair whose segments are shorter than the
        // period forces cross-boundary carry on every engine; the
        // bitwise pin against the oracle is the real assertion, this
        // test just guards the premise that segments < period occur.
        let (net, prof, topo) = gaia_multigraph(5);
        let p = topo.s_max() as usize;
        let sc = ScenarioSpec::from_event_strs(
            2,
            &["leave@3:silo=1", "rejoin@7:silo=1", "leave@11:silo=5", "rejoin@13:silo=5"],
        )
        .unwrap();
        let tl = build_timeline(&sc, &net, 3 * p).unwrap();
        assert!(tl.segments.iter().any(|s| s.len < p), "premise: short segments exist");
        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let mut b = MultigraphTopology::from_network(&net, &prof, 5);
        let want = simulate_summary_scenario_naive(&mut a, &net, &prof, 3 * p, &sc).unwrap();
        let (got, _) = simulate_summary_scenario(&mut b, &net, &prof, 3 * p, &sc).unwrap();
        assert_bitwise(&want, &got, "carry across boundaries");
        let c = MultigraphTopology::from_network(&net, &prof, 5);
        let (fact, _) = run_scenario_factored(&c, &net, &prof, 3 * p, &sc).unwrap().unwrap();
        assert_bitwise(&want, &fact, "factored carry across boundaries");
    }
}
