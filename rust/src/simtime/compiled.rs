//! Compiled zero-allocation simulation engine + exact cycle-detection
//! fast path.
//!
//! The naive path ([`super::simulate_summary_naive`]) pays, per round:
//! a fresh `RoundPlan` vec, a degrees vec, a strong-delays vec, and two
//! `HashMap<(usize, usize)>` probes per edge. This module compiles a
//! [`TopologyDesign`] into a **dense edge arena** — stable edge ids, a
//! flat `d0`/`backlog` slab, per-state (edge id, type) tables and
//! isolation counts — so the per-round step is a plain walk over edge
//! ids with zero allocation and zero hashing.
//!
//! Since PR 3 the compilation product is split into two layers:
//!
//! * [`CompiledTopology`] — the **immutable, shareable** part: stable
//!   edge ids with the (pair, first-appearance degrees) needed to seed
//!   delays, per-state edge tables, and precomputed isolation counts.
//!   It holds no network- or profile-resolved numbers, so one compile
//!   can be wrapped in an `Arc` and simulated under any delay inputs
//!   and round budget (the sweep engine's build-once cache does exactly
//!   this; see `crate::sweep::cache`).
//! * [`DelaySlab`] — the **per-cell, mutable** part: the `d0` slab
//!   resolved against a concrete (network, profile) plus the Eq. 4
//!   `backlog` slab the round loop mutates. Cheap to build, never
//!   shared.
//!
//! On top of that sits an **exact cycle-detection fast path**: periodic
//! schedules ([`TopologyDesign::period`]) drive a finite-state system —
//! [`crate::delay::EdgeDelayState`] resets to `d0` on every strong
//! round, so the full simulator state (state index + backlog
//! bit-patterns) recurs exactly. The engine snapshots backlog bits at
//! period boundaries, detects the first recurrence, and replays the
//! recorded τ sequence with the same sequential f64 accumulation — a
//! 6400-round cell costs roughly one period of real per-edge work while
//! every artifact stays byte-identical to the naive path. (Schedules
//! with an all-strong state 0 — the overlay state every in-tree periodic
//! design starts with — are guaranteed to recur by round `2·period`.)
//! While the detector is live it does pay for itself: one τ push per
//! round plus an O(edges) snapshot per period boundary, until the
//! recurrence fires or the detector gives up after `MAX_SNAPSHOTS`
//! boundaries; "zero allocation" describes the steady per-round edge
//! walk, not the bounded detector bookkeeping.
//!
//! Designs whose period is too large to materialize but whose schedule
//! factorizes into per-multiplicity groups (the parsed multigraph at
//! t = 30 has s_max ≈ 2.3e9) run on the **factored engine**
//! ([`super::factored`]): O(distinct multiplicities) per round, no
//! states materialized. Everything else — stochastic MATCHA,
//! structureless third-party designs — runs on the **streaming
//! engine**: the same arena and scratch buffers, fed by
//! [`TopologyDesign::plan_into`] each round (with a rayon
//! chunk-parallel τ reduce on large plans) — still zero hashing and
//! zero steady-state allocation, just no replay.
//!
//! Bit-identity with the reference path is not best-effort: both paths
//! seed d_0 through [`pair_d0_ms`], apply the same Eq. 4 update in the
//! same per-round order, and accumulate `total_ms` in round order. The
//! simcore bench, `tests/sweep_determinism.rs`, and the proptest suite
//! (`tests/proptest_simcore.rs`) all pin `SimSummary` equality down to
//! the bits.

use crate::delay::{pair_d0_ms, EdgeType};
use crate::net::{DatasetProfile, NetworkSpec};
use crate::topo::{RoundPlan, TopologyDesign};

use super::SimSummary;

/// Largest period the engine will materialize per-state tables for.
/// Beyond this (e.g. multigraph s_max at t ≥ 20) the streaming engine
/// runs instead; the fast path would never fire inside a realistic
/// round budget anyway.
pub const MAX_COMPILED_STATES: u64 = 1 << 16;

/// Snapshots the cycle detector retains before giving up. Every in-tree
/// periodic schedule recurs by the second period boundary (state 0 is
/// all-strong), so this is pure insurance against exotic third-party
/// designs — it bounds detector memory, never correctness. Shared with
/// the batched engine's per-lane detectors ([`super::batched`]).
pub(crate) const MAX_SNAPSHOTS: usize = 64;

/// Which engine executed a simulation. The dispatch order in
/// [`simulate_summary_scratch`] is: periodic (materializable period)
/// → factored (schedule exposes a multiplicity factorization) →
/// streaming (everything else).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Per-state tables materialized; exact cycle detection + replay.
    Periodic,
    /// Cross-cell SoA batch ([`super::batched`]): many cells sharing
    /// one periodic schedule stepped in lockstep, per-lane cycle
    /// detection and replay.
    Batched,
    /// Period-factorized group engine ([`super::factored`]):
    /// O(distinct multiplicities) per round.
    Factored,
    /// Arena-backed per-edge streaming (stochastic or structureless
    /// schedules).
    Streaming,
}

impl EngineKind {
    /// Stable lowercase label (report JSON/CSV, summary lines).
    pub fn as_str(&self) -> &'static str {
        match self {
            EngineKind::Periodic => "periodic",
            EngineKind::Batched => "batched",
            EngineKind::Factored => "factored",
            EngineKind::Streaming => "streaming",
        }
    }
}

/// How a simulation was executed. Deterministic for a given cell spec
/// (the dispatch consumes no randomness and no wall-clock), so it may
/// ride along in sweep reports without breaking artifact determinism.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineStats {
    /// Which engine ran.
    pub kind: EngineKind,
    /// The materialized period, if the periodic engine ran.
    pub period: Option<usize>,
    /// Round at which the cycle detector fired, if it did.
    pub cycle_detected_at: Option<usize>,
    /// Length of the detected cycle.
    pub cycle_len: Option<usize>,
    /// Rounds that did real per-edge (or, factored, per-group) work —
    /// the rest were replayed from a detected cycle.
    pub simulated_rounds: usize,
    /// Distinct multiplicity groups (factored engine only).
    pub groups: Option<usize>,
}

/// One stable edge id's identity: the normalized pair plus the plan
/// degrees of the state it first appeared in — everything [`DelaySlab`]
/// needs to resolve the pair's d_0 under a concrete (network, profile),
/// and nothing that depends on one.
#[derive(Debug, Clone, Copy)]
pub struct CompiledEdge {
    /// Lower endpoint (u < v).
    pub u: u32,
    /// Upper endpoint.
    pub v: u32,
    /// Plan degree of `u` when the pair first appeared.
    pub deg_u: u32,
    /// Plan degree of `v` when the pair first appeared.
    pub deg_v: u32,
}

/// One compiled schedule state: edge ids with their connection type, in
/// plan order (the advance pass must run in the exact order the naive
/// tracker walks `plan.edges`, or a plan listing the same pair twice
/// with mixed types would diverge), plus the precomputed isolated-node
/// count (isolation depends only on the plan, never on delays).
#[derive(Debug, Clone)]
struct StateTable {
    edges: Vec<(u32, EdgeType)>,
    isolated: usize,
}

/// The immutable product of compiling a periodic [`TopologyDesign`]:
/// stable edge ids, per-state edge tables, isolation counts. Contains
/// no delay numbers — those live in the per-cell [`DelaySlab`] — so a
/// single compile is `Send + Sync` plain data, shareable via `Arc`
/// across every simulation of the same schedule.
#[derive(Debug, Clone)]
pub struct CompiledTopology {
    name: String,
    n: usize,
    edges: Vec<CompiledEdge>,
    states: Vec<StateTable>,
}

impl CompiledTopology {
    /// Enumerate states `0..period` once and build the edge/state
    /// tables. Returns `None` when the design is stochastic or the
    /// period is too large to materialize profitably within `rounds`
    /// (those cells run the streaming engine instead).
    pub fn compile(topo: &mut dyn TopologyDesign, rounds: usize) -> Option<Self> {
        let p = topo.period()?;
        if p == 0 || p > MAX_COMPILED_STATES || p > rounds as u64 {
            return None;
        }
        let p = p as usize;
        let n = topo.overlay().n();
        // Row-major (min, max) pair → edge id; `u32::MAX` = unassigned.
        // Only needed while compiling — the run loop walks edge ids.
        let mut pair_id = vec![u32::MAX; n * n];
        let mut edges: Vec<CompiledEdge> = Vec::new();
        let mut plan = RoundPlan::empty(n);
        let mut degrees: Vec<usize> = Vec::new();
        let mut states = Vec::with_capacity(p);
        for s in 0..p {
            topo.plan_into(s, &mut plan);
            let mut st = StateTable { edges: Vec::new(), isolated: plan.isolated_nodes().len() };
            let mut degrees_ready = false;
            for &(u, v, ty) in &plan.edges {
                let (a, b) = if u <= v { (u, v) } else { (v, u) };
                let mut id = pair_id[a * n + b];
                if id == u32::MAX {
                    // A pair entering the schedule records the degrees
                    // of the plan it first appears in — exactly when
                    // (and with what) the naive tracker would seed its
                    // d_0, because rounds 0..p visit states 0..p in
                    // order.
                    if !degrees_ready {
                        plan.degrees_into(&mut degrees);
                        degrees_ready = true;
                    }
                    id = edges.len() as u32;
                    pair_id[a * n + b] = id;
                    edges.push(CompiledEdge {
                        u: u as u32,
                        v: v as u32,
                        deg_u: degrees[u] as u32,
                        deg_v: degrees[v] as u32,
                    });
                }
                st.edges.push((id, ty));
            }
            states.push(st);
        }
        Some(CompiledTopology { name: topo.name().to_string(), n, edges, states })
    }

    /// Name of the design this schedule was compiled from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Silo count the schedule was compiled over (must match the
    /// network a [`DelaySlab`] is resolved against).
    pub fn n(&self) -> usize {
        self.n
    }

    /// The materialized schedule period.
    pub fn period(&self) -> usize {
        self.states.len()
    }

    /// Distinct pairs the schedule ever plans.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// The stable edge-id table (lane delay resolution in
    /// [`super::batched`] seeds per-lane d_0 from it).
    pub(crate) fn edge_table(&self) -> &[CompiledEdge] {
        &self.edges
    }

    /// State `s`'s (edge id, type) table in plan order, plus its
    /// precomputed isolated-node count.
    pub(crate) fn state(&self, s: usize) -> (&[(u32, EdgeType)], usize) {
        let st = &self.states[s];
        (&st.edges, st.isolated)
    }

    /// FNV-1a fingerprint of the compiled *schedule* — edge identities
    /// and per-state tables, the design **name excluded** — so two
    /// designs compiling to the same schedule fingerprint equal. A
    /// cheap grouping key for the sweep batch planner; equal
    /// fingerprints are confirmed with [`Self::schedule_eq`] before
    /// cells share a batch, so a collision can never corrupt results.
    pub fn schedule_fingerprint(&self) -> u64 {
        fn fnv_u64(mut h: u64, x: u64) -> u64 {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001B3);
            }
            h
        }
        let mut h = 0xCBF29CE484222325u64;
        h = fnv_u64(h, self.n as u64);
        h = fnv_u64(h, self.edges.len() as u64);
        h = fnv_u64(h, self.states.len() as u64);
        for e in &self.edges {
            h = fnv_u64(h, e.u as u64);
            h = fnv_u64(h, e.v as u64);
            h = fnv_u64(h, e.deg_u as u64);
            h = fnv_u64(h, e.deg_v as u64);
        }
        for st in &self.states {
            h = fnv_u64(h, st.edges.len() as u64);
            for &(id, ty) in &st.edges {
                h = fnv_u64(h, id as u64);
                h = fnv_u64(h, matches!(ty, EdgeType::Strong) as u64);
            }
            h = fnv_u64(h, st.isolated as u64);
        }
        h
    }

    /// Structural schedule equality — same silo count, same edge
    /// identities, same per-state tables (ids, types, plan order,
    /// isolation counts); the design name is excluded. Two compiles
    /// that are `schedule_eq` drive bit-identical simulations for any
    /// given delay inputs, which is the batched engine's admission
    /// contract.
    pub fn schedule_eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.edges.len() == other.edges.len()
            && self.states.len() == other.states.len()
            && self
                .edges
                .iter()
                .zip(&other.edges)
                .all(|(a, b)| {
                    a.u == b.u && a.v == b.v && a.deg_u == b.deg_u && a.deg_v == b.deg_v
                })
            && self
                .states
                .iter()
                .zip(&other.states)
                .all(|(a, b)| a.isolated == b.isolated && a.edges == b.edges)
    }
}

/// The per-cell mutable layer over a shared [`CompiledTopology`]: the
/// d_0 slab resolved against one (network, profile) plus the Eq. 4
/// backlog slab the round loop mutates.
#[derive(Debug, Clone, Default)]
pub struct DelaySlab {
    d0: Vec<f64>,
    backlog: Vec<f64>,
}

impl DelaySlab {
    /// Resolve `ct`'s edges against a concrete network and profile.
    ///
    /// `net` must be the network the design behind `ct` was built for
    /// (same silo count, same silos) — the compiled structure encodes
    /// that network's schedule, only the delay numbers are resolved
    /// here.
    pub fn new(ct: &CompiledTopology, net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        let mut slab = DelaySlab::default();
        slab.resolve(ct, net, profile);
        slab
    }

    /// Like [`Self::new`] but reusing this slab's allocations — the
    /// scratch-pool entry point for cells of the same shape.
    pub fn resolve(&mut self, ct: &CompiledTopology, net: &NetworkSpec, profile: &DatasetProfile) {
        assert_eq!(
            ct.n,
            net.n(),
            "compiled topology '{}' has {} silos but network '{}' has {}",
            ct.name,
            ct.n,
            net.name,
            net.n()
        );
        self.d0.clear();
        self.d0.extend(ct.edges.iter().map(|e| {
            pair_d0_ms(
                net,
                profile,
                e.u as usize,
                e.v as usize,
                e.deg_u as usize,
                e.deg_v as usize,
            )
        }));
        // The backlog slab is materialized by `reset()` at run entry
        // (run_compiled always resets), so resolving skips one copy.
        self.backlog.clear();
    }

    /// (Re)seed the backlog to the fresh-transfer state — Alg. 1 seeds
    /// edge delays from the overlay (all strong), mirroring
    /// `EdgeDelayState::new` — making the slab reusable across runs.
    pub fn reset(&mut self) {
        self.backlog.clear();
        self.backlog.extend_from_slice(&self.d0);
    }
}

/// One simulated round over slab-resident edges: the Eq. 5 inner max
/// (mirroring `strong_delay_ms` + the fold in `round_cycle_time_ms`;
/// f64::max is order-insensitive here, all delays positive and non-NaN)
/// followed by the Eq. 4 advance (mirroring `EdgeDelayState::advance`)
/// **in plan order** — the same per-edge order the naive tracker uses,
/// which keeps plans listing a pair twice with mixed types bit-exact.
/// Shared by the periodic and streaming engines so the bit-identity-
/// critical inner loop exists exactly once. Returns τ_k.
#[inline]
fn step_edges(d0: &[f64], backlog: &mut [f64], edges: &[(u32, EdgeType)], floor: f64) -> f64 {
    let tau = reduce_tau(backlog, edges, floor);
    // The Eq. 4 advance stays serial: exotic plans may list one pair
    // twice (the id appears twice), so a parallel in-place update would
    // race. The read-only τ reduce above is where large-N cells spend
    // their time anyway.
    for &(id, ty) in edges {
        match ty {
            EdgeType::Strong => backlog[id as usize] = d0[id as usize],
            EdgeType::Weak => {
                let b = &mut backlog[id as usize];
                *b = (*b - tau).max(floor);
            }
        }
    }
    tau
}

/// Sequential Eq. 5 inner max — the bit-identity-critical fold.
#[inline]
fn reduce_tau_serial(backlog: &[f64], edges: &[(u32, EdgeType)], floor: f64) -> f64 {
    let mut tau = floor;
    for &(id, ty) in edges {
        if ty == EdgeType::Strong {
            tau = tau.max(floor.max(backlog[id as usize]));
        }
    }
    tau
}

/// Edge count below which the parallel τ reduce is not worth the
/// fork/join overhead (rayon builds only).
#[cfg(feature = "rayon")]
const PAR_REDUCE_MIN_EDGES: usize = 1 << 13;

/// Portable chunked τ reduce for scalar (non-rayon) builds: four
/// independent max accumulators walk the plan in chunks of four and
/// fold at the end, breaking the serial `max` dependency chain so the
/// hot reduce gets ILP without any fork/join machinery. `f64::max` is
/// exact and order-independent on the positive finite delays the model
/// produces (no NaN, no signed zero mixing), so the result is
/// bit-identical to [`reduce_tau_serial`].
#[cfg(not(feature = "rayon"))]
#[inline]
fn reduce_tau(backlog: &[f64], edges: &[(u32, EdgeType)], floor: f64) -> f64 {
    let mut m = [floor; 4];
    let mut chunks = edges.chunks_exact(4);
    for chunk in &mut chunks {
        for (lane, &(id, ty)) in m.iter_mut().zip(chunk) {
            if ty == EdgeType::Strong {
                *lane = lane.max(floor.max(backlog[id as usize]));
            }
        }
    }
    let mut tau = m[0].max(m[1]).max(m[2].max(m[3]));
    for &(id, ty) in chunks.remainder() {
        if ty == EdgeType::Strong {
            tau = tau.max(floor.max(backlog[id as usize]));
        }
    }
    tau
}

/// Chunk-parallel τ reduce for large streaming cells (N = 4096
/// synthetic networks plan thousands of edges per round): each chunk
/// folds serially, chunk maxima combine with `f64::max`. Exact and
/// order-independent on the positive finite delays the model produces,
/// so the result is bit-identical to the serial fold regardless of
/// chunking or scheduling.
#[cfg(feature = "rayon")]
#[inline]
fn reduce_tau(backlog: &[f64], edges: &[(u32, EdgeType)], floor: f64) -> f64 {
    use rayon::prelude::*;
    if edges.len() < PAR_REDUCE_MIN_EDGES {
        return reduce_tau_serial(backlog, edges, floor);
    }
    edges
        .par_chunks(PAR_REDUCE_MIN_EDGES / 2)
        .map(|chunk| reduce_tau_serial(backlog, chunk, floor))
        .reduce(|| floor, f64::max)
}

/// Periodic engine: per-round step over a (possibly `Arc`-shared)
/// [`CompiledTopology`] and a per-cell [`DelaySlab`], with exact cycle
/// detection + sequential replay. Resets the slab on entry, so one slab
/// may be reused across runs.
pub fn run_compiled(
    ct: &CompiledTopology,
    slab: &mut DelaySlab,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> (SimSummary, EngineStats) {
    assert!(rounds > 0);
    slab.reset();
    let p = ct.states.len();
    let floor = profile.u as f64 * profile.t_c_ms;
    let mut total_ms = 0.0;
    let mut rounds_with_isolated = 0usize;
    let mut max_isolated = 0usize;

    // Cycle detector: recording τ is only worthwhile if a recurrence can
    // fire before the run ends.
    let mut detecting = p < rounds;
    let mut rec_tau: Vec<f64> = Vec::new();
    let mut snapshots: Vec<(usize, Vec<u64>)> = Vec::new();
    let mut cycle: Option<(usize, usize)> = None; // (start round, length)

    let mut k = 0usize;
    while k < rounds {
        let s = k % p;
        if detecting && s == 0 {
            // The simulator state entering round k is (s, backlog bits);
            // an exact repeat means the τ/isolation future repeats too.
            let snap: Vec<u64> = slab.backlog.iter().map(|b| b.to_bits()).collect();
            if let Some(&(k0, _)) = snapshots.iter().find(|(_, old)| *old == snap) {
                cycle = Some((k0, k - k0));
                break;
            }
            if snapshots.len() >= MAX_SNAPSHOTS {
                // Give up: stop paying for snapshots and τ recording.
                detecting = false;
                rec_tau = Vec::new();
                snapshots = Vec::new();
            } else {
                snapshots.push((k, snap));
            }
        }

        let st = &ct.states[s];
        let tau = step_edges(&slab.d0, &mut slab.backlog, &st.edges, floor);

        total_ms += tau;
        if st.isolated > 0 {
            rounds_with_isolated += 1;
            max_isolated = max_isolated.max(st.isolated);
        }
        if detecting {
            rec_tau.push(tau);
        }
        k += 1;
    }

    let simulated_rounds = k;
    if let Some((k0, len)) = cycle {
        // Replay: the τ sequence from the cycle repeats verbatim, so the
        // remaining rounds are pure sequential adds of recorded values —
        // identical accumulation order, identical bits, ~zero work.
        for j in k..rounds {
            total_ms += rec_tau[k0 + (j - k0) % len];
            let iso = ct.states[j % p].isolated;
            if iso > 0 {
                rounds_with_isolated += 1;
                max_isolated = max_isolated.max(iso);
            }
        }
    }

    let summary = SimSummary {
        topology: ct.name.clone(),
        network: net.name.clone(),
        profile: profile.name.clone(),
        rounds,
        mean_cycle_ms: total_ms / rounds as f64,
        total_ms,
        rounds_with_isolated,
        max_isolated,
        scenario: None,
    };
    let stats = EngineStats {
        kind: EngineKind::Periodic,
        period: Some(p),
        cycle_detected_at: cycle.map(|_| simulated_rounds),
        cycle_len: cycle.map(|(_, len)| len),
        simulated_rounds,
        groups: None,
    };
    (summary, stats)
}

/// Dense per-pair delay state for the streaming engine: stable edge ids
/// assigned on first appearance, O(1) pair→id lookup without hashing.
struct EdgeArena {
    n: usize,
    /// Row-major (min, max) pair → edge id; `u32::MAX` = unassigned.
    pair_id: Vec<u32>,
    /// Static Eq. 3 pair delay (fresh-transfer cost) per edge id.
    d0: Vec<f64>,
    /// Eq. 4 backlog per edge id.
    backlog: Vec<f64>,
}

impl Default for EdgeArena {
    fn default() -> Self {
        EdgeArena { n: 0, pair_id: Vec::new(), d0: Vec::new(), backlog: Vec::new() }
    }
}

impl EdgeArena {
    /// Clear for a fresh cell over `n` silos, reusing allocations
    /// (cells of the same shape stop paying the O(N²) pair-table
    /// allocation; the `u32::MAX` refill is a memset).
    fn reset(&mut self, n: usize) {
        self.n = n;
        self.pair_id.clear();
        self.pair_id.resize(n * n, u32::MAX);
        self.d0.clear();
        self.backlog.clear();
    }

    #[inline]
    fn id(&self, u: usize, v: usize) -> u32 {
        let (a, b) = if u <= v { (u, v) } else { (v, u) };
        self.pair_id[a * self.n + b]
    }

    fn insert(&mut self, u: usize, v: usize, d0: f64) -> u32 {
        let (a, b) = if u <= v { (u, v) } else { (v, u) };
        let id = self.d0.len() as u32;
        self.pair_id[a * self.n + b] = id;
        self.d0.push(d0);
        // Alg. 1 seeds edge delays from the overlay (all strong):
        // mirrors `EdgeDelayState::new`.
        self.backlog.push(d0);
        id
    }
}

/// Reusable scratch for the streaming engine: the edge arena plus every
/// per-round buffer. One per worker thread (the sweep scratch pool)
/// stops large-N cells from reallocating the O(N²) pair table and the
/// per-round vecs cell after cell.
#[derive(Default)]
pub struct StreamScratch {
    arena: EdgeArena,
    plan: RoundPlan,
    ids: Vec<(u32, EdgeType)>,
    degrees: Vec<usize>,
    has_edge: Vec<bool>,
    has_strong: Vec<bool>,
}

/// Streaming engine: arena-backed stepping for stochastic or
/// structureless designs. Zero hashing, zero steady-state allocation —
/// plans, ids, degrees, and isolation scratch live in `scratch` and are
/// reused both across rounds and (via the sweep pool) across cells.
fn run_streaming(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    scratch: &mut StreamScratch,
) -> (SimSummary, EngineStats) {
    let n = net.n();
    let floor = profile.u as f64 * profile.t_c_ms;
    scratch.arena.reset(n);
    let StreamScratch { arena, plan, ids, degrees, has_edge, has_strong } = scratch;

    let mut total_ms = 0.0;
    let mut rounds_with_isolated = 0usize;
    let mut max_isolated = 0usize;

    for k in 0..rounds {
        topo.plan_into(k, plan);
        ids.clear();
        let mut degrees_ready = false;
        for &(u, v, ty) in &plan.edges {
            let mut id = arena.id(u, v);
            if id == u32::MAX {
                if !degrees_ready {
                    plan.degrees_into(degrees);
                    degrees_ready = true;
                }
                id = arena.insert(u, v, pair_d0_ms(net, profile, u, v, degrees[u], degrees[v]));
            }
            ids.push((id, ty));
        }

        let tau = step_edges(&arena.d0, &mut arena.backlog, ids, floor);
        let isolated = plan.isolated_count_into(has_edge, has_strong);

        total_ms += tau;
        if isolated > 0 {
            rounds_with_isolated += 1;
            max_isolated = max_isolated.max(isolated);
        }
    }

    let summary = SimSummary {
        topology: topo.name().to_string(),
        network: net.name.clone(),
        profile: profile.name.clone(),
        rounds,
        mean_cycle_ms: total_ms / rounds as f64,
        total_ms,
        rounds_with_isolated,
        max_isolated,
        scenario: None,
    };
    let stats = EngineStats {
        kind: EngineKind::Streaming,
        period: None,
        cycle_detected_at: None,
        cycle_len: None,
        simulated_rounds: rounds,
        groups: None,
    };
    (summary, stats)
}

/// Per-thread bundle of every engine's reusable mutable layer. The
/// sweep cache keeps one per worker thread (`sweep::cache`'s
/// thread-local pool); standalone entry points create a fresh one per
/// call. Reuse never changes results — each engine fully re-resolves /
/// resets its layer per cell, pinned by the slab-reuse tests.
#[derive(Default)]
pub struct SimScratch {
    /// Periodic engine: d_0 + backlog slab.
    pub slab: DelaySlab,
    /// Batched engine: the `[edge][lane]` SoA slabs.
    pub batched: super::batched::BatchSlab,
    /// Factored engine: group envelopes + representative backlog.
    pub factored: super::factored::FactoredSlab,
    /// Streaming engine: edge arena + per-round buffers.
    pub stream: StreamScratch,
}

/// Compiled-engine equivalent of [`super::simulate_summary_naive`]:
/// bit-identical `SimSummary`, a fraction of the work.
pub fn simulate_summary_compiled(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> SimSummary {
    simulate_summary_compiled_with_stats(topo, net, profile, rounds).0
}

/// Like [`simulate_summary_compiled`] but also reporting how the engine
/// executed (which path, whether the cycle fast path fired).
pub fn simulate_summary_compiled_with_stats(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> (SimSummary, EngineStats) {
    let mut scratch = SimScratch::default();
    simulate_summary_scratch(topo, net, profile, rounds, &mut scratch)
}

/// The engine dispatcher, over caller-owned scratch:
///
/// 1. **periodic** — the schedule's period is materializable within
///    the round budget ([`CompiledTopology::compile`]): per-state
///    tables + exact cycle replay;
/// 2. **factored** — the design exposes a
///    [`crate::topo::ScheduleFactorization`]
///    ([`super::factored::FactoredTopology::compile`]): O(distinct
///    multiplicities) per round, no states materialized;
/// 3. **streaming** — everything else (stochastic MATCHA, third-party
///    designs): O(E) per round over the edge arena, with the rayon
///    chunk-parallel τ reduce on large plans.
///
/// All three are bit-identical to [`super::simulate_summary_naive`];
/// the dispatch is a pure function of the design's structure and the
/// round budget, so which engine runs is deterministic per cell.
pub fn simulate_summary_scratch(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    scratch: &mut SimScratch,
) -> (SimSummary, EngineStats) {
    assert!(rounds > 0);
    if let Some(ct) = CompiledTopology::compile(topo, rounds) {
        scratch.slab.resolve(&ct, net, profile);
        return run_compiled(&ct, &mut scratch.slab, net, profile, rounds);
    }
    if let Some(ft) = super::factored::FactoredTopology::compile(topo) {
        scratch.factored.resolve(&ft, net, profile);
        return super::factored::run_factored(&ft, &mut scratch.factored, net, profile, rounds);
    }
    run_streaming(topo, net, profile, rounds, &mut scratch.stream)
}

/// Force the streaming engine, bypassing the periodic/factored fast
/// paths — the per-edge oracle benches and tests measure factored
/// speedups against, and the only engine available to designs without
/// structure. Bit-identical to every other path.
pub fn simulate_summary_streaming_with_stats(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> (SimSummary, EngineStats) {
    let mut scratch = SimScratch::default();
    simulate_summary_streaming_scratch(topo, net, profile, rounds, &mut scratch)
}

/// [`simulate_summary_streaming_with_stats`] over caller-owned scratch —
/// the sweep cache's streaming-verdict arm, where re-running the
/// periodic/factored compile attempts would waste the cached verdict.
pub fn simulate_summary_streaming_scratch(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
    scratch: &mut SimScratch,
) -> (SimSummary, EngineStats) {
    assert!(rounds > 0);
    run_streaming(topo, net, profile, rounds, &mut scratch.stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, TopologyKind};
    use crate::net::zoo;
    use crate::simtime::simulate_summary_naive;
    use crate::topo::MultigraphTopology;

    fn assert_bitwise_equal(a: &SimSummary, b: &SimSummary) {
        assert_eq!(a.topology, b.topology);
        assert_eq!(a.network, b.network);
        assert_eq!(a.profile, b.profile);
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(
            a.total_ms.to_bits(),
            b.total_ms.to_bits(),
            "total_ms {} vs {} ({}/{}/{})",
            a.total_ms,
            b.total_ms,
            a.topology,
            a.network,
            a.profile
        );
        assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits());
        assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated);
        assert_eq!(a.max_isolated, b.max_isolated);
    }

    fn compare(kind: TopologyKind, network: &str, t: u32, rounds: usize) -> EngineStats {
        let cfg = ExperimentConfig {
            network: network.into(),
            topology: kind,
            t,
            sim_rounds: rounds,
            ..Default::default()
        };
        let net = cfg.resolve_network();
        let prof = cfg.resolve_profile().unwrap();
        let mut a = cfg.build_topology();
        let mut b = cfg.build_topology();
        let naive = simulate_summary_naive(a.as_mut(), &net, &prof, rounds);
        let (fast, stats) = simulate_summary_compiled_with_stats(b.as_mut(), &net, &prof, rounds);
        assert_bitwise_equal(&naive, &fast);
        stats
    }

    #[test]
    fn every_design_matches_naive_on_every_network() {
        for net in zoo::all_networks() {
            for kind in TopologyKind::all() {
                compare(kind, &net.name, 5, 130);
            }
        }
    }

    #[test]
    fn cycle_fast_path_fires_on_multigraph_and_stays_exact() {
        // Gaia t=5: state 0 is all-strong, so the simulator state must
        // recur within two periods. A 6400-round cell then does at most
        // 2·s_max rounds of real work — the rest is replay — and still
        // matches the naive path bitwise (checked inside `compare`).
        // The bitwise assert doubles as the replay-is-sequential guard:
        // a `cycle_sum × repeats` replay diverges from the naive sum in
        // the low bits at this round count and would fail `compare`.
        let net = zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        let p = MultigraphTopology::from_network(&net, &prof, 5).s_max() as usize;
        assert!(p >= 2 && p <= 6400, "test premise: periodic schedule shorter than the run");
        let stats = compare(TopologyKind::Multigraph, "gaia", 5, 6400);
        assert_eq!(stats.kind, EngineKind::Periodic);
        assert_eq!(stats.period, Some(p));
        let detected = stats.cycle_detected_at.expect("cycle must be detected");
        assert!(detected <= 2 * p, "detected at {detected}, period {p}");
        assert_eq!(stats.simulated_rounds, detected);
        let len = stats.cycle_len.expect("cycle length");
        assert!(len % p == 0 && len <= 2 * p, "cycle length {len} vs period {p}");
        // The acceptance bar: ≥ 5× less real work on the paper's cell.
        assert!(stats.simulated_rounds * 5 <= 6400, "fast path saved < 5x");
    }

    #[test]
    fn static_designs_detect_a_length_one_cycle() {
        for kind in [TopologyKind::Ring, TopologyKind::Star, TopologyKind::Mst] {
            let stats = compare(kind, "gaia", 5, 500);
            assert_eq!(stats.kind, EngineKind::Periodic);
            assert_eq!(stats.period, Some(1));
            assert_eq!(stats.cycle_len, Some(1));
            assert_eq!(stats.simulated_rounds, 1, "{kind:?} should replay after round 0");
        }
    }

    #[test]
    fn stochastic_matcha_streams_and_matches() {
        let stats = compare(TopologyKind::Matcha, "gaia", 5, 300);
        assert_eq!(
            stats.kind,
            EngineKind::Streaming,
            "stochastic MATCHA must take the streaming engine"
        );
        assert_eq!(stats.simulated_rounds, 300);
    }

    #[test]
    fn large_period_takes_the_factored_engine() {
        // High-t multigraphs (paper Table 6 goes to t = 30) can have an
        // s_max far beyond any round budget; those cells skip the
        // periodic compile and take the factored engine — and still
        // match the oracle (checked inside `compare`).
        let net = zoo::exodus();
        let prof = crate::net::DatasetProfile::femnist();
        for t in [20u32, 30] {
            let s_max = MultigraphTopology::from_network(&net, &prof, t).s_max();
            let stats = compare(TopologyKind::Multigraph, "exodus", t, 90);
            if s_max > 90 {
                assert_eq!(
                    stats.kind,
                    EngineKind::Factored,
                    "t={t}: s_max={s_max} must take the factored engine"
                );
                assert!(stats.groups.unwrap() >= 2);
            }
            assert_eq!(stats.simulated_rounds, 90);
        }
    }

    #[test]
    fn period_longer_than_run_still_matches() {
        // Gaia t=5 has s_max > 2; at rounds = 2 the periodic compile is
        // skipped (no replay could fire) and the multigraph's factored
        // closed form runs instead — still bit-identical.
        let net = zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        assert!(MultigraphTopology::from_network(&net, &prof, 5).s_max() > 2);
        let stats = compare(TopologyKind::Multigraph, "gaia", 5, 2);
        assert_eq!(stats.kind, EngineKind::Factored);
    }

    #[test]
    fn forced_streaming_matches_every_fast_path() {
        // The public streaming entry bypasses both fast paths; all
        // three engines must agree bitwise on a factorizable cell.
        let cfg = ExperimentConfig {
            network: "gaia".into(),
            topology: TopologyKind::Multigraph,
            t: 30,
            sim_rounds: 140,
            ..Default::default()
        };
        let net = cfg.resolve_network();
        let prof = cfg.resolve_profile().unwrap();
        let mut a = cfg.build_topology();
        let mut b = cfg.build_topology();
        let (stream, s_stats) = simulate_summary_streaming_with_stats(a.as_mut(), &net, &prof, 140);
        let (fast, f_stats) = simulate_summary_compiled_with_stats(b.as_mut(), &net, &prof, 140);
        assert_eq!(s_stats.kind, EngineKind::Streaming);
        assert_eq!(f_stats.kind, EngineKind::Factored);
        assert_bitwise_equal(&stream, &fast);
    }

    #[test]
    fn shared_compiled_topology_matches_fresh_compiles() {
        // One compile, many simulations: the Arc-shareable half must be
        // reusable across round budgets and across runs of one slab,
        // each bit-identical to a fresh end-to-end simulation.
        let net = zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        let mut topo = MultigraphTopology::from_network(&net, &prof, 5);
        let ct = CompiledTopology::compile(&mut topo, 500).expect("gaia t=5 is materializable");
        assert_eq!(ct.name(), "multigraph");
        assert_eq!(ct.n(), net.n());
        assert_eq!(ct.period(), topo.s_max() as usize);
        assert!(ct.num_edges() > 0);

        let mut slab = DelaySlab::new(&ct, &net, &prof);
        for rounds in [130usize, 500, 130] {
            let (got, stats) = run_compiled(&ct, &mut slab, &net, &prof, rounds);
            assert_eq!(stats.kind, EngineKind::Periodic);
            let mut fresh = MultigraphTopology::from_network(&net, &prof, 5);
            let want = simulate_summary_naive(&mut fresh, &net, &prof, rounds);
            assert_bitwise_equal(&want, &got);
        }
    }

    #[test]
    fn split_compile_is_exact_on_every_profile() {
        // The compiled structure holds no profile-resolved numbers —
        // delay resolution happens entirely in DelaySlab::new. Pin that
        // split against the naive oracle for each Table 2 profile.
        let net = zoo::gaia();
        for prof in crate::net::DatasetProfile::all() {
            let mut topo = MultigraphTopology::from_network(&net, &prof, 5);
            let ct = CompiledTopology::compile(&mut topo, 200).expect("materializable");
            let mut slab = DelaySlab::new(&ct, &net, &prof);
            let (got, _) = run_compiled(&ct, &mut slab, &net, &prof, 200);
            let mut fresh = MultigraphTopology::from_network(&net, &prof, 5);
            let want = simulate_summary_naive(&mut fresh, &net, &prof, 200);
            assert_bitwise_equal(&want, &got);
        }
    }

    #[test]
    fn schedule_fingerprint_tracks_structural_equality() {
        let net = zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let mut b = MultigraphTopology::from_network(&net, &prof, 5);
        let ca = CompiledTopology::compile(&mut a, 200).unwrap();
        let cb = CompiledTopology::compile(&mut b, 200).unwrap();
        assert!(ca.schedule_eq(&cb));
        assert!(ca.schedule_eq(&ca));
        assert_eq!(ca.schedule_fingerprint(), cb.schedule_fingerprint());

        // A different t changes the schedule: fingerprints must split.
        let mut c = MultigraphTopology::from_network(&net, &prof, 3);
        let cc = CompiledTopology::compile(&mut c, 200).unwrap();
        assert!(!ca.schedule_eq(&cc));
        assert_ne!(ca.schedule_fingerprint(), cc.schedule_fingerprint());

        // Same design over a different network: different structure.
        let exodus = zoo::exodus();
        let mut d = MultigraphTopology::from_network(&exodus, &prof, 5);
        let cd = CompiledTopology::compile(&mut d, 200).unwrap();
        assert!(!ca.schedule_eq(&cd));
        assert_ne!(ca.schedule_fingerprint(), cd.schedule_fingerprint());
    }

    #[test]
    #[should_panic(expected = "silos")]
    fn delay_slab_rejects_mismatched_network() {
        let gaia = zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        let mut topo = MultigraphTopology::from_network(&gaia, &prof, 5);
        let ct = CompiledTopology::compile(&mut topo, 200).unwrap();
        let _ = DelaySlab::new(&ct, &zoo::exodus(), &prof);
    }
}
