//! Period-factorized simulation engine: O(distinct multiplicities) per
//! round for schedules that expose a [`ScheduleFactorization`].
//!
//! The parsed multigraph's closed form (Algorithm 2, `topo::states`)
//! says a pair with multiplicity n is strong exactly when
//! `k % n == 0`. Two consequences make the per-round edge walk
//! collapsible:
//!
//! 1. **Edges with the same multiplicity share one schedule.** They are
//!    strong in the same rounds and weak in the same rounds, so they
//!    all undergo the *same sequence* of Eq. 4 operations: reset to
//!    their own d_0 on strong rounds, `x → max(floor, x − τ)` on weak
//!    rounds. Both operations are monotone non-decreasing in the edge's
//!    value, and the reset targets order like the d_0s do — so the
//!    group's maximum backlog is, at every round, exactly the backlog
//!    of its maximum-d_0 edge, computed by the very same iterated f64
//!    ops the naive tracker applies to that edge. One representative
//!    value per group replaces the whole group.
//! 2. **τ regroups exactly.** The round cycle time is a fold of
//!    `f64::max` over strong-edge contributions; `max` on positive
//!    finite f64 is exact, associative and commutative, so folding
//!    per-group maxima instead of per-edge values is bit-identical.
//!
//! The steady-state per-round cost is therefore O(m) where m =
//! distinct multiplicities (m ≤ t, typically < 10 — independent of N),
//! instead of the streaming engine's O(E): the N = 4096, t = 30 cells
//! that PR 4 opened stop paying 4096 edge visits per round and pay ~10
//! group updates. Isolation counts depend only on *which* multiplicity
//! groups are strong this round (a node is isolated iff it has edges
//! and none of its incident groups is active), so they are memoized
//! per active-group bitmask — O(N) once per distinct mask, O(1)
//! amortized.
//!
//! Bit-identity with [`super::simulate_summary_naive`] is by
//! construction, not best-effort: d_0 seeds through the shared
//! [`pair_d0_ms`] with the same round-0 plan degrees, the
//! representative backlog applies the same `(b − τ).max(floor)` /
//! reset updates in the same per-round order, and `total_ms`
//! accumulates τ sequentially in round order. The regrouping argument
//! above was additionally cross-validated bitwise against a Python f64
//! model (5000 randomized trials, adversarial floors included);
//! in-tree, `tests/factored_engine.rs`, the factored proptest suite,
//! and `benches/factored.rs` pin the equality down to the bits.
//!
//! Like `compiled.rs`, the product splits into an immutable shareable
//! half ([`FactoredTopology`] — group structure, edge identities, node
//! masks; `Arc`-able across cells) and a per-cell mutable half
//! ([`FactoredSlab`] — the (network, profile)-resolved group envelopes
//! plus the running backlog), so the sweep cache can compile once per
//! (topology, network, profile, t) and simulate under any round budget.

use std::collections::HashMap;

use crate::delay::pair_d0_ms;
use crate::net::{DatasetProfile, NetworkSpec};
use crate::topo::TopologyDesign;

use super::compiled::{EngineKind, EngineStats};
use super::SimSummary;

/// The factored engine tracks active groups in a u64 bitmask; a
/// factorization with more distinct multiplicities than this (never the
/// multigraph — multiplicities are bounded by t) falls back to
/// streaming.
pub const MAX_FACTOR_GROUPS: usize = 64;

/// One factored edge: the pair, the (round-constant) plan degrees that
/// seed its d_0, and the multiplicity group it belongs to.
#[derive(Debug, Clone, Copy)]
struct FactoredEdge {
    u: u32,
    v: u32,
    deg_u: u32,
    deg_v: u32,
    group: u32,
}

/// The immutable, `Arc`-shareable product of compiling a
/// [`crate::topo::ScheduleFactorization`]: per-multiplicity groups,
/// edge identities (for d_0 resolution), and per-node incident-group
/// bitmasks (for isolation counting). Holds no delay numbers — those
/// live in the per-cell [`FactoredSlab`].
#[derive(Debug, Clone)]
pub struct FactoredTopology {
    name: String,
    n: usize,
    edges: Vec<FactoredEdge>,
    /// Distinct multiplicities, in first-appearance (edge) order; the
    /// group index is the position here.
    groups: Vec<u32>,
    /// Bit g set ⇔ the node has an incident edge in group g. A node
    /// with no edges at all has mask 0 and is never isolated (matching
    /// `RoundPlan::mark_participation`: no edge ⇒ not isolated).
    node_mask: Vec<u64>,
}

impl FactoredTopology {
    /// Compile `topo`'s factorization, if it exposes one. Returns
    /// `None` when the design does not factorize, when the edge list
    /// is malformed (a multiplicity of 0, an unnormalized pair, or a
    /// pair listed twice — the tracker would share one delay state
    /// where the grouping would fork it), or when there are more than
    /// [`MAX_FACTOR_GROUPS`] distinct multiplicities — those cells run
    /// the streaming engine instead.
    pub fn compile(topo: &dyn TopologyDesign) -> Option<Self> {
        let f = topo.factorization()?;
        // Round-constant plan degrees: the factorization contract says
        // every round plans exactly these edges, so the round-0 degrees
        // the naive tracker seeds d_0 with are the degrees over the
        // full edge list.
        let mut degrees = vec![0u32; f.n];
        let mut seen = std::collections::HashSet::with_capacity(f.edges.len());
        for &(u, v, m) in &f.edges {
            if m == 0 || u >= v || v >= f.n || !seen.insert((u, v)) {
                return None;
            }
            degrees[u] += 1;
            degrees[v] += 1;
        }
        let mut groups: Vec<u32> = Vec::new();
        let mut node_mask = vec![0u64; f.n];
        let mut edges = Vec::with_capacity(f.edges.len());
        for &(u, v, m) in &f.edges {
            let group = match groups.iter().position(|&g| g == m) {
                Some(g) => g,
                None => {
                    if groups.len() >= MAX_FACTOR_GROUPS {
                        return None;
                    }
                    groups.push(m);
                    groups.len() - 1
                }
            };
            node_mask[u] |= 1u64 << group;
            node_mask[v] |= 1u64 << group;
            edges.push(FactoredEdge {
                u: u as u32,
                v: v as u32,
                deg_u: degrees[u],
                deg_v: degrees[v],
                group: group as u32,
            });
        }
        Some(FactoredTopology { name: topo.name().to_string(), n: f.n, edges, groups, node_mask })
    }

    /// Name of the design this schedule was factored from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Silo count the schedule was compiled over.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Distinct multiplicity groups — the per-round work factor.
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Pairs in the factorized schedule.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }
}

/// The per-cell mutable layer over a shared [`FactoredTopology`]: the
/// group d_0 envelopes resolved against one (network, profile), the
/// running representative backlog per group, and the per-active-mask
/// isolation-count memo. Reusable across cells via [`Self::resolve`]
/// (the sweep scratch pool holds one per worker thread).
#[derive(Debug, Clone, Default)]
pub struct FactoredSlab {
    /// max d_0 over each group's edges — the value a group's
    /// representative backlog resets to on strong rounds.
    d0_max: Vec<f64>,
    /// Representative (= maximum) backlog per group.
    backlog: Vec<f64>,
    /// active-group bitmask → isolated-node count. Structure-only (no
    /// delay numbers), lazily filled at O(N) per distinct mask.
    iso_cache: HashMap<u64, usize>,
}

impl FactoredSlab {
    /// Fresh slab resolved against one (network, profile).
    pub fn new(ft: &FactoredTopology, net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        let mut slab = FactoredSlab::default();
        slab.resolve(ft, net, profile);
        slab
    }

    /// (Re)resolve against `ft` under a concrete network and profile,
    /// reusing this slab's allocations. `net` must be the network the
    /// design behind `ft` was built for.
    pub fn resolve(&mut self, ft: &FactoredTopology, net: &NetworkSpec, profile: &DatasetProfile) {
        assert_eq!(
            ft.n,
            net.n(),
            "factored topology '{}' has {} silos but network '{}' has {}",
            ft.name,
            ft.n,
            net.name,
            net.n()
        );
        self.d0_max.clear();
        self.d0_max.resize(ft.groups.len(), f64::NEG_INFINITY);
        for e in &ft.edges {
            let d0 = pair_d0_ms(
                net,
                profile,
                e.u as usize,
                e.v as usize,
                e.deg_u as usize,
                e.deg_v as usize,
            );
            let slot = &mut self.d0_max[e.group as usize];
            *slot = slot.max(d0);
        }
        // The backlog is materialized by `reset()` at run entry.
        self.backlog.clear();
        // The memo keys are masks of whatever ft was resolved last;
        // a re-resolve may target a different schedule.
        self.iso_cache.clear();
    }

    /// (Re)seed the representative backlogs to the fresh-transfer
    /// state, mirroring `EdgeDelayState::new` (backlog = d_0).
    pub fn reset(&mut self) {
        self.backlog.clear();
        self.backlog.extend_from_slice(&self.d0_max);
    }

    /// Isolated-node count under `active` (bit g ⇔ group g strong this
    /// round): nodes with edges but no incident active group.
    #[inline]
    fn iso_count(&mut self, ft: &FactoredTopology, active: u64) -> usize {
        *self.iso_cache.entry(active).or_insert_with(|| {
            ft.node_mask.iter().filter(|&&m| m != 0 && m & active == 0).count()
        })
    }
}

/// Factored engine: per-round step over a (possibly `Arc`-shared)
/// [`FactoredTopology`] and a per-cell [`FactoredSlab`], O(groups) per
/// round. Resets the slab on entry, so one slab may be reused across
/// runs. Bit-identical to the naive/streaming paths (see module docs).
pub fn run_factored(
    ft: &FactoredTopology,
    slab: &mut FactoredSlab,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> (SimSummary, EngineStats) {
    assert!(rounds > 0);
    assert_eq!(
        slab.d0_max.len(),
        ft.groups.len(),
        "slab must be resolved against this factored topology before running"
    );
    slab.reset();
    let floor = profile.u as f64 * profile.t_c_ms;
    let mut total_ms = 0.0;
    let mut rounds_with_isolated = 0usize;
    let mut max_isolated = 0usize;

    for k in 0..rounds {
        // Pass 1 — τ_k: the Eq. 5 inner max over strong edges. Each
        // active group contributes its representative (= maximum)
        // backlog; regrouping the naive per-edge fold is exact because
        // f64::max is order-independent on positive finite values.
        let mut active = 0u64;
        let mut tau = floor;
        for (g, &m) in ft.groups.iter().enumerate() {
            if k as u64 % m as u64 == 0 {
                active |= 1u64 << g;
                tau = tau.max(floor.max(slab.backlog[g]));
            }
        }
        // Pass 2 — Eq. 4 advance, mirroring `step_edges`: strong
        // groups reset to their d_0 envelope, weak groups drain by τ.
        for (g, b) in slab.backlog.iter_mut().enumerate() {
            if active & (1u64 << g) != 0 {
                *b = slab.d0_max[g];
            } else {
                *b = (*b - tau).max(floor);
            }
        }

        total_ms += tau;
        let iso = slab.iso_count(ft, active);
        if iso > 0 {
            rounds_with_isolated += 1;
            max_isolated = max_isolated.max(iso);
        }
    }

    let summary = SimSummary {
        topology: ft.name.clone(),
        network: net.name.clone(),
        profile: profile.name.clone(),
        rounds,
        mean_cycle_ms: total_ms / rounds as f64,
        total_ms,
        rounds_with_isolated,
        max_isolated,
        scenario: None,
    };
    let stats = EngineStats {
        kind: EngineKind::Factored,
        period: None,
        cycle_detected_at: None,
        cycle_len: None,
        simulated_rounds: rounds,
        groups: Some(ft.groups.len()),
    };
    (summary, stats)
}

/// One-shot convenience: compile `topo`'s factorization and run it.
/// `None` when the design does not factorize (the dispatcher then falls
/// back to the streaming engine).
pub fn simulate_summary_factored_with_stats(
    topo: &dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> Option<(SimSummary, EngineStats)> {
    let ft = FactoredTopology::compile(topo)?;
    let mut slab = FactoredSlab::new(&ft, net, profile);
    Some(run_factored(&ft, &mut slab, net, profile, rounds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, TopologyKind};
    use crate::net::zoo;
    use crate::simtime::simulate_summary_naive;
    use crate::topo::MultigraphTopology;

    fn assert_bitwise_equal(a: &SimSummary, b: &SimSummary, ctx: &str) {
        assert_eq!(a.topology, b.topology, "{ctx}");
        assert_eq!(a.network, b.network, "{ctx}");
        assert_eq!(a.profile, b.profile, "{ctx}");
        assert_eq!(a.rounds, b.rounds, "{ctx}");
        assert_eq!(
            a.total_ms.to_bits(),
            b.total_ms.to_bits(),
            "{ctx}: total_ms {} vs {}",
            a.total_ms,
            b.total_ms
        );
        assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}");
        assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}");
        assert_eq!(a.max_isolated, b.max_isolated, "{ctx}");
    }

    fn compare_multigraph(network: &str, t: u32, rounds: usize) {
        let cfg = ExperimentConfig {
            network: network.into(),
            topology: TopologyKind::Multigraph,
            t,
            sim_rounds: rounds,
            ..Default::default()
        };
        let net = cfg.resolve_network();
        let prof = cfg.resolve_profile().unwrap();
        let mut a = cfg.build_topology();
        let b = cfg.build_topology();
        let naive = simulate_summary_naive(a.as_mut(), &net, &prof, rounds);
        let (fast, stats) = simulate_summary_factored_with_stats(b.as_ref(), &net, &prof, rounds)
            .expect("multigraph factorizes");
        assert_bitwise_equal(&naive, &fast, &format!("{network} t={t} rounds={rounds}"));
        assert_eq!(stats.kind, EngineKind::Factored);
        assert_eq!(stats.simulated_rounds, rounds);
        assert!(stats.groups.unwrap() >= 1);
    }

    #[test]
    fn factored_matches_naive_small_period() {
        // t = 5 (s_max = 60 on gaia): factored, periodic, and naive all
        // agree even where the periodic engine would normally run.
        compare_multigraph("gaia", 5, 150);
        compare_multigraph("gaia", 1, 40); // t=1: a single all-strong group
    }

    #[test]
    fn factored_matches_naive_huge_period() {
        // t ∈ {20, 30}: s_max is far beyond any materializable table —
        // exactly the cells the engine exists for.
        for t in [20u32, 30] {
            compare_multigraph("gaia", t, 300);
            compare_multigraph("exodus", t, 300);
        }
    }

    #[test]
    fn group_count_equals_distinct_multiplicities() {
        let net = zoo::exodus();
        let prof = crate::net::DatasetProfile::femnist();
        let topo = MultigraphTopology::from_network(&net, &prof, 30);
        let ft = FactoredTopology::compile(&topo).unwrap();
        let mut mults: Vec<u32> = topo.multigraph().edges.iter().map(|e| e.n_edges).collect();
        mults.sort_unstable();
        mults.dedup();
        assert_eq!(ft.num_groups(), mults.len());
        assert_eq!(ft.num_edges(), topo.multigraph().edges.len());
        assert_eq!(ft.n(), net.n());
        assert_eq!(ft.name(), "multigraph");
        // The whole point: group count is tiny and N-independent.
        assert!(ft.num_groups() <= 30, "groups bounded by t");
    }

    #[test]
    fn non_factorizable_designs_return_none() {
        let net = zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        for kind in [TopologyKind::Matcha, TopologyKind::Ring, TopologyKind::Star] {
            let cfg = ExperimentConfig {
                network: "gaia".into(),
                topology: kind,
                ..Default::default()
            };
            let topo = cfg.build_topology();
            assert!(
                FactoredTopology::compile(topo.as_ref()).is_none(),
                "{kind:?} must not claim a factorization"
            );
            let got = simulate_summary_factored_with_stats(topo.as_ref(), &net, &prof, 10);
            assert!(got.is_none());
        }
    }

    #[test]
    fn slab_reuse_across_cells_is_exact() {
        // One slab resolved against cell A, then cell B, must equal a
        // fresh slab on cell B — the scratch-pool contract.
        let prof = crate::net::DatasetProfile::femnist();
        let gaia = zoo::gaia();
        let exodus = zoo::exodus();
        let topo_a = MultigraphTopology::from_network(&gaia, &prof, 20);
        let topo_b = MultigraphTopology::from_network(&exodus, &prof, 30);
        let ft_a = FactoredTopology::compile(&topo_a).unwrap();
        let ft_b = FactoredTopology::compile(&topo_b).unwrap();

        let mut pooled = FactoredSlab::default();
        pooled.resolve(&ft_a, &gaia, &prof);
        let (got_a, _) = run_factored(&ft_a, &mut pooled, &gaia, &prof, 120);
        pooled.resolve(&ft_b, &exodus, &prof);
        let (got_b, _) = run_factored(&ft_b, &mut pooled, &exodus, &prof, 120);

        let mut fresh_a = FactoredSlab::new(&ft_a, &gaia, &prof);
        let (want_a, _) = run_factored(&ft_a, &mut fresh_a, &gaia, &prof, 120);
        let mut fresh_b = FactoredSlab::new(&ft_b, &exodus, &prof);
        let (want_b, _) = run_factored(&ft_b, &mut fresh_b, &exodus, &prof, 120);

        assert_bitwise_equal(&want_a, &got_a, "pooled slab, cell A");
        assert_bitwise_equal(&want_b, &got_b, "pooled slab, cell B");
    }

    #[test]
    fn repeated_runs_on_one_slab_are_exact() {
        // reset() must fully re-seed state: 3 runs over one slab, each
        // bit-identical to the naive oracle.
        let net = zoo::gaia();
        let prof = crate::net::DatasetProfile::femnist();
        let topo = MultigraphTopology::from_network(&net, &prof, 30);
        let ft = FactoredTopology::compile(&topo).unwrap();
        let mut slab = FactoredSlab::new(&ft, &net, &prof);
        for rounds in [90usize, 250, 90] {
            let (got, _) = run_factored(&ft, &mut slab, &net, &prof, rounds);
            let mut fresh = MultigraphTopology::from_network(&net, &prof, 30);
            let want = simulate_summary_naive(&mut fresh, &net, &prof, rounds);
            assert_bitwise_equal(&want, &got, &format!("{rounds} rounds"));
        }
    }

    #[test]
    fn factored_is_exact_on_every_profile() {
        let net = zoo::gaia();
        for prof in crate::net::DatasetProfile::all() {
            let mut a = MultigraphTopology::from_network(&net, &prof, 10);
            let b = MultigraphTopology::from_network(&net, &prof, 10);
            let naive = simulate_summary_naive(&mut a, &net, &prof, 200);
            let (fast, _) = simulate_summary_factored_with_stats(&b, &net, &prof, 200).unwrap();
            assert_bitwise_equal(&naive, &fast, &prof.name);
        }
    }

    #[test]
    #[should_panic(expected = "silos")]
    fn slab_rejects_mismatched_network() {
        let prof = crate::net::DatasetProfile::femnist();
        let topo = MultigraphTopology::from_network(&zoo::gaia(), &prof, 5);
        let ft = FactoredTopology::compile(&topo).unwrap();
        let _ = FactoredSlab::new(&ft, &zoo::exodus(), &prof);
    }
}
