//! Cross-cell SoA batched periodic engine: step many cells in lockstep
//! through one compiled schedule.
//!
//! Every engine before this one accelerates a *single* cell; the
//! workloads that matter run thousands. A sweep grid fans one design
//! out over profiles and seeds, and `mgfl optimize` evaluates thousands
//! of candidates against one network — so after the dedup layer the
//! remaining unique cells still contain groups that share one schedule
//! ([`CompiledTopology`]) and differ only in their delay inputs (the
//! per-cell `d0`/backlog the [`super::DelaySlab`] resolves). This
//! module executes such a group as **one** walk over the per-round edge
//! tables:
//!
//! * **Structure of arrays.** The per-edge Eq. 4 backlog of all lanes
//!   is one contiguous slab indexed `[edge][lane]`, with the lane count
//!   padded to a power of two (`stride`) so the inner loops — the Eq. 5
//!   τ max-reduce and the Eq. 4 weak-edge drain — are fixed-stride
//!   walks the compiler can auto-vectorize. Padding lanes replicate
//!   lane 0's inputs (finite, positive — the arithmetic stays benign)
//!   and their results are discarded.
//! * **No cross-lane arithmetic.** Lane `j`'s values never touch lane
//!   `i`'s: each lane performs exactly the f64 op sequence
//!   [`super::run_compiled`] would perform for it alone — same d₀ seed
//!   via [`pair_d0_ms`], same per-round reduce/advance order, same
//!   sequential `total_ms` accumulation. Bit-identity with the naive
//!   oracle is therefore inherited per lane, not re-argued: the batch
//!   is a scheduling change, not a numerical one.
//! * **Per-lane cycle detection.** The exact-recurrence fast path runs
//!   independently per lane (each lane snapshots *its own* backlog bits
//!   at period boundaries, records its own τ sequence, and replays the
//!   moment its own recurrence fires) so a lane's reported
//!   `simulated_rounds`/`cycle_*` stats — which ride in sweep
//!   artifacts — are identical whether the cell ran solo or in any
//!   batch composition. Replay-heavy cells are why the batch planner
//!   never needs a special "replay hit" fallback: a lane that would
//!   replay solo replays in the batch at the same round.
//!
//! Lanes must share the representative's schedule *structurally*
//! ([`CompiledTopology::schedule_eq`] — name excluded, so two designs
//! that happen to compile to the same schedule may share a batch while
//! keeping their own report names). The sweep batch planner
//! ([`crate::sweep::cache`]) discovers such groups from the post-dedup
//! unique-cell set; `mgfl optimize` batches same-schedule candidate
//! evaluations the same way.

use crate::delay::{pair_d0_ms, EdgeType};
use crate::net::{DatasetProfile, NetworkSpec};

use super::compiled::{CompiledTopology, EngineKind, EngineStats, MAX_SNAPSHOTS};
use super::SimSummary;

/// Maximum lanes per batch. Eight f64 lanes are two AVX2 (or one
/// AVX-512) vectors per edge visit — wide enough to amortize the
/// schedule walk, small enough that the SoA slab of a large-N cell
/// group stays cache-resident.
pub const LANE_WIDTH: usize = 8;

/// Smallest structural group the sweep planner batches. Groups below
/// this run the ordinary per-cell path — a single-lane batch is legal
/// (the no-dedup engine uses it for labeling parity) but buys nothing.
pub const MIN_BATCH: usize = 2;

/// One cell of a batch: the lane's own compiled schedule (structurally
/// equal to the batch representative's; kept so the lane's report name
/// is its own) plus the (network, profile) its delays resolve against.
pub struct BatchLane<'a> {
    /// The lane's own compile — `schedule_eq` to the representative.
    pub ct: &'a CompiledTopology,
    /// Network the lane's d₀ values resolve against.
    pub net: &'a NetworkSpec,
    /// Dataset profile (model size, floor u·T_c) of the lane.
    pub profile: &'a DatasetProfile,
}

/// Reusable SoA scratch for [`run_batched`]: the `[edge][lane]` d₀ and
/// backlog slabs plus the per-lane floor/τ rows. Lives in
/// [`super::SimScratch`] so sweep workers reuse one allocation across
/// every batch they execute.
#[derive(Debug, Clone, Default)]
pub struct BatchSlab {
    d0: Vec<f64>,
    backlog: Vec<f64>,
    floor: Vec<f64>,
    tau: Vec<f64>,
}

/// Step every lane through `rep`'s schedule in lockstep; returns one
/// `(SimSummary, EngineStats)` per lane, in lane order, each
/// bit-identical to what [`super::run_compiled`] would produce for that
/// lane alone (stats report [`EngineKind::Batched`]; every other stats
/// field — period, cycle round, simulated rounds — matches the solo run
/// exactly, because detection and replay are per-lane).
///
/// Panics if `lanes` is empty, exceeds [`LANE_WIDTH`], or a lane's
/// network size disagrees with the schedule; debug builds additionally
/// verify every lane's schedule is structurally equal to `rep`'s.
pub fn run_batched(
    rep: &CompiledTopology,
    lanes: &[BatchLane<'_>],
    rounds: usize,
    slab: &mut BatchSlab,
) -> Vec<(SimSummary, EngineStats)> {
    assert!(rounds > 0);
    assert!(
        !lanes.is_empty() && lanes.len() <= LANE_WIDTH,
        "batch must hold 1..={LANE_WIDTH} lanes, got {}",
        lanes.len()
    );
    let p = rep.period();
    let n_edges = rep.num_edges();
    for lane in lanes {
        assert_eq!(
            lane.net.n(),
            rep.n(),
            "lane network '{}' has {} silos but the schedule was compiled over {}",
            lane.net.name,
            lane.net.n(),
            rep.n()
        );
        debug_assert!(
            lane.ct.schedule_eq(rep),
            "batched lane '{}' does not share the representative schedule '{}'",
            lane.ct.name(),
            rep.name()
        );
    }
    let l = lanes.len();
    let stride = l.next_power_of_two();

    // Resolve per-lane delay inputs into the SoA layout. Each lane's d₀
    // comes from pair_d0_ms over the representative's edge table — the
    // identical seeding run_compiled's DelaySlab performs (schedule_eq
    // guarantees identical edge identities). Padding lanes replicate
    // lane 0 so every slot holds finite positive values.
    slab.d0.clear();
    slab.d0.resize(n_edges * stride, 0.0);
    for (e, ce) in rep.edge_table().iter().enumerate() {
        let base = e * stride;
        for (j, lane) in lanes.iter().enumerate() {
            slab.d0[base + j] = pair_d0_ms(
                lane.net,
                lane.profile,
                ce.u as usize,
                ce.v as usize,
                ce.deg_u as usize,
                ce.deg_v as usize,
            );
        }
        for j in l..stride {
            slab.d0[base + j] = slab.d0[base];
        }
    }
    slab.floor.clear();
    slab.floor.resize(stride, 0.0);
    for (j, lane) in lanes.iter().enumerate() {
        slab.floor[j] = lane.profile.u as f64 * lane.profile.t_c_ms;
    }
    for j in l..stride {
        slab.floor[j] = slab.floor[0];
    }
    // Backlog seeds to d₀ (Alg. 1 seeds from the all-strong overlay),
    // mirroring DelaySlab::reset.
    slab.backlog.clear();
    slab.backlog.extend_from_slice(&slab.d0);
    slab.tau.clear();
    slab.tau.resize(stride, 0.0);

    // Split-borrow the slab fields so the strong-edge reset can copy
    // d0 -> backlog slices while both live in one struct.
    let BatchSlab { d0, backlog, floor, tau } = slab;
    let d0: &[f64] = d0;
    let floor: &[f64] = floor;

    let mut total = vec![0.0f64; l];
    let mut riso = vec![0usize; l];
    let mut miso = vec![0usize; l];
    // The cycle detector state is per lane — each lane mirrors
    // run_compiled's detector over its own backlog bits.
    let mut detecting = vec![p < rounds; l];
    let mut rec_tau: Vec<Vec<f64>> = vec![Vec::new(); l];
    let mut snapshots: Vec<Vec<(usize, Vec<u64>)>> = vec![Vec::new(); l];
    let mut cycle: Vec<Option<(usize, usize)>> = vec![None; l];
    let mut sim_rounds = vec![rounds; l];
    let mut done = vec![false; l];
    let mut live = l;

    let mut k = 0usize;
    while k < rounds && live > 0 {
        let s = k % p;
        if s == 0 {
            for j in 0..l {
                if done[j] || !detecting[j] {
                    continue;
                }
                let snap: Vec<u64> =
                    (0..n_edges).map(|e| backlog[e * stride + j].to_bits()).collect();
                if let Some(&(k0, _)) = snapshots[j].iter().find(|(_, old)| *old == snap) {
                    // Lane j's state entering round k repeats round k0's:
                    // replay its recorded τ sequence for the rest of the
                    // run — the same sequential adds run_compiled does —
                    // and freeze the lane before this round steps.
                    let len = k - k0;
                    cycle[j] = Some((k0, len));
                    sim_rounds[j] = k;
                    for jj in k..rounds {
                        total[j] += rec_tau[j][k0 + (jj - k0) % len];
                        let iso = rep.state(jj % p).1;
                        if iso > 0 {
                            riso[j] += 1;
                            miso[j] = miso[j].max(iso);
                        }
                    }
                    done[j] = true;
                    live -= 1;
                } else if snapshots[j].len() >= MAX_SNAPSHOTS {
                    // Give up for this lane only: stop paying for its
                    // snapshots and τ recording.
                    detecting[j] = false;
                    rec_tau[j] = Vec::new();
                    snapshots[j] = Vec::new();
                } else {
                    snapshots[j].push((k, snap));
                }
            }
            if live == 0 {
                break;
            }
        }

        // One lockstep round: the Eq. 5 reduce then the Eq. 4 advance,
        // walking the shared edge table once for all lanes. Per lane
        // this is exactly run_compiled's step_edges (serial reduce; the
        // advance in plan order). Replayed lanes keep stepping in the
        // SoA — their values stay finite and their accumulators are
        // frozen below, so the extra arithmetic is waste, never error.
        let (st_edges, isolated) = rep.state(s);
        tau.copy_from_slice(floor);
        for &(id, ty) in st_edges {
            if ty == EdgeType::Strong {
                let base = id as usize * stride;
                for j in 0..stride {
                    tau[j] = tau[j].max(floor[j].max(backlog[base + j]));
                }
            }
        }
        for &(id, ty) in st_edges {
            let base = id as usize * stride;
            match ty {
                EdgeType::Strong => {
                    backlog[base..base + stride].copy_from_slice(&d0[base..base + stride]);
                }
                EdgeType::Weak => {
                    for j in 0..stride {
                        let b = &mut backlog[base + j];
                        *b = (*b - tau[j]).max(floor[j]);
                    }
                }
            }
        }

        for j in 0..l {
            if done[j] {
                continue;
            }
            total[j] += tau[j];
            if isolated > 0 {
                riso[j] += 1;
                miso[j] = miso[j].max(isolated);
            }
            if detecting[j] {
                rec_tau[j].push(tau[j]);
            }
        }
        k += 1;
    }

    lanes
        .iter()
        .enumerate()
        .map(|(j, lane)| {
            let summary = SimSummary {
                topology: lane.ct.name().to_string(),
                network: lane.net.name.clone(),
                profile: lane.profile.name.clone(),
                rounds,
                mean_cycle_ms: total[j] / rounds as f64,
                total_ms: total[j],
                rounds_with_isolated: riso[j],
                max_isolated: miso[j],
                scenario: None,
            };
            let stats = EngineStats {
                kind: EngineKind::Batched,
                period: Some(p),
                cycle_detected_at: cycle[j].map(|_| sim_rounds[j]),
                cycle_len: cycle[j].map(|(_, len)| len),
                simulated_rounds: sim_rounds[j],
                groups: None,
            };
            (summary, stats)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{zoo, DatasetProfile};
    use crate::simtime::compiled::run_compiled;
    use crate::simtime::{simulate_summary_naive, DelaySlab};
    use crate::topo::ring::RingTopology;
    use crate::topo::MultigraphTopology;

    fn assert_bitwise(a: &SimSummary, b: &SimSummary, ctx: &str) {
        assert_eq!(a.topology, b.topology, "{ctx}");
        assert_eq!(a.network, b.network, "{ctx}");
        assert_eq!(a.profile, b.profile, "{ctx}");
        assert_eq!(
            a.total_ms.to_bits(),
            b.total_ms.to_bits(),
            "{ctx}: total_ms {} vs {}",
            a.total_ms,
            b.total_ms
        );
        assert_eq!(a.mean_cycle_ms.to_bits(), b.mean_cycle_ms.to_bits(), "{ctx}");
        assert_eq!(a.rounds_with_isolated, b.rounds_with_isolated, "{ctx}");
        assert_eq!(a.max_isolated, b.max_isolated, "{ctx}");
    }

    #[test]
    fn ring_lanes_across_profiles_match_solo_and_naive() {
        // The planner's bread-and-butter group: one static design fanned
        // over the profile axis. Zoo capacities are uniform, so the ring
        // overlay — and hence the compiled schedule — is profile-
        // independent; only the delay numbers differ per lane.
        for net in zoo::all_networks() {
            let profiles = DatasetProfile::all();
            let mut compiles: Vec<CompiledTopology> = profiles
                .iter()
                .map(|prof| {
                    let mut topo = RingTopology::new(&net, prof);
                    CompiledTopology::compile(&mut topo, 90).expect("ring is periodic")
                })
                .collect();
            let rep = compiles.remove(0);
            for ct in &compiles {
                assert!(ct.schedule_eq(&rep), "{}: ring must be profile-independent", net.name);
            }
            let all: Vec<&CompiledTopology> =
                std::iter::once(&rep).chain(compiles.iter()).collect();
            let lanes: Vec<BatchLane> = profiles
                .iter()
                .zip(&all)
                .map(|(prof, ct)| BatchLane { ct, net: &net, profile: prof })
                .collect();
            let mut slab = BatchSlab::default();
            let got = run_batched(&rep, &lanes, 90, &mut slab);
            assert_eq!(got.len(), 3);
            for ((prof, ct), (summary, stats)) in profiles.iter().zip(&all).zip(&got) {
                let mut naive_topo = RingTopology::new(&net, prof);
                let want = simulate_summary_naive(&mut naive_topo, &net, prof, 90);
                assert_bitwise(summary, &want, &format!("{}/{}", net.name, prof.name));
                // Stats must equal the solo periodic run's, kind aside.
                let mut delay = DelaySlab::new(ct, &net, prof);
                let (_, solo) = run_compiled(ct, &mut delay, &net, prof, 90);
                assert_eq!(stats.kind, EngineKind::Batched);
                assert_eq!(stats.period, solo.period);
                assert_eq!(stats.cycle_detected_at, solo.cycle_detected_at);
                assert_eq!(stats.cycle_len, solo.cycle_len);
                assert_eq!(stats.simulated_rounds, solo.simulated_rounds);
            }
        }
    }

    #[test]
    fn identical_multigraph_lanes_replay_like_the_solo_engine() {
        // Eight copies of one cell (the bench's timing shape): every
        // lane must detect the cycle at the same round as a solo run and
        // come out bitwise equal to it — and to the naive oracle.
        let net = zoo::gaia();
        let prof = DatasetProfile::femnist();
        let rounds = 400;
        let mut topo = MultigraphTopology::from_network(&net, &prof, 5);
        let rep = CompiledTopology::compile(&mut topo, rounds).expect("gaia t=5 materializes");
        let lanes: Vec<BatchLane> = (0..LANE_WIDTH)
            .map(|_| BatchLane { ct: &rep, net: &net, profile: &prof })
            .collect();
        let mut slab = BatchSlab::default();
        let got = run_batched(&rep, &lanes, rounds, &mut slab);

        let mut delay = DelaySlab::new(&rep, &net, &prof);
        let (solo, solo_stats) = run_compiled(&rep, &mut delay, &net, &prof, rounds);
        assert!(solo_stats.cycle_detected_at.is_some(), "test premise: replay fires");
        let mut naive_topo = MultigraphTopology::from_network(&net, &prof, 5);
        let want = simulate_summary_naive(&mut naive_topo, &net, &prof, rounds);
        for (j, (summary, stats)) in got.iter().enumerate() {
            assert_bitwise(summary, &solo, &format!("lane {j} vs solo"));
            assert_bitwise(summary, &want, &format!("lane {j} vs naive"));
            assert_eq!(stats.cycle_detected_at, solo_stats.cycle_detected_at, "lane {j}");
            assert_eq!(stats.cycle_len, solo_stats.cycle_len, "lane {j}");
            assert_eq!(stats.simulated_rounds, solo_stats.simulated_rounds, "lane {j}");
        }
    }

    #[test]
    fn odd_lane_counts_pad_without_perturbing_results() {
        // 3 lanes pad to stride 4; the padding lane replicates lane 0
        // and must not change any real lane's bits.
        let net = zoo::exodus();
        let profiles = DatasetProfile::all();
        let rounds = 70;
        let compiles: Vec<CompiledTopology> = profiles
            .iter()
            .map(|prof| {
                let mut topo = RingTopology::new(&net, prof);
                CompiledTopology::compile(&mut topo, rounds).expect("periodic")
            })
            .collect();
        let lanes: Vec<BatchLane> = profiles
            .iter()
            .zip(&compiles)
            .map(|(prof, ct)| BatchLane { ct, net: &net, profile: prof })
            .collect();
        let mut slab = BatchSlab::default();
        let got = run_batched(&compiles[0], &lanes, rounds, &mut slab);
        for ((prof, ct), (summary, _)) in profiles.iter().zip(&compiles).zip(&got) {
            let mut delay = DelaySlab::new(ct, &net, prof);
            let (want, _) = run_compiled(ct, &mut delay, &net, prof, rounds);
            assert_bitwise(summary, &want, &format!("padded lane {}", prof.name));
        }
    }

    #[test]
    fn single_lane_batch_equals_run_compiled_bitwise() {
        // The no-dedup engine labels batchable cells and runs them as
        // 1-lane batches; that path must be exactly run_compiled.
        let net = zoo::gaia();
        let prof = DatasetProfile::sentiment140();
        for rounds in [1usize, 2, 59, 200] {
            let mut topo = MultigraphTopology::from_network(&net, &prof, 3);
            let Some(rep) = CompiledTopology::compile(&mut topo, rounds) else {
                continue;
            };
            let lane = BatchLane { ct: &rep, net: &net, profile: &prof };
            let mut slab = BatchSlab::default();
            let got = run_batched(&rep, std::slice::from_ref(&lane), rounds, &mut slab);
            let mut delay = DelaySlab::new(&rep, &net, &prof);
            let (want, want_stats) = run_compiled(&rep, &mut delay, &net, &prof, rounds);
            assert_bitwise(&got[0].0, &want, &format!("rounds {rounds}"));
            let stats = got[0].1;
            assert_eq!(stats.period, want_stats.period);
            assert_eq!(stats.cycle_detected_at, want_stats.cycle_detected_at);
            assert_eq!(stats.cycle_len, want_stats.cycle_len);
            assert_eq!(stats.simulated_rounds, want_stats.simulated_rounds);
        }
    }

    #[test]
    fn slab_reuse_across_batches_is_exact() {
        // One BatchSlab reused across differently-shaped batches must
        // fully re-resolve (the sweep workers pool it per thread).
        let gaia = zoo::gaia();
        let exodus = zoo::exodus();
        let prof = DatasetProfile::femnist();
        let mut slab = BatchSlab::default();
        for net in [&gaia, &exodus, &gaia] {
            let mut topo = RingTopology::new(net, &prof);
            let rep = CompiledTopology::compile(&mut topo, 50).expect("periodic");
            let lanes = [
                BatchLane { ct: &rep, net, profile: &prof },
                BatchLane { ct: &rep, net, profile: &prof },
            ];
            let got = run_batched(&rep, &lanes, 50, &mut slab);
            let mut naive_topo = RingTopology::new(net, &prof);
            let want = simulate_summary_naive(&mut naive_topo, net, &prof, 50);
            assert_bitwise(&got[0].0, &want, &net.name);
            assert_bitwise(&got[1].0, &want, &net.name);
        }
    }

    #[test]
    #[should_panic(expected = "silos")]
    fn mismatched_lane_network_is_rejected() {
        let gaia = zoo::gaia();
        let prof = DatasetProfile::femnist();
        let mut topo = RingTopology::new(&gaia, &prof);
        let rep = CompiledTopology::compile(&mut topo, 50).unwrap();
        let exodus = zoo::exodus();
        let lane = BatchLane { ct: &rep, net: &exodus, profile: &prof };
        let _ = run_batched(&rep, std::slice::from_ref(&lane), 50, &mut BatchSlab::default());
    }
}
