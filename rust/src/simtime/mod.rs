//! Round-by-round time simulator: drives any [`TopologyDesign`] through
//! the paper's delay model and reports cycle times (Eq. 5).
//!
//! This is the rust re-implementation of the PyTorch/MPI time simulator
//! the paper borrows from Marfoq et al. (§5.1 "Time Simulator"): wall
//! clock is *simulated* from the delay equations, decoupled from how
//! long the local hardware takes, which is exactly how the paper's
//! cycle-time tables are produced.

pub mod batched;
pub mod compiled;
pub mod factored;
pub mod scenario;

use std::collections::HashMap;

use crate::delay::{pair_d0_ms, round_cycle_time_ms, EdgeDelayState, EdgeType};
use crate::net::{DatasetProfile, NetworkSpec};
use crate::topo::TopologyDesign;

pub use batched::{run_batched, BatchLane, BatchSlab, LANE_WIDTH, MIN_BATCH};
pub use compiled::{
    run_compiled, simulate_summary_compiled, simulate_summary_compiled_with_stats,
    simulate_summary_scratch, simulate_summary_streaming_scratch,
    simulate_summary_streaming_with_stats, CompiledTopology, DelaySlab, EngineKind, EngineStats,
    SimScratch, StreamScratch,
};
pub use factored::{
    run_factored, simulate_summary_factored_with_stats, FactoredSlab, FactoredTopology,
};
pub use scenario::{
    build_timeline, run_scenario_batched, run_scenario_compiled, run_scenario_factored,
    run_spliced, simulate_summary_scenario, simulate_summary_scenario_naive, AdaptMetrics, Event,
    EventKind, OutageWindow, ScenarioMetrics, ScenarioSpec, Segment, SegmentMetrics, SplicedPhase,
    Timeline,
};

/// Simulation output for one (topology, network, profile) cell.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Design name (from [`crate::topo::TopologyDesign::name`]).
    pub topology: String,
    /// Network name.
    pub network: String,
    /// Dataset-profile name.
    pub profile: String,
    /// Simulated communication rounds.
    pub rounds: usize,
    /// Mean cycle time over rounds, ms (Eq. 5) — the Table 1 number.
    pub mean_cycle_ms: f64,
    /// Simulated total wall-clock, ms.
    pub total_ms: f64,
    /// Per-round cycle time, ms (Fig. 5 bottom row x-axis).
    pub per_round_ms: Vec<f64>,
    /// Rounds in which at least one node was isolated (Table 3).
    pub rounds_with_isolated: usize,
    /// Max isolated-node count seen in any round.
    pub max_isolated: usize,
}

impl SimResult {
    /// Cumulative wall-clock at each round boundary (for loss-vs-time).
    pub fn cumulative_ms(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.per_round_ms
            .iter()
            .map(|&c| {
                acc += c;
                acc
            })
            .collect()
    }
}

/// Incremental Eq. 4 delay tracker: feed it one [`crate::topo::RoundPlan`]
/// per round, get the round's cycle time back. Shared by [`simulate`] and
/// the real training coordinator so simulated clocks agree everywhere.
pub struct DelayTracker {
    net: NetworkSpec,
    profile: DatasetProfile,
    // Eq. 4 state per undirected pair (delays are symmetric under the
    // paper's uniform 10 Gbps capacities; we track the pair max).
    edge_state: HashMap<(usize, usize), EdgeDelayState>,
}

/// Per-round output of [`DelayTracker::step`].
#[derive(Debug, Clone, Copy)]
pub struct RoundTime {
    /// τ_k: this round's cycle time, ms (Eq. 5 inner max).
    pub cycle_ms: f64,
    /// Number of isolated nodes this round.
    pub isolated: usize,
}

impl DelayTracker {
    /// Fresh tracker with no per-pair Eq. 4 state yet.
    pub fn new(net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        DelayTracker { net: net.clone(), profile: profile.clone(), edge_state: HashMap::new() }
    }

    /// Current backlog of a pair, if tracked (diagnostics).
    pub fn pair_delay_ms(&self, u: usize, v: usize) -> Option<f64> {
        let key = if u <= v { (u, v) } else { (v, u) };
        self.edge_state.get(&key).map(|s| s.d())
    }

    /// Advance one round under `plan`; returns τ_k and isolation stats.
    pub fn step(&mut self, plan: &crate::topo::RoundPlan) -> RoundTime {
        let degrees = plan.degrees();
        // Delays for this round: persistent Eq. 4 state for pairs we have
        // seen; fresh Eq. 3 for pairs entering the schedule (their d_0 is
        // the current-plan-degree delay, matching Alg. 1's overlay seed).
        // Keys are normalized u <= v — the same normalization as
        // `pair_delay_ms` — so a design emitting (v, u) cannot silently
        // fork a pair into two delay states.
        let mut strong_delays = Vec::new();
        for &(u, v, ty) in &plan.edges {
            let key = if u <= v { (u, v) } else { (v, u) };
            let st = self.edge_state.entry(key).or_insert_with(|| {
                let d0 = pair_d0_ms(&self.net, &self.profile, u, v, degrees[u], degrees[v]);
                EdgeDelayState::new(d0)
            });
            if ty == EdgeType::Strong {
                strong_delays.push(st.strong_delay_ms(&self.profile));
            }
        }

        let tau = round_cycle_time_ms(strong_delays.iter().copied(), &self.profile);

        // Advance Eq. 4 for every pair present this round.
        for &(u, v, ty) in &plan.edges {
            let key = if u <= v { (u, v) } else { (v, u) };
            self.edge_state.get_mut(&key).unwrap().advance(ty, tau, &self.profile);
        }

        RoundTime { cycle_ms: tau, isolated: plan.isolated_nodes().len() }
    }
}

/// Compact simulation output: everything [`SimResult`] carries except the
/// per-round trace. The sweep engine runs thousands of cells at 6400
/// rounds each; dropping the per-round `Vec` keeps a full paper-grid
/// sweep's resident set flat.
#[derive(Debug, Clone)]
pub struct SimSummary {
    /// Design name (from [`crate::topo::TopologyDesign::name`]).
    pub topology: String,
    /// Network name.
    pub network: String,
    /// Dataset-profile name.
    pub profile: String,
    /// Simulated communication rounds.
    pub rounds: usize,
    /// Mean cycle time over rounds, ms (Eq. 5) — the Table 1 number.
    pub mean_cycle_ms: f64,
    /// Simulated total wall-clock, ms.
    pub total_ms: f64,
    /// Rounds in which at least one node was isolated.
    pub rounds_with_isolated: usize,
    /// Max isolated-node count seen in any round.
    pub max_isolated: usize,
    /// Degraded-mode metrics — `Some` iff the cell ran under a
    /// fault-injection scenario ([`scenario::ScenarioSpec`]).
    pub scenario: Option<ScenarioMetrics>,
}

/// Like [`simulate`] but without recording the per-round trace.
///
/// Summation order over rounds is fixed (sequential accumulation), so for
/// a given (topology, network, profile, rounds, seed) the result is
/// bit-identical wherever it runs — the property the sweep determinism
/// test pins down.
///
/// Since PR 2 this runs on the compiled zero-allocation engine
/// ([`compiled`]): a dense edge arena plus an exact cycle-detection fast
/// path for periodic schedules. Since PR 5 the dispatcher additionally
/// routes schedules that expose a multiplicity factorization (the
/// parsed multigraph at any t) to the period-factorized engine
/// ([`factored`]) when their period is too large to materialize —
/// O(distinct multiplicities) per round instead of O(E). Every engine
/// is pinned bit-identical to the [`DelayTracker`] reference path
/// ([`simulate_summary_naive`]) by the simcore/factored benches, unit
/// tests, and the proptest suites.
pub fn simulate_summary(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> SimSummary {
    compiled::simulate_summary_compiled(topo, net, profile, rounds)
}

/// The reference implementation of [`simulate_summary`]: one
/// [`DelayTracker`] step per round, allocating plans and hashing pair
/// keys. Kept as the oracle the compiled engine is verified against —
/// never deleted, never optimized.
pub fn simulate_summary_naive(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> SimSummary {
    assert!(rounds > 0);
    let mut tracker = DelayTracker::new(net, profile);
    let mut total_ms = 0.0;
    let mut rounds_with_isolated = 0;
    let mut max_isolated = 0;

    for k in 0..rounds {
        let plan = topo.plan(k);
        let rt = tracker.step(&plan);
        total_ms += rt.cycle_ms;
        if rt.isolated > 0 {
            rounds_with_isolated += 1;
            max_isolated = max_isolated.max(rt.isolated);
        }
    }

    SimSummary {
        topology: topo.name().to_string(),
        network: net.name.clone(),
        profile: profile.name.clone(),
        rounds,
        mean_cycle_ms: total_ms / rounds as f64,
        total_ms,
        rounds_with_isolated,
        max_isolated,
        scenario: None,
    }
}

/// Simulate `rounds` communication rounds of `topo` on `net`/`profile`.
///
/// Static all-strong designs reduce to the constant Eq. 3 max; the
/// multigraph exercises the full Eq. 4 recurrence: per-directed-edge
/// delay states evolve with the strong/weak schedule, and each round's
/// cycle time is the max strong-edge delay (floored by u*T_c).
pub fn simulate(
    topo: &mut dyn TopologyDesign,
    net: &NetworkSpec,
    profile: &DatasetProfile,
    rounds: usize,
) -> SimResult {
    assert!(rounds > 0);
    let mut tracker = DelayTracker::new(net, profile);
    let mut per_round_ms = Vec::with_capacity(rounds);
    let mut rounds_with_isolated = 0;
    let mut max_isolated = 0;

    for k in 0..rounds {
        let plan = topo.plan(k);
        let rt = tracker.step(&plan);
        per_round_ms.push(rt.cycle_ms);
        if rt.isolated > 0 {
            rounds_with_isolated += 1;
            max_isolated = max_isolated.max(rt.isolated);
        }
    }

    let total_ms: f64 = per_round_ms.iter().sum();
    SimResult {
        topology: topo.name().to_string(),
        network: net.name.clone(),
        profile: profile.name.clone(),
        rounds,
        mean_cycle_ms: total_ms / rounds as f64,
        total_ms,
        per_round_ms,
        rounds_with_isolated,
        max_isolated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::eq3_delay_ms;
    use crate::net::zoo;
    use crate::topo::ring::RingTopology;
    use crate::topo::star::StarTopology;
    use crate::topo::MultigraphTopology;

    #[test]
    fn static_ring_cycle_is_constant_max_edge_delay() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut ring = RingTopology::new(&net, &p);
        let res = simulate(&mut ring, &net, &p, 50);
        // All rounds identical.
        let first = res.per_round_ms[0];
        assert!(res.per_round_ms.iter().all(|&c| (c - first).abs() < 1e-9));
        assert_eq!(res.rounds_with_isolated, 0);
        // Equals the max Eq. 3 delay over ring edges at degree 2.
        let overlay = ring.overlay().clone();
        let expect = overlay
            .edges()
            .iter()
            .map(|e| {
                eq3_delay_ms(&net, &p, e.u, e.v, 2, 2)
                    .max(eq3_delay_ms(&net, &p, e.v, e.u, 2, 2))
            })
            .fold(0.0, f64::max);
        assert!((first - expect).abs() < 1e-9, "{first} vs {expect}");
    }

    #[test]
    fn multigraph_beats_ring_on_gaia_femnist() {
        // The paper's headline (Table 1): ours < RING. Gaia FEMNIST
        // reduction is 3.6x in the paper; require at least 1.2x here.
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut ring = RingTopology::new(&net, &p);
        let mut ours = MultigraphTopology::from_network(&net, &p, 5);
        let r_ring = simulate(&mut ring, &net, &p, 600);
        let r_ours = simulate(&mut ours, &net, &p, 600);
        assert!(
            r_ours.mean_cycle_ms < r_ring.mean_cycle_ms / 1.2,
            "ours {} vs ring {}",
            r_ours.mean_cycle_ms,
            r_ring.mean_cycle_ms
        );
        assert!(r_ours.rounds_with_isolated > 0);
    }

    #[test]
    fn star_slower_than_ring_on_wide_networks() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut star = StarTopology::new(&net, &p);
        let mut ring = RingTopology::new(&net, &p);
        let s = simulate(&mut star, &net, &p, 20);
        let r = simulate(&mut ring, &net, &p, 20);
        assert!(
            s.mean_cycle_ms > r.mean_cycle_ms,
            "star {} ring {}",
            s.mean_cycle_ms,
            r.mean_cycle_ms
        );
    }

    #[test]
    fn summary_matches_full_simulation_bitwise() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut a = MultigraphTopology::from_network(&net, &p, 5);
        let mut b = MultigraphTopology::from_network(&net, &p, 5);
        let full = simulate(&mut a, &net, &p, 120);
        let summary = simulate_summary(&mut b, &net, &p, 120);
        assert_eq!(full.total_ms.to_bits(), summary.total_ms.to_bits());
        assert_eq!(full.mean_cycle_ms.to_bits(), summary.mean_cycle_ms.to_bits());
        assert_eq!(full.rounds_with_isolated, summary.rounds_with_isolated);
        assert_eq!(full.max_isolated, summary.max_isolated);
        assert_eq!(summary.topology, "multigraph");
    }

    #[test]
    fn cumulative_is_monotone() {
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut ours = MultigraphTopology::from_network(&net, &p, 5);
        let res = simulate(&mut ours, &net, &p, 30);
        let cum = res.cumulative_ms();
        assert_eq!(cum.len(), 30);
        assert!(cum.windows(2).all(|w| w[1] > w[0]));
        assert!((cum[29] - res.total_ms).abs() < 1e-9);
    }

    #[test]
    fn first_round_multigraph_equals_ring_round() {
        // State 0 is the overlay: the very first multigraph round must
        // cost the same as a RING round.
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mut ring = RingTopology::new(&net, &p);
        let mut ours = MultigraphTopology::from_network(&net, &p, 5);
        let r = simulate(&mut ring, &net, &p, 1);
        let o = simulate(&mut ours, &net, &p, 1);
        assert!((r.per_round_ms[0] - o.per_round_ms[0]).abs() < 1e-9);
    }
}
