//! Metrics: per-round training records, loss curves, CSV/JSON writers,
//! and the `mgfl optimize` search artifact ([`search::SearchReport`]).

pub mod search;

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Json;

/// One communication round of a real training run.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// Simulated cycle time of this round, ms (Eq. 5 term).
    pub cycle_ms: f64,
    /// Simulated cumulative wall-clock, ms.
    pub sim_elapsed_ms: f64,
    /// Mean local training loss across silos.
    pub train_loss: f64,
    /// Isolated-node count this round.
    pub isolated: usize,
    /// Eval metrics, present on eval rounds.
    pub eval_loss: Option<f64>,
    pub eval_acc: Option<f64>,
}

/// A full training trace.
#[derive(Debug, Clone, Default)]
pub struct TrainTrace {
    pub topology: String,
    pub network: String,
    pub model: String,
    pub records: Vec<RoundRecord>,
    /// Real (host) wall-clock of the whole run, ms — for §Perf.
    pub host_elapsed_ms: f64,
}

impl TrainTrace {
    pub fn new(topology: &str, network: &str, model: &str) -> Self {
        TrainTrace {
            topology: topology.into(),
            network: network.into(),
            model: model.into(),
            ..Default::default()
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.eval_acc)
    }

    pub fn final_train_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.train_loss)
    }

    pub fn total_sim_ms(&self) -> f64 {
        self.records.last().map(|r| r.sim_elapsed_ms).unwrap_or(0.0)
    }

    pub fn mean_cycle_ms(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.cycle_ms).sum::<f64>() / self.records.len() as f64
    }

    /// Write a CSV with one row per round (Fig. 5 raw data).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        writeln!(f, "round,cycle_ms,sim_elapsed_ms,train_loss,isolated,eval_loss,eval_acc")?;
        for r in &self.records {
            writeln!(
                f,
                "{},{:.4},{:.4},{:.6},{},{},{}",
                r.round,
                r.cycle_ms,
                r.sim_elapsed_ms,
                r.train_loss,
                r.isolated,
                r.eval_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
                r.eval_acc.map(|v| format!("{v:.4}")).unwrap_or_default(),
            )?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let recs: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("round".into(), Json::Num(r.round as f64));
                m.insert("cycle_ms".into(), Json::Num(r.cycle_ms));
                m.insert("sim_elapsed_ms".into(), Json::Num(r.sim_elapsed_ms));
                m.insert("train_loss".into(), Json::Num(r.train_loss));
                m.insert("isolated".into(), Json::Num(r.isolated as f64));
                m.insert(
                    "eval_loss".into(),
                    r.eval_loss.map(Json::Num).unwrap_or(Json::Null),
                );
                m.insert("eval_acc".into(), r.eval_acc.map(Json::Num).unwrap_or(Json::Null));
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("topology".into(), Json::Str(self.topology.clone()));
        top.insert("network".into(), Json::Str(self.network.clone()));
        top.insert("model".into(), Json::Str(self.model.clone()));
        top.insert("host_elapsed_ms".into(), Json::Num(self.host_elapsed_ms));
        top.insert("records".into(), Json::Arr(recs));
        Json::Obj(top)
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string())
            .with_context(|| format!("writing {}", path.as_ref().display()))?;
        Ok(())
    }
}

/// Nearest-rank percentile over an ascending-sorted slice: the element
/// at index `floor((len − 1) · q)`. Deterministic pure selection — no
/// interpolation, no new f64s — so every engine computing degraded-mode
/// percentiles from bit-identical series reports bit-identical values.
///
/// Panics on an empty slice; `q` is clamped to `[0, 1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty series");
    let q = q.clamp(0.0, 1.0);
    let idx = ((sorted.len() - 1) as f64 * q).floor() as usize;
    sorted[idx]
}

/// Render an aligned text table (CLI output for the paper tables).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Render a pivot grid: one row per `row_keys` entry, one column per
/// `col_keys` entry, the corner labelled `corner`, and every body cell
/// produced by `cell(row, col)`. This is how sweep-report slices become
/// paper-style tables (rows = networks, cols = topologies, …) without
/// the caller hand-assembling string matrices.
pub fn render_pivot(
    corner: &str,
    row_keys: &[String],
    col_keys: &[String],
    cell: impl Fn(&str, &str) -> String,
) -> String {
    let mut headers: Vec<&str> = vec![corner];
    headers.extend(col_keys.iter().map(String::as_str));
    let rows: Vec<Vec<String>> = row_keys
        .iter()
        .map(|r| {
            let mut row = vec![r.clone()];
            row.extend(col_keys.iter().map(|c| cell(r, c)));
            row
        })
        .collect();
    render_table(&headers, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> TrainTrace {
        let mut t = TrainTrace::new("multigraph", "gaia", "femnist_mlp");
        t.push(RoundRecord {
            round: 0,
            cycle_ms: 50.0,
            sim_elapsed_ms: 50.0,
            train_loss: 4.0,
            isolated: 0,
            eval_loss: None,
            eval_acc: None,
        });
        t.push(RoundRecord {
            round: 1,
            cycle_ms: 10.0,
            sim_elapsed_ms: 60.0,
            train_loss: 3.0,
            isolated: 2,
            eval_loss: Some(3.1),
            eval_acc: Some(0.42),
        });
        t
    }

    #[test]
    fn summary_stats() {
        let t = trace();
        assert_eq!(t.final_accuracy(), Some(0.42));
        assert_eq!(t.final_train_loss(), Some(3.0));
        assert_eq!(t.total_sim_ms(), 60.0);
        assert!((t.mean_cycle_ms() - 30.0).abs() < 1e-12);
    }

    fn temp_path(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("mgfl_test_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip_shape() {
        let t = trace();
        let path = temp_path("trace.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[2].contains("0.4200"));
    }

    #[test]
    fn json_writes_parseable_trace() {
        let t = trace();
        let path = temp_path("trace.json");
        t.write_json(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("topology").unwrap().as_str().unwrap(), "multigraph");
        assert_eq!(j.get("records").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn pivot_renders_every_cell() {
        let rows = vec!["gaia".to_string(), "amazon".to_string()];
        let cols = vec!["ring".to_string(), "multigraph".to_string()];
        let s = render_pivot("network", &rows, &cols, |r, c| format!("{r}:{c}"));
        assert!(s.contains("network"));
        assert!(s.contains("gaia:ring"));
        assert!(s.contains("amazon:multigraph"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 0.5), 3.0);
        assert_eq!(percentile(&s, 0.95), 4.0); // floor(4 * 0.95) = 3
        assert_eq!(percentile(&s, 1.0), 5.0);
        assert_eq!(percentile(&[7.5], 0.95), 7.5);
        assert_eq!(percentile(&s, 2.0), 5.0); // clamped
    }

    #[test]
    fn table_renders_aligned() {
        let s = render_table(
            &["net", "ours", "ring"],
            &[vec!["gaia".into(), "15.7".into(), "57.2".into()]],
        );
        assert!(s.contains("gaia"));
        assert!(s.lines().count() == 3);
    }
}
