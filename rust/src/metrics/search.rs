//! Structured `mgfl optimize` output: the accepted-move trace of every
//! chain, the baselines the searched topology is judged against, and
//! JSON/CSV artifact writers in [`crate::sweep::SweepReport`] style.
//!
//! Like sweep reports, a [`SearchReport`] is deliberately free of
//! wall-clock and thread-count fields: it is a pure function of its
//! [`crate::search::OptimizeSpec`], so the same spec + seed produces
//! byte-identical artifacts on 1 thread and N threads (pinned by
//! `tests/search_determinism.rs`). Host-side timing lives in
//! [`crate::search::SearchOutcome`] instead.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::Json;

/// One genome with its fitness, as reported (start / best candidates).
#[derive(Debug, Clone)]
pub struct CandidateSummary {
    /// Ring visit order (`order[0] == 0`).
    pub order: Vec<usize>,
    /// Chord edges beyond the ring, sorted, each `u < v`.
    pub chords: Vec<(usize, usize)>,
    /// Algorithm 1's t for this candidate.
    pub t: u32,
    /// The canonical dedup key ([`crate::search::Genome::canonical_key`]).
    pub key: String,
    /// Simulated fitness: mean Eq. 5 cycle time, ms.
    pub mean_cycle_ms: f64,
}

/// One accepted transition of a chain (or its start / a restart).
#[derive(Debug, Clone)]
pub struct TraceStep {
    /// Proposal step the transition happened at (0 = chain start).
    pub step: usize,
    /// Move name (`two_opt`, `or_opt`, `t_up`, `t_down`, `chord_add`,
    /// `chord_drop`) or the synthetic `start` / `restart` markers.
    pub mv: String,
    /// Fitness after the transition, ms.
    pub fitness_ms: f64,
}

/// The full trajectory of one search chain.
#[derive(Debug, Clone)]
pub struct ChainTrace {
    /// Chain index (chain 0 starts from the paper design).
    pub chain: usize,
    /// Where the chain started.
    pub start: CandidateSummary,
    /// The best candidate the chain ever held.
    pub best: CandidateSummary,
    /// Accepted transitions (trace entries past the start marker).
    pub accepted: usize,
    /// The accepted-move trace, step-ordered, starting with `start`.
    pub trace: Vec<TraceStep>,
}

/// A reference design the search result is compared against.
#[derive(Debug, Clone)]
pub struct BaselineRow {
    /// Design name (`multigraph`, `ring`).
    pub topology: String,
    /// Algorithm 1's t the baseline was built with.
    pub t: u32,
    /// Simulated mean cycle time, ms.
    pub mean_cycle_ms: f64,
}

/// One MATCHA budget probed alongside the overlay search.
#[derive(Debug, Clone)]
pub struct BudgetProbe {
    /// Per-round matching activation budget, in (0, 1].
    pub budget: f64,
    /// Simulated mean cycle time, ms.
    pub mean_cycle_ms: f64,
}

/// The full result of one `mgfl optimize` run.
#[derive(Debug, Clone)]
pub struct SearchReport {
    /// Artifact stem (`optimize_<name>.json` / `.csv`).
    pub name: String,
    /// Canonical network name searched over.
    pub network: String,
    /// Canonical dataset profile name.
    pub profile: String,
    /// Strategy that drove the chains (`hill` / `anneal`).
    pub strategy: String,
    /// Simulated rounds per fitness evaluation.
    pub rounds: usize,
    /// The spec's base seed (all chain streams derive from it).
    pub seed: u64,
    /// Every chain's trajectory, in chain order.
    pub chains: Vec<ChainTrace>,
    /// Reference designs (paper multigraph at `baseline_t`, ring).
    pub baselines: Vec<BaselineRow>,
    /// MATCHA budget probes (empty unless the spec lists budgets).
    pub budget_probes: Vec<BudgetProbe>,
    /// Index into `chains` of the winning chain (first minimum).
    pub best_chain: usize,
    /// The searched winner across all chains.
    pub best: CandidateSummary,
    /// `100 · (1 − best / multigraph-baseline)` — positive means the
    /// searched topology beats the paper's design.
    pub improvement_pct: f64,
    /// Distinct genomes simulated (canonical-key dedup).
    pub unique_evals: usize,
    /// Fitness lookups served from the dedup cache.
    pub cache_hits: usize,
    /// True when any chain stopped at the spec's wall-clock deadline
    /// ([`crate::search::OptimizeSpec::deadline_ms`]) before consuming
    /// its full step budget. Always `false` at the `deadline_ms = 0`
    /// default, where the pure-function-of-spec contract holds; a
    /// nonzero deadline is explicitly host-dependent, and this flag is
    /// how an artifact discloses that a run was truncated.
    pub budget_exhausted: bool,
}

fn candidate_json(c: &CandidateSummary) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "order".into(),
        Json::Arr(c.order.iter().map(|&x| Json::Num(x as f64)).collect()),
    );
    m.insert(
        "chords".into(),
        Json::Arr(
            c.chords
                .iter()
                .map(|&(u, v)| Json::Arr(vec![Json::Num(u as f64), Json::Num(v as f64)]))
                .collect(),
        ),
    );
    m.insert("t".into(), Json::Num(c.t as f64));
    m.insert("key".into(), Json::Str(c.key.clone()));
    m.insert("mean_cycle_ms".into(), Json::Num(c.mean_cycle_ms));
    Json::Obj(m)
}

impl SearchReport {
    /// JSON artifact (deterministic: BTreeMap keys, chain-ordered
    /// traces, no host timing).
    pub fn to_json(&self) -> Json {
        let chains: Vec<Json> = self
            .chains
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert("chain".into(), Json::Num(c.chain as f64));
                m.insert("start".into(), candidate_json(&c.start));
                m.insert("best".into(), candidate_json(&c.best));
                m.insert("accepted".into(), Json::Num(c.accepted as f64));
                let trace: Vec<Json> = c
                    .trace
                    .iter()
                    .map(|s| {
                        let mut t = BTreeMap::new();
                        t.insert("step".into(), Json::Num(s.step as f64));
                        t.insert("move".into(), Json::Str(s.mv.clone()));
                        t.insert("fitness_ms".into(), Json::Num(s.fitness_ms));
                        Json::Obj(t)
                    })
                    .collect();
                m.insert("trace".into(), Json::Arr(trace));
                Json::Obj(m)
            })
            .collect();
        let baselines: Vec<Json> = self
            .baselines
            .iter()
            .map(|b| {
                let mut m = BTreeMap::new();
                m.insert("topology".into(), Json::Str(b.topology.clone()));
                m.insert("t".into(), Json::Num(b.t as f64));
                m.insert("mean_cycle_ms".into(), Json::Num(b.mean_cycle_ms));
                Json::Obj(m)
            })
            .collect();
        let probes: Vec<Json> = self
            .budget_probes
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("budget".into(), Json::Num(p.budget));
                m.insert("mean_cycle_ms".into(), Json::Num(p.mean_cycle_ms));
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("name".into(), Json::Str(self.name.clone()));
        top.insert("network".into(), Json::Str(self.network.clone()));
        top.insert("profile".into(), Json::Str(self.profile.clone()));
        top.insert("strategy".into(), Json::Str(self.strategy.clone()));
        top.insert("rounds".into(), Json::Num(self.rounds as f64));
        // Base seeds are validated < 2^53 so the JSON number is exact.
        top.insert("seed".into(), Json::Num(self.seed as f64));
        top.insert("chains".into(), Json::Arr(chains));
        top.insert("baselines".into(), Json::Arr(baselines));
        top.insert("budget_probes".into(), Json::Arr(probes));
        top.insert("best_chain".into(), Json::Num(self.best_chain as f64));
        top.insert("best".into(), candidate_json(&self.best));
        top.insert("improvement_pct".into(), Json::Num(self.improvement_pct));
        top.insert("unique_evals".into(), Json::Num(self.unique_evals as f64));
        top.insert("cache_hits".into(), Json::Num(self.cache_hits as f64));
        top.insert("budget_exhausted".into(), Json::Bool(self.budget_exhausted));
        Json::Obj(top)
    }

    /// CSV artifact: the accepted-move trace, one row per transition,
    /// chain-major step-minor (deterministic).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("chain,step,move,mean_cycle_ms\n");
        for c in &self.chains {
            for s in &c.trace {
                let _ = writeln!(out, "{},{},{},{:.6}", c.chain, s.step, s.mv, s.fitness_ms);
            }
        }
        out
    }

    /// Write `<dir>/optimize_<name>.json` + `.csv`; returns both paths.
    pub fn write_artifacts(&self, dir: impl AsRef<Path>) -> Result<(PathBuf, PathBuf)> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        let json_path = dir.join(format!("optimize_{}.json", self.name));
        let csv_path = dir.join(format!("optimize_{}.csv", self.name));
        std::fs::write(&json_path, self.to_json().to_string())
            .with_context(|| format!("writing {}", json_path.display()))?;
        std::fs::write(&csv_path, self.to_csv())
            .with_context(|| format!("writing {}", csv_path.display()))?;
        Ok((json_path, csv_path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn candidate(t: u32, f: f64) -> CandidateSummary {
        CandidateSummary {
            order: vec![0, 2, 1],
            chords: vec![(0, 1)],
            t,
            key: format!("overlay/o=0,1,2;c=0-1;t={t}"),
            mean_cycle_ms: f,
        }
    }

    fn report() -> SearchReport {
        SearchReport {
            name: "test".into(),
            network: "gaia".into(),
            profile: "femnist".into(),
            strategy: "hill".into(),
            rounds: 60,
            seed: 17,
            chains: vec![ChainTrace {
                chain: 0,
                start: candidate(5, 20.0),
                best: candidate(7, 14.5),
                accepted: 2,
                trace: vec![
                    TraceStep { step: 0, mv: "start".into(), fitness_ms: 20.0 },
                    TraceStep { step: 3, mv: "two_opt".into(), fitness_ms: 16.25 },
                    TraceStep { step: 9, mv: "t_up".into(), fitness_ms: 14.5 },
                ],
            }],
            baselines: vec![BaselineRow {
                topology: "multigraph".into(),
                t: 5,
                mean_cycle_ms: 20.0,
            }],
            budget_probes: vec![BudgetProbe { budget: 0.5, mean_cycle_ms: 33.0 }],
            best_chain: 0,
            best: candidate(7, 14.5),
            improvement_pct: 27.5,
            unique_evals: 9,
            cache_hits: 4,
            budget_exhausted: false,
        }
    }

    #[test]
    fn json_is_parseable_and_complete() {
        let r = report();
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "test");
        assert_eq!(j.get("best_chain").unwrap().as_usize().unwrap(), 0);
        let best = j.get("best").unwrap();
        assert_eq!(best.get("t").unwrap().as_usize().unwrap(), 7);
        assert_eq!(best.get("order").unwrap().as_arr().unwrap().len(), 3);
        let chains = j.get("chains").unwrap().as_arr().unwrap();
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].get("trace").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            chains[0].get("trace").unwrap().as_arr().unwrap()[1]
                .get("move")
                .unwrap()
                .as_str()
                .unwrap(),
            "two_opt"
        );
        assert_eq!(j.get("baselines").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("budget_probes").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("unique_evals").unwrap().as_usize().unwrap(), 9);
        assert_eq!(j.get("budget_exhausted").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn csv_lists_the_trace_in_order() {
        let csv = report().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "chain,step,move,mean_cycle_ms");
        assert_eq!(lines[1], "0,0,start,20.000000");
        assert_eq!(lines[2], "0,3,two_opt,16.250000");
        assert_eq!(lines[3], "0,9,t_up,14.500000");
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn artifacts_round_trip_through_disk() {
        let dir = std::env::temp_dir().join(format!("mgfl_search_report_{}", std::process::id()));
        let (json_path, csv_path) = report().write_artifacts(&dir).unwrap();
        assert!(json_path.ends_with("optimize_test.json"));
        let parsed = Json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
        assert_eq!(parsed.get("chains").unwrap().as_arr().unwrap().len(), 1);
        assert!(std::fs::read_to_string(&csv_path).unwrap().starts_with("chain,"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
