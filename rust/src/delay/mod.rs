//! The paper's delay model: Eq. 3 (static edge delay), Eq. 4 (per-round
//! delay recurrence over the multigraph), Eq. 5 (cycle time).
//!
//! Delays are *directed*: d(i, j) is the time for node j to receive node
//! i's model. Capacity is shared across concurrent transfers — Eq. 3's
//! O(i,j) divides i's upload capacity by its out-degree and j's download
//! capacity by its in-degree (uploads and downloads run in parallel, so
//! the two do not add).

use crate::net::{DatasetProfile, NetworkSpec};

/// Edge connection type in a multigraph state (paper §3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeType {
    /// e(i,j) = 1 — both endpoints wait for the transfer (synchronous).
    Strong,
    /// e(i,j) = 0 — transfer is asynchronous; nobody waits.
    Weak,
}

/// Eq. 3: d(i,j) = u*T_c(i) + l(i,j) + M / O(i,j), in ms.
///
/// `out_deg_i` = |N_i^-| (concurrent uploads at i), `in_deg_j` = |N_j^+|
/// (concurrent downloads at j); both >= 1.
pub fn eq3_delay_ms(
    net: &NetworkSpec,
    profile: &DatasetProfile,
    i: usize,
    j: usize,
    out_deg_i: usize,
    in_deg_j: usize,
) -> f64 {
    assert!(out_deg_i >= 1 && in_deg_j >= 1, "degrees must be >= 1");
    let capacity = (net.silos[i].up_gbps / out_deg_i as f64)
        .min(net.silos[j].dn_gbps / in_deg_j as f64);
    // M [Mbit] / C [Gbit/s] = ms exactly.
    profile.u as f64 * profile.t_c_ms + net.latency_ms(i, j) + profile.model_size_mbits / capacity
}

/// Symmetrized pair delay: the max of the two directed Eq. 3 delays,
/// which is what seeds an [`EdgeDelayState`] when a pair first enters the
/// schedule. Degrees are floored at 1 (a planned edge always implies at
/// least one concurrent transfer at each endpoint).
///
/// Both the reference [`crate::simtime::DelayTracker`] and the compiled
/// engine ([`crate::simtime::compiled`]) seed d_0 through this one
/// function, so the two paths stay bit-identical by construction.
pub fn pair_d0_ms(
    net: &NetworkSpec,
    profile: &DatasetProfile,
    u: usize,
    v: usize,
    deg_u: usize,
    deg_v: usize,
) -> f64 {
    let du = eq3_delay_ms(net, profile, u, v, deg_u.max(1), deg_v.max(1));
    let dv = eq3_delay_ms(net, profile, v, u, deg_v.max(1), deg_u.max(1));
    du.max(dv)
}

/// Per-edge state for the Eq. 4 delay recurrence.
///
/// ## Deviation from the literal Eq. 4 (DESIGN.md §Substitutions)
///
/// Transcribing the paper's four cases verbatim produces a divergent
/// system: the weak/weak case `d_{k+1} = τ_k + d_{k-1}` grows without
/// bound and feeds back into τ through the strong-after-weak case,
/// which we verified sends Gaia cycle times to ~2000 ms (the paper's
/// own Table 1 numbers are ~16 ms, so the printed recurrence cannot be
/// what their simulator ran). We implement the physically-coherent
/// reading that preserves each case's *intent*:
///
/// * weak rounds transfer asynchronously in the background, so the
///   pending transfer's **backlog** drains by τ_k per round
///   (the paper's `d_k − d_{k-1}` = "delay minus what already elapsed");
/// * a strong round waits `max(u·T_c, backlog)` — exactly the paper's
///   strong-after-weak `max(u×T_c(j), ·)` floor;
/// * a steady strong edge waits its static Eq. 3 delay every round
///   (`d_{k+1} = d_k`, the paper's strong/strong case);
/// * after any strong round a fresh transfer starts (backlog resets to
///   the static delay).
///
/// Under this model a pair that is weak in w consecutive states
/// re-strengthens with residual `max(u·T_c, d0 − Σ τ)` — long-delay
/// pairs become cheap exactly when the multigraph gave them many weak
/// edges, which is the mechanism the paper's §4 describes.
#[derive(Debug, Clone, Copy)]
pub struct EdgeDelayState {
    /// Static Eq. 3 delay of the pair (fresh-transfer cost), ms.
    pub d0: f64,
    /// Remaining backlog of the in-flight transfer, ms.
    pub backlog: f64,
}

impl EdgeDelayState {
    pub fn new(d0: f64) -> Self {
        // Alg. 1 seeds edge delays from the overlay (all strong).
        EdgeDelayState { d0, backlog: d0 }
    }

    /// The delay this edge contributes if it is strong this round.
    pub fn strong_delay_ms(&self, profile: &DatasetProfile) -> f64 {
        (profile.u as f64 * profile.t_c_ms).max(self.backlog)
    }

    /// Current delay estimate d_k (diagnostics; equals the backlog).
    pub fn d(&self) -> f64 {
        self.backlog
    }

    /// Advance one round given this round's edge type and cycle time τ_k.
    pub fn advance(&mut self, this_type: EdgeType, tau_k_ms: f64, profile: &DatasetProfile) {
        let floor = profile.u as f64 * profile.t_c_ms;
        match this_type {
            // Synchronous round completed; the next round's transfer is
            // fresh, so the backlog resets to the static delay.
            EdgeType::Strong => self.backlog = self.d0,
            // Asynchronous round: the background transfer progressed by
            // the round's wall-clock τ_k.
            EdgeType::Weak => self.backlog = (self.backlog - tau_k_ms).max(floor),
        }
    }
}

/// Eq. 5 inner max for one round: the cycle time is the maximum delay
/// over strong directed edges, floored by the pure-local round length
/// u*T_c (the j = i term of \(\mathcal{N}_i^{++} \cup \{i\}\)).
pub fn round_cycle_time_ms(
    strong_delays: impl IntoIterator<Item = f64>,
    profile: &DatasetProfile,
) -> f64 {
    let local = profile.u as f64 * profile.t_c_ms;
    strong_delays.into_iter().fold(local, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;

    fn setup() -> (NetworkSpec, DatasetProfile) {
        (zoo::gaia(), DatasetProfile::femnist())
    }

    #[test]
    fn eq3_components_add_up() {
        let (net, p) = setup();
        let d = eq3_delay_ms(&net, &p, 0, 1, 1, 1);
        let expect = p.t_c_ms + net.latency_ms(0, 1) + p.model_size_mbits / 10.0;
        assert!((d - expect).abs() < 1e-9);
    }

    #[test]
    fn eq3_degree_divides_capacity() {
        let (net, p) = setup();
        let d1 = eq3_delay_ms(&net, &p, 0, 1, 1, 1);
        let d4 = eq3_delay_ms(&net, &p, 0, 1, 2, 4);
        // 4 concurrent downloads -> 2.5 Gbps -> transmission x4.
        let extra = p.model_size_mbits / 2.5 - p.model_size_mbits / 10.0;
        assert!((d4 - d1 - extra).abs() < 1e-9, "{d4} vs {d1}");
    }

    #[test]
    #[should_panic(expected = "degrees")]
    fn eq3_rejects_zero_degree() {
        let (net, p) = setup();
        eq3_delay_ms(&net, &p, 0, 1, 0, 1);
    }

    #[test]
    fn pair_d0_is_direction_symmetric_max() {
        let (net, p) = setup();
        let a = pair_d0_ms(&net, &p, 0, 1, 2, 3);
        let b = pair_d0_ms(&net, &p, 1, 0, 3, 2);
        assert_eq!(a.to_bits(), b.to_bits());
        let expect = eq3_delay_ms(&net, &p, 0, 1, 2, 3).max(eq3_delay_ms(&net, &p, 1, 0, 3, 2));
        assert_eq!(a.to_bits(), expect.to_bits());
        // Zero degrees are floored at 1, not rejected.
        assert!(pair_d0_ms(&net, &p, 0, 1, 0, 0) > 0.0);
    }

    #[test]
    fn eq4_steady_strong_keeps_static_delay() {
        let p = DatasetProfile::femnist();
        let mut s = EdgeDelayState::new(40.0);
        for _ in 0..5 {
            assert_eq!(s.strong_delay_ms(&p), 40.0);
            s.advance(EdgeType::Strong, 100.0, &p);
        }
        assert_eq!(s.d(), 40.0);
    }

    #[test]
    fn eq4_weak_rounds_drain_backlog() {
        let p = DatasetProfile::femnist();
        let mut s = EdgeDelayState::new(40.0);
        s.advance(EdgeType::Weak, 15.0, &p); // 40 - 15 = 25
        assert_eq!(s.d(), 25.0);
        s.advance(EdgeType::Weak, 12.0, &p); // 25 - 12 = 13
        assert_eq!(s.d(), 13.0);
    }

    #[test]
    fn eq4_backlog_floors_at_compute_time() {
        let p = DatasetProfile::femnist();
        let floor = p.u as f64 * p.t_c_ms;
        let mut s = EdgeDelayState::new(40.0);
        s.advance(EdgeType::Weak, 500.0, &p);
        assert_eq!(s.d(), floor, "backlog floors at u*T_c");
        assert_eq!(s.strong_delay_ms(&p), floor);
    }

    #[test]
    fn eq4_restrengthened_edge_waits_residual_only() {
        let p = DatasetProfile::femnist();
        let mut s = EdgeDelayState::new(100.0);
        s.advance(EdgeType::Weak, 30.0, &p); // 70 left
        s.advance(EdgeType::Weak, 30.0, &p); // 40 left
        assert_eq!(s.strong_delay_ms(&p), 40.0);
        // After a strong round, a fresh transfer restarts.
        s.advance(EdgeType::Strong, 40.0, &p);
        assert_eq!(s.strong_delay_ms(&p), 100.0);
    }

    #[test]
    fn eq4_system_converges_not_diverges() {
        // Regression for the literal-Eq.4 divergence: alternating
        // weak/strong must keep delays bounded by d0 forever.
        let p = DatasetProfile::femnist();
        let mut s = EdgeDelayState::new(80.0);
        for k in 0..1000 {
            let ty = if k % 5 == 0 { EdgeType::Strong } else { EdgeType::Weak };
            assert!(s.strong_delay_ms(&p) <= 80.0 + 1e-9, "round {k}: {}", s.d());
            s.advance(ty, 10.0, &p);
        }
    }

    #[test]
    fn cycle_time_is_max_with_local_floor() {
        let p = DatasetProfile::femnist();
        assert_eq!(round_cycle_time_ms([5.0, 30.0, 12.0], &p), 30.0);
        // No strong edges at all -> floor at u*T_c.
        assert_eq!(round_cycle_time_ms([], &p), p.u as f64 * p.t_c_ms);
    }
}
