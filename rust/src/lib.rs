#![warn(missing_docs)]

//! # mgfl — Multigraph Topology for Cross-Silo Federated Learning
//!
//! A rust + JAX/Pallas reproduction of *"Reducing Training Time in
//! Cross-Silo Federated Learning using Multigraph Topology"* (Do et al.,
//! 2022).
//!
//! ## Architecture
//!
//! Three layers; Python never runs on the round path:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: topology
//!   designs ([`topo`]), the multigraph construction/parsing algorithms,
//!   the delay model ([`delay`]) and time simulator ([`simtime`]), and
//!   the DPASGD training coordinator ([`coordinator`]) that executes
//!   real rounds against the PJRT runtime.
//! * **Layer 2** — JAX model fwd/bwd (`python/compile/model.py`), AOT
//!   lowered once to HLO text in `artifacts/`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): MXU-tiled
//!   matmul, im2col conv, and the consensus aggregation kernel.
//!
//! ## Quick start
//!
//! ```no_run
//! use mgfl::net::{zoo, DatasetProfile};
//! use mgfl::topo::MultigraphTopology;
//! use mgfl::simtime::simulate;
//!
//! let net = zoo::gaia();
//! let profile = DatasetProfile::femnist();
//! let mut ours = MultigraphTopology::from_network(&net, &profile, 5);
//! let result = simulate(&mut ours, &net, &profile, 6400);
//! println!("mean cycle time: {:.1} ms", result.mean_cycle_ms);
//! ```
//!
//! ## Sweeps
//!
//! Paper tables are grids of independent simulations; the [`sweep`]
//! engine deduplicates any such grid into its unique work items, runs
//! those across threads, and writes deterministic JSON/CSV artifacts
//! (`mgfl sweep spec.toml` from the CLI):
//!
//! ```no_run
//! use mgfl::sweep::{self, Axis, RunOptions, SweepSpec};
//!
//! let spec = SweepSpec::table1(vec!["femnist".into()], 5, 6400);
//! let outcome = sweep::run(&spec, &RunOptions::default()).unwrap();
//! outcome.report.write_artifacts("results").unwrap();
//! print!("{}", outcome.report.render_slice(Axis::Network, Axis::Topology, |_| true));
//! ```
//!
//! ## Topology search
//!
//! The [`search`] module turns the simulator into a fitness oracle:
//! `mgfl optimize spec.toml` hill-climbs (or anneals) over ring orders,
//! chords, and `t` to find overlays whose simulated cycle time beats
//! the paper's hand-constructed multigraph, deterministically from a
//! spec + seed:
//!
//! ```no_run
//! use mgfl::search::{self, OptimizeSpec};
//! use mgfl::sweep::RunOptions;
//!
//! let spec = OptimizeSpec::default(); // gaia / femnist, hill-climbing
//! let outcome = search::run(&spec, &RunOptions::default()).unwrap();
//! println!(
//!     "best {:.3} ms ({:.1}% better than the paper multigraph)",
//!     outcome.report.best.mean_cycle_ms,
//!     outcome.report.improvement_pct
//! );
//! ```
//!
//! See `rust/docs/ARCHITECTURE.md` for the engine-dispatch decision
//! tree and the dedup/caching contracts, and `rust/docs/SPECS.md` for
//! the full TOML spec reference.

// The `missing_docs` lint is enforced on the substrate the search and
// sweep engines expose (`topo`, `sweep`, `simtime`, `search`, and this
// root); modules still being documented carry an explicit allow so the
// docs CI job (`RUSTDOCFLAGS="-D warnings" cargo doc`) stays green
// while coverage expands.
#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod coordinator;
#[allow(missing_docs)]
pub mod data;
#[allow(missing_docs)]
pub mod delay;
#[allow(missing_docs)]
pub mod fl;
#[allow(missing_docs)]
pub mod graph;
#[allow(missing_docs)]
pub mod metrics;
#[allow(missing_docs)]
pub mod net;
#[allow(missing_docs)]
pub mod runtime;
pub mod search;
pub mod simtime;
pub mod store;
pub mod sweep;
pub mod topo;
#[allow(missing_docs)]
pub mod util;

/// Build every Table 1 topology for a (network, profile) pair, in the
/// paper's column order — one [`config::build_design`] call per kind,
/// so this list can never drift from what sweeps construct.
pub fn all_topologies(
    net: &net::NetworkSpec,
    profile: &net::DatasetProfile,
    t: u32,
    seed: u64,
) -> Vec<Box<dyn topo::TopologyDesign>> {
    config::TopologyKind::all()
        .iter()
        .map(|&kind| config::build_design(kind, net, profile, t, seed))
        .collect()
}
