//! # mgfl — Multigraph Topology for Cross-Silo Federated Learning
//!
//! A rust + JAX/Pallas reproduction of *"Reducing Training Time in
//! Cross-Silo Federated Learning using Multigraph Topology"* (Do et al.,
//! 2022).
//!
//! ## Architecture
//!
//! Three layers; Python never runs on the round path:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: topology
//!   designs ([`topo`]), the multigraph construction/parsing algorithms,
//!   the delay model ([`delay`]) and time simulator ([`simtime`]), and
//!   the DPASGD training coordinator ([`coordinator`]) that executes
//!   real rounds against the PJRT runtime.
//! * **Layer 2** — JAX model fwd/bwd (`python/compile/model.py`), AOT
//!   lowered once to HLO text in `artifacts/`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): MXU-tiled
//!   matmul, im2col conv, and the consensus aggregation kernel.
//!
//! ## Quick start
//!
//! ```no_run
//! use mgfl::net::{zoo, DatasetProfile};
//! use mgfl::topo::MultigraphTopology;
//! use mgfl::simtime::simulate;
//!
//! let net = zoo::gaia();
//! let profile = DatasetProfile::femnist();
//! let mut ours = MultigraphTopology::from_network(&net, &profile, 5);
//! let result = simulate(&mut ours, &net, &profile, 6400);
//! println!("mean cycle time: {:.1} ms", result.mean_cycle_ms);
//! ```
//!
//! ## Sweeps
//!
//! Paper tables are grids of independent simulations; the [`sweep`]
//! engine deduplicates any such grid into its unique work items, runs
//! those across threads, and writes deterministic JSON/CSV artifacts
//! (`mgfl sweep spec.toml` from the CLI):
//!
//! ```no_run
//! use mgfl::sweep::{self, Axis, RunOptions, SweepSpec};
//!
//! let spec = SweepSpec::table1(vec!["femnist".into()], 5, 6400);
//! let outcome = sweep::run(&spec, &RunOptions::default()).unwrap();
//! outcome.report.write_artifacts("results").unwrap();
//! print!("{}", outcome.report.render_slice(Axis::Network, Axis::Topology, |_| true));
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod fl;
pub mod graph;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod simtime;
pub mod sweep;
pub mod topo;
pub mod util;

/// Build every Table 1 topology for a (network, profile) pair, in the
/// paper's column order — one [`config::build_design`] call per kind,
/// so this list can never drift from what sweeps construct.
pub fn all_topologies(
    net: &net::NetworkSpec,
    profile: &net::DatasetProfile,
    t: u32,
    seed: u64,
) -> Vec<Box<dyn topo::TopologyDesign>> {
    config::TopologyKind::all()
        .iter()
        .map(|&kind| config::build_design(kind, net, profile, t, seed))
        .collect()
}
