//! Algorithm 2 — Multigraph Parsing (the paper's §4.2) and the
//! [`MultigraphTopology`] design that cycles through the parsed states.
//!
//! Algorithm 2's dynamic track list yields, for a pair with multiplicity
//! n, the periodic pattern strong, weak, ..., weak (period n): the pair
//! is strong exactly in states `s ≡ 0 (mod n)`. The first state (s = 0)
//! is therefore the full overlay, as the paper requires. We exploit this
//! closed form so the schedule is O(1) per edge per round and s_max (the
//! LCM, which reaches 2.3e9 at t = 30) never needs materializing; the
//! explicit list-based algorithm is kept in `parse_states_explicit` and
//! tested equal to the closed form.

use super::multigraph::Multigraph;
use super::{RoundPlan, ScheduleFactorization, TopologyDesign};
use crate::delay::EdgeType;
use crate::graph::{Graph, NodeId};

/// One parsed state \(\mathcal{G}_m^s\): a simple graph whose edges are
/// marked strong/weak, plus the derived isolated-node set.
#[derive(Debug, Clone)]
pub struct GraphState {
    /// State index s in `0..s_max`.
    pub index: u64,
    /// Every overlay pair with its strong/weak mark in this state.
    pub edges: Vec<(NodeId, NodeId, EdgeType)>,
    /// Nodes touching no strong edge (they skip this round entirely).
    pub isolated: Vec<NodeId>,
}

/// Edge type of a pair with multiplicity `n` in state `s` (closed form of
/// Algorithm 2's track-list update).
#[inline]
pub fn edge_type_in_state(n_edges: u32, s: u64) -> EdgeType {
    if s % n_edges as u64 == 0 {
        EdgeType::Strong
    } else {
        EdgeType::Weak
    }
}

/// Literal transcription of Algorithm 2 (list-based), for validation and
/// for Fig. 3/4-style state dumps. Materializes `min(s_max, cap)` states.
pub fn parse_states_explicit(mg: &Multigraph, cap: u64) -> Vec<GraphState> {
    let s_max = mg.s_max().min(cap);
    // \bar{L} initialized from L (line 2).
    let mut bar_l: Vec<u32> = mg.edges.iter().map(|e| e.n_edges).collect();
    let mut out = Vec::with_capacity(s_max as usize);
    for s in 0..s_max {
        let mut edges = Vec::with_capacity(mg.edges.len());
        for (idx, e) in mg.edges.iter().enumerate() {
            // Lines 7-14: strong iff the track equals the original count.
            let ty = if bar_l[idx] == e.n_edges { EdgeType::Strong } else { EdgeType::Weak };
            edges.push((e.u, e.v, ty));
            if bar_l[idx] == 1 {
                bar_l[idx] = e.n_edges; // reset (line 12)
            } else {
                bar_l[idx] -= 1; // decrement (line 14)
            }
        }
        let plan = RoundPlan::new(mg.n, edges.clone());
        out.push(GraphState { index: s, edges, isolated: plan.isolated_nodes() });
    }
    out
}

/// The paper's topology: overlay-derived multigraph cycled state by state.
pub struct MultigraphTopology {
    overlay: Graph,
    mg: Multigraph,
    s_max: u64,
}

impl MultigraphTopology {
    /// Wrap an already-constructed multigraph and its overlay.
    pub fn new(overlay: Graph, mg: Multigraph) -> Self {
        assert_eq!(overlay.n(), mg.n);
        let s_max = mg.s_max();
        MultigraphTopology { overlay, mg, s_max }
    }

    /// Convenience: RING overlay -> Algorithm 1 -> Algorithm 2. The
    /// overlay is built over the dense connectivity slab
    /// ([`crate::graph::ring_overlay_dense`]); Algorithm 1 itself only
    /// touches the O(N) overlay edges. Byte-identical to
    /// [`Self::from_network_reference`].
    pub fn from_network(
        net: &crate::net::NetworkSpec,
        profile: &crate::net::DatasetProfile,
        t: u32,
    ) -> Self {
        let overlay = crate::graph::ring_overlay_dense(&net.connectivity_dense(profile));
        let mg = Multigraph::construct(&overlay, net, profile, t);
        Self::new(overlay, mg)
    }

    /// Pre-overhaul construction over the sparse complete graph, kept
    /// as the dense path's byte-identity oracle.
    pub fn from_network_reference(
        net: &crate::net::NetworkSpec,
        profile: &crate::net::DatasetProfile,
        t: u32,
    ) -> Self {
        let conn = net.connectivity_graph(profile);
        let overlay = crate::graph::ring_overlay(&conn);
        let mg = Multigraph::construct(&overlay, net, profile, t);
        Self::new(overlay, mg)
    }

    /// The underlying Algorithm-1 multigraph (pairs + multiplicities).
    pub fn multigraph(&self) -> &Multigraph {
        &self.mg
    }

    /// Schedule period: lcm of the edge multiplicities.
    pub fn s_max(&self) -> u64 {
        self.s_max
    }

    /// The state used at round `k` (round-robin through states).
    pub fn state_index(&self, k: usize) -> u64 {
        k as u64 % self.s_max
    }

    /// Plan for an explicit state index (used by state-analysis tools).
    pub fn plan_for_state(&self, s: u64) -> RoundPlan {
        let mut plan = RoundPlan::empty(self.mg.n);
        self.plan_for_state_into(s, &mut plan);
        plan
    }

    /// Like [`Self::plan_for_state`] but reusing `out` — the per-edge
    /// closed-form pattern evaluated with zero allocation (the compiled
    /// engine's streaming path when s_max is too large to materialize).
    pub fn plan_for_state_into(&self, s: u64, out: &mut RoundPlan) {
        out.reset(self.mg.n);
        for e in &self.mg.edges {
            out.push(e.u, e.v, edge_type_in_state(e.n_edges, s));
        }
    }

    /// Indices of states (within one period, capped) containing at least
    /// one isolated node — paper Table 3's "#States" numerator.
    pub fn states_with_isolated(&self, cap: u64) -> Vec<u64> {
        (0..self.s_max.min(cap))
            .filter(|&s| !self.plan_for_state(s).isolated_nodes().is_empty())
            .collect()
    }
}

impl TopologyDesign for MultigraphTopology {
    fn name(&self) -> &str {
        "multigraph"
    }

    fn overlay(&self) -> &Graph {
        &self.overlay
    }

    fn plan(&mut self, k: usize) -> RoundPlan {
        self.plan_for_state(self.state_index(k))
    }

    fn plan_into(&mut self, k: usize, out: &mut RoundPlan) {
        self.plan_for_state_into(self.state_index(k), out);
    }

    fn period(&self) -> Option<u64> {
        Some(self.s_max)
    }

    /// The closed form of Algorithm 2, exported structurally: every
    /// round's plan is the full edge list ([`Self::plan_for_state_into`]
    /// pushes every pair), pair (u, v) strong iff `s % n(u,v) == 0`,
    /// and `s = k % s_max` with `n(u,v) | s_max` ⇒ `s % n == k % n`.
    fn factorization(&self) -> Option<ScheduleFactorization> {
        Some(ScheduleFactorization {
            n: self.mg.n,
            edges: self.mg.edges.iter().map(|e| (e.u, e.v, e.n_edges)).collect(),
        })
    }

    /// Algorithms 1 and 2 are deterministic in (network, profile, t);
    /// the schedule consumes no randomness.
    fn seed_sensitive(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{zoo, DatasetProfile};

    fn gaia_topo(t: u32) -> MultigraphTopology {
        MultigraphTopology::from_network(&zoo::gaia(), &DatasetProfile::femnist(), t)
    }

    #[test]
    fn closed_form_matches_explicit_algorithm2() {
        let topo = gaia_topo(5);
        let explicit = parse_states_explicit(topo.multigraph(), 60);
        assert_eq!(explicit.len() as u64, topo.s_max().min(60));
        for st in &explicit {
            let plan = topo.plan_for_state(st.index);
            assert_eq!(plan.edges, st.edges, "state {}", st.index);
            assert_eq!(plan.isolated_nodes(), st.isolated);
        }
    }

    #[test]
    fn first_state_is_the_overlay_all_strong() {
        let topo = gaia_topo(5);
        let plan = topo.plan_for_state(0);
        assert!(plan.edges.iter().all(|&(_, _, t)| t == EdgeType::Strong));
        assert_eq!(plan.edges.len(), topo.overlay().edges().len());
        assert!(plan.isolated_nodes().is_empty());
    }

    #[test]
    fn strong_edge_appears_every_n_states() {
        let topo = gaia_topo(5);
        for e in &topo.multigraph().edges {
            for s in 0..topo.s_max() {
                let expect = s % e.n_edges as u64 == 0;
                let got = edge_type_in_state(e.n_edges, s) == EdgeType::Strong;
                assert_eq!(expect, got);
            }
        }
    }

    #[test]
    fn gaia_t5_has_isolated_states() {
        // Paper Table 3: Gaia/FEMNIST t=5 -> 44/60 states have isolated
        // nodes. Exact count depends on the delay substitution; assert
        // the paper's qualitative claim: a majority of states do.
        let topo = gaia_topo(5);
        let iso = topo.states_with_isolated(u64::MAX);
        assert!(topo.s_max() >= 2);
        assert!(
            iso.len() as f64 >= 0.3 * topo.s_max() as f64,
            "{} / {} states isolated",
            iso.len(),
            topo.s_max()
        );
        // State 0 (the overlay) is never isolated.
        assert!(!iso.contains(&0));
    }

    #[test]
    fn t1_schedule_is_constant_ring() {
        let topo = gaia_topo(1);
        assert_eq!(topo.s_max(), 1);
        let p = topo.plan_for_state(0);
        assert!(p.isolated_nodes().is_empty());
    }

    #[test]
    fn period_cycles() {
        let mut topo = gaia_topo(3);
        let s_max = topo.s_max() as usize;
        let a = topo.plan(1);
        let b = topo.plan(1 + s_max);
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn isolated_nodes_have_only_weak_edges() {
        let topo = gaia_topo(5);
        for s in 0..topo.s_max() {
            let plan = topo.plan_for_state(s);
            for &i in &plan.isolated_nodes() {
                for &(u, v, ty) in &plan.edges {
                    if u == i || v == i {
                        assert_eq!(ty, EdgeType::Weak, "state {s}, node {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn dense_build_matches_reference_on_zoo() {
        let p = DatasetProfile::femnist();
        for net in [zoo::gaia(), zoo::exodus()] {
            let dense = MultigraphTopology::from_network(&net, &p, 5);
            let reference = MultigraphTopology::from_network_reference(&net, &p, 5);
            assert_eq!(dense.s_max(), reference.s_max(), "{}", net.name);
            assert_eq!(dense.multigraph().edges, reference.multigraph().edges, "{}", net.name);
            for s in 0..dense.s_max().min(8) {
                assert_eq!(
                    dense.plan_for_state(s).edges,
                    reference.plan_for_state(s).edges,
                    "{} state {s}",
                    net.name
                );
            }
        }
    }

    #[test]
    fn factorization_matches_plans_round_by_round() {
        // The factorization contract: plan(k) lists exactly the
        // factorization edges, in order, strong iff k % multiplicity
        // == 0 — pinned across more than one full period (s_max = 60
        // at t = 5) so the `s % n == k % n` reduction is exercised
        // past the period boundary.
        for t in [3u32, 5, 30] {
            let mut topo = gaia_topo(t);
            let f = topo.factorization().expect("multigraph factorizes");
            assert_eq!(f.n, topo.multigraph().n);
            assert_eq!(f.edges.len(), topo.multigraph().edges.len());
            let rounds = if topo.s_max() < 100 { topo.s_max() as usize + 13 } else { 150 };
            for k in 0..rounds {
                let plan = topo.plan(k);
                assert_eq!(plan.edges.len(), f.edges.len(), "t={t} round {k}");
                for (&(u, v, ty), &(fu, fv, m)) in plan.edges.iter().zip(&f.edges) {
                    assert_eq!((u, v), (fu, fv), "t={t} round {k}");
                    let expect =
                        if k as u64 % m as u64 == 0 { EdgeType::Strong } else { EdgeType::Weak };
                    assert_eq!(ty, expect, "t={t} round {k} pair ({u},{v}) mult {m}");
                }
            }
        }
    }

    #[test]
    fn large_t_s_max_not_materialized() {
        // t = 30 (paper Table 6 extreme): s_max may be astronomically
        // large; plan_for_state must stay O(edges).
        let topo = gaia_topo(30);
        let _ = topo.plan_for_state(topo.s_max() - 1);
        let _ = topo.states_with_isolated(100);
    }
}
