//! Availability-masked view of a [`TopologyDesign`]: the plan
//! invalidation layer the scenario engine ([`crate::simtime::scenario`])
//! uses when silos leave and rejoin.
//!
//! A [`MaskedTopology`] wraps an inner design with a per-silo up/down
//! mask and a round offset, and emits the inner design's plans with
//! every edge touching a down silo removed. The node set is untouched —
//! a down silo stays in the plan's `n` with zero edges, which under the
//! single isolation rule ([`RoundPlan::mark_participation`]: isolated ⇔
//! has an edge and no strong edge) counts it as *absent*, not isolated.
//! That is the paper-consistent reading: an isolated node is one the
//! schedule serves badly this round, not one that has left the
//! federation.
//!
//! The offset re-bases the inner round index: `plan(k)` delegates to
//! `inner.plan(offset + k)`, so a scenario segment starting at global
//! round `s` can be driven from local round 0 while the inner design
//! sees the true global schedule position. Filtering preserves plan
//! order, so delay-state updates walk edges in exactly the order the
//! unmasked design would — the property every engine's bit-identity
//! argument rests on.

use crate::graph::Graph;

use super::{RoundPlan, ScheduleFactorization, TopologyDesign};

/// A [`TopologyDesign`] filtered through a silo up/down mask, re-based
/// at a round offset. See the module docs.
pub struct MaskedTopology<'a> {
    inner: &'a mut dyn TopologyDesign,
    offset: usize,
    up: &'a [bool],
    scratch: RoundPlan,
}

impl<'a> MaskedTopology<'a> {
    /// Wrap `inner`, dropping every planned edge with a down endpoint
    /// and re-basing round `k` to inner round `offset + k`.
    ///
    /// Panics if the mask length disagrees with the overlay's silo
    /// count.
    pub fn new(inner: &'a mut dyn TopologyDesign, offset: usize, up: &'a [bool]) -> Self {
        assert_eq!(
            inner.overlay().n(),
            up.len(),
            "mask has {} entries but design '{}' covers {} silos",
            up.len(),
            inner.name(),
            inner.overlay().n()
        );
        MaskedTopology { inner, offset, up, scratch: RoundPlan::default() }
    }

    /// Silos currently up under the mask.
    pub fn up_count(&self) -> usize {
        self.up.iter().filter(|&&u| u).count()
    }
}

impl TopologyDesign for MaskedTopology<'_> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    /// The *unmasked* overlay: which pairs may ever communicate when
    /// everyone is up. Masking is a runtime availability statement, not
    /// a design change.
    fn overlay(&self) -> &Graph {
        self.inner.overlay()
    }

    fn plan(&mut self, k: usize) -> RoundPlan {
        let mut out = RoundPlan::default();
        self.plan_into(k, &mut out);
        out
    }

    fn plan_into(&mut self, k: usize, out: &mut RoundPlan) {
        self.inner.plan_into(self.offset + k, &mut self.scratch);
        out.reset(self.scratch.n);
        for &(u, v, ty) in &self.scratch.edges {
            if self.up[u] && self.up[v] {
                out.push(u, v, ty);
            }
        }
    }

    /// The inner period survives masking: the mask is round-constant
    /// and `(offset + k) % p` depends only on `k % p`.
    fn period(&self) -> Option<u64> {
        self.inner.period()
    }

    /// The inner factorization filtered by the mask — but only at
    /// offset 0. The factorization contract keys strong rounds to
    /// `k % m == 0` in the *caller's* round index; a nonzero offset
    /// shifts that phase, which [`ScheduleFactorization`] cannot
    /// express, so offset segments must handle the phase themselves
    /// (the scenario engine's factored runner does).
    fn factorization(&self) -> Option<ScheduleFactorization> {
        if self.offset != 0 {
            return None;
        }
        let f = self.inner.factorization()?;
        let edges: Vec<(usize, usize, u32)> =
            f.edges.into_iter().filter(|&(u, v, _)| self.up[u] && self.up[v]).collect();
        Some(ScheduleFactorization { n: f.n, edges })
    }

    fn seed_sensitive(&self) -> bool {
        self.inner.seed_sensitive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{zoo, DatasetProfile};
    use crate::topo::ring::RingTopology;
    use crate::topo::MultigraphTopology;

    #[test]
    fn full_mask_is_the_identity() {
        let net = zoo::gaia();
        let prof = DatasetProfile::femnist();
        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let mut b = MultigraphTopology::from_network(&net, &prof, 5);
        let up = vec![true; net.n()];
        let mut masked = MaskedTopology::new(&mut b, 0, &up);
        assert_eq!(masked.up_count(), net.n());
        for k in 0..40 {
            let want = a.plan(k);
            let got = masked.plan(k);
            assert_eq!(want.n, got.n);
            assert_eq!(want.edges, got.edges, "round {k}");
        }
        assert_eq!(masked.period(), a.period());
        assert_eq!(masked.seed_sensitive(), a.seed_sensitive());
        assert_eq!(masked.name(), "multigraph");
    }

    #[test]
    fn down_silo_loses_every_edge_but_stays_in_n() {
        let net = zoo::gaia();
        let prof = DatasetProfile::femnist();
        let mut inner = RingTopology::new(&net, &prof);
        let mut up = vec![true; net.n()];
        up[3] = false;
        let mut masked = MaskedTopology::new(&mut inner, 0, &up);
        let plan = masked.plan(0);
        assert_eq!(plan.n, net.n());
        assert!(plan.edges.iter().all(|&(u, v, _)| u != 3 && v != 3));
        assert!(!plan.edges.is_empty());
        // A down silo has no edges, so it is absent — never isolated.
        assert!(!plan.isolated_nodes().contains(&3));
        // Order of the surviving edges matches the unmasked plan.
        let mut fresh = RingTopology::new(&net, &prof);
        let unmasked = fresh.plan(0);
        let filtered: Vec<_> = unmasked
            .edges
            .iter()
            .copied()
            .filter(|&(u, v, _)| u != 3 && v != 3)
            .collect();
        assert_eq!(plan.edges, filtered);
    }

    #[test]
    fn offset_rebases_the_round_index() {
        let net = zoo::gaia();
        let prof = DatasetProfile::femnist();
        let mut a = MultigraphTopology::from_network(&net, &prof, 5);
        let mut b = MultigraphTopology::from_network(&net, &prof, 5);
        let up = vec![true; net.n()];
        let mut masked = MaskedTopology::new(&mut b, 7, &up);
        for k in 0..20 {
            assert_eq!(a.plan(7 + k).edges, masked.plan(k).edges, "round {k}");
        }
    }

    #[test]
    fn factorization_filters_at_offset_zero_and_hides_elsewhere() {
        let net = zoo::gaia();
        let prof = DatasetProfile::femnist();
        let mut inner = MultigraphTopology::from_network(&net, &prof, 20);
        let full = inner.factorization().expect("multigraph factorizes");
        let mut up = vec![true; net.n()];
        up[0] = false;
        {
            let masked = MaskedTopology::new(&mut inner, 0, &up);
            let f = masked.factorization().expect("offset 0 keeps the factorization");
            assert!(f.edges.len() < full.edges.len());
            assert!(f.edges.iter().all(|&(u, v, _)| u != 0 && v != 0));
        }
        let masked = MaskedTopology::new(&mut inner, 3, &up);
        assert!(masked.factorization().is_none(), "offset phase is inexpressible");
    }

    #[test]
    #[should_panic(expected = "mask")]
    fn mismatched_mask_length_is_rejected() {
        let net = zoo::gaia();
        let prof = DatasetProfile::femnist();
        let mut inner = RingTopology::new(&net, &prof);
        let up = vec![true; 3];
        let _ = MaskedTopology::new(&mut inner, 0, &up);
    }
}
