//! MST baseline (Prim 1957 — paper Table 1 "MST [72]"): the minimum
//! spanning tree of the delay-weighted connectivity graph, static.

use super::{RoundPlan, TopologyDesign};
use crate::graph::{prim_mst, prim_mst_dense, Graph};
use crate::net::{DatasetProfile, NetworkSpec};

/// Static MST design: every round is the all-strong minimum spanning
/// tree.
pub struct MstTopology {
    overlay: Graph,
}

impl MstTopology {
    /// Prim over the dense connectivity slab — byte-identical to
    /// [`Self::new_reference`], large-N viable.
    pub fn new(net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        MstTopology { overlay: prim_mst_dense(&net.connectivity_dense(profile)) }
    }

    /// Pre-overhaul construction over the sparse complete [`Graph`],
    /// kept as the dense path's byte-identity oracle.
    pub fn new_reference(net: &NetworkSpec, profile: &DatasetProfile) -> Self {
        let conn = net.connectivity_graph(profile);
        MstTopology { overlay: prim_mst(&conn) }
    }
}

impl TopologyDesign for MstTopology {
    fn name(&self) -> &str {
        "mst"
    }

    fn overlay(&self) -> &Graph {
        &self.overlay
    }

    fn plan(&mut self, _k: usize) -> RoundPlan {
        RoundPlan::all_strong(&self.overlay)
    }

    fn plan_into(&mut self, _k: usize, out: &mut RoundPlan) {
        RoundPlan::all_strong_into(&self.overlay, out);
    }

    /// Prim's MST is deterministic in (network, profile).
    fn seed_sensitive(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo;

    #[test]
    fn mst_spans_with_n_minus_1_edges() {
        let net = zoo::geant();
        let t = MstTopology::new(&net, &DatasetProfile::femnist());
        assert_eq!(t.overlay().edges().len(), net.n() - 1);
        assert!(t.overlay().is_connected());
    }

    #[test]
    fn mst_total_weight_below_ring() {
        // MST is the lightest spanning structure; the ring must be heavier.
        let net = zoo::gaia();
        let p = DatasetProfile::femnist();
        let mst = MstTopology::new(&net, &p);
        let ring = super::super::ring::RingTopology::new(&net, &p);
        assert!(mst.overlay().total_weight() <= ring.overlay().total_weight() + 1e-9);
    }

    #[test]
    fn dense_build_matches_reference_on_zoo() {
        let p = DatasetProfile::femnist();
        for net in [zoo::gaia(), zoo::geant()] {
            let dense = MstTopology::new(&net, &p);
            let reference = MstTopology::new_reference(&net, &p);
            let (a, b) = (dense.overlay().edges(), reference.overlay().edges());
            assert_eq!(a.len(), b.len(), "{}", net.name);
            for (x, y) in a.iter().zip(b) {
                assert_eq!((x.u, x.v, x.w.to_bits()), (y.u, y.v, y.w.to_bits()), "{}", net.name);
            }
        }
    }
}
